//! Bench: regenerates paper Table IV (single-crossbar WF instance costs)
//! and times the host-side mirror of the same computation (the Rust
//! banded WF), giving the host-vs-PIM comparison the paper's §IV
//! latency-reduction claims are framed against.
//!
//!     cargo bench --bench table4_crossbar

use dart_pim::align::banded_affine::affine_wf_band;
use dart_pim::align::banded_linear::linear_wf_band;
use dart_pim::eval::figures;
use dart_pim::params::{window_len, READ_LEN};
use dart_pim::pim::xbar_sim::{affine_instance_cost, linear_instance_cost, CostSource};
use dart_pim::util::bench::bench_units;
use dart_pim::util::SmallRng;

fn main() {
    println!("{}", figures::table4());

    // PIM-time per instance at the 2 ns cycle (paper §VII-B)
    let lin = linear_instance_cost(CostSource::PaperTable4);
    let aff = affine_instance_cost(CostSource::PaperTable4);
    println!(
        "PIM instance latency @2ns: linear {:.3} ms, affine {:.3} ms \
         (x32 / x8 instances in parallel per crossbar)\n",
        lin.total_cycles() as f64 * 2e-9 * 1e3,
        aff.total_cycles() as f64 * 2e-9 * 1e3
    );

    // Host mirror timings (for EXPERIMENTS.md §Perf)
    let mut rng = SmallRng::seed_from_u64(4);
    let read: Vec<u8> = (0..READ_LEN).map(|_| rng.gen_range(0..4)).collect();
    let mut win: Vec<u8> = (0..window_len(READ_LEN)).map(|_| rng.gen_range(0..4)).collect();
    win[6..6 + READ_LEN].copy_from_slice(&read);

    let s = bench_units("host linear_wf_band (1 instance)", 50, 2000, 1.0, &mut || {
        std::hint::black_box(linear_wf_band(&read, &win));
    });
    println!("{s}");
    let s = bench_units("host affine_wf_band (1 instance)", 20, 500, 1.0, &mut || {
        std::hint::black_box(affine_wf_band(&read, &win));
    });
    println!("{s}");
}
