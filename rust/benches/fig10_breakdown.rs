//! Bench: regenerates paper Fig. 10 — (a) execution-time breakdown,
//! (b) energy breakdown, (c) area breakdown — plus the Batched8 affine
//! ablation and the constructive-vs-paper cost-source ablation called
//! out in DESIGN.md.
//!
//!     cargo bench --bench fig10_breakdown

use dart_pim::eval::figures;
use dart_pim::pim::xbar_sim::CostSource;
use dart_pim::pim::DartPimConfig;
use dart_pim::simulator::report::{build_report, paper_workload_counts};
use dart_pim::simulator::TimingMode;

fn main() {
    println!("{}", figures::fig10a());
    println!("{}", figures::fig10b());
    println!("{}", figures::fig10c());

    // Ablation 1: affine lock-step accounting (PaperSerial vs Batched8)
    println!("ablation — affine iteration accounting (maxReads=25k):");
    let cfg = DartPimConfig::with_max_reads(25_000);
    let counts = paper_workload_counts(&cfg);
    for (name, timing) in
        [("PaperSerial", TimingMode::PaperSerial), ("Batched8", TimingMode::Batched8)]
    {
        let r = build_report(&counts, &cfg, CostSource::PaperTable4, timing);
        println!(
            "  {:<12} T={:>7.1}s  throughput={:>6.2} Mreads/s",
            name,
            r.exec_time_s,
            r.throughput() / 1e6
        );
    }

    // Ablation 2: cost source (published Table IV vs constructive op
    // sequences)
    println!("ablation — instance cost source (maxReads=25k):");
    for (name, cost) in
        [("PaperTable4", CostSource::PaperTable4), ("Constructive", CostSource::Constructive)]
    {
        let r = build_report(&counts, &cfg, cost, TimingMode::PaperSerial);
        println!(
            "  {:<12} T={:>7.1}s  E={:>7.1} kJ",
            name,
            r.exec_time_s,
            r.energy.total() / 1e3
        );
    }
}
