//! Bench: regenerates paper Fig. 8 (throughput vs accuracy scatter) and
//! measures our pipeline's accuracy on a live synthetic workload for the
//! DART-PIM points.
//!
//!     cargo bench --bench fig8_accuracy_throughput

use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::eval::accuracy::evaluate_accuracy;
use dart_pim::eval::figures;
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::RustEngine;

fn main() {
    println!("{}", figures::fig8());

    // live accuracy points across the maxReads sweep (the paper's
    // accuracy knob): mapping accuracy is measured, throughput is the
    // Eq. 6 model on the measured workload
    let genome = SynthConfig { len: 500_000, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads: 1500, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);

    println!("live synthetic accuracy (n={}):", reads.len());
    for max_reads in [12_500usize, 25_000, 50_000] {
        let cfg = PipelineConfig {
            dart: DartPimConfig { max_reads, low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let mut p = Pipeline::new(&index, cfg, RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        let rep = evaluate_accuracy(&index, &reads, &mappings, 5);
        println!(
            "  maxReads={:<7} accuracy vs truth {:.4}  vs oracle {:.4}  dropped pairs {}",
            max_reads,
            rep.accuracy_vs_truth(),
            rep.accuracy_vs_oracle(),
            metrics.dropped_pairs
        );
    }
}
