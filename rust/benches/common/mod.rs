//! Workload builders shared by the engine benches, so the printed
//! (`wf_engines`) and recorded (`pipeline_scaling`) comparisons measure
//! exactly the same batch shape.

use dart_pim::params::{window_len, ETH, READ_LEN};
use dart_pim::util::SmallRng;

/// A batch of `b` random reads, each planted exactly (no errors) at the
/// band anchor of an otherwise-random window — the standard engine
/// micro-bench workload.
pub fn planted_wf_batch(rng: &mut SmallRng, b: usize) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let reads: Vec<Vec<u8>> =
        (0..b).map(|_| (0..READ_LEN).map(|_| rng.gen_range(0..4)).collect()).collect();
    let wins: Vec<Vec<u8>> = reads
        .iter()
        .map(|r| {
            let mut w: Vec<u8> =
                (0..window_len(READ_LEN)).map(|_| rng.gen_range(0..4)).collect();
            w[ETH..ETH + READ_LEN].copy_from_slice(r);
            w
        })
        .collect();
    (reads, wins)
}
