//! Bench: sharded-pipeline throughput scaling — end-to-end `map_reads`
//! reads/s at 1/2/4 worker threads on a synthetic workload, recorded to
//! `BENCH_pipeline.json` at the repository root so future PRs have a
//! perf trajectory to compare against.
//!
//!     cargo bench --bench pipeline_scaling
//!
//! The workload mirrors the wf_engines end-to-end case (500 kbp
//! reference, 2000 simulated 150 bp reads, lowTh = 0 so all work takes
//! the crossbar path). Output at every thread count is byte-identical
//! (held by tests/shard_determinism.rs); only the wall-clock changes.

use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::RustEngine;
use dart_pim::util::bench::bench_units;
use dart_pim::util::json::Json;

const GENOME_LEN: usize = 500_000;
const N_READS: usize = 2000;
const THREADS: [usize; 3] = [1, 2, 4];

fn main() {
    let genome = SynthConfig { len: GENOME_LEN, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads: N_READS, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);
    let base = PipelineConfig {
        dart: DartPimConfig { low_th: 0, ..Default::default() },
        ..Default::default()
    };

    println!("== sharded pipeline scaling ({N_READS} reads, {GENOME_LEN} bp ref) ==");
    let loads = index.shard_loads(*THREADS.last().unwrap());
    println!("occurrence shard loads at t=4: {loads:?}");

    let mut rates: Vec<f64> = Vec::new();
    for &threads in &THREADS {
        let cfg = PipelineConfig { threads, ..base.clone() };
        let s = bench_units(
            &format!("pipeline rust t={threads}"),
            1,
            5,
            reads.len() as f64,
            &mut || {
                let mut p = Pipeline::new(&index, cfg.clone(), RustEngine);
                std::hint::black_box(p.map_reads(&reads).unwrap());
            },
        );
        println!("{s}");
        rates.push(s.throughput());
    }
    let speedup: Vec<f64> = rates.iter().map(|r| r / rates[0].max(1e-12)).collect();
    println!(
        "speedup vs 1 thread: {}",
        speedup.iter().map(|s| format!("{s:.2}x")).collect::<Vec<_>>().join(" ")
    );

    let j = Json::obj(vec![
        ("bench", Json::Str("pipeline_scaling".into())),
        ("measured", Json::Bool(true)),
        (
            "workload",
            Json::obj(vec![
                ("genome_len", GENOME_LEN.into()),
                ("n_reads", N_READS.into()),
                ("read_len", READ_LEN.into()),
                ("low_th", 0usize.into()),
                ("engine", Json::Str("rust".into())),
            ]),
        ),
        ("threads", Json::Arr(THREADS.iter().map(|&t| t.into()).collect())),
        ("reads_per_s", Json::Arr(rates.iter().map(|&r| r.into()).collect())),
        ("speedup_vs_1", Json::Arr(speedup.iter().map(|&s| s.into()).collect())),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pipeline.json");
    std::fs::write(out, j.pretty()).expect("write BENCH_pipeline.json");
    println!("wrote {out}");
}
