//! Bench: sharded-pipeline throughput scaling — end-to-end mapping
//! reads/s (both the `map_reads` collect wrapper and the streaming
//! `map_stream` path) at 1/2/4 worker threads for each host engine
//! (`rust` scalar vs `bitpal` bit-parallel), plus the isolated
//! filter-stage comparison,
//! recorded to `BENCH_pipeline.json` at the repository root so future
//! PRs have a perf trajectory to compare against.
//!
//!     cargo bench --bench pipeline_scaling
//!     cargo bench --bench pipeline_scaling -- --smoke  # CI: tiny run, no JSON
//!
//! The workload mirrors the wf_engines end-to-end case (500 kbp
//! reference, 2000 simulated 150 bp reads, lowTh = 0 so all work takes
//! the crossbar path). Output at every thread count and engine is
//! byte-identical (held by tests/shard_determinism.rs); only the
//! wall-clock changes.

// the workload builders live with the test suites: one definition of
// "the standard engine batch" shared by tests and benches
#[path = "../tests/common/mod.rs"]
mod common;

use common::planted_wf_batch;
use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::{EngineKind, WfEngine};
use dart_pim::util::bench::bench_units;
use dart_pim::util::json::Json;
use dart_pim::util::SmallRng;

const GENOME_LEN: usize = 500_000;
const N_READS: usize = 2000;
const THREADS: [usize; 3] = [1, 2, 4];
const ENGINES: [EngineKind; 2] = [EngineKind::Rust, EngineKind::Bitpal];
/// Filter-stage batch sizes for the bitpal-vs-rust comparison (the >= 2x
/// target applies from one full 64-lane word up).
const FILTER_BATCHES: [usize; 3] = [32, 64, 256];

fn main() {
    let smoke = std::env::args().skip(1).any(|a| a == "--smoke");
    let (genome_len, n_reads) = if smoke { (60_000, 100) } else { (GENOME_LEN, N_READS) };
    let genome = SynthConfig { len: genome_len, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);
    let base = PipelineConfig {
        dart: DartPimConfig { low_th: 0, ..Default::default() },
        ..Default::default()
    };

    println!("== sharded pipeline scaling ({n_reads} reads, {genome_len} bp ref) ==");
    let loads = index.shard_loads(*THREADS.last().unwrap());
    println!("occurrence shard loads at t=4: {loads:?}");

    // ---- end-to-end map_reads: engine x threads ----
    let mut rates: Vec<(EngineKind, Vec<f64>)> = Vec::new();
    for kind in ENGINES {
        let mut engine_rates = Vec::new();
        for &threads in &THREADS {
            let cfg = PipelineConfig { threads, worker_engine: kind, ..base.clone() };
            let s = bench_units(
                &format!("pipeline {} t={threads}", kind.name()),
                if smoke { 0 } else { 1 },
                if smoke { 1 } else { 5 },
                reads.len() as f64,
                &mut || {
                    let mut p = Pipeline::new(&index, cfg.clone(), kind.build());
                    std::hint::black_box(p.map_reads(&reads).unwrap());
                },
            );
            println!("{s}");
            engine_rates.push(s.throughput());
        }
        let speedup: Vec<f64> =
            engine_rates.iter().map(|r| r / engine_rates[0].max(1e-12)).collect();
        println!(
            "{} speedup vs 1 thread: {}",
            kind.name(),
            speedup.iter().map(|s| format!("{s:.2}x")).collect::<Vec<_>>().join(" ")
        );
        rates.push((kind, engine_rates));
    }

    // ---- streaming entry point: map_stream with a small epoch at the
    // max thread count (the bounded-memory production path; must track
    // the in-memory wrapper closely — the flush barriers are the only
    // added cost) ----
    let mut stream_rates: Vec<(EngineKind, f64)> = Vec::new();
    for kind in ENGINES {
        let threads = *THREADS.last().unwrap();
        let cfg = PipelineConfig {
            threads,
            worker_engine: kind,
            stream_epoch: 256,
            ..base.clone()
        };
        let s = bench_units(
            &format!("stream   {} t={threads}", kind.name()),
            if smoke { 0 } else { 1 },
            if smoke { 1 } else { 5 },
            reads.len() as f64,
            &mut || {
                let mut p = Pipeline::new(&index, cfg.clone(), kind.build());
                let mut mapped = 0usize;
                p.map_stream(reads.iter().cloned().map(Ok), |_, m| {
                    mapped += m.is_some() as usize;
                    Ok(())
                })
                .unwrap();
                std::hint::black_box(mapped);
            },
        );
        println!("{s}");
        stream_rates.push((kind, s.throughput()));
    }

    // ---- isolated filter stage: bitpal vs rust ----
    println!("\n== filter stage: bitpal vs rust ==");
    let mut rng = SmallRng::seed_from_u64(11);
    let mut filter_rows: Vec<(usize, f64, f64)> = Vec::new();
    for b in FILTER_BATCHES {
        let (fr, fw) = planted_wf_batch(&mut rng, b);
        let rr: Vec<&[u8]> = fr.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = fw.iter().map(|v| v.as_slice()).collect();
        let iters = if smoke { 1 } else { 40 };
        let mut rust = EngineKind::Rust.build();
        let rs = bench_units(&format!("rust   filter b={b}"), 0, iters, b as f64, &mut || {
            std::hint::black_box(rust.linear_batch(&rr, &ww).unwrap());
        });
        let mut bit = EngineKind::Bitpal.build();
        let bs = bench_units(&format!("bitpal filter b={b}"), 0, iters, b as f64, &mut || {
            std::hint::black_box(bit.linear_batch(&rr, &ww).unwrap());
        });
        println!("{rs}");
        println!("{bs}");
        println!("  -> speedup {:.2}x", bs.throughput() / rs.throughput().max(1e-12));
        filter_rows.push((b, rs.throughput(), bs.throughput()));
    }

    if smoke {
        println!("smoke run: skipping BENCH_pipeline.json (numbers are not measurements)");
        return;
    }

    let engines_json = Json::Arr(
        rates
            .iter()
            .map(|(kind, engine_rates)| {
                Json::obj(vec![
                    ("engine", Json::Str(kind.name().into())),
                    (
                        "reads_per_s",
                        Json::Arr(engine_rates.iter().map(|&r| r.into()).collect()),
                    ),
                    (
                        "speedup_vs_1",
                        Json::Arr(
                            engine_rates
                                .iter()
                                .map(|r| (r / engine_rates[0].max(1e-12)).into())
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    let stream_json = Json::Arr(
        stream_rates
            .iter()
            .map(|&(kind, tp)| {
                Json::obj(vec![
                    ("engine", Json::Str(kind.name().into())),
                    ("threads", (*THREADS.last().unwrap()).into()),
                    ("stream_epoch", 256usize.into()),
                    ("reads_per_s", tp.into()),
                ])
            })
            .collect(),
    );
    let filter_json = Json::Arr(
        filter_rows
            .iter()
            .map(|&(b, rust_tp, bit_tp)| {
                Json::obj(vec![
                    ("batch", b.into()),
                    ("rust_instances_per_s", rust_tp.into()),
                    ("bitpal_instances_per_s", bit_tp.into()),
                    ("speedup", (bit_tp / rust_tp.max(1e-12)).into()),
                ])
            })
            .collect(),
    );
    let j = Json::obj(vec![
        ("bench", Json::Str("pipeline_scaling".into())),
        ("measured", Json::Bool(true)),
        (
            "workload",
            Json::obj(vec![
                ("genome_len", GENOME_LEN.into()),
                ("n_reads", N_READS.into()),
                ("read_len", READ_LEN.into()),
                ("low_th", 0usize.into()),
            ]),
        ),
        ("threads", Json::Arr(THREADS.iter().map(|&t| t.into()).collect())),
        ("engines", engines_json),
        ("map_stream", stream_json),
        ("filter_stage_bitpal_vs_rust", filter_json),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pipeline.json");
    std::fs::write(out, j.pretty()).expect("write BENCH_pipeline.json");
    println!("wrote {out}");
}
