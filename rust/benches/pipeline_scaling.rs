//! Bench: sharded-pipeline throughput scaling — end-to-end mapping
//! reads/s (both the `map_reads` collect wrapper and the streaming
//! `map_stream` path) at 1/2/4 worker threads for each host engine
//! (`rust` scalar vs `bitpal` bit-parallel), plus the isolated
//! filter-stage comparison and the `--simd` lane-width sweep
//! (off/u64/wide, with the wide-vs-u64 >= 4x structural check at
//! batch >= 256), recorded to `BENCH_pipeline.json` at the repository
//! root so future PRs have a perf trajectory to compare against.
//!
//!     cargo bench --bench pipeline_scaling
//!     cargo bench --bench pipeline_scaling -- --smoke  # CI: tiny run, no JSON
//!
//! The workload mirrors the wf_engines end-to-end case (500 kbp
//! reference, 2000 simulated 150 bp reads, lowTh = 0 so all work takes
//! the crossbar path). Output at every thread count and engine is
//! byte-identical (held by tests/shard_determinism.rs); only the
//! wall-clock changes.

// the workload builders live with the test suites: one definition of
// "the standard engine batch" shared by tests and benches
#[path = "../tests/common/mod.rs"]
mod common;

use common::planted_wf_batch;
use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::{BitpalEngine, EngineKind, SimdMode, WfEngine};
use dart_pim::util::bench::bench_units;
use dart_pim::util::json::Json;
use dart_pim::util::SmallRng;

const GENOME_LEN: usize = 500_000;
const N_READS: usize = 2000;
const THREADS: [usize; 3] = [1, 2, 4];
const ENGINES: [EngineKind; 2] = [EngineKind::Rust, EngineKind::Bitpal];
/// Filter-stage batch sizes for the bitpal-vs-rust comparison (the >= 2x
/// target applies from one full 64-lane word up).
const FILTER_BATCHES: [usize; 3] = [32, 64, 256];
/// Lane-width sweep batches: the >= 4x wide-vs-u64 target applies from
/// batch 256 up (every 256/512-bit lane full).
const SIMD_BATCHES: [usize; 4] = [64, 128, 256, 512];

fn main() {
    let smoke = std::env::args().skip(1).any(|a| a == "--smoke");
    let (genome_len, n_reads) = if smoke { (60_000, 100) } else { (GENOME_LEN, N_READS) };
    let genome = SynthConfig { len: genome_len, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);
    let base = PipelineConfig {
        dart: DartPimConfig { low_th: 0, ..Default::default() },
        ..Default::default()
    };

    println!("== sharded pipeline scaling ({n_reads} reads, {genome_len} bp ref) ==");
    let loads = index.shard_loads(*THREADS.last().unwrap());
    println!("occurrence shard loads at t=4: {loads:?}");

    // ---- end-to-end map_reads: engine x threads ----
    let mut rates: Vec<(EngineKind, Vec<f64>)> = Vec::new();
    for kind in ENGINES {
        let mut engine_rates = Vec::new();
        for &threads in &THREADS {
            let cfg = PipelineConfig { threads, worker_engine: kind, ..base.clone() };
            let s = bench_units(
                &format!("pipeline {} t={threads}", kind.name()),
                if smoke { 0 } else { 1 },
                if smoke { 1 } else { 5 },
                reads.len() as f64,
                &mut || {
                    let mut p = Pipeline::new(&index, cfg.clone(), kind.build());
                    std::hint::black_box(p.map_reads(&reads).unwrap());
                },
            );
            println!("{s}");
            engine_rates.push(s.throughput());
        }
        let speedup: Vec<f64> =
            engine_rates.iter().map(|r| r / engine_rates[0].max(1e-12)).collect();
        println!(
            "{} speedup vs 1 thread: {}",
            kind.name(),
            speedup.iter().map(|s| format!("{s:.2}x")).collect::<Vec<_>>().join(" ")
        );
        rates.push((kind, engine_rates));
    }

    // ---- streaming entry point: map_stream with a small epoch at the
    // max thread count (the bounded-memory production path; must track
    // the in-memory wrapper closely — the flush barriers are the only
    // added cost) ----
    let mut stream_rates: Vec<(EngineKind, f64)> = Vec::new();
    for kind in ENGINES {
        let threads = *THREADS.last().unwrap();
        let cfg = PipelineConfig {
            threads,
            worker_engine: kind,
            stream_epoch: 256,
            ..base.clone()
        };
        let s = bench_units(
            &format!("stream   {} t={threads}", kind.name()),
            if smoke { 0 } else { 1 },
            if smoke { 1 } else { 5 },
            reads.len() as f64,
            &mut || {
                let mut p = Pipeline::new(&index, cfg.clone(), kind.build());
                let mut mapped = 0usize;
                p.map_stream(reads.iter().cloned().map(Ok), |_, m| {
                    mapped += m.is_some() as usize;
                    Ok(())
                })
                .unwrap();
                std::hint::black_box(mapped);
            },
        );
        println!("{s}");
        stream_rates.push((kind, s.throughput()));
    }

    // ---- isolated filter stage: bitpal vs rust ----
    println!("\n== filter stage: bitpal vs rust ==");
    let mut rng = SmallRng::seed_from_u64(11);
    let mut filter_rows: Vec<(usize, f64, f64)> = Vec::new();
    for b in FILTER_BATCHES {
        let (fr, fw) = planted_wf_batch(&mut rng, b);
        let rr: Vec<&[u8]> = fr.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = fw.iter().map(|v| v.as_slice()).collect();
        let iters = if smoke { 1 } else { 40 };
        let mut rust = EngineKind::Rust.build();
        let rs = bench_units(&format!("rust   filter b={b}"), 0, iters, b as f64, &mut || {
            std::hint::black_box(rust.linear_batch(&rr, &ww).unwrap());
        });
        let mut bit = EngineKind::Bitpal.build();
        let bs = bench_units(&format!("bitpal filter b={b}"), 0, iters, b as f64, &mut || {
            std::hint::black_box(bit.linear_batch(&rr, &ww).unwrap());
        });
        println!("{rs}");
        println!("{bs}");
        println!("  -> speedup {:.2}x", bs.throughput() / rs.throughput().max(1e-12));
        filter_rows.push((b, rs.throughput(), bs.throughput()));
    }

    // ---- lane-width sweep: --simd off / u64 / wide on the isolated
    // filter stage (the tentpole's structural check: wide >= 4x u64 at
    // batch >= 256 when a wide kernel resolved on this host) ----
    let wide_bits = BitpalEngine::with_mode(SimdMode::Wide).width_bits();
    println!("\n== filter stage: simd lane sweep (wide resolves to {wide_bits} bits) ==");
    // (off_tp, u64_tp, wide_tp) per batch
    let mut simd_rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    for b in SIMD_BATCHES {
        let (fr, fw) = planted_wf_batch(&mut rng, b);
        let rr: Vec<&[u8]> = fr.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = fw.iter().map(|v| v.as_slice()).collect();
        let iters = if smoke { 1 } else { 40 };
        let mut tps = [0.0f64; 3];
        let modes = [SimdMode::Off, SimdMode::U64, SimdMode::Wide];
        for (i, mode) in modes.into_iter().enumerate() {
            let mut e = BitpalEngine::with_mode(mode);
            let s = bench_units(
                &format!("simd={:<4} filter b={b}", mode.name()),
                0,
                iters,
                b as f64,
                &mut || {
                    std::hint::black_box(e.linear_batch(&rr, &ww).unwrap());
                },
            );
            println!("{s}");
            tps[i] = s.throughput();
        }
        let wide_vs_u64 = tps[2] / tps[1].max(1e-12);
        let verdict = if smoke {
            "(smoke run; not a measurement)"
        } else if wide_bits <= 64 {
            "(no wide kernel on this host; target moot)"
        } else if b >= 256 && wide_vs_u64 < 4.0 {
            "** below the 4x target **"
        } else {
            ""
        };
        println!("  -> wide/u64 {wide_vs_u64:.2}x {verdict}");
        simd_rows.push((b, tps[0], tps[1], tps[2]));
    }

    if smoke {
        println!("smoke run: skipping BENCH_pipeline.json (numbers are not measurements)");
        return;
    }

    let engines_json = Json::Arr(
        rates
            .iter()
            .map(|(kind, engine_rates)| {
                Json::obj(vec![
                    ("engine", Json::Str(kind.name().into())),
                    (
                        "reads_per_s",
                        Json::Arr(engine_rates.iter().map(|&r| r.into()).collect()),
                    ),
                    (
                        "speedup_vs_1",
                        Json::Arr(
                            engine_rates
                                .iter()
                                .map(|r| (r / engine_rates[0].max(1e-12)).into())
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    let stream_json = Json::Arr(
        stream_rates
            .iter()
            .map(|&(kind, tp)| {
                Json::obj(vec![
                    ("engine", Json::Str(kind.name().into())),
                    ("threads", (*THREADS.last().unwrap()).into()),
                    ("stream_epoch", 256usize.into()),
                    ("reads_per_s", tp.into()),
                ])
            })
            .collect(),
    );
    let filter_json = Json::Arr(
        filter_rows
            .iter()
            .map(|&(b, rust_tp, bit_tp)| {
                Json::obj(vec![
                    ("batch", b.into()),
                    ("rust_instances_per_s", rust_tp.into()),
                    ("bitpal_instances_per_s", bit_tp.into()),
                    ("speedup", (bit_tp / rust_tp.max(1e-12)).into()),
                ])
            })
            .collect(),
    );
    let simd_json = Json::Arr(
        simd_rows
            .iter()
            .map(|&(b, off_tp, u64_tp, wide_tp)| {
                let wide_vs_u64 = wide_tp / u64_tp.max(1e-12);
                Json::obj(vec![
                    ("batch", b.into()),
                    ("off_instances_per_s", off_tp.into()),
                    ("u64_instances_per_s", u64_tp.into()),
                    ("wide_instances_per_s", wide_tp.into()),
                    ("wide_vs_u64", wide_vs_u64.into()),
                    ("meets_4x", Json::Bool(b >= 256 && wide_vs_u64 >= 4.0)),
                ])
            })
            .collect(),
    );
    let j = Json::obj(vec![
        ("bench", Json::Str("pipeline_scaling".into())),
        ("measured", Json::Bool(true)),
        ("simd_wide_bits", wide_bits.into()),
        (
            "workload",
            Json::obj(vec![
                ("genome_len", GENOME_LEN.into()),
                ("n_reads", N_READS.into()),
                ("read_len", READ_LEN.into()),
                ("low_th", 0usize.into()),
            ]),
        ),
        ("threads", Json::Arr(THREADS.iter().map(|&t| t.into()).collect())),
        ("engines", engines_json),
        ("map_stream", stream_json),
        ("filter_stage_bitpal_vs_rust", filter_json),
        ("filter_stage_simd", simd_json),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_pipeline.json");
    std::fs::write(out, j.pretty()).expect("write BENCH_pipeline.json");
    println!("wrote {out}");
}
