//! Bench: regenerates paper Fig. 9 — throughput / energy efficiency /
//! area efficiency for DART-PIM (model) against the five published
//! comparators, plus a measured-workload variant.
//!
//!     cargo bench --bench fig9_efficiency

use dart_pim::eval::figures;
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::xbar_sim::CostSource;
use dart_pim::pim::DartPimConfig;
use dart_pim::simulator::report::{build_report, scale_counts};
use dart_pim::simulator::{FullSystemSim, TimingMode};

fn main() {
    // paper-workload model rows + published numbers
    println!("{}", figures::fig9());

    // measured synthetic workload, projected to 389M reads
    let genome = SynthConfig { len: 1_000_000, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads: 4000, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);
    println!("measured synthetic workload projected to 389M reads:");
    println!(
        "{:<12} {:>14} {:>14} {:>18}",
        "maxReads", "reads/s", "reads/J", "reads/(s*mm^2)"
    );
    for max_reads in [12_500usize, 25_000, 50_000] {
        let cfg = DartPimConfig { max_reads, low_th: 0, ..Default::default() };
        let counts = FullSystemSim::new(&index, cfg.clone()).simulate(&reads);
        let scaled = scale_counts(&counts, 389_000_000, &cfg);
        let r = build_report(&scaled, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial);
        println!(
            "{:<12} {:>14.0} {:>14.1} {:>18.1}",
            max_reads,
            r.throughput(),
            r.energy_efficiency(),
            r.area_efficiency()
        );
    }
    println!("\n{}", figures::headline());
}
