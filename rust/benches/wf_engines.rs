//! Bench: the host hot path — batched WF engine throughput (bit-parallel
//! bitpal vs pure Rust vs XLA/PJRT) across batch sizes, plus the
//! end-to-end pipeline rate. This is the §Perf working bench
//! (EXPERIMENTS.md).
//!
//!     cargo bench --bench wf_engines
//!     cargo bench --bench wf_engines -- --smoke   # CI: compile + run, tiny iters
//!
//! The headline number is the filter-stage comparison: `bitpal` advances
//! 64 instances per word op (one lane each), so its `linear_batch`
//! should beat `rust` by >= 2x at batch >= 64.

// the workload builders live with the test suites: one definition of
// "the standard engine batch" shared by tests and benches
#[path = "../tests/common/mod.rs"]
mod common;

use common::planted_wf_batch as mk_batch;
use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::DartPimConfig;
#[cfg(feature = "pjrt")]
use dart_pim::runtime::XlaEngine;
use dart_pim::runtime::{BitpalEngine, EngineKind, RustEngine, WfEngine};
use dart_pim::util::bench::bench_units;
use dart_pim::util::SmallRng;

fn engine_suite(name: &str, engine: &mut dyn WfEngine, rng: &mut SmallRng, smoke: bool) {
    for b in [32usize, 256] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let iters = if smoke {
            2
        } else if b >= 256 {
            20
        } else {
            60
        };
        let warmup = if smoke { 0 } else { 3 };
        let s = bench_units(&format!("{name} linear b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(engine.linear_batch(&rr, &ww).unwrap());
        });
        println!("{s}");
    }
    for b in [8usize, 64] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let iters = if smoke { 2 } else { 20 };
        let warmup = if smoke { 0 } else { 2 };
        let s = bench_units(&format!("{name} affine b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(engine.affine_batch(&rr, &ww).unwrap());
        });
        println!("{s}");
    }
}

/// The tentpole comparison: bitpal vs rust on the linear filter stage.
fn filter_stage_comparison(rng: &mut SmallRng, smoke: bool) {
    println!("\n== filter stage: bitpal vs rust (linear_batch reads/s) ==");
    let iters = if smoke { 2 } else { 40 };
    let warmup = if smoke { 0 } else { 3 };
    for b in [32usize, 64, 256] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let mut rust = RustEngine;
        let rs = bench_units(&format!("rust   filter b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(rust.linear_batch(&rr, &ww).unwrap());
        });
        let mut bit = BitpalEngine::new();
        let bs = bench_units(&format!("bitpal filter b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(bit.linear_batch(&rr, &ww).unwrap());
        });
        println!("{rs}");
        println!("{bs}");
        let speedup = bs.throughput() / rs.throughput().max(1e-12);
        let verdict = if smoke {
            "(smoke run; not a measurement)"
        } else if b >= 64 && speedup < 2.0 {
            "** below the 2x target **"
        } else {
            ""
        };
        println!("  -> bitpal/rust speedup at b={b}: {speedup:.2}x {verdict}");
    }
}

#[cfg(feature = "pjrt")]
fn xla_engine_suite(rng: &mut SmallRng, smoke: bool) {
    match XlaEngine::load_default() {
        Ok(mut e) => engine_suite("xla ", &mut e, rng, smoke),
        Err(e) => println!("xla engine unavailable ({e}); run `make artifacts`"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn xla_engine_suite(_rng: &mut SmallRng, _smoke: bool) {
    println!("xla engine not compiled in (enable with `--features pjrt`)");
}

fn main() {
    let smoke = std::env::args().skip(1).any(|a| a == "--smoke");
    let mut rng = SmallRng::seed_from_u64(9);
    println!("== WF engine micro-bench (units = WF instances) ==");
    engine_suite("rust", &mut RustEngine, &mut rng, smoke);
    engine_suite("bitpal", &mut BitpalEngine::new(), &mut rng, smoke);
    xla_engine_suite(&mut rng, smoke);

    filter_stage_comparison(&mut rng, smoke);

    println!("\n== end-to-end pipeline (host reads/s) ==");
    let (genome_len, n_reads, iters) = if smoke { (60_000, 100, 1) } else { (500_000, 2000, 3) };
    let genome = SynthConfig { len: genome_len, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);
    let cfg = PipelineConfig {
        dart: DartPimConfig { low_th: 0, ..Default::default() },
        ..Default::default()
    };
    // sharded scaling x engine kind: minimizer-hash partition across
    // worker threads (see benches/pipeline_scaling.rs for the recorded
    // baseline)
    for kind in [EngineKind::Rust, EngineKind::Bitpal] {
        for threads in [1usize, 2, 4] {
            let c = PipelineConfig { threads, worker_engine: kind, ..cfg.clone() };
            let s = bench_units(
                &format!("pipeline {} {n_reads} reads t={threads}", kind.name()),
                if smoke { 0 } else { 1 },
                iters,
                reads.len() as f64,
                &mut || {
                    let mut p = Pipeline::new(&index, c.clone(), kind.build());
                    std::hint::black_box(p.map_reads(&reads).unwrap());
                },
            );
            println!("{s}");
        }
    }
    #[cfg(feature = "pjrt")]
    if let Ok(engine) = XlaEngine::load_default() {
        // PJRT client is constructed once; pipeline borrows it per run
        let mut p = Pipeline::new(&index, cfg.clone(), engine);
        let s = bench_units("pipeline xla 2k reads", 1, 3, reads.len() as f64, &mut || {
            std::hint::black_box(p.map_reads(&reads).unwrap());
        });
        println!("{s}");
    }
}
