//! Bench: the host hot path — batched WF engine throughput (bit-parallel
//! bitpal vs pure Rust vs XLA/PJRT) across batch sizes, plus the
//! end-to-end pipeline rate. This is the §Perf working bench
//! (EXPERIMENTS.md).
//!
//!     cargo bench --bench wf_engines
//!     cargo bench --bench wf_engines -- --smoke   # CI: compile + run, tiny iters
//!
//! The headline number is the filter-stage comparison: `bitpal`
//! advances one instance per bit lane, so its `linear_batch` should
//! beat `rust` by >= 2x from one full word up — and the SIMD-wide
//! kernel (`--simd wide`: 256-bit AVX2 / 512-bit AVX-512 lanes) targets
//! a further >= 4x over the plain u64 word at batch >= 256, where every
//! lane is full (the structural check the tentpole records).

// the workload builders live with the test suites: one definition of
// "the standard engine batch" shared by tests and benches
#[path = "../tests/common/mod.rs"]
mod common;

use common::planted_wf_batch as mk_batch;
use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::DartPimConfig;
#[cfg(feature = "pjrt")]
use dart_pim::runtime::XlaEngine;
use dart_pim::runtime::{BitpalEngine, EngineKind, RustEngine, SimdMode, WfEngine};
use dart_pim::util::bench::bench_units;
use dart_pim::util::SmallRng;

fn engine_suite(name: &str, engine: &mut dyn WfEngine, rng: &mut SmallRng, smoke: bool) {
    for b in [32usize, 256] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let iters = if smoke {
            2
        } else if b >= 256 {
            20
        } else {
            60
        };
        let warmup = if smoke { 0 } else { 3 };
        let s = bench_units(&format!("{name} linear b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(engine.linear_batch(&rr, &ww).unwrap());
        });
        println!("{s}");
    }
    for b in [8usize, 64] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let iters = if smoke { 2 } else { 20 };
        let warmup = if smoke { 0 } else { 2 };
        let s = bench_units(&format!("{name} affine b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(engine.affine_batch(&rr, &ww).unwrap());
        });
        println!("{s}");
    }
}

/// The tentpole comparison: bitpal vs rust on the linear filter stage.
fn filter_stage_comparison(rng: &mut SmallRng, smoke: bool) {
    println!("\n== filter stage: bitpal vs rust (linear_batch reads/s) ==");
    let iters = if smoke { 2 } else { 40 };
    let warmup = if smoke { 0 } else { 3 };
    for b in [32usize, 64, 256] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let mut rust = RustEngine;
        let rs = bench_units(&format!("rust   filter b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(rust.linear_batch(&rr, &ww).unwrap());
        });
        let mut bit = BitpalEngine::new();
        let bs = bench_units(&format!("bitpal filter b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(bit.linear_batch(&rr, &ww).unwrap());
        });
        println!("{rs}");
        println!("{bs}");
        let speedup = bs.throughput() / rs.throughput().max(1e-12);
        let verdict = if smoke {
            "(smoke run; not a measurement)"
        } else if b >= 64 && speedup < 2.0 {
            "** below the 2x target **"
        } else {
            ""
        };
        println!("  -> bitpal/rust speedup at b={b}: {speedup:.2}x {verdict}");
    }
}

/// The tentpole lane-width comparison: `--simd wide` vs `--simd u64` on
/// the linear filter, at batches large enough to fill every wide lane.
/// Structural check: >= 4x at batch >= 256 when a wide kernel resolved
/// (on a 64-bit-only host wide == u64 and the check is moot).
fn simd_width_comparison(rng: &mut SmallRng, smoke: bool) {
    let wide_bits = BitpalEngine::with_mode(SimdMode::Wide).width_bits();
    println!("\n== filter stage: simd wide ({wide_bits}-bit) vs u64 (instances/s) ==");
    let iters = if smoke { 2 } else { 40 };
    let warmup = if smoke { 0 } else { 3 };
    for b in [64usize, 256, 512] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let mut u64e = BitpalEngine::with_mode(SimdMode::U64);
        let us = bench_units(&format!("u64  filter b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(u64e.linear_batch(&rr, &ww).unwrap());
        });
        let mut wide = BitpalEngine::with_mode(SimdMode::Wide);
        let ws = bench_units(&format!("wide filter b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(wide.linear_batch(&rr, &ww).unwrap());
        });
        println!("{us}");
        println!("{ws}");
        let speedup = ws.throughput() / us.throughput().max(1e-12);
        let verdict = if smoke {
            "(smoke run; not a measurement)"
        } else if wide_bits <= 64 {
            "(no wide kernel on this host)"
        } else if b >= 256 && speedup < 4.0 {
            "** below the 4x target **"
        } else {
            ""
        };
        println!("  -> wide/u64 speedup at b={b}: {speedup:.2}x {verdict}");
    }
    // the affine stage is bit-sliced too: wide vs the scalar fallback
    for b in [64usize, 256] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let mut off = BitpalEngine::with_mode(SimdMode::Off);
        let os = bench_units(&format!("off  affine b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(off.affine_batch(&rr, &ww).unwrap());
        });
        let mut wide = BitpalEngine::with_mode(SimdMode::Wide);
        let ws = bench_units(&format!("wide affine b={b}"), warmup, iters, b as f64, &mut || {
            std::hint::black_box(wide.affine_batch(&rr, &ww).unwrap());
        });
        println!("{os}");
        println!("{ws}");
        println!(
            "  -> wide/scalar affine speedup at b={b}: {:.2}x",
            ws.throughput() / os.throughput().max(1e-12)
        );
    }
}

#[cfg(feature = "pjrt")]
fn xla_engine_suite(rng: &mut SmallRng, smoke: bool) {
    match XlaEngine::load_default() {
        Ok(mut e) => engine_suite("xla ", &mut e, rng, smoke),
        Err(e) => println!("xla engine unavailable ({e}); run `make artifacts`"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn xla_engine_suite(_rng: &mut SmallRng, _smoke: bool) {
    println!("xla engine not compiled in (enable with `--features pjrt`)");
}

fn main() {
    let smoke = std::env::args().skip(1).any(|a| a == "--smoke");
    let mut rng = SmallRng::seed_from_u64(9);
    println!("== WF engine micro-bench (units = WF instances) ==");
    engine_suite("rust", &mut RustEngine, &mut rng, smoke);
    // bitpal at every --simd mode: the default wide kernel, the plain
    // 64-bit word, and the scalar fallback (all byte-identical outputs)
    engine_suite("bitpal-wide", &mut BitpalEngine::with_mode(SimdMode::Wide), &mut rng, smoke);
    engine_suite("bitpal-u64", &mut BitpalEngine::with_mode(SimdMode::U64), &mut rng, smoke);
    engine_suite("bitpal-off", &mut BitpalEngine::with_mode(SimdMode::Off), &mut rng, smoke);
    xla_engine_suite(&mut rng, smoke);

    filter_stage_comparison(&mut rng, smoke);
    simd_width_comparison(&mut rng, smoke);

    println!("\n== end-to-end pipeline (host reads/s) ==");
    let (genome_len, n_reads, iters) = if smoke { (60_000, 100, 1) } else { (500_000, 2000, 3) };
    let genome = SynthConfig { len: genome_len, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);
    let cfg = PipelineConfig {
        dart: DartPimConfig { low_th: 0, ..Default::default() },
        ..Default::default()
    };
    // sharded scaling x engine kind: minimizer-hash partition across
    // worker threads (see benches/pipeline_scaling.rs for the recorded
    // baseline)
    for kind in [EngineKind::Rust, EngineKind::Bitpal] {
        for threads in [1usize, 2, 4] {
            let c = PipelineConfig { threads, worker_engine: kind, ..cfg.clone() };
            let s = bench_units(
                &format!("pipeline {} {n_reads} reads t={threads}", kind.name()),
                if smoke { 0 } else { 1 },
                iters,
                reads.len() as f64,
                &mut || {
                    let mut p = Pipeline::new(&index, c.clone(), kind.build());
                    std::hint::black_box(p.map_reads(&reads).unwrap());
                },
            );
            println!("{s}");
        }
    }
    #[cfg(feature = "pjrt")]
    if let Ok(engine) = XlaEngine::load_default() {
        // PJRT client is constructed once; pipeline borrows it per run
        let mut p = Pipeline::new(&index, cfg.clone(), engine);
        let s = bench_units("pipeline xla 2k reads", 1, 3, reads.len() as f64, &mut || {
            std::hint::black_box(p.map_reads(&reads).unwrap());
        });
        println!("{s}");
    }
}
