//! Bench: the host hot path — batched WF engine throughput (XLA/PJRT vs
//! pure Rust) across batch sizes, plus the end-to-end pipeline rate.
//! This is the §Perf working bench (EXPERIMENTS.md).
//!
//!     cargo bench --bench wf_engines

use dart_pim::coordinator::{Pipeline, PipelineConfig};
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{window_len, K, READ_LEN, W};
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::{RustEngine, WfEngine};
#[cfg(feature = "pjrt")]
use dart_pim::runtime::XlaEngine;
use dart_pim::util::bench::bench_units;
use dart_pim::util::SmallRng;

fn mk_batch(rng: &mut SmallRng, b: usize) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let reads: Vec<Vec<u8>> =
        (0..b).map(|_| (0..READ_LEN).map(|_| rng.gen_range(0..4)).collect()).collect();
    let wins: Vec<Vec<u8>> = reads
        .iter()
        .map(|r| {
            let mut w: Vec<u8> =
                (0..window_len(READ_LEN)).map(|_| rng.gen_range(0..4)).collect();
            w[6..6 + READ_LEN].copy_from_slice(r);
            w
        })
        .collect();
    (reads, wins)
}

fn engine_suite(name: &str, engine: &mut dyn WfEngine, rng: &mut SmallRng) {
    for b in [32usize, 256] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let iters = if b >= 256 { 20 } else { 60 };
        let s = bench_units(&format!("{name} linear b={b}"), 3, iters, b as f64, &mut || {
            std::hint::black_box(engine.linear_batch(&rr, &ww).unwrap());
        });
        println!("{s}");
    }
    for b in [8usize, 64] {
        let (reads, wins) = mk_batch(rng, b);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let s = bench_units(&format!("{name} affine b={b}"), 2, 20, b as f64, &mut || {
            std::hint::black_box(engine.affine_batch(&rr, &ww).unwrap());
        });
        println!("{s}");
    }
}

#[cfg(feature = "pjrt")]
fn xla_engine_suite(rng: &mut SmallRng) {
    match XlaEngine::load_default() {
        Ok(mut e) => engine_suite("xla ", &mut e, rng),
        Err(e) => println!("xla engine unavailable ({e}); run `make artifacts`"),
    }
}

#[cfg(not(feature = "pjrt"))]
fn xla_engine_suite(_rng: &mut SmallRng) {
    println!("xla engine not compiled in (enable with `--features pjrt`)");
}

fn main() {
    let mut rng = SmallRng::seed_from_u64(9);
    println!("== WF engine micro-bench (units = WF instances) ==");
    engine_suite("rust", &mut RustEngine, &mut rng);
    xla_engine_suite(&mut rng);

    println!("\n== end-to-end pipeline (host reads/s) ==");
    let genome = SynthConfig { len: 500_000, ..Default::default() }.generate();
    let index = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads = ReadSimConfig { n_reads: 2000, ..Default::default() }
        .simulate(&index.reference, |p| p as u32);
    let cfg = PipelineConfig {
        dart: DartPimConfig { low_th: 0, ..Default::default() },
        ..Default::default()
    };
    // sharded scaling: minimizer-hash partition across worker threads
    // (see benches/pipeline_scaling.rs for the recorded baseline)
    for threads in [1usize, 2, 4] {
        let c = PipelineConfig { threads, ..cfg.clone() };
        let s = bench_units(
            &format!("pipeline rust 2k reads t={threads}"),
            1,
            3,
            reads.len() as f64,
            &mut || {
                let mut p = Pipeline::new(&index, c.clone(), RustEngine);
                std::hint::black_box(p.map_reads(&reads).unwrap());
            },
        );
        println!("{s}");
    }
    #[cfg(feature = "pjrt")]
    if let Ok(engine) = XlaEngine::load_default() {
        // PJRT client is constructed once; pipeline borrows it per run
        let mut p = Pipeline::new(&index, cfg.clone(), engine);
        let s = bench_units("pipeline xla 2k reads", 1, 3, reads.len() as f64, &mut || {
            std::hint::black_box(p.map_reads(&reads).unwrap());
        });
        println!("{s}");
    }
}
