//! The `dart-pim serve` wire protocol: a one-line handshake followed by
//! either raw bytes or length-prefixed frames. SERVING.md is the
//! normative spec; this module is its implementation plus unit tests.
//!
//! # Handshake
//!
//! The client's first line (ASCII, `\n`-terminated, ≤ 256 bytes):
//!
//! ```text
//! DART/1 mode=<se|pe> [framing=<framed|raw>]
//! ```
//!
//! `mode=se` streams single-end FASTQ; `mode=pe` streams interleaved
//! pairs (R1, R2, R1, …). `framing` defaults to `framed`.
//!
//! # Framed mode
//!
//! After the handshake, every byte in both directions travels in frames
//! of `[kind: 1 byte][len: u32 big-endian][payload: len bytes]`:
//!
//! * client → server: `D` (FASTQ bytes, arbitrary chunking) and `F`
//!   (finish, len 0 — end of the read stream);
//! * server → client: `D` (TSV bytes), then exactly one `M` (final
//!   per-session metrics line) on success or `E` (error message) on
//!   failure.
//!
//! A connection that closes before `F` is a client hangup and fails the
//! session ([`FrameReader`] surfaces it as `UnexpectedEof`), which is
//! the reason framed mode exists: raw TCP/Unix EOF cannot distinguish
//! "done" from "died".
//!
//! # Raw mode
//!
//! No framing in either direction: the client streams FASTQ and
//! half-closes (EOF = end of stream); the server answers with exactly
//! the TSV bytes `map` would write (byte parity, invariant 7). On error
//! the server appends one `#!error: …` line — distinguishable because
//! TSV rows never start with `#` — and closes. Raw mode is what
//! `socat`/`nc` speak; see SERVING.md for a worked example.

use std::io::{self, Read, Write};

/// Frame kind: FASTQ or TSV payload bytes.
pub const KIND_DATA: u8 = b'D';
/// Frame kind: end of the client's read stream (len 0).
pub const KIND_FINISH: u8 = b'F';
/// Frame kind: the server's final metrics line (success).
pub const KIND_METRICS: u8 = b'M';
/// Frame kind: the server's error message (failure).
pub const KIND_ERROR: u8 = b'E';

/// Upper bound on a single frame's payload, to fail fast on garbage
/// headers (e.g. a client that skipped the handshake line).
pub const MAX_FRAME_LEN: usize = 1 << 26;

/// Longest accepted handshake line, terminator included.
const MAX_HANDSHAKE: usize = 256;

/// Whether a session streams single-end reads or interleaved pairs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// Single-end FASTQ.
    Single,
    /// Interleaved paired FASTQ (R1 at even records, R2 at odd).
    Paired,
}

/// Whether a session speaks frames or raw bytes after the handshake.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framing {
    /// Length-prefixed frames both ways (the default).
    Framed,
    /// Raw FASTQ in, raw TSV out; client EOF ends the stream.
    Raw,
}

/// A parsed handshake line.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Handshake {
    /// Single-end or interleaved-paired input.
    pub mode: Mode,
    /// Framed or raw transport.
    pub framing: Framing,
}

/// Read and parse the handshake line, byte-at-a-time so no stream bytes
/// beyond the terminating `\n` are consumed (the FASTQ or the first
/// frame begins immediately after it).
pub fn read_handshake<R: Read>(r: &mut R) -> anyhow::Result<Handshake> {
    let mut line = Vec::with_capacity(64);
    loop {
        let mut b = [0u8; 1];
        match r.read(&mut b) {
            Ok(0) => anyhow::bail!("connection closed before a handshake line"),
            Ok(_) => {
                if b[0] == b'\n' {
                    break;
                }
                line.push(b[0]);
                anyhow::ensure!(
                    line.len() <= MAX_HANDSHAKE,
                    "handshake line exceeds {MAX_HANDSHAKE} bytes; expected \
                     `DART/1 mode=<se|pe> [framing=<framed|raw>]`"
                );
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let text = std::str::from_utf8(&line).map_err(|_| {
        anyhow::anyhow!("handshake line is not UTF-8; expected `DART/1 mode=<se|pe> ...`")
    })?;
    parse_handshake(text.trim_end_matches('\r'))
}

/// Parse the handshake text (no trailing newline). Unknown tokens are
/// rejected rather than ignored so protocol drift fails loudly.
pub fn parse_handshake(text: &str) -> anyhow::Result<Handshake> {
    let mut tokens = text.split_whitespace();
    let magic = tokens.next().unwrap_or("");
    anyhow::ensure!(
        magic == "DART/1",
        "unknown protocol greeting {magic:?}; this daemon speaks DART/1"
    );
    let mut mode: Option<Mode> = None;
    let mut framing = Framing::Framed;
    for tok in tokens {
        match tok.split_once('=') {
            Some(("mode", "se")) => mode = Some(Mode::Single),
            Some(("mode", "pe")) => mode = Some(Mode::Paired),
            Some(("framing", "framed")) => framing = Framing::Framed,
            Some(("framing", "raw")) => framing = Framing::Raw,
            _ => anyhow::bail!(
                "unknown handshake token {tok:?}; expected mode=<se|pe> and \
                 optionally framing=<framed|raw>"
            ),
        }
    }
    let mode = mode.ok_or_else(|| anyhow::anyhow!("handshake is missing mode=<se|pe>"))?;
    Ok(Handshake { mode, framing })
}

/// Adapts a framed client stream into a plain [`Read`] over the FASTQ
/// payload bytes: `D` frames concatenate, `F` is EOF. A transport EOF
/// *before* `F` is a client hangup and surfaces as
/// [`io::ErrorKind::UnexpectedEof`] — the failure-mode detection raw
/// mode cannot offer.
pub struct FrameReader<R: Read> {
    inner: R,
    /// Payload bytes left in the current `D` frame.
    remaining: usize,
    /// `F` seen: everything after is EOF.
    finished: bool,
}

impl<R: Read> FrameReader<R> {
    /// Wrap a transport positioned just past the handshake line.
    pub fn new(inner: R) -> Self {
        FrameReader { inner, remaining: 0, finished: false }
    }
}

impl<R: Read> Read for FrameReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        while !self.finished && self.remaining == 0 {
            let mut hdr = [0u8; 5];
            self.inner.read_exact(&mut hdr).map_err(|e| {
                if e.kind() == io::ErrorKind::UnexpectedEof {
                    io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "client hung up mid-stream (connection closed without a finish frame)",
                    )
                } else {
                    e
                }
            })?;
            let len = u32::from_be_bytes([hdr[1], hdr[2], hdr[3], hdr[4]]) as usize;
            match hdr[0] {
                KIND_FINISH => {
                    if len != 0 {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!(
                                "finish frame declares a {len} byte payload; F frames carry none"
                            ),
                        ));
                    }
                    self.finished = true;
                }
                KIND_DATA => {
                    if len > MAX_FRAME_LEN {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            format!("frame length {len} exceeds the {MAX_FRAME_LEN} byte cap"),
                        ));
                    }
                    self.remaining = len;
                }
                other => {
                    return Err(io::Error::new(
                        io::ErrorKind::InvalidData,
                        format!("unknown client frame kind {:?}", other as char),
                    ));
                }
            }
        }
        if self.finished {
            return Ok(0);
        }
        let want = buf.len().min(self.remaining);
        let got = loop {
            match self.inner.read(&mut buf[..want]) {
                Ok(n) => break n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        };
        if got == 0 {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "client hung up mid-frame",
            ));
        }
        self.remaining -= got;
        Ok(got)
    }
}

/// Adapts a plain [`Write`] into the framed server→client channel:
/// every `write` becomes one `D` frame (wrap in a
/// [`io::BufWriter`] so frames coalesce to its buffer size), and
/// [`FrameWriter::frame`] emits the terminal `M`/`E` frame.
pub struct FrameWriter<W: Write> {
    inner: W,
}

impl<W: Write> FrameWriter<W> {
    /// Wrap a transport write half.
    pub fn new(inner: W) -> Self {
        FrameWriter { inner }
    }

    /// Emit one frame of the given kind and flush the transport.
    pub fn frame(&mut self, kind: u8, payload: &[u8]) -> io::Result<()> {
        let mut hdr = [0u8; 5];
        hdr[0] = kind;
        hdr[1..].copy_from_slice(&(payload.len() as u32).to_be_bytes());
        self.inner.write_all(&hdr)?;
        self.inner.write_all(payload)?;
        self.inner.flush()
    }
}

impl<W: Write> Write for FrameWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        if !buf.is_empty() {
            let mut hdr = [0u8; 5];
            hdr[0] = KIND_DATA;
            hdr[1..].copy_from_slice(&(buf.len() as u32).to_be_bytes());
            self.inner.write_all(&hdr)?;
            self.inner.write_all(buf)?;
        }
        Ok(buf.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

/// Client-side helper for tests and tools: collect a framed server
/// response into (TSV bytes, metrics line, error message).
pub fn read_framed_response<R: Read>(
    r: &mut R,
) -> anyhow::Result<(Vec<u8>, Option<String>, Option<String>)> {
    let mut tsv = Vec::new();
    let mut metrics = None;
    let mut error = None;
    loop {
        let mut hdr = [0u8; 5];
        match r.read_exact(&mut hdr) {
            Ok(()) => {}
            Err(e) if e.kind() == io::ErrorKind::UnexpectedEof => break,
            Err(e) => return Err(e.into()),
        }
        let len = u32::from_be_bytes([hdr[1], hdr[2], hdr[3], hdr[4]]) as usize;
        anyhow::ensure!(len <= MAX_FRAME_LEN, "server frame length {len} exceeds cap");
        // never preallocate from the untrusted header length: read
        // through `take`, so memory tracks bytes actually received
        let mut payload = Vec::new();
        let got = r.by_ref().take(len as u64).read_to_end(&mut payload)?;
        anyhow::ensure!(
            got == len,
            "truncated server frame: header declares {len} bytes, stream ended after {got}"
        );
        match hdr[0] {
            KIND_DATA => tsv.extend_from_slice(&payload),
            KIND_METRICS => metrics = Some(String::from_utf8_lossy(&payload).into_owned()),
            KIND_ERROR => error = Some(String::from_utf8_lossy(&payload).into_owned()),
            other => anyhow::bail!("unknown server frame kind {:?}", other as char),
        }
    }
    Ok((tsv, metrics, error))
}

/// Client-side helper: wrap `payload` as one `D` frame.
pub fn encode_data_frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(5 + payload.len());
    out.push(KIND_DATA);
    out.extend_from_slice(&(payload.len() as u32).to_be_bytes());
    out.extend_from_slice(payload);
    out
}

/// Client-side helper: the 5-byte `F` (finish) frame.
pub fn finish_frame() -> [u8; 5] {
    [KIND_FINISH, 0, 0, 0, 0]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handshake_parses_modes_and_framing() {
        let h = parse_handshake("DART/1 mode=se").unwrap();
        assert_eq!(h, Handshake { mode: Mode::Single, framing: Framing::Framed });
        let h = parse_handshake("DART/1 mode=pe framing=raw").unwrap();
        assert_eq!(h, Handshake { mode: Mode::Paired, framing: Framing::Raw });
        let h = parse_handshake("DART/1 framing=framed mode=pe").unwrap();
        assert_eq!(h, Handshake { mode: Mode::Paired, framing: Framing::Framed });
    }

    #[test]
    fn handshake_rejects_garbage() {
        assert!(parse_handshake("HTTP/1.1 GET /").is_err());
        assert!(parse_handshake("DART/1").is_err(), "mode is required");
        assert!(parse_handshake("DART/1 mode=tripled").is_err());
        assert!(parse_handshake("DART/1 mode=se compression=zstd").is_err());
        let err = read_handshake(&mut io::Cursor::new(b"".to_vec())).unwrap_err();
        assert!(format!("{err:#}").contains("before a handshake"));
    }

    #[test]
    fn read_handshake_consumes_exactly_one_line() {
        let mut cur = io::Cursor::new(b"DART/1 mode=se framing=raw\n@r0\nACGT\n".to_vec());
        let h = read_handshake(&mut cur).unwrap();
        assert_eq!(h.framing, Framing::Raw);
        let mut rest = String::new();
        cur.read_to_string(&mut rest).unwrap();
        assert_eq!(rest, "@r0\nACGT\n", "no FASTQ bytes may be swallowed");
    }

    #[test]
    fn frames_roundtrip_through_reader_and_writer() {
        // writer side: two data chunks + finish
        let mut wire = Vec::new();
        wire.extend_from_slice(&encode_data_frame(b"@r0\nAC"));
        wire.extend_from_slice(&encode_data_frame(b""));
        wire.extend_from_slice(&encode_data_frame(b"GT\n+\nII\n"));
        wire.extend_from_slice(&finish_frame());
        let mut rd = FrameReader::new(io::Cursor::new(wire));
        let mut got = String::new();
        rd.read_to_string(&mut got).unwrap();
        assert_eq!(got, "@r0\nACGT\n+\nII\n");
        // EOF is sticky after F
        let mut buf = [0u8; 4];
        assert_eq!(rd.read(&mut buf).unwrap(), 0);
    }

    #[test]
    fn hangup_without_finish_frame_is_an_error() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&encode_data_frame(b"@r0\nACGT\n"));
        // connection drops here: no F frame
        let mut rd = FrameReader::new(io::Cursor::new(wire));
        let mut got = Vec::new();
        let err = rd.read_to_end(&mut got).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        assert!(err.to_string().contains("finish frame"), "{err}");
    }

    #[test]
    fn truncated_frame_payload_is_an_error() {
        let mut wire = encode_data_frame(b"@r0\nACGT\n");
        wire.truncate(wire.len() - 3);
        let mut rd = FrameReader::new(io::Cursor::new(wire));
        let mut got = Vec::new();
        let err = rd.read_to_end(&mut got).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn finish_frame_with_payload_is_rejected() {
        let wire = vec![KIND_FINISH, 0, 0, 0, 4];
        let mut rd = FrameReader::new(io::Cursor::new(wire));
        let mut got = Vec::new();
        let err = rd.read_to_end(&mut got).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("finish frame"), "{err}");
    }

    #[test]
    fn oversized_data_frame_header_fails_before_any_payload_read() {
        // a malicious header claiming u32::MAX bytes must be rejected
        // from the 5 header bytes alone — no allocation, no read
        let wire = vec![KIND_DATA, 0xFF, 0xFF, 0xFF, 0xFF];
        let mut rd = FrameReader::new(io::Cursor::new(wire));
        let mut got = Vec::new();
        let err = rd.read_to_end(&mut got).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn truncated_server_frame_is_a_loud_error() {
        // header declares 9 payload bytes but the stream ends after 3
        let mut wire = encode_data_frame(b"short.tsv");
        wire.truncate(5 + 3);
        let err = read_framed_response(&mut io::Cursor::new(wire)).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("truncated server frame"), "{msg}");
    }

    #[test]
    fn oversized_server_frame_header_is_rejected_without_allocating() {
        let wire = vec![KIND_DATA, 0xFF, 0xFF, 0xFF, 0xFF];
        let err = read_framed_response(&mut io::Cursor::new(wire)).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds cap"), "{err:#}");
    }

    #[test]
    fn unknown_frame_kind_is_rejected() {
        let wire = vec![b'Z', 0, 0, 0, 0];
        let mut rd = FrameReader::new(io::Cursor::new(wire));
        let mut got = Vec::new();
        let err = rd.read_to_end(&mut got).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    #[test]
    fn frame_writer_emits_one_data_frame_per_write_plus_terminal_frame() {
        let mut fw = FrameWriter::new(Vec::new());
        fw.write_all(b"read_id\tpos\n").unwrap();
        fw.write_all(b"0\t42\n").unwrap();
        fw.frame(KIND_METRICS, b"reads=1").unwrap();
        let wire = fw.inner;
        let mut cur = io::Cursor::new(wire);
        let (tsv, metrics, error) = read_framed_response(&mut cur).unwrap();
        assert_eq!(tsv, b"read_id\tpos\n0\t42\n");
        assert_eq!(metrics.as_deref(), Some("reads=1"));
        assert_eq!(error, None);
    }
}
