//! Minimal async-signal-safe SIGTERM/SIGINT latch for the daemon.
//!
//! This offline build vendors no `libc`/`signal-hook`, so the handler
//! is registered through the C `signal(2)` entry point directly. The
//! handler only stores to an atomic — one of the few operations that
//! are async-signal-safe — and the accept loop polls the latch.
//!
//! glibc's `signal()` installs BSD semantics (`SA_RESTART`): blocking
//! socket reads *resume* after the handler runs instead of failing with
//! `EINTR`. That is exactly the drain behavior we want — in-flight
//! sessions keep streaming to completion after SIGTERM — while the
//! accept loop notices the latch because it is nonblocking and sleeps
//! in short intervals (see [`super`]).

use std::sync::atomic::{AtomicBool, Ordering};

/// Set by the handler; polled by the accept loop.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

/// Linux signal numbers (asm-generic, which x86-64/aarch64 share).
const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

extern "C" {
    /// C library `signal(2)`: returns the previous handler address.
    fn signal(signum: i32, handler: usize) -> usize;
}

/// The handler: latch and return. No allocation, no locks, no I/O.
extern "C" fn on_signal(_signum: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

/// Install the drain latch for SIGTERM and SIGINT. Idempotent.
pub fn install() {
    let handler = on_signal as extern "C" fn(i32);
    // SAFETY: `signal` is the C library entry point; the handler is an
    // `extern "C" fn(i32)` that only performs an atomic store.
    unsafe {
        signal(SIGTERM, handler as usize);
        signal(SIGINT, handler as usize);
    }
}

/// True once a drain signal has been received.
pub fn shutting_down() -> bool {
    SHUTDOWN.load(Ordering::SeqCst)
}
