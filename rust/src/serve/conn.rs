//! One connection = one session: handshake, FASTQ intake, mapping
//! through a pooled [`MapSession`], and the TSV/metrics response.
//!
//! Everything here is best-effort toward the *client* and precise
//! toward the *daemon*: a session failure is reported on the wire when
//! the transport still works, and always lands in the returned
//! [`SessionOutcome`] so the accept loop can log and count it. A failed
//! session never takes the daemon down — its worker-side state is
//! retired when the [`MapSession`] drops (see `coordinator::pool`).

use std::io::{self, BufRead, Read, Write};

use anyhow::Result;

use crate::cli;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::{MapSession, WorkerPool};
use crate::coordinator::{FinalMapping, Router};
use crate::genome::fastq::{FastqRecord, PairedFastqStream};
use crate::genome::ReadRecord;
use crate::index::IndexRef;

use super::protocol::{
    read_handshake, FrameReader, FrameWriter, Framing, Mode, KIND_ERROR, KIND_METRICS,
};
use super::{SessionTemplate, Stream};

/// What the accept loop learns when a handler thread settles.
pub(crate) struct SessionOutcome {
    /// The session's merged metrics, when it completed cleanly.
    pub(crate) metrics: Option<Metrics>,
    /// The failure rendered for the daemon log, when it did not.
    pub(crate) error: Option<String>,
}

/// The per-session metrics line (the `M` frame payload, also echoed to
/// the daemon log and aggregated daemon-wide).
pub(crate) fn metrics_line(m: &Metrics) -> String {
    format!(
        "reads={} proper_pairs={} wf_calls={} wall_ms={}",
        m.n_reads,
        m.proper_pairs,
        m.linear_instances + m.affine_instances,
        m.t_total.as_millis()
    )
}

/// The server→client channel in whichever transport the handshake
/// picked. TSV rows buffer here; the terminal metrics/error follows the
/// framing rules in `protocol`.
enum OutChan {
    /// Raw bytes; errors become a trailing `#!error:` line.
    Raw(io::BufWriter<Stream>),
    /// `D` frames, terminated by one `M` or `E` frame.
    Framed(io::BufWriter<FrameWriter<Stream>>),
}

impl Write for OutChan {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            OutChan::Raw(w) => w.write(buf),
            OutChan::Framed(w) => w.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            OutChan::Raw(w) => w.flush(),
            OutChan::Framed(w) => w.flush(),
        }
    }
}

impl OutChan {
    /// Seal a successful session: flush the TSV and, in framed mode,
    /// append the metrics frame.
    fn finish_ok(&mut self, metrics_line: &str) -> io::Result<()> {
        match self {
            OutChan::Raw(w) => w.flush(),
            OutChan::Framed(w) => {
                w.flush()?;
                w.get_mut().frame(KIND_METRICS, metrics_line.as_bytes())
            }
        }
    }

    /// Report a session failure on the wire, best-effort (the client
    /// may be the reason the session failed).
    fn report_err(&mut self, msg: &str) {
        match self {
            OutChan::Raw(w) => {
                // TSV rows never start with '#', so the trailer is
                // unambiguous even after partial output
                let _ = w.flush();
                let _ = writeln!(w.get_mut(), "#!error: {msg}");
                let _ = w.get_mut().flush();
            }
            OutChan::Framed(w) => {
                let _ = w.flush();
                let _ = w.get_mut().frame(KIND_ERROR, msg.as_bytes());
            }
        }
    }
}

/// Serve one accepted connection to completion. Runs on its own thread;
/// never panics the daemon for client-induced failures.
pub(crate) fn handle_connection(
    mut stream: Stream,
    session_id: u64,
    index: IndexRef<'_>,
    router: &Router,
    template: &SessionTemplate,
    pool: &WorkerPool,
) -> SessionOutcome {
    let hs = match read_handshake(&mut stream) {
        Ok(h) => h,
        Err(e) => {
            // no transport negotiated yet: answer in the raw dialect
            let msg = format!("{e:#}");
            let _ = writeln!(stream, "#!error: {msg}");
            return SessionOutcome { metrics: None, error: Some(msg) };
        }
    };
    let read_half = match stream.try_clone() {
        Ok(r) => r,
        Err(e) => {
            let msg = format!("splitting the connection: {e}");
            let _ = writeln!(stream, "#!error: {msg}");
            return SessionOutcome { metrics: None, error: Some(msg) };
        }
    };
    let reader: Box<dyn Read> = match hs.framing {
        Framing::Raw => Box::new(read_half),
        Framing::Framed => Box::new(FrameReader::new(read_half)),
    };
    let mut out = match hs.framing {
        Framing::Raw => OutChan::Raw(io::BufWriter::new(stream)),
        Framing::Framed => OutChan::Framed(io::BufWriter::new(FrameWriter::new(stream))),
    };
    match run_session(reader, &mut out, hs.mode, session_id, index, router, template, pool) {
        Ok(metrics) => {
            let line = metrics_line(&metrics);
            match out.finish_ok(&line) {
                Ok(()) => SessionOutcome { metrics: Some(metrics), error: None },
                // mapped fine, but the client vanished before the tail
                Err(e) => SessionOutcome {
                    metrics: Some(metrics),
                    error: Some(format!("writing the response tail: {e}")),
                },
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            out.report_err(&msg);
            SessionOutcome { metrics: None, error: Some(msg) }
        }
    }
}

/// The session body: intake → pooled mapping → TSV rows in read order.
/// Byte parity with `map` holds because intake, config, sharding, and
/// row rendering are the same code `cmd_map` runs (invariant 7).
#[allow(clippy::too_many_arguments)]
fn run_session(
    reader: Box<dyn Read>,
    out: &mut OutChan,
    mode: Mode,
    session_id: u64,
    index: IndexRef<'_>,
    router: &Router,
    template: &SessionTemplate,
    pool: &WorkerPool,
) -> Result<Metrics> {
    let cfg = template.session_cfg(mode);
    let paired = cfg.pairing.is_some();
    let label = format!("session {session_id} FASTQ stream");
    let buf: Box<dyn BufRead> = Box::new(io::BufReader::new(reader));
    let (read_len, reads): (usize, Box<dyn Iterator<Item = Result<ReadRecord>>>) = if paired {
        let pairs: Box<dyn Iterator<Item = io::Result<(FastqRecord, FastqRecord)>>> =
            Box::new(PairedFastqStream::interleaved(buf));
        cli::stream_paired_from(pairs, label)?
    } else {
        let (rl, it) = cli::stream_reads_from(buf, label)?;
        (rl, Box::new(it))
    };
    anyhow::ensure!(
        read_len == index.read_len(),
        "session streams {read_len} bp reads, but this daemon's index was built for {} bp \
         (restart serve with --read-len {read_len} to serve them)",
        index.read_len()
    );
    cli::write_tsv_header(out, paired)?;
    let mut sink = |_id: u32, m: Option<FinalMapping>| -> Result<()> {
        if let Some(m) = m {
            cli::write_tsv_row(out, paired, &m)?;
        }
        Ok(())
    };
    let mut session = MapSession::new(session_id, index, router, cfg, pool);
    for read in reads {
        session.push(&read?, &mut sink)?;
    }
    session.finish(&mut sink)
}
