//! `dart-pim serve` — the long-lived mapping daemon.
//!
//! The daemon loads the minimizer index once, spawns one shared
//! shard-worker pool (`coordinator::pool`), and then accepts concurrent
//! FASTQ streams over a Unix-domain socket (or TCP behind `--tcp`).
//! Each accepted connection becomes a *session*: a handler thread reads
//! the client's handshake and FASTQ, routes reads through a
//! [`crate::coordinator::pool::MapSession`] multiplexed onto the shared
//! workers, and streams the TSV rows back in read order. For any single
//! client the response bytes are identical to `map` on the same input
//! and flags — determinism invariant 7 (ARCHITECTURE.md).
//!
//! Module map:
//!
//! * [`protocol`] — the DART/1 handshake and frame codec (SERVING.md is
//!   the normative spec)
//! * `conn`       — per-connection session driver (private)
//! * `signal`     — the SIGTERM/SIGINT drain latch (private)
//!
//! # Drain
//!
//! SIGTERM/SIGINT latch a flag; the nonblocking accept loop notices it,
//! stops accepting, joins every in-flight session (their blocking socket
//! I/O is *not* interrupted — the handler threads run to completion),
//! logs the aggregate metrics, removes the socket file, and returns
//! `Ok(())` so the process exits 0.

use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::Mutex;
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::{PairingConfig, PipelineConfig, Router};
use crate::index::IndexRef;

mod conn;
pub mod protocol;
mod signal;

/// How often the accept loop polls for connections, finished sessions,
/// and the drain latch. Latency floor for accepting a connection;
/// irrelevant once a session is running (handlers block on their own
/// sockets).
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// The daemon-wide session policy: the worker pool's pipeline config
/// plus the producer-side knobs each session instantiates per its
/// handshake mode.
pub struct SessionTemplate {
    /// The pool's config. Worker-side fields (engine, batch, filter
    /// policy, DART parameters) are shared by every session; the
    /// producer-side fields are overridden per session by
    /// [`SessionTemplate::session_cfg`].
    pub cfg: PipelineConfig,
    /// Pair arbitration policy applied to every `mode=pe` session.
    pub pairing: PairingConfig,
    /// `--revcomp`: map both strands in single-end sessions too
    /// (`mode=pe` always does).
    pub revcomp: bool,
}

impl SessionTemplate {
    /// The session config for a handshake `mode` — exactly what
    /// `cmd_map` builds for the same flags, which is what makes the
    /// byte-parity invariant hold.
    fn session_cfg(&self, mode: protocol::Mode) -> PipelineConfig {
        let mut cfg = self.cfg.clone();
        match mode {
            protocol::Mode::Single => {
                cfg.handle_revcomp = self.revcomp;
                cfg.pairing = None;
            }
            protocol::Mode::Paired => {
                cfg.handle_revcomp = true;
                cfg.pairing = Some(self.pairing.clone());
            }
        }
        cfg
    }
}

/// Where the daemon listens.
pub enum Bind {
    /// A Unix-domain socket at this path — created at startup (the path
    /// must not exist) and removed on exit.
    Unix(PathBuf),
    /// A TCP listen address, e.g. `127.0.0.1:7777`.
    Tcp(String),
}

/// The two listener transports behind one accept interface.
enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Listener::Unix(l) => l.set_nonblocking(nb),
            Listener::Tcp(l) => l.set_nonblocking(nb),
        }
    }

    fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Unix(l) => l.accept().map(|(s, _)| Stream::Unix(s)),
            Listener::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
        }
    }
}

/// One accepted connection, either transport. Sessions split it into a
/// read half and a write half via [`Stream::try_clone`].
pub(crate) enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    pub(crate) fn try_clone(&self) -> io::Result<Stream> {
        match self {
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
        }
    }

    /// Sockets accepted from a nonblocking listener can inherit the
    /// nonblocking flag on some platforms; sessions want blocking I/O.
    fn set_nonblocking(&self, nb: bool) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_nonblocking(nb),
            Stream::Tcp(s) => s.set_nonblocking(nb),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

/// Removes the Unix socket file when the daemon winds down, on every
/// exit path (including errors).
struct SocketGuard(Option<PathBuf>);

impl Drop for SocketGuard {
    fn drop(&mut self) {
        if let Some(p) = &self.0 {
            let _ = std::fs::remove_file(p);
        }
    }
}

/// Daemon-wide aggregates across settled sessions.
#[derive(Default)]
struct DaemonStats {
    sessions: u64,
    failed: u64,
    metrics: Metrics,
}

/// Run the daemon until a drain signal: bind, accept, one handler
/// thread per connection, all sessions multiplexed onto one worker
/// pool. Returns `Ok(())` after a graceful drain (so `serve` exits 0
/// under SIGTERM) and `Err` for daemon-level failures (bad bind,
/// accept-loop I/O errors, dead worker pool).
pub fn run_daemon<'a>(
    index: impl Into<IndexRef<'a>>,
    template: SessionTemplate,
    bind: Bind,
) -> Result<()> {
    let index = index.into();
    signal::install();
    let (listener, _guard, addr) = match &bind {
        Bind::Unix(path) => {
            if path.exists() {
                bail!(
                    "socket path {} already exists — another daemon may be running \
                     (remove the stale file to rebind)",
                    path.display()
                );
            }
            let l = UnixListener::bind(path)
                .with_context(|| format!("binding unix socket {}", path.display()))?;
            (Listener::Unix(l), SocketGuard(Some(path.clone())), format!("unix:{}", path.display()))
        }
        Bind::Tcp(spec) => {
            let l = TcpListener::bind(spec).with_context(|| format!("binding tcp {spec}"))?;
            (Listener::Tcp(l), SocketGuard(None), format!("tcp:{spec}"))
        }
    };
    listener.set_nonblocking(true).context("making the listener nonblocking")?;
    let router = Router::new(index, &template.cfg.dart);
    let n_shards = template.cfg.threads.max(1);
    let stats = Mutex::new(DaemonStats::default());
    // SIMD lane selection is per-daemon (workers build their engines at
    // spawn), never per-session — the banner is the place to see it
    eprintln!(
        "serve: listening on {addr} ({} bp reads, {} shard worker(s), engine {}, simd {})",
        index.read_len(),
        n_shards,
        template.cfg.worker_engine.name(),
        template.cfg.simd.name()
    );
    let result = thread::scope(|s| -> Result<()> {
        let pool = WorkerPool::spawn(s, index, &template.cfg, n_shards);
        let mut handles: Vec<(u64, thread::ScopedJoinHandle<'_, conn::SessionOutcome>)> =
            Vec::new();
        let mut next_session: u64 = 0;
        while !signal::shutting_down() {
            match listener.accept() {
                Ok(stream) => {
                    // handlers want blocking I/O even if the socket
                    // inherited the listener's nonblocking flag
                    if let Err(e) = stream.set_nonblocking(false) {
                        eprintln!("serve: rejecting connection: {e}");
                        continue;
                    }
                    let id = next_session;
                    next_session += 1;
                    let session_pool = pool.clone();
                    let router = &router;
                    let template = &template;
                    let h = s.spawn(move || {
                        conn::handle_connection(stream, id, index, router, template, &session_pool)
                    });
                    handles.push((id, h));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    reap_finished(&mut handles, &stats);
                    if !pool.healthy() {
                        // sessions cannot settle without their workers;
                        // fail loudly rather than serve hung clients
                        drain(handles, &stats);
                        bail!("a shard worker terminated; shutting the daemon down");
                    }
                    thread::sleep(ACCEPT_POLL);
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    drain(handles, &stats);
                    return Err(e).context("accepting a connection");
                }
            }
        }
        let in_flight = handles.iter().filter(|(_, h)| !h.is_finished()).count();
        eprintln!("serve: drain requested; finishing {in_flight} in-flight session(s)");
        drain(handles, &stats);
        Ok(())
    });
    let stats = stats.into_inner().unwrap_or_else(|e| e.into_inner());
    eprintln!(
        "serve: {} session(s) served, {} failed; aggregate: {}",
        stats.sessions,
        stats.failed,
        conn::metrics_line(&stats.metrics)
    );
    result
}

/// Settle every handler that has already finished, without blocking on
/// the ones still streaming.
fn reap_finished(
    handles: &mut Vec<(u64, thread::ScopedJoinHandle<'_, conn::SessionOutcome>)>,
    stats: &Mutex<DaemonStats>,
) {
    let mut i = 0;
    while i < handles.len() {
        if handles[i].1.is_finished() {
            let (id, h) = handles.swap_remove(i);
            settle(id, h, stats);
        } else {
            i += 1;
        }
    }
}

/// Join every remaining handler (the drain path: blocks until in-flight
/// sessions run to completion).
fn drain(
    handles: Vec<(u64, thread::ScopedJoinHandle<'_, conn::SessionOutcome>)>,
    stats: &Mutex<DaemonStats>,
) {
    for (id, h) in handles {
        settle(id, h, stats);
    }
}

/// Fold one settled session into the daemon log and aggregates.
fn settle(
    id: u64,
    h: thread::ScopedJoinHandle<'_, conn::SessionOutcome>,
    stats: &Mutex<DaemonStats>,
) {
    let mut st = stats.lock().unwrap_or_else(|e| e.into_inner());
    st.sessions += 1;
    match h.join() {
        Ok(outcome) => {
            if let Some(m) = &outcome.metrics {
                eprintln!("serve: session {id} done: {}", conn::metrics_line(m));
            }
            if let Some(err) = &outcome.error {
                st.failed += 1;
                eprintln!("serve: session {id} failed: {err}");
            }
            if let Some(m) = outcome.metrics {
                st.metrics.merge(m);
            }
        }
        Err(_) => {
            st.failed += 1;
            eprintln!("serve: session {id} handler panicked");
        }
    }
}
