//! The minimizer index: minimizer k-mer -> all reference occurrences,
//! plus segment extraction (the data a crossbar stores at indexing time).

use std::collections::HashMap;

use super::minimizer::minimizers;
use crate::genome::encode::{Seq, BASE_N};
use crate::params::{segment_len, ETH};

/// Offline minimizer index of a reference genome (paper §V-B).
///
/// Unlike a classical hash-table mapper, DART-PIM materializes the
/// reference *segments* themselves into the crossbars; here the index
/// stores occurrence positions and extracts segments on demand (the
/// 17x storage blowup is accounted for in [`IndexStats`] and the PIM
/// area/energy models, not duplicated in host memory).
pub struct MinimizerIndex {
    /// minimizer k-mer -> sorted occurrence positions (k-mer start).
    // dart-analyze: allow(determinism): iterated only through iter(),
    // whose three consumers are all order-free — Router::new and
    // save_index sort the collected entries by k-mer before use, and
    // stats() computes sums/maxes. Keyed lookups (occurrences()) carry
    // the hot path; per-minimizer position lists are sorted at build
    // time.
    occurrences: HashMap<u64, Vec<u32>>,
    /// The reference genome (base codes).
    pub reference: Seq,
    /// k-mer length used at build time.
    pub k: usize,
    /// Minimizer window size (k-mers per window) used at build time.
    pub w: usize,
    /// Read length the segment geometry is built for.
    pub read_len: usize,
}

/// Deterministic shard owner of a minimizer under an `n_shards`-way
/// partition of the index (the host mirror of the paper's per-crossbar
/// data organization, §V-B).
///
/// Because the crossbar assignment gives every minimizer a private
/// contiguous crossbar range (see [`crate::coordinator::Router`]),
/// partitioning *by minimizer* also partitions crossbars, Reads FIFOs,
/// and reference segments into disjoint per-shard slices. The low bits
/// of packed k-mers are heavily biased (2-bit bases), so the key is
/// mixed (64-bit finalizer) before reduction.
pub fn shard_of(kmer: u64, n_shards: usize) -> usize {
    debug_assert!(n_shards >= 1);
    if n_shards <= 1 {
        return 0;
    }
    // murmur3 / splitmix-style 64-bit finalizer
    let mut x = kmer;
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^= x >> 33;
    (x % n_shards as u64) as usize
}

/// Banded-WF window for (occurrence `pos`, read minimizer offset `q`)
/// against a raw reference slice — the single implementation behind
/// [`MinimizerIndex::window_for`] and the mapped backend's
/// [`super::backend::IndexRef::window_for`]. Sharing the body is what
/// makes determinism invariant 9 (backend never changes output bytes)
/// hold by construction rather than by parallel maintenance.
pub(crate) fn window_from(reference: &[u8], read_len: usize, pos: u32, q: usize) -> Seq {
    let wl = crate::params::window_len(read_len);
    let start = pos as i64 - q as i64 - ETH as i64;
    let mut out = vec![BASE_N; wl];
    let lo = start.max(0) as usize;
    let hi = ((start + wl as i64).min(reference.len() as i64)).max(0) as usize;
    if lo < hi {
        let off = (lo as i64 - start) as usize;
        out[off..off + (hi - lo)].copy_from_slice(&reference[lo..hi]);
    }
    out
}

/// Summary statistics of an index (drives Fig. 8-10 workload modelling
/// and the §II data-volume motivation numbers).
#[derive(Debug, Clone)]
pub struct IndexStats {
    /// Distinct minimizers in the index.
    pub n_minimizers: usize,
    /// Total occurrence positions across all minimizers.
    pub n_occurrences: usize,
    /// Largest single-minimizer occurrence count.
    pub max_occurrences: usize,
    /// Mean occurrences per minimizer.
    pub mean_occurrences: f64,
    /// Minimizers with occurrence count <= lowTh are offloaded to the
    /// DP-RISC-V cores (paper §V-A).
    pub low_freq_minimizers: usize,
    /// Bytes of segment data a DART-PIM deployment would replicate into
    /// crossbars (2 bits/base), vs. the hash-table footprint.
    pub segment_storage_bytes: usize,
    /// Bytes of the equivalent classical hash-table index.
    pub hashtable_storage_bytes: usize,
}

impl MinimizerIndex {
    /// Reassemble from deserialized parts (see [`super::io`]).
    pub(crate) fn from_parts(
        occurrences: HashMap<u64, Vec<u32>>,
        reference: Seq,
        k: usize,
        w: usize,
        read_len: usize,
    ) -> Self {
        MinimizerIndex { occurrences, reference, k, w, read_len }
    }

    /// Build the index over `reference`.
    pub fn build(reference: Seq, k: usize, w: usize, read_len: usize) -> Self {
        let mut occurrences: HashMap<u64, Vec<u32>> = HashMap::new();
        for m in minimizers(&reference, k, w) {
            occurrences.entry(m.kmer).or_default().push(m.pos);
        }
        for v in occurrences.values_mut() {
            v.sort_unstable();
            v.dedup();
        }
        MinimizerIndex { occurrences, reference, k, w, read_len }
    }

    /// Occurrence positions of a minimizer (empty if absent).
    pub fn occurrences(&self, kmer: u64) -> &[u32] {
        self.occurrences.get(&kmer).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Number of distinct minimizers.
    pub fn n_minimizers(&self) -> usize {
        self.occurrences.len()
    }

    /// Iterate over (minimizer, occurrence list).
    pub fn iter(&self) -> impl Iterator<Item = (u64, &[u32])> {
        self.occurrences.iter().map(|(k, v)| (*k, v.as_slice()))
    }

    /// Segment length for this geometry: `2(rl + eth) - k`.
    pub fn seg_len(&self) -> usize {
        segment_len(self.read_len)
    }

    /// Extract the reference segment for a minimizer occurrence at `pos`
    /// (k-mer start). The segment spans
    /// `[pos - (rl - k) - eth, pos + rl + eth)` — the union of banded WF
    /// windows over all in-read minimizer offsets — clamped to the
    /// reference with N padding so geometry is uniform at the boundaries.
    pub fn segment(&self, pos: u32) -> Seq {
        let sl = self.seg_len();
        let lead = (self.read_len - self.k) + ETH;
        let start = pos as i64 - lead as i64;
        let mut out = vec![BASE_N; sl];
        for (i, slot) in out.iter_mut().enumerate() {
            let p = start + i as i64;
            if p >= 0 && (p as usize) < self.reference.len() {
                *slot = self.reference[p as usize];
            }
        }
        out
    }

    /// The banded-WF window for a read whose minimizer sits at read
    /// offset `q`, taken from a segment returned by [`Self::segment`]:
    /// `segment[(rl - k) - q .. + rl + 2*eth)`.
    pub fn window_of_segment<'a>(&self, segment: &'a [u8], q: usize) -> &'a [u8] {
        let off = (self.read_len - self.k) - q;
        &segment[off..off + crate::params::window_len(self.read_len)]
    }

    /// Mapped reference position implied by occurrence `pos` and read
    /// minimizer offset `q` (the PL, potential location).
    pub fn potential_location(&self, pos: u32, q: usize) -> i64 {
        pos as i64 - q as i64
    }

    /// Banded-WF window for (occurrence `pos`, read minimizer offset
    /// `q`), extracted directly from the reference (equivalent to
    /// `window_of_segment(&segment(pos), q)` without materializing the
    /// 300-base segment — the host-side fast path; the PIM cost model
    /// still charges for the replicated segments).
    pub fn window_for(&self, pos: u32, q: usize) -> Seq {
        window_from(&self.reference, self.read_len, pos, q)
    }

    /// Occurrence totals per shard under an `n_shards`-way
    /// [`shard_of`] partition — the work each pipeline shard would own.
    /// Used to check partition balance (a pathological reference could
    /// concentrate occurrences in one shard and serialize the pipeline).
    pub fn shard_loads(&self, n_shards: usize) -> Vec<u64> {
        let mut loads = vec![0u64; n_shards.max(1)];
        for (kmer, occs) in self.iter() {
            loads[shard_of(kmer, n_shards)] += occs.len() as u64;
        }
        loads
    }

    /// Compute index statistics.
    pub fn stats(&self, low_th: usize) -> IndexStats {
        let n_minimizers = self.occurrences.len();
        let n_occurrences: usize = self.occurrences.values().map(|v| v.len()).sum();
        let max_occurrences = self.occurrences.values().map(|v| v.len()).max().unwrap_or(0);
        let low_freq_minimizers =
            self.occurrences.values().filter(|v| v.len() <= low_th).count();
        IndexStats {
            n_minimizers,
            n_occurrences,
            max_occurrences,
            mean_occurrences: if n_minimizers == 0 {
                0.0
            } else {
                n_occurrences as f64 / n_minimizers as f64
            },
            low_freq_minimizers,
            segment_storage_bytes: n_occurrences * self.seg_len() / 4, // 2 bits/base
            hashtable_storage_bytes: n_occurrences * 4 + n_minimizers * 12,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::SynthConfig;
    use crate::params::{window_len, K, READ_LEN};

    fn index() -> MinimizerIndex {
        let g = SynthConfig { len: 60_000, ..Default::default() }.generate();
        MinimizerIndex::build(g, K, crate::params::W, READ_LEN)
    }

    #[test]
    fn occurrences_point_at_their_kmer() {
        let idx = index();
        let mut checked = 0;
        for (kmer, occs) in idx.iter().take(50) {
            for &p in occs {
                let packed =
                    crate::index::kmer::pack_kmer(&idx.reference[p as usize..p as usize + K]);
                assert_eq!(packed, Some(kmer));
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn segment_geometry() {
        let idx = index();
        assert_eq!(idx.seg_len(), 2 * (READ_LEN + ETH) - K); // 300 for rl=150
        let (_, occs) = idx.iter().next().unwrap();
        let seg = idx.segment(occs[0]);
        assert_eq!(seg.len(), idx.seg_len());
    }

    #[test]
    fn segment_contains_reference_around_occurrence() {
        let idx = index();
        // pick an occurrence far from the boundary
        let pos = idx
            .iter()
            .flat_map(|(_, o)| o.iter().copied())
            .find(|&p| p > 400 && (p as usize) < idx.reference.len() - 400)
            .unwrap();
        let seg = idx.segment(pos);
        let lead = (READ_LEN - K) + ETH;
        // the k-mer itself sits at offset `lead` in the segment
        assert_eq!(
            &seg[lead..lead + K],
            &idx.reference[pos as usize..pos as usize + K]
        );
    }

    #[test]
    fn window_slicing_matches_pl_semantics() {
        let idx = index();
        let pos = idx
            .iter()
            .flat_map(|(_, o)| o.iter().copied())
            .find(|&p| p > 400 && (p as usize) < idx.reference.len() - 400)
            .unwrap();
        let seg = idx.segment(pos);
        for q in [0usize, 50, READ_LEN - K] {
            let win = idx.window_of_segment(&seg, q);
            assert_eq!(win.len(), window_len(READ_LEN));
            // window start in reference coords = PL - eth
            let pl = idx.potential_location(pos, q);
            let win_start = pl - ETH as i64;
            assert_eq!(win[0], idx.reference[win_start as usize]);
            assert_eq!(
                win[window_len(READ_LEN) - 1],
                idx.reference[(win_start as usize) + window_len(READ_LEN) - 1]
            );
        }
    }

    #[test]
    fn boundary_segments_are_n_padded() {
        let idx = index();
        let first = idx.iter().flat_map(|(_, o)| o.iter().copied()).min().unwrap();
        if (first as usize) < (READ_LEN - K) + ETH {
            let seg = idx.segment(first);
            assert_eq!(seg[0], BASE_N);
        }
    }

    #[test]
    fn shard_of_is_stable_and_in_range() {
        let idx = index();
        for (kmer, _) in idx.iter() {
            for n in [1usize, 2, 3, 4, 7, 16] {
                let s = shard_of(kmer, n);
                assert!(s < n);
                assert_eq!(s, shard_of(kmer, n), "must be deterministic");
            }
            assert_eq!(shard_of(kmer, 1), 0);
        }
    }

    #[test]
    fn shard_loads_sum_and_balance() {
        let idx = index();
        let stats = idx.stats(0);
        for n in [1usize, 2, 4, 8] {
            let loads = idx.shard_loads(n);
            assert_eq!(loads.len(), n);
            assert_eq!(loads.iter().sum::<u64>() as usize, stats.n_occurrences);
        }
        // the mixed hash must not collapse a random-ish genome onto a
        // few shards: every 4-way shard gets a meaningful share
        let loads = idx.shard_loads(4);
        let total: u64 = loads.iter().sum();
        for (i, &l) in loads.iter().enumerate() {
            assert!(
                l as f64 >= 0.05 * total as f64,
                "shard {i} owns {l}/{total} occurrences — partition is degenerate"
            );
        }
    }

    #[test]
    fn stats_are_consistent() {
        let idx = index();
        let s = idx.stats(3);
        assert_eq!(s.n_minimizers, idx.n_minimizers());
        assert!(s.n_occurrences >= s.n_minimizers);
        assert!(s.max_occurrences >= 1);
        assert!(s.low_freq_minimizers <= s.n_minimizers);
        // the paper's 17x storage blowup argument: segments >> hashtable
        assert!(s.segment_storage_bytes > s.hashtable_storage_bytes);
    }
}
