//! Binary serialization of the minimizer index — the artifact the
//! paper's *offline* indexing stage produces once per reference genome
//! (§V-B). Simple length-prefixed little-endian format with a magic tag
//! and a geometry header; refuses to load indexes built for a different
//! k/W/read-length geometry.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::index::MinimizerIndex;

const MAGIC: &[u8; 8] = b"DARTPIM1";

fn w_u64<W: Write>(w: &mut W, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_u32<W: Write>(w: &mut W, v: u32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Serialize the index.
pub fn write_index<W: Write>(w: &mut W, idx: &MinimizerIndex) -> io::Result<()> {
    w.write_all(MAGIC)?;
    w_u64(w, idx.k as u64)?;
    w_u64(w, idx.w as u64)?;
    w_u64(w, idx.read_len as u64)?;
    w_u64(w, idx.reference.len() as u64)?;
    w.write_all(&idx.reference)?;
    let entries: Vec<(u64, &[u32])> = {
        let mut v: Vec<(u64, &[u32])> = idx.iter().collect();
        v.sort_unstable_by_key(|(m, _)| *m);
        v
    };
    w_u64(w, entries.len() as u64)?;
    for (m, occs) in entries {
        w_u64(w, m)?;
        w_u32(w, occs.len() as u32)?;
        for &p in occs {
            w_u32(w, p)?;
        }
    }
    Ok(())
}

/// Deserialize an index, validating the geometry header.
pub fn read_index<R: Read>(r: &mut R) -> io::Result<MinimizerIndex> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("not a DART-PIM index file"));
    }
    let k = r_u64(r)? as usize;
    let w = r_u64(r)? as usize;
    let read_len = r_u64(r)? as usize;
    if k == 0 || k > 32 || w == 0 || read_len < k {
        return Err(bad("implausible index geometry"));
    }
    let ref_len = r_u64(r)? as usize;
    let mut reference = vec![0u8; ref_len];
    r.read_exact(&mut reference)?;
    if reference.iter().any(|&c| c > 4) {
        return Err(bad("invalid base codes in reference"));
    }
    let n = r_u64(r)? as usize;
    let mut occurrences = std::collections::HashMap::with_capacity(n);
    for _ in 0..n {
        let m = r_u64(r)?;
        let cnt = r_u32(r)? as usize;
        let mut v = Vec::with_capacity(cnt);
        for _ in 0..cnt {
            let p = r_u32(r)?;
            if p as usize + k > ref_len {
                return Err(bad("occurrence out of reference bounds"));
            }
            v.push(p);
        }
        occurrences.insert(m, v);
    }
    Ok(MinimizerIndex::from_parts(occurrences, reference, k, w, read_len))
}

/// Save to a file.
pub fn save_index<P: AsRef<Path>>(path: P, idx: &MinimizerIndex) -> io::Result<()> {
    let mut f = BufWriter::new(std::fs::File::create(path)?);
    write_index(&mut f, idx)
}

/// Load from a file.
pub fn load_index<P: AsRef<Path>>(path: P) -> io::Result<MinimizerIndex> {
    let mut f = BufReader::new(std::fs::File::open(path)?);
    read_index(&mut f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::SynthConfig;
    use crate::params::{K, READ_LEN, W};

    fn index() -> MinimizerIndex {
        let g = SynthConfig { len: 30_000, ..Default::default() }.generate();
        MinimizerIndex::build(g, K, W, READ_LEN)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let idx = index();
        let mut buf = Vec::new();
        write_index(&mut buf, &idx).unwrap();
        let back = read_index(&mut buf.as_slice()).unwrap();
        assert_eq!(back.k, idx.k);
        assert_eq!(back.w, idx.w);
        assert_eq!(back.read_len, idx.read_len);
        assert_eq!(back.reference, idx.reference);
        assert_eq!(back.n_minimizers(), idx.n_minimizers());
        for (m, occs) in idx.iter() {
            assert_eq!(back.occurrences(m), occs);
        }
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        assert!(read_index(&mut &b"NOTANIDX"[..]).is_err());
        let idx = index();
        let mut buf = Vec::new();
        write_index(&mut buf, &idx).unwrap();
        let cut = buf.len() / 2;
        assert!(read_index(&mut &buf[..cut]).is_err(), "truncated file must fail");
        buf[3] = b'X';
        assert!(read_index(&mut buf.as_slice()).is_err(), "bad magic must fail");
    }

    #[test]
    fn file_roundtrip() {
        let idx = index();
        let path = std::env::temp_dir().join(format!("dartpim-idx-{}.bin", std::process::id()));
        save_index(&path, &idx).unwrap();
        let back = load_index(&path).unwrap();
        assert_eq!(back.reference, idx.reference);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loaded_index_maps_identically() {
        use crate::coordinator::{Pipeline, PipelineConfig};
        use crate::genome::synth::ReadSimConfig;
        use crate::pim::DartPimConfig;
        use crate::runtime::RustEngine;
        let idx = index();
        let reads = ReadSimConfig { n_reads: 20, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let mut buf = Vec::new();
        write_index(&mut buf, &idx).unwrap();
        let loaded = read_index(&mut buf.as_slice()).unwrap();
        let cfg = || PipelineConfig {
            dart: DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let (a, _) = Pipeline::new(&idx, cfg(), RustEngine).map_reads(&reads).unwrap();
        let (b, _) = Pipeline::new(&loaded, cfg(), RustEngine).map_reads(&reads).unwrap();
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (None, None) => {}
                (Some(x), Some(y)) => assert_eq!((x.pos, x.dist), (y.pos, y.dist)),
                _ => panic!("presence mismatch"),
            }
        }
    }
}
