//! Binary serialization of the minimizer index — the artifact the
//! paper's *offline* indexing stage produces once per reference genome
//! (§V-B). Simple length-prefixed little-endian format with a magic tag
//! and a geometry header; refuses to load indexes built for a different
//! k/W/read-length geometry.

use std::io::{self, BufReader, BufWriter, Read, Write};
use std::path::Path;

use super::index::MinimizerIndex;

const MAGIC: &[u8; 8] = b"DARTPIM1";
/// The format-family prefix of [`MAGIC`]; the trailing byte is the
/// format version, so a future-version file is distinguishable from a
/// non-index file.
const MAGIC_FAMILY: &[u8; 7] = b"DARTPIM";

fn w_u64<W: Write>(w: &mut W, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn w_u32<W: Write>(w: &mut W, v: u32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u64<R: Read>(r: &mut R) -> io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn r_u32<R: Read>(r: &mut R) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

/// Narrow a length to the format's `u32` field or fail with an error
/// naming the field — the writer-side half of the round-trip guarantee.
/// A silent `as u32` wrap here would produce a file the hardened reader
/// rejects (or, for wraps landing on plausible values, half-parses as a
/// different index), so any value that cannot round-trip must be
/// refused at write time.
pub(crate) fn checked_u32(v: usize, what: &str) -> io::Result<u32> {
    u32::try_from(v).map_err(|_| {
        io::Error::new(
            io::ErrorKind::InvalidInput,
            format!("index not serializable: {what} ({v}) exceeds the format's u32 limit"),
        )
    })
}

/// Serialize the index. Errors (rather than wrapping) on any count that
/// does not fit the format's fixed-width fields, so everything
/// [`write_index`] accepts is readable back verbatim.
pub fn write_index<W: Write>(w: &mut W, idx: &MinimizerIndex) -> io::Result<()> {
    w.write_all(MAGIC)?;
    w_u64(w, idx.k as u64)?;
    w_u64(w, idx.w as u64)?;
    w_u64(w, idx.read_len as u64)?;
    w_u64(w, idx.reference.len() as u64)?;
    w.write_all(&idx.reference)?;
    let entries: Vec<(u64, &[u32])> = {
        let mut v: Vec<(u64, &[u32])> = idx.iter().collect();
        v.sort_unstable_by_key(|(m, _)| *m);
        v
    };
    w_u64(w, entries.len() as u64)?;
    for (m, occs) in entries {
        w_u64(w, m)?;
        w_u32(w, checked_u32(occs.len(), &format!("occurrence count of {m:#x}"))?)?;
        for &p in occs {
            w_u32(w, p)?;
        }
    }
    Ok(())
}

/// Deserialize an index, rejecting truncated or corrupted inputs with a
/// descriptive error instead of misparsing.
///
/// Validation layers: magic + format version, geometry plausibility,
/// declared-vs-available length agreement for every section (declared
/// sizes are never trusted with a large up-front allocation — a corrupt
/// length field fails with "truncated", not an OOM), occurrence bounds
/// against the reference, and a trailing-bytes check so a concatenated
/// or padded file is caught rather than silently half-read.
pub fn read_index<R: Read>(r: &mut R) -> io::Result<MinimizerIndex> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            bad("truncated index: shorter than the 8-byte magic")
        } else {
            e
        }
    })?;
    if &magic != MAGIC {
        if &magic[..7] == MAGIC_FAMILY {
            return Err(bad(&format!(
                "unsupported DART-PIM index version {:?} (this build reads {:?})",
                magic[7] as char, MAGIC[7] as char
            )));
        }
        return Err(bad("not a DART-PIM index file (bad magic)"));
    }
    let k = read_u64_ctx(r, "geometry header (k)")? as usize;
    let w = read_u64_ctx(r, "geometry header (w)")? as usize;
    let read_len = read_u64_ctx(r, "geometry header (read_len)")? as usize;
    if k == 0 || k > 32 || w == 0 || read_len < k {
        return Err(bad(&format!(
            "implausible index geometry: k={k}, w={w}, read_len={read_len}"
        )));
    }
    let ref_len = read_u64_ctx(r, "reference length")? as usize;
    // read incrementally (take + read_to_end) so a corrupt ref_len can
    // only fail with "truncated", never allocate ref_len bytes up front
    let mut reference = Vec::new();
    r.by_ref().take(ref_len as u64).read_to_end(&mut reference)?;
    if reference.len() != ref_len {
        return Err(bad(&format!(
            "truncated index: reference section has {} of {} declared bytes",
            reference.len(),
            ref_len
        )));
    }
    if reference.iter().any(|&c| c > 4) {
        return Err(bad("corrupted index: invalid base codes in reference"));
    }
    let n = read_u64_ctx(r, "minimizer count")? as usize;
    if n > ref_len {
        return Err(bad(&format!(
            "corrupted index: {n} minimizers declared for a {ref_len}-base reference"
        )));
    }
    // dart-analyze: allow(determinism): deserialization target only; the
    // constructed index is read through keyed lookups or sorted/order-free
    // iteration (see the allow note in index.rs), never raw map order.
    let mut occurrences = std::collections::HashMap::with_capacity(n);
    for entry in 0..n {
        let m = read_u64_ctx(r, "minimizer entry")?;
        let cnt = read_u32_ctx(r, "occurrence count")? as usize;
        if cnt > ref_len {
            return Err(bad(&format!(
                "corrupted index: minimizer entry #{entry} declares {cnt} occurrences \
                 for a {ref_len}-base reference"
            )));
        }
        let mut v = Vec::with_capacity(cnt.min(4096));
        for _ in 0..cnt {
            let p = read_u32_ctx(r, "occurrence position")?;
            if p as usize + k > ref_len {
                return Err(bad(&format!(
                    "corrupted index: occurrence at {p} of minimizer entry #{entry} is \
                     out of reference bounds"
                )));
            }
            v.push(p);
        }
        if occurrences.insert(m, v).is_some() {
            return Err(bad(&format!(
                "corrupted index: duplicate minimizer entry {m:#x}"
            )));
        }
    }
    let mut trailing = [0u8; 1];
    if r.read(&mut trailing)? != 0 {
        return Err(bad("corrupted index: trailing bytes after the occurrence table"));
    }
    Ok(MinimizerIndex::from_parts(occurrences, reference, k, w, read_len))
}

fn read_u64_ctx<R: Read>(r: &mut R, what: &str) -> io::Result<u64> {
    r_u64(r).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            bad(&format!("truncated index: unexpected end of file in {what}"))
        } else {
            e
        }
    })
}

fn read_u32_ctx<R: Read>(r: &mut R, what: &str) -> io::Result<u32> {
    r_u32(r).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            bad(&format!("truncated index: unexpected end of file in {what}"))
        } else {
            e
        }
    })
}

/// Save to a file.
pub fn save_index<P: AsRef<Path>>(path: P, idx: &MinimizerIndex) -> io::Result<()> {
    let mut f = BufWriter::new(std::fs::File::create(path)?);
    write_index(&mut f, idx)
}

/// Load from a file.
pub fn load_index<P: AsRef<Path>>(path: P) -> io::Result<MinimizerIndex> {
    let mut f = BufReader::new(std::fs::File::open(path)?);
    read_index(&mut f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::SynthConfig;
    use crate::params::{K, READ_LEN, W};

    fn index() -> MinimizerIndex {
        let g = SynthConfig { len: 30_000, ..Default::default() }.generate();
        MinimizerIndex::build(g, K, W, READ_LEN)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let idx = index();
        let mut buf = Vec::new();
        write_index(&mut buf, &idx).unwrap();
        let back = read_index(&mut buf.as_slice()).unwrap();
        assert_eq!(back.k, idx.k);
        assert_eq!(back.w, idx.w);
        assert_eq!(back.read_len, idx.read_len);
        assert_eq!(back.reference, idx.reference);
        assert_eq!(back.n_minimizers(), idx.n_minimizers());
        for (m, occs) in idx.iter() {
            assert_eq!(back.occurrences(m), occs);
        }
    }

    #[test]
    fn rejects_garbage_and_truncation() {
        assert!(read_index(&mut &b"NOTANIDX"[..]).is_err());
        let idx = index();
        let mut buf = Vec::new();
        write_index(&mut buf, &idx).unwrap();
        let cut = buf.len() / 2;
        assert!(read_index(&mut &buf[..cut]).is_err(), "truncated file must fail");
        buf[3] = b'X';
        assert!(read_index(&mut buf.as_slice()).is_err(), "bad magic must fail");
    }

    #[test]
    fn u32_narrowing_is_total_at_the_boundaries() {
        // the exact boundary round-trips; one past it must error with a
        // message naming the offending field (a 2^32-entry occurrence
        // list cannot be materialized in a test, so the narrowing
        // helper carries the property)
        assert_eq!(checked_u32(0, "x").unwrap(), 0);
        assert_eq!(checked_u32(u32::MAX as usize, "x").unwrap(), u32::MAX);
        let err = checked_u32(u32::MAX as usize + 1, "occurrence count of 0xbeef").unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidInput);
        let msg = err.to_string();
        assert!(
            msg.contains("occurrence count of 0xbeef") && msg.contains("u32"),
            "unhelpful error: {msg}"
        );
    }

    #[test]
    fn file_roundtrip() {
        let idx = index();
        let path = std::env::temp_dir().join(format!("dartpim-idx-{}.bin", std::process::id()));
        save_index(&path, &idx).unwrap();
        let back = load_index(&path).unwrap();
        assert_eq!(back.reference, idx.reference);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn loaded_index_maps_identically() {
        use crate::coordinator::{Pipeline, PipelineConfig};
        use crate::genome::synth::ReadSimConfig;
        use crate::pim::DartPimConfig;
        use crate::runtime::RustEngine;
        let idx = index();
        let reads = ReadSimConfig { n_reads: 20, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let mut buf = Vec::new();
        write_index(&mut buf, &idx).unwrap();
        let loaded = read_index(&mut buf.as_slice()).unwrap();
        let cfg = || PipelineConfig {
            dart: DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let (a, _) = Pipeline::new(&idx, cfg(), RustEngine).map_reads(&reads).unwrap();
        let (b, _) = Pipeline::new(&loaded, cfg(), RustEngine).map_reads(&reads).unwrap();
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (None, None) => {}
                (Some(x), Some(y)) => assert_eq!((x.pos, x.dist), (y.pos, y.dist)),
                _ => panic!("presence mismatch"),
            }
        }
    }
}
