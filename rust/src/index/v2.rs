//! DARTPIM2 — the mmap-able sharded on-disk index format.
//!
//! The v1 format (`super::io`) deserializes the whole postings table
//! into one heap `HashMap`, which is the named scaling wall: a
//! GRCh38-scale index cannot load at all, and every restart re-parses
//! the file. DARTPIM2 instead lays the index out so the *file is the
//! index*: fixed little-endian sections, every section 8-byte aligned,
//! postings grouped into per-shard slabs by [`shard_of`] — the host
//! mirror of the paper's per-crossbar data organization (§V-B), where
//! each crossbar owns exactly its own slice of the reference segments.
//! A mapped process touches only the pages of the shards it queries.
//!
//! # Layout (all integers little-endian)
//!
//! ```text
//! offset  size            field
//! 0       8               magic  b"DARTPIM2"
//! 8       8               k
//! 16      8               w                (minimizer window, k-mers)
//! 24      8               read_len
//! 32      8               ref_len          (bases; <= u32::MAX)
//! 40      8               n_shards         (1 ..= 2^20)
//! 48      8               n_entries_total  (distinct minimizers)
//! 56      8               n_positions_total
//! 64      8               file_len         (whole file, bytes)
//! 72      ref_len         reference base codes (0..=4), zero-padded
//!                         to the next 8-byte boundary
//! dir     n_shards * 32   per-shard directory records:
//!                           slab_off u64 (absolute, 8-aligned)
//!                           n_entries u64
//!                           n_positions u64
//!                           slab_len u64 (8-aligned, padding included)
//! slabs   ...             shard slabs, ascending, contiguous:
//!                           keys      n_entries  x u64, strictly
//!                                     ascending, owned by this shard
//!                           ends      n_entries  x u64, cumulative
//!                                     position counts (strictly
//!                                     increasing; last == n_positions)
//!                           positions n_positions x u32, ascending
//!                                     within each entry
//!                           zero padding to the 8-byte boundary
//! ```
//!
//! A lookup is `shard_of(kmer)` → binary search the shard's key array →
//! slice `positions[ends[i-1]..ends[i]]`, all zero-copy against the
//! mapping. [`parse_v2`] validates every structural invariant above at
//! open (same hardening ethos as the v1 reader: a lying field fails
//! loudly, it never misparses), so the hot path needs no checks beyond
//! the binary search.

use std::collections::BTreeMap;
use std::io::{self, Seek, SeekFrom, Write};
use std::path::Path;

use super::index::{shard_of, window_from, MinimizerIndex};
use super::minimizer::MinimizerScan;
use super::mmap::Mmap;
use crate::genome::encode::Seq;

/// Magic tag of the DARTPIM2 format (family `DARTPIM`, version `2`).
pub const MAGIC_V2: &[u8; 8] = b"DARTPIM2";
/// Fixed header length in bytes.
pub const HEADER_LEN: usize = 72;
/// Bytes per shard-directory record.
pub const DIR_RECORD_LEN: usize = 32;
/// Upper bound on the shard count a file may declare (a format cap, not
/// a runtime tunable; 2^20 slabs is far beyond any sane partition).
pub const MAX_SHARDS: usize = 1 << 20;

/// Default shard count for newly built v2 indexes (`--shards`).
pub const DEFAULT_V2_SHARDS: usize = 16;

fn bad(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg)
}

fn bad_input(msg: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidInput, msg)
}

/// Round `x` up to the next multiple of 8.
fn align8(x: u64) -> u64 {
    (x + 7) & !7
}

fn u64_at(buf: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(buf[off..off + 8].try_into().expect("8 bytes"))
}

fn u32_at(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().expect("4 bytes"))
}

/// Validated offsets of one shard's slab inside a DARTPIM2 file.
#[derive(Debug, Clone)]
pub struct ShardLayout {
    /// Byte offset of the key array (== the slab offset; 8-aligned).
    pub keys_off: usize,
    /// Byte offset of the cumulative-ends array (8-aligned).
    pub ends_off: usize,
    /// Byte offset of the position array (8-aligned).
    pub pos_off: usize,
    /// Distinct minimizers in this shard.
    pub n_entries: usize,
    /// Occurrence positions in this shard.
    pub n_positions: usize,
}

/// Validated layout of a DARTPIM2 file: header fields plus per-shard
/// slab offsets. Holds no borrow of the buffer — offsets only — so it
/// can live next to the mapping that produced it.
#[derive(Debug, Clone)]
pub struct V2Layout {
    /// k-mer length.
    pub k: usize,
    /// Minimizer window size (k-mers).
    pub w: usize,
    /// Read length the segment geometry is built for.
    pub read_len: usize,
    /// Byte offset of the reference section (== [`HEADER_LEN`]).
    pub ref_off: usize,
    /// Reference length in bases.
    pub ref_len: usize,
    /// Shard count of the on-disk partition.
    pub n_shards: usize,
    /// Total distinct minimizers.
    pub n_entries: u64,
    /// Total occurrence positions.
    pub n_positions: u64,
    /// Per-shard slab offsets, indexed by shard id.
    pub shards: Vec<ShardLayout>,
}

/// Validate a DARTPIM2 image and return its layout.
///
/// Purely byte-wise and allocation-light: it works on any `&[u8]`
/// (unaligned test buffers included) and performs the *full* structural
/// audit — magic/version, geometry, section bounds, declared-vs-actual
/// file length, directory/slab agreement, slab alignment and
/// contiguity, key ordering and shard ownership, cumulative-end
/// monotonicity, position bounds and per-entry ordering, and zeroed
/// padding. Everything [`MappedIndex`] later does zero-copy is proven
/// here once, at open.
pub fn parse_v2(buf: &[u8]) -> io::Result<V2Layout> {
    if buf.len() < 8 {
        return Err(bad("truncated index: shorter than the 8-byte magic"));
    }
    if &buf[..8] != MAGIC_V2 {
        if &buf[..7] == b"DARTPIM" {
            return Err(bad(&format!(
                "unsupported DART-PIM index version {:?} (this reader handles '2'; convert \
                 with `index --from`)",
                buf[7] as char
            )));
        }
        return Err(bad("not a DART-PIM index file (bad magic)"));
    }
    if buf.len() < HEADER_LEN {
        return Err(bad("truncated index: incomplete DARTPIM2 header"));
    }
    let k64 = u64_at(buf, 8);
    let w64 = u64_at(buf, 16);
    let read_len64 = u64_at(buf, 24);
    let ref_len64 = u64_at(buf, 32);
    let n_shards64 = u64_at(buf, 40);
    let n_entries = u64_at(buf, 48);
    let n_positions = u64_at(buf, 56);
    let file_len = u64_at(buf, 64);
    if k64 == 0 || k64 > 32 || w64 == 0 || read_len64 < k64 {
        return Err(bad(&format!(
            "implausible index geometry: k={k64}, w={w64}, read_len={read_len64}"
        )));
    }
    if ref_len64 > u32::MAX as u64 {
        return Err(bad(&format!(
            "corrupted index: reference length {ref_len64} exceeds u32 occurrence positions"
        )));
    }
    if file_len != buf.len() as u64 {
        return Err(bad(&format!(
            "truncated or padded index: header declares {file_len} bytes, found {}",
            buf.len()
        )));
    }
    let (k, w, read_len) = (k64 as usize, w64 as usize, read_len64 as usize);
    let ref_len = ref_len64 as usize;
    let ref_end = HEADER_LEN + ref_len; // no overflow: ref_len <= u32::MAX
    if ref_end > buf.len() {
        return Err(bad(&format!(
            "truncated index: reference section needs {ref_len} bytes past the header"
        )));
    }
    if buf[HEADER_LEN..ref_end].iter().any(|&c| c > 4) {
        return Err(bad("corrupted index: invalid base codes in reference"));
    }
    if n_shards64 == 0 || n_shards64 > MAX_SHARDS as u64 {
        return Err(bad(&format!("implausible shard count {n_shards64}")));
    }
    let n_shards = n_shards64 as usize;
    let dir_off = align8(ref_end as u64) as usize;
    if dir_off > buf.len() || buf[ref_end..dir_off].iter().any(|&b| b != 0) {
        return Err(bad("corrupted index: nonzero padding after the reference"));
    }
    let dir_end = dir_off + n_shards * DIR_RECORD_LEN; // bounded by MAX_SHARDS * 32
    if dir_end > buf.len() {
        return Err(bad(&format!(
            "truncated index: shard directory needs {n_shards} records"
        )));
    }
    let mut shards = Vec::with_capacity(n_shards);
    let mut expected = dir_end as u64;
    let (mut sum_entries, mut sum_positions) = (0u64, 0u64);
    for s in 0..n_shards {
        let rec = dir_off + s * DIR_RECORD_LEN;
        let slab_off = u64_at(buf, rec);
        let n_e = u64_at(buf, rec + 8);
        let n_p = u64_at(buf, rec + 16);
        let slab_len = u64_at(buf, rec + 24);
        if slab_off % 8 != 0 {
            return Err(bad(&format!(
                "corrupted index: shard {s} slab at {slab_off} is misaligned (8-byte \
                 alignment required)"
            )));
        }
        if slab_off != expected {
            return Err(bad(&format!(
                "corrupted index: shard {s} slab at {slab_off}, expected {expected} (slabs \
                 must be contiguous)"
            )));
        }
        let payload = n_e
            .checked_mul(16)
            .and_then(|b| n_p.checked_mul(4).and_then(|p| b.checked_add(p)))
            .ok_or_else(|| bad(&format!("corrupted index: shard {s} counts overflow")))?;
        // bound the raw payload by the file before align8 (which would
        // wrap for payloads within 7 bytes of u64::MAX) — after this,
        // every count-derived offset below fits the buffer
        if payload > buf.len() as u64 {
            return Err(bad(&format!(
                "truncated index: shard {s} slab runs past the end of the file"
            )));
        }
        if slab_len != align8(payload) {
            return Err(bad(&format!(
                "corrupted index: shard {s} slab length {slab_len} disagrees with its \
                 directory counts (want {})",
                align8(payload)
            )));
        }
        let slab_end = slab_off
            .checked_add(slab_len)
            .filter(|&e| e <= buf.len() as u64)
            .ok_or_else(|| {
                bad(&format!("truncated index: shard {s} slab runs past the end of the file"))
            })?;
        sum_entries = sum_entries
            .checked_add(n_e)
            .ok_or_else(|| bad("corrupted index: entry totals overflow"))?;
        sum_positions = sum_positions
            .checked_add(n_p)
            .ok_or_else(|| bad("corrupted index: position totals overflow"))?;
        let (n_e, n_p) = (n_e as usize, n_p as usize);
        let keys_off = slab_off as usize;
        let ends_off = keys_off + 8 * n_e;
        let pos_off = ends_off + 8 * n_e;
        // keys: strictly ascending, every key owned by this shard
        let mut prev_key: Option<u64> = None;
        for i in 0..n_e {
            let key = u64_at(buf, keys_off + 8 * i);
            if prev_key.is_some_and(|p| p >= key) {
                return Err(bad(&format!("corrupted index: shard {s} keys are not sorted")));
            }
            prev_key = Some(key);
            let owner = shard_of(key, n_shards);
            if owner != s {
                return Err(bad(&format!(
                    "corrupted index: minimizer {key:#x} stored in shard {s} but owned by \
                     shard {owner}"
                )));
            }
        }
        // ends: strictly increasing cumulative counts, closing at n_p
        let mut prev_end = 0u64;
        for i in 0..n_e {
            let e = u64_at(buf, ends_off + 8 * i);
            if e <= prev_end {
                return Err(bad(&format!(
                    "corrupted index: shard {s} cumulative ends are not increasing"
                )));
            }
            prev_end = e;
        }
        if prev_end != n_p as u64 {
            return Err(bad(&format!(
                "corrupted index: shard {s} ends close at {prev_end} but the directory \
                 declares {n_p} positions"
            )));
        }
        // positions: in reference bounds, ascending within each entry
        let mut lo = 0usize;
        for i in 0..n_e {
            let hi = u64_at(buf, ends_off + 8 * i) as usize;
            let mut prev_pos: Option<u32> = None;
            for j in lo..hi {
                let p = u32_at(buf, pos_off + 4 * j);
                if p as usize + k > ref_len {
                    return Err(bad(&format!(
                        "corrupted index: occurrence at {p} in shard {s} is out of \
                         reference bounds"
                    )));
                }
                if prev_pos.is_some_and(|q| q >= p) {
                    return Err(bad(&format!(
                        "corrupted index: shard {s} occurrence positions are not sorted"
                    )));
                }
                prev_pos = Some(p);
            }
            lo = hi;
        }
        let pad_start = pos_off + 4 * n_p;
        if buf[pad_start..slab_end as usize].iter().any(|&b| b != 0) {
            return Err(bad(&format!("corrupted index: nonzero padding in shard {s} slab")));
        }
        shards.push(ShardLayout { keys_off, ends_off, pos_off, n_entries: n_e, n_positions: n_p });
        expected = slab_end;
    }
    if expected != buf.len() as u64 {
        return Err(bad("corrupted index: trailing bytes after the last slab"));
    }
    if sum_entries != n_entries || sum_positions != n_positions {
        return Err(bad(&format!(
            "corrupted index: directory totals ({sum_entries} entries, {sum_positions} \
             positions) disagree with the header ({n_entries}, {n_positions})"
        )));
    }
    Ok(V2Layout {
        k,
        w,
        read_len,
        ref_off: HEADER_LEN,
        ref_len,
        n_shards,
        n_entries,
        n_positions,
        shards,
    })
}

/// A DARTPIM2 index served zero-copy from a memory-mapped file.
///
/// Opening validates the whole image once ([`parse_v2`]); every lookup
/// after that is a shard pick + binary search over borrowed slab
/// views, touching only that shard's pages. The mapped backend returns
/// byte-identical mapping output to the heap backend (determinism
/// invariant 9, held by `tests/index_v2.rs`).
pub struct MappedIndex {
    map: Mmap,
    layout: V2Layout,
}

impl MappedIndex {
    /// Map and validate the DARTPIM2 file at `path`.
    pub fn open<P: AsRef<Path>>(path: P) -> io::Result<MappedIndex> {
        if cfg!(target_endian = "big") {
            return Err(bad_input(
                "the mapped DARTPIM2 backend requires a little-endian host (use the v1 \
                 heap backend instead)",
            ));
        }
        let path = path.as_ref();
        let map = Mmap::open(path)?;
        let layout = parse_v2(map.bytes())
            .map_err(|e| io::Error::new(e.kind(), format!("{}: {e}", path.display())))?;
        Ok(MappedIndex { map, layout })
    }

    /// k-mer length used at build time.
    pub fn k(&self) -> usize {
        self.layout.k
    }

    /// Minimizer window size (k-mers per window) used at build time.
    pub fn w(&self) -> usize {
        self.layout.w
    }

    /// Read length the segment geometry is built for.
    pub fn read_len(&self) -> usize {
        self.layout.read_len
    }

    /// Shard count of the on-disk partition (a file property,
    /// independent of the runtime worker count).
    pub fn n_shards(&self) -> usize {
        self.layout.n_shards
    }

    /// Number of distinct minimizers.
    pub fn n_minimizers(&self) -> usize {
        self.layout.n_entries as usize
    }

    /// The reference genome (base codes), borrowed from the mapping.
    pub fn reference(&self) -> &[u8] {
        &self.map.bytes()[self.layout.ref_off..self.layout.ref_off + self.layout.ref_len]
    }

    /// Occurrence positions of a minimizer (empty if absent) — a
    /// zero-copy slice of the owning shard's slab.
    pub fn occurrences(&self, kmer: u64) -> &[u32] {
        let sh = &self.layout.shards[shard_of(kmer, self.layout.n_shards)];
        let keys = self.map.u64s_at(sh.keys_off, sh.n_entries);
        match keys.binary_search(&kmer) {
            Ok(i) => {
                let ends = self.map.u64s_at(sh.ends_off, sh.n_entries);
                let lo = if i == 0 { 0 } else { ends[i - 1] as usize };
                let hi = ends[i] as usize;
                &self.map.u32s_at(sh.pos_off, sh.n_positions)[lo..hi]
            }
            Err(_) => &[],
        }
    }

    /// Banded-WF window for (occurrence `pos`, read minimizer offset
    /// `q`) — the same implementation the heap index uses.
    pub fn window_for(&self, pos: u32, q: usize) -> Seq {
        window_from(self.reference(), self.layout.read_len, pos, q)
    }

    /// Iterate over (minimizer, occurrence list) in shard-major,
    /// key-ascending order (a total order, unlike the heap backend's
    /// map order; all iter consumers are order-free).
    pub fn iter(&self) -> impl Iterator<Item = (u64, &[u32])> {
        self.layout.shards.iter().flat_map(move |sh| {
            let keys = self.map.u64s_at(sh.keys_off, sh.n_entries);
            let ends = self.map.u64s_at(sh.ends_off, sh.n_entries);
            let pos = self.map.u32s_at(sh.pos_off, sh.n_positions);
            (0..sh.n_entries).map(move |i| {
                let lo = if i == 0 { 0 } else { ends[i - 1] as usize };
                (keys[i], &pos[lo..ends[i] as usize])
            })
        })
    }

    /// Materialize a heap [`MinimizerIndex`] with identical contents —
    /// the v2 → v1 conversion path, and the bridge for heap-only
    /// consumers (`evaluate`, `simulate`).
    pub fn to_heap(&self) -> MinimizerIndex {
        // dart-analyze: allow(determinism): deserialization target only;
        // the constructed map is read through keyed lookups or
        // sorted/order-free iteration (see the allow note in index.rs).
        let mut occurrences = std::collections::HashMap::with_capacity(self.n_minimizers());
        for (kmer, occs) in self.iter() {
            occurrences.insert(kmer, occs.to_vec());
        }
        MinimizerIndex::from_parts(
            occurrences,
            self.reference().to_vec(),
            self.layout.k,
            self.layout.w,
            self.layout.read_len,
        )
    }
}

/// Per-shard postings of one shard, sorted by key — the unit both
/// writers feed to [`push_slab`].
type ShardEntries<'a> = Vec<(u64, &'a [u32])>;

/// Append one shard's slab bytes (keys, cumulative ends, positions,
/// zero padding to 8) to `out`. Entries must arrive key-sorted; both
/// writers build them from `BTreeMap`s, so any upstream `HashMap`
/// iteration order is laundered through a total order before a single
/// byte is produced.
fn push_slab(out: &mut Vec<u8>, entries: &ShardEntries<'_>) {
    for (kmer, _) in entries {
        out.extend_from_slice(&kmer.to_le_bytes());
    }
    let mut cum = 0u64;
    for (_, occs) in entries {
        cum += occs.len() as u64;
        out.extend_from_slice(&cum.to_le_bytes());
    }
    for (_, occs) in entries {
        for &p in *occs {
            out.extend_from_slice(&p.to_le_bytes());
        }
    }
    while out.len() % 8 != 0 {
        out.push(0);
    }
}

/// Serialize the 72-byte header.
fn header_bytes(
    k: usize,
    w: usize,
    read_len: usize,
    ref_len: usize,
    n_shards: usize,
    n_entries: u64,
    n_positions: u64,
    file_len: u64,
) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(MAGIC_V2);
    for (i, v) in [
        k as u64,
        w as u64,
        read_len as u64,
        ref_len as u64,
        n_shards as u64,
        n_entries,
        n_positions,
        file_len,
    ]
    .iter()
    .enumerate()
    {
        h[8 + 8 * i..16 + 8 * i].copy_from_slice(&v.to_le_bytes());
    }
    h
}

/// Writer-side validation shared by both writers: refuse anything the
/// format cannot represent (the same totality guarantee
/// [`super::io::write_index`] gives v1).
fn check_writable(
    k: usize,
    w: usize,
    read_len: usize,
    ref_len: usize,
    n_shards: usize,
) -> io::Result<()> {
    if n_shards == 0 || n_shards > MAX_SHARDS {
        return Err(bad_input(&format!(
            "index not serializable: shard count {n_shards} outside 1..={MAX_SHARDS}"
        )));
    }
    if ref_len > u32::MAX as usize {
        return Err(bad_input(&format!(
            "index not serializable: reference length {ref_len} exceeds u32 occurrence \
             positions"
        )));
    }
    if k == 0 || k > 32 || w == 0 || read_len < k {
        return Err(bad_input(&format!(
            "index not serializable: implausible geometry k={k}, w={w}, read_len={read_len}"
        )));
    }
    Ok(())
}

/// Convert a heap [`MinimizerIndex`] to DARTPIM2 (the v1 → v2
/// converter). Memory stays O(index + one slab); the output is
/// byte-identical to what the streaming builder produces for the same
/// reference and shard count (held by the tests below).
pub fn write_index_v2<W: Write>(
    w: &mut W,
    idx: &MinimizerIndex,
    n_shards: usize,
) -> io::Result<()> {
    check_writable(idx.k, idx.w, idx.read_len, idx.reference.len(), n_shards)?;
    // bucket the (unordered) heap iteration into per-shard BTreeMaps:
    // every downstream byte derives from these key-sorted maps, never
    // from HashMap order
    let mut shards: Vec<BTreeMap<u64, &[u32]>> = vec![BTreeMap::new(); n_shards];
    for (m, occs) in idx.iter() {
        shards[shard_of(m, n_shards)].insert(m, occs);
    }
    let mut n_entries = 0u64;
    let mut n_positions = 0u64;
    let mut slab_lens: Vec<u64> = Vec::with_capacity(n_shards);
    for sh in &shards {
        let e = sh.len() as u64;
        let p: u64 = sh.values().map(|o| o.len() as u64).sum();
        n_entries += e;
        n_positions += p;
        slab_lens.push(align8(16 * e + 4 * p));
    }
    let ref_len = idx.reference.len();
    let dir_off = align8((HEADER_LEN + ref_len) as u64);
    let dir_end = dir_off + (n_shards * DIR_RECORD_LEN) as u64;
    let file_len = dir_end + slab_lens.iter().sum::<u64>();
    w.write_all(&header_bytes(
        idx.k,
        idx.w,
        idx.read_len,
        ref_len,
        n_shards,
        n_entries,
        n_positions,
        file_len,
    ))?;
    w.write_all(&idx.reference)?;
    w.write_all(&vec![0u8; dir_off as usize - (HEADER_LEN + ref_len)])?;
    let mut slab_off = dir_end;
    for (sh, &slab_len) in shards.iter().zip(&slab_lens) {
        let p: u64 = sh.values().map(|o| o.len() as u64).sum();
        for v in [slab_off, sh.len() as u64, p, slab_len] {
            w.write_all(&v.to_le_bytes())?;
        }
        slab_off += slab_len;
    }
    for sh in &shards {
        let entries: ShardEntries<'_> = sh.iter().map(|(&m, &o)| (m, o)).collect();
        let mut slab = Vec::new();
        push_slab(&mut slab, &entries);
        w.write_all(&slab)?;
    }
    Ok(())
}

/// Statistics reported by the streaming builder.
#[derive(Debug, Clone)]
pub struct V2BuildStats {
    /// Total distinct minimizers written.
    pub n_entries: u64,
    /// Total occurrence positions written.
    pub n_positions: u64,
    /// Occurrence positions per shard (partition-balance report).
    pub shard_positions: Vec<u64>,
}

/// Build a DARTPIM2 index straight from a reference with bounded
/// memory — the two-pass streaming builder. Pass 1 streams the
/// reference once through [`MinimizerScan`] counting postings per
/// shard; pass 2 re-scans once per shard, holding only that shard's
/// postings, and writes its slab in place. Peak memory is O(scan
/// window + largest shard), never O(index) — a heap `MinimizerIndex`
/// is never constructed. The directory and header totals are
/// backpatched once the last slab lands, which is why the writer needs
/// `Seek`.
pub fn write_index_v2_streaming<W: Write + Seek>(
    out: &mut W,
    reference: &[u8],
    k: usize,
    w: usize,
    read_len: usize,
    n_shards: usize,
) -> io::Result<V2BuildStats> {
    check_writable(k, w, read_len, reference.len(), n_shards)?;
    let base = out.stream_position()?;
    // pass 1: one streaming scan, counting postings per shard
    let mut shard_positions = vec![0u64; n_shards];
    for m in MinimizerScan::new(reference, k, w) {
        shard_positions[shard_of(m.kmer, n_shards)] += 1;
    }
    let ref_len = reference.len();
    let dir_off = align8((HEADER_LEN + ref_len) as u64);
    let dir_end = dir_off + (n_shards * DIR_RECORD_LEN) as u64;
    // placeholders for the header and directory; backpatched below
    out.write_all(&[0u8; HEADER_LEN])?;
    out.write_all(reference)?;
    out.write_all(&vec![0u8; dir_off as usize - (HEADER_LEN + ref_len)])?;
    out.write_all(&vec![0u8; n_shards * DIR_RECORD_LEN])?;
    // pass 2: one sub-pass per shard, memory O(that shard)
    let mut dir: Vec<[u64; 4]> = Vec::with_capacity(n_shards);
    let mut slab_off = dir_end;
    let mut n_entries = 0u64;
    let mut n_positions = 0u64;
    for s in 0..n_shards {
        let mut postings: BTreeMap<u64, Vec<u32>> = BTreeMap::new();
        for m in MinimizerScan::new(reference, k, w) {
            if shard_of(m.kmer, n_shards) == s {
                postings.entry(m.kmer).or_default().push(m.pos);
            }
        }
        // mirror MinimizerIndex::build exactly: sorted, deduplicated
        for v in postings.values_mut() {
            v.sort_unstable();
            v.dedup();
        }
        let entries: ShardEntries<'_> =
            postings.iter().map(|(&m, o)| (m, o.as_slice())).collect();
        let e = entries.len() as u64;
        let p: u64 = entries.iter().map(|(_, o)| o.len() as u64).sum();
        let mut slab = Vec::new();
        push_slab(&mut slab, &entries);
        out.write_all(&slab)?;
        dir.push([slab_off, e, p, slab.len() as u64]);
        slab_off += slab.len() as u64;
        n_entries += e;
        n_positions += p;
    }
    let file_len = slab_off;
    // backpatch the directory, then the header, then park at the end
    out.seek(SeekFrom::Start(base + dir_off))?;
    for rec in &dir {
        for v in rec {
            out.write_all(&v.to_le_bytes())?;
        }
    }
    out.seek(SeekFrom::Start(base))?;
    out.write_all(&header_bytes(
        k,
        w,
        read_len,
        ref_len,
        n_shards,
        n_entries,
        n_positions,
        file_len,
    ))?;
    out.seek(SeekFrom::Start(base + file_len))?;
    Ok(V2BuildStats { n_entries, n_positions, shard_positions })
}

/// Convert a heap index to a DARTPIM2 file at `path`.
pub fn save_index_v2<P: AsRef<Path>>(
    path: P,
    idx: &MinimizerIndex,
    n_shards: usize,
) -> io::Result<()> {
    let mut f = io::BufWriter::new(std::fs::File::create(path)?);
    write_index_v2(&mut f, idx, n_shards)?;
    f.flush()
}

/// Build a DARTPIM2 file at `path` straight from a reference with
/// bounded memory (see [`write_index_v2_streaming`]).
pub fn build_index_v2<P: AsRef<Path>>(
    path: P,
    reference: &[u8],
    k: usize,
    w: usize,
    read_len: usize,
    n_shards: usize,
) -> io::Result<V2BuildStats> {
    let mut f = io::BufWriter::new(std::fs::File::create(path)?);
    let stats = write_index_v2_streaming(&mut f, reference, k, w, read_len, n_shards)?;
    f.flush()?;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::SynthConfig;
    use crate::params::{K, READ_LEN, W};

    fn build() -> MinimizerIndex {
        let g = SynthConfig { len: 30_000, ..Default::default() }.generate();
        MinimizerIndex::build(g, K, W, READ_LEN)
    }

    fn v2_bytes(idx: &MinimizerIndex, n_shards: usize) -> Vec<u8> {
        let mut buf = Vec::new();
        write_index_v2(&mut buf, idx, n_shards).unwrap();
        buf
    }

    #[test]
    fn converter_output_parses_and_round_trips_contents() {
        let idx = build();
        for n_shards in [1usize, 4, 16] {
            let buf = v2_bytes(&idx, n_shards);
            let layout = parse_v2(&buf).unwrap();
            assert_eq!(layout.n_shards, n_shards);
            assert_eq!(layout.n_entries as usize, idx.n_minimizers());
            assert_eq!((layout.k, layout.w, layout.read_len), (idx.k, idx.w, idx.read_len));
            assert_eq!(&buf[layout.ref_off..layout.ref_off + layout.ref_len], &idx.reference[..]);
        }
    }

    #[test]
    fn streaming_builder_matches_converter_byte_for_byte() {
        let idx = build();
        for n_shards in [1usize, 3, 16] {
            let converted = v2_bytes(&idx, n_shards);
            let mut streamed = io::Cursor::new(Vec::new());
            let stats = write_index_v2_streaming(
                &mut streamed,
                &idx.reference,
                idx.k,
                idx.w,
                idx.read_len,
                n_shards,
            )
            .unwrap();
            assert_eq!(
                converted,
                streamed.into_inner(),
                "shards={n_shards}: the two build paths must agree bytewise"
            );
            assert_eq!(stats.n_entries as usize, idx.n_minimizers());
            assert_eq!(stats.shard_positions.len(), n_shards);
            assert_eq!(
                stats.shard_positions.iter().sum::<u64>(),
                stats.n_positions,
                "pass-1 balance counts must sum to the written total"
            );
        }
    }

    #[test]
    fn mapped_lookups_match_heap_lookups() {
        let idx = build();
        let path =
            std::env::temp_dir().join(format!("dartpim-v2-{}.idx2", std::process::id()));
        save_index_v2(&path, &idx, 8).unwrap();
        let mapped = MappedIndex::open(&path).unwrap();
        assert_eq!(mapped.n_minimizers(), idx.n_minimizers());
        assert_eq!(mapped.reference(), &idx.reference[..]);
        for (m, occs) in idx.iter() {
            assert_eq!(mapped.occurrences(m), occs, "minimizer {m:#x}");
        }
        assert_eq!(mapped.occurrences(0xFFFF_FFFF_FFFF_FFFF), &[] as &[u32]);
        // windows must come out identical too (shared implementation)
        let (_, occs) = idx.iter().next().unwrap();
        assert_eq!(mapped.window_for(occs[0], 3), idx.window_for(occs[0], 3));
        let heap_again = mapped.to_heap();
        assert_eq!(heap_again.n_minimizers(), idx.n_minimizers());
        for (m, occs) in idx.iter() {
            assert_eq!(heap_again.occurrences(m), occs);
        }
        drop(mapped);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn writers_refuse_unserializable_inputs() {
        let idx = build();
        let mut sink = Vec::new();
        let err = write_index_v2(&mut sink, &idx, 0).unwrap_err();
        assert!(err.to_string().contains("shard count"), "{err}");
        let err = write_index_v2(&mut sink, &idx, MAX_SHARDS + 1).unwrap_err();
        assert!(err.to_string().contains("shard count"), "{err}");
        let mut cur = io::Cursor::new(Vec::new());
        let err =
            write_index_v2_streaming(&mut cur, &idx.reference, 0, W, READ_LEN, 4).unwrap_err();
        assert!(err.to_string().contains("geometry"), "{err}");
    }

    #[test]
    fn empty_reference_builds_an_empty_valid_index() {
        let mut cur = io::Cursor::new(Vec::new());
        let stats = write_index_v2_streaming(&mut cur, &[], K, W, READ_LEN, 4).unwrap();
        assert_eq!(stats.n_entries, 0);
        let buf = cur.into_inner();
        let layout = parse_v2(&buf).unwrap();
        assert_eq!(layout.n_entries, 0);
        assert_eq!(layout.n_positions, 0);
    }
}
