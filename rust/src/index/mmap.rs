//! Read-only file memory mapping via the C `mmap(2)` entry point.
//!
//! This offline build vendors no `libc`/`memmap2`, so the mapping goes
//! through bare `extern "C"` declarations, the same way
//! [`crate::serve`]'s signal latch binds `signal(2)`. The wrapper is
//! deliberately minimal: map a whole file read-only and private, expose
//! the bytes, and unmap on drop. Every `unsafe` in the mapped-index
//! backend lives in this module — callers only ever see checked safe
//! slices — so dart-analyze's `unsafe` audit covers the entire surface
//! in one place.

use std::fs::File;
use std::io;
use std::os::unix::io::AsRawFd;
use std::path::Path;

/// `PROT_READ` from `<sys/mman.h>` (asm-generic value, shared by
/// x86-64 / aarch64 Linux).
const PROT_READ: i32 = 1;
/// `MAP_PRIVATE` from `<sys/mman.h>` (asm-generic value).
const MAP_PRIVATE: i32 = 2;

extern "C" {
    /// C library `mmap(2)`: maps `len` bytes of `fd` from `offset`;
    /// returns `MAP_FAILED` (-1 cast to a pointer) on error.
    fn mmap(addr: *mut u8, len: usize, prot: i32, flags: i32, fd: i32, offset: i64) -> *mut u8;
    /// C library `munmap(2)`: releases a mapping created by `mmap`.
    fn munmap(addr: *mut u8, len: usize) -> i32;
}

/// A read-only, private, whole-file memory mapping.
///
/// The kernel pages file contents in on demand, so opening a mapping is
/// O(1) in file size and resident memory grows only with the pages
/// actually touched — the property the DARTPIM2 mapped backend is
/// built on. The base address is page-aligned (a kernel guarantee), so
/// any 8-aligned file offset is also 8-aligned in memory; the typed
/// accessors below rely on that.
pub struct Mmap {
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ + MAP_PRIVATE: the pages cannot be
// written through this handle and carry no interior mutability, so
// moving the handle across threads is sound.
unsafe impl Send for Mmap {}

// SAFETY: as above — concurrent reads of immutable pages are sound.
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map the whole file at `path` read-only. Empty files are rejected
    /// (`mmap` cannot create zero-length mappings; an empty file is
    /// never a valid index anyway).
    pub fn open(path: &Path) -> io::Result<Mmap> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        if len == 0 {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{}: cannot map an empty file", path.display()),
            ));
        }
        let len = usize::try_from(len).map_err(|_| {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{}: file exceeds the address space", path.display()),
            )
        })?;
        // SAFETY: `mmap` is the C library entry point; a whole-file
        // PROT_READ + MAP_PRIVATE mapping of an owned descriptor
        // aliases no Rust-managed memory, and the returned region
        // (checked against MAP_FAILED below) stays valid until the
        // matching `munmap` in `Drop`. The descriptor may close right
        // after — POSIX keeps the mapping alive independently.
        let ptr = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr as usize == usize::MAX {
            return Err(io::Error::new(
                io::Error::last_os_error().kind(),
                format!("{}: mmap failed: {}", path.display(), io::Error::last_os_error()),
            ));
        }
        Ok(Mmap { ptr, len })
    }

    /// The mapped bytes.
    pub fn bytes(&self) -> &[u8] {
        // SAFETY: `ptr` is a live PROT_READ mapping of exactly `len`
        // bytes (established in `open`, released only in `Drop`), and
        // the pages are never written through any alias in this
        // process, so the slice is valid and immutable for the
        // borrow's lifetime.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// The `n` native-endian `u64` words starting at byte offset `off`
    /// — zero-copy. DARTPIM2 stores little-endian words and refuses to
    /// open on big-endian hosts, so native == file order wherever this
    /// can run.
    ///
    /// # Panics
    ///
    /// If the range leaves the mapping or `off` is not 8-byte aligned;
    /// the DARTPIM2 validator establishes both before any call.
    pub fn u64s_at(&self, off: usize, n: usize) -> &[u64] {
        let bytes = n.checked_mul(8).expect("u64 range overflows");
        assert!(off.checked_add(bytes).is_some_and(|end| end <= self.len), "u64 range OOB");
        assert!(off % 8 == 0, "u64 range misaligned");
        // SAFETY: the range is in bounds and 8-aligned (asserted above;
        // the base address is page-aligned), the mapping is immutable
        // and outlives the borrow, and any bit pattern is a valid u64.
        unsafe { std::slice::from_raw_parts(self.ptr.add(off) as *const u64, n) }
    }

    /// The `n` native-endian `u32` words starting at byte offset `off`
    /// — zero-copy; same contract as [`Mmap::u64s_at`] with 4-byte
    /// alignment.
    ///
    /// # Panics
    ///
    /// If the range leaves the mapping or `off` is not 4-byte aligned.
    pub fn u32s_at(&self, off: usize, n: usize) -> &[u32] {
        let bytes = n.checked_mul(4).expect("u32 range overflows");
        assert!(off.checked_add(bytes).is_some_and(|end| end <= self.len), "u32 range OOB");
        assert!(off % 4 == 0, "u32 range misaligned");
        // SAFETY: the range is in bounds and 4-aligned (asserted above;
        // the base address is page-aligned), the mapping is immutable
        // and outlives the borrow, and any bit pattern is a valid u32.
        unsafe { std::slice::from_raw_parts(self.ptr.add(off) as *const u32, n) }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: `ptr`/`len` describe exactly the mapping created in
        // `open` and unmapped nowhere else; after this call the pointer
        // is never dereferenced again.
        unsafe {
            munmap(self.ptr, self.len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("dartpim-mmap-{}-{name}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn maps_file_bytes_exactly() {
        let want: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let p = tmp("bytes.bin", &want);
        let m = Mmap::open(&p).unwrap();
        assert_eq!(m.bytes(), want.as_slice());
        drop(m);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_and_missing_files_are_rejected() {
        let p = tmp("empty.bin", b"");
        let err = Mmap::open(&p).unwrap_err();
        assert!(err.to_string().contains("empty"), "{err}");
        std::fs::remove_file(&p).ok();
        assert!(Mmap::open(std::path::Path::new("/nonexistent/dartpim.idx")).is_err());
    }

    #[test]
    fn typed_views_decode_little_endian_words() {
        let mut bytes = Vec::new();
        for v in [1u64, u64::MAX, 0xDEAD_BEEF_0123_4567] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        for v in [7u32, u32::MAX] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        let p = tmp("words.bin", &bytes);
        let m = Mmap::open(&p).unwrap();
        assert_eq!(m.u64s_at(0, 3), &[1, u64::MAX, 0xDEAD_BEEF_0123_4567]);
        assert_eq!(m.u32s_at(24, 2), &[7, u32::MAX]);
        drop(m);
        std::fs::remove_file(&p).ok();
    }
}
