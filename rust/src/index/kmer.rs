//! k-mer packing and hashing.

/// Pack a k-mer (base codes, no N) into a `u64`, 2 bits per base.
/// Returns `None` if any base is N/padding.
#[inline]
pub fn pack_kmer(seq: &[u8]) -> Option<u64> {
    debug_assert!(seq.len() <= 32);
    let mut v: u64 = 0;
    for &c in seq {
        if c >= 4 {
            return None;
        }
        v = (v << 2) | c as u64;
    }
    Some(v)
}

/// Invertible 64-bit mix (splitmix64 finalizer). Used to order k-mers for
/// minimizer selection so that the minimum is pseudo-random rather than
/// biased toward poly-A (the standard minimizer-robustness trick, cf.
/// minimap2's hash).
#[inline]
pub fn kmer_hash(v: u64) -> u64 {
    let mut z = v.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Rolling k-mer iterator over a sequence: yields `(pos, packed)` for
/// every N-free k-mer window.
pub struct KmerIter<'a> {
    seq: &'a [u8],
    k: usize,
    pos: usize,
    cur: u64,
    valid: usize, // number of consecutive non-N bases ending at pos-1
    mask: u64,
}

impl<'a> KmerIter<'a> {
    /// Iterator over `seq` with k-mer length `k` (1..=32).
    pub fn new(seq: &'a [u8], k: usize) -> Self {
        assert!(k >= 1 && k <= 32);
        KmerIter { seq, k, pos: 0, cur: 0, valid: 0, mask: (1u64 << (2 * k)) - 1 }
    }
}

impl<'a> Iterator for KmerIter<'a> {
    type Item = (u32, u64);

    fn next(&mut self) -> Option<(u32, u64)> {
        while self.pos < self.seq.len() {
            let c = self.seq[self.pos];
            self.pos += 1;
            if c >= 4 {
                self.valid = 0;
                self.cur = 0;
                continue;
            }
            self.cur = ((self.cur << 2) | c as u64) & self.mask;
            self.valid += 1;
            if self.valid >= self.k {
                return Some(((self.pos - self.k) as u32, self.cur));
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::encode_seq;

    #[test]
    fn pack_matches_manual() {
        let s = encode_seq(b"ACGT");
        assert_eq!(pack_kmer(&s), Some(0b00_01_10_11));
        assert_eq!(pack_kmer(&encode_seq(b"ACNG")), None);
    }

    #[test]
    fn rolling_matches_direct() {
        let s = encode_seq(b"ACGTTGCAGT");
        let k = 4;
        let rolled: Vec<_> = KmerIter::new(&s, k).collect();
        let direct: Vec<_> = (0..=s.len() - k)
            .filter_map(|i| pack_kmer(&s[i..i + k]).map(|v| (i as u32, v)))
            .collect();
        assert_eq!(rolled, direct);
    }

    #[test]
    fn rolling_skips_n_windows() {
        let s = encode_seq(b"ACGTNACGT");
        let got: Vec<u32> = KmerIter::new(&s, 4).map(|(p, _)| p).collect();
        assert_eq!(got, vec![0, 5]); // windows overlapping the N are dropped
    }

    #[test]
    fn hash_is_deterministic_and_spreads() {
        let a = kmer_hash(0);
        let b = kmer_hash(1);
        assert_ne!(a, b);
        assert_eq!(a, kmer_hash(0));
    }
}
