//! Minimizer selection (Roberts et al. scheme, paper §II).
//!
//! A window of `W` consecutive k-mers (W + k − 1 bases) is represented by
//! its minimum-hash k-mer. Consecutive windows usually share their
//! minimizer, so the per-sequence minimizer set is sparse (~2/(W+1)
//! density). Selection uses a monotone deque for O(n) total time.

use super::kmer::{kmer_hash, KmerIter};

/// One selected minimizer occurrence.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Minimizer {
    /// Start position of the k-mer in the sequence.
    pub pos: u32,
    /// Packed 2-bit k-mer value (the minimizer id used for routing).
    pub kmer: u64,
}

/// Streaming minimizer selection over a sequence: yields each selected
/// [`Minimizer`] in emission order with O(w) state, no matter how long
/// the input is. [`minimizers`] is its collect; the DARTPIM2 streaming
/// index builder iterates it directly so whole-genome index
/// construction never materializes the minimizer list.
pub struct MinimizerScan<'a> {
    kmers: KmerIter<'a>,
    w: usize,
    /// Monotone deque of (pos, kmer, hash), increasing hash
    /// front-to-back.
    deque: std::collections::VecDeque<(u32, u64, u64)>,
    n_kmers: usize,
    last_reported: Option<(u32, u64)>,
}

impl<'a> MinimizerScan<'a> {
    /// Scan `seq` with k-mer length `k` and a window of `w` k-mers.
    /// Deduplicates consecutive repeats (same (pos, kmer) chosen by
    /// adjacent windows is reported once). Ties within a window are
    /// broken toward the *rightmost* position (minimap2 convention).
    pub fn new(seq: &'a [u8], k: usize, w: usize) -> Self {
        assert!(w >= 1);
        MinimizerScan {
            kmers: KmerIter::new(seq, k),
            w,
            deque: Default::default(),
            n_kmers: 0,
            last_reported: None,
        }
    }
}

impl Iterator for MinimizerScan<'_> {
    type Item = Minimizer;

    fn next(&mut self) -> Option<Minimizer> {
        for (pos, kmer) in self.kmers.by_ref() {
            let h = kmer_hash(kmer);
            // Note: KmerIter skips N-interrupted regions; positions
            // restart monotonically, so stale entries are evicted by
            // the window check.
            while let Some(&(_, _, bh)) = self.deque.back() {
                if bh >= h {
                    self.deque.pop_back(); // rightmost tie-break: >= evicts equals
                } else {
                    break;
                }
            }
            self.deque.push_back((pos, kmer, h));
            self.n_kmers += 1;
            // Evict k-mers that fell out of the current window of w
            // k-mers (window = k-mer start positions in [pos-w+1, pos]).
            while let Some(&(fp, _, _)) = self.deque.front() {
                if fp + (self.w as u32) <= pos {
                    self.deque.pop_front();
                } else {
                    break;
                }
            }
            if self.n_kmers >= self.w {
                let &(mp, mk, _) =
                    self.deque.front().expect("deque non-empty within a window");
                if self.last_reported != Some((mp, mk)) {
                    self.last_reported = Some((mp, mk));
                    return Some(Minimizer { pos: mp, kmer: mk });
                }
            }
        }
        None
    }
}

/// Select minimizers of `seq` with k-mer length `k` and window of `w`
/// k-mers — the materialized form of [`MinimizerScan`] (identical
/// emissions by construction).
pub fn minimizers(seq: &[u8], k: usize, w: usize) -> Vec<Minimizer> {
    MinimizerScan::new(seq, k, w).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::encode_seq;
    use crate::genome::synth::SynthConfig;

    /// Brute-force oracle: min-hash per window, rightmost tie-break.
    fn brute(seq: &[u8], k: usize, w: usize) -> Vec<Minimizer> {
        let kmers: Vec<(u32, u64)> = KmerIter::new(seq, k).collect();
        let mut out = Vec::new();
        let mut last = None;
        // only valid for N-free sequences (contiguous kmer positions)
        for win in kmers.windows(w) {
            let m = win
                .iter()
                .map(|&(p, v)| (kmer_hash(v), p, v))
                .fold(None::<(u64, u32, u64)>, |acc, x| match acc {
                    None => Some(x),
                    Some(a) => Some(if x.0 < a.0 || (x.0 == a.0 && x.1 > a.1) { x } else { a }),
                })
                .unwrap();
            if last != Some((m.1, m.2)) {
                out.push(Minimizer { pos: m.1, kmer: m.2 });
                last = Some((m.1, m.2));
            }
        }
        out
    }

    #[test]
    fn matches_bruteforce_on_random_sequences() {
        for seed in 0..5u64 {
            let g = SynthConfig { len: 2000, seed, repeat_fraction: 0.2, ..Default::default() }
                .generate();
            for (k, w) in [(5, 4), (12, 19), (8, 11)] {
                assert_eq!(minimizers(&g, k, w), brute(&g, k, w), "k={k} w={w} seed={seed}");
            }
        }
    }

    #[test]
    fn density_is_about_2_over_w_plus_1() {
        let g = SynthConfig { len: 200_000, repeat_fraction: 0.0, ..Default::default() }.generate();
        let (k, w) = (12, 19);
        let m = minimizers(&g, k, w);
        let density = m.len() as f64 / g.len() as f64;
        let expect = 2.0 / (w as f64 + 1.0);
        assert!((density - expect).abs() / expect < 0.15, "density={density} expect≈{expect}");
    }

    #[test]
    fn identical_windows_share_minimizers() {
        // a repeated block yields the same minimizer k-mers in both copies
        let unit = SynthConfig { len: 400, repeat_fraction: 0.0, ..Default::default() }.generate();
        let mut g = unit.clone();
        g.extend_from_slice(&unit);
        let m = minimizers(&g, 12, 19);
        let first: std::collections::HashSet<u64> =
            m.iter().filter(|mm| (mm.pos as usize) < 300).map(|mm| mm.kmer).collect();
        let second: std::collections::HashSet<u64> =
            m.iter()
                .filter(|mm| (mm.pos as usize) >= 400 && (mm.pos as usize) < 700)
                .map(|mm| mm.kmer)
                .collect();
        let shared = first.intersection(&second).count();
        assert!(shared * 2 >= first.len(), "repeat copies should share most minimizers");
    }

    #[test]
    fn short_sequence_yields_nothing() {
        let g = encode_seq(b"ACGTACGT");
        assert!(minimizers(&g, 12, 19).is_empty());
    }

    #[test]
    fn positions_are_valid_kmer_starts() {
        let g = SynthConfig { len: 5000, ..Default::default() }.generate();
        for m in minimizers(&g, 12, 19) {
            assert!((m.pos as usize) + 12 <= g.len());
            let packed = crate::index::kmer::pack_kmer(&g[m.pos as usize..m.pos as usize + 12]);
            assert_eq!(packed, Some(m.kmer));
        }
    }
}
