//! Minimizer indexing of the reference genome (paper §II, §V-B).
//!
//! The offline stage of DART-PIM: select minimizers (k = 12, W = 30) over
//! the reference and record, per minimizer, every occurrence position
//! plus the surrounding *reference segment* (2(rl+eth)−k bases) that a
//! crossbar stores verbatim.
//!
//! Two on-disk formats back the same query interface ([`IndexRef`]):
//! `DARTPIM1` (heap-deserialized, [`io`]) and `DARTPIM2` (mmap-able
//! sharded slabs served zero-copy, [`v2`] over [`mmap`]).

pub mod backend;
pub mod io;
pub mod kmer;
pub mod minimizer;
pub mod mmap;
pub mod v2;
#[allow(clippy::module_inception)]
pub mod index;

pub use backend::{sniff_format, IndexBackend, IndexFormat, IndexRef};
pub use index::{shard_of, IndexStats, MinimizerIndex};
pub use io::{load_index, save_index};
pub use kmer::{kmer_hash, pack_kmer};
pub use minimizer::{minimizers, Minimizer, MinimizerScan};
pub use mmap::Mmap;
pub use v2::{build_index_v2, parse_v2, save_index_v2, MappedIndex, V2BuildStats};
