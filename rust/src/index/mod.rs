//! Minimizer indexing of the reference genome (paper §II, §V-B).
//!
//! The offline stage of DART-PIM: select minimizers (k = 12, W = 30) over
//! the reference and record, per minimizer, every occurrence position
//! plus the surrounding *reference segment* (2(rl+eth)−k bases) that a
//! crossbar stores verbatim.

pub mod io;
pub mod kmer;
pub mod minimizer;
#[allow(clippy::module_inception)]
pub mod index;

pub use index::{shard_of, IndexStats, MinimizerIndex};
pub use io::{load_index, save_index};
pub use kmer::{kmer_hash, pack_kmer};
pub use minimizer::{minimizers, Minimizer};
