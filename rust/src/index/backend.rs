//! Index backends: one query interface over the heap (v1) and mapped
//! (DARTPIM2) representations.
//!
//! The pipeline, router, seeder, and serve daemon are all written
//! against [`IndexRef`], a `Copy` by-reference view. Its contract is
//! determinism invariant 9: *for a fixed index content, every query —
//! `occurrences`, `window_for`, geometry — returns identical results
//! from both backends, so the mapping output bytes never depend on
//! which backend served them.* `occurrences` hits the same
//! sorted-deduplicated position lists either way, and `window_for` is
//! literally the same function (`super::index::window_from`) on both
//! arms.

use std::io::{self, Read};
use std::path::Path;

use super::index::MinimizerIndex;
use super::v2::MappedIndex;
use crate::genome::encode::Seq;

/// On-disk index format selector (the `--index-format` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexFormat {
    /// `DARTPIM1`: length-prefixed stream, deserialized into the heap.
    V1,
    /// `DARTPIM2`: mmap-able sharded slabs, served zero-copy.
    V2,
}

impl IndexFormat {
    /// The flag spelling (`v1` / `v2`).
    pub fn as_str(self) -> &'static str {
        match self {
            IndexFormat::V1 => "v1",
            IndexFormat::V2 => "v2",
        }
    }
}

/// Identify an index file's format from its magic tag — the auto-detect
/// behind `map`/`serve` when `--index-format` is not forced.
pub fn sniff_format<P: AsRef<Path>>(path: P) -> io::Result<IndexFormat> {
    let path = path.as_ref();
    let mut magic = [0u8; 8];
    std::fs::File::open(path)?.read_exact(&mut magic).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            io::Error::new(
                io::ErrorKind::InvalidData,
                format!("{}: truncated index: shorter than the 8-byte magic", path.display()),
            )
        } else {
            e
        }
    })?;
    match &magic {
        b"DARTPIM1" => Ok(IndexFormat::V1),
        b"DARTPIM2" => Ok(IndexFormat::V2),
        _ => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("{}: not a DART-PIM index file (bad magic)", path.display()),
        )),
    }
}

/// An owned index, either backend. The CLI resolves flags and file
/// magic into one of these, then hands [`IndexBackend::view`] to the
/// pipeline.
pub enum IndexBackend {
    /// Heap-resident [`MinimizerIndex`] (v1 files, or in-memory builds).
    Heap(MinimizerIndex),
    /// Memory-mapped DARTPIM2 file served zero-copy.
    Mapped(MappedIndex),
}

impl IndexBackend {
    /// Borrow the backend as the common query view.
    pub fn view(&self) -> IndexRef<'_> {
        match self {
            IndexBackend::Heap(idx) => IndexRef::Heap(idx),
            IndexBackend::Mapped(idx) => IndexRef::Mapped(idx),
        }
    }

    /// Human-readable backend name for banners and logs.
    pub fn kind(&self) -> &'static str {
        match self {
            IndexBackend::Heap(_) => "heap",
            IndexBackend::Mapped(_) => "mapped",
        }
    }
}

/// A `Copy` by-reference view of either index backend — the type every
/// index consumer takes. Public constructors accept
/// `impl Into<IndexRef>`, so existing `&MinimizerIndex` call sites keep
/// working unchanged.
#[derive(Clone, Copy)]
pub enum IndexRef<'a> {
    /// Borrowed heap index.
    Heap(&'a MinimizerIndex),
    /// Borrowed mapped index.
    Mapped(&'a MappedIndex),
}

impl<'a> From<&'a MinimizerIndex> for IndexRef<'a> {
    fn from(idx: &'a MinimizerIndex) -> IndexRef<'a> {
        IndexRef::Heap(idx)
    }
}

impl<'a> From<&'a MappedIndex> for IndexRef<'a> {
    fn from(idx: &'a MappedIndex) -> IndexRef<'a> {
        IndexRef::Mapped(idx)
    }
}

impl<'a> From<&'a IndexBackend> for IndexRef<'a> {
    fn from(b: &'a IndexBackend) -> IndexRef<'a> {
        b.view()
    }
}

impl<'a> IndexRef<'a> {
    /// k-mer length used at build time.
    pub fn k(self) -> usize {
        match self {
            IndexRef::Heap(idx) => idx.k,
            IndexRef::Mapped(idx) => idx.k(),
        }
    }

    /// Minimizer window size (k-mers per window) used at build time.
    pub fn w(self) -> usize {
        match self {
            IndexRef::Heap(idx) => idx.w,
            IndexRef::Mapped(idx) => idx.w(),
        }
    }

    /// Read length the segment geometry is built for.
    pub fn read_len(self) -> usize {
        match self {
            IndexRef::Heap(idx) => idx.read_len,
            IndexRef::Mapped(idx) => idx.read_len(),
        }
    }

    /// The reference genome (base codes).
    pub fn reference(self) -> &'a [u8] {
        match self {
            IndexRef::Heap(idx) => &idx.reference,
            IndexRef::Mapped(idx) => idx.reference(),
        }
    }

    /// Number of distinct minimizers.
    pub fn n_minimizers(self) -> usize {
        match self {
            IndexRef::Heap(idx) => idx.n_minimizers(),
            IndexRef::Mapped(idx) => idx.n_minimizers(),
        }
    }

    /// Occurrence positions of a minimizer (sorted ascending, empty if
    /// absent) — identical lists from both backends (invariant 9).
    pub fn occurrences(self, kmer: u64) -> &'a [u32] {
        match self {
            IndexRef::Heap(idx) => idx.occurrences(kmer),
            IndexRef::Mapped(idx) => idx.occurrences(kmer),
        }
    }

    /// Banded-WF window for (occurrence `pos`, read minimizer offset
    /// `q`) — one shared implementation behind both arms, so the
    /// alignment inputs cannot diverge by backend.
    pub fn window_for(self, pos: u32, q: usize) -> Seq {
        match self {
            IndexRef::Heap(idx) => idx.window_for(pos, q),
            IndexRef::Mapped(idx) => idx.window_for(pos, q),
        }
    }

    /// Iterate over (minimizer, occurrence list). Iteration *order*
    /// differs by backend (heap: map order; mapped: shard-major sorted)
    /// — every consumer either sorts or is order-free, which
    /// dart-analyze's determinism taint check enforces.
    pub fn iter(self) -> Box<dyn Iterator<Item = (u64, &'a [u32])> + 'a> {
        match self {
            IndexRef::Heap(idx) => Box::new(idx.iter()),
            IndexRef::Mapped(idx) => Box::new(idx.iter()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::SynthConfig;
    use crate::index::v2::save_index_v2;
    use crate::params::{K, READ_LEN, W};

    #[test]
    fn both_backends_answer_every_query_identically() {
        let g = SynthConfig { len: 30_000, ..Default::default() }.generate();
        let heap = MinimizerIndex::build(g, K, W, READ_LEN);
        let path =
            std::env::temp_dir().join(format!("dartpim-backend-{}.idx2", std::process::id()));
        save_index_v2(&path, &heap, 8).unwrap();
        let backend = IndexBackend::Mapped(MappedIndex::open(&path).unwrap());
        let (h, m) = (IndexRef::from(&heap), backend.view());
        assert_eq!((h.k(), h.w(), h.read_len()), (m.k(), m.w(), m.read_len()));
        assert_eq!(h.reference(), m.reference());
        assert_eq!(h.n_minimizers(), m.n_minimizers());
        for (kmer, occs) in h.iter() {
            assert_eq!(m.occurrences(kmer), occs, "minimizer {kmer:#x}");
            assert_eq!(h.window_for(occs[0], 2), m.window_for(occs[0], 2));
        }
        // both iterations cover the same entry set (order may differ:
        // the mapped backend is shard-major, sorted within each shard)
        let mut hk: Vec<u64> = h.iter().map(|(k, _)| k).collect();
        hk.sort_unstable();
        let mut mk: Vec<u64> = m.iter().map(|(k, _)| k).collect();
        mk.sort_unstable();
        assert_eq!(hk, mk);
        drop(backend);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn sniffing_distinguishes_formats_and_garbage() {
        let g = SynthConfig { len: 20_000, ..Default::default() }.generate();
        let heap = MinimizerIndex::build(g, K, W, READ_LEN);
        let dir = std::env::temp_dir();
        let pid = std::process::id();
        let p1 = dir.join(format!("dartpim-sniff1-{pid}.idx"));
        let p2 = dir.join(format!("dartpim-sniff2-{pid}.idx"));
        let pg = dir.join(format!("dartpim-sniffg-{pid}.idx"));
        crate::index::save_index(&p1, &heap).unwrap();
        save_index_v2(&p2, &heap, 4).unwrap();
        std::fs::write(&pg, b"not an index at all").unwrap();
        assert_eq!(sniff_format(&p1).unwrap(), IndexFormat::V1);
        assert_eq!(sniff_format(&p2).unwrap(), IndexFormat::V2);
        assert!(sniff_format(&pg).unwrap_err().to_string().contains("magic"));
        let short = dir.join(format!("dartpim-sniffs-{pid}.idx"));
        std::fs::write(&short, b"DAR").unwrap();
        assert!(sniff_format(&short).unwrap_err().to_string().contains("truncated"));
        for p in [p1, p2, pg, short] {
            std::fs::remove_file(&p).ok();
        }
    }
}
