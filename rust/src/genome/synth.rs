//! Synthetic reference genomes and simulated short-read sets.
//!
//! Stand-in for GRCh38 + the HG002 Illumina runs (DESIGN.md §6): a random
//! backbone with planted repeat families (so the minimizer frequency
//! distribution is skewed, exercising the paper's lowTh / maxReads
//! mechanics) and an Illumina-like read simulator (substitutions ≫
//! indels) over a SNP-diverged donor genome. All generation is seeded and
//! reproducible.

use crate::util::SmallRng;

use super::encode::Seq;

/// Reference genome synthesis parameters.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    /// Total reference length in bases.
    pub len: usize,
    /// GC content in [0, 1] (human ≈ 0.41).
    pub gc: f64,
    /// Fraction of the genome covered by planted repeat copies (human ≈
    /// 0.5; drives minimizer multiplicity).
    pub repeat_fraction: f64,
    /// Length of each repeat unit.
    pub repeat_unit_len: usize,
    /// Number of distinct repeat families.
    pub repeat_families: usize,
    /// Per-base divergence between repeat copies (so copies are near- but
    /// not exact duplicates, like real repeat families).
    pub repeat_divergence: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig {
            len: 1 << 20, // 1 Mbp
            gc: 0.41,
            repeat_fraction: 0.30,
            repeat_unit_len: 300,
            repeat_families: 32,
            // human repeat families are diverged enough that most copies
            // fail an eth=6 banded filter on 150 bp windows (paper's
            // measured pass rate is ~6 %); 5 %/base gives that behaviour
            repeat_divergence: 0.05,
            seed: 0xDA27_0001,
        }
    }
}

impl SynthConfig {
    /// Generate the reference genome.
    pub fn generate(&self) -> Seq {
        assert!(self.len >= self.repeat_unit_len.max(64), "genome too short");
        let mut rng = SmallRng::seed_from_u64(self.seed);
        let mut genome = random_seq(&mut rng, self.len, self.gc);

        // Plant repeat families: each family is a unit copied to random
        // locations with small per-copy divergence.
        if self.repeat_fraction > 0.0 && self.repeat_families > 0 {
            let target = (self.len as f64 * self.repeat_fraction) as usize;
            let copies_total = target / self.repeat_unit_len.max(1);
            let per_family = (copies_total / self.repeat_families).max(1);
            for _ in 0..self.repeat_families {
                let unit = random_seq(&mut rng, self.repeat_unit_len, self.gc);
                for _ in 0..per_family {
                    let pos = rng.gen_range(0..self.len - self.repeat_unit_len);
                    for (i, &b) in unit.iter().enumerate() {
                        genome[pos + i] = if rng.gen_bool(self.repeat_divergence) {
                            mutate_base(&mut rng, b)
                        } else {
                            b
                        };
                    }
                }
            }
        }
        genome
    }
}

fn random_seq(rng: &mut SmallRng, len: usize, gc: f64) -> Seq {
    (0..len)
        .map(|_| {
            if rng.gen_bool(gc) {
                if rng.gen_bool(0.5) { super::encode::BASE_G } else { super::encode::BASE_C }
            } else if rng.gen_bool(0.5) {
                super::encode::BASE_A
            } else {
                super::encode::BASE_T
            }
        })
        .collect()
}

/// Replace a base with a uniformly random *different* base.
pub(crate) fn mutate_base(rng: &mut SmallRng, b: u8) -> u8 {
    debug_assert!(b < 4);
    (b + rng.gen_range(1..4u8)) % 4
}

/// One simulated read with its ground-truth origin.
#[derive(Debug, Clone)]
pub struct ReadRecord {
    /// Read id (dense, 0-based).
    pub id: u32,
    /// Base codes, length = read_len.
    pub seq: Seq,
    /// True 0-based position of the read's first base on the *reference*
    /// coordinate system.
    pub truth_pos: u32,
    /// Number of sequencing errors injected (subs + indels).
    pub errors: u32,
}

/// Read simulator parameters (Illumina-like error profile).
#[derive(Debug, Clone)]
pub struct ReadSimConfig {
    /// Number of reads to simulate.
    pub n_reads: usize,
    /// Read length in bases.
    pub read_len: usize,
    /// Per-base substitution rate (Illumina ≈ 1e-3; we default higher to
    /// exercise the filter at small scale).
    pub sub_rate: f64,
    /// Per-read insertion probability (rare for Illumina).
    pub ins_rate: f64,
    /// Per-read deletion probability (rare for Illumina).
    pub del_rate: f64,
    /// RNG seed (deterministic read set for a given config).
    pub seed: u64,
}

impl Default for ReadSimConfig {
    fn default() -> Self {
        ReadSimConfig {
            n_reads: 1000,
            read_len: crate::params::READ_LEN,
            sub_rate: 0.004,
            ins_rate: 0.02,
            del_rate: 0.02,
            seed: 0xDA27_0002,
        }
    }
}

impl ReadSimConfig {
    /// Sample reads from `donor`, reporting positions in reference
    /// coordinates via `donor_to_ref` (identity when sampling straight
    /// from the reference).
    pub fn simulate(&self, donor: &[u8], donor_to_ref: impl Fn(usize) -> u32) -> Vec<ReadRecord> {
        assert!(donor.len() > self.read_len + 8, "donor shorter than a read");
        let mut rng = SmallRng::seed_from_u64(self.seed);
        let mut out = Vec::with_capacity(self.n_reads);
        for id in 0..self.n_reads {
            // Sample a slightly longer template so indels keep length.
            let max_start = donor.len() - self.read_len - 8;
            let start = rng.gen_range(0..max_start);
            let template = &donor[start..start + self.read_len + 8];
            let (seq, errors) = sequencing_errors(
                &mut rng,
                template,
                self.read_len,
                self.sub_rate,
                self.ins_rate,
                self.del_rate,
            );
            out.push(ReadRecord {
                id: id as u32,
                seq,
                truth_pos: donor_to_ref(start),
                errors,
            });
        }
        out
    }
}

/// Apply the Illumina-like error model to one template: at most one
/// indel event per read, independent per-base substitutions. Shared by
/// the single-end and paired simulators so both mates of a pair carry
/// exactly the same error profile.
pub(crate) fn sequencing_errors(
    rng: &mut SmallRng,
    template: &[u8],
    read_len: usize,
    sub_rate: f64,
    ins_rate: f64,
    del_rate: f64,
) -> (Seq, u32) {
    let mut errors = 0u32;
    let mut seq = Vec::with_capacity(read_len);
    let mut t = 0usize; // template cursor
    // At most one indel event per read (Illumina-like).
    let ins_at = if rng.gen_bool(ins_rate) {
        errors += 1;
        Some(rng.gen_range(1..read_len - 1))
    } else {
        None
    };
    let del_at = if ins_at.is_none() && rng.gen_bool(del_rate) {
        errors += 1;
        Some(rng.gen_range(1..read_len - 1))
    } else {
        None
    };
    while seq.len() < read_len {
        if Some(seq.len()) == ins_at {
            seq.push(rng.gen_range(0..4u8)); // inserted base
            continue;
        }
        if Some(seq.len()) == del_at && t + 1 < template.len() {
            t += 1; // skip a template base
        }
        let mut b = template[t.min(template.len() - 1)];
        t += 1;
        if b > 3 {
            b = rng.gen_range(0..4u8);
        }
        if rng.gen_bool(sub_rate) {
            b = mutate_base(rng, b);
            errors += 1;
        }
        seq.push(b);
    }
    (seq, errors)
}

/// Paired-end read simulator: samples a fragment of
/// `insert_mean ± insert_sd` bases from the donor and reports both ends
/// in standard Illumina FR orientation — R1 is the forward strand of the
/// fragment start, R2 the reverse complement of the fragment end.
///
/// Insert sizes are drawn from an Irwin–Hall approximation of a normal
/// (the sum of 12 uniforms), which keeps generation exactly reproducible
/// across platforms (no transcendental libm calls).
#[derive(Debug, Clone)]
pub struct PairSimConfig {
    /// Number of read *pairs* to simulate (2× this many records).
    pub n_pairs: usize,
    /// Read length of each mate in bases.
    pub read_len: usize,
    /// Mean fragment (insert) length, outer distance R1-start..R2-end.
    pub insert_mean: usize,
    /// Fragment-length standard deviation.
    pub insert_sd: usize,
    /// Per-base substitution rate (per mate).
    pub sub_rate: f64,
    /// Per-mate insertion probability.
    pub ins_rate: f64,
    /// Per-mate deletion probability.
    pub del_rate: f64,
    /// RNG seed (deterministic pair set for a given config).
    pub seed: u64,
}

impl Default for PairSimConfig {
    fn default() -> Self {
        PairSimConfig {
            n_pairs: 500,
            read_len: crate::params::READ_LEN,
            insert_mean: 350,
            insert_sd: 30,
            sub_rate: 0.004,
            ins_rate: 0.02,
            del_rate: 0.02,
            seed: 0xDA27_0004,
        }
    }
}

impl PairSimConfig {
    /// Sample an insert length: `mean + (IrwinHall(12) - 6) * sd`,
    /// clamped so the fragment always holds two non-overlapping mates.
    fn sample_insert(&self, rng: &mut SmallRng, donor_len: usize) -> usize {
        let mut s = 0.0f64;
        for _ in 0..12 {
            s += rng.next_f64();
        }
        let raw = self.insert_mean as f64 + (s - 6.0) * self.insert_sd as f64;
        let lo = 2 * self.read_len;
        let hi = donor_len.saturating_sub(16).max(lo);
        (raw as i64).clamp(lo as i64, hi as i64) as usize
    }

    /// Sample pairs from `donor`, reporting each mate's leftmost base in
    /// reference coordinates via `donor_to_ref`. The result is a flat
    /// record vector with dense ids: R1 of pair `i` at id `2i`, R2 at
    /// id `2i + 1` (the layout the paired mapping pipeline consumes).
    pub fn simulate(&self, donor: &[u8], donor_to_ref: impl Fn(usize) -> u32) -> Vec<ReadRecord> {
        assert!(
            donor.len() > 2 * self.read_len + 24,
            "donor shorter than two mates plus slack"
        );
        let mut rng = SmallRng::seed_from_u64(self.seed);
        let mut out = Vec::with_capacity(2 * self.n_pairs);
        for pair in 0..self.n_pairs {
            let insert = self.sample_insert(&mut rng, donor.len());
            let max_start = donor.len() - insert - 8;
            let start = rng.gen_range(0..max_start.max(1));
            // R1: forward strand of the fragment start.
            let t1 = &donor[start..start + self.read_len + 8];
            let (seq1, err1) = sequencing_errors(
                &mut rng,
                t1,
                self.read_len,
                self.sub_rate,
                self.ins_rate,
                self.del_rate,
            );
            // R2: reverse complement of the fragment end (template is
            // the revcomp of the donor tail, so errors apply to the
            // as-sequenced orientation exactly like R1).
            let r2_start = start + insert - self.read_len;
            // revcomp so template[0] is the fragment's last base (R2's
            // first sequenced base); the 8-base indel slack extends past
            // the read's tail, i.e. below r2_start in donor coordinates
            let t2: Seq =
                super::encode::revcomp(&donor[r2_start.saturating_sub(8)..start + insert]);
            let (seq2, err2) = sequencing_errors(
                &mut rng,
                &t2,
                self.read_len,
                self.sub_rate,
                self.ins_rate,
                self.del_rate,
            );
            out.push(ReadRecord {
                id: 2 * pair as u32,
                seq: seq1,
                truth_pos: donor_to_ref(start),
                errors: err1,
            });
            out.push(ReadRecord {
                id: 2 * pair as u32 + 1,
                seq: seq2,
                truth_pos: donor_to_ref(r2_start),
                errors: err2,
            });
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_reproducible_and_sized() {
        let cfg = SynthConfig { len: 20_000, ..Default::default() };
        let a = cfg.generate();
        let b = cfg.generate();
        assert_eq!(a.len(), 20_000);
        assert_eq!(a, b);
        assert!(a.iter().all(|&c| c < 4));
    }

    #[test]
    fn different_seeds_differ() {
        let a = SynthConfig { len: 10_000, seed: 1, ..Default::default() }.generate();
        let b = SynthConfig { len: 10_000, seed: 2, ..Default::default() }.generate();
        assert_ne!(a, b);
    }

    #[test]
    fn gc_content_tracks_config() {
        let g = SynthConfig { len: 200_000, gc: 0.6, repeat_fraction: 0.0, ..Default::default() }
            .generate();
        let gc = g.iter().filter(|&&c| c == 1 || c == 2).count() as f64 / g.len() as f64;
        assert!((gc - 0.6).abs() < 0.01, "gc={gc}");
    }

    #[test]
    fn repeats_create_duplicate_kmers() {
        let cfg = SynthConfig {
            len: 100_000,
            repeat_fraction: 0.5,
            repeat_divergence: 0.0,
            ..Default::default()
        };
        let g = cfg.generate();
        // Count exact 32-mer duplicates via sampling.
        use std::collections::HashMap;
        let mut counts: HashMap<&[u8], u32> = HashMap::new();
        for i in (0..g.len() - 32).step_by(7) {
            *counts.entry(&g[i..i + 32]).or_default() += 1;
        }
        let dup = counts.values().filter(|&&c| c > 1).count();
        assert!(dup > 0, "expected repeated 32-mers in a repeat-rich genome");
    }

    #[test]
    fn reads_are_seeded_and_error_free_reads_match_reference() {
        let genome = SynthConfig { len: 50_000, ..Default::default() }.generate();
        let cfg = ReadSimConfig {
            n_reads: 50,
            read_len: 100,
            sub_rate: 0.0,
            ins_rate: 0.0,
            del_rate: 0.0,
            seed: 42,
        };
        let reads = cfg.simulate(&genome, |p| p as u32);
        assert_eq!(reads.len(), 50);
        for r in &reads {
            assert_eq!(r.errors, 0);
            let p = r.truth_pos as usize;
            assert_eq!(&genome[p..p + 100], &r.seq[..], "read should equal its origin");
        }
    }

    #[test]
    fn error_free_pairs_match_reference_in_fr_orientation() {
        let genome = SynthConfig { len: 50_000, ..Default::default() }.generate();
        let cfg = PairSimConfig {
            n_pairs: 40,
            read_len: 100,
            sub_rate: 0.0,
            ins_rate: 0.0,
            del_rate: 0.0,
            ..Default::default()
        };
        let reads = cfg.simulate(&genome, |p| p as u32);
        assert_eq!(reads.len(), 80);
        for pair in 0..40 {
            let r1 = &reads[2 * pair];
            let r2 = &reads[2 * pair + 1];
            assert_eq!(r1.id, 2 * pair as u32);
            assert_eq!(r2.id, 2 * pair as u32 + 1);
            assert_eq!((r1.errors, r2.errors), (0, 0));
            let p1 = r1.truth_pos as usize;
            let p2 = r2.truth_pos as usize;
            // R1 is the forward fragment start
            assert_eq!(&genome[p1..p1 + 100], &r1.seq[..]);
            // R2 is the reverse complement of the fragment end
            assert_eq!(
                crate::genome::revcomp(&genome[p2..p2 + 100]),
                r2.seq,
                "pair {pair}"
            );
            // FR orientation: R2's leftmost base sits downstream of R1,
            // and the outer distance tracks the configured insert model
            let insert = p2 + 100 - p1;
            assert!(p2 >= p1, "pair {pair}: R2 upstream of R1");
            assert!(
                (200..=530).contains(&insert),
                "pair {pair}: insert {insert} outside the sampling envelope"
            );
        }
    }

    #[test]
    fn pair_simulation_is_reproducible_and_inserts_track_mean() {
        let genome = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let cfg = PairSimConfig { n_pairs: 200, ..Default::default() };
        let a = cfg.simulate(&genome, |p| p as u32);
        let b = cfg.simulate(&genome, |p| p as u32);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.id, x.truth_pos, &x.seq), (y.id, y.truth_pos, &y.seq));
        }
        let mean: f64 = (0..200)
            .map(|i| {
                (a[2 * i + 1].truth_pos as f64 + cfg.read_len as f64) - a[2 * i].truth_pos as f64
            })
            .sum::<f64>()
            / 200.0;
        assert!((mean - 350.0).abs() < 15.0, "mean insert {mean}");
    }

    #[test]
    fn error_rates_inject_errors() {
        let genome = SynthConfig { len: 50_000, ..Default::default() }.generate();
        let cfg = ReadSimConfig {
            n_reads: 200,
            read_len: 100,
            sub_rate: 0.01,
            ins_rate: 0.1,
            del_rate: 0.1,
            seed: 43,
        };
        let reads = cfg.simulate(&genome, |p| p as u32);
        let total_errors: u32 = reads.iter().map(|r| r.errors).sum();
        assert!(total_errors > 100, "expected errors, got {total_errors}");
        for r in &reads {
            assert_eq!(r.seq.len(), 100);
        }
    }
}
