//! Minimal FASTQ reader/writer (4-line records).
//!
//! The primary ingestion path is [`FastqStream`], an incremental
//! pull-parser over any [`BufRead`]: it holds one record in memory at a
//! time, so the mapping pipeline can consume arbitrarily large read sets
//! (including stdin) in O(1) parser memory. [`read_fastq`] /
//! [`load_fastq`] survive as thin collect wrappers for callers that
//! genuinely need the whole set.
//!
//! Accepted syntax beyond the strict 4-line form: CRLF line endings, a
//! final record without a trailing newline, and blank lines *between*
//! records. Malformed input errors name the 1-based record ordinal and
//! the read name, so a bad record deep inside a multi-gigabyte stream is
//! diagnosable.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::path::Path;

use super::encode::{decode_seq, encode_seq, Seq};

/// One FASTQ record. Quality is kept verbatim (synthetic reads carry a
/// constant quality; the mapper itself is quality-agnostic, as is the
/// paper's pipeline).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FastqRecord {
    /// Header line without the leading `@`.
    pub name: String,
    /// Encoded sequence (base codes).
    pub seq: Seq,
    /// Quality string, verbatim ASCII.
    pub qual: Vec<u8>,
}

impl FastqRecord {
    /// Build a record whose every base has quality `q`.
    pub fn with_const_qual(name: String, seq: Seq, q: u8) -> Self {
        let qual = vec![q; seq.len()];
        FastqRecord { name, seq, qual }
    }
}

/// Incremental FASTQ parser: an iterator of `io::Result<FastqRecord>`
/// over any buffered reader. Memory is one record regardless of input
/// size — the ingestion half of the pipeline's bounded-memory contract.
///
/// The stream fuses after the first error (a parse failure mid-stream
/// leaves the reader at an unknown position; resynchronizing would risk
/// silently misparsing the remainder).
pub struct FastqStream<R: BufRead> {
    reader: R,
    /// Scratch for the current line (reused across records).
    line: String,
    /// Records successfully parsed so far (== 1-based ordinal of the
    /// last record returned).
    records: u64,
    /// Set once EOF or an error was returned; the iterator is fused.
    done: bool,
}

impl<R: BufRead> FastqStream<R> {
    /// Stream records from `reader`.
    pub fn new(reader: R) -> Self {
        FastqStream { reader, line: String::new(), records: 0, done: false }
    }

    /// Records successfully parsed so far.
    pub fn records_read(&self) -> u64 {
        self.records
    }

    /// Read the next line into `self.line`, stripping the trailing
    /// `\n` / `\r\n` (and a bare trailing `\r`, which only occurs when a
    /// CRLF file is cut between the two bytes). `false` at EOF.
    fn fill_line(&mut self) -> io::Result<bool> {
        self.line.clear();
        if self.reader.read_line(&mut self.line)? == 0 {
            return Ok(false);
        }
        if self.line.ends_with('\n') {
            self.line.pop();
        }
        if self.line.ends_with('\r') {
            self.line.pop();
        }
        Ok(true)
    }

    /// Parse one record; `Ok(None)` at clean end of input.
    fn parse_record(&mut self) -> io::Result<Option<FastqRecord>> {
        // skip blank lines between records
        loop {
            if !self.fill_line()? {
                return Ok(None);
            }
            if !self.line.trim().is_empty() {
                break;
            }
        }
        let ordinal = self.records + 1;
        if !self.line.starts_with('@') {
            return Err(malformed(ordinal, None, "header line does not start with '@'"));
        }
        let name = self.line[1..].split_whitespace().next().unwrap_or("").to_string();

        if !self.fill_line()? {
            return Err(truncated(ordinal, &name, "sequence line"));
        }
        let seq = encode_seq(self.line.trim_end().as_bytes());

        if !self.fill_line()? {
            return Err(truncated(ordinal, &name, "'+' separator line"));
        }
        if !self.line.starts_with('+') {
            return Err(malformed(ordinal, Some(&name), "separator line does not start with '+'"));
        }

        if !self.fill_line()? {
            return Err(truncated(ordinal, &name, "quality line"));
        }
        let qual = self.line.trim_end().as_bytes().to_vec();
        if seq.len() != qual.len() {
            return Err(malformed(
                ordinal,
                Some(&name),
                &format!(
                    "sequence length {} does not match quality length {}",
                    seq.len(),
                    qual.len()
                ),
            ));
        }

        self.records = ordinal;
        Ok(Some(FastqRecord { name, seq, qual }))
    }
}

impl<R: BufRead> Iterator for FastqStream<R> {
    type Item = io::Result<FastqRecord>;

    fn next(&mut self) -> Option<io::Result<FastqRecord>> {
        if self.done {
            return None;
        }
        match self.parse_record() {
            Ok(Some(rec)) => Some(Ok(rec)),
            Ok(None) => {
                self.done = true;
                None
            }
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
        }
    }
}

fn malformed(ordinal: u64, name: Option<&str>, what: &str) -> io::Error {
    let who = match name {
        Some(n) if !n.is_empty() => format!("FASTQ record #{ordinal} (read {n:?})"),
        _ => format!("FASTQ record #{ordinal}"),
    };
    io::Error::new(io::ErrorKind::InvalidData, format!("{who}: {what}"))
}

fn truncated(ordinal: u64, name: &str, missing: &str) -> io::Error {
    let who = if name.is_empty() {
        format!("FASTQ record #{ordinal}")
    } else {
        format!("FASTQ record #{ordinal} (read {name:?})")
    };
    io::Error::new(io::ErrorKind::UnexpectedEof, format!("truncated {who}: missing {missing}"))
}

/// Parse FASTQ from any reader into a vector (thin wrapper over
/// [`FastqStream`]; prefer the stream for large inputs).
pub fn read_fastq<R: Read>(r: R) -> io::Result<Vec<FastqRecord>> {
    FastqStream::new(BufReader::new(r)).collect()
}

/// Load a FASTQ file (collecting wrapper; prefer [`FastqStream`] for
/// large inputs).
pub fn load_fastq<P: AsRef<Path>>(path: P) -> io::Result<Vec<FastqRecord>> {
    read_fastq(std::fs::File::open(path)?)
}

/// Write FASTQ records.
pub fn write_fastq<W: Write>(w: &mut W, records: &[FastqRecord]) -> io::Result<()> {
    for rec in records {
        writeln!(w, "@{}", rec.name)?;
        writeln!(w, "{}", decode_seq(&rec.seq))?;
        writeln!(w, "+")?;
        w.write_all(&rec.qual)?;
        writeln!(w)?;
    }
    Ok(())
}

/// Save FASTQ records to a file.
pub fn save_fastq<P: AsRef<Path>>(path: P, records: &[FastqRecord]) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_fastq(&mut f, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let recs = vec![
            FastqRecord::with_const_qual("r0".into(), encode_seq(b"ACGT"), b'I'),
            FastqRecord::with_const_qual("r1".into(), encode_seq(b"TTGCA"), b'I'),
        ];
        let mut buf = Vec::new();
        write_fastq(&mut buf, &recs).unwrap();
        assert_eq!(read_fastq(&buf[..]).unwrap(), recs);
    }

    #[test]
    fn streaming_yields_records_one_at_a_time() {
        let input = b"@a\nACGT\n+\nIIII\n\n@b x y\nTT\n+\nII\n";
        let mut s = FastqStream::new(&input[..]);
        let a = s.next().unwrap().unwrap();
        assert_eq!(a.name, "a");
        assert_eq!(s.records_read(), 1);
        let b = s.next().unwrap().unwrap();
        assert_eq!(b.name, "b", "name stops at the first whitespace");
        assert_eq!(b.seq, encode_seq(b"TT"));
        assert!(s.next().is_none());
        assert!(s.next().is_none(), "stream is fused");
        assert_eq!(s.records_read(), 2);
    }

    #[test]
    fn accepts_crlf_line_endings() {
        let unix = b"@r\nACGT\n+\nIIII\n";
        let dos = b"@r\r\nACGT\r\n+\r\nIIII\r\n";
        assert_eq!(read_fastq(&unix[..]).unwrap(), read_fastq(&dos[..]).unwrap());
        let rec = &read_fastq(&dos[..]).unwrap()[0];
        assert_eq!(rec.seq, encode_seq(b"ACGT"));
        assert_eq!(rec.qual, b"IIII");
    }

    #[test]
    fn accepts_final_record_without_trailing_newline() {
        let recs = read_fastq(&b"@r0\nACGT\n+\nIIII\n@r1\nTTAA\n+\nJJJJ"[..]).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].qual, b"JJJJ");
    }

    #[test]
    fn rejects_length_mismatch_naming_the_record() {
        let err = read_fastq(&b"@ok\nAC\n+\nII\n@bad\nACGT\n+\nII\n"[..]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("#2"), "must name the ordinal: {msg}");
        assert!(msg.contains("bad"), "must name the read: {msg}");
        assert!(msg.contains('4') && msg.contains('2'), "must name both lengths: {msg}");
    }

    #[test]
    fn rejects_truncation_naming_the_record() {
        for (input, missing) in [
            (&b"@r\nACGT\n"[..], "separator"),
            (&b"@r\n"[..], "sequence"),
            (&b"@r\nACGT\n+\n"[..], "quality"),
        ] {
            let err = read_fastq(input).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
            let msg = err.to_string();
            assert!(msg.contains("#1") && msg.contains('r'), "{msg}");
            assert!(msg.contains(missing), "{msg} should mention {missing}");
        }
    }

    #[test]
    fn rejects_bad_markers() {
        assert!(read_fastq(&b"r\nACGT\n+\nIIII\n"[..]).is_err());
        assert!(read_fastq(&b"@r\nACGT\nx\nIIII\n"[..]).is_err());
    }

    #[test]
    fn stream_fuses_after_error() {
        let mut s = FastqStream::new(&b"@r\nACGT\n+\nII\n@next\nAC\n+\nII\n"[..]);
        assert!(s.next().unwrap().is_err());
        assert!(s.next().is_none(), "no resynchronization after a parse error");
    }
}
