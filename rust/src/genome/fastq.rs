//! Minimal FASTQ reader/writer (4-line records).

use std::io::{self, BufRead, BufReader, Read, Write};
use std::path::Path;

use super::encode::{decode_seq, encode_seq, Seq};

/// One FASTQ record. Quality is kept verbatim (synthetic reads carry a
/// constant quality; the mapper itself is quality-agnostic, as is the
/// paper's pipeline).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FastqRecord {
    /// Header line without the leading `@`.
    pub name: String,
    /// Encoded sequence (base codes).
    pub seq: Seq,
    /// Quality string, verbatim ASCII.
    pub qual: Vec<u8>,
}

impl FastqRecord {
    /// Build a record whose every base has quality `q`.
    pub fn with_const_qual(name: String, seq: Seq, q: u8) -> Self {
        let qual = vec![q; seq.len()];
        FastqRecord { name, seq, qual }
    }
}

/// Parse FASTQ from any reader.
pub fn read_fastq<R: Read>(r: R) -> io::Result<Vec<FastqRecord>> {
    let mut lines = BufReader::new(r).lines();
    let mut out = Vec::new();
    loop {
        let header = match lines.next() {
            None => break,
            Some(l) => l?,
        };
        if header.trim().is_empty() {
            continue;
        }
        let seq = lines.next().ok_or_else(|| truncated())??;
        let plus = lines.next().ok_or_else(|| truncated())??;
        let qual = lines.next().ok_or_else(|| truncated())??;
        if !header.starts_with('@') || !plus.starts_with('+') {
            return Err(io::Error::new(io::ErrorKind::InvalidData, "malformed FASTQ record"));
        }
        if seq.len() != qual.len() {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                "FASTQ sequence/quality length mismatch",
            ));
        }
        out.push(FastqRecord {
            name: header[1..].split_whitespace().next().unwrap_or("").to_string(),
            seq: encode_seq(seq.trim_end().as_bytes()),
            qual: qual.trim_end().as_bytes().to_vec(),
        });
    }
    Ok(out)
}

fn truncated() -> io::Error {
    io::Error::new(io::ErrorKind::UnexpectedEof, "truncated FASTQ record")
}

/// Load a FASTQ file.
pub fn load_fastq<P: AsRef<Path>>(path: P) -> io::Result<Vec<FastqRecord>> {
    read_fastq(std::fs::File::open(path)?)
}

/// Write FASTQ records.
pub fn write_fastq<W: Write>(w: &mut W, records: &[FastqRecord]) -> io::Result<()> {
    for rec in records {
        writeln!(w, "@{}", rec.name)?;
        writeln!(w, "{}", decode_seq(&rec.seq))?;
        writeln!(w, "+")?;
        w.write_all(&rec.qual)?;
        writeln!(w)?;
    }
    Ok(())
}

/// Save FASTQ records to a file.
pub fn save_fastq<P: AsRef<Path>>(path: P, records: &[FastqRecord]) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_fastq(&mut f, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let recs = vec![
            FastqRecord::with_const_qual("r0".into(), encode_seq(b"ACGT"), b'I'),
            FastqRecord::with_const_qual("r1".into(), encode_seq(b"TTGCA"), b'I'),
        ];
        let mut buf = Vec::new();
        write_fastq(&mut buf, &recs).unwrap();
        assert_eq!(read_fastq(&buf[..]).unwrap(), recs);
    }

    #[test]
    fn rejects_length_mismatch() {
        assert!(read_fastq(&b"@r\nACGT\n+\nII\n"[..]).is_err());
    }

    #[test]
    fn rejects_truncation() {
        assert!(read_fastq(&b"@r\nACGT\n"[..]).is_err());
    }

    #[test]
    fn rejects_bad_markers() {
        assert!(read_fastq(&b"r\nACGT\n+\nIIII\n"[..]).is_err());
        assert!(read_fastq(&b"@r\nACGT\nx\nIIII\n"[..]).is_err());
    }
}
