//! Minimal FASTQ reader/writer (4-line records).
//!
//! The primary ingestion path is [`FastqStream`], an incremental
//! pull-parser over any [`BufRead`]: it holds one record in memory at a
//! time, so the mapping pipeline can consume arbitrarily large read sets
//! (including stdin) in O(1) parser memory. [`read_fastq`] /
//! [`load_fastq`] survive as thin collect wrappers for callers that
//! genuinely need the whole set.
//!
//! Accepted syntax beyond the strict 4-line form: CRLF line endings, a
//! final record without a trailing newline, and blank lines *between*
//! records. Malformed input errors name the 1-based record ordinal and
//! the read name, so a bad record deep inside a multi-gigabyte stream is
//! diagnosable.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::path::Path;

use super::encode::{decode_seq, encode_seq, Seq};

/// One FASTQ record. Quality is kept verbatim (synthetic reads carry a
/// constant quality; the mapper itself is quality-agnostic, as is the
/// paper's pipeline).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FastqRecord {
    /// Header line without the leading `@`.
    pub name: String,
    /// Encoded sequence (base codes).
    pub seq: Seq,
    /// Quality string, verbatim ASCII.
    pub qual: Vec<u8>,
}

impl FastqRecord {
    /// Build a record whose every base has quality `q`.
    pub fn with_const_qual(name: String, seq: Seq, q: u8) -> Self {
        let qual = vec![q; seq.len()];
        FastqRecord { name, seq, qual }
    }
}

/// Incremental FASTQ parser: an iterator of `io::Result<FastqRecord>`
/// over any buffered reader. Memory is one record regardless of input
/// size — the ingestion half of the pipeline's bounded-memory contract.
///
/// The stream fuses after the first error (a parse failure mid-stream
/// leaves the reader at an unknown position; resynchronizing would risk
/// silently misparsing the remainder).
pub struct FastqStream<R: BufRead> {
    reader: R,
    /// Scratch for the current line (reused across records).
    line: String,
    /// Records successfully parsed so far (== 1-based ordinal of the
    /// last record returned).
    records: u64,
    /// Set once EOF or an error was returned; the iterator is fused.
    done: bool,
}

impl<R: BufRead> FastqStream<R> {
    /// Stream records from `reader`.
    pub fn new(reader: R) -> Self {
        FastqStream { reader, line: String::new(), records: 0, done: false }
    }

    /// Records successfully parsed so far.
    pub fn records_read(&self) -> u64 {
        self.records
    }

    /// Read the next line into `self.line`, stripping the trailing
    /// `\n` / `\r\n` (and a bare trailing `\r`, which only occurs when a
    /// CRLF file is cut between the two bytes). `false` at EOF.
    fn fill_line(&mut self) -> io::Result<bool> {
        self.line.clear();
        if self.reader.read_line(&mut self.line)? == 0 {
            return Ok(false);
        }
        if self.line.ends_with('\n') {
            self.line.pop();
        }
        if self.line.ends_with('\r') {
            self.line.pop();
        }
        Ok(true)
    }

    /// Parse one record; `Ok(None)` at clean end of input.
    fn parse_record(&mut self) -> io::Result<Option<FastqRecord>> {
        // skip blank lines between records
        loop {
            if !self.fill_line()? {
                return Ok(None);
            }
            if !self.line.trim().is_empty() {
                break;
            }
        }
        let ordinal = self.records + 1;
        if !self.line.starts_with('@') {
            return Err(malformed(ordinal, None, "header line does not start with '@'"));
        }
        let name = self.line[1..].split_whitespace().next().unwrap_or("").to_string();

        if !self.fill_line()? {
            return Err(truncated(ordinal, &name, "sequence line"));
        }
        let seq = encode_seq(self.line.trim_end().as_bytes());

        if !self.fill_line()? {
            return Err(truncated(ordinal, &name, "'+' separator line"));
        }
        if !self.line.starts_with('+') {
            return Err(malformed(ordinal, Some(&name), "separator line does not start with '+'"));
        }

        if !self.fill_line()? {
            return Err(truncated(ordinal, &name, "quality line"));
        }
        let qual = self.line.trim_end().as_bytes().to_vec();
        if seq.len() != qual.len() {
            return Err(malformed(
                ordinal,
                Some(&name),
                &format!(
                    "sequence length {} does not match quality length {}",
                    seq.len(),
                    qual.len()
                ),
            ));
        }

        self.records = ordinal;
        Ok(Some(FastqRecord { name, seq, qual }))
    }
}

impl<R: BufRead> Iterator for FastqStream<R> {
    type Item = io::Result<FastqRecord>;

    fn next(&mut self) -> Option<io::Result<FastqRecord>> {
        if self.done {
            return None;
        }
        match self.parse_record() {
            Ok(Some(rec)) => Some(Ok(rec)),
            Ok(None) => {
                self.done = true;
                None
            }
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
        }
    }
}

fn malformed(ordinal: u64, name: Option<&str>, what: &str) -> io::Error {
    let who = match name {
        Some(n) if !n.is_empty() => format!("FASTQ record #{ordinal} (read {n:?})"),
        _ => format!("FASTQ record #{ordinal}"),
    };
    io::Error::new(io::ErrorKind::InvalidData, format!("{who}: {what}"))
}

fn truncated(ordinal: u64, name: &str, missing: &str) -> io::Error {
    let who = if name.is_empty() {
        format!("FASTQ record #{ordinal}")
    } else {
        format!("FASTQ record #{ordinal} (read {name:?})")
    };
    io::Error::new(io::ErrorKind::UnexpectedEof, format!("truncated {who}: missing {missing}"))
}

/// The mate-agnostic base name of a read: `frag/1` and `frag/2` (the
/// conventional paired-end suffixes) both canonicalize to `frag`; names
/// without a mate suffix are returned unchanged.
pub fn mate_base_name(name: &str) -> &str {
    name.strip_suffix("/1").or_else(|| name.strip_suffix("/2")).unwrap_or(name)
}

/// How a paired stream sources its records.
enum PairSource<R1: BufRead, R2: BufRead> {
    /// Two parallel files (R1, R2), zipped record by record.
    TwoFiles(FastqStream<R1>, FastqStream<R2>),
    /// One interleaved stream: records alternate R1, R2, R1, R2, …
    Interleaved(FastqStream<R1>),
}

/// Incremental paired-FASTQ parser: an iterator of
/// `io::Result<(FastqRecord, FastqRecord)>` yielding one (R1, R2) pair
/// at a time, from either two parallel files or one interleaved stream.
/// Memory is O(1) in the stream length, like [`FastqStream`].
///
/// Structural errors are positioned: a stream that ends with an
/// unmatched mate, or a pair whose mate names disagree (after stripping
/// the conventional `/1` / `/2` suffixes, see [`mate_base_name`]),
/// errors with the 1-based pair ordinal and the read name(s) involved.
/// Like the underlying parser, the stream fuses after the first error.
pub struct PairedFastqStream<R1: BufRead, R2: BufRead> {
    src: PairSource<R1, R2>,
    /// Pairs successfully yielded so far.
    pairs: u64,
    done: bool,
}

impl<R: BufRead> PairedFastqStream<R, R> {
    /// Pair up one interleaved stream (R1, R2 records alternating).
    pub fn interleaved(reader: R) -> Self {
        PairedFastqStream {
            src: PairSource::Interleaved(FastqStream::new(reader)),
            pairs: 0,
            done: false,
        }
    }
}

impl<R1: BufRead, R2: BufRead> PairedFastqStream<R1, R2> {
    /// Zip two parallel files (`reads_1.fastq`, `reads_2.fastq`).
    pub fn two_files(r1: R1, r2: R2) -> Self {
        PairedFastqStream {
            src: PairSource::TwoFiles(FastqStream::new(r1), FastqStream::new(r2)),
            pairs: 0,
            done: false,
        }
    }

    /// Pairs successfully yielded so far.
    pub fn pairs_read(&self) -> u64 {
        self.pairs
    }

    fn next_pair(&mut self) -> io::Result<Option<(FastqRecord, FastqRecord)>> {
        let ordinal = self.pairs + 1;
        let (r1, r2) = match &mut self.src {
            PairSource::TwoFiles(s1, s2) => {
                let mates = (s1.next().transpose()?, s2.next().transpose()?);
                match mates {
                    (None, None) => return Ok(None),
                    (Some(r1), None) => {
                        return Err(unmatched(ordinal, &r1.name, "R2 input ended"));
                    }
                    (None, Some(r2)) => {
                        return Err(unmatched(ordinal, &r2.name, "R1 input ended"));
                    }
                    (Some(r1), Some(r2)) => (r1, r2),
                }
            }
            PairSource::Interleaved(s) => {
                let Some(r1) = s.next().transpose()? else {
                    return Ok(None);
                };
                let Some(r2) = s.next().transpose()? else {
                    return Err(unmatched(ordinal, &r1.name, "interleaved input ended mid-pair"));
                };
                (r1, r2)
            }
        };
        if mate_base_name(&r1.name) != mate_base_name(&r2.name) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "read pair #{ordinal}: mate names disagree (R1 {:?} vs R2 {:?})",
                    r1.name, r2.name
                ),
            ));
        }
        self.pairs = ordinal;
        Ok(Some((r1, r2)))
    }
}

fn unmatched(ordinal: u64, name: &str, what: &str) -> io::Error {
    io::Error::new(
        io::ErrorKind::UnexpectedEof,
        format!("read pair #{ordinal}: {what}; read {name:?} has no mate"),
    )
}

impl<R1: BufRead, R2: BufRead> Iterator for PairedFastqStream<R1, R2> {
    type Item = io::Result<(FastqRecord, FastqRecord)>;

    fn next(&mut self) -> Option<io::Result<(FastqRecord, FastqRecord)>> {
        if self.done {
            return None;
        }
        match self.next_pair() {
            Ok(Some(pair)) => Some(Ok(pair)),
            Ok(None) => {
                self.done = true;
                None
            }
            Err(e) => {
                self.done = true;
                Some(Err(e))
            }
        }
    }
}

/// Parse FASTQ from any reader into a vector (thin wrapper over
/// [`FastqStream`]; prefer the stream for large inputs).
pub fn read_fastq<R: Read>(r: R) -> io::Result<Vec<FastqRecord>> {
    FastqStream::new(BufReader::new(r)).collect()
}

/// Load a FASTQ file (collecting wrapper; prefer [`FastqStream`] for
/// large inputs).
pub fn load_fastq<P: AsRef<Path>>(path: P) -> io::Result<Vec<FastqRecord>> {
    read_fastq(std::fs::File::open(path)?)
}

/// Write FASTQ records.
pub fn write_fastq<W: Write>(w: &mut W, records: &[FastqRecord]) -> io::Result<()> {
    for rec in records {
        writeln!(w, "@{}", rec.name)?;
        writeln!(w, "{}", decode_seq(&rec.seq))?;
        writeln!(w, "+")?;
        w.write_all(&rec.qual)?;
        writeln!(w)?;
    }
    Ok(())
}

/// Save FASTQ records to a file.
pub fn save_fastq<P: AsRef<Path>>(path: P, records: &[FastqRecord]) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_fastq(&mut f, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let recs = vec![
            FastqRecord::with_const_qual("r0".into(), encode_seq(b"ACGT"), b'I'),
            FastqRecord::with_const_qual("r1".into(), encode_seq(b"TTGCA"), b'I'),
        ];
        let mut buf = Vec::new();
        write_fastq(&mut buf, &recs).unwrap();
        assert_eq!(read_fastq(&buf[..]).unwrap(), recs);
    }

    #[test]
    fn streaming_yields_records_one_at_a_time() {
        let input = b"@a\nACGT\n+\nIIII\n\n@b x y\nTT\n+\nII\n";
        let mut s = FastqStream::new(&input[..]);
        let a = s.next().unwrap().unwrap();
        assert_eq!(a.name, "a");
        assert_eq!(s.records_read(), 1);
        let b = s.next().unwrap().unwrap();
        assert_eq!(b.name, "b", "name stops at the first whitespace");
        assert_eq!(b.seq, encode_seq(b"TT"));
        assert!(s.next().is_none());
        assert!(s.next().is_none(), "stream is fused");
        assert_eq!(s.records_read(), 2);
    }

    #[test]
    fn accepts_crlf_line_endings() {
        let unix = b"@r\nACGT\n+\nIIII\n";
        let dos = b"@r\r\nACGT\r\n+\r\nIIII\r\n";
        assert_eq!(read_fastq(&unix[..]).unwrap(), read_fastq(&dos[..]).unwrap());
        let rec = &read_fastq(&dos[..]).unwrap()[0];
        assert_eq!(rec.seq, encode_seq(b"ACGT"));
        assert_eq!(rec.qual, b"IIII");
    }

    #[test]
    fn accepts_final_record_without_trailing_newline() {
        let recs = read_fastq(&b"@r0\nACGT\n+\nIIII\n@r1\nTTAA\n+\nJJJJ"[..]).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1].qual, b"JJJJ");
    }

    #[test]
    fn rejects_length_mismatch_naming_the_record() {
        let err = read_fastq(&b"@ok\nAC\n+\nII\n@bad\nACGT\n+\nII\n"[..]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("#2"), "must name the ordinal: {msg}");
        assert!(msg.contains("bad"), "must name the read: {msg}");
        assert!(msg.contains('4') && msg.contains('2'), "must name both lengths: {msg}");
    }

    #[test]
    fn rejects_truncation_naming_the_record() {
        for (input, missing) in [
            (&b"@r\nACGT\n"[..], "separator"),
            (&b"@r\n"[..], "sequence"),
            (&b"@r\nACGT\n+\n"[..], "quality"),
        ] {
            let err = read_fastq(input).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
            let msg = err.to_string();
            assert!(msg.contains("#1") && msg.contains('r'), "{msg}");
            assert!(msg.contains(missing), "{msg} should mention {missing}");
        }
    }

    #[test]
    fn rejects_bad_markers() {
        assert!(read_fastq(&b"r\nACGT\n+\nIIII\n"[..]).is_err());
        assert!(read_fastq(&b"@r\nACGT\nx\nIIII\n"[..]).is_err());
    }

    #[test]
    fn stream_fuses_after_error() {
        let mut s = FastqStream::new(&b"@r\nACGT\n+\nII\n@next\nAC\n+\nII\n"[..]);
        assert!(s.next().unwrap().is_err());
        assert!(s.next().is_none(), "no resynchronization after a parse error");
    }

    #[test]
    fn mate_base_name_strips_conventional_suffixes() {
        assert_eq!(mate_base_name("frag7/1"), "frag7");
        assert_eq!(mate_base_name("frag7/2"), "frag7");
        assert_eq!(mate_base_name("frag7"), "frag7");
        assert_eq!(mate_base_name("frag/3"), "frag/3", "only /1 and /2 are mate suffixes");
    }

    #[test]
    fn paired_two_files_zips_records() {
        let r1 = b"@a/1\nACGT\n+\nIIII\n@b/1\nTTTT\n+\nIIII\n";
        let r2 = b"@a/2\nCCCC\n+\nIIII\n@b/2\nGGGG\n+\nIIII\n";
        let mut s = PairedFastqStream::two_files(&r1[..], &r2[..]);
        let (a1, a2) = s.next().unwrap().unwrap();
        assert_eq!((a1.name.as_str(), a2.name.as_str()), ("a/1", "a/2"));
        assert_eq!(s.pairs_read(), 1);
        let (b1, b2) = s.next().unwrap().unwrap();
        assert_eq!(b1.seq, encode_seq(b"TTTT"));
        assert_eq!(b2.seq, encode_seq(b"GGGG"));
        assert!(s.next().is_none());
        assert!(s.next().is_none(), "paired stream is fused");
        assert_eq!(s.pairs_read(), 2);
    }

    #[test]
    fn paired_interleaved_takes_records_two_at_a_time() {
        let il = b"@a/1\nAC\n+\nII\n@a/2\nGT\n+\nII\n@b/1\nTT\n+\nII\n@b/2\nAA\n+\nII\n";
        let pairs: Vec<_> =
            PairedFastqStream::interleaved(&il[..]).collect::<io::Result<_>>().unwrap();
        assert_eq!(pairs.len(), 2);
        assert_eq!(pairs[1].0.name, "b/1");
        assert_eq!(pairs[1].1.name, "b/2");
    }

    #[test]
    fn unmatched_mate_errors_name_the_pair_and_read() {
        // R2 file one record short
        let r1 = b"@a/1\nAC\n+\nII\n@b/1\nGT\n+\nII\n";
        let r2 = b"@a/2\nCC\n+\nII\n";
        let mut s = PairedFastqStream::two_files(&r1[..], &r2[..]);
        assert!(s.next().unwrap().is_ok());
        let err = s.next().unwrap().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("#2") && msg.contains("b/1"), "{msg}");
        assert!(msg.contains("R2"), "{msg}");
        assert!(s.next().is_none(), "fused after the structural error");

        // R1 file one record short: symmetric
        let mut s = PairedFastqStream::two_files(&r2[..], &r1[..]);
        assert!(s.next().unwrap().is_ok());
        let msg = s.next().unwrap().unwrap_err().to_string();
        assert!(msg.contains("#2") && msg.contains("b/1") && msg.contains("R1"), "{msg}");

        // interleaved stream ends mid-pair
        let il = b"@a/1\nAC\n+\nII\n@a/2\nGT\n+\nII\n@c/1\nTT\n+\nII\n";
        let mut s = PairedFastqStream::interleaved(&il[..]);
        assert!(s.next().unwrap().is_ok());
        let msg = s.next().unwrap().unwrap_err().to_string();
        assert!(msg.contains("#2") && msg.contains("c/1") && msg.contains("mid-pair"), "{msg}");
    }

    #[test]
    fn mate_name_mismatch_errors_name_both_reads() {
        let r1 = b"@a/1\nAC\n+\nII\n";
        let r2 = b"@z/2\nCC\n+\nII\n";
        let mut s = PairedFastqStream::two_files(&r1[..], &r2[..]);
        let msg = s.next().unwrap().unwrap_err().to_string();
        assert!(msg.contains("#1") && msg.contains("a/1") && msg.contains("z/2"), "{msg}");
    }

    #[test]
    fn paired_stream_propagates_parse_errors() {
        // a malformed record inside R2 surfaces the underlying parser's
        // positioned error, not a bogus pairing error
        let r1 = b"@a/1\nAC\n+\nII\n";
        let r2 = b"@a/2\nACGT\n+\nII\n";
        let mut s = PairedFastqStream::two_files(&r1[..], &r2[..]);
        let msg = s.next().unwrap().unwrap_err().to_string();
        assert!(msg.contains("a/2") && msg.contains('4') && msg.contains('2'), "{msg}");
    }
}
