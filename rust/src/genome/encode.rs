//! 2-bit DNA base encoding.
//!
//! Bases are stored one code per byte (`0..=3` = A, C, G, T; `4` = N /
//! padding). The PIM cost model accounts for the paper's physical 2-bit
//! packing; in-host we trade 4x memory for simple indexing.

/// Base code for A.
pub const BASE_A: u8 = 0;
/// Base code for C.
pub const BASE_C: u8 = 1;
/// Base code for G.
pub const BASE_G: u8 = 2;
/// Base code for T.
pub const BASE_T: u8 = 3;
/// Unknown / padding (never matches anything, including itself, in WF).
pub const BASE_N: u8 = 4;

/// A DNA sequence as base codes.
pub type Seq = Vec<u8>;

/// Encode one ASCII base character (case-insensitive); unknown -> N.
#[inline]
pub fn encode_base(c: u8) -> u8 {
    match c {
        b'A' | b'a' => BASE_A,
        b'C' | b'c' => BASE_C,
        b'G' | b'g' => BASE_G,
        b'T' | b't' => BASE_T,
        _ => BASE_N,
    }
}

/// Decode one base code to ASCII.
#[inline]
pub fn decode_base(code: u8) -> u8 {
    match code {
        BASE_A => b'A',
        BASE_C => b'C',
        BASE_G => b'G',
        BASE_T => b'T',
        _ => b'N',
    }
}

/// Encode an ASCII string to base codes.
pub fn encode_seq(s: &[u8]) -> Seq {
    s.iter().map(|&c| encode_base(c)).collect()
}

/// Decode base codes to an ASCII string.
pub fn decode_seq(seq: &[u8]) -> String {
    seq.iter().map(|&c| decode_base(c) as char).collect()
}

/// Complement of one base code (N maps to N).
#[inline]
pub fn complement(code: u8) -> u8 {
    match code {
        BASE_A => BASE_T,
        BASE_C => BASE_G,
        BASE_G => BASE_C,
        BASE_T => BASE_A,
        other => other,
    }
}

/// Reverse complement.
pub fn revcomp(seq: &[u8]) -> Seq {
    seq.iter().rev().map(|&c| complement(c)).collect()
}

/// Pack up to 32 base codes into a `u64`, 2 bits each, first base in the
/// high bits (lexicographic order preserved). Panics on N.
pub fn pack_2bit(seq: &[u8]) -> u64 {
    assert!(seq.len() <= 32, "pack_2bit supports up to 32 bases");
    let mut v: u64 = 0;
    for &c in seq {
        assert!(c < 4, "cannot 2-bit-pack an N base");
        v = (v << 2) | c as u64;
    }
    v
}

/// Inverse of [`pack_2bit`] for a known length.
pub fn unpack_2bit(mut v: u64, len: usize) -> Seq {
    let mut out = vec![0u8; len];
    for i in (0..len).rev() {
        out[i] = (v & 3) as u8;
        v >>= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = b"ACGTacgtNX";
        let codes = encode_seq(s);
        assert_eq!(codes, vec![0, 1, 2, 3, 0, 1, 2, 3, 4, 4]);
        assert_eq!(decode_seq(&codes), "ACGTACGTNN");
    }

    #[test]
    fn revcomp_involution() {
        let s = encode_seq(b"ACGTTGCA");
        assert_eq!(revcomp(&revcomp(&s)), s);
    }

    #[test]
    fn revcomp_known() {
        assert_eq!(decode_seq(&revcomp(&encode_seq(b"AACGT"))), "ACGTT");
    }

    #[test]
    fn complement_n_preserved() {
        assert_eq!(complement(BASE_N), BASE_N);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let s = encode_seq(b"ACGTTTGACGGA");
        assert_eq!(unpack_2bit(pack_2bit(&s), s.len()), s);
    }

    #[test]
    fn pack_is_lexicographic() {
        // AA.. < AC.. < TT for equal lengths
        let a = pack_2bit(&encode_seq(b"AAC"));
        let b = pack_2bit(&encode_seq(b"ACA"));
        let c = pack_2bit(&encode_seq(b"TTT"));
        assert!(a < b && b < c);
    }

    #[test]
    #[should_panic]
    fn pack_rejects_n() {
        pack_2bit(&[BASE_N]);
    }
}
