//! Minimal FASTA reader/writer (multi-record, wrapped or unwrapped).

use std::io::{self, BufRead, BufReader, Read, Write};
use std::path::Path;

use super::encode::{decode_seq, encode_seq, Seq};

/// One FASTA record: header (without `>`) + encoded sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FastaRecord {
    /// Header line without the leading `>`.
    pub name: String,
    /// Encoded sequence (base codes).
    pub seq: Seq,
}

/// Parse FASTA from any reader.
pub fn read_fasta<R: Read>(r: R) -> io::Result<Vec<FastaRecord>> {
    let mut records = Vec::new();
    let mut name: Option<String> = None;
    let mut seq: Vec<u8> = Vec::new();
    for line in BufReader::new(r).lines() {
        let line = line?;
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(h) = line.strip_prefix('>') {
            if let Some(n) = name.take() {
                records.push(FastaRecord { name: n, seq: encode_seq(&seq) });
                seq.clear();
            }
            name = Some(h.split_whitespace().next().unwrap_or("").to_string());
        } else {
            if name.is_none() {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    "FASTA sequence data before any '>' header",
                ));
            }
            seq.extend_from_slice(line.as_bytes());
        }
    }
    if let Some(n) = name {
        records.push(FastaRecord { name: n, seq: encode_seq(&seq) });
    }
    Ok(records)
}

/// Load a FASTA file.
pub fn load_fasta<P: AsRef<Path>>(path: P) -> io::Result<Vec<FastaRecord>> {
    read_fasta(std::fs::File::open(path)?)
}

/// Write records to FASTA, 80 columns.
pub fn write_fasta<W: Write>(w: &mut W, records: &[FastaRecord]) -> io::Result<()> {
    for rec in records {
        writeln!(w, ">{}", rec.name)?;
        let text = decode_seq(&rec.seq);
        for chunk in text.as_bytes().chunks(80) {
            w.write_all(chunk)?;
            writeln!(w)?;
        }
    }
    Ok(())
}

/// Save records to a FASTA file.
pub fn save_fasta<P: AsRef<Path>>(path: P, records: &[FastaRecord]) -> io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    write_fasta(&mut f, records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let recs = vec![
            FastaRecord { name: "chr1".into(), seq: encode_seq(b"ACGTACGTAC") },
            FastaRecord { name: "chr2".into(), seq: encode_seq(&vec![b'G'; 200]) },
        ];
        let mut buf = Vec::new();
        write_fasta(&mut buf, &recs).unwrap();
        let back = read_fasta(&buf[..]).unwrap();
        assert_eq!(back, recs);
    }

    #[test]
    fn parses_wrapped_and_headers_with_descriptions() {
        let text = b">seq1 some description\nACGT\nACGT\n\n>seq2\nTTTT\n";
        let recs = read_fasta(&text[..]).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[0].name, "seq1");
        assert_eq!(recs[0].seq.len(), 8);
        assert_eq!(recs[1].name, "seq2");
    }

    #[test]
    fn rejects_headerless_data() {
        assert!(read_fasta(&b"ACGT\n"[..]).is_err());
    }

    #[test]
    fn empty_input_is_empty() {
        assert!(read_fasta(&b""[..]).unwrap().is_empty());
    }
}
