//! Donor-genome generation: apply a SNP + small-indel profile to the
//! reference, modelling the ~0.1 % individual-vs-reference divergence
//! that read mapping must tolerate (paper §I: >99 % resemblance).
//!
//! Indels shift coordinates, so the donor carries a coordinate map back
//! to the reference; read ground truth is always expressed in reference
//! coordinates.

use crate::util::SmallRng;

use super::encode::Seq;
use super::synth::mutate_base;

/// Donor mutation profile.
#[derive(Debug, Clone)]
pub struct MutateConfig {
    /// Per-base SNP rate (human ≈ 1e-3).
    pub snp_rate: f64,
    /// Per-base small-insertion rate.
    pub ins_rate: f64,
    /// Per-base small-deletion rate.
    pub del_rate: f64,
    /// Max indel length (uniform in 1..=max).
    pub max_indel: usize,
    /// RNG seed (deterministic donor for a given config).
    pub seed: u64,
}

impl Default for MutateConfig {
    fn default() -> Self {
        MutateConfig {
            snp_rate: 1e-3,
            ins_rate: 5e-5,
            del_rate: 5e-5,
            max_indel: 3,
            seed: 0xDA27_0003,
        }
    }
}

/// A donor genome plus its coordinate map to the reference.
pub struct Donor {
    /// The donor sequence (base codes).
    pub seq: Seq,
    /// For each donor base, the reference coordinate it derives from (for
    /// inserted bases: the coordinate of the nearest following reference
    /// base). Monotone non-decreasing.
    map: Vec<u32>,
    /// Number of SNPs applied.
    pub n_snps: usize,
    /// Number of indel events applied.
    pub n_indels: usize,
}

impl Donor {
    /// Reference coordinate of donor position `p`.
    #[inline]
    pub fn to_ref(&self, p: usize) -> u32 {
        self.map[p]
    }

    /// Donor genome length in bases.
    pub fn len(&self) -> usize {
        self.seq.len()
    }

    /// True when the donor sequence is empty.
    pub fn is_empty(&self) -> bool {
        self.seq.is_empty()
    }

    /// The donor→reference coordinate map as a closure, the shape the
    /// read simulators take (`cfg.simulate(&donor.seq, donor.mapper())`).
    pub fn mapper(&self) -> impl Fn(usize) -> u32 + '_ {
        move |p| self.to_ref(p)
    }
}

impl MutateConfig {
    /// Apply the profile to `reference`, producing a donor genome.
    pub fn apply(&self, reference: &[u8]) -> Donor {
        let mut rng = SmallRng::seed_from_u64(self.seed);
        let mut seq = Vec::with_capacity(reference.len());
        let mut map = Vec::with_capacity(reference.len());
        let (mut n_snps, mut n_indels) = (0usize, 0usize);
        let mut i = 0usize;
        while i < reference.len() {
            if self.del_rate > 0.0 && rng.gen_bool(self.del_rate) {
                let l = rng.gen_range(1..=self.max_indel).min(reference.len() - i);
                i += l; // skip reference bases
                n_indels += 1;
                continue;
            }
            if self.ins_rate > 0.0 && rng.gen_bool(self.ins_rate) {
                let l = rng.gen_range(1..=self.max_indel);
                for _ in 0..l {
                    seq.push(rng.gen_range(0..4u8));
                    map.push(i as u32);
                }
                n_indels += 1;
            }
            let b = reference[i];
            let b = if b < 4 && rng.gen_bool(self.snp_rate) {
                n_snps += 1;
                mutate_base(&mut rng, b)
            } else {
                b
            };
            seq.push(b);
            map.push(i as u32);
            i += 1;
        }
        Donor { seq, map, n_snps, n_indels }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::SynthConfig;

    fn reference() -> Seq {
        SynthConfig { len: 30_000, ..Default::default() }.generate()
    }

    #[test]
    fn zero_rates_are_identity() {
        let r = reference();
        let d = MutateConfig { snp_rate: 0.0, ins_rate: 0.0, del_rate: 0.0, ..Default::default() }
            .apply(&r);
        assert_eq!(d.seq, r);
        assert_eq!(d.n_snps + d.n_indels, 0);
        assert_eq!(d.to_ref(12345), 12345);
    }

    #[test]
    fn snps_change_bases_but_not_length() {
        let r = reference();
        let d = MutateConfig { snp_rate: 0.01, ins_rate: 0.0, del_rate: 0.0, ..Default::default() }
            .apply(&r);
        assert_eq!(d.len(), r.len());
        assert!(d.n_snps > 100, "n_snps={}", d.n_snps);
        let diff = r.iter().zip(&d.seq).filter(|(a, b)| a != b).count();
        assert_eq!(diff, d.n_snps);
    }

    #[test]
    fn coordinate_map_is_monotone_and_bounded() {
        let r = reference();
        let d = MutateConfig { ins_rate: 1e-3, del_rate: 1e-3, ..Default::default() }.apply(&r);
        assert!(d.n_indels > 0);
        let mut prev = 0u32;
        for p in 0..d.len() {
            let m = d.to_ref(p);
            assert!(m >= prev && (m as usize) < r.len());
            prev = m;
        }
    }

    #[test]
    fn unmutated_stretches_map_identically() {
        let r = reference();
        let d = MutateConfig::default().apply(&r);
        // most donor positions should map to a reference position whose
        // base agrees (SNP rate is low)
        let agree = (0..d.len())
            .filter(|&p| d.seq[p] == r[d.to_ref(p) as usize])
            .count();
        assert!(agree as f64 / d.len() as f64 > 0.99);
    }
}
