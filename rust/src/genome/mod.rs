//! Genomics substrate: base encoding, FASTA/FASTQ I/O, and synthetic
//! genome / read-set generation (the stand-in for GRCh38 + HG002 —
//! DESIGN.md §6 documents the substitution).

pub mod encode;
pub mod fasta;
pub mod fastq;
pub mod mutate;
pub mod synth;

pub use encode::{decode_seq, encode_seq, revcomp, Seq, BASE_A, BASE_C, BASE_G, BASE_N, BASE_T};
pub use synth::{PairSimConfig, ReadRecord, ReadSimConfig, SynthConfig};
