//! DART-PIM architecture + algorithm configuration (paper Tables II/III).

/// Full DART-PIM configuration. Defaults reproduce the paper's evaluated
/// system exactly.
#[derive(Debug, Clone)]
pub struct DartPimConfig {
    // ---- Table II: architecture ----
    /// PIM modules (DRAM-rank analogue).
    pub n_modules: usize,
    /// Memory chips per PIM module.
    pub chips_per_module: usize,
    /// Banks per chip.
    pub banks_per_chip: usize,
    /// Crossbars per bank.
    pub xbars_per_bank: usize,
    /// Crossbar width in bits (columns).
    pub xbar_cols: usize,
    /// Crossbar height in rows.
    pub xbar_rows: usize,
    /// RISC-V cores per chip.
    pub riscv_per_chip: usize,
    /// L1 cache per chip (bytes).
    pub cache_per_chip: usize,
    /// RISC-V <-> memory bus width (bits).
    pub bus_bits: usize,

    // ---- Table III: crossbar partition + policies ----
    /// Reads FIFO rows (3 reads per row).
    pub fifo_rows: usize,
    /// Linear WF buffer rows (concurrent linear instances).
    pub linear_rows: usize,
    /// Affine WF buffer rows (8 rows per instance).
    pub affine_rows: usize,
    /// Rows per affine instance (1 compute + 7 traceback).
    pub affine_rows_per_instance: usize,
    /// Minimizer-frequency threshold below which WF work is offloaded to
    /// the DP-RISC-V cores.
    pub low_th: usize,
    /// Maximum reads routed to any single crossbar (accuracy/time knob;
    /// paper evaluates 12.5k / 25k / 50k).
    pub max_reads: usize,

    // ---- Timing (Table V) ----
    /// MAGIC / write cycle time in seconds (2 ns, conservatively scaled).
    pub t_clk: f64,
}

impl Default for DartPimConfig {
    fn default() -> Self {
        DartPimConfig {
            n_modules: 1,
            chips_per_module: 32,
            banks_per_chip: 512,
            xbars_per_bank: 512,
            xbar_cols: 1024,
            xbar_rows: 256,
            riscv_per_chip: 4,
            cache_per_chip: 128 << 10,
            bus_bits: 512,
            fifo_rows: 160,
            linear_rows: 32,
            affine_rows: 64,
            affine_rows_per_instance: 8,
            low_th: 3,
            max_reads: 25_000,
            t_clk: 2e-9,
        }
    }
}

impl DartPimConfig {
    /// Preset with a given maxReads (the paper's sweep values).
    pub fn with_max_reads(max_reads: usize) -> Self {
        DartPimConfig { max_reads, ..Default::default() }
    }

    /// Total crossbars in the system (8M in the paper config).
    pub fn total_xbars(&self) -> usize {
        self.n_modules * self.chips_per_module * self.banks_per_chip * self.xbars_per_bank
    }

    /// Total RISC-V cores (128 in the paper config).
    pub fn total_riscv(&self) -> usize {
        self.n_modules * self.chips_per_module * self.riscv_per_chip
    }

    /// Total memory capacity in bytes (crossbar bits / 8).
    pub fn total_capacity_bytes(&self) -> usize {
        self.total_xbars() * self.xbar_cols * self.xbar_rows / 8
    }

    /// Reads the FIFO can hold (3 per row — paper Fig. 6).
    pub fn fifo_capacity_reads(&self) -> usize {
        self.fifo_rows * 3
    }

    /// Concurrent affine instances per crossbar.
    pub fn affine_instances(&self) -> usize {
        self.affine_rows / self.affine_rows_per_instance
    }

    /// Sanity: the three buffers exactly fill the crossbar rows.
    pub fn rows_consistent(&self) -> bool {
        self.fifo_rows + self.linear_rows + self.affine_rows == self.xbar_rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configuration() {
        let c = DartPimConfig::default();
        assert_eq!(c.total_xbars(), 8 * 1024 * 1024); // 8M crossbars
        assert_eq!(c.total_capacity_bytes(), 256 << 30); // 256 GB (Table II)
        assert_eq!(c.total_riscv(), 128);
        assert_eq!(c.fifo_capacity_reads(), 480);
        assert_eq!(c.affine_instances(), 8);
        assert!(c.rows_consistent());
    }

    #[test]
    fn max_reads_presets() {
        for m in [12_500, 25_000, 50_000] {
            assert_eq!(DartPimConfig::with_max_reads(m).max_reads, m);
        }
    }
}
