//! Controller hierarchy (paper Fig. 5, Table VI): one PIM controller,
//! per-chip controllers, per-bank controllers, per-crossbar controllers.
//! All crossbars execute identical op sequences, so controllers are
//! simple broadcast machines; this module models their counts and power
//! roll-up, and provides the broadcast fan-out used by the coordinator.

use super::config::DartPimConfig;

/// Per-unit controller power (W), Table VI (synthesized, TSMC 28 nm).
#[derive(Debug, Clone)]
pub struct ControllerPower {
    /// Per-crossbar controller power (W).
    pub xbar_w: f64,
    /// Per-bank controller power (W).
    pub bank_w: f64,
    /// Per-chip controller power (W).
    pub chip_w: f64,
    /// Top-level PIM controller power (W).
    pub pim_w: f64,
    /// Peripheral decode-and-drive unit power (W) per bank.
    pub decode_drive_w: f64,
}

impl Default for ControllerPower {
    fn default() -> Self {
        ControllerPower {
            xbar_w: 9.43e-6,
            bank_w: 0.42e-3,
            chip_w: 9.4e-3,
            pim_w: 0.5e-3,
            decode_drive_w: 129.1e-6,
        }
    }
}

/// Controller counts for a configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ControllerCounts {
    /// Top-level PIM controllers (one per module).
    pub pim: usize,
    /// Chip controllers.
    pub chip: usize,
    /// Bank controllers.
    pub bank: usize,
    /// Crossbar controllers.
    pub xbar: usize,
}

/// Hierarchical address of one crossbar.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct XbarAddr {
    /// Chip index within the module.
    pub chip: u32,
    /// Bank index within the chip.
    pub bank: u32,
    /// Crossbar index within the bank.
    pub xbar: u32,
}

/// Controller counts for a configuration.
pub fn counts(cfg: &DartPimConfig) -> ControllerCounts {
    ControllerCounts {
        pim: cfg.n_modules,
        chip: cfg.n_modules * cfg.chips_per_module,
        bank: cfg.n_modules * cfg.chips_per_module * cfg.banks_per_chip,
        xbar: cfg.total_xbars(),
    }
}

/// Aggregate controller power (the paper quotes 86 W).
pub fn total_power(cfg: &DartPimConfig, p: &ControllerPower) -> f64 {
    let c = counts(cfg);
    c.pim as f64 * p.pim_w
        + c.chip as f64 * p.chip_w
        + c.bank as f64 * p.bank_w
        + c.xbar as f64 * p.xbar_w
}

/// Decompose a flat crossbar id into its hierarchical address (routing:
/// the PIM controller forwards a read only to chips/banks owning its
/// minimizers — paper §V-C).
pub fn addr_of(cfg: &DartPimConfig, flat: usize) -> XbarAddr {
    assert!(flat < cfg.total_xbars(), "crossbar id out of range");
    let per_chip = cfg.banks_per_chip * cfg.xbars_per_bank;
    XbarAddr {
        chip: (flat / per_chip) as u32,
        bank: ((flat % per_chip) / cfg.xbars_per_bank) as u32,
        xbar: (flat % cfg.xbars_per_bank) as u32,
    }
}

/// Inverse of [`addr_of`].
pub fn flat_of(cfg: &DartPimConfig, addr: XbarAddr) -> usize {
    (addr.chip as usize * cfg.banks_per_chip + addr.bank as usize) * cfg.xbars_per_bank
        + addr.xbar as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_match_table_ii() {
        let c = counts(&DartPimConfig::default());
        assert_eq!(c, ControllerCounts { pim: 1, chip: 32, bank: 16_384, xbar: 8 * 1024 * 1024 });
    }

    #[test]
    fn power_matches_paper_86w() {
        let p = total_power(&DartPimConfig::default(), &ControllerPower::default());
        assert!((p - 86.0).abs() / 86.0 < 0.02, "controllers power = {p}");
    }

    #[test]
    fn addr_roundtrip() {
        let cfg = DartPimConfig::default();
        for flat in [0usize, 1, 511, 512, 262_143, 262_144, 8 * 1024 * 1024 - 1] {
            let a = addr_of(&cfg, flat);
            assert_eq!(flat_of(&cfg, a), flat);
            assert!((a.chip as usize) < 32);
            assert!((a.bank as usize) < 512);
            assert!((a.xbar as usize) < 512);
        }
    }

    #[test]
    #[should_panic]
    fn addr_bounds_checked() {
        addr_of(&DartPimConfig::default(), 8 * 1024 * 1024);
    }
}
