//! Digital-PIM hardware model of DART-PIM (paper §IV-§V).
//!
//! This is the substrate the paper evaluates on — memristive crossbars
//! executing MAGIC NOR sequences — reproduced as a set of explicit,
//! constructive cost models:
//!
//! * [`config`]   — architecture + algorithm parameters (Tables II/III)
//! * [`magic`]    — MAGIC-NOR composite-op cycle costs (Table I)
//! * [`xbar_sim`] — single-crossbar cycle/switch accounting for one
//!                  linear / affine WF instance (Table IV), plus the
//!                  crossbar row bit-allocation check (Fig. 3/6)
//! * [`energy`]   — switching/transfer/controller energy (Tables V/VI,
//!                  Eq. 7; Fig. 10b)
//! * [`area`]     — component areas (Table VI; Fig. 10c)
//! * [`controller`] — the controller hierarchy (PIM/chip/bank/crossbar)
//!                  with power/area roll-ups

pub mod area;
pub mod config;
pub mod controller;
pub mod energy;
pub mod magic;
pub mod xbar_sim;

pub use config::DartPimConfig;
pub use xbar_sim::{affine_instance_cost, linear_instance_cost, InstanceCost};
