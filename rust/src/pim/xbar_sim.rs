//! Single-crossbar simulator: cycle and switch accounting for one
//! linear / affine WF instance (reproduces paper Table IV), plus the
//! crossbar row bit-allocation of Figs. 3/6.
//!
//! Two cost sources are provided:
//!
//! * [`CostSource::Constructive`] — build the explicit MAGIC op sequence
//!   per WF cell from Table I (see [`super::magic`]) and sum. For the
//!   linear WF this reproduces the paper's per-cell 37b+19 exactly
//!   (254,585 MAGIC cycles); for the affine WF the paper does not publish
//!   its op sequence and our construction lands within ~20 % of the
//!   published total (EXPERIMENTS.md, Table IV row).
//! * [`CostSource::PaperTable4`] — the published Table IV numbers
//!   verbatim; used by default for system-level projections (Figs. 9/10)
//!   so those reproduce the paper's arithmetic.

use super::magic::{min_with_writeback, MagicOp};
use crate::params::{window_len, BAND, READ_LEN};

/// Bit width of linear WF cells (paper §III: 3-bit).
pub const B_LINEAR: usize = 3;
/// Bit width of affine WF cells (paper §III: 5-bit).
pub const B_AFFINE: usize = 5;

/// Where instance costs come from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CostSource {
    /// Explicit op-sequence construction from Table I.
    Constructive,
    /// Published Table IV numbers.
    #[default]
    PaperTable4,
}

/// Cycle/switch cost of one WF instance on one crossbar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InstanceCost {
    /// Compute cycles (MAGIC NOR sequences).
    pub magic_cycles: u64,
    /// Memristor switches during compute.
    pub magic_switches: u64,
    /// Cycles spent writing operands into rows.
    pub write_cycles: u64,
    /// Memristor switches during operand writes.
    pub write_switches: u64,
}

impl InstanceCost {
    /// Compute + write cycles.
    pub fn total_cycles(&self) -> u64 {
        self.magic_cycles + self.write_cycles
    }

    /// Compute + write switches (drives the energy model).
    pub fn total_switches(&self) -> u64 {
        self.magic_switches + self.write_switches
    }
}

/// MAGIC op sequence for one *linear* WF cell (paper Algorithm 1),
/// bit-width `b`. Total = 37b + 19.
pub fn linear_cell_ops(b: usize) -> Vec<MagicOp> {
    let mut seq = Vec::new();
    seq.extend(min_with_writeback(b)); // X = min(D_top, D_left)            13b
    seq.extend(min_with_writeback(b)); // Y = min(X, D_diag)                13b
    seq.push(MagicOp::AddConst(b)); //    Z = Y + 1                          5b
    seq.push(MagicOp::Raw(6)); //         S1 = saturation detect (2 ANDs)     6
    seq.push(MagicOp::Mux(b)); //         MUX1 = S1 ? Y : Z               3b+1
    seq.push(MagicOp::Raw(11)); //        S2 = match detect (2 XNOR + AND)   11
    seq.push(MagicOp::Mux(b)); //         D_ij = S2 ? D_diag : MUX1       3b+1
    seq
}

/// MAGIC op sequence for one *affine* WF cell (Eqs. 3-5 + traceback),
/// bit-width `b`. Constructive — see module docs.
pub fn affine_cell_ops(b: usize) -> Vec<MagicOp> {
    let mut seq = Vec::new();
    // M1 = min(M1_up + w_ex, D_up + w_op + w_ex), direction bit kept
    seq.push(MagicOp::AddConst(b));
    seq.push(MagicOp::AddConst(b));
    seq.extend(min_with_writeback(b));
    seq.push(MagicOp::Raw(2)); // M1 direction copy (1+1)
    // A = min(M1, D + w_sub)
    seq.push(MagicOp::AddConst(b));
    seq.extend(min_with_writeback(b));
    // M2 = min(cbase, M2_left + w_ex); cbase = (match ? D : A) + (w_op+w_ex)
    seq.push(MagicOp::Raw(11)); // match detect (2 XNOR + AND on 2-bit codes)
    seq.push(MagicOp::Mux(b)); // cbase select
    seq.push(MagicOp::AddConst(b)); // + w_op + w_ex
    seq.extend(min_with_writeback(b)); // chain min
    seq.push(MagicOp::Raw(2)); // M2 direction copy
    // D = match ? D_diag : min(A, M2)
    seq.extend(min_with_writeback(b));
    seq.push(MagicOp::Mux(b));
    seq.push(MagicOp::Raw(6)); // D-origin 2-bit encode from select lines
    // 5-bit saturation of M1 and M2 (D saturates through the final mux)
    seq.extend(min_with_writeback(b));
    seq.extend(min_with_writeback(b));
    // traceback: copy the packed 4 direction bits to the traceback rows
    seq.push(MagicOp::Copy(4));
    seq
}

/// Paper-reported residual cycles outside the per-cell loop for a linear
/// instance: first row/column init + the step-(4) minimum extraction
/// across the 32 linear-buffer rows (paper §VII-B: 254,585 - 1950*130).
pub const LINEAR_INIT_CYCLES: u64 = 1_085;
/// Same residual scaled by bit-width for the affine instance
/// (constructive mode; the paper does not break this out).
pub const AFFINE_INIT_CYCLES: u64 = LINEAR_INIT_CYCLES * B_AFFINE as u64 / B_LINEAR as u64;

/// Input-data write bits for one linear instance: the read (2 bits/base)
/// broadcast into the row + band-buffer initialization.
fn linear_data_bits(read_len: usize) -> u64 {
    (2 * read_len + BAND * B_LINEAR) as u64
}

/// Input-data write bits for one affine instance: read + the aligned
/// window sub-segment copied from the linear stage + 3 band buffers.
fn affine_data_bits(read_len: usize) -> u64 {
    (2 * read_len + 2 * window_len(read_len) + 3 * BAND * B_AFFINE) as u64
}

/// Row-parallel write width (bits initialized per write cycle): MAGIC
/// output cells are re-initialized in batches across the row.
pub const WRITE_WIDTH: u64 = 64;

/// Published Table IV (linear WF row).
pub const PAPER_LINEAR: InstanceCost = InstanceCost {
    magic_cycles: 254_585,
    magic_switches: 254_384,
    write_cycles: 4_035,
    write_switches: 255_499,
};

/// Published Table IV (affine WF row).
pub const PAPER_AFFINE: InstanceCost = InstanceCost {
    magic_cycles: 1_288_281,
    magic_switches: 1_271_921,
    write_cycles: 20_418,
    write_switches: 1_277_495,
};

fn constructive(cell_cycles: u64, init: u64, data_bits: u64, read_len: usize) -> InstanceCost {
    let cells = (BAND * read_len) as u64;
    let magic_cycles = cells * cell_cycles + init;
    // Every MAGIC gate output cell is initialized before use (one switch
    // each, WRITE_WIDTH per cycle); plus the input data writes.
    let write_switches = magic_cycles + data_bits;
    let write_cycles = write_switches.div_ceil(WRITE_WIDTH);
    InstanceCost {
        magic_cycles,
        // upper bound: every MAGIC cycle switches its output cell
        magic_switches: magic_cycles,
        write_cycles,
        write_switches,
    }
}

/// Cost of one linear WF instance (read_len = 150 unless noted).
pub fn linear_instance_cost(src: CostSource) -> InstanceCost {
    match src {
        CostSource::PaperTable4 => PAPER_LINEAR,
        CostSource::Constructive => constructive(
            MagicOp::total(&linear_cell_ops(B_LINEAR)) as u64,
            LINEAR_INIT_CYCLES,
            linear_data_bits(READ_LEN),
            READ_LEN,
        ),
    }
}

/// Cost of one affine WF instance.
pub fn affine_instance_cost(src: CostSource) -> InstanceCost {
    match src {
        CostSource::PaperTable4 => PAPER_AFFINE,
        CostSource::Constructive => constructive(
            MagicOp::total(&affine_cell_ops(B_AFFINE)) as u64,
            AFFINE_INIT_CYCLES,
            affine_data_bits(READ_LEN),
            READ_LEN,
        ),
    }
}

/// Crossbar-row bit allocation (Fig. 3 for the linear buffer, Fig. 6 for
/// the affine buffer). Asserted to fit the 1024-bit row.
#[derive(Debug, Clone)]
pub struct RowAllocation {
    /// Bits holding the reference segment / window.
    pub segment_bits: usize,
    /// Bits holding the read.
    pub read_bits: usize,
    /// Bits holding the WF band value columns.
    pub band_bits: usize,
    /// Bits reserved for intermediates.
    pub temp_bits: usize,
    /// Physical row width (1024 in the paper).
    pub row_bits: usize,
}

impl RowAllocation {
    /// Bits allocated to data (segment + read + band).
    pub fn used(&self) -> usize {
        self.segment_bits + self.read_bits + self.band_bits
    }

    /// True when the allocation leaves the paper's ~80 temp bits free.
    pub fn fits(&self) -> bool {
        // the paper requires >= ~80 temp bits for intermediates
        self.used() + 80 <= self.row_bits
    }
}

/// Linear-buffer row: full reference segment + read + 13x3b band.
pub fn linear_row_allocation(read_len: usize, row_bits: usize) -> RowAllocation {
    RowAllocation {
        segment_bits: 2 * crate::params::segment_len(read_len),
        read_bits: 2 * read_len,
        band_bits: BAND * B_LINEAR,
        temp_bits: row_bits.saturating_sub(
            2 * crate::params::segment_len(read_len) + 2 * read_len + BAND * B_LINEAR,
        ),
        row_bits,
    }
}

/// Affine compute row: aligned window sub-segment + read + 3 bands x 5b.
pub fn affine_row_allocation(read_len: usize, row_bits: usize) -> RowAllocation {
    RowAllocation {
        segment_bits: 2 * window_len(read_len),
        read_bits: 2 * read_len,
        band_bits: 3 * BAND * B_AFFINE,
        temp_bits: row_bits
            .saturating_sub(2 * window_len(read_len) + 2 * read_len + 3 * BAND * B_AFFINE),
        row_bits,
    }
}

/// Traceback storage demand in bits for one affine instance (4 bits per
/// banded cell) — fits the 8-row affine instance allocation (7 dedicated
/// traceback rows + the compute row's spare bits).
pub fn traceback_bits(read_len: usize) -> usize {
    4 * BAND * read_len
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_cell_is_37b_plus_19() {
        for b in [3usize, 4, 5, 8] {
            assert_eq!(MagicOp::total(&linear_cell_ops(b)), 37 * b + 19);
        }
    }

    #[test]
    fn linear_constructive_reproduces_table4_cycles_exactly() {
        let c = linear_instance_cost(CostSource::Constructive);
        // 1950 cells x 130 cycles + 1085 init = 254,585 (paper §VII-B)
        assert_eq!(c.magic_cycles, PAPER_LINEAR.magic_cycles);
    }

    #[test]
    fn linear_constructive_close_to_table4_switches_and_writes() {
        let c = linear_instance_cost(CostSource::Constructive);
        let p = PAPER_LINEAR;
        let pct = |a: u64, b: u64| (a as f64 - b as f64).abs() / b as f64;
        assert!(pct(c.magic_switches, p.magic_switches) < 0.002);
        assert!(pct(c.write_switches, p.write_switches) < 0.003);
        assert!(pct(c.write_cycles, p.write_cycles) < 0.02);
    }

    #[test]
    fn affine_constructive_within_20pct_of_table4() {
        let c = affine_instance_cost(CostSource::Constructive);
        let p = PAPER_AFFINE;
        let ratio = c.magic_cycles as f64 / p.magic_cycles as f64;
        assert!((0.8..=1.2).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn paper_mode_is_verbatim() {
        assert_eq!(linear_instance_cost(CostSource::PaperTable4), PAPER_LINEAR);
        assert_eq!(affine_instance_cost(CostSource::PaperTable4), PAPER_AFFINE);
        assert_eq!(PAPER_LINEAR.total_cycles(), 258_620); // paper text
        assert_eq!(PAPER_AFFINE.total_cycles(), 1_308_699);
    }

    #[test]
    fn rows_fit_1024_bits() {
        let lin = linear_row_allocation(READ_LEN, 1024);
        assert!(lin.fits(), "linear row: {lin:?}");
        assert_eq!(lin.segment_bits, 600); // 300 bases (paper §V-B)
        let aff = affine_row_allocation(READ_LEN, 1024);
        assert!(aff.fits(), "affine row: {aff:?}");
    }

    #[test]
    fn traceback_fits_the_eight_row_instance() {
        // 4 b/cell x 13 x 150 = 7800 bits ≈ 7.6 rows — matching the
        // paper's "7x more rows than used for computation" (the last
        // ~600 bits overflow into the compute row's spare region; the
        // paper's figure of exactly 7 dedicated rows assumes the D-origin
        // bits of pure-match rows are elided).
        let bits = traceback_bits(READ_LEN);
        assert_eq!(bits, 7800);
        assert!(bits <= 8 * 1024, "must fit the 8-row instance allocation");
        assert!(bits > 6 * 1024, "needs ~7 traceback rows, as the paper states");
    }

    #[test]
    fn affine_cost_dominates_linear() {
        let l = linear_instance_cost(CostSource::Constructive);
        let a = affine_instance_cost(CostSource::Constructive);
        assert!(a.magic_cycles > 3 * l.magic_cycles);
    }
}
