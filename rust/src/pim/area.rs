//! Area model (paper §VII-E, Table VI; Fig. 10c). 28 nm CMOS; crossbar
//! cells 4F² at F = 30 nm.

use super::config::DartPimConfig;

/// Component areas in mm².
#[derive(Debug, Clone)]
pub struct AreaModel {
    /// Memristive cell feature size (m) — 30 nm [45].
    pub feature_size: f64,
    /// RISC-V core area (mm²) — AndesCore AX25: 0.11.
    pub riscv_core_mm2: f64,
    /// RISC-V cache area (mm²) — 0.05.
    pub riscv_cache_mm2: f64,
    /// Crossbar controller area (µm²), Table VI.
    pub xbar_ctrl_um2: f64,
    /// Bank controller area (µm²), Table VI.
    pub bank_ctrl_um2: f64,
    /// Chip controller area (µm²), Table VI.
    pub chip_ctrl_um2: f64,
    /// PIM controller area (µm²), Table VI.
    pub pim_ctrl_um2: f64,
    /// Peripheral decode-and-drive unit area (µm²), Table VI (RACER,
    /// scaled to 28 nm).
    pub decode_drive_um2: f64,
    /// Read/write circuit area per cell column (µm²).
    pub rw_circuit_um2: f64,
    /// Selector pass-gate area per cell (µm²).
    pub selector_passgate_um2: f64,
    /// Driver pass-gate area per cell (µm²).
    pub driver_passgate_um2: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            feature_size: 30e-9,
            riscv_core_mm2: 0.11,
            riscv_cache_mm2: 0.05,
            xbar_ctrl_um2: 21.0,
            bank_ctrl_um2: 939.0,
            chip_ctrl_um2: 20_091.0,
            pim_ctrl_um2: 938.0,
            decode_drive_um2: 277.0,
            rw_circuit_um2: 0.06,
            selector_passgate_um2: 0.001,
            driver_passgate_um2: 0.001,
        }
    }
}

/// Area breakdown in mm² (Fig. 10c categories).
#[derive(Debug, Clone)]
pub struct AreaBreakdown {
    /// Memristive crossbar arrays.
    pub crossbars: f64,
    /// Controller hierarchy (PIM/chip/bank/crossbar).
    pub controllers: f64,
    /// Peripheral decode-and-drive circuitry.
    pub peripherals: f64,
    /// DP-RISC-V cores and caches.
    pub riscv: f64,
}

impl AreaBreakdown {
    /// Total area in mm².
    pub fn total(&self) -> f64 {
        self.crossbars + self.controllers + self.peripherals + self.riscv
    }
}

impl AreaModel {
    /// Area of one crossbar: cells x 4F².
    pub fn crossbar_mm2(&self, cfg: &DartPimConfig) -> f64 {
        let cell_m2 = 4.0 * self.feature_size * self.feature_size;
        let cells = (cfg.xbar_cols * cfg.xbar_rows) as f64;
        cells * cell_m2 * 1e6 // m² -> mm²
    }

    /// Full breakdown for a configuration.
    pub fn breakdown(&self, cfg: &DartPimConfig) -> AreaBreakdown {
        let um2 = 1e-6; // µm² -> mm²
        let n_xbar = cfg.total_xbars() as f64;
        let n_bank = (cfg.n_modules * cfg.chips_per_module * cfg.banks_per_chip) as f64;
        let n_chip = (cfg.n_modules * cfg.chips_per_module) as f64;
        let n_riscv = cfg.total_riscv() as f64;
        let controllers = um2
            * (n_xbar * self.xbar_ctrl_um2
                + n_bank * self.bank_ctrl_um2
                + n_chip * self.chip_ctrl_um2
                + cfg.n_modules as f64 * self.pim_ctrl_um2);
        let peripherals = um2
            * (n_bank * self.decode_drive_um2
                + n_xbar * self.rw_circuit_um2
                + n_xbar * cfg.xbar_cols as f64 * self.selector_passgate_um2
                + n_xbar * cfg.xbar_rows as f64 * self.driver_passgate_um2);
        AreaBreakdown {
            crossbars: n_xbar * self.crossbar_mm2(cfg),
            controllers,
            peripherals,
            riscv: n_riscv * (self.riscv_core_mm2 + self.riscv_cache_mm2),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crossbar_area_matches_paper() {
        // 256x1024 cells x 4F² (F = 30 nm) = 944 µm² (paper §VII-E)
        let a = AreaModel::default().crossbar_mm2(&DartPimConfig::default());
        assert!((a * 1e6 - 944.0).abs() < 2.0, "xbar area µm² = {}", a * 1e6);
    }

    #[test]
    fn total_area_matches_paper_ballpark() {
        // paper: 8170 mm² total, crossbars 7916 mm² (96.9 %)
        let b = AreaModel::default().breakdown(&DartPimConfig::default());
        assert!((b.crossbars - 7916.0).abs() / 7916.0 < 0.01, "crossbars={}", b.crossbars);
        let total = b.total();
        assert!((total - 8170.0).abs() / 8170.0 < 0.05, "total={total}");
        assert!(b.crossbars / total > 0.95);
    }

    #[test]
    fn riscv_area_matches_paper() {
        // 128 x (0.11 + 0.05) = 20.5 mm² (paper: 14.2 + 6.4 = 20.6)
        let b = AreaModel::default().breakdown(&DartPimConfig::default());
        assert!((b.riscv - 20.48).abs() < 0.2, "riscv={}", b.riscv);
    }

    #[test]
    fn controllers_match_paper_aggregate() {
        // paper: controllers 191.9 mm² (dominated by 8M crossbar
        // controllers at 21 µm²; our sum uses 32 chip controllers where
        // Table VI lists 16 — difference < 1 mm²)
        let b = AreaModel::default().breakdown(&DartPimConfig::default());
        assert!((b.controllers - 191.9).abs() / 191.9 < 0.10, "controllers={}", b.controllers);
    }
}
