//! MAGIC NOR composite-operation cycle costs (paper Table I).
//!
//! All in-crossbar computation decomposes into sequences of these
//! composite ops; each is itself a latency-optimal sequence of 1-cycle
//! MAGIC NOR gates (SIMPLER-MAGIC synthesis, paper refs [13], [14]).
//! The same op executes in every participating row simultaneously, so
//! cycle counts are per-row-sequence, independent of row parallelism.

/// A composite in-memory operation over `N`-bit operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MagicOp {
    /// Bitwise AND of two N-bit operands.
    And(usize),
    /// Bitwise XNOR.
    Xnor(usize),
    /// Bitwise XOR.
    Xor(usize),
    /// Copy N bits.
    Copy(usize),
    /// Add two in-memory N-bit numbers.
    Add(usize),
    /// Add an N-bit and a single-bit in-memory number.
    AddBit(usize),
    /// Add an in-memory N-bit number and a constant.
    AddConst(usize),
    /// Subtract two in-memory N-bit numbers.
    Sub(usize),
    /// Select between two in-memory N-bit numbers.
    Mux(usize),
    /// Minimum of two in-memory N-bit numbers.
    Min(usize),
    /// Raw MAGIC NOR gates (fixed count) — used for the small glue steps
    /// Algorithm 1 accounts explicitly (match detect, select derive).
    Raw(usize),
}

impl MagicOp {
    /// Execution cycles (Table I).
    pub fn cycles(&self) -> usize {
        match *self {
            MagicOp::And(n) => 3 * n,
            MagicOp::Xnor(n) => 4 * n,
            MagicOp::Xor(n) => 5 * n,
            MagicOp::Copy(n) => 1 + n,
            MagicOp::Add(n) => 9 * n,
            MagicOp::AddBit(n) => 5 * n,
            MagicOp::AddConst(n) => 5 * n,
            MagicOp::Sub(n) => 9 * n,
            MagicOp::Mux(n) => 3 * n + 1,
            MagicOp::Min(n) => 12 * n + 1,
            MagicOp::Raw(c) => c,
        }
    }

    /// Cycles of an op sequence.
    pub fn total(seq: &[MagicOp]) -> usize {
        seq.iter().map(|op| op.cycles()).sum()
    }
}

/// The paper's Algorithm 1 accounts `min` as 13 cycles/bit (a Table-I
/// `Min` plus result copy-back into the distance buffer); modelled
/// explicitly so the per-cell total lands on the published 37b+19.
pub fn min_with_writeback(n: usize) -> Vec<MagicOp> {
    vec![MagicOp::Min(n), MagicOp::Raw(n - 1)] // 12n+1 + (n-1) = 13n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_one_values() {
        // Table I with N = 3 (linear WF bit-width)
        assert_eq!(MagicOp::And(3).cycles(), 9);
        assert_eq!(MagicOp::Xnor(3).cycles(), 12);
        assert_eq!(MagicOp::Xor(3).cycles(), 15);
        assert_eq!(MagicOp::Copy(3).cycles(), 4);
        assert_eq!(MagicOp::Add(3).cycles(), 27);
        assert_eq!(MagicOp::AddBit(3).cycles(), 15);
        assert_eq!(MagicOp::AddConst(3).cycles(), 15);
        assert_eq!(MagicOp::Sub(3).cycles(), 27);
        assert_eq!(MagicOp::Mux(3).cycles(), 10);
        assert_eq!(MagicOp::Min(3).cycles(), 37);
    }

    #[test]
    fn min_with_writeback_is_13n() {
        assert_eq!(MagicOp::total(&min_with_writeback(3)), 39);
        assert_eq!(MagicOp::total(&min_with_writeback(5)), 65);
    }

    #[test]
    fn sequence_totals() {
        let seq = [MagicOp::Min(3), MagicOp::AddConst(3), MagicOp::Mux(3)];
        assert_eq!(MagicOp::total(&seq), 37 + 15 + 10);
    }
}
