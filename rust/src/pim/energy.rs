//! Energy model (paper Tables V/VI, Eq. 7; Fig. 10b).
//!
//! Crossbar compute energy is switch-count based (90 fJ per MAGIC or
//! write switch, conservatively scaled from RACER); data transfer uses
//! CONCEPT's per-bit costs; controllers / peripherals / RISC-V contribute
//! power x execution-time.

use super::config::DartPimConfig;
use super::xbar_sim::InstanceCost;

/// Per-event energy constants.
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// Energy per MAGIC switch (J) — Table V: 90 fJ.
    pub e_magic: f64,
    /// Energy per write switch (J) — Table V: 90 fJ.
    pub e_write: f64,
    /// DP-RISC-V -> DP-memory write transfer (J/bit) — Table VI: 11.7 pJ.
    pub e_xfer_write: f64,
    /// DP-memory -> DP-RISC-V read transfer (J/bit) — Table VI: 5.64 pJ.
    pub e_xfer_read: f64,
    /// Single RISC-V core power (W) — Table VI: 40 mW (AndesCore AX25).
    pub p_riscv_core: f64,
    /// Single RISC-V cache power (W) — Table VI: 8 mW.
    pub p_riscv_cache: f64,
    /// Aggregate controller power (W) — paper §VII-D: 86 W.
    pub p_controllers: f64,
    /// Memory peripheral power (W) — paper §VII-D (RACER, scaled): 5.7 W.
    pub p_peripherals: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            e_magic: 90e-15,
            e_write: 90e-15,
            e_xfer_write: 11.7e-12,
            e_xfer_read: 5.64e-12,
            p_riscv_core: 40e-3,
            p_riscv_cache: 8e-3,
            p_controllers: 86.0,
            p_peripherals: 5.7,
        }
    }
}

/// Energy breakdown for a full run (Fig. 10b categories), in joules.
#[derive(Debug, Clone, Default)]
pub struct EnergyBreakdown {
    /// MAGIC switching energy in the crossbar arrays.
    pub crossbars: f64,
    /// Controller hierarchy energy.
    pub controllers: f64,
    /// Peripheral decode-and-drive energy.
    pub peripherals: f64,
    /// DP-RISC-V compute energy.
    pub riscv: f64,
    /// Read-stream transfer into the PIM modules.
    pub transfer_in: f64,
    /// Result readout transfer back to the host.
    pub transfer_out: f64,
}

impl EnergyBreakdown {
    /// Total energy in joules.
    pub fn total(&self) -> f64 {
        self.crossbars
            + self.controllers
            + self.peripherals
            + self.riscv
            + self.transfer_in
            + self.transfer_out
    }

    /// Average power over an execution time.
    pub fn avg_power(&self, exec_time_s: f64) -> f64 {
        self.total() / exec_time_s
    }
}

impl EnergyModel {
    /// Energy of one WF instance (switch counts x per-switch energy).
    pub fn instance_energy(&self, cost: &InstanceCost) -> f64 {
        self.e_magic * cost.magic_switches as f64 + self.e_write * cost.write_switches as f64
    }

    /// Eq. 7: total crossbar compute energy for `j_linear` linear and
    /// `j_affine` affine instances.
    pub fn crossbars_energy(
        &self,
        linear: &InstanceCost,
        affine: &InstanceCost,
        j_linear: u64,
        j_affine: u64,
    ) -> f64 {
        self.instance_energy(linear) * j_linear as f64
            + self.instance_energy(affine) * j_affine as f64
    }

    /// Full-system energy breakdown.
    ///
    /// * `bits_in` — read data written into DP-memory over the run.
    /// * `bits_out` — result data read out of DP-memory.
    /// * `riscv_busy_s` — aggregate busy time across all RISC-V cores.
    /// * `exec_time_s` — wall-clock execution time (controller /
    ///   peripheral energy is power x time).
    #[allow(clippy::too_many_arguments)]
    pub fn breakdown(
        &self,
        cfg: &DartPimConfig,
        linear: &InstanceCost,
        affine: &InstanceCost,
        j_linear: u64,
        j_affine: u64,
        bits_in: f64,
        bits_out: f64,
        riscv_busy_s: f64,
        exec_time_s: f64,
    ) -> EnergyBreakdown {
        let n_riscv = cfg.total_riscv() as f64;
        EnergyBreakdown {
            crossbars: self.crossbars_energy(linear, affine, j_linear, j_affine),
            controllers: self.p_controllers * exec_time_s,
            peripherals: self.p_peripherals * exec_time_s,
            // cores idle/busy modelled at constant power (paper uses the
            // AX25 nominal power for all cores over the run)
            riscv: n_riscv
                * (self.p_riscv_core + self.p_riscv_cache)
                * exec_time_s.max(riscv_busy_s / n_riscv),
            transfer_in: self.e_xfer_write * bits_in,
            transfer_out: self.e_xfer_read * bits_out,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::xbar_sim::{PAPER_AFFINE, PAPER_LINEAR};

    #[test]
    fn paper_instance_energies() {
        let m = EnergyModel::default();
        // paper §VII-B: 509,883 switches x 90 fJ = 45.9 nJ (linear)
        let e_lin = m.instance_energy(&PAPER_LINEAR);
        assert!((e_lin - 45.9e-9).abs() / 45.9e-9 < 0.01, "e_lin={e_lin}");
        // 2,549,416 x 90 fJ = 229 nJ (affine)
        let e_aff = m.instance_energy(&PAPER_AFFINE);
        assert!((e_aff - 229e-9).abs() / 229e-9 < 0.01, "e_aff={e_aff}");
    }

    #[test]
    fn eq7_is_linear_in_instances() {
        let m = EnergyModel::default();
        let e1 = m.crossbars_energy(&PAPER_LINEAR, &PAPER_AFFINE, 1000, 10);
        let e2 = m.crossbars_energy(&PAPER_LINEAR, &PAPER_AFFINE, 2000, 20);
        assert!((e2 / e1 - 2.0).abs() < 1e-12);
    }

    #[test]
    fn riscv_power_matches_paper() {
        // 128 cores x (40 + 8) mW = 6.1 W (paper §VII-D)
        let m = EnergyModel::default();
        let p = 128.0 * (m.p_riscv_core + m.p_riscv_cache);
        assert!((p - 6.144).abs() < 0.05);
    }

    #[test]
    fn breakdown_sums() {
        let m = EnergyModel::default();
        let cfg = DartPimConfig::default();
        let b = m.breakdown(
            &cfg,
            &PAPER_LINEAR,
            &PAPER_AFFINE,
            1_000_000,
            10_000,
            1e9,
            1e9,
            10.0,
            100.0,
        );
        let s =
            b.crossbars + b.controllers + b.peripherals + b.riscv + b.transfer_in + b.transfer_out;
        assert!((b.total() - s).abs() < 1e-9);
        assert!(b.avg_power(100.0) > 0.0);
    }
}
