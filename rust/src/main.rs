//! dart-pim CLI entrypoint (the "leader" binary): synthesis, mapping,
//! simulation, and figure regeneration. See `dart-pim help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dart_pim::cli::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
