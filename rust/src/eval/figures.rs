//! Rendering of the paper's tables and figures as aligned-text tables
//! (consumed by the CLI `figures` subcommand and the bench harnesses).
//!
//! Figure data comes from two sources, always labelled: the paper's own
//! reported numbers (`baselines::published`) and our model
//! (`simulator::report` over either the paper workload statistics or a
//! measured synthetic run).

use crate::baselines::published::{paper_dartpim_rows, published_systems, DATASET_READS};
use crate::pim::area::{AreaBreakdown, AreaModel};
use crate::pim::xbar_sim::{affine_instance_cost, linear_instance_cost, CostSource};
use crate::pim::DartPimConfig;
use crate::simulator::report::{build_report, paper_workload_counts};
use crate::simulator::{SystemReport, TimingMode};

/// DART-PIM model rows across the maxReads sweep, paper workload.
pub fn dartpim_model_reports() -> Vec<(usize, SystemReport)> {
    [12_500usize, 25_000, 50_000]
        .into_iter()
        .map(|m| {
            let cfg = DartPimConfig::with_max_reads(m);
            let counts = paper_workload_counts(&cfg);
            (m, build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial))
        })
        .collect()
}

/// Model accuracies per maxReads (paper §VII-A).
pub fn paper_accuracy(max_reads: usize) -> f64 {
    match max_reads {
        12_500 => 0.997,
        _ => 0.998,
    }
}

/// Table IV: per-instance cycle and switch counts, constructive vs
/// published.
pub fn table4() -> String {
    let mut s = String::new();
    s.push_str("Table IV — single-crossbar WF instance costs\n");
    s.push_str(&format!(
        "{:<28} {:>14} {:>14} {:>12} {:>14}\n",
        "", "MAGIC cycles", "MAGIC switches", "write cycles", "write switches"
    ));
    for (name, cost) in [
        ("linear WF (paper)", linear_instance_cost(CostSource::PaperTable4)),
        ("linear WF (constructive)", linear_instance_cost(CostSource::Constructive)),
        ("affine WF (paper)", affine_instance_cost(CostSource::PaperTable4)),
        ("affine WF (constructive)", affine_instance_cost(CostSource::Constructive)),
    ] {
        s.push_str(&format!(
            "{:<28} {:>14} {:>14} {:>12} {:>14}\n",
            name, cost.magic_cycles, cost.magic_switches, cost.write_cycles, cost.write_switches
        ));
    }
    s
}

/// Fig. 8: throughput vs accuracy scatter (reads/s, fraction).
pub fn fig8() -> String {
    let mut s = String::new();
    s.push_str("Fig. 8 — throughput vs accuracy\n");
    s.push_str(&format!("{:<28} {:>16} {:>10}\n", "system", "reads/s", "accuracy"));
    for sys in published_systems() {
        let row = format!("{:<28} {:>16.0} {:>10.3}\n", sys.name, sys.throughput(), sys.accuracy);
        s.push_str(&row);
    }
    for (m, r) in dartpim_model_reports() {
        s.push_str(&format!(
            "{:<28} {:>16.0} {:>10.3}\n",
            format!("DART-PIM (model, {}k)", m / 1000),
            r.throughput(),
            paper_accuracy(m)
        ));
    }
    s
}

/// Fig. 9: throughput / energy efficiency / area efficiency.
pub fn fig9() -> String {
    let mut s = String::new();
    s.push_str("Fig. 9 — throughput, energy efficiency, area efficiency (389M reads)\n");
    s.push_str(&format!(
        "{:<28} {:>14} {:>14} {:>18}\n",
        "system", "reads/s", "reads/J", "reads/(s*mm^2)"
    ));
    for sys in published_systems() {
        s.push_str(&format!(
            "{:<28} {:>14.0} {:>14.1} {:>18.1}\n",
            sys.name,
            sys.throughput(),
            sys.reads_per_joule(),
            sys.area_efficiency()
        ));
    }
    for (m, paper) in paper_dartpim_rows() {
        s.push_str(&format!(
            "{:<28} {:>14.0} {:>14.1} {:>18.1}\n",
            paper.name,
            paper.throughput(),
            paper.reads_per_joule(),
            paper.area_efficiency()
        ));
        let _ = m;
    }
    for (m, r) in dartpim_model_reports() {
        s.push_str(&format!(
            "{:<28} {:>14.0} {:>14.1} {:>18.1}\n",
            format!("DART-PIM (model, {}k)", m / 1000),
            r.throughput(),
            r.energy_efficiency(),
            r.area_efficiency()
        ));
    }
    s
}

/// Fig. 10a: execution-time breakdown across maxReads.
pub fn fig10a() -> String {
    let mut s = String::new();
    s.push_str("Fig. 10a — execution time breakdown (s), 389M reads\n");
    s.push_str(&format!(
        "{:<12} {:>10} {:>10} {:>10} {:>10}  (paper total)\n",
        "maxReads", "DP-mem", "RISC-V", "readout", "total"
    ));
    let paper = [(12_500usize, 43.8), (25_000, 87.2), (50_000, 174.0)];
    for ((m, r), (_, paper_t)) in dartpim_model_reports().into_iter().zip(paper) {
        s.push_str(&format!(
            "{:<12} {:>10.1} {:>10.1} {:>10.1} {:>10.1}  ({:.1})\n",
            m, r.t_dpmem_s, r.t_riscv_s, r.t_readout_s, r.exec_time_s, paper_t
        ));
    }
    s
}

/// Fig. 10b: energy breakdown across maxReads.
pub fn fig10b() -> String {
    let mut s = String::new();
    s.push_str("Fig. 10b — energy breakdown (kJ), 389M reads\n");
    s.push_str(&format!(
        "{:<10} {:>10} {:>10} {:>10} {:>8} {:>10} {:>8} {:>9}\n",
        "maxReads", "crossbars", "ctrl", "periph", "riscv", "transfer", "total", "avg W"
    ));
    for (m, r) in dartpim_model_reports() {
        let e = &r.energy;
        s.push_str(&format!(
            "{:<10} {:>10.1} {:>10.1} {:>10.1} {:>8.2} {:>10.3} {:>8.1} {:>9.0}\n",
            m,
            e.crossbars / 1e3,
            e.controllers / 1e3,
            e.peripherals / 1e3,
            e.riscv / 1e3,
            (e.transfer_in + e.transfer_out) / 1e3,
            e.total() / 1e3,
            r.avg_power_w()
        ));
    }
    s
}

/// Fig. 10c: area breakdown.
pub fn fig10c() -> String {
    let a: AreaBreakdown = AreaModel::default().breakdown(&DartPimConfig::default());
    let mut s = String::new();
    s.push_str("Fig. 10c — area breakdown (mm²)\n");
    s.push_str(&format!(
        "crossbars {:.0}  controllers {:.1}  peripherals {:.1}  riscv {:.1}  \
         total {:.0} (paper: 8170)\n",
        a.crossbars,
        a.controllers,
        a.peripherals,
        a.riscv,
        a.total()
    ));
    let share = 100.0 * a.crossbars / a.total();
    s.push_str(&format!("crossbar share: {share:.1}% (paper: 96.9%)\n"));
    s
}

/// Headline comparison (abstract): speedups/energy vs Parabricks & SeGraM.
pub fn headline() -> String {
    let reports = dartpim_model_reports();
    let (_, r25) = reports.iter().find(|(m, _)| *m == 25_000).unwrap();
    let systems = published_systems();
    let by = |n: &str| systems.iter().find(|s| s.name.starts_with(n)).unwrap();
    let mut s = String::new();
    s.push_str("Headline (maxReads=25k, model vs paper-reported baselines):\n");
    for name in ["Parabricks", "SeGraM", "minimap2", "GenASM", "GenVoM"] {
        let sys = by(name);
        s.push_str(&format!(
            "  vs {:<12} throughput {:>7.1}x   energy {:>7.1}x\n",
            name,
            r25.throughput() / sys.throughput(),
            r25.energy_efficiency() / sys.reads_per_joule(),
        ));
    }
    s.push_str("  (paper: 5.7x / 257x throughput vs Parabricks / SeGraM; 92x / 27x energy)\n");
    s.push_str(&format!(
        "  model: {:.1} Mreads/s, {:.1} s, {:.1} kJ, {:.0} W\n",
        r25.throughput() / 1e6,
        DATASET_READS as f64 / r25.throughput(),
        r25.energy.total() / 1e3,
        r25.avg_power_w()
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        for t in [table4(), fig8(), fig9(), fig10a(), fig10b(), fig10c(), headline()] {
            assert!(t.len() > 50, "table too short:\n{t}");
        }
    }

    #[test]
    fn table4_contains_published_numbers() {
        let t = table4();
        assert!(t.contains("254585") || t.contains("254,585") || t.contains("254585"));
        assert!(t.contains("1288281"));
    }

    #[test]
    fn fig9_has_all_systems() {
        let t = fig9();
        let names =
            ["minimap2", "Parabricks", "GenASM", "SeGraM", "GenVoM", "DART-PIM (model, 25k)"];
        for name in names {
            assert!(t.contains(name), "missing {name} in:\n{t}");
        }
    }

    #[test]
    fn headline_speedup_in_paper_range() {
        let reports = dartpim_model_reports();
        let (_, r) = reports.iter().find(|(m, _)| *m == 25_000).unwrap();
        let systems = published_systems();
        let parabricks = systems.iter().find(|s| s.name.starts_with("Parabricks")).unwrap();
        let speedup = r.throughput() / parabricks.throughput();
        // paper: 5.7x; our Eq. 6 model lands ~11% high (no scheduling
        // overhead term) — assert the shape holds
        assert!((4.5..=8.0).contains(&speedup), "speedup = {speedup}");
        let segram = systems.iter().find(|s| s.name.starts_with("SeGraM")).unwrap();
        let speedup = r.throughput() / segram.throughput();
        assert!((200.0..=350.0).contains(&speedup), "SeGraM speedup = {speedup}");
    }

    #[test]
    fn fig10a_scales_linearly_with_max_reads() {
        let reports = dartpim_model_reports();
        let t = |m: usize| reports.iter().find(|(mm, _)| *mm == m).unwrap().1.exec_time_s;
        let ratio = t(50_000) / t(12_500);
        assert!((3.5..=4.5).contains(&ratio), "ratio = {ratio}");
    }
}
