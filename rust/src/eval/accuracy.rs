//! Accuracy evaluation (paper §VII-A).
//!
//! The paper measures the fraction of DART-PIM mappings that exactly
//! match BWA-MEM's. Our oracle is the exhaustive CPU mapper
//! ([`crate::baselines::CpuMapper`]); we additionally report agreement
//! with the simulated read origins (possible because our reads are
//! synthetic), which the paper could not measure directly.

use crate::baselines::CpuMapper;
use crate::coordinator::FinalMapping;
use crate::genome::ReadRecord;
use crate::index::MinimizerIndex;

/// Accuracy summary.
#[derive(Debug, Clone)]
pub struct AccuracyReport {
    /// Reads evaluated.
    pub n_reads: usize,
    /// Reads the pipeline mapped.
    pub mapped: usize,
    /// Agreement with the oracle mapper's position (exact).
    pub oracle_exact: usize,
    /// Agreement with the oracle within +-tolerance.
    pub oracle_near: usize,
    /// Oracle itself produced a mapping.
    pub oracle_mapped: usize,
    /// Agreement with the simulated origin within +-tolerance.
    pub truth_near: usize,
    /// Position tolerance used for the "near" counts.
    pub tolerance: i64,
}

impl AccuracyReport {
    /// The paper's §VII-A metric: fraction of our mappings that match
    /// the oracle (over reads where both mapped).
    pub fn accuracy_vs_oracle(&self) -> f64 {
        if self.oracle_mapped == 0 {
            return 0.0;
        }
        self.oracle_near as f64 / self.oracle_mapped as f64
    }

    /// Fraction of all reads mapped within tolerance of their origin.
    pub fn accuracy_vs_truth(&self) -> f64 {
        if self.n_reads == 0 {
            return 0.0;
        }
        self.truth_near as f64 / self.n_reads as f64
    }
}

/// Compare pipeline mappings against the oracle and the simulated truth.
pub fn evaluate_accuracy(
    index: &MinimizerIndex,
    reads: &[ReadRecord],
    mappings: &[Option<FinalMapping>],
    tolerance: i64,
) -> AccuracyReport {
    assert_eq!(reads.len(), mappings.len());
    let oracle = CpuMapper::new(index);
    let mut r = AccuracyReport {
        n_reads: reads.len(),
        mapped: 0,
        oracle_exact: 0,
        oracle_near: 0,
        oracle_mapped: 0,
        truth_near: 0,
        tolerance,
    };
    for read in reads {
        let ours = &mappings[read.id as usize];
        let oracle_m = oracle.map(&read.seq);
        if let Some(o) = &oracle_m {
            r.oracle_mapped += 1;
            if let Some(m) = ours {
                if m.pos == o.pos {
                    r.oracle_exact += 1;
                }
                if (m.pos - o.pos).abs() <= tolerance {
                    r.oracle_near += 1;
                }
            }
        }
        if let Some(m) = ours {
            r.mapped += 1;
            if (m.pos - read.truth_pos as i64).abs() <= tolerance {
                r.truth_near += 1;
            }
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Pipeline, PipelineConfig};
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{K, READ_LEN, W};
    use crate::pim::DartPimConfig;
    use crate::runtime::RustEngine;

    #[test]
    fn pipeline_accuracy_is_high_on_synthetic_data() {
        let g = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 50, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let cfg = PipelineConfig {
            dart: DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let mut p = Pipeline::new(&idx, cfg, RustEngine);
        let (mappings, _) = p.map_reads(&reads).unwrap();
        let rep = evaluate_accuracy(&idx, &reads, &mappings, 5);
        assert!(rep.accuracy_vs_truth() > 0.9, "vs truth: {}", rep.accuracy_vs_truth());
        assert!(rep.accuracy_vs_oracle() > 0.9, "vs oracle: {}", rep.accuracy_vs_oracle());
        assert!(rep.oracle_exact <= rep.oracle_near);
        assert!(rep.mapped <= rep.n_reads);
    }

    #[test]
    fn empty_inputs() {
        let g = SynthConfig { len: 30_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let rep = evaluate_accuracy(&idx, &[], &[], 5);
        assert_eq!(rep.accuracy_vs_truth(), 0.0);
        assert_eq!(rep.accuracy_vs_oracle(), 0.0);
    }
}
