//! Accuracy evaluation (paper §VII-A).
//!
//! The paper measures the fraction of DART-PIM mappings that exactly
//! match BWA-MEM's. Our oracle is the exhaustive CPU mapper
//! ([`crate::baselines::CpuMapper`]); we additionally report agreement
//! with the simulated read origins (possible because our reads are
//! synthetic), which the paper could not measure directly.

use crate::baselines::CpuMapper;
use crate::coordinator::FinalMapping;
use crate::genome::ReadRecord;
use crate::index::MinimizerIndex;

/// Accuracy summary.
#[derive(Debug, Clone)]
pub struct AccuracyReport {
    /// Reads evaluated.
    pub n_reads: usize,
    /// Reads the pipeline mapped.
    pub mapped: usize,
    /// Agreement with the oracle mapper's position (exact).
    pub oracle_exact: usize,
    /// Agreement with the oracle within +-tolerance.
    pub oracle_near: usize,
    /// Oracle itself produced a mapping.
    pub oracle_mapped: usize,
    /// Agreement with the simulated origin within +-tolerance.
    pub truth_near: usize,
    /// Position tolerance used for the "near" counts.
    pub tolerance: i64,
}

impl AccuracyReport {
    /// The paper's §VII-A metric: fraction of our mappings that match
    /// the oracle (over reads where both mapped).
    pub fn accuracy_vs_oracle(&self) -> f64 {
        if self.oracle_mapped == 0 {
            return 0.0;
        }
        self.oracle_near as f64 / self.oracle_mapped as f64
    }

    /// Fraction of all reads mapped within tolerance of their origin.
    pub fn accuracy_vs_truth(&self) -> f64 {
        if self.n_reads == 0 {
            return 0.0;
        }
        self.truth_near as f64 / self.n_reads as f64
    }
}

/// Pair-aware accuracy summary (paired-end runs).
///
/// A *mate* is correct when its mapped position is within tolerance of
/// its simulated origin; a *pair* is correct when both mates are. The
/// interesting comparison is `mate_accuracy()` against the same metric
/// of a single-end run over the same records: proper-pair arbitration
/// disambiguates repeat-placed reads, so the paired number should
/// dominate (held by `tests/pair_parity.rs`).
#[derive(Debug, Clone)]
pub struct PairAccuracyReport {
    /// Read pairs evaluated.
    pub n_pairs: usize,
    /// Individual mates evaluated (`2 * n_pairs`).
    pub n_reads: usize,
    /// Mates the pipeline mapped.
    pub mate_mapped: usize,
    /// Mates mapped within tolerance of their simulated origin.
    pub mate_correct: usize,
    /// Pairs with both mates mapped.
    pub both_mapped: usize,
    /// Pairs with both mates within tolerance of their origins.
    pub pair_correct: usize,
    /// Mates whose decision was a proper-pair resolution.
    pub proper_mates: usize,
    /// Mates recovered by the rescue scan.
    pub rescued_mates: usize,
    /// Position tolerance used.
    pub tolerance: i64,
}

impl PairAccuracyReport {
    /// Fraction of pairs fully recovered (both mates near truth).
    pub fn pair_recall(&self) -> f64 {
        if self.n_pairs == 0 {
            return 0.0;
        }
        self.pair_correct as f64 / self.n_pairs as f64
    }

    /// Fraction of all mates mapped near their origin (directly
    /// comparable to [`AccuracyReport::accuracy_vs_truth`]).
    pub fn mate_accuracy(&self) -> f64 {
        if self.n_reads == 0 {
            return 0.0;
        }
        self.mate_correct as f64 / self.n_reads as f64
    }

    /// Fraction of *mapped* mates that are near their origin (mapping
    /// precision; wrong placements dilute it).
    pub fn mate_precision(&self) -> f64 {
        if self.mate_mapped == 0 {
            return 0.0;
        }
        self.mate_correct as f64 / self.mate_mapped as f64
    }
}

/// Score a paired run against the simulated ground truth. `reads` must
/// be the paired layout (R1 at even ids, R2 at odd ids) and `mappings`
/// the matching decision vector.
pub fn evaluate_pair_accuracy(
    reads: &[ReadRecord],
    mappings: &[Option<FinalMapping>],
    tolerance: i64,
) -> PairAccuracyReport {
    assert_eq!(reads.len(), mappings.len());
    assert_eq!(reads.len() % 2, 0, "paired evaluation needs complete pairs");
    let mut r = PairAccuracyReport {
        n_pairs: reads.len() / 2,
        n_reads: reads.len(),
        mate_mapped: 0,
        mate_correct: 0,
        both_mapped: 0,
        pair_correct: 0,
        proper_mates: 0,
        rescued_mates: 0,
        tolerance,
    };
    let near = |read: &ReadRecord| -> (bool, bool) {
        match &mappings[read.id as usize] {
            None => (false, false),
            Some(m) => (true, (m.pos - read.truth_pos as i64).abs() <= tolerance),
        }
    };
    for pair in reads.chunks_exact(2) {
        let (m1, ok1) = near(&pair[0]);
        let (m2, ok2) = near(&pair[1]);
        r.mate_mapped += usize::from(m1) + usize::from(m2);
        r.mate_correct += usize::from(ok1) + usize::from(ok2);
        if m1 && m2 {
            r.both_mapped += 1;
        }
        if ok1 && ok2 {
            r.pair_correct += 1;
        }
        for read in pair {
            if let Some(m) = &mappings[read.id as usize] {
                match m.pair {
                    crate::coordinator::PairStatus::Proper => r.proper_mates += 1,
                    crate::coordinator::PairStatus::Rescued => r.rescued_mates += 1,
                    crate::coordinator::PairStatus::Unpaired
                    | crate::coordinator::PairStatus::Single => {}
                }
            }
        }
    }
    r
}

/// Compare pipeline mappings against the oracle and the simulated truth.
pub fn evaluate_accuracy(
    index: &MinimizerIndex,
    reads: &[ReadRecord],
    mappings: &[Option<FinalMapping>],
    tolerance: i64,
) -> AccuracyReport {
    assert_eq!(reads.len(), mappings.len());
    let oracle = CpuMapper::new(index);
    let mut r = AccuracyReport {
        n_reads: reads.len(),
        mapped: 0,
        oracle_exact: 0,
        oracle_near: 0,
        oracle_mapped: 0,
        truth_near: 0,
        tolerance,
    };
    for read in reads {
        let ours = &mappings[read.id as usize];
        let oracle_m = oracle.map(&read.seq);
        if let Some(o) = &oracle_m {
            r.oracle_mapped += 1;
            if let Some(m) = ours {
                if m.pos == o.pos {
                    r.oracle_exact += 1;
                }
                if (m.pos - o.pos).abs() <= tolerance {
                    r.oracle_near += 1;
                }
            }
        }
        if let Some(m) = ours {
            r.mapped += 1;
            if (m.pos - read.truth_pos as i64).abs() <= tolerance {
                r.truth_near += 1;
            }
        }
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Pipeline, PipelineConfig};
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{K, READ_LEN, W};
    use crate::pim::DartPimConfig;
    use crate::runtime::RustEngine;

    #[test]
    fn pipeline_accuracy_is_high_on_synthetic_data() {
        let g = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 50, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let cfg = PipelineConfig {
            dart: DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let mut p = Pipeline::new(&idx, cfg, RustEngine);
        let (mappings, _) = p.map_reads(&reads).unwrap();
        let rep = evaluate_accuracy(&idx, &reads, &mappings, 5);
        assert!(rep.accuracy_vs_truth() > 0.9, "vs truth: {}", rep.accuracy_vs_truth());
        assert!(rep.accuracy_vs_oracle() > 0.9, "vs oracle: {}", rep.accuracy_vs_oracle());
        assert!(rep.oracle_exact <= rep.oracle_near);
        assert!(rep.mapped <= rep.n_reads);
    }

    #[test]
    fn paired_accuracy_beats_or_matches_single_end_on_paired_reads() {
        use crate::coordinator::PairingConfig;
        use crate::genome::synth::PairSimConfig;
        let g = SynthConfig { len: 100_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = PairSimConfig { n_pairs: 40, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let run = |pairing: Option<PairingConfig>| {
            let cfg = PipelineConfig {
                dart: DartPimConfig { low_th: 0, ..Default::default() },
                handle_revcomp: true,
                pairing,
                ..Default::default()
            };
            Pipeline::new(&idx, cfg, RustEngine).map_reads(&reads).unwrap().0
        };
        let paired = run(Some(PairingConfig::default()));
        let single = run(None);
        let pr = evaluate_pair_accuracy(&reads, &paired, 5);
        let sr = evaluate_pair_accuracy(&reads, &single, 5);
        assert_eq!(pr.n_pairs, 40);
        assert!(pr.pair_recall() > 0.85, "pair recall {}", pr.pair_recall());
        assert!(
            pr.mate_accuracy() >= sr.mate_accuracy(),
            "pairing must not lose accuracy: paired {} vs single {}",
            pr.mate_accuracy(),
            sr.mate_accuracy()
        );
        assert!(pr.proper_mates > 0);
        assert!(pr.mate_precision() >= pr.mate_accuracy());
    }

    #[test]
    fn empty_inputs() {
        let g = SynthConfig { len: 30_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let rep = evaluate_accuracy(&idx, &[], &[], 5);
        assert_eq!(rep.accuracy_vs_truth(), 0.0);
        assert_eq!(rep.accuracy_vs_oracle(), 0.0);
    }
}
