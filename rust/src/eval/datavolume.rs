//! §II motivation study: the seeding stage's data-volume blowup that
//! justifies processing-in-memory.
//!
//! Paper numbers (human, 389 M x 150 bp reads = 14.6 GB): seeding emits
//! ~1000 PLs/read x 32 bits = 1556 GB — roughly 100x the input — which
//! would all cross the memory bus in a CPU/GPU mapper. DART-PIM never
//! materializes it. We compute the same quantities for any workload.

use crate::index::MinimizerIndex;
use crate::seeding::seeder::all_seed_hits;

/// Data-volume summary for a workload.
#[derive(Debug, Clone)]
pub struct DataVolume {
    /// Reads in the workload.
    pub n_reads: u64,
    /// Read length in bases.
    pub read_len: usize,
    /// Raw read payload (2 bits/base packed -> bytes).
    pub input_bytes: u64,
    /// Total PLs produced by seeding.
    pub total_pls: u64,
    /// PL payload at 32 bits each.
    pub pl_bytes: u64,
    /// Reference-segment traffic a non-PIM mapper would move (one
    /// segment fetch per PL, 2 bits/base).
    pub segment_bytes: u64,
}

impl DataVolume {
    /// Mean potential locations per read.
    pub fn pls_per_read(&self) -> f64 {
        self.total_pls as f64 / self.n_reads.max(1) as f64
    }

    /// The headline blowup: seeding output vs read input (the paper's
    /// §II "~100x larger" counts the PL payload; segment traffic comes
    /// on top and is reported separately).
    pub fn blowup(&self) -> f64 {
        self.pl_bytes as f64 / self.input_bytes.max(1) as f64
    }
}

/// Measure seeding data volumes over a sample of reads.
pub fn measure(index: &MinimizerIndex, reads: &[crate::genome::ReadRecord]) -> DataVolume {
    let mut total_pls = 0u64;
    for r in reads {
        total_pls += all_seed_hits(index, &r.seq).len() as u64;
    }
    let n_reads = reads.len() as u64;
    let read_len = index.read_len;
    DataVolume {
        n_reads,
        read_len,
        input_bytes: n_reads * (read_len as u64) / 4,
        total_pls,
        pl_bytes: total_pls * 4,
        segment_bytes: total_pls * (index.seg_len() as u64) / 4,
    }
}

/// The paper's own §II numbers for reference.
pub fn paper_volume() -> DataVolume {
    DataVolume {
        n_reads: 389_000_000,
        read_len: 150,
        input_bytes: 14_600_000_000,
        total_pls: 389_000_000 * 1000,
        pl_bytes: 389_000_000 * 1000 * 4,
        segment_bytes: 389_000_000 * 1000 * 75, // 300 bp @ 2 bits
    }
}

/// Render the motivation table.
pub fn render(v: &DataVolume, label: &str) -> String {
    format!(
        "{label}: reads={} ({:.2} GB in), PLs/read={:.0}, PL data={:.2} GB, \
         segment traffic={:.2} GB, blowup={:.0}x\n",
        v.n_reads,
        v.input_bytes as f64 / 1e9,
        v.pls_per_read(),
        v.pl_bytes as f64 / 1e9,
        v.segment_bytes as f64 / 1e9,
        v.blowup()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{K, READ_LEN, W};

    #[test]
    fn paper_blowup_is_about_100x() {
        let v = paper_volume();
        assert!((80.0..=130.0).contains(&v.blowup()), "blowup = {}", v.blowup());
        assert!((v.pl_bytes as f64 / 1e9 - 1556.0).abs() / 1556.0 < 0.01);
    }

    #[test]
    fn measured_volumes_consistent() {
        let g = SynthConfig { len: 60_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 30, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let v = measure(&idx, &reads);
        assert_eq!(v.n_reads, 30);
        assert!(v.total_pls > 0);
        // PL-count blowup is a repeat-density effect that only shows at
        // genome scale; segment *traffic* amplifies at any scale.
        assert!(
            v.segment_bytes > v.input_bytes,
            "segment traffic must exceed input: {} vs {}",
            v.segment_bytes,
            v.input_bytes
        );
        assert!(render(&v, "synthetic").contains("blowup"));
    }
}
