//! Evaluation harness: regenerates every table and figure of the paper's
//! §VII (see DESIGN.md §7 for the experiment index).
//!
//! * [`accuracy`]   — §VII-A: agreement with the ground-truth mapper and
//!                    with the simulated read origins.
//! * [`figures`]    — text/CSV renderings of Fig. 8 (throughput vs
//!                    accuracy), Fig. 9 (throughput / energy / area
//!                    efficiency), Fig. 10 (breakdowns), Table IV.
//! * [`datavolume`] — §II's motivation numbers (PLs per read, the ~100x
//!                    seeding data blowup).

pub mod accuracy;
pub mod datavolume;
pub mod figures;

pub use accuracy::{evaluate_accuracy, evaluate_pair_accuracy, AccuracyReport, PairAccuracyReport};
