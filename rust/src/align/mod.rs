//! Alignment algorithms.
//!
//! * [`banded_linear`] / [`banded_affine`] — exact Rust mirrors of the L1
//!   Pallas kernels (same band anchoring, pads, saturation, direction
//!   tie-breaks). They serve as the pure-Rust engine, the oracle for the
//!   XLA engine parity tests, and the RISC-V-offload compute path.
//! * [`full_dp`] — unbanded reference algorithms (Wagner-Fischer edit
//!   distance, Gotoh affine semi-global) used by the ground-truth mapper
//!   and by property tests (band == full DP when the distance is small).
//! * [`traceback`] / [`cigar`] — alignment reconstruction from the packed
//!   4-bit direction codes the affine kernel emits.
//!
//! Base codes >= 4 (N) never match anything, including another N.
//! Simulated reads are N-free; windows may carry N padding at reference
//! boundaries.

pub mod banded_affine;
pub mod banded_linear;
pub mod cigar;
pub mod full_dp;
pub mod traceback;

pub use banded_affine::affine_wf_band;
pub use banded_linear::{best_of_band, linear_wf_band};
pub use cigar::Cigar;
pub use traceback::{script_cost, traceback, EditOp};
