//! Traceback over the packed 4-bit direction codes emitted by the affine
//! kernel / [`super::banded_affine`], reconstructing the optimal edit
//! script (paper §III-B: "the optimal sequence alignment can be inferred
//! without having to save the entire matrix").
//!
//! Mirrors `python/compile/kernels/ref.py::traceback` exactly.

use crate::params::{BAND, ETH, W_EX, W_OP, W_SUB};

use super::banded_affine::{D_M1, D_M2, D_MATCH, D_SUB};

/// One alignment operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EditOp {
    /// Read base equals reference base.
    Match,
    /// Substitution.
    Sub,
    /// Insertion: read base with a gap in the reference.
    Ins,
    /// Deletion: reference base skipped by the read.
    Del,
}

/// Traceback failure modes. A valid, unsaturated alignment never fails;
/// failures indicate a saturated path (caller should not have asked) or
/// corrupted direction data (e.g. a runtime mismatch).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TracebackError {
    /// The walk left the band at row `i`, band coordinate `j`.
    EscapedBand {
        /// Row (read position) where the walk escaped.
        i: usize,
        /// Band coordinate at the escape point.
        j: i64,
    },
    /// The walk reached row 0 while still inside a gap layer.
    EndedInGap,
    /// The walk exceeded the maximum possible number of steps.
    NotTerminating,
}

impl std::fmt::Display for TracebackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TracebackError::EscapedBand { i, j } => {
                write!(f, "traceback escaped the band at i={i}, j={j}")
            }
            TracebackError::EndedInGap => write!(f, "traceback ended inside a gap matrix"),
            TracebackError::NotTerminating => write!(f, "traceback did not terminate"),
        }
    }
}

impl std::error::Error for TracebackError {}

/// Reconstructed alignment.
#[derive(Debug, Clone)]
pub struct Alignment {
    /// Ops from the start of the read.
    pub ops: Vec<EditOp>,
    /// Band coordinate at row 0 == window offset where the alignment
    /// begins (anchoring charge |j_end - eth| applies).
    pub j_end: usize,
}

impl Alignment {
    /// Refined mapping position given the PL this window was built for:
    /// `pl + (j_end - eth)`.
    pub fn refined_pos(&self, pl: i64) -> i64 {
        pl + self.j_end as i64 - ETH as i64
    }
}

/// Walk the packed directions from DP cell `(n, n + j_start)` in matrix D
/// back to row 0. `dirs` is row-major `(n, BAND)`.
pub fn traceback(dirs: &[u8], n: usize, j_start: usize) -> Result<Alignment, TracebackError> {
    assert_eq!(dirs.len(), n * BAND, "dirs shape mismatch");
    let mut i = n;
    let mut j = j_start as i64;
    #[derive(PartialEq)]
    enum Mat {
        D,
        M1,
        M2,
    }
    let mut mat = Mat::D;
    let mut ops = Vec::with_capacity(n + 8);
    let limit = 4 * (n + BAND) + 16;
    let mut steps = 0;
    while i > 0 {
        steps += 1;
        if steps > limit {
            return Err(TracebackError::NotTerminating);
        }
        if !(0..BAND as i64).contains(&j) {
            return Err(TracebackError::EscapedBand { i, j });
        }
        let bits = dirs[(i - 1) * BAND + j as usize];
        match mat {
            Mat::D => match bits & 3 {
                D_MATCH => {
                    ops.push(EditOp::Match);
                    i -= 1;
                }
                D_SUB => {
                    ops.push(EditOp::Sub);
                    i -= 1;
                }
                D_M1 => mat = Mat::M1,
                D_M2 => mat = Mat::M2,
                _ => unreachable!(),
            },
            Mat::M1 => {
                ops.push(EditOp::Ins);
                let ext = (bits >> 2) & 1;
                i -= 1;
                j += 1;
                if ext == 0 {
                    mat = Mat::D;
                }
            }
            Mat::M2 => {
                ops.push(EditOp::Del);
                let ext = (bits >> 3) & 1;
                j -= 1;
                if ext == 0 {
                    mat = Mat::D;
                }
            }
        }
    }
    if mat != Mat::D {
        return Err(TracebackError::EndedInGap);
    }
    ops.reverse();
    Ok(Alignment { ops, j_end: j as usize })
}

/// Affine cost of an edit script plus the anchoring charge — must equal
/// the band distance for unsaturated alignments.
pub fn script_cost(ops: &[EditOp], j_end: usize) -> i32 {
    let mut cost = (j_end as i32 - ETH as i32).abs();
    let mut i = 0;
    while i < ops.len() {
        match ops[i] {
            EditOp::Match => i += 1,
            EditOp::Sub => {
                cost += W_SUB;
                i += 1;
            }
            gap @ (EditOp::Ins | EditOp::Del) => {
                let mut run = 0;
                while i < ops.len() && ops[i] == gap {
                    run += 1;
                    i += 1;
                }
                cost += W_OP + run * W_EX;
            }
        }
    }
    cost
}

/// Check structural consistency: applying the script to the window must
/// re-derive the read at every Match position and consume exactly
/// `read.len()` read bases. Returns false on any inconsistency.
pub fn script_consistent(ops: &[EditOp], j_end: usize, read: &[u8], win: &[u8]) -> bool {
    let mut c = j_end; // window cursor
    let mut r = 0usize; // read cursor
    for &op in ops {
        match op {
            EditOp::Match => {
                if c >= win.len() || r >= read.len() || read[r] != win[c] {
                    return false;
                }
                c += 1;
                r += 1;
            }
            EditOp::Sub => {
                if c >= win.len() || r >= read.len() || read[r] == win[c] {
                    return false;
                }
                c += 1;
                r += 1;
            }
            EditOp::Ins => {
                if r >= read.len() {
                    return false;
                }
                r += 1;
            }
            EditOp::Del => {
                if c >= win.len() {
                    return false;
                }
                c += 1;
            }
        }
    }
    r == read.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::banded_affine::affine_wf_band;
    use crate::align::banded_linear::best_of_band;
    use crate::params::{window_len, SAT_AFFINE};

    use crate::util::SmallRng;

    fn planted(
        rng: &mut SmallRng,
        n: usize,
        subs: usize,
        dels: usize,
        inss: usize,
    ) -> (Vec<u8>, Vec<u8>) {
        let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..4)).collect();
        let mut seq = read.clone();
        for _ in 0..dels {
            let p = rng.gen_range(0..seq.len());
            seq.remove(p);
        }
        for _ in 0..inss {
            let p = rng.gen_range(0..=seq.len());
            seq.insert(p, rng.gen_range(0..4));
        }
        for _ in 0..subs {
            let p = rng.gen_range(0..seq.len());
            seq[p] = (seq[p] + rng.gen_range(1..4u8)) % 4;
        }
        let m = window_len(n);
        let shift = rng.gen_range(0..BAND);
        let mut win: Vec<u8> = (0..m).map(|_| rng.gen_range(0..4)).collect();
        let take = seq.len().min(m - shift);
        win[shift..shift + take].copy_from_slice(&seq[..take]);
        (read, win)
    }

    #[test]
    fn cost_identity_and_consistency() {
        let mut rng = SmallRng::seed_from_u64(20);
        let mut checked = 0;
        for _ in 0..300 {
            let subs = rng.gen_range(0..4);
            let dels = rng.gen_range(0..3);
            let inss = rng.gen_range(0..3);
            let (read, win) = planted(&mut rng, 40, subs, dels, inss);
            let res = affine_wf_band(&read, &win);
            let (dist, j) = best_of_band(&res.band);
            if dist >= SAT_AFFINE {
                continue;
            }
            let aln = traceback(&res.dirs, read.len(), j).expect("unsaturated path");
            assert_eq!(script_cost(&aln.ops, aln.j_end), dist, "cost identity");
            assert!(script_consistent(&aln.ops, aln.j_end, &read, &win));
            checked += 1;
        }
        assert!(checked > 100, "too few unsaturated cases: {checked}");
    }

    #[test]
    fn refined_position() {
        let aln = Alignment { ops: vec![], j_end: ETH + 2 };
        assert_eq!(aln.refined_pos(1000), 1002);
        let aln = Alignment { ops: vec![], j_end: ETH - 1 };
        assert_eq!(aln.refined_pos(1000), 999);
    }

    #[test]
    fn exact_alignment_is_all_matches() {
        let mut rng = SmallRng::seed_from_u64(21);
        let (read, win) = planted(&mut rng, 30, 0, 0, 0);
        let res = affine_wf_band(&read, &win);
        let (dist, j) = best_of_band(&res.band);
        let aln = traceback(&res.dirs, read.len(), j).unwrap();
        assert_eq!(dist, script_cost(&aln.ops, aln.j_end));
        assert_eq!(
            aln.ops.iter().filter(|&&o| o == EditOp::Match).count(),
            30 - aln.ops.iter().filter(|&&o| o != EditOp::Match && o != EditOp::Del).count()
        );
    }

    #[test]
    fn corrupt_dirs_fail_gracefully() {
        // All-Ins directions march j out of the band or never terminate;
        // must return an error, not panic or loop.
        let n = 10;
        let dirs = vec![(D_M1 | 0b0100) as u8; n * BAND]; // M1, always extend
        let r = traceback(&dirs, n, ETH);
        assert!(r.is_err() || r.is_ok()); // no panic; typically escapes band
        let dirs = vec![(D_M2 | 0b1000) as u8; n * BAND]; // M2, always extend
        assert!(traceback(&dirs, n, ETH).is_err());
    }
}
