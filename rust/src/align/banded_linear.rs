//! Banded linear Wagner-Fischer (the pre-alignment filter), mirroring
//! `python/compile/kernels/linear_wf.py` / `ref.linear_wf_band` exactly.
//!
//! Band coordinate `j in [0, 2*eth]` maps DP cell `(i, c)` with
//! `c = i + j`; the window has length `read_len + 2*eth`; the read is
//! anchored at window offset `eth` (init `|j - eth|`); values saturate at
//! `eth + 1` at end-of-row.

use crate::params::{BAND, BIG, ETH, SAT_LINEAR, window_len};

/// Compute the final band row for one (read, window) pair.
///
/// Panics if `win.len() != read.len() + 2*eth`.
pub fn linear_wf_band(read: &[u8], win: &[u8]) -> [i32; BAND] {
    assert_eq!(win.len(), window_len(read.len()), "bad window length");
    let mut wfd = init_band();
    let mut raw = [0i32; BAND];
    for (i, &r) in read.iter().enumerate() {
        // fixed-length view lets the compiler elide bounds checks (§Perf)
        let g: &[u8; BAND] = win[i..i + BAND].try_into().expect("window geometry");
        let mut left = BIG;
        let mut all_sat = true;
        for j in 0..BAND {
            let mm = i32::from(r != g[j] || r >= 4);
            let top = if j < BAND - 1 { wfd[j + 1] } else { SAT_LINEAR } + 1;
            let diag = wfd[j] + mm;
            raw[j] = diag.min(top).min(left + 1);
            left = raw[j];
            all_sat &= raw[j] >= SAT_LINEAR;
        }
        for j in 0..BAND {
            wfd[j] = raw[j].min(SAT_LINEAR);
        }
        // All-saturated is a fixed point of the recurrence (every
        // successor is min(sat+mm, sat+1, ·) >= sat), so the remaining
        // rows cannot change the output — early exit (§Perf opt 2). The
        // final band is all-SAT either way, so outputs are identical to
        // the full computation (and to the XLA kernel, which has no
        // data-dependent control flow).
        if all_sat {
            return [SAT_LINEAR; BAND];
        }
    }
    wfd
}

/// The anchored initial band row `|j - eth|`.
pub fn init_band() -> [i32; BAND] {
    let mut b = [0i32; BAND];
    for (j, v) in b.iter_mut().enumerate() {
        *v = (j as i32 - ETH as i32).abs();
    }
    b
}

/// Best distance in a band row with the deterministic tie-break
/// (distance, |j - eth|, j) — identical to the L2 `best_of_band`
/// epilogue's key encoding.
pub fn best_of_band(band: &[i32; BAND]) -> (i32, usize) {
    let mut best_key = i32::MAX;
    let mut best = (0i32, 0usize);
    for (j, &d) in band.iter().enumerate() {
        let key = d * 1024 + (j as i32 - ETH as i32).abs() * 16 + j as i32;
        if key < best_key {
            best_key = key;
            best = (d, j);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::encode_seq;

    use crate::util::SmallRng;

    fn rand_pair(rng: &mut SmallRng, n: usize) -> (Vec<u8>, Vec<u8>) {
        let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..4)).collect();
        let win: Vec<u8> = (0..window_len(n)).map(|_| rng.gen_range(0..4)).collect();
        (read, win)
    }

    /// Planted window: read at `shift` with `subs` substitutions.
    pub(crate) fn planted(
        rng: &mut SmallRng,
        n: usize,
        shift: usize,
        subs: usize,
    ) -> (Vec<u8>, Vec<u8>) {
        let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..4)).collect();
        let mut win: Vec<u8> = (0..window_len(n)).map(|_| rng.gen_range(0..4)).collect();
        win[shift..shift + n].copy_from_slice(&read);
        for _ in 0..subs {
            let p = rng.gen_range(shift..shift + n);
            win[p] = (win[p] + rng.gen_range(1..4u8)) % 4;
        }
        (read, win)
    }

    #[test]
    fn exact_match_is_zero_at_center() {
        let mut rng = SmallRng::seed_from_u64(1);
        let (read, win) = planted(&mut rng, 40, ETH, 0);
        let band = linear_wf_band(&read, &win);
        assert_eq!(band[ETH], 0);
    }

    #[test]
    fn substitutions_count() {
        let mut rng = SmallRng::seed_from_u64(2);
        for subs in 0..=4 {
            let (read, win) = planted(&mut rng, 60, ETH, subs);
            let band = linear_wf_band(&read, &win);
            // planted subs can coincide or be mimicked by chance; bound only
            assert!(band[ETH] <= subs as i32, "subs={subs} got {}", band[ETH]);
        }
    }

    #[test]
    fn shift_costs_anchor_penalty() {
        let mut rng = SmallRng::seed_from_u64(3);
        for shift in 0..BAND {
            let (read, win) = planted(&mut rng, 50, shift, 0);
            let band = linear_wf_band(&read, &win);
            assert!(band[shift] <= (shift as i32 - ETH as i32).abs());
        }
    }

    #[test]
    fn random_pairs_saturate() {
        let mut rng = SmallRng::seed_from_u64(4);
        let (read, win) = rand_pair(&mut rng, 150);
        let band = linear_wf_band(&read, &win);
        assert!(band.iter().all(|&d| d == SAT_LINEAR), "random 150bp pair must saturate");
    }

    #[test]
    fn n_bases_never_match() {
        let read = encode_seq(b"NNNN");
        let win = encode_seq(b"NNNNNNNNNNNNNNNN");
        let band = linear_wf_band(&read, &win);
        assert!(band.iter().all(|&d| d > 0));
    }

    #[test]
    fn band_values_bounded() {
        let mut rng = SmallRng::seed_from_u64(5);
        for _ in 0..20 {
            let (read, win) = rand_pair(&mut rng, 30);
            for d in linear_wf_band(&read, &win) {
                assert!((0..=SAT_LINEAR).contains(&d));
            }
        }
    }

    #[test]
    fn best_of_band_tie_breaks_match_python() {
        // mirrors python/tests/test_affine_kernel.py::test_best_of_band_tie_breaks
        let mk = |vals: [i32; BAND]| best_of_band(&vals);
        assert_eq!(mk([5, 3, 3, 9, 9, 9, 9, 9, 9, 9, 9, 9, 9]), (3, 2));
        assert_eq!(mk([9, 9, 9, 9, 9, 2, 9, 2, 9, 9, 9, 9, 9]), (2, 5));
        assert_eq!(mk([9, 9, 9, 9, 9, 9, 0, 9, 9, 9, 9, 9, 9]), (0, 6));
    }
}
