//! Unbanded reference algorithms.
//!
//! These are the "classical" comparators the paper positions WF against
//! (§III): full Wagner-Fischer edit distance, and a Gotoh-style affine
//! semi-global aligner with free flanks on the reference side. The
//! exhaustive ground-truth mapper ([`crate::baselines::cpu_mapper`])
//! aligns every PL with these, playing the role BWA-MEM plays in the
//! paper's accuracy study.

use crate::params::{BIG, W_EX, W_OP, W_SUB};

/// Plain global Wagner-Fischer edit distance (unit costs).
pub fn edit_distance(a: &[u8], b: &[u8]) -> i32 {
    let n = a.len();
    let m = b.len();
    let mut prev: Vec<i32> = (0..=m as i32).collect();
    let mut cur = vec![0i32; m + 1];
    for i in 1..=n {
        cur[0] = i as i32;
        for j in 1..=m {
            let mm = if a[i - 1] == b[j - 1] && a[i - 1] < 4 { 0 } else { W_SUB };
            cur[j] = (prev[j - 1] + mm).min(prev[j] + 1).min(cur[j - 1] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[m]
}

/// Result of a semi-global alignment of a read within a longer segment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SemiGlobalHit {
    /// Total cost (affine or linear, depending on the function).
    pub dist: i32,
    /// 0-based start column of the alignment in the segment.
    pub start: u32,
    /// 0-based end column (exclusive) in the segment.
    pub end: u32,
}

/// Semi-global *linear* alignment: the read aligns globally, the segment
/// flanks are free. Returns the minimum cost with its start/end columns
/// (leftmost on ties).
pub fn semi_global_linear(read: &[u8], seg: &[u8]) -> SemiGlobalHit {
    let n = read.len();
    let m = seg.len();
    // D[i][c] with start tracking.
    let mut prev = vec![0i32; m + 1];
    let mut prev_s: Vec<u32> = (0..=m as u32).collect();
    let mut cur = vec![0i32; m + 1];
    let mut cur_s = vec![0u32; m + 1];
    for i in 1..=n {
        cur[0] = i as i32;
        cur_s[0] = 0;
        for c in 1..=m {
            let mm = if read[i - 1] == seg[c - 1] && read[i - 1] < 4 { 0 } else { W_SUB };
            let (mut best, mut s) = (prev[c - 1] + mm, prev_s[c - 1]);
            if prev[c] + 1 < best {
                best = prev[c] + 1;
                s = prev_s[c];
            }
            if cur[c - 1] + 1 < best {
                best = cur[c - 1] + 1;
                s = cur_s[c - 1];
            }
            cur[c] = best;
            cur_s[c] = s;
        }
        std::mem::swap(&mut prev, &mut cur);
        std::mem::swap(&mut prev_s, &mut cur_s);
    }
    let (mut dist, mut start, mut end) = (BIG, 0u32, 0u32);
    for c in 0..=m {
        if prev[c] < dist {
            dist = prev[c];
            start = prev_s[c];
            end = c as u32;
        }
    }
    SemiGlobalHit { dist, start, end }
}

/// Semi-global *affine* (Gotoh) alignment: read global, segment flanks
/// free; gap run of length L costs `w_op + L*w_ex`. Leftmost end wins
/// ties. This is the ground-truth scorer.
pub fn semi_global_affine(read: &[u8], seg: &[u8]) -> SemiGlobalHit {
    let n = read.len();
    let m = seg.len();
    let inf = BIG;
    // Rolling rows for D, M1 (vertical/read gap... consumes read), M2
    // (horizontal, consumes segment), each with start tracking.
    let mut d_prev = vec![0i32; m + 1];
    let mut d_prev_s: Vec<u32> = (0..=m as u32).collect();
    let mut m1_prev = vec![inf; m + 1];
    let mut m1_prev_s = vec![0u32; m + 1];

    let mut d_cur = vec![0i32; m + 1];
    let mut d_cur_s = vec![0u32; m + 1];
    let mut m1_cur = vec![0i32; m + 1];
    let mut m1_cur_s = vec![0u32; m + 1];
    let mut m2_cur = vec![0i32; m + 1];
    let mut m2_cur_s = vec![0u32; m + 1];

    for i in 1..=n {
        // column 0: read prefix aligned to nothing => vertical gap
        m1_cur[0] = W_OP + i as i32 * W_EX;
        m1_cur_s[0] = 0;
        m2_cur[0] = inf;
        m2_cur_s[0] = 0;
        d_cur[0] = m1_cur[0];
        d_cur_s[0] = 0;
        for c in 1..=m {
            // M1: gap in segment (consume read base)
            let ext = m1_prev[c] + W_EX;
            let opn = d_prev[c] + W_OP + W_EX;
            if ext <= opn {
                m1_cur[c] = ext;
                m1_cur_s[c] = m1_prev_s[c];
            } else {
                m1_cur[c] = opn;
                m1_cur_s[c] = d_prev_s[c];
            }
            // M2: gap in read (consume segment base)
            let ext2 = m2_cur[c - 1] + W_EX;
            let opn2 = d_cur[c - 1] + W_OP + W_EX;
            if ext2 <= opn2 {
                m2_cur[c] = ext2;
                m2_cur_s[c] = m2_cur_s[c - 1];
            } else {
                m2_cur[c] = opn2;
                m2_cur_s[c] = d_cur_s[c - 1];
            }
            // D
            let mm = if read[i - 1] == seg[c - 1] && read[i - 1] < 4 { 0 } else { W_SUB };
            let (mut best, mut s) = (d_prev[c - 1] + mm, d_prev_s[c - 1]);
            if m1_cur[c] < best {
                best = m1_cur[c];
                s = m1_cur_s[c];
            }
            if m2_cur[c] < best {
                best = m2_cur[c];
                s = m2_cur_s[c];
            }
            d_cur[c] = best;
            d_cur_s[c] = s;
        }
        std::mem::swap(&mut d_prev, &mut d_cur);
        std::mem::swap(&mut d_prev_s, &mut d_cur_s);
        std::mem::swap(&mut m1_prev, &mut m1_cur);
        std::mem::swap(&mut m1_prev_s, &mut m1_cur_s);
    }
    let (mut dist, mut start, mut end) = (BIG, 0u32, 0u32);
    for c in 0..=m {
        if d_prev[c] < dist {
            dist = d_prev[c];
            start = d_prev_s[c];
            end = c as u32;
        }
    }
    SemiGlobalHit { dist, start, end }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::encode_seq;

    use crate::util::SmallRng;

    #[test]
    fn edit_distance_basics() {
        assert_eq!(edit_distance(b"\x00\x01\x02", b"\x00\x01\x02"), 0);
        assert_eq!(edit_distance(&encode_seq(b"ACGT"), &encode_seq(b"AGGT")), 1);
        assert_eq!(edit_distance(&encode_seq(b"ACGT"), &encode_seq(b"ACT")), 1);
        assert_eq!(edit_distance(&encode_seq(b""), &encode_seq(b"ACT")), 3);
        // all-N degenerate: nothing matches, distance = max(len) (subs + length delta)
        assert_eq!(edit_distance(&encode_seq(b"NNNNNN"), &encode_seq(b"NNNNNNN")), 7);
    }

    #[test]
    fn semi_global_finds_planted_read() {
        let mut rng = SmallRng::seed_from_u64(30);
        let seg: Vec<u8> = (0..300).map(|_| rng.gen_range(0..4)).collect();
        let read = seg[100..160].to_vec();
        let hit = semi_global_linear(&read, &seg);
        assert_eq!(hit.dist, 0);
        assert_eq!(hit.start, 100);
        assert_eq!(hit.end, 160);
        let hit = semi_global_affine(&read, &seg);
        assert_eq!((hit.dist, hit.start, hit.end), (0, 100, 160));
    }

    #[test]
    fn affine_charges_gap_opens() {
        let mut rng = SmallRng::seed_from_u64(31);
        let seg: Vec<u8> = (0..200).map(|_| rng.gen_range(0..4)).collect();
        let mut read = seg[50..110].to_vec();
        read.drain(20..23); // 3-base deletion in the read
        let lin = semi_global_linear(&read, &seg);
        let aff = semi_global_affine(&read, &seg);
        assert_eq!(lin.dist, 3); // 3 deletions, linear
        assert_eq!(aff.dist, 4); // open + 3 extends
        assert_eq!(aff.start, 50);
    }

    #[test]
    fn affine_less_or_equal_substitution_path() {
        // affine distance never exceeds #subs when no indels planted
        let mut rng = SmallRng::seed_from_u64(32);
        let seg: Vec<u8> = (0..200).map(|_| rng.gen_range(0..4)).collect();
        let mut read = seg[30..90].to_vec();
        for p in [5usize, 25, 45] {
            read[p] = (read[p] + 1) % 4;
        }
        let aff = semi_global_affine(&read, &seg);
        assert!(aff.dist <= 3);
    }

    #[test]
    fn read_longer_than_segment_degrades_gracefully() {
        let read: Vec<u8> = vec![0; 10];
        let seg: Vec<u8> = vec![0; 4];
        let hit = semi_global_affine(&read, &seg);
        // 4 matches + a 6-long read gap = open(1) + 6
        assert_eq!(hit.dist, 7);
    }

    #[test]
    fn n_padding_never_matches() {
        let read = vec![0u8; 5];
        let seg = vec![4u8; 20]; // all N
        let hit = semi_global_linear(&read, &seg);
        assert_eq!(hit.dist, 5);
    }
}
