//! CIGAR strings (SAM-style, extended ops: = X I D) from edit scripts.

use super::traceback::EditOp;

/// Run-length-encoded alignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Cigar(
    /// `(count, op)` runs; ops are the extended SAM codes `= X I D`.
    pub Vec<(u32, u8)>,
);

impl Cigar {
    /// Compress an op sequence.
    pub fn from_ops(ops: &[EditOp]) -> Self {
        let mut out: Vec<(u32, u8)> = Vec::new();
        for &op in ops {
            let c = match op {
                EditOp::Match => b'=',
                EditOp::Sub => b'X',
                EditOp::Ins => b'I',
                EditOp::Del => b'D',
            };
            match out.last_mut() {
                Some((n, lc)) if *lc == c => *n += 1,
                _ => out.push((1, c)),
            }
        }
        Cigar(out)
    }

    /// Number of read bases consumed (= X I).
    pub fn read_len(&self) -> u32 {
        self.0.iter().filter(|(_, c)| matches!(c, b'=' | b'X' | b'I')).map(|(n, _)| n).sum()
    }

    /// Number of reference bases consumed (= X D).
    pub fn ref_len(&self) -> u32 {
        self.0.iter().filter(|(_, c)| matches!(c, b'=' | b'X' | b'D')).map(|(n, _)| n).sum()
    }

    /// Total edits (X I D).
    pub fn n_edits(&self) -> u32 {
        self.0.iter().filter(|(_, c)| matches!(c, b'X' | b'I' | b'D')).map(|(n, _)| n).sum()
    }
}

impl std::fmt::Display for Cigar {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.0.is_empty() {
            return write!(f, "*");
        }
        for (n, c) in &self.0 {
            write!(f, "{}{}", n, *c as char)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use EditOp::*;

    #[test]
    fn compresses_runs() {
        let ops = [Match, Match, Sub, Ins, Ins, Match, Del];
        let c = Cigar::from_ops(&ops);
        assert_eq!(c.to_string(), "2=1X2I1=1D");
        assert_eq!(c.read_len(), 6);
        assert_eq!(c.ref_len(), 5);
        assert_eq!(c.n_edits(), 4);
    }

    #[test]
    fn empty_is_star() {
        assert_eq!(Cigar::from_ops(&[]).to_string(), "*");
    }

    #[test]
    fn pure_match() {
        let c = Cigar::from_ops(&[Match; 150]);
        assert_eq!(c.to_string(), "150=");
        assert_eq!(c.n_edits(), 0);
    }
}
