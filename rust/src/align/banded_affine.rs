//! Banded affine-gap Wagner-Fischer with traceback directions, mirroring
//! `python/compile/kernels/affine_wf.py` / `ref.affine_wf_band` exactly
//! (paper Eqs. 3-5; all costs 1; 5-bit saturation at 31).
//!
//! Direction encoding per cell (4 bits, see python params.py):
//! bits[1:0] D-origin (0 match / 1 sub / 2 from-M1 / 3 from-M2),
//! bit[2] M1 extend, bit[3] M2 extend. Ties prefer open / sub < M1 < M2.

use crate::params::{BAND, BIG, SAT_AFFINE, W_EX, W_OP, W_SUB, window_len};

use super::banded_linear::init_band;

/// D-origin code: diagonal match.
pub const D_MATCH: u8 = 0;
/// D-origin code: diagonal substitution.
pub const D_SUB: u8 = 1;
/// D-origin code: from the M1 (insertion) layer.
pub const D_M1: u8 = 2;
/// D-origin code: from the M2 (deletion) layer.
pub const D_M2: u8 = 3;

/// Result of one banded affine WF instance.
#[derive(Debug, Clone)]
pub struct AffineResult {
    /// Final D band row, saturated at 31.
    pub band: [i32; BAND],
    /// Packed 4-bit directions, row-major `(read_len, BAND)`.
    pub dirs: Vec<u8>,
}

/// Compute banded affine WF for one (read, window) pair.
pub fn affine_wf_band(read: &[u8], win: &[u8]) -> AffineResult {
    assert_eq!(win.len(), window_len(read.len()), "bad window length");
    let n = read.len();
    let sat = SAT_AFFINE;
    let mut d = init_band();
    let mut m1 = [sat; BAND];
    let mut m2 = [sat; BAND];
    let mut dirs = vec![0u8; n * BAND];

    let mut m1new = [0i32; BAND];
    let mut m1dir = [0u8; BAND];
    let mut m2raw = [0i32; BAND];
    let mut m2dir = [0u8; BAND];
    let mut a = [0i32; BAND];
    let mut matches = [false; BAND];

    for (i, &r) in read.iter().enumerate() {
        // fixed-length view elides bounds checks in the row loops (§Perf)
        let g: &[u8; BAND] = win[i..i + BAND].try_into().expect("window geometry");
        for j in 0..BAND {
            matches[j] = r == g[j] && r < 4;
        }
        // M1 (vertical: consume read base, gap in reference)
        for j in 0..BAND {
            let up_m1 = if j < BAND - 1 { m1[j + 1] } else { sat };
            let up_d = if j < BAND - 1 { d[j + 1] } else { sat };
            let ext = up_m1 + W_EX;
            let opn = up_d + W_OP + W_EX;
            m1new[j] = ext.min(opn);
            m1dir[j] = u8::from(ext < opn); // prefer open on ties
            a[j] = m1new[j].min(d[j] + W_SUB);
        }
        // M2 (horizontal) via the folded serial chain
        let mut prev = BIG;
        for j in 0..BAND {
            let cbase = if j == 0 {
                BIG
            } else {
                W_OP + W_EX + if matches[j - 1] { d[j - 1] } else { a[j - 1] }
            };
            m2raw[j] = cbase.min(prev + W_EX);
            m2dir[j] = u8::from(m2raw[j] < cbase); // prefer open on ties
            prev = m2raw[j];
        }
        // D with deterministic origin priority: match, then sub<M1<M2.
        for j in 0..BAND {
            let (dn, dd) = if matches[j] {
                (d[j], D_MATCH)
            } else {
                let vsub = d[j] + W_SUB;
                let dn = vsub.min(m1new[j]).min(m2raw[j]);
                let dd = if vsub <= m1new[j] && vsub <= m2raw[j] {
                    D_SUB
                } else if m1new[j] <= m2raw[j] {
                    D_M1
                } else {
                    D_M2
                };
                (dn, dd)
            };
            dirs[i * BAND + j] = dd | (m1dir[j] << 2) | (m2dir[j] << 3);
            d[j] = dn.min(sat);
        }
        for j in 0..BAND {
            m1[j] = m1new[j].min(sat);
            m2[j] = m2raw[j].min(sat);
        }
    }
    AffineResult { band: d, dirs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::banded_linear::{best_of_band, linear_wf_band};
    use crate::params::ETH;

    use crate::util::SmallRng;

    fn planted_with_gap(
        rng: &mut SmallRng,
        n: usize,
        gap_len: usize,
        gap_is_del: bool,
    ) -> (Vec<u8>, Vec<u8>) {
        let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..4)).collect();
        let mut seq: Vec<u8> = read.clone();
        let p = n / 2;
        if gap_is_del {
            // window lacks `gap_len` read bases => read insertion
            seq.drain(p..p + gap_len);
        } else {
            for _ in 0..gap_len {
                seq.insert(p, rng.gen_range(0..4));
            }
        }
        let m = window_len(n);
        let mut win: Vec<u8> = (0..m).map(|_| rng.gen_range(0..4)).collect();
        let take = seq.len().min(m - ETH);
        win[ETH..ETH + take].copy_from_slice(&seq[..take]);
        (read, win)
    }

    #[test]
    fn exact_match_is_zero() {
        let mut rng = SmallRng::seed_from_u64(10);
        let read: Vec<u8> = (0..60).map(|_| rng.gen_range(0..4)).collect();
        let mut win: Vec<u8> = (0..window_len(60)).map(|_| rng.gen_range(0..4)).collect();
        win[ETH..ETH + 60].copy_from_slice(&read);
        let res = affine_wf_band(&read, &win);
        assert_eq!(res.band[ETH], 0);
        // all direction codes on the diagonal are matches
        for i in 0..60 {
            assert_eq!(res.dirs[i * BAND + ETH] & 3, D_MATCH);
        }
    }

    #[test]
    fn gap_costs_open_plus_extend() {
        let mut rng = SmallRng::seed_from_u64(11);
        for gap in 1..=4usize {
            for del in [true, false] {
                let (read, win) = planted_with_gap(&mut rng, 60, gap, del);
                let res = affine_wf_band(&read, &win);
                let (best, _) = best_of_band(&res.band);
                assert!(
                    best <= (W_OP + gap as i32 * W_EX),
                    "gap={gap} del={del} best={best}"
                );
            }
        }
    }

    #[test]
    fn affine_never_beats_linear_minus_opens() {
        // affine distance >= linear distance (affine charges extra opens)
        let mut rng = SmallRng::seed_from_u64(12);
        for _ in 0..20 {
            let (read, win) = planted_with_gap(&mut rng, 40, 2, true);
            let lin = best_of_band(&linear_wf_band(&read, &win)).0;
            let aff = best_of_band(&affine_wf_band(&read, &win).band).0;
            assert!(aff >= lin.min(SAT_AFFINE), "aff={aff} lin={lin}");
        }
    }

    #[test]
    fn random_pairs_saturate() {
        let mut rng = SmallRng::seed_from_u64(13);
        let read: Vec<u8> = (0..150).map(|_| rng.gen_range(0..4)).collect();
        let win: Vec<u8> = (0..window_len(150)).map(|_| rng.gen_range(0..4)).collect();
        let res = affine_wf_band(&read, &win);
        assert!(res.band.iter().all(|&d| d >= SAT_AFFINE - 4), "band={:?}", res.band);
    }

    #[test]
    fn dirs_fit_four_bits() {
        let mut rng = SmallRng::seed_from_u64(14);
        let (read, win) = planted_with_gap(&mut rng, 50, 2, false);
        let res = affine_wf_band(&read, &win);
        assert!(res.dirs.iter().all(|&b| b < 16));
        assert_eq!(res.dirs.len(), 50 * BAND);
    }
}
