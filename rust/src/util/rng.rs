//! Deterministic seeded RNG (splitmix64 core), API-compatible with the
//! subset of `rand` this crate needs. Not cryptographic; used for
//! synthetic data generation and property tests only.

use std::ops::{Range, RangeInclusive};

/// Splitmix64 generator. Tiny state, excellent distribution for
/// simulation purposes, fully deterministic across platforms.
#[derive(Debug, Clone)]
pub struct SmallRng {
    state: u64,
}

impl SmallRng {
    /// Generator seeded with `seed` (same seed => same stream).
    pub fn seed_from_u64(seed: u64) -> Self {
        SmallRng { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        debug_assert!((0.0..=1.0).contains(&p));
        self.next_f64() < p
    }

    /// Uniform sample from a range (exclusive or inclusive).
    #[inline]
    pub fn gen_range<T, R: SampleRange<T>>(&mut self, r: R) -> T {
        r.sample(self)
    }

    /// Debiased uniform in [0, n) (Lemire-style rejection is overkill for
    /// simulation; modulo bias is < 2^-32 for our n).
    #[inline]
    fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0, "empty range");
        self.next_u64() % n
    }
}

/// Range sampling, monomorphized per integer type.
pub trait SampleRange<T> {
    /// Uniform sample from `self` using `rng`.
    fn sample(self, rng: &mut SmallRng) -> T;
}

macro_rules! impl_sample {
    ($t:ty) => {
        impl SampleRange<$t> for Range<$t> {
            #[inline]
            fn sample(self, rng: &mut SmallRng) -> $t {
                assert!(self.start < self.end, "empty range");
                let span = (self.end as u64) - (self.start as u64);
                self.start + rng.below(span) as $t
            }
        }
        impl SampleRange<$t> for RangeInclusive<$t> {
            #[inline]
            fn sample(self, rng: &mut SmallRng) -> $t {
                let (lo, hi) = (*self.start(), *self.end());
                assert!(lo <= hi, "empty range");
                let span = (hi as u64) - (lo as u64) + 1;
                lo + rng.below(span) as $t
            }
        }
    };
}

impl_sample!(u8);
impl_sample!(u16);
impl_sample!(u32);
impl_sample!(u64);
impl_sample!(usize);

macro_rules! impl_sample_signed {
    ($t:ty) => {
        impl SampleRange<$t> for Range<$t> {
            #[inline]
            fn sample(self, rng: &mut SmallRng) -> $t {
                assert!(self.start < self.end, "empty range");
                let span = (self.end as i128 - self.start as i128) as u64;
                (self.start as i128 + rng.below(span) as i128) as $t
            }
        }
        impl SampleRange<$t> for RangeInclusive<$t> {
            #[inline]
            fn sample(self, rng: &mut SmallRng) -> $t {
                let (lo, hi) = (*self.start(), *self.end());
                assert!(lo <= hi, "empty range");
                let span = (hi as i128 - lo as i128 + 1) as u64;
                (lo as i128 + rng.below(span) as i128) as $t
            }
        }
    };
}

impl_sample_signed!(i32);
impl_sample_signed!(i64);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SmallRng::seed_from_u64(7);
        let mut b = SmallRng::seed_from_u64(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_in_bounds() {
        let mut rng = SmallRng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x: u8 = rng.gen_range(0..4);
            assert!(x < 4);
            let y: usize = rng.gen_range(5..=9);
            assert!((5..=9).contains(&y));
        }
    }

    #[test]
    fn gen_bool_tracks_probability() {
        let mut rng = SmallRng::seed_from_u64(2);
        let hits = (0..100_000).filter(|_| rng.gen_bool(0.3)).count();
        let p = hits as f64 / 100_000.0;
        assert!((p - 0.3).abs() < 0.01, "p={p}");
    }

    #[test]
    fn range_distribution_roughly_uniform() {
        let mut rng = SmallRng::seed_from_u64(3);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[rng.gen_range(0..4usize)] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }
}
