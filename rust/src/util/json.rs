//! Minimal JSON: a writer for reports and a parser sufficient for the
//! artifacts manifest (flat objects, arrays, strings, integers).
//! Stand-in for serde_json in this offline build.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (integers render without a fraction).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys: deterministic serialization).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Member `key` of an object (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The numeric payload truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    let _ = write!(out, "{pad}  ");
                    v.write(out, indent + 1);
                    out.push_str(if i + 1 < a.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}]");
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    let _ = write!(out, "{pad}  \"{}\": ", escape(k));
                    v.write(out, indent + 1);
                    out.push_str(if i + 1 < m.len() { ",\n" } else { "\n" });
                }
                let _ = write!(out, "{pad}}}");
            }
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i32> for Json {
    fn from(n: i32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}

fn escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => "\\\"".chars().collect::<Vec<_>>(),
            '\\' => "\\\\".chars().collect(),
            '\n' => "\\n".chars().collect(),
            '\t' => "\\t".chars().collect(),
            '\r' => "\\r".chars().collect(),
            c => vec![c],
        })
        .collect()
}

/// Parse a JSON document. Supports objects, arrays, strings (with basic
/// escapes), numbers, booleans, null — everything the manifest uses.
pub fn parse(text: &str) -> Result<Json, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8, String> {
        self.ws();
        self.b.get(self.i).copied().ok_or_else(|| "unexpected end of input".into())
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        self.ws();
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            let k = self.string()?;
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or("unterminated string")?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or("bad escape")?;
                    self.i += 1;
                    s.push(match e {
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b.get(self.i..self.i + 4).ok_or("bad \\u")?,
                            )
                            .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            char::from_u32(cp).ok_or("bad codepoint")?
                        }
                        other => other as char,
                    });
                }
                other => s.push(other as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_manifest_like() {
        let text = r#"{
  "read_len": 150,
  "band": 13,
  "artifacts": [
    {"name": "linear_wf_b32", "batch": 32, "file": "linear_wf_b32.hlo.txt"},
    {"name": "affine_wf_b8", "batch": 8, "file": "affine_wf_b8.hlo.txt"}
  ],
  "ok": true,
  "note": "a \"quoted\" string\nwith newline"
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("read_len").unwrap().as_usize(), Some(150));
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 2);
        assert_eq!(arts[1].get("name").unwrap().as_str(), Some("affine_wf_b8"));
        assert_eq!(v.get("ok"), Some(&Json::Bool(true)));
        // pretty() output reparses to the same value
        let again = parse(&v.pretty()).unwrap();
        assert_eq!(again, v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
    }

    #[test]
    fn parses_real_manifest() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = parse(&text).unwrap();
            assert!(v.get("artifacts").unwrap().as_arr().unwrap().len() >= 4);
        }
    }

    #[test]
    fn numbers_and_unicode() {
        let v = parse(r#"{"x": -1.5e3, "s": "A"}"#).unwrap();
        assert_eq!(v.get("x").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(v.get("s").unwrap().as_str(), Some("A"));
    }
}
