//! Small in-crate utilities standing in for unavailable third-party
//! crates (offline build — see Cargo.toml note): a seeded RNG, a JSON
//! writer, a property-test helper, and a micro-bench timer.

pub mod bench;
pub mod json;
pub mod proptest;
pub mod rng;

pub use rng::SmallRng;
