//! Micro-bench timer (stand-in for criterion in this offline build):
//! warmup + timed iterations with mean / p50 / p95 reporting.

use std::time::{Duration, Instant};

/// Timing summary for one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark case label.
    pub name: String,
    /// Measured iterations (excluding warmup).
    pub iters: usize,
    /// Mean iteration latency.
    pub mean: Duration,
    /// Median iteration latency.
    pub p50: Duration,
    /// 95th-percentile iteration latency.
    pub p95: Duration,
    /// Optional work units per iteration (for throughput lines).
    pub units_per_iter: f64,
}

impl BenchStats {
    /// Units per second at the mean latency.
    pub fn throughput(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            return f64::INFINITY;
        }
        self.units_per_iter / self.mean.as_secs_f64()
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<36} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95",
            self.name, self.mean, self.p50, self.p95
        )?;
        if self.units_per_iter > 0.0 {
            write!(f, "  {:>12.1} units/s", self.throughput())?;
        }
        Ok(())
    }
}

/// Run `body` with `warmup` unmeasured and `iters` measured iterations.
pub fn bench(name: &str, warmup: usize, iters: usize, mut body: impl FnMut()) -> BenchStats {
    bench_units(name, warmup, iters, 0.0, &mut body)
}

/// Like [`bench`] but reports throughput in `units` per iteration.
pub fn bench_units(
    name: &str,
    warmup: usize,
    iters: usize,
    units: f64,
    body: &mut dyn FnMut(),
) -> BenchStats {
    assert!(iters >= 1);
    for _ in 0..warmup {
        body();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        body();
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchStats {
        name: name.to_string(),
        iters,
        mean: total / iters as u32,
        p50: samples[iters / 2],
        p95: samples[(iters * 95 / 100).min(iters - 1)],
        units_per_iter: units,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let s = bench("noop-ish", 1, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(s.iters, 10);
        assert!(s.p50 <= s.p95);
    }

    #[test]
    fn throughput_uses_units() {
        let s = bench_units("t", 0, 5, 100.0, &mut || {
            std::thread::sleep(Duration::from_millis(1))
        });
        assert!(s.throughput() > 0.0 && s.throughput() < 1_000_000.0);
    }
}
