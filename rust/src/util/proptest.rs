//! Property-test helper (stand-in for the proptest crate): run a check
//! over many seeded random cases and report the first failing seed so
//! failures are reproducible with `check_one`.

use super::rng::SmallRng;

/// Run `body` for `cases` seeds derived from `base_seed`. On failure the
/// panic message names the failing seed.
pub fn check(name: &str, base_seed: u64, cases: usize, mut body: impl FnMut(&mut SmallRng)) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = SmallRng::seed_from_u64(seed);
            body(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing case by seed.
pub fn check_one(seed: u64, mut body: impl FnMut(&mut SmallRng)) {
    let mut rng = SmallRng::seed_from_u64(seed);
    body(&mut rng);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_clean_properties() {
        check("sum-commutes", 1, 50, |rng| {
            let a: u32 = rng.gen_range(0..1000);
            let b: u32 = rng.gen_range(0..1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always-fails", 2, 3, |_| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| {
                err.downcast_ref::<&str>().map(|s| s.to_string()).unwrap_or_default()
            });
        assert!(msg.contains("always-fails") && msg.contains("seed"), "msg={msg}");
    }
}
