//! Full-system simulator (paper §VI item 1).
//!
//! Performs the offline crossbar assignment and routes a concrete read
//! workload through it, counting:
//!
//! * **instances** `J_L` / `J_A` — total linear / affine WF computations
//!   (drive Eq. 7, energy), and
//! * **iterations** `K_L` / `K_A` — lock-step rounds at the bottleneck
//!   crossbar (drive Eq. 6, execution time: all crossbars receive the
//!   same broadcast op sequence, so the busiest crossbar paces the run).
//!
//! Filtering policy: every segment whose linear WF distance passes
//! (<= eth) proceeds to affine alignment ("AllPassing"). On the paper's
//! human dataset this yields ~45 affine instances per read, consistent
//! with its energy and RISC-V-load numbers (DESIGN.md §4 derivation).
//!
//! Like the live pipeline, the simulator is streaming:
//! [`FullSystemSim::simulate_stream`] pulls reads from any fallible
//! iterator, partitions (read, minimizer) pairs by minimizer hash
//! across persistent per-shard workers over bounded channels, and keeps
//! at most `SIM_FILTER_BATCH` WF instances in flight per shard. The one
//! per-read residual is candidate tracking (`reads_with_candidates`
//! needs cross-shard dedup): **1 bit per read per shard**, i.e.
//! ~49 MB/shard at the paper's 389 M-read scale — the WF working set
//! stays O(batch). The slice entry points ([`FullSystemSim::simulate`]
//! and friends) are thin wrappers.
//!
//! Affine iteration accounting ([`TimingMode`]):
//! * `PaperSerial` — one affine instance per lock-step round
//!   (`K_A ≈` affine instances at the bottleneck). This reproduces the
//!   paper's reported execution times (43.8 s / 87 s / 174 s for
//!   maxReads = 12.5k/25k/50k at 389 M reads) within ~12 %.
//! * `Batched8` — the idealized 8-instances-per-round mode the affine
//!   buffer geometry permits; reported as an ablation.

use std::borrow::Borrow;
use std::collections::HashMap;
use std::sync::{mpsc, Arc};
use std::thread;

use anyhow::Result;

use crate::index::{shard_of, MinimizerIndex};
use crate::params::ETH;
use crate::pim::DartPimConfig;
use crate::runtime::{default_engine, default_simd_mode, EngineKind, SimdMode, WfEngine};
use crate::seeding::{seed_read, ReadSeed};

/// Engine flush size for the shard filter pass (the largest artifact
/// batch; big enough that the bit-parallel engine runs full 64-lane
/// words). Also the per-shard in-flight instance bound of the streaming
/// simulation.
const SIM_FILTER_BATCH: usize = 256;

/// Dense read index for the sim stream, guarded like the pipeline's
/// read-id counter (a silent u32 wrap would alias candidate bits).
fn sim_read_id(n_reads: u64) -> Result<u32> {
    u32::try_from(n_reads).map_err(|_| anyhow::anyhow!("read stream exceeds u32 read ids"))
}

/// Seeded pairs per channel send in the streaming simulation.
const SIM_CHUNK: usize = 512;
/// Bounded depth of each sim worker's channel (backpressure).
const SIM_CHANNEL_DEPTH: usize = 4;

/// How affine lock-step rounds are counted (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TimingMode {
    /// One affine instance per lock-step round (reproduces the paper).
    #[default]
    PaperSerial,
    /// Idealized 8-instances-per-round ablation.
    Batched8,
}

/// Counters produced by one simulated run.
#[derive(Debug, Clone, Default)]
pub struct SimCounts {
    /// Reads in the simulated workload.
    pub n_reads: u64,
    /// (read, minimizer) pairs routed to crossbars.
    pub routed_pairs: u64,
    /// Pairs dropped by the maxReads cap (accuracy loss).
    pub dropped_pairs: u64,
    /// Pairs routed to the DP-RISC-V cores (lowTh minimizers).
    pub riscv_pairs: u64,
    /// J_L: linear WF instances in DP-memory.
    pub linear_instances: u64,
    /// J_A: affine WF instances in DP-memory.
    pub affine_instances: u64,
    /// Linear WF instances computed by the RISC-V cores.
    pub riscv_linear_instances: u64,
    /// Affine WF instances computed by the RISC-V cores.
    pub riscv_affine_instances: u64,
    /// Linear lock-step rounds at the bottleneck crossbar (K_L).
    pub k_linear: u64,
    /// Affine instances at the bottleneck crossbar (pre TimingMode).
    pub bottleneck_affine: u64,
    /// Number of crossbars that received any work.
    pub active_xbars: u64,
    /// Reads with at least one surviving (affine-aligned) PL.
    pub reads_with_candidates: u64,
    /// Read pairs in the workload (paired simulations only; zero for
    /// single-end runs).
    pub n_pairs: u64,
    /// Pairs where *both* mates survive the filter — the input
    /// availability of the live pipeline's proper-pair arbitration
    /// (paired simulations only).
    pub pairs_with_candidates: u64,
}

impl SimCounts {
    /// K_A under a timing mode.
    pub fn k_affine(&self, mode: TimingMode) -> u64 {
        match mode {
            TimingMode::PaperSerial => self.bottleneck_affine,
            TimingMode::Batched8 => self.bottleneck_affine.div_ceil(8),
        }
    }

    /// Fraction of affine work on the RISC-V cores (paper: 0.16 %).
    pub fn riscv_affine_share(&self) -> f64 {
        let total = self.affine_instances + self.riscv_affine_instances;
        if total == 0 {
            return 0.0;
        }
        self.riscv_affine_instances as f64 / total as f64
    }

    /// Average linear instances (PLs) per read — §II motivation.
    pub fn pls_per_read(&self) -> f64 {
        if self.n_reads == 0 {
            return 0.0;
        }
        (self.linear_instances + self.riscv_linear_instances) as f64 / self.n_reads as f64
    }

    /// Filter pass rate (affine instances / linear instances).
    pub fn pass_rate(&self) -> f64 {
        if self.linear_instances == 0 {
            return 0.0;
        }
        self.affine_instances as f64 / self.linear_instances as f64
    }
}

/// Growable bitset marking reads with at least one surviving candidate
/// (1 bit per read: the streaming replacement for a `Vec<bool>` sized to
/// a read count that is unknown up front).
#[derive(Debug, Default, Clone)]
struct ReadFlags {
    words: Vec<u64>,
}

impl ReadFlags {
    fn set(&mut self, i: u32) {
        let w = (i / 64) as usize;
        if w >= self.words.len() {
            self.words.resize(w + 1, 0);
        }
        self.words[w] |= 1u64 << (i % 64);
    }

    fn get(&self, i: u64) -> bool {
        self.words.get((i / 64) as usize).is_some_and(|w| w & (1u64 << (i % 64)) != 0)
    }

    fn union(&mut self, other: &ReadFlags) {
        if other.words.len() > self.words.len() {
            self.words.resize(other.words.len(), 0);
        }
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= *b;
        }
    }

    fn count(&self) -> u64 {
        self.words.iter().map(|w| u64::from(w.count_ones())).sum()
    }
}

/// One seeded (read, minimizer) pair in flight to a sim shard.
struct SimItem {
    /// Dense stream index of the read.
    ri: u32,
    /// The resolved minimizer.
    seed: ReadSeed,
    /// The read's sequence (shared across its seeds).
    seq: Arc<[u8]>,
}

/// One pending filter instance: read index, owning crossbar (None =
/// RISC-V pool), read sequence, extracted window.
struct PendingInstance {
    ri: u32,
    xbar: Option<u32>,
    seq: Arc<[u8]>,
    win: Vec<u8>,
}

/// Per-shard state of the workload simulation: counters, the shard's
/// private per-crossbar cap accounting, and the bounded in-flight
/// instance buffer. Persists for the whole stream (cap accounting is a
/// lifetime quantity).
struct SimShard {
    counts: SimCounts,
    pairs_per_xbar: HashMap<u32, u64>,
    affine_per_xbar: HashMap<u32, u64>,
    candidates: ReadFlags,
    pending: Vec<PendingInstance>,
    engine: Box<dyn WfEngine + Send>,
}

impl SimShard {
    fn new(engine: EngineKind, simd: SimdMode) -> Self {
        SimShard {
            counts: SimCounts::default(),
            pairs_per_xbar: HashMap::new(),
            affine_per_xbar: HashMap::new(),
            candidates: ReadFlags::default(),
            pending: Vec::with_capacity(SIM_FILTER_BATCH),
            engine: engine.build_simd(simd),
        }
    }

    /// Run the buffered instances through the engine (Rust mirror of the
    /// L1 kernel, scalar or bit-parallel — identical numerics) and fold
    /// the pass/fail results into the shard counters.
    fn drain(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let rr: Vec<&[u8]> = self.pending.iter().map(|x| x.seq.as_ref()).collect();
        let ww: Vec<&[u8]> = self.pending.iter().map(|x| x.win.as_slice()).collect();
        let out = self.engine.linear_batch(&rr, &ww).expect("simulator filter batch");
        drop((rr, ww));
        for (inst, &best) in self.pending.iter().zip(&out.best) {
            if best > ETH as i32 {
                continue;
            }
            self.candidates.set(inst.ri);
            match inst.xbar {
                None => self.counts.riscv_affine_instances += 1,
                Some(xb) => {
                    self.counts.affine_instances += 1;
                    *self.affine_per_xbar.entry(xb).or_default() += 1;
                }
            }
        }
        self.pending.clear();
    }
}

/// Offline crossbar assignment: each minimizer above lowTh owns
/// `ceil(occurrences / linear_rows)` crossbars.
pub struct FullSystemSim<'a> {
    /// The minimizer index being simulated against.
    pub index: &'a MinimizerIndex,
    /// Architecture configuration.
    pub cfg: DartPimConfig,
    /// minimizer -> (first crossbar id, number of crossbars), for
    /// minimizers assigned to DP-memory.
    assignment: HashMap<u64, (u32, u32)>,
    /// Total crossbars allocated.
    pub xbars_used: u32,
}

impl<'a> FullSystemSim<'a> {
    /// Build the offline assignment (paper §V-B / Fig. 7a).
    pub fn new(index: &'a MinimizerIndex, cfg: DartPimConfig) -> Self {
        let mut assignment = HashMap::new();
        let mut next = 0u32;
        // deterministic order: sort minimizers for reproducible layouts
        let mut minis: Vec<(u64, usize)> =
            index.iter().map(|(m, occ)| (m, occ.len())).collect();
        minis.sort_unstable();
        for (m, occ) in minis {
            if occ > cfg.low_th {
                let n = occ.div_ceil(cfg.linear_rows) as u32;
                assignment.insert(m, (next, n));
                next += n;
            }
        }
        FullSystemSim { index, cfg, assignment, xbars_used: next }
    }

    /// Where a minimizer lives: `Some((first_xbar, n_xbars))` for
    /// DP-memory minimizers, `None` for RISC-V (lowTh) ones.
    pub fn assignment_of(&self, minimizer: u64) -> Option<(u32, u32)> {
        self.assignment.get(&minimizer).copied()
    }

    /// Simulate the online phase over a workload, running the actual
    /// linear filter per segment (Rust mirror of the L1 kernel).
    pub fn simulate(&self, reads: &[crate::genome::ReadRecord]) -> SimCounts {
        self.simulate_threaded(reads, 1)
    }

    /// [`Self::simulate`] sharded across `n_threads` worker threads on
    /// the [`default_engine`] filter engine.
    pub fn simulate_threaded(
        &self,
        reads: &[crate::genome::ReadRecord],
        n_threads: usize,
    ) -> SimCounts {
        self.simulate_threaded_with(reads, n_threads, default_engine())
    }

    /// [`Self::simulate`] sharded across `n_threads` worker threads,
    /// filtering through `engine` — a thin slice wrapper over
    /// [`Self::simulate_stream`].
    pub fn simulate_threaded_with(
        &self,
        reads: &[crate::genome::ReadRecord],
        n_threads: usize,
        engine: EngineKind,
    ) -> SimCounts {
        self.simulate_stream(reads.iter().map(Ok), n_threads, engine, default_simd_mode())
            .expect("slice-backed simulation cannot fail")
    }

    /// Simulate a read **stream** with bounded memory.
    ///
    /// (read, minimizer) pairs are partitioned by minimizer hash
    /// ([`shard_of`]) exactly like the live pipeline, so each worker's
    /// per-crossbar cap accounting touches a disjoint crossbar set and
    /// the merged counts are identical to the serial path for every
    /// thread count — and, because the engines share one numerics
    /// contract, for every engine kind. Each worker owns its engine
    /// (constructed on its own thread — the reason the PJRT engine is
    /// not an [`EngineKind`]) and keeps at most `SIM_FILTER_BATCH`
    /// instances in flight; pairs travel over bounded channels, so a
    /// slow filter backpressures seeding.
    ///
    /// Only the read iterator (or a stream longer than u32 read ids)
    /// can produce an `Err`; engine failures are programming errors and
    /// panic, as in the slice path.
    pub fn simulate_stream<I, R>(
        &self,
        reads: I,
        n_threads: usize,
        engine: EngineKind,
        simd: SimdMode,
    ) -> Result<SimCounts>
    where
        I: IntoIterator<Item = Result<R>>,
        R: std::borrow::Borrow<crate::genome::ReadRecord>,
    {
        self.simulate_stream_inner(reads, n_threads, engine, simd, false)
    }

    /// [`Self::simulate_stream`] over a **paired** read stream (R1 at
    /// even stream indices, R2 at odd — the layout of every paired
    /// source in this crate). Mirrors the live pipeline's paired intake:
    /// the stream must hold complete pairs (an odd read count errors),
    /// every mate is seeded in **both orientations** (paired `map`
    /// forces reverse-complement handling, since R2 is sequenced from
    /// the opposite strand), and the counts additionally report
    /// `n_pairs` and `pairs_with_candidates` — how many pairs reach the
    /// proper-pair arbitration with both mates alive. The per-instance
    /// counters therefore match a single-end simulation over the same
    /// reads *plus their reverse complements*, because pairing changes
    /// arbitration, not the WF workload of an oriented read set.
    pub fn simulate_stream_paired<I, R>(
        &self,
        reads: I,
        n_threads: usize,
        engine: EngineKind,
        simd: SimdMode,
    ) -> Result<SimCounts>
    where
        I: IntoIterator<Item = Result<R>>,
        R: std::borrow::Borrow<crate::genome::ReadRecord>,
    {
        self.simulate_stream_inner(reads, n_threads, engine, simd, true)
    }

    fn simulate_stream_inner<I, R>(
        &self,
        reads: I,
        n_threads: usize,
        engine: EngineKind,
        simd: SimdMode,
        paired: bool,
    ) -> Result<SimCounts>
    where
        I: IntoIterator<Item = Result<R>>,
        R: std::borrow::Borrow<crate::genome::ReadRecord>,
    {
        let n = n_threads.max(1);
        let (shards, n_reads) = if n == 1 {
            // serial: one persistent shard fed inline
            let mut shard = SimShard::new(engine, simd);
            let mut n_reads = 0u64;
            let mut chunk: Vec<SimItem> = Vec::new();
            for rec in reads {
                let rec = rec?;
                let ri = sim_read_id(n_reads)?;
                self.seed_into(ri, rec.borrow(), 1, paired, |_, item| chunk.push(item));
                self.sim_ingest(&mut shard, chunk.drain(..));
                n_reads += 1;
            }
            shard.drain();
            (vec![shard], n_reads)
        } else {
            self.simulate_stream_threaded(reads, n, engine, simd, paired)?
        };

        // deterministic merge: sums and disjoint map unions
        let mut c = SimCounts { n_reads, ..Default::default() };
        let mut pairs_per_xbar: HashMap<u32, u64> = HashMap::new();
        let mut affine_per_xbar: HashMap<u32, u64> = HashMap::new();
        let mut candidates = ReadFlags::default();
        for p in shards {
            c.routed_pairs += p.counts.routed_pairs;
            c.dropped_pairs += p.counts.dropped_pairs;
            c.riscv_pairs += p.counts.riscv_pairs;
            c.linear_instances += p.counts.linear_instances;
            c.affine_instances += p.counts.affine_instances;
            c.riscv_linear_instances += p.counts.riscv_linear_instances;
            c.riscv_affine_instances += p.counts.riscv_affine_instances;
            for (k, v) in p.pairs_per_xbar {
                *pairs_per_xbar.entry(k).or_default() += v;
            }
            for (k, v) in p.affine_per_xbar {
                *affine_per_xbar.entry(k).or_default() += v;
            }
            candidates.union(&p.candidates);
        }
        c.reads_with_candidates = candidates.count();
        c.k_linear = pairs_per_xbar.values().copied().max().unwrap_or(0);
        c.bottleneck_affine = affine_per_xbar.values().copied().max().unwrap_or(0);
        c.active_xbars = pairs_per_xbar.len() as u64;
        if paired {
            anyhow::ensure!(
                n_reads % 2 == 0,
                "paired simulation requires an even read stream; got {n_reads} reads"
            );
            c.n_pairs = n_reads / 2;
            c.pairs_with_candidates = (0..c.n_pairs)
                .filter(|&p| candidates.get(2 * p) && candidates.get(2 * p + 1))
                .count() as u64;
        }
        Ok(c)
    }

    /// Threaded body of [`Self::simulate_stream`]: persistent per-shard
    /// workers behind bounded channels; shard states return at join.
    fn simulate_stream_threaded<I, R>(
        &self,
        reads: I,
        n: usize,
        engine: EngineKind,
        simd: SimdMode,
        paired: bool,
    ) -> Result<(Vec<SimShard>, u64)>
    where
        I: IntoIterator<Item = Result<R>>,
        R: std::borrow::Borrow<crate::genome::ReadRecord>,
    {
        thread::scope(|s| -> Result<(Vec<SimShard>, u64)> {
            let mut txs = Vec::with_capacity(n);
            let mut handles = Vec::with_capacity(n);
            for _ in 0..n {
                let (tx, rx) = mpsc::sync_channel::<Vec<SimItem>>(SIM_CHANNEL_DEPTH);
                txs.push(tx);
                handles.push(s.spawn(move || {
                    let mut shard = SimShard::new(engine, simd);
                    while let Ok(items) = rx.recv() {
                        self.sim_ingest(&mut shard, items);
                    }
                    shard.drain();
                    shard
                }));
            }

            let mut pending: Vec<Vec<SimItem>> =
                (0..n).map(|_| Vec::with_capacity(SIM_CHUNK)).collect();
            let mut n_reads = 0u64;
            for rec in reads {
                let rec = rec?;
                let ri = sim_read_id(n_reads)?;
                self.seed_into(ri, rec.borrow(), n, paired, |sh, item| {
                    pending[sh].push(item);
                    if pending[sh].len() >= SIM_CHUNK {
                        let full = std::mem::replace(
                            &mut pending[sh],
                            Vec::with_capacity(SIM_CHUNK),
                        );
                        // a send error means the worker died (panic in
                        // the engine); join below re-raises it
                        let _ = txs[sh].send(full);
                    }
                });
                n_reads += 1;
            }
            for (sh, tx) in txs.into_iter().enumerate() {
                let rest = std::mem::take(&mut pending[sh]);
                if !rest.is_empty() {
                    let _ = tx.send(rest);
                }
                // tx drops here: the worker drains and returns its state
            }
            let shards: Vec<SimShard> =
                handles.into_iter().map(|h| h.join().expect("sim shard panicked")).collect();
            Ok((shards, n_reads))
        })
    }

    /// Seed one read and emit its productive (read, minimizer) pairs,
    /// tagged with the owning shard under an `n`-way partition. In
    /// paired mode every mate is seeded in **both** orientations —
    /// paired mapping forces reverse-complement handling in the live
    /// pipeline (R2 is sequenced from the opposite strand), so the
    /// simulated workload routes the same oriented read set.
    fn seed_into(
        &self,
        ri: u32,
        read: &crate::genome::ReadRecord,
        n: usize,
        paired: bool,
        mut emit: impl FnMut(usize, SimItem),
    ) {
        let mut oriented: Vec<Arc<[u8]>> = Vec::with_capacity(2);
        oriented.push(Arc::from(read.seq.as_slice()));
        if paired {
            oriented.push(Arc::from(crate::genome::revcomp(&read.seq)));
        }
        for seq in oriented {
            for seed in seed_read(self.index, &seq) {
                if self.index.occurrences(seed.kmer).is_empty() {
                    continue;
                }
                let sh = shard_of(seed.kmer, n);
                emit(sh, SimItem { ri, seed, seq: seq.clone() });
            }
        }
    }

    /// Count one shard's workload: the serial per-pair semantics over a
    /// partition-ordered item stream (cap accounting stays exact because
    /// a minimizer's crossbars belong to exactly one shard).
    ///
    /// Routing and cap accounting stay per-pair (order-sensitive); the
    /// surviving WF instances accumulate into the shard's
    /// [`SIM_FILTER_BATCH`] buffer that drains through its engine as it
    /// fills, so memory stays bounded no matter the workload. Instance
    /// results are independent, so batch boundaries cannot change any
    /// count.
    fn sim_ingest(&self, p: &mut SimShard, items: impl IntoIterator<Item = SimItem>) {
        for item in items {
            let occs = self.index.occurrences(item.seed.kmer);
            match self.assignment_of(item.seed.kmer) {
                None => {
                    // lowTh minimizer: the RISC-V cores run both WF
                    // stages for every occurrence.
                    p.counts.riscv_pairs += 1;
                    p.counts.riscv_linear_instances += occs.len() as u64;
                    for &pos in occs {
                        p.pending.push(PendingInstance {
                            ri: item.ri,
                            xbar: None,
                            seq: item.seq.clone(),
                            win: self.index.window_for(pos, item.seed.read_offset as usize),
                        });
                    }
                }
                Some((first, count)) => {
                    // the read is broadcast to every crossbar of the
                    // minimizer; the FIFO cap applies per crossbar
                    let cap = self.cfg.max_reads as u64;
                    let slot = p.pairs_per_xbar.entry(first).or_default();
                    if *slot >= cap {
                        p.counts.dropped_pairs += 1;
                        continue;
                    }
                    *slot += 1;
                    for sub in 1..count {
                        *p.pairs_per_xbar.entry(first + sub).or_default() += 1;
                    }
                    p.counts.routed_pairs += 1;
                    p.counts.linear_instances += occs.len() as u64;
                    for (i, &pos) in occs.iter().enumerate() {
                        p.pending.push(PendingInstance {
                            ri: item.ri,
                            xbar: Some(first + (i / self.cfg.linear_rows) as u32),
                            seq: item.seq.clone(),
                            win: self.index.window_for(pos, item.seed.read_offset as usize),
                        });
                    }
                }
            }
            if p.pending.len() >= SIM_FILTER_BATCH {
                p.drain();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{K, READ_LEN, W};

    fn setup(n_reads: usize) -> (MinimizerIndex, Vec<crate::genome::ReadRecord>) {
        let g = SynthConfig { len: 120_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        (idx, reads)
    }

    #[test]
    fn assignment_covers_all_frequent_minimizers() {
        let (idx, _) = setup(1);
        // small genomes have few minimizers above the human-scale lowTh
        let cfg = DartPimConfig { low_th: 1, ..Default::default() };
        let sim = FullSystemSim::new(&idx, cfg.clone());
        let mut covered = 0;
        for (m, occ) in idx.iter() {
            if occ.len() > cfg.low_th {
                let (_, n) = sim.assignment_of(m).expect("frequent minimizer assigned");
                assert_eq!(n as usize, occ.len().div_ceil(cfg.linear_rows));
                covered += 1;
            } else {
                assert!(sim.assignment_of(m).is_none());
            }
        }
        assert!(covered > 0);
        assert!(sim.xbars_used > 0);
    }

    #[test]
    fn counts_are_consistent() {
        let (idx, reads) = setup(120);
        let sim =
            FullSystemSim::new(&idx, DartPimConfig { low_th: 0, ..Default::default() });
        let c = sim.simulate(&reads);
        assert_eq!(c.n_reads, 120);
        assert!(c.routed_pairs > 0);
        assert!(c.linear_instances >= c.routed_pairs, "each pair has >= 1 segment");
        assert!(c.affine_instances <= c.linear_instances, "filter can only shrink");
        assert!(c.k_linear > 0 && c.k_linear <= c.routed_pairs);
        assert!(c.bottleneck_affine <= c.affine_instances);
        assert!(c.reads_with_candidates <= c.n_reads);
        // simulated reads come from the reference: nearly all must survive
        assert!(
            c.reads_with_candidates as f64 / c.n_reads as f64 > 0.9,
            "survival = {}/{}",
            c.reads_with_candidates,
            c.n_reads
        );
    }

    #[test]
    fn max_reads_cap_drops_pairs() {
        // high coverage so overlapping reads share minimizers
        let g = SynthConfig { len: 20_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 400, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        // low_th = 0 so every minimizer is crossbar-assigned (a 20 kbp
        // genome has few minimizers above the default lowTh = 3)
        let tight = DartPimConfig { max_reads: 1, low_th: 0, ..Default::default() };
        let sim = FullSystemSim::new(&idx, tight);
        let c = sim.simulate(&reads);
        assert!(c.dropped_pairs > 0, "cap of 1 read/crossbar must drop work");
        let loose = DartPimConfig { low_th: 0, ..Default::default() };
        let loose = FullSystemSim::new(&idx, loose).simulate(&reads);
        assert_eq!(loose.dropped_pairs, 0);
        assert!(loose.routed_pairs > c.routed_pairs);
    }

    #[test]
    fn threaded_simulation_matches_serial() {
        let (idx, reads) = setup(150);
        let sim =
            FullSystemSim::new(&idx, DartPimConfig { low_th: 1, ..Default::default() });
        let serial = sim.simulate(&reads);
        for n in [2usize, 4, 7] {
            let t = sim.simulate_threaded(&reads, n);
            assert_eq!(t.n_reads, serial.n_reads, "n={n}");
            assert_eq!(t.routed_pairs, serial.routed_pairs, "n={n}");
            assert_eq!(t.dropped_pairs, serial.dropped_pairs, "n={n}");
            assert_eq!(t.riscv_pairs, serial.riscv_pairs, "n={n}");
            assert_eq!(t.linear_instances, serial.linear_instances, "n={n}");
            assert_eq!(t.affine_instances, serial.affine_instances, "n={n}");
            assert_eq!(t.riscv_linear_instances, serial.riscv_linear_instances, "n={n}");
            assert_eq!(t.riscv_affine_instances, serial.riscv_affine_instances, "n={n}");
            assert_eq!(t.k_linear, serial.k_linear, "n={n}");
            assert_eq!(t.bottleneck_affine, serial.bottleneck_affine, "n={n}");
            assert_eq!(t.active_xbars, serial.active_xbars, "n={n}");
            assert_eq!(t.reads_with_candidates, serial.reads_with_candidates, "n={n}");
        }
    }

    #[test]
    fn stream_matches_slice_and_propagates_errors() {
        let (idx, reads) = setup(60);
        let sim =
            FullSystemSim::new(&idx, DartPimConfig { low_th: 1, ..Default::default() });
        let slice = sim.simulate(&reads);
        for n in [1usize, 3] {
            let c = sim
                .simulate_stream(reads.iter().cloned().map(Ok), n, EngineKind::Rust, SimdMode::Off)
                .unwrap();
            assert_eq!(c.routed_pairs, slice.routed_pairs, "n={n}");
            assert_eq!(c.reads_with_candidates, slice.reads_with_candidates, "n={n}");
            let err = sim
                .simulate_stream(
                    reads
                        .iter()
                        .cloned()
                        .map(Ok)
                        .chain(std::iter::once(Err(anyhow::anyhow!("bad record")))),
                    n,
                    EngineKind::Rust,
                    SimdMode::Off,
                )
                .unwrap_err();
            assert!(err.to_string().contains("bad record"), "n={n}");
        }
    }

    #[test]
    fn paired_stream_counts_pairs_and_matches_both_orientation_workload() {
        let g = SynthConfig { len: 100_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = crate::genome::synth::PairSimConfig { n_pairs: 40, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let sim = FullSystemSim::new(&idx, DartPimConfig { low_th: 1, ..Default::default() });
        // baseline: the oriented read set the live paired pipeline
        // routes — every mate forward AND reverse-complemented — fed as
        // one single-end stream (2x the records)
        let mut both = reads.clone();
        both.extend(reads.iter().map(|r| crate::genome::ReadRecord {
            id: 80 + r.id,
            seq: crate::genome::revcomp(&r.seq),
            truth_pos: r.truth_pos,
            errors: r.errors,
        }));
        let single = sim
            .simulate_stream(both.iter().map(Ok), 1, EngineKind::Rust, SimdMode::Off)
            .unwrap();
        for n in [1usize, 3] {
            let c = sim
                .simulate_stream_paired(reads.iter().map(Ok), n, EngineKind::Rust, SimdMode::Off)
                .unwrap();
            // pairing is an arbitration-layer concept: the simulated WF
            // workload equals the both-orientations single-end run
            assert_eq!(c.routed_pairs, single.routed_pairs, "n={n}");
            assert_eq!(c.riscv_pairs, single.riscv_pairs, "n={n}");
            assert_eq!(c.linear_instances, single.linear_instances, "n={n}");
            assert_eq!(c.affine_instances, single.affine_instances, "n={n}");
            assert_eq!(c.k_linear, single.k_linear, "n={n}");
            assert_eq!(c.active_xbars, single.active_xbars, "n={n}");
            assert_eq!(c.n_reads, 80, "n={n}");
            assert_eq!(c.n_pairs, 40, "n={n}");
            // nearly every pair reaches arbitration with both mates
            // alive: R1 survives forward, R2 via its reverse complement
            assert!(c.pairs_with_candidates >= 28, "n={n}: {}", c.pairs_with_candidates);
            assert!(2 * c.pairs_with_candidates <= c.reads_with_candidates, "n={n}");
        }
        assert_eq!(single.n_pairs, 0, "single-end runs report no pairs");
        // odd streams are rejected
        let err = sim
            .simulate_stream_paired(reads[..3].iter().map(Ok), 1, EngineKind::Rust, SimdMode::Off)
            .unwrap_err();
        assert!(err.to_string().contains("even"), "{err}");
    }

    #[test]
    fn timing_modes_order() {
        let (idx, reads) = setup(80);
        let c = FullSystemSim::new(&idx, DartPimConfig::default()).simulate(&reads);
        assert!(c.k_affine(TimingMode::Batched8) <= c.k_affine(TimingMode::PaperSerial));
    }

    #[test]
    fn low_th_routes_to_riscv() {
        let (idx, reads) = setup(100);
        // with an absurd lowTh everything goes to the RISC-V side
        let all_riscv = DartPimConfig { low_th: usize::MAX, ..Default::default() };
        let sim = FullSystemSim::new(&idx, all_riscv);
        assert_eq!(sim.xbars_used, 0);
        let c = sim.simulate(&reads);
        assert_eq!(c.routed_pairs, 0);
        assert!(c.riscv_pairs > 0);
        assert_eq!(c.linear_instances, 0);
        assert!(c.riscv_linear_instances > 0);
    }
}
