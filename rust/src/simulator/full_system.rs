//! Full-system simulator (paper §VI item 1).
//!
//! Performs the offline crossbar assignment and routes a concrete read
//! workload through it, counting:
//!
//! * **instances** `J_L` / `J_A` — total linear / affine WF computations
//!   (drive Eq. 7, energy), and
//! * **iterations** `K_L` / `K_A` — lock-step rounds at the bottleneck
//!   crossbar (drive Eq. 6, execution time: all crossbars receive the
//!   same broadcast op sequence, so the busiest crossbar paces the run).
//!
//! Filtering policy: every segment whose linear WF distance passes
//! (<= eth) proceeds to affine alignment ("AllPassing"). On the paper's
//! human dataset this yields ~45 affine instances per read, consistent
//! with its energy and RISC-V-load numbers (DESIGN.md §4 derivation).
//!
//! Affine iteration accounting ([`TimingMode`]):
//! * `PaperSerial` — one affine instance per lock-step round
//!   (`K_A ≈` affine instances at the bottleneck). This reproduces the
//!   paper's reported execution times (43.8 s / 87 s / 174 s for
//!   maxReads = 12.5k/25k/50k at 389 M reads) within ~12 %.
//! * `Batched8` — the idealized 8-instances-per-round mode the affine
//!   buffer geometry permits; reported as an ablation.

use std::collections::HashMap;
use std::thread;

use crate::index::{shard_of, MinimizerIndex};
use crate::params::ETH;
use crate::pim::DartPimConfig;
use crate::runtime::{default_engine, EngineKind, WfEngine};
use crate::seeding::{seed_read, ReadSeed};

/// Engine flush size for the shard filter pass (the largest artifact
/// batch; big enough that the bit-parallel engine runs full 64-lane
/// words).
const SIM_FILTER_BATCH: usize = 256;

/// How affine lock-step rounds are counted (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TimingMode {
    /// One affine instance per lock-step round (reproduces the paper).
    #[default]
    PaperSerial,
    /// Idealized 8-instances-per-round ablation.
    Batched8,
}

/// Counters produced by one simulated run.
#[derive(Debug, Clone, Default)]
pub struct SimCounts {
    /// Reads in the simulated workload.
    pub n_reads: u64,
    /// (read, minimizer) pairs routed to crossbars.
    pub routed_pairs: u64,
    /// Pairs dropped by the maxReads cap (accuracy loss).
    pub dropped_pairs: u64,
    /// Pairs routed to the DP-RISC-V cores (lowTh minimizers).
    pub riscv_pairs: u64,
    /// J_L: linear WF instances in DP-memory.
    pub linear_instances: u64,
    /// J_A: affine WF instances in DP-memory.
    pub affine_instances: u64,
    /// Linear WF instances computed by the RISC-V cores.
    pub riscv_linear_instances: u64,
    /// Affine WF instances computed by the RISC-V cores.
    pub riscv_affine_instances: u64,
    /// Linear lock-step rounds at the bottleneck crossbar (K_L).
    pub k_linear: u64,
    /// Affine instances at the bottleneck crossbar (pre TimingMode).
    pub bottleneck_affine: u64,
    /// Number of crossbars that received any work.
    pub active_xbars: u64,
    /// Reads with at least one surviving (affine-aligned) PL.
    pub reads_with_candidates: u64,
}

impl SimCounts {
    /// K_A under a timing mode.
    pub fn k_affine(&self, mode: TimingMode) -> u64 {
        match mode {
            TimingMode::PaperSerial => self.bottleneck_affine,
            TimingMode::Batched8 => self.bottleneck_affine.div_ceil(8),
        }
    }

    /// Fraction of affine work on the RISC-V cores (paper: 0.16 %).
    pub fn riscv_affine_share(&self) -> f64 {
        let total = self.affine_instances + self.riscv_affine_instances;
        if total == 0 {
            return 0.0;
        }
        self.riscv_affine_instances as f64 / total as f64
    }

    /// Average linear instances (PLs) per read — §II motivation.
    pub fn pls_per_read(&self) -> f64 {
        if self.n_reads == 0 {
            return 0.0;
        }
        (self.linear_instances + self.riscv_linear_instances) as f64 / self.n_reads as f64
    }

    /// Filter pass rate (affine instances / linear instances).
    pub fn pass_rate(&self) -> f64 {
        if self.linear_instances == 0 {
            return 0.0;
        }
        self.affine_instances as f64 / self.linear_instances as f64
    }
}

/// Per-shard partial result of the workload simulation (private to the
/// shard merge in [`FullSystemSim::simulate_threaded`]).
struct ShardSimCounts {
    counts: SimCounts,
    pairs_per_xbar: HashMap<u32, u64>,
    affine_per_xbar: HashMap<u32, u64>,
    candidates: Vec<bool>,
}

/// Offline crossbar assignment: each minimizer above lowTh owns
/// `ceil(occurrences / linear_rows)` crossbars.
pub struct FullSystemSim<'a> {
    /// The minimizer index being simulated against.
    pub index: &'a MinimizerIndex,
    /// Architecture configuration.
    pub cfg: DartPimConfig,
    /// minimizer -> (first crossbar id, number of crossbars), for
    /// minimizers assigned to DP-memory.
    assignment: HashMap<u64, (u32, u32)>,
    /// Total crossbars allocated.
    pub xbars_used: u32,
}

impl<'a> FullSystemSim<'a> {
    /// Build the offline assignment (paper §V-B / Fig. 7a).
    pub fn new(index: &'a MinimizerIndex, cfg: DartPimConfig) -> Self {
        let mut assignment = HashMap::new();
        let mut next = 0u32;
        // deterministic order: sort minimizers for reproducible layouts
        let mut minis: Vec<(u64, usize)> =
            index.iter().map(|(m, occ)| (m, occ.len())).collect();
        minis.sort_unstable();
        for (m, occ) in minis {
            if occ > cfg.low_th {
                let n = occ.div_ceil(cfg.linear_rows) as u32;
                assignment.insert(m, (next, n));
                next += n;
            }
        }
        FullSystemSim { index, cfg, assignment, xbars_used: next }
    }

    /// Where a minimizer lives: `Some((first_xbar, n_xbars))` for
    /// DP-memory minimizers, `None` for RISC-V (lowTh) ones.
    pub fn assignment_of(&self, minimizer: u64) -> Option<(u32, u32)> {
        self.assignment.get(&minimizer).copied()
    }

    /// Simulate the online phase over a workload, running the actual
    /// linear filter per segment (Rust mirror of the L1 kernel).
    pub fn simulate(&self, reads: &[crate::genome::ReadRecord]) -> SimCounts {
        self.simulate_threaded(reads, 1)
    }

    /// [`Self::simulate`] sharded across `n_threads` worker threads on
    /// the [`default_engine`] filter engine.
    pub fn simulate_threaded(
        &self,
        reads: &[crate::genome::ReadRecord],
        n_threads: usize,
    ) -> SimCounts {
        self.simulate_threaded_with(reads, n_threads, default_engine())
    }

    /// [`Self::simulate`] sharded across `n_threads` worker threads,
    /// filtering through `engine` (each worker constructs its own — the
    /// reason the PJRT engine is not an [`EngineKind`]).
    ///
    /// (read, minimizer) pairs are partitioned by minimizer hash
    /// ([`shard_of`]) exactly like the live pipeline, so each worker's
    /// per-crossbar cap accounting touches a disjoint crossbar set and
    /// the merged counts are identical to the serial path for every
    /// thread count — and, because the engines share one numerics
    /// contract, for every engine kind.
    pub fn simulate_threaded_with(
        &self,
        reads: &[crate::genome::ReadRecord],
        n_threads: usize,
        engine: EngineKind,
    ) -> SimCounts {
        let n = n_threads.max(1);
        // stage 1 (serial): seed every read, partition pairs by minimizer
        let mut shards: Vec<Vec<(u32, ReadSeed)>> = (0..n).map(|_| Vec::new()).collect();
        for (ri, read) in reads.iter().enumerate() {
            for seed in seed_read(self.index, &read.seq) {
                if self.index.occurrences(seed.kmer).is_empty() {
                    continue;
                }
                shards[shard_of(seed.kmer, n)].push((ri as u32, seed));
            }
        }

        // stage 2: per-shard workload counting (threaded when asked)
        let parts: Vec<ShardSimCounts> = if n == 1 {
            vec![self.simulate_shard(reads, &shards[0], engine)]
        } else {
            thread::scope(|s| {
                let handles: Vec<_> = shards
                    .iter()
                    .map(|items| s.spawn(move || self.simulate_shard(reads, items, engine)))
                    .collect();
                handles.into_iter().map(|h| h.join().expect("sim shard panicked")).collect()
            })
        };

        // deterministic merge: sums and disjoint map unions
        let mut c = SimCounts { n_reads: reads.len() as u64, ..Default::default() };
        let mut pairs_per_xbar: HashMap<u32, u64> = HashMap::new();
        let mut affine_per_xbar: HashMap<u32, u64> = HashMap::new();
        let mut candidates = vec![false; reads.len()];
        for p in parts {
            c.routed_pairs += p.counts.routed_pairs;
            c.dropped_pairs += p.counts.dropped_pairs;
            c.riscv_pairs += p.counts.riscv_pairs;
            c.linear_instances += p.counts.linear_instances;
            c.affine_instances += p.counts.affine_instances;
            c.riscv_linear_instances += p.counts.riscv_linear_instances;
            c.riscv_affine_instances += p.counts.riscv_affine_instances;
            for (k, v) in p.pairs_per_xbar {
                *pairs_per_xbar.entry(k).or_default() += v;
            }
            for (k, v) in p.affine_per_xbar {
                *affine_per_xbar.entry(k).or_default() += v;
            }
            for (i, had) in p.candidates.into_iter().enumerate() {
                candidates[i] |= had;
            }
        }
        c.reads_with_candidates = candidates.iter().filter(|&&x| x).count() as u64;
        c.k_linear = pairs_per_xbar.values().copied().max().unwrap_or(0);
        c.bottleneck_affine = affine_per_xbar.values().copied().max().unwrap_or(0);
        c.active_xbars = pairs_per_xbar.len() as u64;
        c
    }

    /// Count one shard's workload: the serial per-pair semantics over a
    /// partition-ordered item list (cap accounting stays exact because a
    /// minimizer's crossbars belong to exactly one shard).
    ///
    /// Routing and cap accounting stay per-pair (order-sensitive); the
    /// surviving WF instances accumulate into a [`SIM_FILTER_BATCH`]
    /// buffer that drains through `engine` as it fills, so memory stays
    /// bounded no matter the workload. Instance results are independent,
    /// so batch boundaries cannot change any count.
    fn simulate_shard(
        &self,
        reads: &[crate::genome::ReadRecord],
        items: &[(u32, ReadSeed)],
        engine: EngineKind,
    ) -> ShardSimCounts {
        // one pending filter instance: read index, owning crossbar
        // (None = RISC-V pool), read slice, extracted window
        struct Pending<'r> {
            ri: u32,
            xbar: Option<u32>,
            read: &'r [u8],
            win: Vec<u8>,
        }
        /// Run the buffered instances through the engine (Rust mirror of
        /// the L1 kernel, scalar or bit-parallel — identical numerics)
        /// and fold the pass/fail results into the shard counters.
        fn drain(
            wf: &mut (dyn WfEngine + Send),
            pending: &mut Vec<Pending<'_>>,
            p: &mut ShardSimCounts,
        ) {
            if pending.is_empty() {
                return;
            }
            let rr: Vec<&[u8]> = pending.iter().map(|x| x.read).collect();
            let ww: Vec<&[u8]> = pending.iter().map(|x| x.win.as_slice()).collect();
            let out = wf.linear_batch(&rr, &ww).expect("simulator filter batch");
            for (inst, &best) in pending.iter().zip(&out.best) {
                if best > ETH as i32 {
                    continue;
                }
                p.candidates[inst.ri as usize] = true;
                match inst.xbar {
                    None => p.counts.riscv_affine_instances += 1,
                    Some(xb) => {
                        p.counts.affine_instances += 1;
                        *p.affine_per_xbar.entry(xb).or_default() += 1;
                    }
                }
            }
            pending.clear();
        }

        let mut p = ShardSimCounts {
            counts: SimCounts::default(),
            pairs_per_xbar: HashMap::new(),
            affine_per_xbar: HashMap::new(),
            candidates: vec![false; reads.len()],
        };
        let mut wf = engine.build();
        let mut pending: Vec<Pending<'_>> = Vec::with_capacity(SIM_FILTER_BATCH);
        for &(ri, ref seed) in items {
            let read = &reads[ri as usize];
            let occs = self.index.occurrences(seed.kmer);
            match self.assignment_of(seed.kmer) {
                None => {
                    // lowTh minimizer: the RISC-V cores run both WF
                    // stages for every occurrence.
                    p.counts.riscv_pairs += 1;
                    p.counts.riscv_linear_instances += occs.len() as u64;
                    for &pos in occs {
                        pending.push(Pending {
                            ri,
                            xbar: None,
                            read: &read.seq,
                            win: self.index.window_for(pos, seed.read_offset as usize),
                        });
                    }
                }
                Some((first, n)) => {
                    // the read is broadcast to every crossbar of the
                    // minimizer; the FIFO cap applies per crossbar
                    let cap = self.cfg.max_reads as u64;
                    let count = p.pairs_per_xbar.entry(first).or_default();
                    if *count >= cap {
                        p.counts.dropped_pairs += 1;
                        continue;
                    }
                    *count += 1;
                    for sub in 1..n {
                        *p.pairs_per_xbar.entry(first + sub).or_default() += 1;
                    }
                    p.counts.routed_pairs += 1;
                    p.counts.linear_instances += occs.len() as u64;
                    for (i, &pos) in occs.iter().enumerate() {
                        pending.push(Pending {
                            ri,
                            xbar: Some(first + (i / self.cfg.linear_rows) as u32),
                            read: &read.seq,
                            win: self.index.window_for(pos, seed.read_offset as usize),
                        });
                    }
                }
            }
            if pending.len() >= SIM_FILTER_BATCH {
                drain(wf.as_mut(), &mut pending, &mut p);
            }
        }
        drain(wf.as_mut(), &mut pending, &mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{K, READ_LEN, W};

    fn setup(n_reads: usize) -> (MinimizerIndex, Vec<crate::genome::ReadRecord>) {
        let g = SynthConfig { len: 120_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        (idx, reads)
    }

    #[test]
    fn assignment_covers_all_frequent_minimizers() {
        let (idx, _) = setup(1);
        // small genomes have few minimizers above the human-scale lowTh
        let cfg = DartPimConfig { low_th: 1, ..Default::default() };
        let sim = FullSystemSim::new(&idx, cfg.clone());
        let mut covered = 0;
        for (m, occ) in idx.iter() {
            if occ.len() > cfg.low_th {
                let (_, n) = sim.assignment_of(m).expect("frequent minimizer assigned");
                assert_eq!(n as usize, occ.len().div_ceil(cfg.linear_rows));
                covered += 1;
            } else {
                assert!(sim.assignment_of(m).is_none());
            }
        }
        assert!(covered > 0);
        assert!(sim.xbars_used > 0);
    }

    #[test]
    fn counts_are_consistent() {
        let (idx, reads) = setup(120);
        let sim =
            FullSystemSim::new(&idx, DartPimConfig { low_th: 0, ..Default::default() });
        let c = sim.simulate(&reads);
        assert_eq!(c.n_reads, 120);
        assert!(c.routed_pairs > 0);
        assert!(c.linear_instances >= c.routed_pairs, "each pair has >= 1 segment");
        assert!(c.affine_instances <= c.linear_instances, "filter can only shrink");
        assert!(c.k_linear > 0 && c.k_linear <= c.routed_pairs);
        assert!(c.bottleneck_affine <= c.affine_instances);
        assert!(c.reads_with_candidates <= c.n_reads);
        // simulated reads come from the reference: nearly all must survive
        assert!(
            c.reads_with_candidates as f64 / c.n_reads as f64 > 0.9,
            "survival = {}/{}",
            c.reads_with_candidates,
            c.n_reads
        );
    }

    #[test]
    fn max_reads_cap_drops_pairs() {
        // high coverage so overlapping reads share minimizers
        let g = SynthConfig { len: 20_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 400, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        // low_th = 0 so every minimizer is crossbar-assigned (a 20 kbp
        // genome has few minimizers above the default lowTh = 3)
        let tight = DartPimConfig { max_reads: 1, low_th: 0, ..Default::default() };
        let sim = FullSystemSim::new(&idx, tight);
        let c = sim.simulate(&reads);
        assert!(c.dropped_pairs > 0, "cap of 1 read/crossbar must drop work");
        let loose = DartPimConfig { low_th: 0, ..Default::default() };
        let loose = FullSystemSim::new(&idx, loose).simulate(&reads);
        assert_eq!(loose.dropped_pairs, 0);
        assert!(loose.routed_pairs > c.routed_pairs);
    }

    #[test]
    fn threaded_simulation_matches_serial() {
        let (idx, reads) = setup(150);
        let sim =
            FullSystemSim::new(&idx, DartPimConfig { low_th: 1, ..Default::default() });
        let serial = sim.simulate(&reads);
        for n in [2usize, 4, 7] {
            let t = sim.simulate_threaded(&reads, n);
            assert_eq!(t.n_reads, serial.n_reads, "n={n}");
            assert_eq!(t.routed_pairs, serial.routed_pairs, "n={n}");
            assert_eq!(t.dropped_pairs, serial.dropped_pairs, "n={n}");
            assert_eq!(t.riscv_pairs, serial.riscv_pairs, "n={n}");
            assert_eq!(t.linear_instances, serial.linear_instances, "n={n}");
            assert_eq!(t.affine_instances, serial.affine_instances, "n={n}");
            assert_eq!(t.riscv_linear_instances, serial.riscv_linear_instances, "n={n}");
            assert_eq!(t.riscv_affine_instances, serial.riscv_affine_instances, "n={n}");
            assert_eq!(t.k_linear, serial.k_linear, "n={n}");
            assert_eq!(t.bottleneck_affine, serial.bottleneck_affine, "n={n}");
            assert_eq!(t.active_xbars, serial.active_xbars, "n={n}");
            assert_eq!(t.reads_with_candidates, serial.reads_with_candidates, "n={n}");
        }
    }

    #[test]
    fn timing_modes_order() {
        let (idx, reads) = setup(80);
        let c = FullSystemSim::new(&idx, DartPimConfig::default()).simulate(&reads);
        assert!(c.k_affine(TimingMode::Batched8) <= c.k_affine(TimingMode::PaperSerial));
    }

    #[test]
    fn low_th_routes_to_riscv() {
        let (idx, reads) = setup(100);
        // with an absurd lowTh everything goes to the RISC-V side
        let all_riscv = DartPimConfig { low_th: usize::MAX, ..Default::default() };
        let sim = FullSystemSim::new(&idx, all_riscv);
        assert_eq!(sim.xbars_used, 0);
        let c = sim.simulate(&reads);
        assert_eq!(c.routed_pairs, 0);
        assert!(c.riscv_pairs > 0);
        assert_eq!(c.linear_instances, 0);
        assert!(c.riscv_linear_instances > 0);
    }
}
