//! DP-RISC-V model (paper §VI item 3, Table VI).
//!
//! The paper simulates AndesCore AX25 cores in GEM5 and reports a single
//! calibrated constant — 88 µs per affine WF instance — plus the policy
//! split: minimizers with reference frequency <= lowTh are computed on
//! the cores (0.16 % of affine instances on the human dataset).

/// RISC-V timing model.
#[derive(Debug, Clone)]
pub struct RiscvModel {
    /// Seconds per affine WF instance on one core (Table VI: 88 µs).
    pub affine_instance_s: f64,
    /// Linear WF is ~5x cheaper than affine on the cores (cycle ratio of
    /// the two algorithms; used only when lowTh routing sends the filter
    /// there too).
    pub linear_instance_s: f64,
    /// Number of cores.
    pub n_cores: usize,
}

impl Default for RiscvModel {
    fn default() -> Self {
        RiscvModel { affine_instance_s: 88e-6, linear_instance_s: 88e-6 / 5.0, n_cores: 128 }
    }
}

impl RiscvModel {
    /// Wall-clock time to process the RISC-V share, all cores parallel.
    pub fn exec_time(&self, linear_instances: u64, affine_instances: u64) -> f64 {
        (linear_instances as f64 * self.linear_instance_s
            + affine_instances as f64 * self.affine_instance_s)
            / self.n_cores as f64
    }

    /// Aggregate busy core-seconds (for energy accounting).
    pub fn busy_core_seconds(&self, linear_instances: u64, affine_instances: u64) -> f64 {
        self.exec_time(linear_instances, affine_instances) * self.n_cores as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example() {
        // paper §VII-C: 0.16 % of affine instances in 19.4 s on 128 cores
        // => 19.4 * 128 / 88e-6 = 28.2 M instances
        let m = RiscvModel::default();
        let inst = (19.4 * 128.0 / 88e-6) as u64;
        let t = m.exec_time(0, inst);
        assert!((t - 19.4).abs() < 0.1, "t={t}");
    }

    #[test]
    fn scales_inverse_with_cores() {
        let m = RiscvModel { n_cores: 64, ..Default::default() };
        let t64 = m.exec_time(0, 1_000_000);
        let m128 = RiscvModel::default();
        let t128 = m128.exec_time(0, 1_000_000);
        assert!((t64 / t128 - 2.0).abs() < 1e-9);
    }
}
