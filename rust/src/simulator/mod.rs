//! The paper's evaluation simulators (§VI):
//!
//! * [`full_system`] — routes a real workload through the crossbar
//!   assignment (minimizer -> crossbar, lowTh RISC-V offload, maxReads
//!   capping), counts WF instances (J_L/J_A of Eq. 7) and lock-step
//!   iterations (K_L/K_A of Eq. 6).
//! * [`riscv`]  — DP-RISC-V latency/occupancy model (GEM5 stand-in:
//!   the paper's measured 88 µs/affine-instance constant).
//! * [`report`] — turns counts into execution time / energy / area
//!   efficiency reports and projects them to the paper's 389 M-read
//!   dataset (Figs. 9/10).
//!
//! The single-crossbar and controller "simulators" live in [`crate::pim`].

pub mod full_system;
pub mod report;
pub mod riscv;

pub use full_system::{FullSystemSim, SimCounts, TimingMode};
pub use report::SystemReport;
