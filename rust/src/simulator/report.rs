//! System-level reporting: Eq. 6 execution time, Eq. 7 energy, and the
//! throughput / energy-efficiency / area-efficiency metrics of Fig. 9,
//! with projection of simulated counts to the paper's dataset scale.

use super::full_system::{SimCounts, TimingMode};
use super::riscv::RiscvModel;
use crate::pim::area::{AreaBreakdown, AreaModel};
use crate::pim::energy::{EnergyBreakdown, EnergyModel};
use crate::pim::xbar_sim::{affine_instance_cost, linear_instance_cost, CostSource};
use crate::pim::DartPimConfig;

/// Result-readout payload per affine instance (read id + PL + distance).
pub const RESULT_BITS_PER_INSTANCE: u64 = 72;
/// Traceback payload read out for each read's final winner (4 bits x 13
/// band cells x 150 rows + header).
pub const TRACEBACK_BITS_PER_READ: u64 = 7_800 + RESULT_BITS_PER_INSTANCE;
/// RISC-V <-> DP-memory bus bandwidth (Table VI: 32 GB/s).
pub const BUS_BYTES_PER_S: f64 = 32e9;

/// Full evaluation report for one configuration + workload.
#[derive(Debug, Clone)]
pub struct SystemReport {
    /// The workload counts the report was built from.
    pub counts: SimCounts,
    /// The architecture configuration.
    pub cfg: DartPimConfig,
    /// Execution-time component (Fig. 10a): DP-memory lock-step rounds.
    /// The run is paced by the slowest of the three components.
    pub t_dpmem_s: f64,
    /// Execution-time component: DP-RISC-V offload compute.
    pub t_riscv_s: f64,
    /// Execution-time component: result readout over the bus.
    pub t_readout_s: f64,
    /// End-to-end execution time (Eq. 6).
    pub exec_time_s: f64,
    /// Energy breakdown (Eq. 7 / Fig. 10b).
    pub energy: EnergyBreakdown,
    /// Area breakdown (Fig. 10c).
    pub area: AreaBreakdown,
}

impl SystemReport {
    /// Mapped reads per second.
    pub fn throughput(&self) -> f64 {
        self.counts.n_reads as f64 / self.exec_time_s
    }

    /// Reads per joule (Fig. 9 middle).
    pub fn energy_efficiency(&self) -> f64 {
        self.counts.n_reads as f64 / self.energy.total()
    }

    /// Reads per second per mm² (Fig. 9 right).
    pub fn area_efficiency(&self) -> f64 {
        self.throughput() / self.area.total()
    }

    /// Average power (Fig. 10b annotation).
    pub fn avg_power_w(&self) -> f64 {
        self.energy.avg_power(self.exec_time_s)
    }
}

/// Build a report from simulated counts.
pub fn build_report(
    counts: &SimCounts,
    cfg: &DartPimConfig,
    cost: CostSource,
    timing: TimingMode,
) -> SystemReport {
    let lin = linear_instance_cost(cost);
    let aff = affine_instance_cost(cost);
    let energy_model = EnergyModel::default();
    let riscv = RiscvModel { n_cores: cfg.total_riscv(), ..Default::default() };

    // Eq. 6 — lock-step rounds x per-round cycles x cycle time.
    let k_l = counts.k_linear;
    let k_a = counts.k_affine(timing);
    let t_dpmem = (k_l * lin.total_cycles() + k_a * aff.total_cycles()) as f64 * cfg.t_clk;
    let t_riscv = riscv.exec_time(counts.riscv_linear_instances, counts.riscv_affine_instances);

    let bits_in = counts.n_reads as f64 * 2.0 * crate::params::READ_LEN as f64;
    let bits_out = counts.affine_instances as f64 * RESULT_BITS_PER_INSTANCE as f64
        + counts.reads_with_candidates as f64 * TRACEBACK_BITS_PER_READ as f64;
    let t_readout = bits_out / 8.0 / BUS_BYTES_PER_S;

    let exec = t_dpmem.max(t_riscv).max(t_readout);
    let energy = energy_model.breakdown(
        cfg,
        &lin,
        &aff,
        counts.linear_instances,
        counts.affine_instances,
        bits_in,
        bits_out,
        riscv.busy_core_seconds(counts.riscv_linear_instances, counts.riscv_affine_instances),
        exec,
    );
    let area = AreaModel::default().breakdown(cfg);
    SystemReport {
        counts: counts.clone(),
        cfg: cfg.clone(),
        t_dpmem_s: t_dpmem,
        t_riscv_s: t_riscv,
        t_readout_s: t_readout,
        exec_time_s: exec,
        energy,
        area,
    }
}

/// Project simulated counts to a larger dataset (e.g. the paper's 389 M
/// reads): totals scale linearly; the bottleneck crossbar saturates at
/// the maxReads cap (that is the cap's purpose).
pub fn scale_counts(c: &SimCounts, target_reads: u64, cfg: &DartPimConfig) -> SimCounts {
    let f = target_reads as f64 / c.n_reads.max(1) as f64;
    let s = |v: u64| (v as f64 * f).round() as u64;
    let affine_ratio = if c.k_linear == 0 {
        0.0
    } else {
        c.bottleneck_affine as f64 / c.k_linear as f64
    };
    let k_linear = (s(c.k_linear)).min(cfg.max_reads as u64);
    SimCounts {
        n_reads: target_reads,
        routed_pairs: s(c.routed_pairs),
        dropped_pairs: s(c.dropped_pairs),
        riscv_pairs: s(c.riscv_pairs),
        linear_instances: s(c.linear_instances),
        affine_instances: s(c.affine_instances),
        riscv_linear_instances: s(c.riscv_linear_instances),
        riscv_affine_instances: s(c.riscv_affine_instances),
        k_linear,
        bottleneck_affine: (k_linear as f64 * affine_ratio).round() as u64,
        active_xbars: c.active_xbars,
        reads_with_candidates: s(c.reads_with_candidates),
        // pair totals scale like every other per-read quantity
        n_pairs: s(c.n_pairs),
        pairs_with_candidates: s(c.pairs_with_candidates),
    }
}

/// Synthetic counts matching the paper's reported human-genome workload
/// statistics (§II: ~1000 PLs/read; energy figures imply ~45 affine
/// instances/read and a saturated bottleneck crossbar). Used to
/// regenerate Figs. 9/10 with the paper's own workload, independent of
/// our synthetic genome.
pub fn paper_workload_counts(cfg: &DartPimConfig) -> SimCounts {
    let n_reads: u64 = 389_000_000;
    let pls_per_read = 707.0; // back-solved from Fig. 10b (DESIGN.md §4)
    let affine_per_read = 44.7;
    let riscv_share = 0.0016;
    let affine_total = (n_reads as f64 * affine_per_read) as u64;
    let riscv_affine = (affine_total as f64 * riscv_share) as u64;
    SimCounts {
        n_reads,
        routed_pairs: n_reads * 10,
        dropped_pairs: 0,
        riscv_pairs: (n_reads as f64 * 10.0 * riscv_share) as u64,
        linear_instances: (n_reads as f64 * pls_per_read) as u64,
        affine_instances: affine_total - riscv_affine,
        // lowTh minimizers have <= 3 occurrences by definition, so the
        // RISC-V linear share is ~3 instances per routed pair
        riscv_linear_instances: (n_reads as f64 * 10.0 * riscv_share * 3.0) as u64,
        riscv_affine_instances: riscv_affine,
        k_linear: cfg.max_reads as u64,
        bottleneck_affine: cfg.max_reads as u64,
        active_xbars: 8 * 1024 * 1024,
        reads_with_candidates: n_reads,
        // the paper's workload is modelled single-end
        n_pairs: 0,
        pairs_with_candidates: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_execution_times_reproduced() {
        // paper §VII-C: 43.8 s / ~87 s / 174 s for maxReads 12.5k/25k/50k
        for (max_reads, paper_s) in [(12_500usize, 43.8), (25_000, 87.2), (50_000, 174.0)] {
            let cfg = DartPimConfig::with_max_reads(max_reads);
            let counts = paper_workload_counts(&cfg);
            let r = build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial);
            let ratio = r.exec_time_s / paper_s;
            assert!(
                (0.85..=1.15).contains(&ratio),
                "maxReads={max_reads}: {}s vs paper {paper_s}s",
                r.exec_time_s
            );
        }
    }

    #[test]
    fn paper_energy_reproduced() {
        // paper §VII-D: 20.8 kJ (12.5k) .. 34.9 kJ (50k); DP-memory
        // compute portion 16.6-18.8 kJ
        let cfg = DartPimConfig::with_max_reads(12_500);
        let counts = paper_workload_counts(&cfg);
        let r = build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial);
        let xbar_kj = r.energy.crossbars / 1e3;
        assert!((14.0..=19.0).contains(&xbar_kj), "crossbars = {xbar_kj} kJ");
        let total_kj = r.energy.total() / 1e3;
        assert!((16.0..=27.0).contains(&total_kj), "total = {total_kj} kJ");
    }

    #[test]
    fn throughput_beats_parabricks_by_paper_margin() {
        // paper: 5.7x over Parabricks (786k reads/s) at maxReads = 25k
        let cfg = DartPimConfig::with_max_reads(25_000);
        let counts = paper_workload_counts(&cfg);
        let r = build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial);
        let speedup = r.throughput() / (389e6 / 495.0);
        assert!((4.5..=7.5).contains(&speedup), "speedup vs Parabricks = {speedup}");
    }

    #[test]
    fn scaling_preserves_rates_and_caps_bottleneck() {
        let cfg = DartPimConfig::with_max_reads(12_500);
        let small = SimCounts {
            n_reads: 1000,
            routed_pairs: 9500,
            linear_instances: 120_000,
            affine_instances: 9_000,
            riscv_linear_instances: 500,
            riscv_affine_instances: 40,
            riscv_pairs: 60,
            k_linear: 800,
            bottleneck_affine: 700,
            active_xbars: 5000,
            reads_with_candidates: 990,
            dropped_pairs: 0,
            n_pairs: 500,
            pairs_with_candidates: 490,
        };
        let big = scale_counts(&small, 389_000_000, &cfg);
        assert_eq!(big.n_reads, 389_000_000);
        assert_eq!(big.k_linear, 12_500, "bottleneck saturates at maxReads");
        let r_small = small.pls_per_read();
        let r_big = big.pls_per_read();
        assert!((r_small - r_big).abs() / r_small < 0.01);
    }

    #[test]
    fn exec_time_is_max_of_components() {
        let cfg = DartPimConfig::default();
        let counts = paper_workload_counts(&cfg);
        let r = build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial);
        assert!(r.exec_time_s >= r.t_dpmem_s);
        assert!(r.exec_time_s >= r.t_riscv_s);
        assert!(r.exec_time_s >= r.t_readout_s);
        assert_eq!(r.exec_time_s, r.t_dpmem_s.max(r.t_riscv_s).max(r.t_readout_s));
    }

    #[test]
    fn batched_mode_is_faster() {
        let cfg = DartPimConfig::default();
        let counts = paper_workload_counts(&cfg);
        let serial = build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::PaperSerial);
        let batched = build_report(&counts, &cfg, CostSource::PaperTable4, TimingMode::Batched8);
        assert!(batched.t_dpmem_s < serial.t_dpmem_s);
    }
}
