//! Exhaustive CPU ground-truth mapper (the BWA-MEM stand-in, DESIGN.md
//! §6): seed with the minimizer index, then run an unbanded affine
//! semi-global alignment against the full segment of *every* PL and keep
//! the global best. No banding, no saturation, no maxReads caps — the
//! accuracy oracle DART-PIM is measured against (paper §VII-A).

use crate::align::full_dp::semi_global_affine;
use crate::genome::encode::Seq;
use crate::index::MinimizerIndex;
use crate::seeding::seeder::all_seed_hits;

/// One mapping decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Mapping {
    /// 0-based reference position of the read's first aligned base.
    pub pos: i64,
    /// Alignment cost (affine, unit costs).
    pub dist: i32,
}

/// The exhaustive mapper.
pub struct CpuMapper<'a> {
    /// The minimizer index used for seeding.
    pub index: &'a MinimizerIndex,
}

impl<'a> CpuMapper<'a> {
    /// Mapper over `index`.
    pub fn new(index: &'a MinimizerIndex) -> Self {
        CpuMapper { index }
    }

    /// Map one read: best (dist, then leftmost pos) over all PLs.
    /// Returns `None` when seeding yields no candidate at all.
    pub fn map(&self, read: &Seq) -> Option<Mapping> {
        let mut best: Option<Mapping> = None;
        // dart-analyze: allow(determinism): membership-only dedup set —
        // insert() return value gates re-evaluation and the set is never
        // iterated; candidate order comes from all_seed_hits, and the
        // (dist, pos) min below is order-free.
        let mut evaluated = std::collections::HashSet::new();
        for hit in all_seed_hits(self.index, read) {
            // distinct segments only: one evaluation per occurrence
            if !evaluated.insert(hit.ref_pos) {
                continue;
            }
            let seg = self.index.segment(hit.ref_pos);
            let sg = semi_global_affine(read, &seg);
            let seg_start = hit.ref_pos as i64
                - ((self.index.read_len - self.index.k) + crate::params::ETH) as i64;
            let m = Mapping { pos: seg_start + sg.start as i64, dist: sg.dist };
            best = match best {
                None => Some(m),
                Some(b) if (m.dist, m.pos) < (b.dist, b.pos) => Some(m),
                b => b,
            };
        }
        best
    }

    /// Map a batch, preserving order.
    pub fn map_all(&self, reads: &[Seq]) -> Vec<Option<Mapping>> {
        reads.iter().map(|r| self.map(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{K, READ_LEN, W};

    fn setup() -> MinimizerIndex {
        let g = SynthConfig { len: 100_000, ..Default::default() }.generate();
        MinimizerIndex::build(g, K, W, READ_LEN)
    }

    #[test]
    fn error_free_reads_map_exactly() {
        let idx = setup();
        let reads = ReadSimConfig {
            n_reads: 40,
            sub_rate: 0.0,
            ins_rate: 0.0,
            del_rate: 0.0,
            ..Default::default()
        }
        .simulate(&idx.reference, |p| p as u32);
        let mapper = CpuMapper::new(&idx);
        let mut exact = 0;
        for r in &reads {
            let m = mapper.map(&r.seq).expect("error-free read must map");
            assert_eq!(m.dist, 0, "error-free read has a zero-cost alignment");
            if m.pos == r.truth_pos as i64 {
                exact += 1;
            }
        }
        // repeats can legitimately produce equal-cost alternates
        assert!(exact >= 36, "exact = {exact}/40");
    }

    #[test]
    fn noisy_reads_map_near_truth() {
        let idx = setup();
        let reads = ReadSimConfig { n_reads: 60, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let mapper = CpuMapper::new(&idx);
        let mut near = 0;
        for r in &reads {
            if let Some(m) = mapper.map(&r.seq) {
                if (m.pos - r.truth_pos as i64).abs() <= 5 {
                    near += 1;
                }
            }
        }
        assert!(near as f64 / reads.len() as f64 > 0.9, "near = {near}/60");
    }

    #[test]
    fn garbage_reads_do_not_map_well() {
        let idx = setup();
        let mut rng = crate::util::SmallRng::seed_from_u64(99);
        let junk: Seq = (0..READ_LEN).map(|_| rng.gen_range(0..4)).collect();
        if let Some(m) = CpuMapper::new(&idx).map(&junk) {
            assert!(m.dist > 10, "random read should align poorly, dist={}", m.dist);
        }
    }
}
