//! Paper-reported comparison numbers (§VII, Figs. 8/9).
//!
//! All figures refer to the paper's dataset: 389 M Illumina reads of
//! 150 bp against GRCh38. Execution times and powers are the paper's
//! §VII-C/§VII-D values; areas §VII-E.

/// Reads in the paper's dataset.
pub const DATASET_READS: u64 = 389_000_000;

/// One comparator system as reported by the paper.
#[derive(Debug, Clone)]
pub struct PublishedSystem {
    /// System name as the paper labels it.
    pub name: &'static str,
    /// End-to-end execution time for the 389 M-read dataset (s).
    pub exec_time_s: f64,
    /// Total energy (J).
    pub energy_j: f64,
    /// Chip area (mm²).
    pub area_mm2: f64,
    /// Mapping accuracy (fraction; the paper's BWA-MEM-agreement metric
    /// for DART-PIM, reported metrics for the others).
    pub accuracy: f64,
}

impl PublishedSystem {
    /// Reads per second over the 389 M-read dataset.
    pub fn throughput(&self) -> f64 {
        DATASET_READS as f64 / self.exec_time_s
    }

    /// Joules per read.
    pub fn energy_per_read(&self) -> f64 {
        self.energy_j / DATASET_READS as f64
    }

    /// Reads mapped per joule (Fig. 9 energy-efficiency column).
    pub fn reads_per_joule(&self) -> f64 {
        DATASET_READS as f64 / self.energy_j
    }

    /// Throughput per mm² (Fig. 9 area-efficiency column).
    pub fn area_efficiency(&self) -> f64 {
        self.throughput() / self.area_mm2
    }
}

/// The five comparators (paper §VI/§VII).
pub fn published_systems() -> Vec<PublishedSystem> {
    vec![
        PublishedSystem {
            name: "minimap2 (CPU)",
            exec_time_s: 19_785.0, // 5.5 h on Xeon E5-2683 v4
            energy_j: 2.4e6,       // 120 W average
            area_mm2: 2_362.0,
            accuracy: 0.999,
        },
        PublishedSystem {
            name: "Parabricks (GPU)",
            exec_time_s: 495.0, // 8.3 min on DGX A100
            energy_j: 2.4e6,    // 4850 W average
            area_mm2: 46_352.0, // 8x A100 + HBM stacks
            accuracy: 0.999,
        },
        PublishedSystem {
            name: "GenASM",
            exec_time_s: 29_154.0, // scaled to 150 bp reads
            energy_j: 94.2e3,      // 3.23 W
            area_mm2: 10.7,
            accuracy: 0.966,
        },
        PublishedSystem {
            name: "SeGraM",
            exec_time_s: 22_426.0, // 1.3x GenASM throughput
            energy_j: 543e3,       // 24.2 W
            area_mm2: 27.8,
            accuracy: 0.966,
        },
        PublishedSystem {
            name: "GenVoM",
            exec_time_s: 39.2, // scaled to 150 bp reads
            energy_j: 1.4e3,   // 35.3 W
            area_mm2: 298.0,
            accuracy: 0.912,
        },
    ]
}

/// Paper-reported DART-PIM rows (for parity checks against our model).
pub fn paper_dartpim_rows() -> Vec<(usize, PublishedSystem)> {
    vec![
        (
            12_500,
            PublishedSystem {
                name: "DART-PIM (12.5k, paper)",
                exec_time_s: 43.8,
                energy_j: 20.8e3,
                area_mm2: 8_170.0,
                accuracy: 0.997,
            },
        ),
        (
            25_000,
            PublishedSystem {
                name: "DART-PIM (25k, paper)",
                exec_time_s: 87.2, // 227x faster than minimap2
                energy_j: 26.5e3,  // 90.6x better energy than minimap2
                area_mm2: 8_170.0,
                accuracy: 0.998,
            },
        ),
        (
            50_000,
            PublishedSystem {
                name: "DART-PIM (50k, paper)",
                exec_time_s: 174.0,
                energy_j: 34.9e3,
                area_mm2: 8_170.0,
                accuracy: 0.998,
            },
        ),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_ratios_hold() {
        // The abstract's headline numbers at maxReads = 25k.
        let rows = paper_dartpim_rows();
        let dart = &rows.iter().find(|(m, _)| *m == 25_000).unwrap().1;
        let systems = published_systems();
        let by = |n: &str| systems.iter().find(|s| s.name.starts_with(n)).unwrap();
        let t = |s: &PublishedSystem| dart.throughput() / s.throughput();
        assert!(
            (t(by("Parabricks")) - 5.7).abs() < 0.3,
            "Parabricks speedup {}",
            t(by("Parabricks"))
        );
        assert!(
            (t(by("SeGraM")) - 257.0).abs() / 257.0 < 0.05,
            "SeGraM speedup {}",
            t(by("SeGraM"))
        );
        assert!((t(by("minimap2")) - 227.0).abs() / 227.0 < 0.05);
        assert!((t(by("GenASM")) - 334.0).abs() / 334.0 < 0.05);
        let e = |s: &PublishedSystem| dart.reads_per_joule() / s.reads_per_joule();
        assert!(
            (e(by("Parabricks")) - 90.6).abs() / 90.6 < 0.05,
            "Parabricks energy {}",
            e(by("Parabricks"))
        );
        assert!((e(by("SeGraM")) - 20.7).abs() / 20.7 < 0.05);
        assert!((e(by("GenASM")) - 3.6).abs() / 3.6 < 0.1);
    }

    #[test]
    fn area_efficiencies_match_paper() {
        // §VII-E: GenASM 1247, SeGraM 623, minimap2 8.3, Parabricks 16.9
        let systems = published_systems();
        let by = |n: &str| systems.iter().find(|s| s.name.starts_with(n)).unwrap();
        assert!((by("GenASM").area_efficiency() - 1247.0).abs() / 1247.0 < 0.05);
        assert!((by("SeGraM").area_efficiency() - 623.0).abs() / 623.0 < 0.05);
        assert!((by("minimap2").area_efficiency() - 8.3).abs() / 8.3 < 0.05);
        assert!((by("Parabricks").area_efficiency() - 16.9).abs() / 16.9 < 0.05);
    }

    #[test]
    fn dartpim_area_efficiency_range() {
        // §VII-E: 1086 reads/mm²/s (12.5k) .. 273 (50k)
        let rows = paper_dartpim_rows();
        let eff = |m: usize| {
            let r = &rows.iter().find(|(mm, _)| *mm == m).unwrap().1;
            r.area_efficiency()
        };
        assert!((eff(12_500) - 1086.0).abs() / 1086.0 < 0.05, "{}", eff(12_500));
        assert!((eff(50_000) - 273.0).abs() / 273.0 < 0.05, "{}", eff(50_000));
    }
}
