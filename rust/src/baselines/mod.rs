//! Comparator systems.
//!
//! * [`published`] — the five systems the paper compares against
//!   (minimap2, NVIDIA Parabricks, GenASM, SeGraM, GenVoM) with their
//!   paper-reported throughput / energy / area / accuracy, plus the
//!   paper's own DART-PIM rows. Figures 8/9 are regenerated from these
//!   (the paper itself uses reported numbers for the comparators).
//! * [`cpu_mapper`] — our live software baseline: an exhaustive
//!   seed-and-extend mapper (lossless seeding + unbanded affine DP over
//!   every PL). Plays the role BWA-MEM plays in the paper's accuracy
//!   study (§VII-A) and anchors the end-to-end example's accuracy check.

pub mod cpu_mapper;
pub mod published;

pub use cpu_mapper::{CpuMapper, Mapping};
pub use published::{published_systems, PublishedSystem, DATASET_READS};
