//! Online seeding (paper §V-C): a read's minimizers select the crossbars
//! (and reference occurrences) that will evaluate it.

pub mod seeder;

pub use seeder::{seed_read, ReadSeed, SeedHit};
