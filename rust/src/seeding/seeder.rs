//! Read seeding: extract a read's minimizers and resolve them against the
//! reference index into potential locations (PLs).

use crate::index::{minimizers, IndexRef};

/// One read minimizer resolved against the index.
#[derive(Debug, Clone)]
pub struct ReadSeed {
    /// The minimizer k-mer (routing key — selects the crossbar).
    pub kmer: u64,
    /// Offset of the minimizer within the read (`q`).
    pub read_offset: u32,
    /// Number of reference occurrences (0 if the minimizer is absent
    /// from the reference).
    pub n_occurrences: usize,
}

/// A potential location with its provenance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SeedHit {
    /// Reference position of the minimizer occurrence (k-mer start).
    pub ref_pos: u32,
    /// Minimizer offset within the read.
    pub read_offset: u32,
    /// Implied mapping position (`ref_pos - read_offset`), may be
    /// negative near the reference start.
    pub pl: i64,
}

/// Seed a read: unique minimizers with their index occurrence counts.
///
/// Duplicate minimizer k-mers within one read are collapsed to their
/// first occurrence (the paper routes one Reads-FIFO entry per (read,
/// minimizer) pair; a duplicate would re-route the same pair).
pub fn seed_read<'a>(index: impl Into<IndexRef<'a>>, read: &[u8]) -> Vec<ReadSeed> {
    let index = index.into();
    // dart-analyze: allow(determinism): membership test only (insert()
    // return value); the set is never iterated, and seed emission order
    // follows the minimizers() scan of the read.
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for m in minimizers(read, index.k(), index.w()) {
        if seen.insert(m.kmer) {
            out.push(ReadSeed {
                kmer: m.kmer,
                read_offset: m.pos,
                n_occurrences: index.occurrences(m.kmer).len(),
            });
        }
    }
    out
}

/// Expand a read's seeds into the full PL set (used by the exhaustive
/// ground-truth mapper and the data-volume motivation study; the PIM
/// pipeline never materializes this list — that is the point of the
/// paper).
pub fn all_seed_hits<'a>(index: impl Into<IndexRef<'a>>, read: &[u8]) -> Vec<SeedHit> {
    let index = index.into();
    let mut hits = Vec::new();
    for seed in seed_read(index, read) {
        for &p in index.occurrences(seed.kmer) {
            hits.push(SeedHit {
                ref_pos: p,
                read_offset: seed.read_offset,
                pl: p as i64 - seed.read_offset as i64,
            });
        }
    }
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::index::MinimizerIndex;
    use crate::params::{K, READ_LEN, W};

    fn setup() -> (MinimizerIndex, Vec<crate::genome::ReadRecord>) {
        let g = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 30, sub_rate: 0.002, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        (idx, reads)
    }

    #[test]
    fn reads_have_seeds_and_unique_kmers() {
        let (idx, reads) = setup();
        for r in &reads {
            let seeds = seed_read(&idx, &r.seq);
            assert!(!seeds.is_empty(), "150bp read should contain minimizers");
            let kmers: std::collections::HashSet<u64> = seeds.iter().map(|s| s.kmer).collect();
            assert_eq!(kmers.len(), seeds.len());
        }
    }

    #[test]
    fn clean_reads_seed_their_true_position() {
        let g = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig {
            n_reads: 40,
            sub_rate: 0.0,
            ins_rate: 0.0,
            del_rate: 0.0,
            ..Default::default()
        }
        .simulate(&idx.reference, |p| p as u32);
        for r in &reads {
            let hits = all_seed_hits(&idx, &r.seq);
            assert!(
                hits.iter().any(|h| h.pl == r.truth_pos as i64),
                "error-free read must have a PL at its origin"
            );
        }
    }

    #[test]
    fn seed_offsets_are_within_read() {
        let (idx, reads) = setup();
        for r in &reads {
            for s in seed_read(&idx, &r.seq) {
                assert!((s.read_offset as usize) + idx.k <= r.seq.len());
            }
        }
    }

    #[test]
    fn pl_arithmetic() {
        let (idx, reads) = setup();
        let r = &reads[0];
        for h in all_seed_hits(&idx, &r.seq) {
            assert_eq!(h.pl, h.ref_pos as i64 - h.read_offset as i64);
        }
    }
}
