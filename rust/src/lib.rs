//! # DART-PIM — DNA read mapping with digital processing-in-memory
//!
//! Reproduction of *"DART-PIM: DNA read mApping acceleRaTor Using
//! Processing-In-Memory"* (arXiv/CS.AR 2024) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L1** — banded Wagner-Fischer Pallas kernels (the paper's
//!   in-crossbar-row compute), authored in `python/compile/kernels/` and
//!   AOT-lowered to HLO text.
//! * **L2** — JAX filter/align graphs with fused best-of-band epilogues
//!   (`python/compile/model.py`).
//! * **L3** — this crate: the coordinator (routing, FIFOs, batching,
//!   best-so-far state), the genomics substrate (FASTA/FASTQ, synthesis,
//!   minimizer indexing, seeding, reference aligners), the PIM cost /
//!   energy / area models, and the paper's four evaluation simulators.
//!
//! Python never runs at request time: the [`runtime`] module loads the
//! HLO artifacts through the PJRT CPU client (`xla` crate) and executes
//! them from the hot path.
//!
//! Start with [`coordinator::pipeline::Pipeline`] (end-to-end mapping) or
//! the `examples/` directory; the [`serve`] module (Unix only) wraps the
//! same pipeline in a long-lived daemon that maps many concurrent FASTQ
//! streams over one resident index (SERVING.md). `DESIGN.md` maps every
//! paper table/figure to the module and bench that regenerates it.

// Every public item must be documented: the crate is the reference map
// between the paper's figures/equations and the code, so an undocumented
// export is a hole in that map. CI turns this into a hard error via
// `cargo doc` with RUSTDOCFLAGS="-D warnings".
#![warn(missing_docs)]
// Clippy levels (deny-all plus the named style allows for code that
// mirrors the paper's recurrences) live in `[workspace.lints]` in the
// root Cargo.toml, shared by every member crate.

pub mod align;
pub mod cli;
pub mod baselines;
pub mod coordinator;
pub mod eval;
pub mod genome;
pub mod index;
pub mod pim;
pub mod runtime;
pub mod seeding;
#[cfg(unix)]
pub mod serve;
pub mod simulator;
pub mod util;

/// Algorithm parameters shared with the Python layer (paper Table III).
/// Must match `python/compile/params.py`; the manifest consumed by
/// [`runtime::artifacts`] cross-checks them at startup.
pub mod params {
    /// Default read length in bases (Illumina short reads).
    pub const READ_LEN: usize = 150;
    /// Minimizer length `k`.
    pub const K: usize = 12;
    /// Minimizer window length `W` (k-mers per window).
    pub const W: usize = 30;
    /// Band half-width (linear error threshold `eth`).
    pub const ETH: usize = 6;
    /// Band width `2*eth + 1`.
    pub const BAND: usize = 2 * ETH + 1;
    /// Linear WF saturation (3-bit cells): `eth + 1`.
    pub const SAT_LINEAR: i32 = (ETH as i32) + 1;
    /// Affine WF saturation (5-bit cells).
    pub const SAT_AFFINE: i32 = 31;
    /// Substitution cost (all edit costs are 1 in the paper).
    pub const W_SUB: i32 = 1;
    /// Insertion cost (linear model).
    pub const W_INS: i32 = 1;
    /// Deletion cost (linear model).
    pub const W_DEL: i32 = 1;
    /// Gap-open cost (affine model).
    pub const W_OP: i32 = 1;
    /// Gap-extend cost (affine model).
    pub const W_EX: i32 = 1;
    /// "Infinity" for in-row scans; matches python params.BIG.
    pub const BIG: i32 = 1 << 20;

    /// Reference window length for a banded WF instance.
    pub const fn window_len(read_len: usize) -> usize {
        read_len + 2 * ETH
    }

    /// Indexed reference segment length per minimizer occurrence:
    /// `2*(rl + eth) - k` (paper §V-B; 300 for 150 bp reads).
    pub const fn segment_len(read_len: usize) -> usize {
        2 * (read_len + ETH) - K
    }
}
