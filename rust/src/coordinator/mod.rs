//! L3 coordinator — the paper's system contribution, host-side.
//!
//! DART-PIM's online flow (Fig. 6 steps 1-7) maps onto:
//!
//! * [`router`]   — minimizer -> crossbar / RISC-V assignment and
//!                  per-read routing (steps 1-2, Fig. 7b)
//! * [`fifo`]     — per-crossbar Reads FIFO with capacity backpressure
//!                  and the maxReads lifetime cap (step 1)
//! * [`batcher`]  — packs (read, window) work items into engine batches;
//!                  the lock-step broadcast across crossbars becomes one
//!                  PJRT call over many instances (steps 3, 6)
//! * [`shard`]    — per-shard execution of steps 2-6: the minimizer-hash
//!                  partition that mirrors the per-crossbar data
//!                  organization (§V-B), and the worker that runs FIFO
//!                  admission, filtering, alignment, and traceback over
//!                  one shard's disjoint slice
//! * [`state`]    — per-read best-so-far PL aggregation, the main
//!                  RISC-V's bookkeeping (step 7), with the deterministic
//!                  tie-break that makes the shard merge order-free
//! * [`metrics`]  — mergeable counters that feed the full-system
//!                  simulator's Eq. 6/7 reports
//! * [`pipeline`] — the end-to-end mapper: single-threaded on the
//!                  configured engine, or sharded across worker threads
//!                  (`PipelineConfig::threads`) with byte-identical output
//! * [`scheduler`]— the chunked streaming driver (producer/compute stage
//!                  threads + channels; std::thread + mpsc — this offline
//!                  build has no tokio)
//!
//! See `ARCHITECTURE.md` at the repository root for the dataflow diagram
//! and the threading/determinism contract.

pub mod batcher;
pub mod fifo;
pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod scheduler;
pub mod shard;
pub mod state;

pub use pipeline::{default_threads, FilterPolicy, FinalMapping, Pipeline, PipelineConfig};
pub use router::{Router, Target};
