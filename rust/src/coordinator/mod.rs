//! L3 coordinator — the paper's system contribution, host-side.
//!
//! DART-PIM's online flow (Fig. 6 steps 1-7) maps onto:
//!
//! * [`router`]   — minimizer -> crossbar / RISC-V assignment and
//!                  per-read routing (steps 1-2, Fig. 7b)
//! * [`fifo`]     — per-crossbar Reads FIFO with capacity backpressure
//!                  and the maxReads lifetime cap (step 1)
//! * [`batcher`]  — packs (read, window) work items into engine batches;
//!                  the lock-step broadcast across crossbars becomes one
//!                  PJRT call over many instances (steps 3, 6)
//! * [`shard`]    — per-shard execution of steps 2-6: the minimizer-hash
//!                  partition that mirrors the per-crossbar data
//!                  organization (§V-B), and the bounded incremental
//!                  worker that runs FIFO admission, filtering,
//!                  alignment, and traceback over one shard's disjoint
//!                  slice with O(batch) in-flight state
//! * [`pair`]     — epoch-boundary proper-pair arbitration for
//!                  paired-end runs: FR orientation + insert-window
//!                  scoring over full candidate lists, single-end
//!                  fallback, and scalar-engine mate rescue — all
//!                  epoch-stateless, preserving byte-identical output
//!                  across threads × engine × epoch
//! * [`state`]    — per-read best-so-far PL aggregation, the main
//!                  RISC-V's bookkeeping (step 7), with the deterministic
//!                  tie-break that makes the shard merge order-free
//! * [`metrics`]  — mergeable counters that feed the full-system
//!                  simulator's Eq. 6/7 reports
//! * [`pool`]     — the shared shard-worker pool and the session
//!                  abstraction: long-lived workers (one engine + one
//!                  per-session `ShardWorker` each) that one stream
//!                  (`map`) or many concurrent streams (`serve`)
//!                  multiplex onto, with per-session epochs, metrics,
//!                  and teardown
//! * [`pipeline`] — the end-to-end mapper: `Pipeline::map_stream` pulls
//!                  reads from any source (FASTQ file, stdin, generator),
//!                  feeds the worker pool through bounded backpressured
//!                  channels, and emits decisions in read order at epoch
//!                  boundaries — memory O(epoch + threads × batch),
//!                  output byte-identical for every thread count and
//!                  epoch size; `map_reads` is its collect wrapper
//! * [`scheduler`]— the older chunked driver (producer/compute stage
//!                  threads + channels) retained for chunk-granular
//!                  hand-off experiments; `pipeline::map_stream` is the
//!                  production streaming path
//!
//! See `ARCHITECTURE.md` at the repository root for the dataflow diagram
//! and the threading/determinism contract (invariants 1–7), and
//! `SERVING.md` for the daemon built on [`pool`].

pub mod batcher;
pub mod fifo;
pub mod metrics;
pub mod pair;
pub mod pipeline;
pub mod pool;
pub mod router;
pub mod scheduler;
pub mod shard;
pub mod state;

pub use pair::{PairStatus, PairingConfig};
pub use pipeline::{default_threads, FilterPolicy, FinalMapping, Pipeline, PipelineConfig};
pub use router::{Router, Target};
