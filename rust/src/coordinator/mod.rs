//! L3 coordinator — the paper's system contribution, host-side.
//!
//! DART-PIM's online flow (Fig. 6 steps 1-7) maps onto:
//!
//! * [`router`]   — minimizer -> crossbar / RISC-V assignment and
//!                  per-read routing (steps 1-2, Fig. 7b)
//! * [`fifo`]     — per-crossbar Reads FIFO with capacity backpressure
//!                  and the maxReads lifetime cap (step 1)
//! * [`batcher`]  — packs (read, window) work items into engine batches;
//!                  the lock-step broadcast across crossbars becomes one
//!                  PJRT call over many instances (steps 3, 6)
//! * [`state`]    — per-read best-so-far PL aggregation, the main
//!                  RISC-V's bookkeeping (step 7)
//! * [`metrics`]  — counters that feed the full-system simulator's
//!                  Eq. 6/7 reports
//! * [`pipeline`] — the single-threaded end-to-end mapper
//! * [`scheduler`]— the threaded driver (stage threads + channels;
//!                  std::thread + mpsc — this offline build has no tokio)

pub mod batcher;
pub mod fifo;
pub mod metrics;
pub mod pipeline;
pub mod router;
pub mod scheduler;
pub mod state;

pub use pipeline::{FilterPolicy, FinalMapping, Pipeline, PipelineConfig};
pub use router::{Router, Target};
