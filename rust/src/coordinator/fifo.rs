//! Per-crossbar Reads FIFO (paper Fig. 6 step 1, §V-C).
//!
//! Each crossbar's FIFO holds up to 480 queued (read, offset) entries
//! (160 rows x 3 reads). When any FIFO fills, the crossbar signals the
//! PIM controller, the read stream pauses, and filtering runs — that is
//! the backpressure boundary the scheduler polls. Independently, the
//! lifetime maxReads cap bounds the total reads any crossbar accepts
//! (paper §V-A: latency/accuracy knob).

use std::collections::VecDeque;

/// Push outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushResult {
    /// Entry admitted to the queue.
    Accepted,
    /// FIFO at capacity — backpressure: run a filtering round first.
    Full,
    /// Lifetime maxReads cap reached — entry dropped permanently.
    CapExceeded,
}

/// One queued entry: a read waiting to be filtered on this crossbar.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FifoEntry {
    /// Read waiting to be filtered on this crossbar.
    pub read_id: u32,
    /// Minimizer offset within the read (address offset sent alongside
    /// the read — paper §V-D step 1).
    pub read_offset: u32,
}

/// Bounded FIFO with a lifetime admission cap.
#[derive(Debug, Clone)]
pub struct ReadsFifo {
    queue: VecDeque<FifoEntry>,
    capacity: usize,
    max_reads: usize,
    accepted_total: usize,
    dropped_total: usize,
}

impl ReadsFifo {
    /// FIFO with queue `capacity` and lifetime admission cap `max_reads`.
    pub fn new(capacity: usize, max_reads: usize) -> Self {
        ReadsFifo {
            queue: VecDeque::with_capacity(capacity.min(1024)),
            capacity,
            max_reads,
            accepted_total: 0,
            dropped_total: 0,
        }
    }

    /// Admission per paper policy: cap first, then capacity.
    pub fn push(&mut self, e: FifoEntry) -> PushResult {
        if self.accepted_total >= self.max_reads {
            self.dropped_total += 1;
            return PushResult::CapExceeded;
        }
        if self.queue.len() >= self.capacity {
            return PushResult::Full;
        }
        self.queue.push_back(e);
        self.accepted_total += 1;
        PushResult::Accepted
    }

    /// Next read for a linear WF iteration.
    pub fn pop(&mut self) -> Option<FifoEntry> {
        self.queue.pop_front()
    }

    /// Entries currently queued.
    pub fn len(&self) -> usize {
        self.queue.len()
    }

    /// True when no entries are queued.
    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// True when the queue is at capacity (backpressure boundary).
    pub fn is_full(&self) -> bool {
        self.queue.len() >= self.capacity
    }

    /// Entries admitted over the FIFO's lifetime.
    pub fn accepted_total(&self) -> usize {
        self.accepted_total
    }

    /// Entries dropped by the lifetime cap.
    pub fn dropped_total(&self) -> usize {
        self.dropped_total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(id: u32) -> FifoEntry {
        FifoEntry { read_id: id, read_offset: 0 }
    }

    #[test]
    fn fifo_order() {
        let mut f = ReadsFifo::new(4, 100);
        for i in 0..3 {
            assert_eq!(f.push(e(i)), PushResult::Accepted);
        }
        assert_eq!(f.pop().unwrap().read_id, 0);
        assert_eq!(f.pop().unwrap().read_id, 1);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn capacity_backpressure_is_not_a_drop() {
        let mut f = ReadsFifo::new(2, 100);
        assert_eq!(f.push(e(0)), PushResult::Accepted);
        assert_eq!(f.push(e(1)), PushResult::Accepted);
        assert_eq!(f.push(e(2)), PushResult::Full);
        assert!(f.is_full());
        assert_eq!(f.dropped_total(), 0, "Full is retryable, not a drop");
        f.pop();
        assert_eq!(f.push(e(2)), PushResult::Accepted);
    }

    #[test]
    fn max_reads_cap_drops_permanently() {
        let mut f = ReadsFifo::new(10, 3);
        for i in 0..3 {
            assert_eq!(f.push(e(i)), PushResult::Accepted);
        }
        f.pop();
        // capacity available, but the lifetime cap is spent
        assert_eq!(f.push(e(9)), PushResult::CapExceeded);
        assert_eq!(f.accepted_total(), 3);
        assert_eq!(f.dropped_total(), 1);
    }

    #[test]
    fn paper_geometry() {
        let cfg = crate::pim::DartPimConfig::default();
        let f = ReadsFifo::new(cfg.fifo_capacity_reads(), cfg.max_reads);
        assert_eq!(f.capacity, 480);
    }
}
