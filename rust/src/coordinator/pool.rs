//! Shared shard-worker pool with session multiplexing — the daemon-era
//! generalization of the sharded streaming pipeline.
//!
//! [`super::pipeline::Pipeline::map_stream`] owns exactly one read
//! stream for the lifetime of its worker threads. A serving daemon
//! (`dart-pim serve`) inverts that: the workers outlive any one stream,
//! and several concurrent streams (sessions) multiplex onto them. This
//! module splits the old monolith into the two halves that makes
//! possible:
//!
//! * [`WorkerPool`] — N long-lived shard workers, spawned once per
//!   process (scoped threads, so they may borrow the index). Each worker
//!   owns one engine and a map of **per-session** [`ShardWorker`]s, so
//!   FIFO maxReads accounting — the only state that persists across
//!   epoch drains — is session-scoped and two clients can never perturb
//!   each other's admission decisions.
//! * [`MapSession`] — one read stream's producer-side state: routing,
//!   pair-id assignment, epoch accounting, retained epoch sequences for
//!   mate rescue, and epoch-ordered emission. Dropping a session (e.g.
//!   a client hangup mid-stream) retires its state in every worker.
//!
//! # Determinism (invariant 7)
//!
//! A session's output is byte-identical to a standalone
//! `Pipeline::map_stream` run over the same reads with the same
//! configuration, regardless of what other sessions are doing:
//!
//! * each session has a single producer, and std `mpsc` channels are
//!   FIFO per sender, so a session's items reach each shard in exactly
//!   the order the single-stream pipeline would send them;
//! * the shard partition (`shard_of`) and epoch boundaries depend only
//!   on the session's own reads;
//! * engines are stateless between batches, so interleaving another
//!   session's batches between ours changes no numerics;
//! * per-session `ShardWorker`s isolate the FIFO cap state (above).
//!
//! `tests/serve_e2e.rs` and the CI serve-smoke job hold this contract
//! over real sockets.

use std::borrow::Borrow;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::genome::ReadRecord;
use crate::index::{shard_of, IndexRef};

use super::metrics::Metrics;
use super::pipeline::{
    bump_read_id, check_even_paired_stream, emit_epoch, epoch_boundary, route_read,
    FinalMapping, PipelineConfig, CHANNEL_DEPTH, SHARD_CHUNK,
};
use super::router::Router;
use super::shard::{ShardItem, ShardWorker};
use super::state::AffineOutcome;

/// One worker's answer to a flush request: its shard index plus the
/// epoch's outcomes (or the session's terminal error).
type ShardAck = (usize, Result<Vec<AffineOutcome>>);

/// Message streamed to one pooled shard worker. Every variant is tagged
/// with the session it belongs to; flush/close replies travel back over
/// a per-request ack channel carried inside the message, so no
/// cross-session reply routing exists to get wrong.
enum PoolMsg {
    /// A chunk of one session's routed items, in emission order.
    Items {
        /// Originating session.
        session: u64,
        /// The routed items.
        items: Vec<ShardItem>,
    },
    /// Epoch barrier for one session: drain its shard state and ack
    /// with the outcomes so far (or its terminal error, exactly once).
    Flush {
        /// Originating session.
        session: u64,
        /// Where to deliver this shard's ack.
        ack: mpsc::Sender<ShardAck>,
    },
    /// Session teardown: finish and discard the session's shard state,
    /// acking with this shard's per-session metrics.
    Close {
        /// Originating session.
        session: u64,
        /// Where to deliver this shard's metrics.
        ack: mpsc::Sender<(usize, Metrics)>,
    },
}

/// Clears the worker's liveness flag when its thread exits for any
/// reason — including a panic unwind — so producers waiting on an ack
/// can distinguish "slow" from "dead".
struct AliveGuard(Arc<AtomicBool>);

impl Drop for AliveGuard {
    fn drop(&mut self) {
        self.0.store(false, Ordering::SeqCst);
    }
}

/// A pool of long-lived shard workers that sessions multiplex onto.
///
/// Cloning the handle clones the senders; workers exit when every
/// handle (and every session) has been dropped. Spawn once per process
/// inside a [`thread::scope`] so workers may borrow the index:
///
/// ```
/// use dart_pim::coordinator::pool::{MapSession, WorkerPool};
/// use dart_pim::coordinator::{PipelineConfig, Router};
/// use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
/// use dart_pim::index::MinimizerIndex;
/// use dart_pim::params::{K, READ_LEN, W};
///
/// let genome = SynthConfig { len: 30_000, ..Default::default() }.generate();
/// let index = MinimizerIndex::build(genome, K, W, READ_LEN);
/// let reads = ReadSimConfig { n_reads: 4, ..Default::default() }
///     .simulate(&index.reference, |p| p as u32);
/// let cfg = PipelineConfig::default();
/// let router = Router::new(&index, &cfg.dart);
/// let metrics = std::thread::scope(|s| {
///     let pool = WorkerPool::spawn(s, &index, &cfg, 2);
///     let mut session = MapSession::new(0, &index, &router, cfg.clone(), &pool);
///     let mut sink = |_, _| Ok(());
///     for r in &reads {
///         session.push(r, &mut sink).unwrap();
///     }
///     session.finish(&mut sink).unwrap()
/// });
/// assert_eq!(metrics.n_reads, 4);
/// ```
#[derive(Clone)]
pub struct WorkerPool {
    txs: Vec<mpsc::SyncSender<PoolMsg>>,
    alive: Vec<Arc<AtomicBool>>,
}

impl WorkerPool {
    /// Spawn `n_shards` (≥ 1) workers on scope `s`. Each worker builds
    /// its engine from `cfg.worker_engine` on its own thread and serves
    /// every session's slice of the minimizer-hash partition. Sessions
    /// may use a config that differs from the pool's in `pairing` /
    /// `handle_revcomp` (producer/emission-side policy); the
    /// worker-side fields (`dart`, `batch_size`, `filter_policy`,
    /// `worker_engine`, `simd`) are fixed at spawn for all sessions.
    pub fn spawn<'scope, 'env>(
        s: &'scope thread::Scope<'scope, 'env>,
        index: impl Into<IndexRef<'env>>,
        cfg: &'env PipelineConfig,
        n_shards: usize,
    ) -> WorkerPool {
        let index = index.into();
        let n = n_shards.max(1);
        let mut txs = Vec::with_capacity(n);
        let mut alive = Vec::with_capacity(n);
        for sh in 0..n {
            let (tx, rx) = mpsc::sync_channel::<PoolMsg>(CHANNEL_DEPTH);
            let flag = Arc::new(AtomicBool::new(true));
            txs.push(tx);
            alive.push(flag.clone());
            s.spawn(move || pool_worker(index, cfg, sh, rx, flag));
        }
        WorkerPool { txs, alive }
    }

    /// Number of shard workers.
    pub fn n_shards(&self) -> usize {
        self.txs.len()
    }

    /// True while every worker thread is still running. A false answer
    /// means a worker panicked or exited early: in-flight sessions will
    /// fail their next flush, and the panic payload re-raises when the
    /// spawning scope joins.
    pub fn healthy(&self) -> bool {
        self.alive.iter().all(|a| a.load(Ordering::SeqCst))
    }
}

/// One pooled worker's thread body: one engine, one
/// per-session [`ShardWorker`] map, plus a poisoned-session map so a
/// failed session reports its error exactly once (at its next flush)
/// without taking the worker — or any other session — down with it.
fn pool_worker(
    index: IndexRef<'_>,
    cfg: &PipelineConfig,
    sh: usize,
    rx: mpsc::Receiver<PoolMsg>,
    alive: Arc<AtomicBool>,
) {
    let _guard = AliveGuard(alive);
    // the engine is constructed on its owning thread (every EngineKind
    // variant is Send-safe to build and run here; the PJRT engine never
    // is). It is shared across sessions: engines are stateless between
    // batches, so session interleaving cannot change any numerics —
    // and neither can the SIMD lane width (invariant 8).
    let mut engine = cfg.worker_engine.build_simd(cfg.simd);
    // Invariant-7 audit: HashMap iteration order is nondeterministic,
    // but these two maps never reach emitted bytes because they are
    // never iterated — `sessions` is touched only via entry()/remove()
    // keyed by the session id carried in each PoolMsg, and `poisoned`
    // only via contains_key()/insert()/remove(). Per-session outcome
    // *order* is fixed upstream: each session has one producer, mpsc
    // channels are FIFO per sender, and flush acks are keyed by shard
    // index. Switching to BTreeMap would change nothing observable; the
    // HashMap stays for O(1) lookups on the per-item hot path.
    // dart-analyze: allow(determinism): neither map is ever iterated —
    // `sessions` is touched only via entry()/get_mut()/remove() and
    // `poisoned` via contains_key()/insert()/remove(), all keyed by the
    // session id carried in each PoolMsg, so map order is unobservable.
    let mut sessions: HashMap<u64, ShardWorker<'_>> = HashMap::new();
    let mut poisoned: HashMap<u64, anyhow::Error> = HashMap::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            PoolMsg::Items { session, items } => {
                if poisoned.contains_key(&session) {
                    continue;
                }
                let worker = sessions
                    .entry(session)
                    .or_insert_with(|| ShardWorker::new(index, cfg));
                if let Err(e) = worker.ingest(engine.as_mut(), items) {
                    sessions.remove(&session);
                    poisoned.insert(session, e);
                }
            }
            PoolMsg::Flush { session, ack } => {
                if let Some(e) = poisoned.remove(&session) {
                    let _ = ack.send((sh, Err(e)));
                    continue;
                }
                let worker = sessions
                    .entry(session)
                    .or_insert_with(|| ShardWorker::new(index, cfg));
                match worker.drain(engine.as_mut()) {
                    Ok(outs) => {
                        let _ = ack.send((sh, Ok(outs)));
                    }
                    Err(e) => {
                        sessions.remove(&session);
                        let _ = ack.send((sh, Err(e)));
                    }
                }
            }
            PoolMsg::Close { session, ack } => {
                poisoned.remove(&session);
                let metrics = match sessions.remove(&session) {
                    // a close always follows a final flush, so finish
                    // has no pending work left; an error here (already
                    // reported through that flush) yields empty metrics
                    Some(w) => {
                        w.finish(engine.as_mut()).map(|(_, m)| m).unwrap_or_default()
                    }
                    None => Metrics::default(),
                };
                let _ = ack.send((sh, metrics));
            }
        }
    }
    // all pool handles and sessions hung up: nothing left to serve
}

/// One read stream's producer-side mapping state, multiplexed onto a
/// [`WorkerPool`]: routing, pair-id assignment, epoch accounting, and
/// epoch-ordered emission. Create with [`MapSession::new`], feed with
/// [`MapSession::push`], and settle with [`MapSession::finish`];
/// dropping an unfinished session (client hangup) retires its worker
/// state without blocking.
pub struct MapSession<'a> {
    id: u64,
    index: IndexRef<'a>,
    router: &'a Router,
    cfg: PipelineConfig,
    txs: Vec<mpsc::SyncSender<PoolMsg>>,
    alive: Vec<Arc<AtomicBool>>,
    pending: Vec<Vec<ShardItem>>,
    epoch_seqs: Vec<Arc<[u8]>>,
    metrics: Metrics,
    t_route: Duration,
    // dart-analyze: allow(determinism): Instant feeds only the stage
    // clocks (t_route/t_total), which Metrics::invariant_counters()
    // excludes by design (invariant 4); no wall-clock value reaches
    // emitted bytes — the TSV and DATA frames are built purely from
    // mapping outcomes.
    t_start: Instant,
    next_pair: u32,
    next_id: u32,
    epoch_start: u32,
    closed: bool,
}

impl<'a> MapSession<'a> {
    /// Open session `id` (unique among live sessions on this pool) with
    /// its own `cfg`. The config may differ from the pool's only in the
    /// producer/emission-side fields (`pairing`, `handle_revcomp`);
    /// worker-side fields must match the pool's, which executes every
    /// session with the config it was spawned with.
    pub fn new(
        id: u64,
        index: impl Into<IndexRef<'a>>,
        router: &'a Router,
        cfg: PipelineConfig,
        pool: &WorkerPool,
    ) -> MapSession<'a> {
        let n = pool.txs.len();
        MapSession {
            id,
            index: index.into(),
            router,
            cfg,
            txs: pool.txs.clone(),
            alive: pool.alive.clone(),
            pending: (0..n).map(|_| Vec::with_capacity(SHARD_CHUNK)).collect(),
            epoch_seqs: Vec::new(),
            metrics: Metrics::default(),
            t_route: Duration::ZERO,
            t_start: Instant::now(),
            next_pair: 0,
            next_id: 0,
            epoch_start: 0,
            closed: false,
        }
    }

    /// Route one read into the pool and, at epoch boundaries, emit the
    /// finished epoch's decisions through `sink` (every read id exactly
    /// once, ascending, `None` for unmapped) — the per-read step of
    /// [`super::pipeline::Pipeline::map_stream`]'s loop.
    pub fn push<S>(&mut self, read: &ReadRecord, sink: &mut S) -> Result<()>
    where
        S: FnMut(u32, Option<FinalMapping>) -> Result<()>,
    {
        let t0 = Instant::now();
        let n_shards = self.txs.len();
        let id = self.id;
        let pending = &mut self.pending;
        let txs = &self.txs;
        let fwd = route_read(
            self.router,
            self.index,
            self.cfg.handle_revcomp,
            self.next_id,
            read,
            &mut self.next_pair,
            |item| {
                let sh = shard_of(item.kmer, n_shards);
                pending[sh].push(item);
                if pending[sh].len() >= SHARD_CHUNK {
                    let full =
                        std::mem::replace(&mut pending[sh], Vec::with_capacity(SHARD_CHUNK));
                    // a send error means the worker died; the flush
                    // barrier below surfaces the failure
                    let _ = txs[sh].send(PoolMsg::Items { session: id, items: full });
                }
            },
        );
        if self.cfg.pairing.is_some() {
            self.epoch_seqs.push(fwd);
        }
        self.t_route += t0.elapsed();
        self.next_id = bump_read_id(self.next_id)?;
        let epoch = self.cfg.stream_epoch.max(1);
        if epoch_boundary(self.epoch_start, self.next_id, epoch, self.cfg.pairing.is_some()) {
            self.emit_finished_epoch(sink)?;
        }
        Ok(())
    }

    /// Settle the stream: final (possibly partial) epoch, worker-side
    /// teardown, and the session's merged metrics.
    pub fn finish<S>(mut self, sink: &mut S) -> Result<Metrics>
    where
        S: FnMut(u32, Option<FinalMapping>) -> Result<()>,
    {
        check_even_paired_stream(self.cfg.pairing.is_some(), self.next_id)?;
        self.emit_finished_epoch(sink)?;
        // close out the per-session worker state, merging each shard's
        // metrics contribution
        let (ack_tx, ack_rx) = mpsc::channel::<(usize, Metrics)>();
        for tx in &self.txs {
            let _ = tx.send(PoolMsg::Close { session: self.id, ack: ack_tx.clone() });
        }
        drop(ack_tx);
        self.closed = true;
        let mut acked = vec![false; self.txs.len()];
        let mut n_acked = 0usize;
        while n_acked < self.txs.len() {
            if let Some((sh, m)) = self.recv_ack(&ack_rx, &acked)? {
                debug_assert!(!acked[sh], "one close ack per shard");
                acked[sh] = true;
                n_acked += 1;
                self.metrics.merge(m);
            }
        }
        self.metrics.t_seed += self.t_route;
        self.metrics.n_reads = u64::from(self.next_id);
        self.metrics.t_total = self.t_start.elapsed();
        Ok(std::mem::take(&mut self.metrics))
    }

    /// Reads mapped so far (the session's dense read-id high-water mark).
    pub fn n_reads(&self) -> u32 {
        self.next_id
    }

    /// Flush the epoch that just closed (or the final partial epoch)
    /// and push its decisions through the sink.
    fn emit_finished_epoch<S>(&mut self, sink: &mut S) -> Result<()>
    where
        S: FnMut(u32, Option<FinalMapping>) -> Result<()>,
    {
        let outs = self.flush()?;
        let span = (self.epoch_start, self.next_id);
        emit_epoch(
            self.index,
            self.cfg.pairing.as_ref(),
            &mut self.epoch_seqs,
            span,
            outs,
            sink,
            &mut self.metrics,
        )?;
        self.epoch_start = self.next_id;
        Ok(())
    }

    /// Epoch barrier: ship each shard's leftover chunk plus a flush
    /// marker, collect exactly one ack per worker (or the session's
    /// terminal error), and return the epoch's merged outcomes.
    fn flush(&mut self) -> Result<Vec<AffineOutcome>> {
        let (ack_tx, ack_rx) = mpsc::channel::<ShardAck>();
        for (sh, tx) in self.txs.iter().enumerate() {
            if !self.pending[sh].is_empty() {
                let items = std::mem::take(&mut self.pending[sh]);
                let _ = tx.send(PoolMsg::Items { session: self.id, items });
            }
            let _ = tx.send(PoolMsg::Flush { session: self.id, ack: ack_tx.clone() });
        }
        drop(ack_tx);
        let mut acked = vec![false; self.txs.len()];
        let mut n_acked = 0usize;
        let mut outcomes: Vec<AffineOutcome> = Vec::new();
        while n_acked < self.txs.len() {
            if let Some((sh, ack)) = self.recv_ack(&ack_rx, &acked)? {
                let outs = ack?;
                debug_assert!(!acked[sh], "one ack per worker per flush");
                acked[sh] = true;
                n_acked += 1;
                outcomes.extend(outs);
            }
        }
        Ok(outcomes)
    }

    /// Receive one ack with dead-worker detection: a worker that exits
    /// without acking (a panic) would otherwise hang the session
    /// forever. `Ok(None)` means "nothing yet, try again".
    fn recv_ack<T>(
        &self,
        rx: &mpsc::Receiver<(usize, T)>,
        acked: &[bool],
    ) -> Result<Option<(usize, T)>> {
        match rx.recv_timeout(Duration::from_millis(100)) {
            Ok(m) => Ok(Some(m)),
            Err(mpsc::RecvTimeoutError::Timeout) => {
                let dead = acked
                    .iter()
                    .zip(&self.alive)
                    .any(|(&a, alive)| !a && !alive.load(Ordering::SeqCst));
                if !dead {
                    Ok(None)
                } else if let Ok(m) = rx.try_recv() {
                    // the dying worker's final message raced the timeout
                    // (its send happened-before the exit we observed):
                    // handle it normally instead of masking the cause
                    Ok(Some(m))
                } else {
                    // exited with no message at all: the worker
                    // panicked. The panic payload re-raises when the
                    // spawning scope joins its threads.
                    bail!("shard worker terminated without delivering session results");
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                bail!("all shard workers disconnected mid-session");
            }
        }
    }
}

impl Drop for MapSession<'_> {
    /// Retire the session's worker-side state on abort (error return or
    /// client hangup): send a fire-and-forget close so per-session
    /// `ShardWorker`s do not accumulate in a long-lived daemon. The
    /// replies land on a receiver we drop immediately; dead workers'
    /// sends fail silently, which is exactly what we want here.
    fn drop(&mut self) {
        if !self.closed {
            let (ack_tx, _ack_rx) = mpsc::channel::<(usize, Metrics)>();
            for tx in &self.txs {
                // dart-analyze: allow(flush-ack): fire-and-forget by
                // design — Drop runs on abort paths where no caller can
                // consume an ack, and blocking in Drop could deadlock a
                // panicking thread against a full pool queue. Dropping
                // _ack_rx makes the workers' replies fail silently; the
                // worker still removes the session either way, so no
                // per-session state leaks (held by tests/serve_e2e.rs).
                let _ = tx.send(PoolMsg::Close { session: self.id, ack: ack_tx.clone() });
            }
        }
    }
}

/// Drive a whole read stream through a fresh single-session pool — the
/// implementation of [`super::pipeline::Pipeline::map_stream`]'s
/// sharded path, kept here so the pipeline and the daemon share one
/// code path for everything past routing.
pub(crate) fn map_stream_pooled<I, R, S>(
    index: IndexRef<'_>,
    router: &Router,
    cfg: &PipelineConfig,
    reads: I,
    sink: &mut S,
) -> Result<Metrics>
where
    I: IntoIterator<Item = Result<R>>,
    R: Borrow<ReadRecord>,
    S: FnMut(u32, Option<FinalMapping>) -> Result<()>,
{
    let t_start = Instant::now();
    let mut metrics = thread::scope(|s| -> Result<Metrics> {
        let pool = WorkerPool::spawn(s, index, cfg, cfg.threads);
        let mut session = MapSession::new(0, index, router, cfg.clone(), &pool);
        for rec in reads {
            let rec = rec?;
            session.push(rec.borrow(), sink)?;
        }
        session.finish(sink)
        // an early Err drops `session` (fire-and-forget close) and the
        // pool handle; every sender gone => workers exit; a worker
        // panic re-raises at the implicit scope join, preserving the
        // old map_stream_sharded contract
    })?;
    metrics.t_total = t_start.elapsed();
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::index::MinimizerIndex;
    use crate::params::{K, READ_LEN, W};
    use crate::runtime::RustEngine;

    fn setup(n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
        let g = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        (idx, reads)
    }

    fn cfg(threads: usize, stream_epoch: usize) -> PipelineConfig {
        PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            threads,
            stream_epoch,
            worker_engine: crate::runtime::EngineKind::Rust,
            ..Default::default()
        }
    }

    fn render(m: &[Option<FinalMapping>]) -> Vec<(u32, i64, i32, String, u32, bool)> {
        m.iter()
            .flatten()
            .map(|f| (f.read_id, f.pos, f.dist, f.cigar.to_string(), f.candidates, f.reverse))
            .collect()
    }

    fn run_session(
        idx: &MinimizerIndex,
        router: &Router,
        pool: &WorkerPool,
        cfg: &PipelineConfig,
        id: u64,
        reads: &[ReadRecord],
    ) -> (Vec<Option<FinalMapping>>, Metrics) {
        let mut out = Vec::new();
        let mut sink = |_, m| {
            out.push(m);
            Ok(())
        };
        let mut session = MapSession::new(id, idx, router, cfg.clone(), pool);
        for r in reads {
            session.push(r, &mut sink).unwrap();
        }
        let m = session.finish(&mut sink).unwrap();
        (out, m)
    }

    /// Two sessions interleaved read-by-read on one pool each match
    /// their own standalone single-stream run — the in-process heart of
    /// determinism invariant 7.
    #[test]
    fn interleaved_sessions_match_standalone_runs() {
        let (idx, reads) = setup(36);
        let (a_reads, b_reads): (Vec<_>, Vec<_>) =
            reads.iter().cloned().partition(|r| r.id % 2 == 0);
        let a_reads: Vec<ReadRecord> = a_reads
            .into_iter()
            .enumerate()
            .map(|(i, mut r)| {
                r.id = i as u32;
                r
            })
            .collect();
        let b_reads: Vec<ReadRecord> = b_reads
            .into_iter()
            .enumerate()
            .map(|(i, mut r)| {
                r.id = i as u32;
                r
            })
            .collect();
        let c = cfg(3, 5);
        let standalone = |rs: &[ReadRecord]| {
            let mut p = crate::coordinator::Pipeline::new(&idx, c.clone(), RustEngine);
            render(&p.map_reads(rs).unwrap().0)
        };
        let want_a = standalone(&a_reads);
        let want_b = standalone(&b_reads);
        let router = Router::new(&idx, &c.dart);
        let (got_a, got_b, ma, mb) = thread::scope(|s| {
            let pool = WorkerPool::spawn(s, &idx, &c, c.threads);
            let mut out_a = Vec::new();
            let mut out_b = Vec::new();
            let mut sink_a = |_, m| {
                out_a.push(m);
                Ok(())
            };
            let mut sink_b = |_, m| {
                out_b.push(m);
                Ok(())
            };
            let mut sa = MapSession::new(1, &idx, &router, c.clone(), &pool);
            let mut sb = MapSession::new(2, &idx, &router, c.clone(), &pool);
            // strict read-by-read interleaving across the shared pool
            for (ra, rb) in a_reads.iter().zip(&b_reads) {
                sa.push(ra, &mut sink_a).unwrap();
                sb.push(rb, &mut sink_b).unwrap();
            }
            let ma = sa.finish(&mut sink_a).unwrap();
            let mb = sb.finish(&mut sink_b).unwrap();
            (render(&out_a), render(&out_b), ma, mb)
        });
        assert_eq!(want_a, got_a, "session A corrupted by interleaving");
        assert_eq!(want_b, got_b, "session B corrupted by interleaving");
        assert_eq!(ma.n_reads, a_reads.len() as u64);
        assert_eq!(mb.n_reads, b_reads.len() as u64);
        assert!(ma.linear_instances > 0 && mb.linear_instances > 0);
    }

    /// A dropped (aborted) session must not leak state that perturbs a
    /// later session with the same id.
    #[test]
    fn dropped_session_state_is_retired() {
        let (idx, reads) = setup(20);
        let c = cfg(2, 4);
        let router = Router::new(&idx, &c.dart);
        let want = {
            let mut p = crate::coordinator::Pipeline::new(&idx, c.clone(), RustEngine);
            render(&p.map_reads(&reads).unwrap().0)
        };
        let got = thread::scope(|s| {
            let pool = WorkerPool::spawn(s, &idx, &c, c.threads);
            {
                // feed half a stream, then hang up without finishing
                let mut aborted = MapSession::new(7, &idx, &router, c.clone(), &pool);
                let mut sink = |_, _| Ok(());
                for r in &reads[..10] {
                    aborted.push(r, &mut sink).unwrap();
                }
                drop(aborted);
            }
            // same session id, fresh stream: must start from clean state
            let (out, m) = run_session(&idx, &router, &pool, &c, 7, &reads);
            assert_eq!(m.n_reads, reads.len() as u64);
            render(&out)
        });
        assert_eq!(want, got, "retired session state leaked into its successor");
    }

    #[test]
    fn pool_reports_healthy_and_sessions_settle_empty_streams() {
        let (idx, _) = setup(1);
        let c = cfg(4, 8);
        let router = Router::new(&idx, &c.dart);
        thread::scope(|s| {
            let pool = WorkerPool::spawn(s, &idx, &c, c.threads);
            assert!(pool.healthy());
            assert_eq!(pool.n_shards(), 4);
            let (out, m) = run_session(&idx, &router, &pool, &c, 1, &[]);
            assert!(out.is_empty());
            assert_eq!(m.n_reads, 0);
            assert!(pool.healthy());
        });
    }
}
