//! Best-so-far mapping state (paper Fig. 6 step 7): the main RISC-V
//! keeps, per read, the minimal-distance PL seen so far across all
//! crossbars' affine results, with a deterministic tie-break so the
//! outcome is independent of arrival order.
//!
//! Arrival-order independence is what makes the sharded pipeline's merge
//! trivial: every shard worker emits [`AffineOutcome`]s in its own order,
//! and folding them into one [`BestSoFar`] in *any* interleaving yields
//! the same winners. Full ties on `(dist, pos, reverse)` are broken by
//! [`AffineOutcome::key`], the instance's serial emission order, so even
//! equal-cost alignments with different CIGARs resolve identically
//! whether the run used one thread or many.

use crate::align::Cigar;

/// One affine result delivered to the aggregator.
#[derive(Debug, Clone)]
pub struct AffineOutcome {
    /// Read this outcome belongs to.
    pub read_id: u32,
    /// Refined mapping position (PL + traceback start offset).
    pub pos: i64,
    /// Affine alignment cost.
    pub dist: i32,
    /// Reconstructed alignment.
    pub cigar: Cigar,
    /// Reverse-complement orientation.
    pub reverse: bool,
    /// Mate index within the read's pair (0 = R1 / single-end, 1 = R2);
    /// provenance from [`super::batcher::WorkTag`], cross-checked by the
    /// pair arbitration against the paired id layout.
    pub mate: u8,
    /// Deterministic arbitration key: `pair_id << 32 | ref_pos`, i.e. the
    /// serial emission order of the WF instance. Breaks full
    /// `(dist, pos, reverse)` ties so the winning candidate (and its
    /// CIGAR) is identical for every shard interleaving.
    pub key: u64,
}

impl AffineOutcome {
    /// The canonical candidate ordering `(dist, pos, reverse, key)` —
    /// the same total order [`BestSoFar::update`] minimizes over, so
    /// sorting a candidate list and taking the head reproduces the
    /// single-end winner exactly. `key` is unique per instance, making
    /// the order total and therefore independent of arrival order.
    pub fn rank(&self) -> (i32, i64, bool, u64) {
        (self.dist, self.pos, self.reverse, self.key)
    }
}

/// Final per-read decision.
#[derive(Debug, Clone)]
pub struct BestMapping {
    /// Refined mapping position in reference coordinates.
    pub pos: i64,
    /// Affine alignment cost of the winning candidate.
    pub dist: i32,
    /// Alignment of the winning candidate.
    pub cigar: Cigar,
    /// How many candidate outcomes were considered.
    pub candidates: u32,
    /// Reverse-complement orientation of the winning candidate.
    pub reverse: bool,
    /// Arbitration key of the winning candidate (see
    /// [`AffineOutcome::key`]).
    pub key: u64,
}

/// Order-independent aggregation: smaller `(dist, pos, reverse, key)`
/// wins.
#[derive(Debug, Default)]
pub struct BestSoFar {
    slots: Vec<Option<BestMapping>>,
}

impl BestSoFar {
    /// Empty state for `n_reads` reads.
    pub fn new(n_reads: usize) -> Self {
        BestSoFar { slots: vec![None; n_reads] }
    }

    /// Fold one outcome in.
    pub fn update(&mut self, o: AffineOutcome) {
        let slot = &mut self.slots[o.read_id as usize];
        match slot {
            None => {
                *slot = Some(BestMapping {
                    pos: o.pos,
                    dist: o.dist,
                    cigar: o.cigar,
                    candidates: 1,
                    reverse: o.reverse,
                    key: o.key,
                })
            }
            Some(b) => {
                b.candidates += 1;
                // forward orientation wins ties; the emission-order key
                // resolves anything still equal (deterministic under any
                // shard interleaving)
                if (o.dist, o.pos, o.reverse, o.key) < (b.dist, b.pos, b.reverse, b.key) {
                    b.pos = o.pos;
                    b.dist = o.dist;
                    b.cigar = o.cigar;
                    b.reverse = o.reverse;
                    b.key = o.key;
                }
            }
        }
    }

    /// Final mapping of one read.
    pub fn get(&self, read_id: u32) -> Option<&BestMapping> {
        self.slots.get(read_id as usize).and_then(|s| s.as_ref())
    }

    /// Consume into the per-read decision vector.
    pub fn into_mappings(self) -> Vec<Option<BestMapping>> {
        self.slots
    }

    /// Number of reads with at least one candidate.
    pub fn mapped_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }
}

/// Order-independent *full* candidate aggregation: where [`BestSoFar`]
/// keeps one winner per read, this keeps every surviving affine outcome,
/// because proper-pair arbitration must score combinations of R1 × R2
/// candidates — the single best of each mate is not enough. Bounded by
/// the streaming epoch (candidate lists are dropped at every emission),
/// and canonicalized on extraction so any arrival interleaving yields
/// identical lists.
#[derive(Debug, Default)]
pub struct PairCandidates {
    slots: Vec<Vec<AffineOutcome>>,
}

impl PairCandidates {
    /// Empty candidate lists for `n_reads` reads.
    pub fn new(n_reads: usize) -> Self {
        PairCandidates { slots: (0..n_reads).map(|_| Vec::new()).collect() }
    }

    /// Append one outcome to its read's list (any arrival order).
    pub fn push(&mut self, o: AffineOutcome) {
        self.slots[o.read_id as usize].push(o);
    }

    /// Consume into per-read candidate lists in the canonical
    /// [`AffineOutcome::rank`] order (head == the [`BestSoFar`] winner),
    /// independent of the order outcomes arrived in.
    pub fn into_sorted(mut self) -> Vec<Vec<AffineOutcome>> {
        for list in &mut self.slots {
            list.sort_by_key(|o| o.rank());
        }
        self.slots
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn o(read_id: u32, pos: i64, dist: i32) -> AffineOutcome {
        AffineOutcome {
            read_id,
            pos,
            dist,
            cigar: Cigar(vec![]),
            reverse: false,
            mate: 0,
            key: 0,
        }
    }

    fn ok(read_id: u32, pos: i64, dist: i32, key: u64) -> AffineOutcome {
        AffineOutcome { key, ..o(read_id, pos, dist) }
    }

    #[test]
    fn keeps_minimum() {
        let mut s = BestSoFar::new(2);
        s.update(o(0, 100, 5));
        s.update(o(0, 50, 2));
        s.update(o(0, 70, 9));
        let b = s.get(0).unwrap();
        assert_eq!((b.pos, b.dist, b.candidates), (50, 2, 3));
        assert!(s.get(1).is_none());
        assert_eq!(s.mapped_count(), 1);
    }

    #[test]
    fn tie_break_leftmost() {
        let mut s = BestSoFar::new(1);
        s.update(o(0, 100, 3));
        s.update(o(0, 40, 3));
        assert_eq!(s.get(0).unwrap().pos, 40);
    }

    #[test]
    fn full_tie_breaks_on_emission_key() {
        // same (dist, pos, reverse): the earlier-emitted instance wins,
        // in either arrival order
        let mut a = BestSoFar::new(1);
        a.update(ok(0, 10, 3, 7));
        a.update(ok(0, 10, 3, 2));
        let mut b = BestSoFar::new(1);
        b.update(ok(0, 10, 3, 2));
        b.update(ok(0, 10, 3, 7));
        assert_eq!(a.get(0).unwrap().key, 2);
        assert_eq!(b.get(0).unwrap().key, 2);
    }

    #[test]
    fn pair_candidates_head_matches_best_so_far_in_any_order() {
        check("pair-candidate canonicalization", 0x9A12, 50, |rng| {
            let n = rng.gen_range(1..15usize);
            let outcomes: Vec<AffineOutcome> = (0..n)
                .map(|i| ok(0, rng.gen_range(0..200i64), rng.gen_range(0..10i32), i as u64))
                .collect();
            let mut fwd = PairCandidates::new(1);
            let mut rev = PairCandidates::new(1);
            let mut best = BestSoFar::new(1);
            for o in outcomes.iter().cloned() {
                fwd.push(o.clone());
                best.update(o);
            }
            for o in outcomes.iter().rev().cloned() {
                rev.push(o);
            }
            let (f, r) = (fwd.into_sorted(), rev.into_sorted());
            let keys = |v: &[AffineOutcome]| v.iter().map(|o| o.key).collect::<Vec<_>>();
            assert_eq!(keys(&f[0]), keys(&r[0]), "canonical order is arrival-independent");
            assert_eq!(f[0][0].key, best.get(0).unwrap().key, "head == BestSoFar winner");
        });
    }

    #[test]
    fn order_independent_property() {
        check("best-so-far order independence", 0xBE57, 50, |rng| {
            let n = rng.gen_range(1..20usize);
            let outcomes: Vec<AffineOutcome> = (0..n)
                .map(|i| ok(0, rng.gen_range(0..1000i64), rng.gen_range(0..30i32), i as u64))
                .collect();
            let mut forward = BestSoFar::new(1);
            for oc in outcomes.iter().cloned() {
                forward.update(oc);
            }
            let mut reverse = BestSoFar::new(1);
            for oc in outcomes.iter().rev().cloned() {
                reverse.update(oc);
            }
            let (f, r) = (forward.get(0).unwrap(), reverse.get(0).unwrap());
            assert_eq!((f.pos, f.dist, f.key), (r.pos, r.dist, r.key));
        });
    }
}
