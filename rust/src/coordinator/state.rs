//! Best-so-far mapping state (paper Fig. 6 step 7): the main RISC-V
//! keeps, per read, the minimal-distance PL seen so far across all
//! crossbars' affine results, with a deterministic tie-break so the
//! outcome is independent of arrival order.

use crate::align::Cigar;

/// One affine result delivered to the aggregator.
#[derive(Debug, Clone)]
pub struct AffineOutcome {
    pub read_id: u32,
    /// Refined mapping position (PL + traceback start offset).
    pub pos: i64,
    pub dist: i32,
    pub cigar: Cigar,
    /// Reverse-complement orientation.
    pub reverse: bool,
}

/// Final per-read decision.
#[derive(Debug, Clone)]
pub struct BestMapping {
    pub pos: i64,
    pub dist: i32,
    pub cigar: Cigar,
    /// How many candidate outcomes were considered.
    pub candidates: u32,
    pub reverse: bool,
}

/// Order-independent aggregation: smaller (dist, pos) wins.
#[derive(Debug, Default)]
pub struct BestSoFar {
    slots: Vec<Option<BestMapping>>,
}

impl BestSoFar {
    pub fn new(n_reads: usize) -> Self {
        BestSoFar { slots: vec![None; n_reads] }
    }

    /// Fold one outcome in.
    pub fn update(&mut self, o: AffineOutcome) {
        let slot = &mut self.slots[o.read_id as usize];
        match slot {
            None => {
                *slot = Some(BestMapping {
                    pos: o.pos,
                    dist: o.dist,
                    cigar: o.cigar,
                    candidates: 1,
                    reverse: o.reverse,
                })
            }
            Some(b) => {
                b.candidates += 1;
                // forward orientation wins ties (deterministic)
                if (o.dist, o.pos, o.reverse) < (b.dist, b.pos, b.reverse) {
                    b.pos = o.pos;
                    b.dist = o.dist;
                    b.cigar = o.cigar;
                    b.reverse = o.reverse;
                }
            }
        }
    }

    /// Final mapping of one read.
    pub fn get(&self, read_id: u32) -> Option<&BestMapping> {
        self.slots.get(read_id as usize).and_then(|s| s.as_ref())
    }

    /// Consume into the per-read decision vector.
    pub fn into_mappings(self) -> Vec<Option<BestMapping>> {
        self.slots
    }

    pub fn mapped_count(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn o(read_id: u32, pos: i64, dist: i32) -> AffineOutcome {
        AffineOutcome { read_id, pos, dist, cigar: Cigar(vec![]), reverse: false }
    }

    #[test]
    fn keeps_minimum() {
        let mut s = BestSoFar::new(2);
        s.update(o(0, 100, 5));
        s.update(o(0, 50, 2));
        s.update(o(0, 70, 9));
        let b = s.get(0).unwrap();
        assert_eq!((b.pos, b.dist, b.candidates), (50, 2, 3));
        assert!(s.get(1).is_none());
        assert_eq!(s.mapped_count(), 1);
    }

    #[test]
    fn tie_break_leftmost() {
        let mut s = BestSoFar::new(1);
        s.update(o(0, 100, 3));
        s.update(o(0, 40, 3));
        assert_eq!(s.get(0).unwrap().pos, 40);
    }

    #[test]
    fn order_independent_property() {
        check("best-so-far order independence", 0xBE57, 50, |rng| {
            let n = rng.gen_range(1..20usize);
            let outcomes: Vec<AffineOutcome> = (0..n)
                .map(|_| o(0, rng.gen_range(0..1000i64), rng.gen_range(0..30i32)))
                .collect();
            let mut forward = BestSoFar::new(1);
            for oc in outcomes.iter().cloned() {
                forward.update(oc);
            }
            let mut reverse = BestSoFar::new(1);
            for oc in outcomes.iter().rev().cloned() {
                reverse.update(oc);
            }
            let (f, r) = (forward.get(0).unwrap(), reverse.get(0).unwrap());
            assert_eq!((f.pos, f.dist), (r.pos, r.dist));
        });
    }
}
