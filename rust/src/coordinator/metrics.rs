//! Pipeline metrics: counters gathered during a real mapping run, and
//! their conversion into [`crate::simulator::SimCounts`] so the paper's
//! Eq. 6/7 reports can be generated from measured (not estimated)
//! workload statistics.

use std::collections::HashMap;
use std::time::Duration;

use crate::simulator::SimCounts;

/// Counters for one pipeline run.
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    pub n_reads: u64,
    pub routed_pairs: u64,
    pub riscv_pairs: u64,
    pub dropped_pairs: u64,
    pub linear_instances: u64,
    pub affine_instances: u64,
    pub riscv_linear_instances: u64,
    pub riscv_affine_instances: u64,
    pub filter_passed: u64,
    pub reads_with_candidates: u64,
    pub linear_batches: u64,
    pub affine_batches: u64,
    pub traceback_failures: u64,
    /// Per-crossbar routed pair counts (bottleneck analysis).
    pub pairs_per_xbar: HashMap<u32, u64>,
    /// Per-crossbar affine instance counts.
    pub affine_per_xbar: HashMap<u32, u64>,
    /// Wall-clock stage timings (host side).
    pub t_seed: Duration,
    pub t_linear: Duration,
    pub t_affine: Duration,
    pub t_traceback: Duration,
    pub t_total: Duration,
}

impl Metrics {
    /// Convert measured counters into simulator counts (the bridge from
    /// the live run to Eq. 6/7 projections).
    pub fn to_sim_counts(&self) -> SimCounts {
        SimCounts {
            n_reads: self.n_reads,
            routed_pairs: self.routed_pairs,
            dropped_pairs: self.dropped_pairs,
            riscv_pairs: self.riscv_pairs,
            linear_instances: self.linear_instances,
            affine_instances: self.affine_instances,
            riscv_linear_instances: self.riscv_linear_instances,
            riscv_affine_instances: self.riscv_affine_instances,
            k_linear: self.pairs_per_xbar.values().copied().max().unwrap_or(0),
            bottleneck_affine: self.affine_per_xbar.values().copied().max().unwrap_or(0),
            active_xbars: self.pairs_per_xbar.len() as u64,
            reads_with_candidates: self.reads_with_candidates,
        }
    }

    /// Host-side mapping throughput (reads/s).
    pub fn host_throughput(&self) -> f64 {
        if self.t_total.is_zero() {
            return 0.0;
        }
        self.n_reads as f64 / self.t_total.as_secs_f64()
    }

    /// Filter pass rate over crossbar linear instances.
    pub fn pass_rate(&self) -> f64 {
        if self.linear_instances == 0 {
            return 0.0;
        }
        self.filter_passed as f64 / self.linear_instances as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "reads={} pairs={} (riscv {}, dropped {}) linJ={} affJ={} pass={:.1}% \
             batches={}L/{}A host={:.1} reads/s",
            self.n_reads,
            self.routed_pairs,
            self.riscv_pairs,
            self.dropped_pairs,
            self.linear_instances,
            self.affine_instances,
            100.0 * self.pass_rate(),
            self.linear_batches,
            self.affine_batches,
            self.host_throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_counts_bridge() {
        let mut m = Metrics { n_reads: 10, routed_pairs: 80, linear_instances: 500, ..Default::default() };
        m.pairs_per_xbar.insert(1, 30);
        m.pairs_per_xbar.insert(2, 50);
        m.affine_per_xbar.insert(2, 7);
        let c = m.to_sim_counts();
        assert_eq!(c.k_linear, 50);
        assert_eq!(c.bottleneck_affine, 7);
        assert_eq!(c.active_xbars, 2);
        assert_eq!(c.n_reads, 10);
    }

    #[test]
    fn rates() {
        let m = Metrics {
            n_reads: 4,
            linear_instances: 100,
            filter_passed: 25,
            t_total: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.pass_rate() - 0.25).abs() < 1e-12);
        assert!((m.host_throughput() - 2.0).abs() < 1e-12);
        assert!(m.summary().contains("pass=25.0%"));
    }
}
