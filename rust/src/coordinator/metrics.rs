//! Pipeline metrics: counters gathered during a real mapping run, and
//! their conversion into [`crate::simulator::SimCounts`] so the paper's
//! Eq. 6/7 reports can be generated from measured (not estimated)
//! workload statistics.
//!
//! Metrics are mergeable ([`Metrics::merge`]): shard workers and
//! streaming chunks each produce a partial `Metrics`, and the driver
//! folds them into one. Workload counters (what was routed, filtered,
//! aligned) are sharding-invariant — [`Metrics::invariant_counters`]
//! collects exactly that subset, which the determinism suite holds
//! byte-identical across thread counts. Batch-shape counters
//! (`linear_batches`/`affine_batches`) and wall-clock timings legitimately
//! depend on how the run was partitioned and are excluded.

use std::collections::{BTreeMap, HashMap};
use std::time::Duration;

use crate::simulator::SimCounts;

/// Counters for one pipeline run (or one shard / streaming chunk of it).
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    /// Reads presented to the pipeline.
    pub n_reads: u64,
    /// (read, minimizer) pairs admitted to crossbars.
    pub routed_pairs: u64,
    /// Pairs routed to the DP-RISC-V pool (lowTh minimizers).
    pub riscv_pairs: u64,
    /// Pairs dropped by the per-crossbar maxReads cap.
    pub dropped_pairs: u64,
    /// Linear WF instances built for the crossbar path.
    pub linear_instances: u64,
    /// Affine WF instances that advanced past the filter.
    pub affine_instances: u64,
    /// Linear WF instances run by the RISC-V offload path.
    pub riscv_linear_instances: u64,
    /// Affine WF instances run by the RISC-V offload path.
    pub riscv_affine_instances: u64,
    /// Linear instances whose distance passed the eth filter.
    pub filter_passed: u64,
    /// Reads with at least one surviving affine candidate.
    pub reads_with_candidates: u64,
    /// Engine calls made by the linear filter stage (depends on
    /// batch size and shard count — not a workload invariant).
    // dart-analyze: allow(metrics-registry): batch shape varies with the
    // partition (threads x epoch), excluded by invariant 4.
    pub linear_batches: u64,
    /// Engine calls made by the affine alignment stage (ditto).
    // dart-analyze: allow(metrics-registry): batch shape varies with the
    // partition (threads x epoch), excluded by invariant 4.
    pub affine_batches: u64,
    /// Resolved SIMD lane width (bits) of the worker engines; 0 when
    /// the engine is scalar (`rust`, or `--simd off`). A gauge, not a
    /// count: [`Metrics::merge`] takes the max, and it is deliberately
    /// OUTSIDE [`Metrics::invariant_counters`] — lane width is a
    /// dispatch detail that must never show up in workload counters,
    /// exactly like batch shape.
    // dart-analyze: allow(metrics-registry): a dispatch gauge (invariant
    // 8) — lane width must never look like a workload counter.
    pub simd_width: u64,
    /// Affine results whose traceback could not be reconstructed.
    pub traceback_failures: u64,
    /// Read pairs resolved as proper pairs (orientation + insert window)
    /// by the epoch-boundary pair arbitration. Zero in single-end runs.
    pub proper_pairs: u64,
    /// Mates recovered by the rescue scan near their partner's locus.
    pub rescued_mates: u64,
    /// Banded WF instances spent by the rescue scan (always on the
    /// scalar engine, so the count is engine-invariant).
    pub rescue_instances: u64,
    /// Per-crossbar routed pair counts (bottleneck analysis).
    // dart-analyze: allow(determinism): the per-crossbar maps are only
    // ever folded order-free — merge() sums into entry() slots,
    // invariant_counters() re-keys them through a sorted BTreeMap, and
    // to_sim_counts() takes max()/len() — so iteration order cannot
    // reach any emitted byte or counter value.
    pub pairs_per_xbar: HashMap<u32, u64>,
    /// Per-crossbar affine instance counts.
    pub affine_per_xbar: HashMap<u32, u64>,
    /// Wall-clock of seed/route/admission/batch building (host side; for
    /// merged metrics, the sum over shards' stage clocks).
    pub t_seed: Duration,
    /// Wall-clock of the batched linear filter stage.
    pub t_linear: Duration,
    /// Wall-clock of the batched affine alignment stage.
    pub t_affine: Duration,
    /// Wall-clock of traceback decoding (inside the affine stage).
    pub t_traceback: Duration,
    /// End-to-end wall-clock of the run.
    pub t_total: Duration,
}

impl Metrics {
    /// Fold another (shard or chunk) `Metrics` into this one: counters
    /// and per-crossbar maps add, stage clocks sum (so merged timings
    /// are aggregate CPU time, not wall-clock, when shards overlap).
    pub fn merge(&mut self, m: Metrics) {
        self.n_reads += m.n_reads;
        self.routed_pairs += m.routed_pairs;
        self.riscv_pairs += m.riscv_pairs;
        self.dropped_pairs += m.dropped_pairs;
        self.linear_instances += m.linear_instances;
        self.affine_instances += m.affine_instances;
        self.riscv_linear_instances += m.riscv_linear_instances;
        self.riscv_affine_instances += m.riscv_affine_instances;
        self.filter_passed += m.filter_passed;
        self.reads_with_candidates += m.reads_with_candidates;
        self.linear_batches += m.linear_batches;
        self.affine_batches += m.affine_batches;
        // dart-analyze: allow(determinism): simd_width is a host gauge
        // reported for diagnostics; invariant_counters() excludes it
        // (invariant 4/5 — output bytes are SIMD-width-invariant, held
        // by the determinism suite's Wide-vs-U64 golden comparison).
        self.simd_width = self.simd_width.max(m.simd_width);
        self.traceback_failures += m.traceback_failures;
        self.proper_pairs += m.proper_pairs;
        self.rescued_mates += m.rescued_mates;
        self.rescue_instances += m.rescue_instances;
        for (k, v) in m.pairs_per_xbar {
            *self.pairs_per_xbar.entry(k).or_default() += v;
        }
        for (k, v) in m.affine_per_xbar {
            *self.affine_per_xbar.entry(k).or_default() += v;
        }
        self.t_seed += m.t_seed;
        self.t_linear += m.t_linear;
        self.t_affine += m.t_affine;
        self.t_traceback += m.t_traceback;
        self.t_total += m.t_total;
    }

    /// The sharding-invariant workload counters as a flat ordered map
    /// (including the per-crossbar distributions). Two runs of the same
    /// read set at different `threads` settings must produce equal maps;
    /// batch-shape counters and timings are deliberately excluded.
    pub fn invariant_counters(&self) -> BTreeMap<String, u64> {
        let mut m = BTreeMap::new();
        m.insert("n_reads".to_string(), self.n_reads);
        m.insert("routed_pairs".to_string(), self.routed_pairs);
        m.insert("riscv_pairs".to_string(), self.riscv_pairs);
        m.insert("dropped_pairs".to_string(), self.dropped_pairs);
        m.insert("linear_instances".to_string(), self.linear_instances);
        m.insert("affine_instances".to_string(), self.affine_instances);
        m.insert("riscv_linear_instances".to_string(), self.riscv_linear_instances);
        m.insert("riscv_affine_instances".to_string(), self.riscv_affine_instances);
        m.insert("filter_passed".to_string(), self.filter_passed);
        m.insert("reads_with_candidates".to_string(), self.reads_with_candidates);
        m.insert("traceback_failures".to_string(), self.traceback_failures);
        m.insert("proper_pairs".to_string(), self.proper_pairs);
        m.insert("rescued_mates".to_string(), self.rescued_mates);
        m.insert("rescue_instances".to_string(), self.rescue_instances);
        for (k, v) in &self.pairs_per_xbar {
            m.insert(format!("xbar{k}:pairs"), *v);
        }
        for (k, v) in &self.affine_per_xbar {
            m.insert(format!("xbar{k}:affine"), *v);
        }
        m
    }

    /// Convert measured counters into simulator counts (the bridge from
    /// the live run to Eq. 6/7 projections). Pair totals are a
    /// simulator-side concept (`Metrics` has no per-pair availability
    /// counter) and are left at zero.
    pub fn to_sim_counts(&self) -> SimCounts {
        SimCounts {
            n_reads: self.n_reads,
            routed_pairs: self.routed_pairs,
            dropped_pairs: self.dropped_pairs,
            riscv_pairs: self.riscv_pairs,
            linear_instances: self.linear_instances,
            affine_instances: self.affine_instances,
            riscv_linear_instances: self.riscv_linear_instances,
            riscv_affine_instances: self.riscv_affine_instances,
            k_linear: self.pairs_per_xbar.values().copied().max().unwrap_or(0),
            bottleneck_affine: self.affine_per_xbar.values().copied().max().unwrap_or(0),
            active_xbars: self.pairs_per_xbar.len() as u64,
            reads_with_candidates: self.reads_with_candidates,
            n_pairs: 0,
            pairs_with_candidates: 0,
        }
    }

    /// Host-side mapping throughput (reads/s).
    pub fn host_throughput(&self) -> f64 {
        if self.t_total.is_zero() {
            return 0.0;
        }
        self.n_reads as f64 / self.t_total.as_secs_f64()
    }

    /// Filter pass rate over crossbar linear instances.
    pub fn pass_rate(&self) -> f64 {
        if self.linear_instances == 0 {
            return 0.0;
        }
        self.filter_passed as f64 / self.linear_instances as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "reads={} pairs={} (riscv {}, dropped {}) linJ={} affJ={} pass={:.1}% \
             batches={}L/{}A host={:.1} reads/s",
            self.n_reads,
            self.routed_pairs,
            self.riscv_pairs,
            self.dropped_pairs,
            self.linear_instances,
            self.affine_instances,
            100.0 * self.pass_rate(),
            self.linear_batches,
            self.affine_batches,
            self.host_throughput(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_counts_bridge() {
        let mut m =
            Metrics { n_reads: 10, routed_pairs: 80, linear_instances: 500, ..Default::default() };
        m.pairs_per_xbar.insert(1, 30);
        m.pairs_per_xbar.insert(2, 50);
        m.affine_per_xbar.insert(2, 7);
        let c = m.to_sim_counts();
        assert_eq!(c.k_linear, 50);
        assert_eq!(c.bottleneck_affine, 7);
        assert_eq!(c.active_xbars, 2);
        assert_eq!(c.n_reads, 10);
    }

    #[test]
    fn rates() {
        let m = Metrics {
            n_reads: 4,
            linear_instances: 100,
            filter_passed: 25,
            t_total: Duration::from_secs(2),
            ..Default::default()
        };
        assert!((m.pass_rate() - 0.25).abs() < 1e-12);
        assert!((m.host_throughput() - 2.0).abs() < 1e-12);
        assert!(m.summary().contains("pass=25.0%"));
    }

    #[test]
    fn merge_sums_counters_and_maps() {
        let mut a = Metrics { n_reads: 2, routed_pairs: 5, ..Default::default() };
        a.pairs_per_xbar.insert(1, 3);
        let mut b = Metrics { n_reads: 3, routed_pairs: 7, ..Default::default() };
        b.pairs_per_xbar.insert(1, 2);
        b.pairs_per_xbar.insert(9, 4);
        b.t_seed = Duration::from_millis(5);
        a.merge(b);
        assert_eq!(a.n_reads, 5);
        assert_eq!(a.routed_pairs, 12);
        assert_eq!(a.pairs_per_xbar[&1], 5);
        assert_eq!(a.pairs_per_xbar[&9], 4);
        assert_eq!(a.t_seed, Duration::from_millis(5));
    }

    #[test]
    fn invariant_counters_exclude_batch_shape() {
        let m = Metrics {
            n_reads: 1,
            linear_batches: 42,
            affine_batches: 17,
            simd_width: 256,
            ..Default::default()
        };
        let c = m.invariant_counters();
        assert_eq!(c["n_reads"], 1);
        assert!(!c.keys().any(|k| k.contains("batch")));
        assert!(!c.keys().any(|k| k.contains("simd")), "lane width is not a workload counter");
    }

    #[test]
    fn simd_width_merges_as_a_gauge() {
        let mut a = Metrics { simd_width: 64, ..Default::default() };
        a.merge(Metrics { simd_width: 512, ..Default::default() });
        a.merge(Metrics { simd_width: 0, ..Default::default() });
        assert_eq!(a.simd_width, 512, "merge takes the max, not the sum");
    }
}
