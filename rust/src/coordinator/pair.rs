//! Proper-pair arbitration for paired-end mapping.
//!
//! Real short-read workloads are overwhelmingly paired: the sequencer
//! reads both ends of a ~350 bp fragment, so the two mates of a pair
//! must map in opposite orientations (FR) at a distance drawn from the
//! library's insert-size distribution. That joint constraint is a major
//! accuracy lever — a read that is ambiguous on its own (a repeat copy)
//! is usually unambiguous once its mate pins the fragment.
//!
//! The pipeline realizes pairing as an **epoch-boundary arbitration
//! stage** that runs on the coordinator after the shard workers drain:
//!
//! 1. every surviving affine outcome of the epoch is grouped per read
//!    ([`super::state::PairCandidates`]) and canonically sorted, so the
//!    candidate lists are identical for any shard interleaving;
//! 2. for each pair, all R1 × R2 candidate combinations in proper FR
//!    orientation with an insert inside
//!    [`PairingConfig::insert_min`]..[`PairingConfig::insert_max`] are
//!    scored by combined affine distance (deterministic lexicographic
//!    tie-break), and the best proper combination wins;
//! 3. pairs with no proper combination fall back to each mate's
//!    **single-end decision** (the head of its canonical candidate
//!    list, which equals the [`super::state::BestSoFar`] winner
//!    exactly — a pair with one unmappable mate degrades to the
//!    single-end result);
//! 4. optionally, a mate with *no* candidates is **rescued**: a banded
//!    WF scan over the insert window implied by its partner's mapping,
//!    always on the scalar engine so the result is engine-invariant.
//!
//! Determinism (the pipeline's sixth invariant): pair resolution is a
//! pure function of one epoch's candidate multiset, the read sequences,
//! the reference, and the [`PairingConfig`]. No state crosses epoch
//! boundaries, epochs always end on pair boundaries, and rescue runs on
//! the fixed scalar engine — so paired output is byte-identical for
//! every threads × engine × epoch setting, exactly like single-end
//! output. `tests/golden_e2e.rs` and `tests/pair_parity.rs` hold this.

use std::sync::Arc;

use anyhow::Result;

use crate::genome::revcomp;
use crate::index::IndexRef;
use crate::params::{ETH, SAT_AFFINE};
use crate::runtime::{RustEngine, WfEngine};

use super::batcher::WorkTag;
use super::metrics::Metrics;
use super::pipeline::FinalMapping;
use super::shard::decode_affine;
use super::state::AffineOutcome;

/// Paired-end resolution policy ([`super::PipelineConfig::pairing`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PairingConfig {
    /// Smallest outer fragment length accepted as a proper pair.
    pub insert_min: u32,
    /// Largest outer fragment length accepted as a proper pair.
    pub insert_max: u32,
    /// Attempt to rescue a candidate-less mate by scanning the insert
    /// window implied by its partner's mapping.
    pub rescue: bool,
}

impl Default for PairingConfig {
    fn default() -> Self {
        PairingConfig { insert_min: 50, insert_max: 1000, rescue: true }
    }
}

/// How a read's final mapping was decided.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PairStatus {
    /// Single-end run: no pair arbitration applied.
    #[default]
    Unpaired,
    /// Both mates placed by a proper-pair combination (FR orientation,
    /// insert inside the window).
    Proper,
    /// Paired run, but this mate kept its single-end decision (no
    /// proper combination existed for the pair).
    Single,
    /// This mate had no candidates of its own and was recovered by the
    /// rescue scan near its partner's locus.
    Rescued,
}

impl PairStatus {
    /// The TSV spelling of the status (`map` paired output column 8).
    pub fn as_str(self) -> &'static str {
        match self {
            PairStatus::Unpaired => "unpaired",
            PairStatus::Proper => "proper",
            PairStatus::Single => "single",
            PairStatus::Rescued => "rescued",
        }
    }
}

/// Upper bound on rescue anchors per mate (a safety valve for absurd
/// insert windows; the default window needs ~160).
const MAX_RESCUE_ANCHORS: usize = 2048;

/// Resolve one epoch's pairs into per-read decisions.
///
/// `lists` holds the canonically sorted candidate list of each read in
/// the epoch (`lists[i]` is read `start + i`; `lists.len()` is even and
/// reads `2k`/`2k+1` are mates). `seqs[i]` is the forward (as-sequenced)
/// sequence of read `start + i`, used only by the rescue scan.
pub(crate) fn resolve_epoch_pairs(
    start: u32,
    lists: Vec<Vec<AffineOutcome>>,
    seqs: &[Arc<[u8]>],
    index: IndexRef<'_>,
    pcfg: &PairingConfig,
    metrics: &mut Metrics,
) -> Result<Vec<Option<FinalMapping>>> {
    debug_assert_eq!(lists.len() % 2, 0, "epochs end on pair boundaries");
    debug_assert_eq!(lists.len(), seqs.len());
    let mut out: Vec<Option<FinalMapping>> = Vec::with_capacity(lists.len());
    let mut it = lists.into_iter();
    let mut slot = 0usize;
    while let (Some(l1), Some(l2)) = (it.next(), it.next()) {
        let (id1, id2) = (start + slot as u32, start + slot as u32 + 1);
        // the mate tag each outcome carried through the shard workers
        // must agree with the paired id layout the arbitration assumes —
        // a mismatch means routing and pairing disagree about which read
        // is which mate
        debug_assert!(l1.iter().all(|o| o.mate == 0), "R1 list holds a mate-1 outcome");
        debug_assert!(l2.iter().all(|o| o.mate == 1), "R2 list holds a mate-0 outcome");
        match best_proper_combination(&l1, &l2, index.read_len(), pcfg) {
            Some((i1, i2)) => {
                metrics.proper_pairs += 1;
                out.push(Some(final_mapping(id1, &l1[i1], l1.len() as u32, PairStatus::Proper)));
                out.push(Some(final_mapping(id2, &l2[i2], l2.len() as u32, PairStatus::Proper)));
            }
            None => {
                let d1 = singleton_decision(id1, &l1);
                let d2 = singleton_decision(id2, &l2);
                let (d1, d2) = match (d1, d2) {
                    // exactly one mate mapped: try to rescue the other
                    // near its partner's locus
                    (Some(a), None) if pcfg.rescue => {
                        let r = rescue_mate(&a, &seqs[slot + 1], id2, 1, index, pcfg, metrics)?;
                        (Some(a), r)
                    }
                    (None, Some(b)) if pcfg.rescue => {
                        let r = rescue_mate(&b, &seqs[slot], id1, 0, index, pcfg, metrics)?;
                        (r, Some(b))
                    }
                    other => other,
                };
                out.push(d1);
                out.push(d2);
            }
        }
        slot += 2;
    }
    Ok(out)
}

/// Build the per-read decision from a winning candidate.
fn final_mapping(
    read_id: u32,
    o: &AffineOutcome,
    candidates: u32,
    pair: PairStatus,
) -> FinalMapping {
    FinalMapping {
        read_id,
        pos: o.pos,
        dist: o.dist,
        cigar: o.cigar.clone(),
        candidates,
        reverse: o.reverse,
        pair,
    }
}

/// The single-end fallback decision: the head of the canonical list
/// (identical to the [`super::state::BestSoFar`] winner), tagged
/// [`PairStatus::Single`].
fn singleton_decision(read_id: u32, list: &[AffineOutcome]) -> Option<FinalMapping> {
    list.first().map(|o| final_mapping(read_id, o, list.len() as u32, PairStatus::Single))
}

/// Scan all R1 × R2 candidate combinations for the best proper pair:
/// opposite orientations, forward mate upstream, outer insert inside the
/// configured window. Score is combined affine distance with a full
/// lexicographic tie-break `(dist, pos1, pos2, key1, key2)`, so the
/// winning combination is unique and arrival-order independent.
/// Returns the winning indices into the (sorted) lists.
fn best_proper_combination(
    l1: &[AffineOutcome],
    l2: &[AffineOutcome],
    read_len: usize,
    pcfg: &PairingConfig,
) -> Option<(usize, usize)> {
    let mut best: Option<((i32, i64, i64, u64, u64), (usize, usize))> = None;
    for (i1, c1) in l1.iter().enumerate() {
        if let Some(((bd, ..), _)) = best {
            // lists are dist-sorted: once c1 alone exceeds the best
            // combined distance, no later combination can win
            if c1.dist > bd {
                break;
            }
        }
        for (i2, c2) in l2.iter().enumerate() {
            if c1.reverse == c2.reverse {
                continue; // FR requires opposite orientations
            }
            let (fwd, rev) = if c1.reverse { (c2, c1) } else { (c1, c2) };
            if rev.pos < fwd.pos {
                continue; // forward mate must be upstream
            }
            let insert = rev.pos + read_len as i64 - fwd.pos;
            if insert < pcfg.insert_min as i64 || insert > pcfg.insert_max as i64 {
                continue;
            }
            let score = (c1.dist + c2.dist, c1.pos, c2.pos, c1.key, c2.key);
            let better = match &best {
                None => true,
                Some((b, _)) => score < *b,
            };
            if better {
                best = Some((score, (i1, i2)));
            }
        }
    }
    best.map(|(_, idx)| idx)
}

/// Rescue scan: the mate had no candidates of its own, but its partner
/// mapped — so if the pair is real, the mate lies in the partner's
/// insert window in the opposite orientation. Sweep banded WF anchors
/// across that window (always on the scalar engine, so the outcome is
/// identical whatever engine the run used) and take the best surviving
/// alignment, if any.
fn rescue_mate(
    partner: &FinalMapping,
    mate_seq: &Arc<[u8]>,
    read_id: u32,
    mate: u8,
    index: IndexRef<'_>,
    pcfg: &PairingConfig,
    metrics: &mut Metrics,
) -> Result<Option<FinalMapping>> {
    let rl = index.read_len() as i64;
    // Expected leftmost position range of the rescued mate under the
    // insert window (FR orientation, partner's side known).
    let (lo, hi) = if partner.reverse {
        // partner is the downstream reverse mate; rescued mate is
        // forward, upstream: insert = partner.pos + rl - a
        (partner.pos + rl - pcfg.insert_max as i64, partner.pos + rl - pcfg.insert_min as i64)
    } else {
        // partner is the upstream forward mate; rescued mate is
        // reverse, downstream: insert = a + rl - partner.pos
        (partner.pos + pcfg.insert_min as i64 - rl, partner.pos + pcfg.insert_max as i64 - rl)
    };
    let lo = lo.max(0);
    let hi = hi.min(index.reference().len() as i64 - 1);
    if hi < lo {
        return Ok(None);
    }
    let expected_reverse = !partner.reverse;
    let query: Vec<u8> =
        if expected_reverse { revcomp(mate_seq) } else { mate_seq.as_ref().to_vec() };

    // Anchor sweep: the band reaches ±eth around each anchor, so a step
    // of eth covers every position in [lo, hi] with margin.
    let span = (hi - lo) as usize + 1;
    let step = (ETH.max(1)).max(span.div_ceil(MAX_RESCUE_ANCHORS)) as i64;
    let mut anchors: Vec<u32> = Vec::with_capacity(span / step as usize + 1);
    let mut a = lo;
    while a <= hi {
        anchors.push(a as u32);
        a += step;
    }
    metrics.rescue_instances += anchors.len() as u64;

    let wins: Vec<Vec<u8>> = anchors.iter().map(|&p| index.window_for(p, 0)).collect();
    let rr: Vec<&[u8]> = anchors.iter().map(|_| query.as_slice()).collect();
    let ww: Vec<&[u8]> = wins.iter().map(|w| w.as_slice()).collect();
    let mut engine = RustEngine;
    let lin = engine.linear_batch(&rr, &ww)?;

    // Affine-align the filter survivors and keep the best decodable
    // outcome by the canonical rank.
    let survivors: Vec<usize> =
        (0..anchors.len()).filter(|&i| lin.best[i] <= ETH as i32).collect();
    if survivors.is_empty() {
        return Ok(None);
    }
    let arr: Vec<&[u8]> = survivors.iter().map(|_| query.as_slice()).collect();
    let aww: Vec<&[u8]> = survivors.iter().map(|&i| ww[i]).collect();
    let aff = engine.affine_batch(&arr, &aww)?;
    let mut best: Option<AffineOutcome> = None;
    for (si, &i) in survivors.iter().enumerate() {
        if aff.best[si] >= SAT_AFFINE {
            continue;
        }
        let tag = WorkTag {
            read_id,
            // rescue instances sit outside the routed pair-id space;
            // the anchor makes the arbitration key total
            pair_id: u32::MAX,
            ref_pos: anchors[i],
            read_offset: 0,
            pl: anchors[i] as i64,
            xbar: u32::MAX,
            reverse: expected_reverse,
            mate,
        };
        let decoded = decode_affine(
            &tag,
            aff.best[si],
            aff.best_j[si] as usize,
            &aff.dirs[si],
            &query,
            metrics,
        );
        if let Some(o) = decoded {
            let better = match &best {
                None => true,
                Some(b) => o.rank() < b.rank(),
            };
            if better {
                best = Some(o);
            }
        }
    }
    Ok(best.map(|o| {
        metrics.rescued_mates += 1;
        final_mapping(read_id, &o, 1, PairStatus::Rescued)
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::Cigar;

    fn cand(pos: i64, dist: i32, reverse: bool, key: u64) -> AffineOutcome {
        AffineOutcome {
            read_id: 0,
            pos,
            dist,
            cigar: Cigar(vec![]),
            reverse,
            mate: 0,
            key,
        }
    }

    fn pcfg() -> PairingConfig {
        PairingConfig { insert_min: 100, insert_max: 500, rescue: true }
    }

    #[test]
    fn proper_combination_requires_fr_orientation_and_insert_window() {
        let rl = 100usize;
        // R1 forward at 1000, R2 reverse at 1250: insert 350 — proper
        let l1 = vec![cand(1000, 1, false, 1)];
        let l2 = vec![cand(1250, 1, true, 2)];
        assert_eq!(best_proper_combination(&l1, &l2, rl, &pcfg()), Some((0, 0)));

        // same orientation: never proper
        let l2_same = vec![cand(1250, 0, false, 2)];
        assert_eq!(best_proper_combination(&l1, &l2_same, rl, &pcfg()), None);

        // insert outside the window
        let l2_far = vec![cand(3000, 0, true, 2)];
        assert_eq!(best_proper_combination(&l1, &l2_far, rl, &pcfg()), None);

        // reverse mate upstream of the forward mate: not FR
        let l2_up = vec![cand(700, 0, true, 2)];
        assert_eq!(best_proper_combination(&l1, &l2_up, rl, &pcfg()), None);

        // RF with R1 reverse / R2 forward is fine the other way around
        let l1r = vec![cand(1250, 1, true, 1)];
        let l2f = vec![cand(1000, 1, false, 2)];
        assert_eq!(best_proper_combination(&l1r, &l2f, rl, &pcfg()), Some((0, 0)));
    }

    #[test]
    fn concordant_candidates_beat_lone_better_distance() {
        let rl = 100usize;
        // R1: a dist-0 decoy at 5000 and the true dist-1 locus at 1000.
        // Lists arrive canonically sorted (dist-ascending).
        let l1 = vec![cand(5000, 0, false, 1), cand(1000, 1, false, 2)];
        // R2 maps only near the true fragment: the decoy has no proper
        // partner, so arbitration must pick the concordant combination.
        let l2 = vec![cand(1250, 1, true, 3)];
        assert_eq!(best_proper_combination(&l1, &l2, rl, &pcfg()), Some((1, 0)));
    }

    #[test]
    fn combination_score_breaks_ties_deterministically() {
        let rl = 100usize;
        // two proper combinations with equal combined distance: the
        // lexicographic (pos1, pos2, key…) tie-break picks the leftmost
        let l1 = vec![cand(1000, 1, false, 1), cand(1010, 1, false, 2)];
        let l2 = vec![cand(1250, 1, true, 3), cand(1260, 1, true, 4)];
        assert_eq!(best_proper_combination(&l1, &l2, rl, &pcfg()), Some((0, 0)));
    }
}
