//! Batching: packs (read, window) work items into engine-sized batches.
//!
//! In hardware the chip controllers broadcast one MAGIC op sequence to
//! every crossbar at once — a "lock-step round" over thousands of rows.
//! Host-side, the equivalent is packing many crossbars' row loads into a
//! single PJRT execution; the batcher accumulates work items and flushes
//! them at the artifact batch size (the engine pads partial batches).

use std::sync::Arc;

use crate::params::window_len;

/// Provenance of one WF instance (flows through to the results).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkTag {
    /// Read this instance belongs to.
    pub read_id: u32,
    /// Dense id of the routed (read, minimizer) pair this instance
    /// belongs to (MinOnly filtering groups by it).
    pub pair_id: u32,
    /// Reference occurrence (k-mer start) this instance aligns against.
    pub ref_pos: u32,
    /// Minimizer offset within the read.
    pub read_offset: u32,
    /// Potential location (ref_pos - read_offset).
    pub pl: i64,
    /// Crossbar that owns this instance (metrics / bottleneck analysis).
    pub xbar: u32,
    /// Reverse-complement orientation of the read.
    pub reverse: bool,
    /// Mate index within the read's pair (0 = R1 / single-end, 1 = R2).
    /// Provenance: pair arbitration groups candidates by read id and
    /// cross-checks this tag against the paired layout at resolution
    /// (`coordinator::pair`), catching any routing/pairing id desync.
    pub mate: u8,
}

/// One batch ready for the engine. Reads are shared slices (one
/// refcounted allocation per oriented read, cloned per instance — the
/// streaming replacement for the old borrowed-slice zero-copy); windows
/// are owned (computed per instance).
pub struct Batch {
    /// Provenance of each instance.
    pub tags: Vec<WorkTag>,
    /// Read sequences (shared; many instances of one read clone one Arc).
    pub reads: Vec<Arc<[u8]>>,
    /// Reference windows, owned (extracted per instance).
    pub wins: Vec<Vec<u8>>,
}

impl Batch {
    /// Number of instances in the batch.
    pub fn len(&self) -> usize {
        self.tags.len()
    }

    /// True when the batch holds no instances.
    pub fn is_empty(&self) -> bool {
        self.tags.is_empty()
    }

    /// Borrow the read sequences as the `&[&[u8]]` shape engines take.
    pub fn read_slices(&self) -> Vec<&[u8]> {
        self.reads.iter().map(|r| r.as_ref()).collect()
    }

    /// Borrow the windows as the `&[&[u8]]` shape engines take.
    pub fn win_slices(&self) -> Vec<&[u8]> {
        self.wins.iter().map(|w| w.as_slice()).collect()
    }
}

/// Accumulates work items; yields full batches eagerly.
pub struct Batcher {
    target: usize,
    read_len: usize,
    pending: Batch,
}

impl Batcher {
    /// `target` is the flush size (use the largest artifact batch for
    /// throughput; smaller for latency).
    pub fn new(target: usize, read_len: usize) -> Self {
        assert!(target >= 1);
        Batcher {
            target,
            read_len,
            pending: Batch { tags: Vec::new(), reads: Vec::new(), wins: Vec::new() },
        }
    }

    /// Add one work item; returns a full batch when the target is hit.
    pub fn push(&mut self, tag: WorkTag, read: Arc<[u8]>, win: Vec<u8>) -> Option<Batch> {
        debug_assert_eq!(read.len(), self.read_len);
        debug_assert_eq!(win.len(), window_len(self.read_len));
        self.pending.tags.push(tag);
        self.pending.reads.push(read);
        self.pending.wins.push(win);
        if self.pending.len() >= self.target {
            Some(self.take())
        } else {
            None
        }
    }

    /// Flush whatever is pending (end of stream or epoch boundary).
    pub fn flush(&mut self) -> Option<Batch> {
        if self.pending.is_empty() {
            None
        } else {
            Some(self.take())
        }
    }

    /// Instances accumulated but not yet flushed.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    fn take(&mut self) -> Batch {
        std::mem::replace(
            &mut self.pending,
            Batch { tags: Vec::new(), reads: Vec::new(), wins: Vec::new() },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{window_len, READ_LEN};

    fn item(i: u32) -> (WorkTag, Arc<[u8]>, Vec<u8>) {
        (
            WorkTag {
                read_id: i,
                pair_id: i,
                ref_pos: i * 10,
                read_offset: 0,
                pl: i as i64 * 10,
                xbar: i,
                reverse: false,
                mate: 0,
            },
            Arc::from(vec![0u8; READ_LEN]),
            vec![1u8; window_len(READ_LEN)],
        )
    }

    #[test]
    fn flushes_at_target() {
        let mut b = Batcher::new(3, READ_LEN);
        let (t, r, w) = item(0);
        assert!(b.push(t, r, w).is_none());
        let (t, r, w) = item(1);
        assert!(b.push(t, r, w).is_none());
        let (t, r, w) = item(2);
        let batch = b.push(t, r, w).expect("full batch");
        assert_eq!(batch.len(), 3);
        assert_eq!(batch.tags[1].read_id, 1);
        assert_eq!(batch.read_slices().len(), 3);
        assert_eq!(batch.win_slices().len(), 3);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn flush_drains_partial() {
        let mut b = Batcher::new(100, READ_LEN);
        for i in 0..5 {
            let (t, r, w) = item(i);
            assert!(b.push(t, r, w).is_none());
        }
        let batch = b.flush().unwrap();
        assert_eq!(batch.len(), 5);
        assert!(b.flush().is_none(), "second flush is empty");
    }

    #[test]
    fn preserves_order_and_provenance() {
        let mut b = Batcher::new(4, READ_LEN);
        let mut out = Vec::new();
        for i in 0..10 {
            let (t, r, w) = item(i);
            if let Some(batch) = b.push(t, r, w) {
                out.extend(batch.tags.iter().map(|t| t.read_id));
            }
        }
        if let Some(batch) = b.flush() {
            out.extend(batch.tags.iter().map(|t| t.read_id));
        }
        assert_eq!(out, (0..10).collect::<Vec<_>>());
    }
}
