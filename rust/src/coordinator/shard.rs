//! Per-shard execution of pipeline stages 2-6 (paper Fig. 6; §V-B data
//! organization).
//!
//! DART-PIM gets its throughput from thousands of crossbars each owning a
//! disjoint slice of the reference segments. The host realization mirrors
//! that: routed (read, minimizer) pairs are partitioned by minimizer hash
//! ([`crate::index::shard_of`]), so every shard touches a disjoint set of
//! minimizers — and, because each minimizer owns a contiguous private
//! crossbar range (see [`super::router`]), a disjoint set of crossbars,
//! Reads FIFOs, and reference segments. One worker thread per shard then
//! runs FIFO admission, the batched linear filter, batched affine
//! alignment, traceback, and the RISC-V offload path over its private
//! slice, with no synchronization beyond the channel that feeds it.
//!
//! A [`ShardWorker`] splits the work into an incremental phase
//! ([`ShardWorker::ingest`]: FIFO admission, window extraction, batch
//! packing — runs as items stream in, overlapping the producer's
//! routing) and a compute phase ([`ShardWorker::finish`]: the batched WF
//! engine calls, traceback, and the RISC-V offload path).
//!
//! Determinism contract (held by `tests/shard_determinism.rs`):
//!
//! * Pair ids are assigned by the serial routing stage, so they are
//!   identical for every shard count.
//! * A crossbar's FIFO receives its entries in the same relative order
//!   regardless of sharding (per-shard item streams preserve the global
//!   emission order), so maxReads drops are identical.
//! * Workers emit [`AffineOutcome`]s whose arbitration key is the serial
//!   emission order; [`super::state::BestSoFar`] resolves full ties with
//!   it, so the merged winners are identical under any interleaving.
//! * Workload counters in [`Metrics`] are item-local sums and merge to
//!   identical totals; only the batch-shape counters
//!   (`linear_batches`/`affine_batches`) and wall-clock timings depend on
//!   the shard count.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::align::traceback::{script_cost, traceback};
use crate::align::Cigar;
use crate::index::MinimizerIndex;
use crate::params::{ETH, SAT_AFFINE};
use crate::runtime::{RustEngine, WfEngine};

use super::batcher::{Batch, Batcher, WorkTag};
use super::fifo::{FifoEntry, PushResult, ReadsFifo};
use super::metrics::Metrics;
use super::pipeline::{FilterPolicy, PipelineConfig};
use super::router::Target;
use super::state::AffineOutcome;

/// One routed (read, minimizer) pair bound to its oriented read sequence:
/// the unit of work a shard worker consumes.
#[derive(Debug, Clone, Copy)]
pub struct ShardItem<'a> {
    /// Globally sequential pair id (assigned by the serial routing
    /// stage; identical for every shard count).
    pub pair_id: u32,
    /// Read this pair belongs to.
    pub read_id: u32,
    /// Minimizer offset within the read.
    pub read_offset: u32,
    /// The minimizer k-mer (the shard partition key).
    pub kmer: u64,
    /// Crossbar range or RISC-V pool executing this pair.
    pub target: Target,
    /// Reverse-complement orientation of `seq`.
    pub reverse: bool,
    /// The oriented read sequence (borrowed from the read set, or from
    /// the materialized reverse complements).
    pub seq: &'a [u8],
}

/// Serial emission order of one WF instance, used as the deterministic
/// tie-break key (see [`AffineOutcome::key`]): pairs are emitted in
/// pair-id order and occurrences within a pair in ascending reference
/// position.
fn emission_key(pair_id: u32, ref_pos: u32) -> u64 {
    (u64::from(pair_id) << 32) | u64::from(ref_pos)
}

/// Executes pipeline stages 2-6 over one shard's item stream.
///
/// The worker owns everything its slice needs — the Reads FIFOs of its
/// crossbars, the linear-stage batcher, and the RISC-V work list — so N
/// workers share nothing but the read-only index.
pub struct ShardWorker<'a> {
    index: &'a MinimizerIndex,
    cfg: &'a PipelineConfig,
    metrics: Metrics,
    fifos: HashMap<u32, ReadsFifo>,
    linear_batcher: Batcher<'a>,
    linear_batches: Vec<Batch<'a>>,
    riscv_items: Vec<(WorkTag, &'a [u8])>,
}

impl<'a> ShardWorker<'a> {
    /// Empty worker for one shard.
    pub fn new(index: &'a MinimizerIndex, cfg: &'a PipelineConfig) -> Self {
        ShardWorker {
            index,
            cfg,
            metrics: Metrics::default(),
            fifos: HashMap::new(),
            linear_batcher: Batcher::new(cfg.batch_size, index.read_len),
            linear_batches: Vec::new(),
            riscv_items: Vec::new(),
        }
    }

    /// Incremental phase (Fig. 6 steps 1-3): FIFO admission, window
    /// extraction, and batch packing for a slice of the item stream.
    /// Called repeatedly as chunks arrive, so this work overlaps the
    /// producer's routing; items must arrive in emission order (the
    /// determinism contract).
    pub fn ingest(&mut self, items: impl IntoIterator<Item = ShardItem<'a>>) {
        let t0 = Instant::now();
        let (index, cfg) = (self.index, self.cfg);
        for item in items {
            let occs = index.occurrences(item.kmer);
            match item.target {
                Target::Riscv => {
                    self.metrics.riscv_pairs += 1;
                    for &pos in occs {
                        self.riscv_items.push((
                            WorkTag {
                                read_id: item.read_id,
                                pair_id: item.pair_id,
                                ref_pos: pos,
                                read_offset: item.read_offset,
                                pl: pos as i64 - item.read_offset as i64,
                                xbar: u32::MAX, // RISC-V pool, not a crossbar
                                reverse: item.reverse,
                            },
                            item.seq,
                        ));
                    }
                }
                Target::Xbar { first, count } => {
                    // FIFO admission on the owning crossbar (the
                    // minimizer's crossbar range is private to this shard)
                    let fifo = self.fifos.entry(first).or_insert_with(|| {
                        ReadsFifo::new(cfg.dart.fifo_capacity_reads(), cfg.dart.max_reads)
                    });
                    let entry =
                        FifoEntry { read_id: item.read_id, read_offset: item.read_offset };
                    match fifo.push(entry) {
                        PushResult::CapExceeded => {
                            self.metrics.dropped_pairs += 1;
                            continue;
                        }
                        PushResult::Full => {
                            // batch-mode backpressure: the entry is
                            // consumed immediately below, so the FIFO
                            // drains as fast as it fills
                            fifo.pop();
                            if fifo.push(entry) == PushResult::CapExceeded {
                                self.metrics.dropped_pairs += 1;
                                continue;
                            }
                        }
                        PushResult::Accepted => {}
                    }
                    fifo.pop(); // consumed by this round's linear iteration
                    self.metrics.routed_pairs += 1;
                    *self.metrics.pairs_per_xbar.entry(first).or_default() += 1;
                    for sub in 1..count {
                        *self.metrics.pairs_per_xbar.entry(first + sub).or_default() += 1;
                    }
                    for (i, &pos) in occs.iter().enumerate() {
                        let tag = WorkTag {
                            read_id: item.read_id,
                            pair_id: item.pair_id,
                            ref_pos: pos,
                            read_offset: item.read_offset,
                            pl: pos as i64 - item.read_offset as i64,
                            // which of the minimizer's crossbars holds
                            // this occurrence's segment row
                            xbar: first + (i / cfg.dart.linear_rows) as u32,
                            reverse: item.reverse,
                        };
                        let win = index.window_for(pos, item.read_offset as usize);
                        self.metrics.linear_instances += 1;
                        if let Some(b) = self.linear_batcher.push(tag, item.seq, win) {
                            self.linear_batches.push(b);
                        }
                    }
                }
            }
        }
        self.metrics.t_seed += t0.elapsed();
    }

    /// Compute phase (Fig. 6 steps 3-6 + RISC-V offload): run the
    /// batched linear filter, batched affine alignment, and traceback on
    /// `engine`, then the RISC-V pairs on the scalar Rust engine.
    ///
    /// Returns the shard's candidate outcomes (for the caller to fold
    /// into a [`super::state::BestSoFar`]) and its [`Metrics`]
    /// contribution (`n_reads`, `reads_with_candidates`, and `t_total`
    /// are left at zero — they are whole-run quantities the caller owns).
    pub fn finish<E: WfEngine + ?Sized>(
        mut self,
        engine: &mut E,
    ) -> Result<(Vec<AffineOutcome>, Metrics)> {
        let mut metrics = self.metrics;
        if let Some(b) = self.linear_batcher.flush() {
            self.linear_batches.push(b);
        }

        // ---- Batched linear filter (Fig. 6 steps 3-4) ----
        let t0 = Instant::now();
        // pair_id -> (best dist, tag, window, read seq) for MinOnly
        let mut pair_best: HashMap<u32, (i32, WorkTag, Vec<u8>, &[u8])> = HashMap::new();
        let mut affine_batcher = Batcher::new(self.cfg.batch_size, self.index.read_len);
        let mut affine_batches: Vec<Batch<'_>> = Vec::new();
        for batch in &mut self.linear_batches {
            let ww: Vec<&[u8]> = batch.wins.iter().map(|v| v.as_slice()).collect();
            let out = engine.linear_batch(&batch.reads, &ww)?;
            drop(ww);
            metrics.linear_batches += 1;
            for i in 0..batch.tags.len() {
                let tag = batch.tags[i];
                if out.best[i] > ETH as i32 {
                    continue; // filtered out
                }
                metrics.filter_passed += 1;
                match self.cfg.filter_policy {
                    FilterPolicy::AllPassing => {
                        metrics.affine_instances += 1;
                        *metrics.affine_per_xbar.entry(tag.xbar).or_default() += 1;
                        // window moves to the affine stage (each is used
                        // at most once — §Perf opt 1)
                        let win = std::mem::take(&mut batch.wins[i]);
                        if let Some(b) = affine_batcher.push(tag, batch.reads[i], win) {
                            affine_batches.push(b);
                        }
                    }
                    FilterPolicy::MinOnly => {
                        let e = pair_best.entry(tag.pair_id);
                        match e {
                            std::collections::hash_map::Entry::Occupied(mut o) => {
                                if out.best[i] < o.get().0 {
                                    *o.get_mut() = (
                                        out.best[i],
                                        tag,
                                        std::mem::take(&mut batch.wins[i]),
                                        batch.reads[i],
                                    );
                                }
                            }
                            std::collections::hash_map::Entry::Vacant(v) => {
                                v.insert((
                                    out.best[i],
                                    tag,
                                    std::mem::take(&mut batch.wins[i]),
                                    batch.reads[i],
                                ));
                            }
                        }
                    }
                }
            }
        }
        if self.cfg.filter_policy == FilterPolicy::MinOnly {
            let mut winners: Vec<(i32, WorkTag, Vec<u8>, &[u8])> =
                pair_best.into_values().collect();
            winners.sort_by_key(|(_, t, _, _)| (t.read_id, t.pair_id));
            for (_, tag, win, seq) in winners {
                metrics.affine_instances += 1;
                *metrics.affine_per_xbar.entry(tag.xbar).or_default() += 1;
                if let Some(b) = affine_batcher.push(tag, seq, win) {
                    affine_batches.push(b);
                }
            }
        }
        if let Some(b) = affine_batcher.flush() {
            affine_batches.push(b);
        }
        metrics.t_linear = t0.elapsed();

        // ---- Batched affine alignment + traceback (Fig. 6 steps 5-6) --
        let t0 = Instant::now();
        let mut outcomes: Vec<AffineOutcome> = Vec::new();
        for batch in &affine_batches {
            let ww: Vec<&[u8]> = batch.wins.iter().map(|v| v.as_slice()).collect();
            let out = engine.affine_batch(&batch.reads, &ww)?;
            metrics.affine_batches += 1;
            let tt = Instant::now();
            for (i, tag) in batch.tags.iter().enumerate() {
                if let Some(outcome) = decode_affine(
                    tag,
                    out.best[i],
                    out.best_j[i] as usize,
                    &out.dirs[i],
                    batch.reads[i],
                    &mut metrics,
                ) {
                    outcomes.push(outcome);
                }
            }
            metrics.t_traceback += tt.elapsed();
        }
        metrics.t_affine = t0.elapsed();

        // ---- RISC-V offload path (scalar Rust engine, always) ----
        let mut riscv_engine = RustEngine;
        for (tag, seq) in self.riscv_items {
            let win = self.index.window_for(tag.ref_pos, tag.read_offset as usize);
            metrics.riscv_linear_instances += 1;
            let lin = riscv_engine.linear_batch(&[seq], &[&win])?;
            if lin.best[0] > ETH as i32 {
                continue;
            }
            metrics.riscv_affine_instances += 1;
            let aff = riscv_engine.affine_batch(&[seq], &[&win])?;
            if let Some(outcome) = decode_affine(
                &tag,
                aff.best[0],
                aff.best_j[0] as usize,
                &aff.dirs[0],
                seq,
                &mut metrics,
            ) {
                outcomes.push(outcome);
            }
        }

        Ok((outcomes, metrics))
    }
}

/// Run stages 2-6 over a complete item list in one call: ingest
/// everything, then compute on `engine`. The single-threaded pipeline
/// path and tests use this; the threaded path drives a [`ShardWorker`]
/// incrementally as chunks stream in.
pub fn run_shard<'a, E: WfEngine + ?Sized>(
    index: &'a MinimizerIndex,
    cfg: &'a PipelineConfig,
    engine: &mut E,
    items: &[ShardItem<'a>],
) -> Result<(Vec<AffineOutcome>, Metrics)> {
    let mut worker = ShardWorker::new(index, cfg);
    worker.ingest(items.iter().copied());
    worker.finish(engine)
}

/// Turn one affine result into an outcome (traceback + position
/// refinement). `None` for saturated or irrecoverable paths.
fn decode_affine(
    tag: &WorkTag,
    dist: i32,
    best_j: usize,
    dirs: &[u8],
    read: &[u8],
    metrics: &mut Metrics,
) -> Option<AffineOutcome> {
    if dist >= SAT_AFFINE {
        return None;
    }
    match traceback(dirs, read.len(), best_j) {
        Ok(aln) => {
            debug_assert_eq!(script_cost(&aln.ops, aln.j_end), dist, "cost identity");
            Some(AffineOutcome {
                read_id: tag.read_id,
                pos: aln.refined_pos(tag.pl),
                dist,
                cigar: Cigar::from_ops(&aln.ops),
                reverse: tag.reverse,
                key: emission_key(tag.pair_id, tag.ref_pos),
            })
        }
        Err(_) => {
            metrics.traceback_failures += 1;
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::index::shard_of;
    use crate::params::{K, READ_LEN, W};

    /// run_shard over everything == the item-level serial semantics; a
    /// partition of the same items produces the same outcome multiset.
    #[test]
    fn partitioned_shards_cover_the_serial_outcomes() {
        let g = SynthConfig { len: 60_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 30, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let cfg = PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let router = crate::coordinator::Router::new(&idx, &cfg.dart);

        let mut items: Vec<ShardItem<'_>> = Vec::new();
        let mut next_pair = 0u32;
        for r in &reads {
            for pair in router.route(&idx, r.id, &r.seq) {
                items.push(ShardItem {
                    pair_id: next_pair,
                    read_id: r.id,
                    read_offset: pair.read_offset,
                    kmer: pair.kmer,
                    target: pair.target,
                    reverse: false,
                    seq: &r.seq,
                });
                next_pair += 1;
            }
        }

        let (serial, sm) = run_shard(&idx, &cfg, &mut RustEngine, &items).unwrap();

        let n = 3;
        let mut sharded: Vec<AffineOutcome> = Vec::new();
        let mut merged = Metrics::default();
        for sh in 0..n {
            let part: Vec<ShardItem<'_>> =
                items.iter().filter(|it| shard_of(it.kmer, n) == sh).copied().collect();
            let (out, m) = run_shard(&idx, &cfg, &mut RustEngine, &part).unwrap();
            sharded.extend(out);
            merged.merge(m);
        }

        let keyset = |v: &[AffineOutcome]| {
            let mut k: Vec<(u64, i64, i32)> = v.iter().map(|o| (o.key, o.pos, o.dist)).collect();
            k.sort_unstable();
            k
        };
        assert_eq!(keyset(&serial), keyset(&sharded));
        assert_eq!(sm.linear_instances, merged.linear_instances);
        assert_eq!(sm.affine_instances, merged.affine_instances);
        assert_eq!(sm.filter_passed, merged.filter_passed);
        assert_eq!(sm.routed_pairs, merged.routed_pairs);
    }

    /// Chunked ingest (the threaded path's streaming shape) must equal
    /// one-shot ingest.
    #[test]
    fn chunked_ingest_equals_one_shot() {
        let g = SynthConfig { len: 50_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 20, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let cfg = PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let router = crate::coordinator::Router::new(&idx, &cfg.dart);
        let mut items: Vec<ShardItem<'_>> = Vec::new();
        let mut next_pair = 0u32;
        for r in &reads {
            for pair in router.route(&idx, r.id, &r.seq) {
                items.push(ShardItem {
                    pair_id: next_pair,
                    read_id: r.id,
                    read_offset: pair.read_offset,
                    kmer: pair.kmer,
                    target: pair.target,
                    reverse: false,
                    seq: &r.seq,
                });
                next_pair += 1;
            }
        }
        let (one_shot, _) = run_shard(&idx, &cfg, &mut RustEngine, &items).unwrap();
        let mut worker = ShardWorker::new(&idx, &cfg);
        for chunk in items.chunks(7) {
            worker.ingest(chunk.iter().copied());
        }
        let (chunked, _) = worker.finish(&mut RustEngine).unwrap();
        assert_eq!(one_shot.len(), chunked.len());
        for (a, b) in one_shot.iter().zip(&chunked) {
            assert_eq!((a.key, a.pos, a.dist), (b.key, b.pos, b.dist));
        }
    }
}
