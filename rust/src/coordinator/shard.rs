//! Per-shard execution of pipeline stages 2-6 (paper Fig. 6; §V-B data
//! organization).
//!
//! DART-PIM gets its throughput from thousands of crossbars each owning a
//! disjoint slice of the reference segments. The host realization mirrors
//! that: routed (read, minimizer) pairs are partitioned by minimizer hash
//! ([`crate::index::shard_of`]), so every shard touches a disjoint set of
//! minimizers — and, because each minimizer owns a contiguous private
//! crossbar range (see [`super::router`]), a disjoint set of crossbars,
//! Reads FIFOs, and reference segments. One worker thread per shard then
//! runs FIFO admission, the batched WF linear filter, batched affine
//! alignment, traceback, and the RISC-V offload path over its private
//! slice, with no synchronization beyond the channel that feeds it.
//!
//! A [`ShardWorker`] is **incremental and bounded**: [`ShardWorker::ingest`]
//! runs FIFO admission and window extraction as items stream in, and
//! executes every engine batch the moment it fills, so in-flight state is
//! O(batch), not O(workload). [`ShardWorker::drain`] is the epoch
//! barrier the streaming pipeline uses to force out partially-filled
//! batches and collect the outcomes accumulated so far;
//! [`ShardWorker::finish`] is the end-of-stream drain that also yields
//! the shard's [`Metrics`]. Long-lived state that must persist across
//! epochs — the per-crossbar FIFO maxReads accounting — lives on the
//! worker, which is why the streaming pipeline keeps one worker per shard
//! alive for the whole run.
//!
//! Determinism contract (held by `tests/shard_determinism.rs` and
//! `tests/stream_parity.rs`):
//!
//! * Pair ids are assigned by the serial routing stage, so they are
//!   identical for every shard count.
//! * A crossbar's FIFO receives its entries in the same relative order
//!   regardless of sharding (per-shard item streams preserve the global
//!   emission order), so maxReads drops are identical.
//! * Workers emit [`AffineOutcome`]s whose arbitration key is the serial
//!   emission order; [`super::state::BestSoFar`] resolves full ties with
//!   it, so the merged winners are identical under any interleaving —
//!   and under any epoch (drain) granularity, since engine numerics are
//!   per-instance and batch boundaries carry no state.
//! * Workload counters in [`Metrics`] are item-local sums and merge to
//!   identical totals; only the batch-shape counters
//!   (`linear_batches`/`affine_batches`) and wall-clock timings depend on
//!   the shard count and epoch size.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::align::traceback::{script_cost, traceback};
use crate::align::Cigar;
use crate::index::IndexRef;
use crate::params::{ETH, SAT_AFFINE};
use crate::runtime::{RustEngine, WfEngine};

use super::batcher::{Batch, Batcher, WorkTag};
use super::fifo::{FifoEntry, PushResult, ReadsFifo};
use super::metrics::Metrics;
use super::pipeline::{FilterPolicy, PipelineConfig};
use super::router::Target;
use super::state::AffineOutcome;

/// One routed (read, minimizer) pair bound to its oriented read sequence:
/// the unit of work a shard worker consumes. The sequence is a shared
/// slice (one refcounted allocation per oriented read), so items can
/// cross thread boundaries without borrowing from a materialized read
/// set — the enabler for streaming ingestion.
#[derive(Debug, Clone)]
pub struct ShardItem {
    /// Globally sequential pair id (assigned by the serial routing
    /// stage; identical for every shard count).
    pub pair_id: u32,
    /// Read this pair belongs to.
    pub read_id: u32,
    /// Minimizer offset within the read.
    pub read_offset: u32,
    /// The minimizer k-mer (the shard partition key).
    pub kmer: u64,
    /// Crossbar range or RISC-V pool executing this pair.
    pub target: Target,
    /// Reverse-complement orientation of `seq`.
    pub reverse: bool,
    /// Mate index within the read's pair (0 = R1, 1 = R2; `read_id % 2`
    /// under the paired layout, ignored in single-end runs); carried
    /// through to [`AffineOutcome`] as provenance, which the
    /// epoch-boundary pair arbitration cross-checks against the paired
    /// id layout.
    pub mate: u8,
    /// The oriented read sequence (shared with the other items of the
    /// same oriented read).
    pub seq: Arc<[u8]>,
}

/// Serial emission order of one WF instance, used as the deterministic
/// tie-break key (see [`AffineOutcome::key`]): pairs are emitted in
/// pair-id order and occurrences within a pair in ascending reference
/// position.
fn emission_key(pair_id: u32, ref_pos: u32) -> u64 {
    (u64::from(pair_id) << 32) | u64::from(ref_pos)
}

/// Executes pipeline stages 2-6 over one shard's item stream with
/// bounded memory.
///
/// The worker owns everything its slice needs — the Reads FIFOs of its
/// crossbars, the stage batchers, the open MinOnly pair state, and the
/// RISC-V work list — so N workers share nothing but the read-only
/// index. All engine work happens eagerly as batches fill; see the
/// module docs for the ingest/drain/finish protocol.
pub struct ShardWorker<'a> {
    index: IndexRef<'a>,
    cfg: &'a PipelineConfig,
    metrics: Metrics,
    // dart-analyze: allow(determinism): accessed exclusively through
    // entry()/get keyed by crossbar id and never iterated, so map order
    // is unobservable; order-sensitive state (pair_best) deliberately
    // lives in a BTreeMap. Proof: every `fifos` use in this file is
    // `entry(..)`, `get(..)`, `get_mut(..)`, or `clear()`.
    fifos: HashMap<u32, ReadsFifo>,
    linear_batcher: Batcher,
    affine_batcher: Batcher,
    /// MinOnly: best passing linear result per pair seen since the last
    /// drain, keyed by pair id (ascending == serial emission order).
    /// Bounded by the epoch size; pairs never span epochs because
    /// epochs split on read boundaries.
    pair_best: BTreeMap<u32, (i32, WorkTag, Vec<u8>, Arc<[u8]>)>,
    /// lowTh pairs awaiting the scalar RISC-V path (bounded: drained
    /// every epoch; each pair has <= lowTh occurrences).
    riscv_items: Vec<(WorkTag, Arc<[u8]>)>,
    /// Outcomes accumulated since the last drain.
    outcomes: Vec<AffineOutcome>,
}

impl<'a> ShardWorker<'a> {
    /// Empty worker for one shard (either index backend).
    pub fn new(index: impl Into<IndexRef<'a>>, cfg: &'a PipelineConfig) -> Self {
        let index = index.into();
        // report the configured lane width of the bit-parallel worker
        // engine — a dispatch gauge, outside the invariant counters.
        // dart-analyze: allow(determinism): simd_width is a diagnostic
        // gauge on Metrics, excluded from invariant_counters() (invariant
        // 4); it is compared by the golden tests only for presence, never
        // folded into mapping output bytes.
        let simd_width = match cfg.worker_engine {
            crate::runtime::EngineKind::Bitpal => {
                cfg.simd.resolve().map_or(0, |w| w.bits() as u64)
            }
            crate::runtime::EngineKind::Rust => 0,
        };
        ShardWorker {
            index,
            cfg,
            metrics: Metrics { simd_width, ..Metrics::default() },
            fifos: HashMap::new(),
            linear_batcher: Batcher::new(cfg.batch_size, index.read_len()),
            affine_batcher: Batcher::new(cfg.batch_size, index.read_len()),
            pair_best: BTreeMap::new(),
            riscv_items: Vec::new(),
            outcomes: Vec::new(),
        }
    }

    /// Incremental phase (Fig. 6 steps 1-3, plus eager 3-6): FIFO
    /// admission, window extraction, and batch packing for a slice of
    /// the item stream — and, whenever a batch fills, the batched WF
    /// compute for it, so memory stays O(batch). Called repeatedly as
    /// chunks arrive; items must arrive in emission order (the
    /// determinism contract).
    pub fn ingest<E: WfEngine + ?Sized>(
        &mut self,
        engine: &mut E,
        items: impl IntoIterator<Item = ShardItem>,
    ) -> Result<()> {
        // dart-analyze: allow(determinism): Instant feeds only the stage
        // clocks (t_seed/t_linear/t_affine), excluded from
        // invariant_counters() by design (invariant 4); no wall-clock
        // value reaches emitted bytes.
        let mut t0 = Instant::now();
        let (index, cfg) = (self.index, self.cfg);
        for item in items {
            let occs = index.occurrences(item.kmer);
            match item.target {
                Target::Riscv => {
                    self.metrics.riscv_pairs += 1;
                    for &pos in occs {
                        self.riscv_items.push((
                            WorkTag {
                                read_id: item.read_id,
                                pair_id: item.pair_id,
                                ref_pos: pos,
                                read_offset: item.read_offset,
                                pl: pos as i64 - item.read_offset as i64,
                                xbar: u32::MAX, // RISC-V pool, not a crossbar
                                reverse: item.reverse,
                                mate: item.mate,
                            },
                            item.seq.clone(),
                        ));
                    }
                }
                Target::Xbar { first, count } => {
                    // FIFO admission on the owning crossbar (the
                    // minimizer's crossbar range is private to this shard)
                    let fifo = self.fifos.entry(first).or_insert_with(|| {
                        ReadsFifo::new(cfg.dart.fifo_capacity_reads(), cfg.dart.max_reads)
                    });
                    let entry =
                        FifoEntry { read_id: item.read_id, read_offset: item.read_offset };
                    match fifo.push(entry) {
                        PushResult::CapExceeded => {
                            self.metrics.dropped_pairs += 1;
                            continue;
                        }
                        PushResult::Full => {
                            // batch-mode backpressure: the entry is
                            // consumed immediately below, so the FIFO
                            // drains as fast as it fills
                            fifo.pop();
                            if fifo.push(entry) == PushResult::CapExceeded {
                                self.metrics.dropped_pairs += 1;
                                continue;
                            }
                        }
                        PushResult::Accepted => {}
                    }
                    fifo.pop(); // consumed by this round's linear iteration
                    self.metrics.routed_pairs += 1;
                    *self.metrics.pairs_per_xbar.entry(first).or_default() += 1;
                    for sub in 1..count {
                        *self.metrics.pairs_per_xbar.entry(first + sub).or_default() += 1;
                    }
                    for (i, &pos) in occs.iter().enumerate() {
                        let tag = WorkTag {
                            read_id: item.read_id,
                            pair_id: item.pair_id,
                            ref_pos: pos,
                            read_offset: item.read_offset,
                            pl: pos as i64 - item.read_offset as i64,
                            // which of the minimizer's crossbars holds
                            // this occurrence's segment row
                            xbar: first + (i / cfg.dart.linear_rows) as u32,
                            reverse: item.reverse,
                            mate: item.mate,
                        };
                        let win = index.window_for(pos, item.read_offset as usize);
                        self.metrics.linear_instances += 1;
                        if let Some(b) = self.linear_batcher.push(tag, item.seq.clone(), win) {
                            // close the admission span so engine time is
                            // not double-counted under t_seed
                            self.metrics.t_seed += t0.elapsed();
                            self.run_linear_batch(engine, b)?;
                            t0 = Instant::now();
                        }
                    }
                }
            }
        }
        self.metrics.t_seed += t0.elapsed();
        Ok(())
    }

    /// Epoch barrier: force partially-filled batches through the engine,
    /// finalize open MinOnly pairs, run the buffered RISC-V pairs, and
    /// return every outcome accumulated since the previous drain. After
    /// a drain the worker holds no pending WF work — only the persistent
    /// FIFO cap state survives into the next epoch.
    pub fn drain<E: WfEngine + ?Sized>(&mut self, engine: &mut E) -> Result<Vec<AffineOutcome>> {
        if let Some(b) = self.linear_batcher.flush() {
            self.run_linear_batch(engine, b)?;
        }
        if self.cfg.filter_policy == FilterPolicy::MinOnly {
            // every seen pair is fully filtered now (no pending linear
            // work), so the per-pair winners are final; emit them in
            // pair-id order == the serial emission order across reads
            let winners = std::mem::take(&mut self.pair_best);
            let mut ready: Vec<Batch> = Vec::new();
            for (_, (_, tag, win, seq)) in winners {
                self.metrics.affine_instances += 1;
                *self.metrics.affine_per_xbar.entry(tag.xbar).or_default() += 1;
                if let Some(b) = self.affine_batcher.push(tag, seq, win) {
                    ready.push(b);
                }
            }
            for b in ready {
                self.run_affine_batch(engine, b)?;
            }
        }
        if let Some(b) = self.affine_batcher.flush() {
            self.run_affine_batch(engine, b)?;
        }
        self.run_riscv()?;
        Ok(std::mem::take(&mut self.outcomes))
    }

    /// End-of-stream: drain everything and hand back the shard's
    /// [`Metrics`] contribution (`n_reads`, `reads_with_candidates`, and
    /// `t_total` are left at zero — they are whole-run quantities the
    /// caller owns).
    pub fn finish<E: WfEngine + ?Sized>(
        mut self,
        engine: &mut E,
    ) -> Result<(Vec<AffineOutcome>, Metrics)> {
        let outcomes = self.drain(engine)?;
        Ok((outcomes, self.metrics))
    }

    /// Batched linear filter (Fig. 6 steps 3-4) over one full batch,
    /// feeding survivors to the affine stage (run eagerly when its
    /// batches fill).
    fn run_linear_batch<E: WfEngine + ?Sized>(
        &mut self,
        engine: &mut E,
        mut batch: Batch,
    ) -> Result<()> {
        let t0 = Instant::now();
        let out = {
            let rr = batch.read_slices();
            let ww = batch.win_slices();
            engine.linear_batch(&rr, &ww)?
        };
        self.metrics.linear_batches += 1;
        let mut ready: Vec<Batch> = Vec::new();
        for i in 0..batch.tags.len() {
            let tag = batch.tags[i];
            if out.best[i] > ETH as i32 {
                continue; // filtered out
            }
            self.metrics.filter_passed += 1;
            match self.cfg.filter_policy {
                FilterPolicy::AllPassing => {
                    self.metrics.affine_instances += 1;
                    *self.metrics.affine_per_xbar.entry(tag.xbar).or_default() += 1;
                    // window moves to the affine stage (each is used at
                    // most once — §Perf opt 1)
                    let win = std::mem::take(&mut batch.wins[i]);
                    let read = batch.reads[i].clone();
                    if let Some(b) = self.affine_batcher.push(tag, read, win) {
                        ready.push(b);
                    }
                }
                FilterPolicy::MinOnly => {
                    let win = std::mem::take(&mut batch.wins[i]);
                    let cand = (out.best[i], tag, win, batch.reads[i].clone());
                    match self.pair_best.entry(tag.pair_id) {
                        std::collections::btree_map::Entry::Occupied(mut o) => {
                            if cand.0 < o.get().0 {
                                *o.get_mut() = cand;
                            }
                        }
                        std::collections::btree_map::Entry::Vacant(v) => {
                            v.insert(cand);
                        }
                    }
                }
            }
        }
        self.metrics.t_linear += t0.elapsed();
        for b in ready {
            self.run_affine_batch(engine, b)?;
        }
        Ok(())
    }

    /// Batched affine alignment + traceback (Fig. 6 steps 5-6) over one
    /// full batch; outcomes accumulate until the next drain.
    fn run_affine_batch<E: WfEngine + ?Sized>(
        &mut self,
        engine: &mut E,
        batch: Batch,
    ) -> Result<()> {
        let t0 = Instant::now();
        let out = {
            let rr = batch.read_slices();
            let ww = batch.win_slices();
            engine.affine_batch(&rr, &ww)?
        };
        self.metrics.affine_batches += 1;
        let tt = Instant::now();
        for (i, tag) in batch.tags.iter().enumerate() {
            if let Some(outcome) = decode_affine(
                tag,
                out.best[i],
                out.best_j[i] as usize,
                &out.dirs[i],
                batch.reads[i].as_ref(),
                &mut self.metrics,
            ) {
                self.outcomes.push(outcome);
            }
        }
        self.metrics.t_traceback += tt.elapsed();
        self.metrics.t_affine += t0.elapsed();
        Ok(())
    }

    /// RISC-V offload path: the buffered lowTh pairs, on the scalar Rust
    /// engine (always — mirroring the paper's heterogeneous split).
    fn run_riscv(&mut self) -> Result<()> {
        let mut riscv_engine = RustEngine;
        for (tag, seq) in std::mem::take(&mut self.riscv_items) {
            let win = self.index.window_for(tag.ref_pos, tag.read_offset as usize);
            self.metrics.riscv_linear_instances += 1;
            let lin = riscv_engine.linear_batch(&[seq.as_ref()], &[&win])?;
            if lin.best[0] > ETH as i32 {
                continue;
            }
            self.metrics.riscv_affine_instances += 1;
            let aff = riscv_engine.affine_batch(&[seq.as_ref()], &[&win])?;
            if let Some(outcome) = decode_affine(
                &tag,
                aff.best[0],
                aff.best_j[0] as usize,
                &aff.dirs[0],
                seq.as_ref(),
                &mut self.metrics,
            ) {
                self.outcomes.push(outcome);
            }
        }
        Ok(())
    }
}

/// Run stages 2-6 over a complete item list in one call: ingest
/// everything, then finish on `engine`. Tests and the shard parity
/// suite use this; the streaming pipeline drives a [`ShardWorker`]
/// incrementally as chunks stream in.
pub fn run_shard<'a, E: WfEngine + ?Sized>(
    index: impl Into<IndexRef<'a>>,
    cfg: &'a PipelineConfig,
    engine: &mut E,
    items: &[ShardItem],
) -> Result<(Vec<AffineOutcome>, Metrics)> {
    let mut worker = ShardWorker::new(index, cfg);
    worker.ingest(engine, items.iter().cloned())?;
    worker.finish(engine)
}

/// Turn one affine result into an outcome (traceback + position
/// refinement). `None` for saturated or irrecoverable paths. Also used
/// by the pair-arbitration mate-rescue scan ([`super::pair`]).
pub(crate) fn decode_affine(
    tag: &WorkTag,
    dist: i32,
    best_j: usize,
    dirs: &[u8],
    read: &[u8],
    metrics: &mut Metrics,
) -> Option<AffineOutcome> {
    if dist >= SAT_AFFINE {
        return None;
    }
    match traceback(dirs, read.len(), best_j) {
        Ok(aln) => {
            debug_assert_eq!(script_cost(&aln.ops, aln.j_end), dist, "cost identity");
            Some(AffineOutcome {
                read_id: tag.read_id,
                pos: aln.refined_pos(tag.pl),
                dist,
                cigar: Cigar::from_ops(&aln.ops),
                reverse: tag.reverse,
                mate: tag.mate,
                key: emission_key(tag.pair_id, tag.ref_pos),
            })
        }
        Err(_) => {
            metrics.traceback_failures += 1;
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::genome::ReadRecord;
    use crate::index::{shard_of, MinimizerIndex};
    use crate::params::{K, READ_LEN, W};

    fn route_all(
        idx: &MinimizerIndex,
        cfg: &PipelineConfig,
        reads: &[ReadRecord],
    ) -> Vec<ShardItem> {
        let router = crate::coordinator::Router::new(idx, &cfg.dart);
        let mut items: Vec<ShardItem> = Vec::new();
        let mut next_pair = 0u32;
        for r in reads {
            let seq: Arc<[u8]> = Arc::from(r.seq.as_slice());
            for pair in router.route(idx, r.id, &r.seq) {
                items.push(ShardItem {
                    pair_id: next_pair,
                    read_id: r.id,
                    read_offset: pair.read_offset,
                    kmer: pair.kmer,
                    target: pair.target,
                    reverse: false,
                    mate: 0,
                    seq: seq.clone(),
                });
                next_pair += 1;
            }
        }
        items
    }

    /// run_shard over everything == the item-level serial semantics; a
    /// partition of the same items produces the same outcome multiset.
    #[test]
    fn partitioned_shards_cover_the_serial_outcomes() {
        let g = SynthConfig { len: 60_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 30, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let cfg = PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let items = route_all(&idx, &cfg, &reads);

        let (serial, sm) = run_shard(&idx, &cfg, &mut RustEngine, &items).unwrap();

        let n = 3;
        let mut sharded: Vec<AffineOutcome> = Vec::new();
        let mut merged = Metrics::default();
        for sh in 0..n {
            let part: Vec<ShardItem> =
                items.iter().filter(|it| shard_of(it.kmer, n) == sh).cloned().collect();
            let (out, m) = run_shard(&idx, &cfg, &mut RustEngine, &part).unwrap();
            sharded.extend(out);
            merged.merge(m);
        }

        let keyset = |v: &[AffineOutcome]| {
            let mut k: Vec<(u64, i64, i32)> = v.iter().map(|o| (o.key, o.pos, o.dist)).collect();
            k.sort_unstable();
            k
        };
        assert_eq!(keyset(&serial), keyset(&sharded));
        assert_eq!(sm.linear_instances, merged.linear_instances);
        assert_eq!(sm.affine_instances, merged.affine_instances);
        assert_eq!(sm.filter_passed, merged.filter_passed);
        assert_eq!(sm.routed_pairs, merged.routed_pairs);
    }

    /// Chunked ingest (the streaming path's shape) must equal one-shot
    /// ingest — including when an epoch drain is forced between chunks.
    #[test]
    fn chunked_ingest_and_mid_stream_drains_equal_one_shot() {
        let g = SynthConfig { len: 50_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 20, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let cfg = PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let items = route_all(&idx, &cfg, &reads);
        let (one_shot, _) = run_shard(&idx, &cfg, &mut RustEngine, &items).unwrap();

        let mut worker = ShardWorker::new(&idx, &cfg);
        let mut drained: Vec<AffineOutcome> = Vec::new();
        for (ci, chunk) in items.chunks(7).enumerate() {
            worker.ingest(&mut RustEngine, chunk.iter().cloned()).unwrap();
            if ci % 3 == 2 {
                // epoch barrier mid-stream: outcomes must be identical
                // in aggregate no matter where the drains land
                drained.extend(worker.drain(&mut RustEngine).unwrap());
            }
        }
        let (rest, _) = worker.finish(&mut RustEngine).unwrap();
        drained.extend(rest);
        assert_eq!(one_shot.len(), drained.len());
        let key = |v: &[AffineOutcome]| {
            let mut k: Vec<(u64, i64, i32)> = v.iter().map(|o| (o.key, o.pos, o.dist)).collect();
            k.sort_unstable();
            k
        };
        assert_eq!(key(&one_shot), key(&drained));
    }

    /// After a drain the worker holds no pending outcomes: finish on an
    /// already-drained worker yields nothing new.
    #[test]
    fn drain_leaves_no_pending_work() {
        let g = SynthConfig { len: 40_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 10, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let cfg = PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let items = route_all(&idx, &cfg, &reads);
        let mut worker = ShardWorker::new(&idx, &cfg);
        worker.ingest(&mut RustEngine, items.iter().cloned()).unwrap();
        let first = worker.drain(&mut RustEngine).unwrap();
        assert!(!first.is_empty(), "workload must produce outcomes");
        let (rest, _) = worker.finish(&mut RustEngine).unwrap();
        assert!(rest.is_empty(), "drain must leave nothing pending");
    }
}
