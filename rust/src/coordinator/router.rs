//! Routing: which crossbar (or the RISC-V pool) evaluates a (read,
//! minimizer) pair (paper §V-C; Figs. 5/7).
//!
//! The assignment is the offline-indexing one: each reference minimizer
//! with frequency above lowTh owns `ceil(occ / 32)` crossbars; the rest
//! are computed by the DP-RISC-V cores. The PIM controller hierarchy
//! forwards a read only toward chips/banks owning its minimizers — here
//! that is a flat map lookup plus [`crate::pim::controller::addr_of`]
//! for the hierarchical address.

use std::collections::HashMap;

use crate::index::IndexRef;
use crate::pim::DartPimConfig;
use crate::seeding::{seed_read, ReadSeed};

/// Where a pair executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// First crossbar id + number of crossbars for this minimizer.
    Xbar { first: u32, count: u32 },
    /// lowTh minimizer -> DP-RISC-V pool.
    Riscv,
}

/// One routed work unit: a read paired with one of its minimizers.
#[derive(Debug, Clone)]
pub struct RoutedPair {
    /// Read this pair belongs to.
    pub read_id: u32,
    /// The minimizer k-mer (routing key).
    pub kmer: u64,
    /// Minimizer offset within the read.
    pub read_offset: u32,
    /// Reference occurrences of the minimizer.
    pub n_occurrences: usize,
    /// Where the pair executes.
    pub target: Target,
}

/// The routing table.
pub struct Router {
    // dart-analyze: allow(determinism): built from a sorted minimizer
    // list and afterwards only read through keyed get() in target_of()
    // — never iterated, so crossbar numbering and all routing decisions
    // are independent of HashMap order.
    assignment: HashMap<u64, (u32, u32)>,
    /// Total crossbars allocated by the offline assignment.
    pub xbars_used: u32,
    low_th: usize,
}

impl Router {
    /// Build from the offline index (deterministic layout; both
    /// backends yield the same table — the minimizer list is sorted
    /// before any crossbar is numbered).
    pub fn new<'a>(index: impl Into<IndexRef<'a>>, cfg: &DartPimConfig) -> Self {
        let index = index.into();
        let mut assignment = HashMap::new();
        let mut next = 0u32;
        let mut minis: Vec<(u64, usize)> = index.iter().map(|(m, o)| (m, o.len())).collect();
        minis.sort_unstable();
        for (m, occ) in minis {
            if occ > cfg.low_th {
                let n = occ.div_ceil(cfg.linear_rows) as u32;
                assignment.insert(m, (next, n));
                next += n;
            }
        }
        Router { assignment, xbars_used: next, low_th: cfg.low_th }
    }

    /// Target of one minimizer (None if it does not occur in the
    /// reference at all — such pairs produce no work).
    pub fn target_of(&self, seed: &ReadSeed) -> Option<Target> {
        if seed.n_occurrences == 0 {
            return None;
        }
        Some(match self.assignment.get(&seed.kmer) {
            Some(&(first, count)) => Target::Xbar { first, count },
            None => {
                debug_assert!(seed.n_occurrences <= self.low_th);
                Target::Riscv
            }
        })
    }

    /// Route one read: seed it and target every productive minimizer.
    pub fn route<'a>(
        &self,
        index: impl Into<IndexRef<'a>>,
        read_id: u32,
        read: &[u8],
    ) -> Vec<RoutedPair> {
        seed_read(index.into(), read)
            .into_iter()
            .filter_map(|seed| {
                self.target_of(&seed).map(|target| RoutedPair {
                    read_id,
                    kmer: seed.kmer,
                    read_offset: seed.read_offset,
                    n_occurrences: seed.n_occurrences,
                    target,
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::index::MinimizerIndex;
    use crate::params::{K, READ_LEN, W};

    fn setup() -> (MinimizerIndex, Vec<crate::genome::ReadRecord>, Router) {
        let g = SynthConfig { len: 100_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 50, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let router = Router::new(&idx, &DartPimConfig::default());
        (idx, reads, router)
    }

    #[test]
    fn routing_is_deterministic() {
        let (idx, reads, router) = setup();
        let router2 = Router::new(&idx, &DartPimConfig::default());
        for r in &reads {
            let a = router.route(&idx, r.id, &r.seq);
            let b = router2.route(&idx, r.id, &r.seq);
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.target, y.target);
                assert_eq!(x.kmer, y.kmer);
            }
        }
    }

    #[test]
    fn targets_respect_low_th() {
        let (idx, reads, router) = setup();
        let cfg = DartPimConfig::default();
        for r in &reads {
            for p in router.route(&idx, r.id, &r.seq) {
                match p.target {
                    Target::Riscv => assert!(p.n_occurrences <= cfg.low_th),
                    Target::Xbar { count, .. } => {
                        assert!(p.n_occurrences > cfg.low_th);
                        assert_eq!(count as usize, p.n_occurrences.div_ceil(cfg.linear_rows));
                    }
                }
            }
        }
    }

    #[test]
    fn crossbar_ranges_do_not_overlap() {
        let (idx, _, router) = setup();
        let mut spans: Vec<(u32, u32)> = idx
            .iter()
            .filter_map(|(m, o)| {
                let seed = ReadSeed { kmer: m, read_offset: 0, n_occurrences: o.len() };
                match router.target_of(&seed) {
                    Some(Target::Xbar { first, count }) => Some((first, first + count)),
                    _ => None,
                }
            })
            .collect();
        spans.sort_unstable();
        for w in spans.windows(2) {
            assert!(w[0].1 <= w[1].0, "overlapping crossbar ranges {w:?}");
        }
        if let Some(&(_, end)) = spans.last() {
            assert_eq!(end, router.xbars_used);
        }
    }

    #[test]
    fn routed_pairs_fit_hardware() {
        let (idx, reads, router) = setup();
        let cfg = DartPimConfig::default();
        for r in &reads {
            for p in router.route(&idx, r.id, &r.seq) {
                if let Target::Xbar { first, count } = p.target {
                    assert!(((first + count) as usize) <= cfg.total_xbars());
                    // hierarchical address decodes for every sub-crossbar
                    for x in first..first + count {
                        let _ = crate::pim::controller::addr_of(&cfg, x as usize);
                    }
                }
            }
        }
    }
}
