//! Threaded streaming driver (std::thread + mpsc; this offline build has
//! no tokio — DESIGN.md §6).
//!
//! Stage threads mirror the hardware's concurrency: a *producer* streams
//! and routes reads (the sequencer + main RISC-V), a *compute* thread
//! owns the WF engine and processes batches (the PIM module), and the
//! caller's thread aggregates results (the main RISC-V's best-so-far
//! bookkeeping). Chunked hand-off bounds memory like the Reads FIFO
//! bounds the hardware stream.

use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Result};

use crate::genome::ReadRecord;
use crate::index::MinimizerIndex;
use crate::runtime::WfEngine;

use super::metrics::Metrics;
use super::pipeline::{FinalMapping, Pipeline, PipelineConfig};

/// Chunked streaming run: reads flow producer -> compute in chunks of
/// `chunk`; per-chunk results merge in arrival order (the per-read
/// best-so-far state makes the merge order-insensitive).
pub fn run_streaming<E, F>(
    index: &MinimizerIndex,
    cfg: PipelineConfig,
    engine_factory: F,
    reads: Vec<ReadRecord>,
    chunk: usize,
) -> Result<(Vec<Option<FinalMapping>>, Metrics)>
where
    E: WfEngine,
    F: FnOnce() -> Result<E> + Send,
{
    assert!(chunk >= 1);
    let n_reads = reads.len();
    let (tx_work, rx_work) = mpsc::sync_channel::<Vec<ReadRecord>>(2); // bounded: backpressure
    let (tx_res, rx_res) = mpsc::channel::<(Vec<Option<FinalMapping>>, Metrics)>();

    thread::scope(|s| -> Result<()> {
        // producer: chunk the stream (ids stay global)
        s.spawn(move || {
            let mut reads = reads;
            while !reads.is_empty() {
                let rest = reads.split_off(reads.len().min(chunk));
                let head = std::mem::replace(&mut reads, rest);
                if tx_work.send(head).is_err() {
                    return; // compute side hung up
                }
            }
        });

        // compute: owns the engine and a pipeline per chunk
        let idx = &*index;
        s.spawn(move || {
            // the engine is constructed on its owning thread (the PJRT
            // client is not Send)
            let Ok(engine) = engine_factory() else { return };
            let mut pipeline = Pipeline::new(idx, cfg, engine);
            while let Ok(chunk_reads) = rx_work.recv() {
                // re-id within the chunk, then restore global ids
                let base = chunk_reads.first().map(|r| r.id).unwrap_or(0);
                let local: Vec<ReadRecord> = chunk_reads
                    .iter()
                    .map(|r| ReadRecord { id: r.id - base, ..r.clone() })
                    .collect();
                match pipeline.map_reads(&local) {
                    Ok((mut mappings, metrics)) => {
                        for m in mappings.iter_mut().flatten() {
                            m.read_id += base;
                        }
                        if tx_res.send((mappings, metrics)).is_err() {
                            return;
                        }
                    }
                    Err(_) => return, // drop the channel; caller sees shortfall
                }
            }
        });
        Ok(())
    })?;

    // aggregate
    let mut all: Vec<Option<FinalMapping>> = vec![None; n_reads];
    let mut total = Metrics::default();
    let mut chunks = 0usize;
    let mut covered = 0usize;
    while let Ok((mappings, m)) = rx_res.recv() {
        chunks += 1;
        covered += mappings.len();
        for fm in mappings.into_iter().flatten() {
            let id = fm.read_id as usize;
            all[id] = Some(fm);
        }
        total.merge(m);
    }
    if covered != n_reads {
        return Err(anyhow!(
            "compute stage failed after {covered}/{n_reads} reads ({chunks} chunks)"
        ));
    }
    Ok((all, total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{K, READ_LEN, W};
    use crate::runtime::RustEngine;

    fn setup(n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
        let g = SynthConfig { len: 60_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        (idx, reads)
    }

    #[test]
    fn streaming_equals_batch() {
        let (idx, reads) = setup(40);
        let (batch, _) = {
            let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
            p.map_reads(&reads).unwrap()
        };
        let (streamed, metrics) =
            run_streaming(&idx, PipelineConfig::default(), || Ok(RustEngine), reads.clone(), 7)
                .unwrap();
        assert_eq!(metrics.n_reads, 40);
        for (a, b) in batch.iter().zip(&streamed) {
            match (a, b) {
                (None, None) => {}
                (Some(a), Some(b)) => {
                    assert_eq!((a.pos, a.dist), (b.pos, b.dist));
                }
                _ => panic!("batch vs streaming presence mismatch"),
            }
        }
    }

    #[test]
    fn chunk_of_one_works() {
        let (idx, reads) = setup(5);
        let cfg = PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let (m, metrics) = run_streaming(&idx, cfg, || Ok(RustEngine), reads, 1).unwrap();
        assert_eq!(m.len(), 5);
        assert!(metrics.linear_batches >= 5);
    }

    #[test]
    fn empty_stream() {
        let (idx, _) = setup(1);
        let (m, metrics) =
            run_streaming(&idx, PipelineConfig::default(), || Ok(RustEngine), Vec::new(), 8)
                .unwrap();
        assert!(m.is_empty());
        assert_eq!(metrics.n_reads, 0);
    }
}
