//! The end-to-end mapping pipeline (paper Fig. 6, host realization):
//! seed/route -> FIFO admission -> batched linear filter -> batched
//! affine alignment -> traceback -> best-so-far aggregation.
//!
//! The pipeline is engine-agnostic ([`WfEngine`]): the production path
//! runs the AOT-compiled Pallas kernels through PJRT
//! ([`crate::runtime::XlaEngine`]); lowTh (RISC-V-offload) pairs always
//! run on the scalar Rust path, mirroring the paper's heterogeneous
//! split.

use std::collections::HashMap;
use std::time::Instant;

use anyhow::Result;

use crate::align::traceback::{script_cost, traceback};
use crate::align::Cigar;
use crate::genome::ReadRecord;
use crate::index::MinimizerIndex;
use crate::params::{ETH, SAT_AFFINE};
use crate::pim::DartPimConfig;
use crate::runtime::{RustEngine, WfEngine};

use super::batcher::{Batch, Batcher, WorkTag};
use super::fifo::{FifoEntry, PushResult, ReadsFifo};
use super::metrics::Metrics;
use super::router::{Router, Target};
use super::state::{AffineOutcome, BestSoFar};

/// Which filtered instances advance to affine alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FilterPolicy {
    /// Every instance with linear distance <= eth (matches the paper's
    /// measured affine workload; default).
    #[default]
    AllPassing,
    /// Only the minimum-distance instance of each routed pair (paper
    /// Fig. 6 step 4's literal description; ablation).
    MinOnly,
}

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub dart: DartPimConfig,
    /// Engine flush size (use the largest artifact batch).
    pub batch_size: usize,
    pub filter_policy: FilterPolicy,
    /// Also try the reverse-complement orientation of every read
    /// (real sequencers emit both strands; the paper elides this, but a
    /// practical mapper needs it — extension feature, DESIGN.md §7).
    pub handle_revcomp: bool,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            dart: DartPimConfig::default(),
            batch_size: 256,
            filter_policy: FilterPolicy::AllPassing,
            handle_revcomp: false,
        }
    }
}

/// Final mapping decision for one read.
#[derive(Debug, Clone)]
pub struct FinalMapping {
    pub read_id: u32,
    pub pos: i64,
    pub dist: i32,
    pub cigar: Cigar,
    pub candidates: u32,
    /// true if the read mapped in reverse-complement orientation.
    pub reverse: bool,
}

/// The mapper.
pub struct Pipeline<'a, E: WfEngine> {
    pub index: &'a MinimizerIndex,
    pub router: Router,
    pub cfg: PipelineConfig,
    engine: E,
    riscv_engine: RustEngine,
}

impl<'a, E: WfEngine> Pipeline<'a, E> {
    pub fn new(index: &'a MinimizerIndex, cfg: PipelineConfig, engine: E) -> Self {
        let router = Router::new(index, &cfg.dart);
        Pipeline { index, router, cfg, engine, riscv_engine: RustEngine }
    }

    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Map a read set end to end. Returns per-read decisions (indexed by
    /// read id) and run metrics.
    pub fn map_reads(&mut self, reads: &[ReadRecord]) -> Result<(Vec<Option<FinalMapping>>, Metrics)> {
        let t_start = Instant::now();
        let mut metrics = Metrics { n_reads: reads.len() as u64, ..Default::default() };
        let mut best = BestSoFar::new(reads.len());
        let mut fifos: HashMap<u32, ReadsFifo> = HashMap::new();

        // ---- Stage 1+2: seed, route, admit, build linear work ----
        let t0 = Instant::now();
        // reverse-complement orientations, materialized once per read so
        // the zero-copy batches can borrow them (empty when disabled)
        let rc_seqs: Vec<crate::genome::encode::Seq> = if self.cfg.handle_revcomp {
            reads.iter().map(|r| crate::genome::revcomp(&r.seq)).collect()
        } else {
            Vec::new()
        };
        let mut linear_batcher = Batcher::new(self.cfg.batch_size, self.index.read_len);
        let mut linear_batches: Vec<Batch<'_>> = Vec::new();
        let mut riscv_items: Vec<(WorkTag, &[u8])> = Vec::new();
        let mut next_pair = 0u32;
        let mut oriented: Vec<(&[u8], bool)> = Vec::with_capacity(2);
        for read in reads {
            oriented.clear();
            oriented.push((read.seq.as_slice(), false));
            if self.cfg.handle_revcomp {
                oriented.push((rc_seqs[read.id as usize].as_slice(), true));
            }
            for &(seq, reverse) in &oriented {
                for pair in self.router.route(self.index, read.id, seq) {
                    let pair_id = next_pair;
                    next_pair += 1;
                    let occs = self.index.occurrences(pair.kmer);
                    match pair.target {
                        Target::Riscv => {
                            metrics.riscv_pairs += 1;
                            for &pos in occs {
                                riscv_items.push((
                                    WorkTag {
                                        read_id: read.id,
                                        pair_id,
                                        ref_pos: pos,
                                        read_offset: pair.read_offset,
                                        pl: pos as i64 - pair.read_offset as i64,
                                        xbar: u32::MAX, // RISC-V pool, not a crossbar
                                        reverse,
                                    },
                                    seq,
                                ));
                            }
                        }
                        Target::Xbar { first, count } => {
                            // FIFO admission on the owning crossbar
                            let fifo = fifos.entry(first).or_insert_with(|| {
                                ReadsFifo::new(
                                    self.cfg.dart.fifo_capacity_reads(),
                                    self.cfg.dart.max_reads,
                                )
                            });
                            let entry =
                                FifoEntry { read_id: read.id, read_offset: pair.read_offset };
                            match fifo.push(entry) {
                                PushResult::CapExceeded => {
                                    metrics.dropped_pairs += 1;
                                    continue;
                                }
                                PushResult::Full => {
                                    // batch-mode backpressure: the entry is
                                    // consumed immediately below, so the FIFO
                                    // drains as fast as it fills
                                    fifo.pop();
                                    if fifo.push(entry) == PushResult::CapExceeded {
                                        metrics.dropped_pairs += 1;
                                        continue;
                                    }
                                }
                                PushResult::Accepted => {}
                            }
                            fifo.pop(); // consumed by this round's linear iteration
                            metrics.routed_pairs += 1;
                            *metrics.pairs_per_xbar.entry(first).or_default() += 1;
                            for sub in 1..count {
                                *metrics.pairs_per_xbar.entry(first + sub).or_default() += 1;
                            }
                            for (i, &pos) in occs.iter().enumerate() {
                                let tag = WorkTag {
                                    read_id: read.id,
                                    pair_id,
                                    ref_pos: pos,
                                    read_offset: pair.read_offset,
                                    pl: pos as i64 - pair.read_offset as i64,
                                    // which of the minimizer's crossbars
                                    // holds this occurrence's segment row
                                    xbar: first + (i / self.cfg.dart.linear_rows) as u32,
                                    reverse,
                                };
                                let win = self.index.window_for(pos, pair.read_offset as usize);
                                metrics.linear_instances += 1;
                                if let Some(b) = linear_batcher.push(tag, seq, win) {
                                    linear_batches.push(b);
                                }
                            }
                        }
                    }
                }
            }
        }
        if let Some(b) = linear_batcher.flush() {
            linear_batches.push(b);
        }
        metrics.t_seed = t0.elapsed();

        // ---- Stage 3: batched linear filter ----
        let t0 = Instant::now();
        // pair_id -> (best dist, tag, window) for MinOnly
        let mut pair_best: HashMap<u32, (i32, WorkTag, Vec<u8>)> = HashMap::new();
        let mut affine_batcher = Batcher::new(self.cfg.batch_size, self.index.read_len);
        let mut affine_batches: Vec<Batch<'_>> = Vec::new();
        for batch in &mut linear_batches {
            let ww: Vec<&[u8]> = batch.wins.iter().map(|v| v.as_slice()).collect();
            let out = self.engine.linear_batch(&batch.reads, &ww)?;
            drop(ww);
            metrics.linear_batches += 1;
            for i in 0..batch.tags.len() {
                let tag = batch.tags[i];
                if out.best[i] > ETH as i32 {
                    continue; // filtered out
                }
                metrics.filter_passed += 1;
                match self.cfg.filter_policy {
                    FilterPolicy::AllPassing => {
                        metrics.affine_instances += 1;
                        *metrics.affine_per_xbar.entry(tag.xbar).or_default() += 1;
                        // window moves to the affine stage (each is used
                        // at most once — §Perf opt 1)
                        let win = std::mem::take(&mut batch.wins[i]);
                        if let Some(b) = affine_batcher.push(tag, batch.reads[i], win) {
                            affine_batches.push(b);
                        }
                    }
                    FilterPolicy::MinOnly => {
                        let e = pair_best.entry(tag.pair_id);
                        match e {
                            std::collections::hash_map::Entry::Occupied(mut o) => {
                                if out.best[i] < o.get().0 {
                                    *o.get_mut() =
                                        (out.best[i], tag, std::mem::take(&mut batch.wins[i]));
                                }
                            }
                            std::collections::hash_map::Entry::Vacant(v) => {
                                v.insert((out.best[i], tag, std::mem::take(&mut batch.wins[i])));
                            }
                        }
                    }
                }
            }
        }
        if self.cfg.filter_policy == FilterPolicy::MinOnly {
            let mut winners: Vec<(i32, WorkTag, Vec<u8>)> = pair_best.into_values().collect();
            winners.sort_by_key(|(_, t, _)| (t.read_id, t.pair_id));
            for (_, tag, win) in winners {
                metrics.affine_instances += 1;
                *metrics.affine_per_xbar.entry(tag.xbar).or_default() += 1;
                let seq: &[u8] = if tag.reverse {
                    &rc_seqs[tag.read_id as usize]
                } else {
                    &reads[tag.read_id as usize].seq
                };
                if let Some(b) = affine_batcher.push(tag, seq, win) {
                    affine_batches.push(b);
                }
            }
        }
        if let Some(b) = affine_batcher.flush() {
            affine_batches.push(b);
        }
        metrics.t_linear = t0.elapsed();

        // ---- Stage 4: batched affine alignment + traceback ----
        let t0 = Instant::now();
        for batch in &affine_batches {
            let ww: Vec<&[u8]> = batch.wins.iter().map(|v| v.as_slice()).collect();
            let out = self.engine.affine_batch(&batch.reads, &ww)?;
            metrics.affine_batches += 1;
            let tt = Instant::now();
            for (i, tag) in batch.tags.iter().enumerate() {
                if let Some(outcome) = self.decode_affine(
                    tag,
                    out.best[i],
                    out.best_j[i] as usize,
                    &out.dirs[i],
                    batch.reads[i],
                    &mut metrics,
                ) {
                    best.update(outcome);
                }
            }
            metrics.t_traceback += tt.elapsed();
        }
        metrics.t_affine = t0.elapsed();

        // ---- RISC-V offload path (scalar Rust engine) ----
        for (tag, seq) in riscv_items {
            let win = self.index.window_for(tag.ref_pos, tag.read_offset as usize);
            metrics.riscv_linear_instances += 1;
            let lin = self.riscv_engine.linear_batch(&[seq], &[&win])?;
            if lin.best[0] > ETH as i32 {
                continue;
            }
            metrics.riscv_affine_instances += 1;
            let aff = self.riscv_engine.affine_batch(&[seq], &[&win])?;
            if let Some(outcome) = self.decode_affine(
                &tag,
                aff.best[0],
                aff.best_j[0] as usize,
                &aff.dirs[0],
                seq,
                &mut metrics,
            ) {
                best.update(outcome);
            }
        }

        // ---- Finalize ----
        metrics.reads_with_candidates = best.mapped_count() as u64;
        metrics.t_total = t_start.elapsed();
        let mappings = best
            .into_mappings()
            .into_iter()
            .enumerate()
            .map(|(id, m)| {
                m.map(|b| FinalMapping {
                    read_id: id as u32,
                    pos: b.pos,
                    dist: b.dist,
                    cigar: b.cigar,
                    candidates: b.candidates,
                    reverse: b.reverse,
                })
            })
            .collect();
        Ok((mappings, metrics))
    }

    /// Turn one affine result into an outcome (traceback + position
    /// refinement). None for saturated or irrecoverable paths.
    fn decode_affine(
        &self,
        tag: &WorkTag,
        dist: i32,
        best_j: usize,
        dirs: &[u8],
        read: &[u8],
        metrics: &mut Metrics,
    ) -> Option<AffineOutcome> {
        if dist >= SAT_AFFINE {
            return None;
        }
        match traceback(dirs, read.len(), best_j) {
            Ok(aln) => {
                debug_assert_eq!(script_cost(&aln.ops, aln.j_end), dist, "cost identity");
                Some(AffineOutcome {
                    read_id: tag.read_id,
                    pos: aln.refined_pos(tag.pl),
                    dist,
                    cigar: Cigar::from_ops(&aln.ops),
                    reverse: tag.reverse,
                })
            }
            Err(_) => {
                metrics.traceback_failures += 1;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{K, READ_LEN, W};

    fn setup(n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
        let g = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        (idx, reads)
    }

    /// Small synthetic genomes have few high-frequency minimizers, so
    /// pin lowTh = 0 to exercise the crossbar path (on human-scale data
    /// the mean minimizer frequency is ~12 and the default lowTh = 3
    /// sends only 0.16 % of work to the RISC-V side).
    fn cfg() -> PipelineConfig {
        PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn maps_simulated_reads_near_truth() {
        let (idx, reads) = setup(60);
        let mut p = Pipeline::new(&idx, cfg(), RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        assert_eq!(mappings.len(), 60);
        let mut near = 0;
        for r in &reads {
            if let Some(m) = &mappings[r.id as usize] {
                if (m.pos - r.truth_pos as i64).abs() <= 5 {
                    near += 1;
                }
            }
        }
        assert!(near >= 54, "near = {near}/60; metrics: {}", metrics.summary());
        assert!(metrics.linear_instances > 0);
        assert!(metrics.affine_instances > 0);
        assert_eq!(metrics.traceback_failures, 0);
    }

    #[test]
    fn cigar_and_distance_consistency() {
        let (idx, reads) = setup(30);
        let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
        let (mappings, _) = p.map_reads(&reads).unwrap();
        for m in mappings.into_iter().flatten() {
            assert_eq!(m.cigar.read_len() as usize, READ_LEN);
            assert!(m.dist <= 2 * ETH as i32 + 1 + SAT_AFFINE); // sane
            assert!(m.candidates >= 1);
        }
    }

    #[test]
    fn min_only_policy_reduces_affine_work() {
        let (idx, reads) = setup(40);
        let all = {
            let mut p = Pipeline::new(&idx, cfg(), RustEngine);
            p.map_reads(&reads).unwrap().1
        };
        let min_only = {
            let c = PipelineConfig { filter_policy: FilterPolicy::MinOnly, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            p.map_reads(&reads).unwrap().1
        };
        assert!(min_only.affine_instances <= all.affine_instances);
        assert!(min_only.affine_instances >= 1);
    }

    #[test]
    fn deterministic_across_runs() {
        let (idx, reads) = setup(25);
        let run = || {
            let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
            p.map_reads(&reads).unwrap().0
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!((x.pos, x.dist, x.cigar.to_string()), (y.pos, y.dist, y.cigar.to_string()))
                }
                _ => panic!("mapping presence differs between runs"),
            }
        }
    }

    #[test]
    fn metrics_bridge_to_simulator() {
        let (idx, reads) = setup(30);
        let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
        let (_, metrics) = p.map_reads(&reads).unwrap();
        let counts = metrics.to_sim_counts();
        let report = crate::simulator::report::build_report(
            &counts,
            &p.cfg.dart,
            crate::pim::xbar_sim::CostSource::PaperTable4,
            crate::simulator::TimingMode::PaperSerial,
        );
        assert!(report.exec_time_s > 0.0);
        assert!(report.energy.total() > 0.0);
        assert!(report.throughput() > 0.0);
    }
}
