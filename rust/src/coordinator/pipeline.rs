//! The end-to-end mapping pipeline (paper Fig. 6, host realization):
//! seed/route -> shard partition -> FIFO admission -> batched linear
//! filter -> batched affine alignment -> traceback -> best-so-far
//! aggregation.
//!
//! The pipeline is engine-agnostic ([`WfEngine`]): the production path
//! runs the AOT-compiled Pallas kernels through PJRT (the
//! `runtime::XlaEngine` behind the `pjrt` feature); lowTh
//! (RISC-V-offload) pairs always run on the scalar Rust path, mirroring
//! the paper's heterogeneous split.
//!
//! # Streaming, bounded-memory execution
//!
//! [`Pipeline::map_stream`] is the primary entry point: it pulls reads
//! from any fallible iterator (a [`crate::genome::fastq::FastqStream`]
//! over a file or stdin, a synthetic generator, a slice), routes them,
//! and pushes final per-read decisions into a sink **in ascending
//! read-id order** as they become final. In-flight state is bounded:
//!
//! * routed items travel to shard workers over **bounded** channels
//!   (`CHANNEL_DEPTH` chunks of `SHARD_CHUNK` items), so a slow
//!   filter stage backpressures routing exactly like a full hardware
//!   Reads FIFO pauses the read stream (paper §V-C);
//! * workers execute every engine batch the moment it fills
//!   (O(batch) in-flight WF state, see [`super::shard`]);
//! * every [`PipelineConfig::stream_epoch`] reads, the coordinator
//!   drains the workers and emits that epoch's decisions, so the
//!   aggregation state is O(epoch), not O(workload).
//!
//! [`Pipeline::map_reads`] survives as a thin collect wrapper over
//! `map_stream` for slice-shaped workloads and tests.
//!
//! # Sharded execution
//!
//! With [`PipelineConfig::threads`] > 1, routed pairs are partitioned by
//! minimizer hash across worker threads (std::thread + mpsc), each
//! owning an engine built on its own thread from
//! [`PipelineConfig::worker_engine`] (the scalar Rust engine or the
//! bit-parallel bitpal engine — both `Send`, unlike PJRT), its own
//! batchers, and the Reads FIFOs of its private crossbar slice — the
//! host mirror of the paper's per-crossbar data organization (§V-B).
//! Output is byte-identical for every thread count, engine kind, and
//! epoch size; see [`super::shard`] for the determinism contract.
//!
//! The sharded path is implemented by [`super::pool`]: a
//! [`super::pool::WorkerPool`] of long-lived shard workers plus a
//! single [`super::pool::MapSession`] driving this one read stream.
//! The `serve` daemon runs the same two pieces with *many* concurrent
//! sessions on one pool, which is why a session's bytes cannot differ
//! from a standalone `map` run (determinism invariant 7).

use std::borrow::Borrow;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::align::Cigar;
use crate::genome::ReadRecord;
use crate::index::IndexRef;
use crate::pim::DartPimConfig;
use crate::runtime::{EngineKind, SimdMode, WfEngine};

use super::metrics::Metrics;
use super::pair::{resolve_epoch_pairs, PairStatus, PairingConfig};
use super::router::Router;
use super::shard::{ShardItem, ShardWorker};
use super::state::{AffineOutcome, BestSoFar, PairCandidates};

/// Which filtered instances advance to affine alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FilterPolicy {
    /// Every instance with linear distance <= eth (matches the paper's
    /// measured affine workload; default).
    #[default]
    AllPassing,
    /// Only the minimum-distance instance of each routed pair (paper
    /// Fig. 6 step 4's literal description; ablation).
    MinOnly,
}

/// Worker-thread count used when a [`PipelineConfig`] does not pin one:
/// the `DART_PIM_THREADS` environment variable when it parses to a
/// positive integer (CI runs the whole suite under `DART_PIM_THREADS=4`),
/// else 1.
pub fn default_threads() -> usize {
    std::env::var("DART_PIM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Number of [`ShardItem`]s streamed to a worker per channel send.
pub(crate) const SHARD_CHUNK: usize = 512;
/// Bounded depth of each worker's item channel (backpressure, like the
/// hardware Reads FIFO bounds the read stream): at most
/// `CHANNEL_DEPTH × SHARD_CHUNK` items are queued per shard before the
/// producer's routing stalls. In a multi-session daemon the channels
/// are shared, so one stalled session backpressures its peers too —
/// see SERVING.md.
pub(crate) const CHANNEL_DEPTH: usize = 4;
/// Default [`PipelineConfig::stream_epoch`].
pub const STREAM_EPOCH_READS: usize = 2048;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Architecture configuration (Tables II/III) driving routing,
    /// FIFO geometry, and the maxReads cap.
    pub dart: DartPimConfig,
    /// Engine flush size (use the largest artifact batch).
    pub batch_size: usize,
    /// Which filtered instances advance to affine alignment.
    pub filter_policy: FilterPolicy,
    /// Also try the reverse-complement orientation of every read
    /// (real sequencers emit both strands; the paper elides this, but a
    /// practical mapper needs it — extension feature, DESIGN.md §7).
    pub handle_revcomp: bool,
    /// Worker shards for the mapping entry points. 1 = run in the
    /// calling thread on the pipeline's own engine; N > 1 = partition
    /// routed pairs by minimizer hash across N worker threads, each
    /// owning an engine built from [`PipelineConfig::worker_engine`].
    /// Output is byte-identical for every value. Defaults to
    /// [`default_threads`].
    pub threads: usize,
    /// Engine each worker shard constructs on its own thread
    /// ([`EngineKind::build`]); the single-threaded path ignores this
    /// and uses the pipeline's configured engine. Defaults to
    /// [`crate::runtime::default_engine`] (the `DART_PIM_ENGINE`
    /// environment variable, else the scalar Rust engine).
    pub worker_engine: EngineKind,
    /// SIMD lane mode for worker-built bit-parallel engines
    /// ([`EngineKind::build_simd`]): pin the classic `u64` word, pick
    /// the widest host lane, or force the scalar fallback. Like the
    /// engine choice and thread count, the mode never changes any
    /// mapping byte (determinism invariant 8) — only throughput.
    /// Defaults to [`crate::runtime::default_simd_mode`] (the
    /// `DART_PIM_SIMD` environment variable, else the widest lane).
    pub simd: SimdMode,
    /// Reads per streaming epoch: the emission / memory granularity of
    /// [`Pipeline::map_stream`]. Peak aggregation state is O(epoch)
    /// reads regardless of input size; mapping decisions are emitted in
    /// read order at every epoch boundary. The value never changes any
    /// mapping decision (engine numerics are per-instance), only
    /// latency/memory. Defaults to [`STREAM_EPOCH_READS`].
    pub stream_epoch: usize,
    /// Paired-end resolution policy. `Some` treats the read stream as
    /// interleaved mates (R1 at even ids, R2 at odd ids — the layout
    /// every paired source in this crate produces) and runs proper-pair
    /// arbitration at every epoch boundary (see [`super::pair`]);
    /// epochs then always end on pair boundaries and the stream length
    /// must be even. `None` (default) is single-end mapping.
    pub pairing: Option<PairingConfig>,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            dart: DartPimConfig::default(),
            batch_size: 256,
            filter_policy: FilterPolicy::AllPassing,
            handle_revcomp: false,
            threads: default_threads(),
            worker_engine: crate::runtime::default_engine(),
            simd: crate::runtime::default_simd_mode(),
            stream_epoch: STREAM_EPOCH_READS,
            pairing: None,
        }
    }
}

/// Final mapping decision for one read.
#[derive(Debug, Clone)]
pub struct FinalMapping {
    /// The read this decision belongs to.
    pub read_id: u32,
    /// Refined mapping position in reference coordinates.
    pub pos: i64,
    /// Affine alignment cost.
    pub dist: i32,
    /// Winning alignment.
    pub cigar: Cigar,
    /// How many candidate outcomes were considered.
    pub candidates: u32,
    /// true if the read mapped in reverse-complement orientation.
    pub reverse: bool,
    /// How the decision was made: [`PairStatus::Unpaired`] in
    /// single-end runs; proper / single-end-fallback / rescued in
    /// paired runs (see [`super::pair`]).
    pub pair: PairStatus,
}

/// The mapper.
///
/// # Example — threaded mapping entry point
///
/// ```
/// use dart_pim::coordinator::{Pipeline, PipelineConfig};
/// use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
/// use dart_pim::index::MinimizerIndex;
/// use dart_pim::params::{K, READ_LEN, W};
/// use dart_pim::runtime::RustEngine;
///
/// let genome = SynthConfig { len: 30_000, ..Default::default() }.generate();
/// let index = MinimizerIndex::build(genome, K, W, READ_LEN);
/// let reads = ReadSimConfig { n_reads: 4, ..Default::default() }
///     .simulate(&index.reference, |p| p as u32);
///
/// // two worker shards; results are byte-identical to threads: 1
/// let cfg = PipelineConfig { threads: 2, ..Default::default() };
/// let mut pipeline = Pipeline::new(&index, cfg, RustEngine);
/// let (mappings, metrics) = pipeline.map_reads(&reads).unwrap();
/// assert_eq!(mappings.len(), 4);
/// assert_eq!(metrics.n_reads, 4);
/// ```
pub struct Pipeline<'a, E: WfEngine> {
    /// The offline minimizer index being mapped against (either
    /// backend; the output bytes are identical for both — determinism
    /// invariant 9).
    pub index: IndexRef<'a>,
    /// Minimizer -> crossbar / RISC-V routing table.
    pub router: Router,
    /// Run configuration.
    pub cfg: PipelineConfig,
    engine: E,
}

impl<'a, E: WfEngine> Pipeline<'a, E> {
    /// Build a pipeline over `index` with the given engine (the engine
    /// is only used by the single-threaded path; worker shards build
    /// their own from [`PipelineConfig::worker_engine`]).
    pub fn new(index: impl Into<IndexRef<'a>>, cfg: PipelineConfig, engine: E) -> Self {
        let index = index.into();
        let router = Router::new(index, &cfg.dart);
        Pipeline { index, router, cfg, engine }
    }

    /// Name of the engine driving the single-threaded path.
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Map a materialized read set end to end — a thin collect wrapper
    /// over [`Pipeline::map_stream`]. Returns per-read decisions
    /// (indexed by read id) and run metrics. Reads must carry dense
    /// sequential ids (`reads[i].id == i`), which every generator in
    /// this crate produces.
    pub fn map_reads(
        &mut self,
        reads: &[ReadRecord],
    ) -> Result<(Vec<Option<FinalMapping>>, Metrics)> {
        let mut out = Vec::with_capacity(reads.len());
        let metrics = self.map_stream(
            reads.iter().enumerate().map(|(i, r)| {
                debug_assert_eq!(r.id as usize, i, "map_reads requires dense sequential ids");
                Ok(r)
            }),
            |_, m| {
                out.push(m);
                Ok(())
            },
        )?;
        Ok((out, metrics))
    }

    /// Map a read stream end to end with bounded memory.
    ///
    /// Reads are pulled from `reads` (any fallible iterator; ids are
    /// assigned by arrival order) and each read's final decision is
    /// pushed into `sink(read_id, decision)` — every id exactly once, in
    /// ascending order, `None` for unmapped reads. Decisions are emitted
    /// at epoch boundaries ([`PipelineConfig::stream_epoch`] reads), so
    /// peak memory is O(epoch + threads × batch) regardless of the
    /// stream length.
    ///
    /// Mappings, CIGARs, and workload counters are byte-identical for
    /// every `threads` / `worker_engine` / `stream_epoch` setting (see
    /// [`Metrics::invariant_counters`]); `tests/stream_parity.rs` and
    /// `tests/shard_determinism.rs` hold that contract.
    ///
    /// An `Err` from the iterator, the sink, or a worker engine aborts
    /// the run and is returned (a worker *panic* propagates as a panic
    /// with its original payload).
    ///
    /// `reads` may yield owned records (a parser) or `&ReadRecord` (a
    /// slice walk — no copies).
    pub fn map_stream<I, R, S>(&mut self, reads: I, mut sink: S) -> Result<Metrics>
    where
        I: IntoIterator<Item = Result<R>>,
        R: Borrow<ReadRecord>,
        S: FnMut(u32, Option<FinalMapping>) -> Result<()>,
    {
        if self.cfg.threads.max(1) == 1 {
            self.map_stream_single(reads, &mut sink)
        } else {
            self.map_stream_sharded(reads, &mut sink)
        }
    }

    /// Single-shard streaming: route inline, run on the pipeline's own
    /// engine (the PJRT path when compiled in).
    fn map_stream_single<I, R, S>(&mut self, reads: I, sink: &mut S) -> Result<Metrics>
    where
        I: IntoIterator<Item = Result<R>>,
        R: Borrow<ReadRecord>,
        S: FnMut(u32, Option<FinalMapping>) -> Result<()>,
    {
        let index = self.index;
        let router = &self.router;
        let cfg = &self.cfg;
        let engine = &mut self.engine;
        let epoch = cfg.stream_epoch.max(1);
        let pairing = cfg.pairing.as_ref();

        let t_start = Instant::now();
        let mut metrics = Metrics::default();
        let mut worker = ShardWorker::new(index, cfg);
        let mut chunk: Vec<ShardItem> = Vec::new();
        // forward sequences of the current epoch's reads, retained only
        // in paired mode (the rescue scan needs them at emission)
        let mut epoch_seqs: Vec<Arc<[u8]>> = Vec::new();
        let mut t_route = Duration::ZERO;
        let mut next_pair = 0u32;
        let mut next_id = 0u32;
        let mut epoch_start = 0u32;
        for rec in reads {
            let rec = rec?;
            let read = rec.borrow();
            let t0 = Instant::now();
            let fwd = route_read(
                router,
                index,
                cfg.handle_revcomp,
                next_id,
                read,
                &mut next_pair,
                |it| chunk.push(it),
            );
            if pairing.is_some() {
                epoch_seqs.push(fwd);
            }
            t_route += t0.elapsed();
            worker.ingest(&mut *engine, chunk.drain(..))?;
            next_id = bump_read_id(next_id)?;
            if epoch_boundary(epoch_start, next_id, epoch, pairing.is_some()) {
                let outs = worker.drain(&mut *engine)?;
                let span = (epoch_start, next_id);
                emit_epoch(index, pairing, &mut epoch_seqs, span, outs, sink, &mut metrics)?;
                epoch_start = next_id;
            }
        }
        check_even_paired_stream(pairing.is_some(), next_id)?;
        let (outs, m) = worker.finish(&mut *engine)?;
        let span = (epoch_start, next_id);
        emit_epoch(index, pairing, &mut epoch_seqs, span, outs, sink, &mut metrics)?;
        metrics.merge(m);
        metrics.t_seed += t_route;
        metrics.n_reads = u64::from(next_id);
        metrics.t_total = t_start.elapsed();
        Ok(metrics)
    }

    /// Sharded streaming: a [`super::pool::WorkerPool`] of persistent
    /// per-shard workers fed over bounded channels by one
    /// [`super::pool::MapSession`], with an epoch flush/ack barrier for
    /// ordered emission. The daemon (`dart-pim serve`) runs the same
    /// pool with many concurrent sessions.
    fn map_stream_sharded<I, R, S>(&mut self, reads: I, sink: &mut S) -> Result<Metrics>
    where
        I: IntoIterator<Item = Result<R>>,
        R: Borrow<ReadRecord>,
        S: FnMut(u32, Option<FinalMapping>) -> Result<()>,
    {
        super::pool::map_stream_pooled(self.index, &self.router, &self.cfg, reads, sink)
    }
}

/// Advance the dense read-id counter (u32 ids cap a single run at ~4.3 G
/// reads — an order of magnitude above the paper's 389 M workload).
pub(crate) fn bump_read_id(next_id: u32) -> Result<u32> {
    next_id.checked_add(1).ok_or_else(|| anyhow!("read stream exceeds u32 read ids"))
}

/// True when read `next_id` closes the current epoch. In paired mode an
/// epoch may only close on a pair boundary (even id), so both mates of
/// every pair resolve inside one epoch — the invariant that keeps pair
/// arbitration epoch-stateless.
pub(crate) fn epoch_boundary(epoch_start: u32, next_id: u32, epoch: usize, paired: bool) -> bool {
    (next_id - epoch_start) as usize >= epoch && (!paired || next_id % 2 == 0)
}

/// Paired streams must hold complete pairs: an odd read count means R1/R2
/// inputs desynchronized upstream of the pipeline.
pub(crate) fn check_even_paired_stream(paired: bool, n_reads: u32) -> Result<()> {
    if paired && n_reads % 2 != 0 {
        bail!("paired mapping requires an even read stream; got {n_reads} reads");
    }
    Ok(())
}

/// Route one read (both orientations when revcomp handling is on) into
/// [`ShardItem`]s, assigning globally sequential pair ids. The oriented
/// sequences are materialized once per read as shared slices; every
/// routed pair clones the refcount, not the bases. Returns the forward
/// sequence slice (retained per epoch in paired mode for mate rescue).
pub(crate) fn route_read(
    router: &Router,
    index: IndexRef<'_>,
    handle_revcomp: bool,
    read_id: u32,
    read: &ReadRecord,
    next_pair: &mut u32,
    mut emit: impl FnMut(ShardItem),
) -> Arc<[u8]> {
    let fwd: Arc<[u8]> = Arc::from(read.seq.as_slice());
    let mut oriented: Vec<(Arc<[u8]>, bool)> = Vec::with_capacity(2);
    oriented.push((fwd.clone(), false));
    if handle_revcomp {
        oriented.push((Arc::from(crate::genome::revcomp(&read.seq)), true));
    }
    for (seq, reverse) in oriented {
        for pair in router.route(index, read_id, &seq) {
            let pair_id = *next_pair;
            *next_pair += 1;
            emit(ShardItem {
                pair_id,
                read_id,
                read_offset: pair.read_offset,
                kmer: pair.kmer,
                target: pair.target,
                reverse,
                mate: (read_id % 2) as u8,
                seq: seq.clone(),
            });
        }
    }
    fwd
}

/// Fold one epoch's outcomes into per-read decisions and push reads
/// `[start, end)` through the sink in ascending id order. Correctness
/// rests on the emission-order arbitration key ([`AffineOutcome::key`]):
/// folding outcomes in *any* arrival order yields identical winners, so
/// thread count and epoch size never change a byte of output.
///
/// Single-end runs aggregate through [`BestSoFar`]; paired runs keep the
/// full per-read candidate lists and resolve them through the
/// epoch-stateless pair arbitration ([`super::pair`]), consuming the
/// epoch's retained forward sequences (`epoch_seqs`) for mate rescue.
#[allow(clippy::too_many_arguments)]
pub(crate) fn emit_epoch<S>(
    index: IndexRef<'_>,
    pairing: Option<&PairingConfig>,
    epoch_seqs: &mut Vec<Arc<[u8]>>,
    (start, end): (u32, u32),
    outcomes: Vec<AffineOutcome>,
    sink: &mut S,
    metrics: &mut Metrics,
) -> Result<()>
where
    S: FnMut(u32, Option<FinalMapping>) -> Result<()>,
{
    let n = (end - start) as usize;
    let decisions: Vec<Option<FinalMapping>> = match pairing {
        None => {
            let mut best = BestSoFar::new(n);
            for mut o in outcomes {
                debug_assert!(o.read_id >= start && o.read_id < end, "outcome outside its epoch");
                o.read_id -= start;
                best.update(o);
            }
            best.into_mappings()
                .into_iter()
                .enumerate()
                .map(|(i, m)| {
                    m.map(|b| FinalMapping {
                        read_id: start + i as u32,
                        pos: b.pos,
                        dist: b.dist,
                        cigar: b.cigar,
                        candidates: b.candidates,
                        reverse: b.reverse,
                        pair: PairStatus::Unpaired,
                    })
                })
                .collect()
        }
        Some(pcfg) => {
            debug_assert_eq!(epoch_seqs.len(), n, "one retained sequence per epoch read");
            let mut cands = PairCandidates::new(n);
            for mut o in outcomes {
                debug_assert!(o.read_id >= start && o.read_id < end, "outcome outside its epoch");
                o.read_id -= start;
                cands.push(o);
            }
            let lists = cands.into_sorted();
            let out = resolve_epoch_pairs(start, lists, epoch_seqs, index, pcfg, metrics)?;
            epoch_seqs.clear();
            out
        }
    };
    for (i, m) in decisions.into_iter().enumerate() {
        let read_id = start + i as u32;
        // rescued mates had no surviving affine candidate of their own
        // (that is the rescue precondition) — they are tracked by
        // `rescued_mates`, not here, so this counter keeps its meaning
        // and its bridge to the simulator's filter-derived counts
        if m.as_ref().is_some_and(|fm| fm.pair != PairStatus::Rescued) {
            metrics.reads_with_candidates += 1;
        }
        sink(read_id, m)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::index::MinimizerIndex;
    use crate::params::{ETH, K, READ_LEN, SAT_AFFINE, W};
    use crate::runtime::{BitpalEngine, RustEngine};

    fn setup(n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
        let g = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        (idx, reads)
    }

    /// Small synthetic genomes have few high-frequency minimizers, so
    /// pin lowTh = 0 to exercise the crossbar path (on human-scale data
    /// the mean minimizer frequency is ~12 and the default lowTh = 3
    /// sends only 0.16 % of work to the RISC-V side).
    fn cfg() -> PipelineConfig {
        PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn maps_simulated_reads_near_truth() {
        let (idx, reads) = setup(60);
        let mut p = Pipeline::new(&idx, cfg(), RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        assert_eq!(mappings.len(), 60);
        let mut near = 0;
        for r in &reads {
            if let Some(m) = &mappings[r.id as usize] {
                if (m.pos - r.truth_pos as i64).abs() <= 5 {
                    near += 1;
                }
            }
        }
        assert!(near >= 54, "near = {near}/60; metrics: {}", metrics.summary());
        assert!(metrics.linear_instances > 0);
        assert!(metrics.affine_instances > 0);
        assert_eq!(metrics.traceback_failures, 0);
    }

    #[test]
    fn cigar_and_distance_consistency() {
        let (idx, reads) = setup(30);
        let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
        let (mappings, _) = p.map_reads(&reads).unwrap();
        for m in mappings.into_iter().flatten() {
            assert_eq!(m.cigar.read_len() as usize, READ_LEN);
            assert!(m.dist <= 2 * ETH as i32 + 1 + SAT_AFFINE); // sane
            assert!(m.candidates >= 1);
        }
    }

    #[test]
    fn min_only_policy_reduces_affine_work() {
        let (idx, reads) = setup(40);
        let all = {
            let mut p = Pipeline::new(&idx, cfg(), RustEngine);
            p.map_reads(&reads).unwrap().1
        };
        let min_only = {
            let c = PipelineConfig { filter_policy: FilterPolicy::MinOnly, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            p.map_reads(&reads).unwrap().1
        };
        assert!(min_only.affine_instances <= all.affine_instances);
        assert!(min_only.affine_instances >= 1);
    }

    #[test]
    fn deterministic_across_runs() {
        let (idx, reads) = setup(25);
        let run = || {
            let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
            p.map_reads(&reads).unwrap().0
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (None, None) => {}
                (Some(x), Some(y)) => assert_eq!(
                    (x.pos, x.dist, x.cigar.to_string()),
                    (y.pos, y.dist, y.cigar.to_string())
                ),
                _ => panic!("mapping presence differs between runs"),
            }
        }
    }

    #[test]
    fn sharded_matches_single_thread_exactly() {
        let (idx, reads) = setup(40);
        let run = |threads: usize| {
            let c = PipelineConfig { threads, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            p.map_reads(&reads).unwrap()
        };
        let (m1, x1) = run(1);
        for threads in [2usize, 3, 4] {
            let (mt, xt) = run(threads);
            for (a, b) in m1.iter().zip(&mt) {
                match (a, b) {
                    (None, None) => {}
                    (Some(a), Some(b)) => assert_eq!(
                        (a.pos, a.dist, a.cigar.to_string(), a.candidates, a.reverse),
                        (b.pos, b.dist, b.cigar.to_string(), b.candidates, b.reverse),
                        "threads={threads}"
                    ),
                    _ => panic!("presence mismatch at threads={threads}"),
                }
            }
            assert_eq!(
                x1.invariant_counters(),
                xt.invariant_counters(),
                "workload counters must not depend on sharding (threads={threads})"
            );
        }
    }

    #[test]
    fn stream_epoch_size_never_changes_output() {
        let (idx, reads) = setup(40);
        let run = |threads: usize, stream_epoch: usize| {
            let c = PipelineConfig { threads, stream_epoch, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            p.map_reads(&reads).unwrap()
        };
        let (base, bm) = run(1, STREAM_EPOCH_READS);
        for (threads, epoch) in [(1usize, 1usize), (1, 7), (4, 7), (4, 16), (3, 1)] {
            let (m, x) = run(threads, epoch);
            assert_eq!(base.len(), m.len());
            for (a, b) in base.iter().zip(&m) {
                match (a, b) {
                    (None, None) => {}
                    (Some(a), Some(b)) => assert_eq!(
                        (a.pos, a.dist, a.cigar.to_string(), a.candidates, a.reverse),
                        (b.pos, b.dist, b.cigar.to_string(), b.candidates, b.reverse),
                        "threads={threads} epoch={epoch}"
                    ),
                    _ => panic!("presence mismatch (threads={threads} epoch={epoch})"),
                }
            }
            assert_eq!(
                bm.invariant_counters(),
                x.invariant_counters(),
                "threads={threads} epoch={epoch}"
            );
        }
    }

    #[test]
    fn map_stream_sink_sees_every_id_in_order() {
        let (idx, reads) = setup(23);
        let c = PipelineConfig { threads: 2, stream_epoch: 5, ..cfg() };
        let mut p = Pipeline::new(&idx, c, RustEngine);
        let mut seen: Vec<u32> = Vec::new();
        let metrics = p
            .map_stream(reads.iter().cloned().map(Ok), |id, _| {
                seen.push(id);
                Ok(())
            })
            .unwrap();
        assert_eq!(seen, (0..23).collect::<Vec<u32>>());
        assert_eq!(metrics.n_reads, 23);
    }

    #[test]
    fn map_stream_propagates_input_errors() {
        let (idx, reads) = setup(8);
        for threads in [1usize, 3] {
            let c = PipelineConfig { threads, stream_epoch: 2, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            let stream = reads
                .iter()
                .cloned()
                .map(Ok)
                .chain(std::iter::once(Err(anyhow!("bad FASTQ record"))));
            let err = p.map_stream(stream, |_, _| Ok(())).unwrap_err();
            assert!(err.to_string().contains("bad FASTQ"), "threads={threads}: {err}");
        }
    }

    #[test]
    fn map_stream_propagates_sink_errors() {
        let (idx, reads) = setup(12);
        for threads in [1usize, 3] {
            let c = PipelineConfig { threads, stream_epoch: 3, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            let mut emitted = 0u32;
            let err = p
                .map_stream(reads.iter().cloned().map(Ok), |_, _| {
                    emitted += 1;
                    if emitted > 4 {
                        bail!("sink full")
                    }
                    Ok(())
                })
                .unwrap_err();
            assert!(err.to_string().contains("sink full"), "threads={threads}: {err}");
        }
    }

    #[test]
    fn bitpal_engine_matches_rust_end_to_end() {
        let (idx, reads) = setup(40);
        let baseline = {
            // pin the baseline to the scalar single-threaded path: the
            // env defaults (DART_PIM_THREADS / DART_PIM_ENGINE) must not
            // be able to turn this into bitpal-vs-bitpal in CI
            let c = PipelineConfig { threads: 1, worker_engine: EngineKind::Rust, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            p.map_reads(&reads).unwrap().0
        };
        for threads in [1usize, 4] {
            let c = PipelineConfig { threads, worker_engine: EngineKind::Bitpal, ..cfg() };
            let mut p = Pipeline::new(&idx, c, BitpalEngine::new());
            let (m, _) = p.map_reads(&reads).unwrap();
            for (a, b) in baseline.iter().zip(&m) {
                match (a, b) {
                    (None, None) => {}
                    (Some(a), Some(b)) => assert_eq!(
                        (a.pos, a.dist, a.cigar.to_string(), a.candidates),
                        (b.pos, b.dist, b.cigar.to_string(), b.candidates),
                        "threads={threads}"
                    ),
                    _ => panic!("presence mismatch (threads={threads})"),
                }
            }
        }
    }

    #[test]
    fn sharded_handles_more_threads_than_work() {
        // more shards than minimizers: some workers receive nothing
        let (idx, reads) = setup(3);
        let c = PipelineConfig { threads: 16, ..cfg() };
        let mut p = Pipeline::new(&idx, c, RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        assert_eq!(mappings.len(), 3);
        assert_eq!(metrics.n_reads, 3);
    }

    #[test]
    fn sharded_empty_read_set() {
        let (idx, _) = setup(1);
        let c = PipelineConfig { threads: 4, ..cfg() };
        let mut p = Pipeline::new(&idx, c, RustEngine);
        let (mappings, metrics) = p.map_reads(&[]).unwrap();
        assert!(mappings.is_empty());
        assert_eq!(metrics.n_reads, 0);
    }

    #[test]
    fn paired_mapping_resolves_proper_pairs_near_truth() {
        use crate::genome::synth::PairSimConfig;
        let g = SynthConfig { len: 120_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = PairSimConfig { n_pairs: 30, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let c = PipelineConfig {
            handle_revcomp: true,
            pairing: Some(PairingConfig::default()),
            ..cfg()
        };
        let mut p = Pipeline::new(&idx, c, RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        assert_eq!(mappings.len(), 60);
        assert!(metrics.proper_pairs >= 22, "proper pairs: {}", metrics.proper_pairs);
        let mut near = 0;
        for r in &reads {
            if let Some(m) = &mappings[r.id as usize] {
                if (m.pos - r.truth_pos as i64).abs() <= 5 {
                    near += 1;
                    if m.pair == PairStatus::Proper {
                        // FR: R1 forward, R2 reverse (synthetic pairs
                        // are always sequenced fragment-forward)
                        assert_eq!(m.reverse, r.id % 2 == 1, "read {}", r.id);
                    }
                }
            }
        }
        assert!(near >= 52, "near = {near}/60; {}", metrics.summary());
    }

    #[test]
    fn paired_output_is_identical_across_threads_and_epochs() {
        use crate::genome::synth::PairSimConfig;
        let g = SynthConfig { len: 100_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = PairSimConfig { n_pairs: 25, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let run = |threads: usize, epoch: usize| {
            let c = PipelineConfig {
                handle_revcomp: true,
                pairing: Some(PairingConfig::default()),
                threads,
                stream_epoch: epoch,
                ..cfg()
            };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            let (m, x) = p.map_reads(&reads).unwrap();
            let rendered: Vec<_> = m
                .iter()
                .flatten()
                .map(|f| {
                    (f.read_id, f.pos, f.dist, f.cigar.to_string(), f.reverse, f.pair.as_str())
                })
                .collect();
            (rendered, x.invariant_counters())
        };
        let (base, bc) = run(1, STREAM_EPOCH_READS);
        assert!(!base.is_empty());
        // epoch 7 is odd on purpose: boundaries must defer to the next
        // pair boundary without changing a single decision
        for (threads, epoch) in [(1usize, 7usize), (4, 7), (4, 16), (3, 2)] {
            let (m, c) = run(threads, epoch);
            assert_eq!(base, m, "threads={threads} epoch={epoch}");
            assert_eq!(bc, c, "threads={threads} epoch={epoch}");
        }
    }

    #[test]
    fn pair_with_unmappable_mate_degrades_to_single_end() {
        use crate::genome::synth::PairSimConfig;
        use crate::util::SmallRng;
        let g = SynthConfig { len: 90_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let mut reads = PairSimConfig { n_pairs: 12, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        // garbage R2s: unmappable and unrescuable (random sequence)
        let mut rng = SmallRng::seed_from_u64(0xBAD2);
        for r in reads.iter_mut().filter(|r| r.id % 2 == 1) {
            r.seq = (0..READ_LEN).map(|_| rng.gen_range(0..4u8)).collect();
        }
        let run = |pairing: Option<PairingConfig>| {
            let c = PipelineConfig { handle_revcomp: true, pairing, ..cfg() };
            Pipeline::new(&idx, c, RustEngine).map_reads(&reads).unwrap().0
        };
        let paired = run(Some(PairingConfig::default()));
        let single = run(None);
        for r in reads.iter().filter(|r| r.id % 2 == 0) {
            match (&paired[r.id as usize], &single[r.id as usize]) {
                (None, None) => {}
                (Some(a), Some(b)) => assert_eq!(
                    (a.pos, a.dist, a.cigar.to_string(), a.candidates, a.reverse),
                    (b.pos, b.dist, b.cigar.to_string(), b.candidates, b.reverse),
                    "read {}: the mapped mate must keep its single-end decision",
                    r.id
                ),
                _ => panic!("presence mismatch at read {}", r.id),
            }
        }
    }

    #[test]
    fn paired_mapping_rejects_odd_streams() {
        let (idx, reads) = setup(5);
        let c = PipelineConfig {
            pairing: Some(PairingConfig::default()),
            ..cfg()
        };
        let mut p = Pipeline::new(&idx, c, RustEngine);
        let err = p.map_reads(&reads).unwrap_err();
        assert!(err.to_string().contains("even"), "{err}");
    }

    #[test]
    fn metrics_bridge_to_simulator() {
        let (idx, reads) = setup(30);
        let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
        let (_, metrics) = p.map_reads(&reads).unwrap();
        let counts = metrics.to_sim_counts();
        let report = crate::simulator::report::build_report(
            &counts,
            &p.cfg.dart,
            crate::pim::xbar_sim::CostSource::PaperTable4,
            crate::simulator::TimingMode::PaperSerial,
        );
        assert!(report.exec_time_s > 0.0);
        assert!(report.energy.total() > 0.0);
        assert!(report.throughput() > 0.0);
    }
}
