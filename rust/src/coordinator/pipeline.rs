//! The end-to-end mapping pipeline (paper Fig. 6, host realization):
//! seed/route -> shard partition -> FIFO admission -> batched linear
//! filter -> batched affine alignment -> traceback -> best-so-far
//! aggregation.
//!
//! The pipeline is engine-agnostic ([`WfEngine`]): the production path
//! runs the AOT-compiled Pallas kernels through PJRT (the
//! `runtime::XlaEngine` behind the `pjrt` feature); lowTh
//! (RISC-V-offload) pairs always run on the scalar Rust path, mirroring
//! the paper's heterogeneous split.
//!
//! # Sharded execution
//!
//! With [`PipelineConfig::threads`] > 1, routed pairs are partitioned by
//! minimizer hash across worker threads (std::thread + mpsc), each
//! owning an engine built on its own thread from
//! [`PipelineConfig::worker_engine`] (the scalar Rust engine or the
//! bit-parallel bitpal engine — both `Send`, unlike PJRT), its own
//! batchers, and the Reads FIFOs of its private crossbar slice — the
//! host mirror of the paper's per-crossbar data organization (§V-B).
//! Output is byte-identical for every thread count and engine kind; see
//! [`super::shard`] for the determinism contract.

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::align::Cigar;
use crate::genome::encode::Seq;
use crate::genome::ReadRecord;
use crate::index::{shard_of, MinimizerIndex};
use crate::pim::DartPimConfig;
use crate::runtime::{EngineKind, WfEngine};

use super::metrics::Metrics;
use super::router::Router;
use super::shard::{run_shard, ShardItem, ShardWorker};
use super::state::{AffineOutcome, BestSoFar};

/// Which filtered instances advance to affine alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum FilterPolicy {
    /// Every instance with linear distance <= eth (matches the paper's
    /// measured affine workload; default).
    #[default]
    AllPassing,
    /// Only the minimum-distance instance of each routed pair (paper
    /// Fig. 6 step 4's literal description; ablation).
    MinOnly,
}

/// Worker-thread count used when a [`PipelineConfig`] does not pin one:
/// the `DART_PIM_THREADS` environment variable when it parses to a
/// positive integer (CI runs the whole suite under `DART_PIM_THREADS=4`),
/// else 1.
pub fn default_threads() -> usize {
    std::env::var("DART_PIM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(1)
}

/// Number of [`ShardItem`]s streamed to a worker per channel send.
const SHARD_CHUNK: usize = 512;
/// Bounded depth of each worker's item channel (backpressure, like the
/// hardware Reads FIFO bounds the read stream).
const CHANNEL_DEPTH: usize = 4;

/// Pipeline configuration.
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    /// Architecture configuration (Tables II/III) driving routing,
    /// FIFO geometry, and the maxReads cap.
    pub dart: DartPimConfig,
    /// Engine flush size (use the largest artifact batch).
    pub batch_size: usize,
    /// Which filtered instances advance to affine alignment.
    pub filter_policy: FilterPolicy,
    /// Also try the reverse-complement orientation of every read
    /// (real sequencers emit both strands; the paper elides this, but a
    /// practical mapper needs it — extension feature, DESIGN.md §7).
    pub handle_revcomp: bool,
    /// Worker shards for [`Pipeline::map_reads`]. 1 = run in the calling
    /// thread on the pipeline's own engine; N > 1 = partition routed
    /// pairs by minimizer hash across N worker threads, each owning an
    /// engine built from [`PipelineConfig::worker_engine`]. Output is
    /// byte-identical for every value. Defaults to [`default_threads`].
    pub threads: usize,
    /// Engine each worker shard constructs on its own thread
    /// ([`EngineKind::build`]); the single-threaded path ignores this
    /// and uses the pipeline's configured engine. Defaults to
    /// [`crate::runtime::default_engine`] (the `DART_PIM_ENGINE`
    /// environment variable, else the scalar Rust engine).
    pub worker_engine: EngineKind,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig {
            dart: DartPimConfig::default(),
            batch_size: 256,
            filter_policy: FilterPolicy::AllPassing,
            handle_revcomp: false,
            threads: default_threads(),
            worker_engine: crate::runtime::default_engine(),
        }
    }
}

/// Final mapping decision for one read.
#[derive(Debug, Clone)]
pub struct FinalMapping {
    /// The read this decision belongs to.
    pub read_id: u32,
    /// Refined mapping position in reference coordinates.
    pub pos: i64,
    /// Affine alignment cost.
    pub dist: i32,
    /// Winning alignment.
    pub cigar: Cigar,
    /// How many candidate outcomes were considered.
    pub candidates: u32,
    /// true if the read mapped in reverse-complement orientation.
    pub reverse: bool,
}

/// The mapper.
///
/// # Example — threaded mapping entry point
///
/// ```
/// use dart_pim::coordinator::{Pipeline, PipelineConfig};
/// use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
/// use dart_pim::index::MinimizerIndex;
/// use dart_pim::params::{K, READ_LEN, W};
/// use dart_pim::runtime::RustEngine;
///
/// let genome = SynthConfig { len: 30_000, ..Default::default() }.generate();
/// let index = MinimizerIndex::build(genome, K, W, READ_LEN);
/// let reads = ReadSimConfig { n_reads: 4, ..Default::default() }
///     .simulate(&index.reference, |p| p as u32);
///
/// // two worker shards; results are byte-identical to threads: 1
/// let cfg = PipelineConfig { threads: 2, ..Default::default() };
/// let mut pipeline = Pipeline::new(&index, cfg, RustEngine);
/// let (mappings, metrics) = pipeline.map_reads(&reads).unwrap();
/// assert_eq!(mappings.len(), 4);
/// assert_eq!(metrics.n_reads, 4);
/// ```
pub struct Pipeline<'a, E: WfEngine> {
    /// The offline minimizer index being mapped against.
    pub index: &'a MinimizerIndex,
    /// Minimizer -> crossbar / RISC-V routing table.
    pub router: Router,
    /// Run configuration.
    pub cfg: PipelineConfig,
    engine: E,
}

impl<'a, E: WfEngine> Pipeline<'a, E> {
    /// Build a pipeline over `index` with the given engine (the engine
    /// is only used by the single-threaded path; worker shards build
    /// their own from [`PipelineConfig::worker_engine`]).
    pub fn new(index: &'a MinimizerIndex, cfg: PipelineConfig, engine: E) -> Self {
        let router = Router::new(index, &cfg.dart);
        Pipeline { index, router, cfg, engine }
    }

    /// Name of the engine driving the single-threaded path.
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Map a read set end to end. Returns per-read decisions (indexed by
    /// read id) and run metrics.
    ///
    /// With `cfg.threads` > 1 the routed pairs are executed by worker
    /// shards; mappings, CIGARs, and workload counters are byte-identical
    /// to the single-threaded path (see
    /// [`Metrics::invariant_counters`]).
    pub fn map_reads(
        &mut self,
        reads: &[ReadRecord],
    ) -> Result<(Vec<Option<FinalMapping>>, Metrics)> {
        let t_start = Instant::now();
        let n_shards = self.cfg.threads.max(1);
        let mut metrics = Metrics { n_reads: reads.len() as u64, ..Default::default() };
        let mut best = BestSoFar::new(reads.len());

        // reverse-complement orientations, materialized once per read so
        // the zero-copy batches can borrow them (empty when disabled)
        let rc_seqs: Vec<Seq> = if self.cfg.handle_revcomp {
            reads.iter().map(|r| crate::genome::revcomp(&r.seq)).collect()
        } else {
            Vec::new()
        };

        if n_shards == 1 {
            // ---- Single shard: route inline, run on the pipeline's own
            // engine (the PJRT path when compiled in) ----
            let t0 = Instant::now();
            let mut items: Vec<ShardItem<'_>> = Vec::new();
            let mut next_pair = 0u32;
            for read in reads {
                self.route_oriented(read, &rc_seqs, &mut next_pair, |item| items.push(item));
            }
            let t_route = t0.elapsed();
            let (outcomes, m) = run_shard(self.index, &self.cfg, &mut self.engine, &items)?;
            for o in outcomes {
                best.update(o);
            }
            metrics.merge(m);
            metrics.t_seed += t_route;
        } else {
            // ---- Sharded: stream routed pairs to worker threads over
            // bounded channels, partitioned by minimizer hash ----
            let index = self.index;
            let cfg = &self.cfg;
            let (shard_results, t_route) = thread::scope(|s| {
                let mut txs = Vec::with_capacity(n_shards);
                let mut handles = Vec::with_capacity(n_shards);
                for _ in 0..n_shards {
                    let (tx, rx) = mpsc::sync_channel::<Vec<ShardItem<'_>>>(CHANNEL_DEPTH);
                    txs.push(tx);
                    handles.push(s.spawn(move || {
                        // ingest chunks as they stream in (FIFO
                        // admission + window extraction overlap the
                        // producer's routing); compute starts when the
                        // producer hangs up
                        let mut worker = ShardWorker::new(index, cfg);
                        while let Ok(chunk) = rx.recv() {
                            worker.ingest(chunk);
                        }
                        // the engine is constructed on its owning thread
                        // (every EngineKind variant is Send-safe to build
                        // and run here; the PJRT engine never is)
                        let mut engine = cfg.worker_engine.build();
                        worker.finish(engine.as_mut())
                    }));
                }

                // producer (this thread): seed, route, partition, send
                let t0 = Instant::now();
                let mut pending: Vec<Vec<ShardItem<'_>>> =
                    (0..n_shards).map(|_| Vec::with_capacity(SHARD_CHUNK)).collect();
                let mut next_pair = 0u32;
                for read in reads {
                    self.route_oriented(read, &rc_seqs, &mut next_pair, |item| {
                        let sh = shard_of(item.kmer, n_shards);
                        pending[sh].push(item);
                        if pending[sh].len() >= SHARD_CHUNK {
                            let full = std::mem::replace(
                                &mut pending[sh],
                                Vec::with_capacity(SHARD_CHUNK),
                            );
                            // a send error means the worker died; its
                            // join below surfaces the cause
                            let _ = txs[sh].send(full);
                        }
                    });
                }
                for (sh, tx) in txs.into_iter().enumerate() {
                    let rest = std::mem::take(&mut pending[sh]);
                    if !rest.is_empty() {
                        let _ = tx.send(rest);
                    }
                    // tx drops here: the worker's recv loop ends and its
                    // compute begins
                }
                let t_route = t0.elapsed();

                // deterministic merge order: shard 0..N (the arbitration
                // key makes any order equivalent)
                let results: Vec<Result<(Vec<AffineOutcome>, Metrics)>> = handles
                    .into_iter()
                    .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("shard worker panicked"))))
                    .collect();
                (results, t_route)
            });
            for r in shard_results {
                let (outcomes, m) = r?;
                for o in outcomes {
                    best.update(o);
                }
                metrics.merge(m);
            }
            metrics.t_seed += t_route;
        }

        // ---- Finalize ----
        metrics.reads_with_candidates = best.mapped_count() as u64;
        metrics.t_total = t_start.elapsed();
        let mappings = best
            .into_mappings()
            .into_iter()
            .enumerate()
            .map(|(id, m)| {
                m.map(|b| FinalMapping {
                    read_id: id as u32,
                    pos: b.pos,
                    dist: b.dist,
                    cigar: b.cigar,
                    candidates: b.candidates,
                    reverse: b.reverse,
                })
            })
            .collect();
        Ok((mappings, metrics))
    }

    /// Route one read (both orientations when revcomp handling is on)
    /// into [`ShardItem`]s, assigning globally sequential pair ids.
    fn route_oriented<'s>(
        &self,
        read: &'s ReadRecord,
        rc_seqs: &'s [Seq],
        next_pair: &mut u32,
        mut emit: impl FnMut(ShardItem<'s>),
    ) {
        let mut oriented: Vec<(&'s [u8], bool)> = Vec::with_capacity(2);
        oriented.push((read.seq.as_slice(), false));
        if self.cfg.handle_revcomp {
            oriented.push((rc_seqs[read.id as usize].as_slice(), true));
        }
        for &(seq, reverse) in &oriented {
            for pair in self.router.route(self.index, read.id, seq) {
                let pair_id = *next_pair;
                *next_pair += 1;
                emit(ShardItem {
                    pair_id,
                    read_id: read.id,
                    read_offset: pair.read_offset,
                    kmer: pair.kmer,
                    target: pair.target,
                    reverse,
                    seq,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::genome::synth::{ReadSimConfig, SynthConfig};
    use crate::params::{ETH, K, READ_LEN, SAT_AFFINE, W};
    use crate::runtime::{BitpalEngine, RustEngine};

    fn setup(n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
        let g = SynthConfig { len: 80_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        (idx, reads)
    }

    /// Small synthetic genomes have few high-frequency minimizers, so
    /// pin lowTh = 0 to exercise the crossbar path (on human-scale data
    /// the mean minimizer frequency is ~12 and the default lowTh = 3
    /// sends only 0.16 % of work to the RISC-V side).
    fn cfg() -> PipelineConfig {
        PipelineConfig {
            dart: crate::pim::DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        }
    }

    #[test]
    fn maps_simulated_reads_near_truth() {
        let (idx, reads) = setup(60);
        let mut p = Pipeline::new(&idx, cfg(), RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        assert_eq!(mappings.len(), 60);
        let mut near = 0;
        for r in &reads {
            if let Some(m) = &mappings[r.id as usize] {
                if (m.pos - r.truth_pos as i64).abs() <= 5 {
                    near += 1;
                }
            }
        }
        assert!(near >= 54, "near = {near}/60; metrics: {}", metrics.summary());
        assert!(metrics.linear_instances > 0);
        assert!(metrics.affine_instances > 0);
        assert_eq!(metrics.traceback_failures, 0);
    }

    #[test]
    fn cigar_and_distance_consistency() {
        let (idx, reads) = setup(30);
        let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
        let (mappings, _) = p.map_reads(&reads).unwrap();
        for m in mappings.into_iter().flatten() {
            assert_eq!(m.cigar.read_len() as usize, READ_LEN);
            assert!(m.dist <= 2 * ETH as i32 + 1 + SAT_AFFINE); // sane
            assert!(m.candidates >= 1);
        }
    }

    #[test]
    fn min_only_policy_reduces_affine_work() {
        let (idx, reads) = setup(40);
        let all = {
            let mut p = Pipeline::new(&idx, cfg(), RustEngine);
            p.map_reads(&reads).unwrap().1
        };
        let min_only = {
            let c = PipelineConfig { filter_policy: FilterPolicy::MinOnly, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            p.map_reads(&reads).unwrap().1
        };
        assert!(min_only.affine_instances <= all.affine_instances);
        assert!(min_only.affine_instances >= 1);
    }

    #[test]
    fn deterministic_across_runs() {
        let (idx, reads) = setup(25);
        let run = || {
            let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
            p.map_reads(&reads).unwrap().0
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            match (x, y) {
                (None, None) => {}
                (Some(x), Some(y)) => assert_eq!(
                    (x.pos, x.dist, x.cigar.to_string()),
                    (y.pos, y.dist, y.cigar.to_string())
                ),
                _ => panic!("mapping presence differs between runs"),
            }
        }
    }

    #[test]
    fn sharded_matches_single_thread_exactly() {
        let (idx, reads) = setup(40);
        let run = |threads: usize| {
            let c = PipelineConfig { threads, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            p.map_reads(&reads).unwrap()
        };
        let (m1, x1) = run(1);
        for threads in [2usize, 3, 4] {
            let (mt, xt) = run(threads);
            for (a, b) in m1.iter().zip(&mt) {
                match (a, b) {
                    (None, None) => {}
                    (Some(a), Some(b)) => assert_eq!(
                        (a.pos, a.dist, a.cigar.to_string(), a.candidates, a.reverse),
                        (b.pos, b.dist, b.cigar.to_string(), b.candidates, b.reverse),
                        "threads={threads}"
                    ),
                    _ => panic!("presence mismatch at threads={threads}"),
                }
            }
            assert_eq!(
                x1.invariant_counters(),
                xt.invariant_counters(),
                "workload counters must not depend on sharding (threads={threads})"
            );
        }
    }

    #[test]
    fn bitpal_engine_matches_rust_end_to_end() {
        let (idx, reads) = setup(40);
        let baseline = {
            // pin the baseline to the scalar single-threaded path: the
            // env defaults (DART_PIM_THREADS / DART_PIM_ENGINE) must not
            // be able to turn this into bitpal-vs-bitpal in CI
            let c = PipelineConfig { threads: 1, worker_engine: EngineKind::Rust, ..cfg() };
            let mut p = Pipeline::new(&idx, c, RustEngine);
            p.map_reads(&reads).unwrap().0
        };
        for threads in [1usize, 4] {
            let c = PipelineConfig { threads, worker_engine: EngineKind::Bitpal, ..cfg() };
            let mut p = Pipeline::new(&idx, c, BitpalEngine::new());
            let (m, _) = p.map_reads(&reads).unwrap();
            for (a, b) in baseline.iter().zip(&m) {
                match (a, b) {
                    (None, None) => {}
                    (Some(a), Some(b)) => assert_eq!(
                        (a.pos, a.dist, a.cigar.to_string(), a.candidates),
                        (b.pos, b.dist, b.cigar.to_string(), b.candidates),
                        "threads={threads}"
                    ),
                    _ => panic!("presence mismatch (threads={threads})"),
                }
            }
        }
    }

    #[test]
    fn sharded_handles_more_threads_than_work() {
        // more shards than minimizers: some workers receive nothing
        let (idx, reads) = setup(3);
        let c = PipelineConfig { threads: 16, ..cfg() };
        let mut p = Pipeline::new(&idx, c, RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        assert_eq!(mappings.len(), 3);
        assert_eq!(metrics.n_reads, 3);
    }

    #[test]
    fn sharded_empty_read_set() {
        let (idx, _) = setup(1);
        let c = PipelineConfig { threads: 4, ..cfg() };
        let mut p = Pipeline::new(&idx, c, RustEngine);
        let (mappings, metrics) = p.map_reads(&[]).unwrap();
        assert!(mappings.is_empty());
        assert_eq!(metrics.n_reads, 0);
    }

    #[test]
    fn metrics_bridge_to_simulator() {
        let (idx, reads) = setup(30);
        let mut p = Pipeline::new(&idx, PipelineConfig::default(), RustEngine);
        let (_, metrics) = p.map_reads(&reads).unwrap();
        let counts = metrics.to_sim_counts();
        let report = crate::simulator::report::build_report(
            &counts,
            &p.cfg.dart,
            crate::pim::xbar_sim::CostSource::PaperTable4,
            crate::simulator::TimingMode::PaperSerial,
        );
        assert!(report.exec_time_s > 0.0);
        assert!(report.energy.total() > 0.0);
        assert!(report.throughput() > 0.0);
    }
}
