//! Hand-rolled CLI (this offline build has no clap — DESIGN.md §6).
//!
//! Subcommands:
//!   synth     — generate a synthetic reference + read set
//!   map       — run the DART-PIM pipeline end to end
//!   serve     — long-lived mapping daemon: index loaded once, many
//!               concurrent FASTQ sessions over a Unix socket (SERVING.md)
//!   evaluate  — map + accuracy vs oracle and simulated truth
//!   simulate  — full-system simulation + Eq. 6/7 report (+ paper-scale
//!               projection)
//!   figures   — regenerate the paper's tables/figures
//!   crossbar  — single-crossbar simulator (Table IV, row allocation)
//!   config    — print the architecture configuration (Tables II/III)

use std::collections::HashMap;
use std::io::{self, BufRead, Write};

use anyhow::{bail, Context, Result};

use crate::coordinator::{default_threads, FilterPolicy, PairingConfig, Pipeline, PipelineConfig};
use crate::eval::figures;
use crate::genome::fasta::{load_fasta, save_fasta, FastaRecord};
use crate::genome::fastq::{save_fastq, FastqRecord, FastqStream, PairedFastqStream};
use crate::genome::mutate::MutateConfig;
use crate::genome::synth::{ReadSimConfig, SynthConfig};
use crate::genome::ReadRecord;
use crate::index::{sniff_format, IndexBackend, IndexFormat, IndexRef, MappedIndex, MinimizerIndex};
use crate::params::{K, READ_LEN, W};
use crate::pim::xbar_sim::{self, CostSource};
use crate::pim::DartPimConfig;
use crate::runtime::{BitpalEngine, EngineKind, RustEngine, SimdMode};
#[cfg(feature = "pjrt")]
use crate::runtime::XlaEngine;
use crate::simulator::report::{build_report, scale_counts};
use crate::simulator::{FullSystemSim, TimingMode};
use crate::util::json::Json;

/// Parsed `--key value` options + positionals.
pub struct Args {
    /// The subcommand (first argv token; "help" when absent).
    pub cmd: String,
    // dart-analyze: allow(determinism): accessed only through keyed
    // get()/insert()/remove() (`Args::get` and the paired-end rewrite in
    // cmd_map) — never iterated, so option-map order cannot influence
    // parsing results or any emitted byte.
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name) into command + options +
    /// flags. Rejects bare positionals.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let cmd = argv.first().cloned().unwrap_or_else(|| "help".into());
        let mut opts = HashMap::new();
        let mut flags = Vec::new();
        let mut i = 1;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    opts.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.push(key.to_string());
                    i += 1;
                }
            } else {
                bail!("unexpected positional argument {a:?}");
            }
        }
        Ok(Args { cmd, opts, flags })
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    /// `--key` as an integer, with a default when absent.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer")),
        }
    }

    /// `--key` as a float, with a default when absent.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number")),
        }
    }

    /// True when the boolean flag `--key` was passed.
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// The `dart-pim help` text.
pub const USAGE: &str = "\
dart-pim — DNA read mapping with a digital-PIM model (DART-PIM reproduction)

USAGE: dart-pim <command> [--key value ...]

COMMANDS
  synth     --out-dir D [--len 2000000] [--reads 10000] [--seed 1]
            [--snp-rate 0.001] [--sub-rate 0.004]
            [--paired] [--insert-mean 350] [--insert-sd 30]
  index     --ref R.fasta --out index.bin [--read-len 150]
            [--index-format v1|v2] [--shards 16]
            (or --from old.bin to re-encode an existing index)
  map       --ref R.fasta --reads R.fastq|- [--engine xla|rust|bitpal]
            (or --index index.bin instead of --ref)
            [--index-format v1|v2]
            [--reads2 R2.fastq | --interleaved]
            [--insert-min 50] [--insert-max 1000] [--no-rescue]
            [--max-reads 25000] [--low-th 3] [--batch 256] [--min-only]
            [--revcomp] [--threads 1] [--simd u64|wide|off]
            [--stream-epoch 2048] [--out mappings.tsv]
  serve     --socket /path/daemon.sock | --tcp HOST:PORT
            (--ref R.fasta [--read-len 150] | --index index.bin)
            [--index-format v1|v2]
            [--engine rust|bitpal] [--threads 1] [--stream-epoch 2048]
            [--max-reads 25000] [--low-th 3] [--batch 256] [--min-only]
            [--revcomp] [--insert-min 50] [--insert-max 1000] [--no-rescue]
            [--simd u64|wide|off]
  evaluate  --ref R.fasta --reads R.fastq --truth truth.tsv
            [--reads2 R2.fastq | --interleaved]
            [--engine xla|rust|bitpal] [--tolerance 5] [--threads 1]
            [--simd u64|wide|off]
  simulate  --ref R.fasta --reads R.fastq|- [--engine rust|bitpal]
            [--reads2 R2.fastq | --interleaved]
            [--max-reads 25000] [--low-th 3] [--scale 389000000]
            [--batched-affine] [--constructive] [--threads 1]
            [--simd u64|wide|off]
  figures   [--fig 8|9|10a|10b|10c|table4|motivation|headline|all]
  crossbar
  config

`map` and `simulate` stream their FASTQ: `--reads -` reads stdin, and
memory stays bounded (O(epoch + threads x batch), not O(input)) no
matter how large the read set is — TSV rows are emitted as reads
finish. `--threads N` shards work across N worker threads
(minimizer-hash partition; output is byte-identical for any N). The
default is 1, or the DART_PIM_THREADS environment variable when set.

PAIRED-END: `--reads2 R2.fastq` zips two parallel FASTQ files;
`--interleaved` reads alternating R1/R2 records from one source
(including stdin: `--interleaved --reads -`). Mates resolve together:
proper pairs (FR orientation, insert within --insert-min/--insert-max)
win, otherwise each mate keeps its single-end decision, and a mate with
no candidates is rescued near its partner unless --no-rescue. Paired
mapping implies --revcomp. The paired map TSV has columns
  pair_id  mate(1|2)  pos  strand  dist  cigar  candidates  pair
where pair is proper|single|rescued; rows appear only for mapped mates.
Output stays byte-identical for every --threads/--engine/epoch setting.

ENGINES: `rust` is the scalar reference engine; `bitpal` computes the
linear filter AND the affine stage bit-parallel (one instance per bit
lane, identical numerics) and, like rust, is Send — both compose with
--threads N. `--simd` picks the bitpal lane width: `u64` forces plain
64-bit machine words, `wide` (the default) runtime-detects the widest
SIMD register (AVX-512 512-bit / AVX2 256-bit / 128-bit otherwise),
`off` falls back to the scalar per-instance loops. DART_PIM_SIMD sets
the default; output bytes are identical in every mode (determinism
invariant 8). DART_PIM_ENGINE sets the default worker engine.
--engine xla is always single-threaded (the PJRT client cannot be
shared across threads); combining it with --threads N > 1 warns and
runs with 1.

INDEX FORMATS: v1 (DARTPIM1) is the original length-prefixed stream,
deserialized into a heap-resident table on load. v2 (DARTPIM2) lays the
index out in fixed little-endian sections — reference, per-shard
postings directory, sorted per-shard slabs — so `map` and `serve` mmap
the file and answer lookups zero-copy from the page cache: resident
memory stays far below the on-disk index size. `index --index-format
v2` builds it in two streaming passes (bounded memory); `index --from
old.bin --index-format v2 --out new.bin` re-encodes an existing index
in either direction. `map` and `serve` auto-detect the format from the
file magic; `--index-format` forces a backend (v1 = heap, loading a v2
file through one-shot conversion; v2 = mmap, refusing v1 files). The
backend never changes output bytes (determinism invariant 9).

SERVE: `serve` keeps the index resident and maps many concurrent FASTQ
streams over one worker pool. Each connection is a session: handshake
`DART/1 mode=<se|pe> [framing=<framed|raw>]`, stream FASTQ (interleaved
pairs for pe), receive exactly the TSV bytes `map` would emit for the
same input and flags (determinism invariant 7), plus a per-session
metrics line. SIGTERM drains gracefully: accepting stops, in-flight
sessions run to completion, the daemon exits 0. SERVING.md specifies
the wire protocol and failure modes and walks a socat example.
";

/// Entry point; returns the process exit code.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.cmd.as_str() {
        "synth" => cmd_synth(&args),
        "index" => cmd_index(&args),
        "map" => cmd_map(&args),
        "serve" => cmd_serve(&args),
        "evaluate" => cmd_evaluate(&args),
        "simulate" => cmd_simulate(&args),
        "figures" => cmd_figures(&args),
        "crossbar" => cmd_crossbar(),
        "config" => cmd_config(),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn dart_config(args: &Args) -> Result<DartPimConfig> {
    Ok(DartPimConfig {
        max_reads: args.get_usize("max-reads", 25_000)?,
        low_th: args.get_usize("low-th", 3)?,
        ..Default::default()
    })
}

fn cmd_synth(args: &Args) -> Result<()> {
    let out_dir = std::path::PathBuf::from(args.get("out-dir").context("--out-dir required")?);
    std::fs::create_dir_all(&out_dir)?;
    let len = args.get_usize("len", 2_000_000)?;
    let n_reads = args.get_usize("reads", 10_000)?;
    let seed = args.get_usize("seed", 1)? as u64;
    let genome = SynthConfig { len, seed, ..Default::default() }.generate();
    let donor = MutateConfig {
        snp_rate: args.get_f64("snp-rate", 1e-3)?,
        seed: seed ^ 0x5eed,
        ..Default::default()
    }
    .apply(&genome);
    let paired = args.flag("paired");
    let reads = if paired {
        // --reads counts pairs in paired mode (2x records on disk)
        crate::genome::synth::PairSimConfig {
            n_pairs: n_reads,
            insert_mean: args.get_usize("insert-mean", 350)?,
            insert_sd: args.get_usize("insert-sd", 30)?,
            sub_rate: args.get_f64("sub-rate", 0.004)?,
            seed: seed ^ 0x0EAD,
            ..Default::default()
        }
        .simulate(&donor.seq, donor.mapper())
    } else {
        ReadSimConfig {
            n_reads,
            sub_rate: args.get_f64("sub-rate", 0.004)?,
            seed: seed ^ 0x0EAD,
            ..Default::default()
        }
        .simulate(&donor.seq, donor.mapper())
    };

    save_fasta(
        out_dir.join("ref.fasta"),
        &[FastaRecord { name: "synthetic".into(), seq: genome }],
    )?;
    if paired {
        // three equivalent paired layouts: R1/R2 files + one interleaved
        let rec = |r: &ReadRecord| {
            let mate = r.id % 2 + 1;
            FastqRecord::with_const_qual(
                format!("pair{}/{mate}", r.id / 2),
                r.seq.clone(),
                b'I',
            )
        };
        let r1: Vec<FastqRecord> =
            reads.iter().filter(|r| r.id % 2 == 0).map(&rec).collect();
        let r2: Vec<FastqRecord> =
            reads.iter().filter(|r| r.id % 2 == 1).map(&rec).collect();
        let il: Vec<FastqRecord> = reads.iter().map(&rec).collect();
        save_fastq(out_dir.join("reads_1.fastq"), &r1)?;
        save_fastq(out_dir.join("reads_2.fastq"), &r2)?;
        save_fastq(out_dir.join("reads_interleaved.fastq"), &il)?;
    } else {
        let records: Vec<FastqRecord> = reads
            .iter()
            .map(|r| FastqRecord::with_const_qual(format!("read{}", r.id), r.seq.clone(), b'I'))
            .collect();
        save_fastq(out_dir.join("reads.fastq"), &records)?;
    }
    let mut truth = String::from("read_id\ttruth_pos\terrors\n");
    for r in &reads {
        truth.push_str(&format!("{}\t{}\t{}\n", r.id, r.truth_pos, r.errors));
    }
    std::fs::write(out_dir.join("truth.tsv"), truth)?;
    println!(
        "wrote {}: {} bp reference ({} SNPs, {} indels in donor), {} {}",
        out_dir.display(),
        len,
        donor.n_snps,
        donor.n_indels,
        n_reads,
        if paired { "read pairs" } else { "reads" }
    );
    Ok(())
}

fn cmd_index(args: &Args) -> Result<()> {
    let out = args.get("out").context("--out required")?;
    let format = index_format_from_args(args)?.unwrap_or(IndexFormat::V1);
    let n_shards = args.get_usize("shards", crate::index::v2::DEFAULT_V2_SHARDS)?;
    if let Some(from) = args.get("from") {
        return convert_index(from, out, format, n_shards);
    }
    let ref_path = args.get("ref").context("--ref or --from required")?;
    let read_len = args.get_usize("read-len", READ_LEN)?;
    let reference = load_reference(ref_path)?;
    match format {
        IndexFormat::V1 => {
            let index = MinimizerIndex::build(reference, K, W, read_len);
            crate::index::save_index(out, &index)?;
            let stats = index.stats(3);
            println!(
                "indexed {} bp -> {} (v1, {} minimizers, {} occurrences)",
                index.reference.len(),
                out,
                stats.n_minimizers,
                stats.n_occurrences
            );
        }
        IndexFormat::V2 => {
            // two streaming passes over the reference: peak memory is
            // O(reference + largest shard), never the whole posting
            // table (ISSUE: index a genome larger than RAM allows)
            let stats = crate::index::build_index_v2(out, &reference, K, W, read_len, n_shards)
                .with_context(|| format!("writing v2 index {out}"))?;
            println!(
                "indexed {} bp -> {} (v2, {} shards, {} minimizers, {} occurrences)",
                reference.len(),
                out,
                n_shards,
                stats.n_entries,
                stats.n_positions
            );
        }
    }
    Ok(())
}

/// `index --from old --out new [--index-format F]`: re-encode an
/// existing index in either direction (v1->v2, v2->v1, or a
/// same-format rewrite). The postings survive byte-exactly — both
/// writers order every shard by key, so converting and mapping again
/// cannot change output bytes (determinism invariant 9).
fn convert_index(from: &str, out: &str, format: IndexFormat, n_shards: usize) -> Result<()> {
    let src_format = sniff_format(from).with_context(|| format!("sniffing index {from}"))?;
    let heap = match src_format {
        IndexFormat::V1 => crate::index::load_index(from)
            .with_context(|| format!("loading v1 index {from}"))?,
        IndexFormat::V2 => MappedIndex::open(from)
            .with_context(|| format!("mapping v2 index {from}"))?
            .to_heap(),
    };
    match format {
        IndexFormat::V1 => crate::index::save_index(out, &heap)
            .with_context(|| format!("writing v1 index {out}"))?,
        IndexFormat::V2 => crate::index::save_index_v2(out, &heap, n_shards)
            .with_context(|| format!("writing v2 index {out}"))?,
    }
    println!(
        "converted {from} ({}) -> {out} ({}, {} minimizers)",
        src_format.as_str(),
        format.as_str(),
        heap.n_minimizers()
    );
    Ok(())
}

/// The `--index-format` selection: `Some` when the user forces a
/// format, `None` for auto-detection (file magic on load, v1 on build).
fn index_format_from_args(args: &Args) -> Result<Option<IndexFormat>> {
    match args.get("index-format") {
        None => Ok(None),
        Some("v1") => Ok(Some(IndexFormat::V1)),
        Some("v2") => Ok(Some(IndexFormat::V2)),
        Some(other) => bail!("unknown --index-format {other:?} (v1|v2)"),
    }
}

/// Load the first sequence of a reference FASTA, with the file path in
/// every error (including the empty-FASTA case, which used to panic).
fn load_reference(ref_path: &str) -> Result<crate::genome::encode::Seq> {
    let fasta =
        load_fasta(ref_path).with_context(|| format!("reading reference FASTA {ref_path}"))?;
    Ok(fasta
        .into_iter()
        .next()
        .with_context(|| format!("reference FASTA {ref_path} contains no sequences"))?
        .seq)
}

/// Open `--reads` as a buffered byte stream; `-` streams stdin.
fn open_reads(path: &str) -> Result<Box<dyn BufRead>> {
    if path == "-" {
        Ok(Box::new(io::BufReader::new(io::stdin())))
    } else {
        let f = std::fs::File::open(path).with_context(|| format!("opening FASTQ {path}"))?;
        Ok(Box::new(io::BufReader::new(f)))
    }
}

/// Start streaming single-end FASTQ from any byte source (a file,
/// stdin, a daemon session's socket): peeks the first record to fix the
/// read length (which determines the index geometry), then yields
/// `ReadRecord`s with dense sequential ids. Parser memory is O(1) in
/// the stream length; a length-divergent or malformed record errors
/// with its ordinal and name. `label` names the source in every error.
pub(crate) fn stream_reads_from(
    reader: Box<dyn BufRead>,
    label: String,
) -> Result<(usize, impl Iterator<Item = Result<ReadRecord>>)> {
    let mut stream = FastqStream::new(reader);
    let first = match stream.next() {
        None => bail!("empty {label}"),
        Some(r) => r.with_context(|| format!("reading {label}"))?,
    };
    let read_len = first.seq.len();
    anyhow::ensure!(read_len > 0, "first record of {label} has an empty sequence");
    let iter = std::iter::once(Ok(first))
        .chain(stream.map(move |r| r.with_context(|| format!("reading {label}"))))
        .enumerate()
        .map(move |(i, r)| {
            let rec = r?;
            anyhow::ensure!(
                rec.seq.len() == read_len,
                "FASTQ record #{} ({:?}) is {} bp; the pipeline requires a uniform read \
                 length ({} bp, set by the first record)",
                i + 1,
                rec.name,
                rec.seq.len(),
                read_len
            );
            Ok(ReadRecord { id: i as u32, seq: rec.seq, truth_pos: 0, errors: 0 })
        });
    Ok((read_len, iter))
}

/// [`stream_reads_from`] over the `--reads` path (`-` = stdin).
fn stream_reads(path: &str) -> Result<(usize, impl Iterator<Item = Result<ReadRecord>>)> {
    stream_reads_from(open_reads(path)?, format!("FASTQ {path}"))
}

/// True when the arguments select paired-end input, after validating
/// that the paired flags are coherent.
fn paired_mode(args: &Args) -> Result<bool> {
    let two_files = args.get("reads2").is_some();
    let interleaved = args.flag("interleaved");
    anyhow::ensure!(
        !(two_files && interleaved),
        "--reads2 and --interleaved are mutually exclusive; pass one paired source"
    );
    if two_files && args.get("reads") == Some("-") && args.get("reads2") == Some("-") {
        bail!(
            "cannot stream both mates from stdin; interleave the pairs and pass \
             `--interleaved --reads -`"
        );
    }
    Ok(two_files || interleaved)
}

/// Start streaming a paired source (`--reads2` two-file zip or
/// `--interleaved`): peeks the first pair to fix the read length, then
/// yields `ReadRecord`s in the paired layout (R1 of pair `i` at id `2i`,
/// R2 at `2i + 1`). Structural errors (unmatched mate, mate-name
/// mismatch, length divergence) name the 1-based pair ordinal and the
/// read name.
fn stream_paired_reads(
    args: &Args,
) -> Result<(usize, Box<dyn Iterator<Item = Result<ReadRecord>>>)> {
    let r1_path = args.get("reads").context("--reads required")?;
    let label = if args.flag("interleaved") {
        format!("interleaved FASTQ {r1_path}")
    } else {
        format!("paired FASTQ {r1_path} + {}", args.get("reads2").unwrap_or("?"))
    };
    let stream: Box<dyn Iterator<Item = io::Result<(FastqRecord, FastqRecord)>>> =
        if args.flag("interleaved") {
            Box::new(PairedFastqStream::interleaved(open_reads(r1_path)?))
        } else {
            let r2_path = args.get("reads2").context("--reads2 required")?;
            Box::new(PairedFastqStream::two_files(open_reads(r1_path)?, open_reads(r2_path)?))
        };
    stream_paired_from(stream, label)
}

/// Start streaming an already-paired record source (a two-file zip, an
/// interleaved file, or a daemon session's interleaved socket stream):
/// peeks the first pair to fix the read length, then yields
/// `ReadRecord`s in the paired layout (R1 of pair `i` at id `2i`, R2 at
/// `2i + 1`). Structural errors (unmatched mate, mate-name mismatch,
/// length divergence) name the 1-based pair ordinal and the read name;
/// `label` names the source in every error.
pub(crate) fn stream_paired_from(
    mut stream: Box<dyn Iterator<Item = io::Result<(FastqRecord, FastqRecord)>>>,
    label: String,
) -> Result<(usize, Box<dyn Iterator<Item = Result<ReadRecord>>>)> {
    let first = match stream.next() {
        None => bail!("empty {label}"),
        Some(p) => p.with_context(|| format!("reading {label}"))?,
    };
    let read_len = first.0.seq.len();
    anyhow::ensure!(read_len > 0, "first record of {label} has an empty sequence");
    let label_owned = label.clone();
    let iter = std::iter::once(Ok(first))
        .chain(stream.map(move |p| p.with_context(|| format!("reading {label_owned}"))))
        .enumerate()
        .flat_map(move |(i, p)| match p {
            Err(e) => vec![Err(e)],
            Ok((r1, r2)) => {
                let check = |mate: u8, rec: &FastqRecord| -> Result<()> {
                    anyhow::ensure!(
                        rec.seq.len() == read_len,
                        "read pair #{} (R{} {:?}) is {} bp; the pipeline requires a uniform \
                         read length ({} bp, set by the first record)",
                        i + 1,
                        mate,
                        rec.name,
                        rec.seq.len(),
                        read_len
                    );
                    Ok(())
                };
                if let Err(e) = check(1, &r1).and_then(|_| check(2, &r2)) {
                    return vec![Err(e)];
                }
                vec![
                    Ok(ReadRecord { id: 2 * i as u32, seq: r1.seq, truth_pos: 0, errors: 0 }),
                    Ok(ReadRecord { id: 2 * i as u32 + 1, seq: r2.seq, truth_pos: 0, errors: 0 }),
                ]
            }
        });
    Ok((read_len, Box::new(iter)))
}

/// Start streaming whichever input shape the arguments select: the
/// single-end `--reads` stream, or the paired layout from
/// `--reads2`/`--interleaved`. Returns (read_len, paired?, stream).
fn stream_input(
    args: &Args,
) -> Result<(usize, bool, Box<dyn Iterator<Item = Result<ReadRecord>>>)> {
    if paired_mode(args)? {
        let (read_len, iter) = stream_paired_reads(args)?;
        Ok((read_len, true, iter))
    } else {
        let reads_path = args.get("reads").context("--reads required")?;
        let (read_len, iter) = stream_reads(reads_path)?;
        Ok((read_len, false, Box::new(iter)))
    }
}

/// Open an on-disk index as the backend `--index-format` selects (or
/// the file's own format when the flag is absent): v1 deserializes
/// into the heap, v2 memory-maps the file and serves lookups zero-copy.
/// Forcing `v1` on a v2 file loads it through a one-shot heap
/// conversion; forcing `v2` on a v1 file errors (convert it first).
fn load_backend(args: &Args, idx_path: &str) -> Result<IndexBackend> {
    let forced = index_format_from_args(args)?;
    let on_disk =
        sniff_format(idx_path).with_context(|| format!("sniffing index {idx_path}"))?;
    Ok(match (forced.unwrap_or(on_disk), on_disk) {
        (IndexFormat::V1, IndexFormat::V1) => IndexBackend::Heap(
            crate::index::load_index(idx_path)
                .with_context(|| format!("loading index {idx_path}"))?,
        ),
        (IndexFormat::V1, IndexFormat::V2) => IndexBackend::Heap(
            MappedIndex::open(idx_path)
                .with_context(|| format!("mapping index {idx_path}"))?
                .to_heap(),
        ),
        (IndexFormat::V2, IndexFormat::V2) => IndexBackend::Mapped(
            MappedIndex::open(idx_path)
                .with_context(|| format!("mapping index {idx_path}"))?,
        ),
        (IndexFormat::V2, IndexFormat::V1) => bail!(
            "{idx_path} is a v1 index; the mapped backend needs the DARTPIM2 layout \
             (convert with `index --from {idx_path} --index-format v2 --out NEW`)"
        ),
    })
}

/// Load the prebuilt index (`--index`) as a backend, or build a heap
/// index from `--ref`, checked against the read stream's geometry.
/// Whichever backend comes out, the mapping output bytes are identical
/// (determinism invariant 9).
fn load_or_build_backend(args: &Args, read_len: usize) -> Result<IndexBackend> {
    let backend = if let Some(idx_path) = args.get("index") {
        load_backend(args, idx_path)?
    } else {
        anyhow::ensure!(
            index_format_from_args(args)? != Some(IndexFormat::V2),
            "--index-format v2 needs an on-disk index (--index FILE); build one with \
             `index --ref ... --index-format v2` first"
        );
        let ref_path = args.get("ref").context("--ref or --index required")?;
        let reference = load_reference(ref_path)?;
        IndexBackend::Heap(MinimizerIndex::build(reference, K, W, read_len))
    };
    anyhow::ensure!(
        backend.view().read_len() == read_len,
        "index was built for {} bp reads, FASTQ has {} bp",
        backend.view().read_len(),
        read_len
    );
    Ok(backend)
}

/// Load the prebuilt index (`--index`) or build one from `--ref`,
/// checked against the read stream's geometry — always heap-resident
/// (v2 files convert on load), for subcommands whose internals hold a
/// concrete [`MinimizerIndex`] (`evaluate`, `simulate`).
fn load_or_build_index(args: &Args, read_len: usize) -> Result<MinimizerIndex> {
    match load_or_build_backend(args, read_len)? {
        IndexBackend::Heap(idx) => Ok(idx),
        IndexBackend::Mapped(mapped) => Ok(mapped.to_heap()),
    }
}

/// Load the reference (or prebuilt index) and the **whole** read set —
/// the collect wrapper over the internal read stream for subcommands
/// that genuinely need random access (`evaluate` joins against a truth
/// table). `map`/`simulate` stream instead. Honors the paired input
/// flags (`--reads2`/`--interleaved`): paired sources collect in the
/// paired id layout.
pub fn load_inputs(args: &Args) -> Result<(MinimizerIndex, Vec<ReadRecord>)> {
    let (read_len, _, reads) = stream_input(args)?;
    let reads: Vec<ReadRecord> = reads.collect::<Result<_>>()?;
    let index = load_or_build_index(args, read_len)?;
    Ok((index, reads))
}

fn load_truth(path: &str, n: usize) -> Result<Vec<u32>> {
    let text = std::fs::read_to_string(path)?;
    let mut truth = vec![0u32; n];
    for line in text.lines().skip(1) {
        let mut it = line.split('\t');
        let id: usize = it.next().context("truth id")?.parse()?;
        let pos: u32 = it.next().context("truth pos")?.parse()?;
        if id < n {
            truth[id] = pos;
        }
    }
    Ok(truth)
}

/// Paired-end arbitration policy from the CLI flags — `map` applies it
/// when the input is paired; `serve` applies it to every `mode=pe`
/// session, so both front ends resolve pairs under identical policy.
pub(crate) fn pairing_from_args(args: &Args) -> Result<PairingConfig> {
    let insert_min = args.get_usize("insert-min", 50)? as u32;
    let insert_max = args.get_usize("insert-max", 1000)? as u32;
    anyhow::ensure!(
        insert_min <= insert_max,
        "--insert-min {insert_min} exceeds --insert-max {insert_max}"
    );
    Ok(PairingConfig { insert_min, insert_max, rescue: !args.flag("no-rescue") })
}

/// The bitpal SIMD lane mode from `--simd` (falling back to
/// `DART_PIM_SIMD`, then `wide`). Shared by every front end that
/// constructs an engine, so the flag means the same thing everywhere —
/// and, per determinism invariant 8, never changes output bytes.
pub(crate) fn simd_from_args(args: &Args) -> Result<SimdMode> {
    match args.get("simd") {
        None => Ok(crate::runtime::default_simd_mode()),
        Some(name) => SimdMode::from_name(name)
            .with_context(|| format!("unknown --simd {name:?} (u64|wide|off)")),
    }
}

/// The [`PipelineConfig`] built from the CLI flags `map` and `serve`
/// share. Producer-side policy (`handle_revcomp`, `pairing`) stays at
/// its single-end defaults; the caller layers it per run (`map`) or per
/// session (`serve`). Constructing both front ends' configs through
/// this one function is what keeps `serve` in flag-for-flag lockstep
/// with `map` — the precondition for determinism invariant 7.
pub(crate) fn shared_pipeline_config(
    args: &Args,
    worker_engine: EngineKind,
) -> Result<PipelineConfig> {
    Ok(PipelineConfig {
        dart: dart_config(args)?,
        batch_size: args.get_usize("batch", 256)?,
        filter_policy: if args.flag("min-only") {
            FilterPolicy::MinOnly
        } else {
            FilterPolicy::AllPassing
        },
        handle_revcomp: false,
        threads: args.get_usize("threads", default_threads())?,
        worker_engine,
        simd: simd_from_args(args)?,
        // emission/memory granularity only — never changes output bytes
        // (tests/golden_e2e.rs sweeps it against the default)
        stream_epoch: args
            .get_usize("stream-epoch", crate::coordinator::pipeline::STREAM_EPOCH_READS)?
            .max(1),
        pairing: None,
    })
}

/// Write the mapping TSV header — one schema for single-end runs, one
/// for paired (shared by `map`'s file sink and every `serve` session,
/// so the two paths cannot drift apart byte-wise).
pub(crate) fn write_tsv_header(out: &mut dyn Write, paired: bool) -> io::Result<()> {
    if paired {
        out.write_all(b"pair_id\tmate\tpos\tstrand\tdist\tcigar\tcandidates\tpair\n")
    } else {
        out.write_all(b"read_id\tpos\tstrand\tdist\tcigar\tcandidates\n")
    }
}

/// Write one mapping decision as a TSV row (see [`write_tsv_header`]
/// for the schema; rows appear only for mapped reads/mates).
pub(crate) fn write_tsv_row(
    out: &mut dyn Write,
    paired: bool,
    m: &crate::coordinator::FinalMapping,
) -> io::Result<()> {
    if paired {
        writeln!(
            out,
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}",
            m.read_id / 2,
            m.read_id % 2 + 1,
            m.pos,
            if m.reverse { '-' } else { '+' },
            m.dist,
            m.cigar,
            m.candidates,
            m.pair.as_str()
        )
    } else {
        writeln!(
            out,
            "{}\t{}\t{}\t{}\t{}\t{}",
            m.read_id,
            m.pos,
            if m.reverse { '-' } else { '+' },
            m.dist,
            m.cigar,
            m.candidates
        )
    }
}

/// Stream a read set through the pipeline on the `--engine` selected by
/// the CLI; per-read decisions leave through `sink` in read order as
/// they become final (the single engine-dispatch site — `map` streams
/// TSV rows, `evaluate` collects via [`run_pipeline`]).
fn run_pipeline_stream<I, R, S>(
    args: &Args,
    index: IndexRef<'_>,
    reads: I,
    sink: S,
) -> Result<crate::coordinator::metrics::Metrics>
where
    I: IntoIterator<Item = Result<R>>,
    R: std::borrow::Borrow<ReadRecord>,
    S: FnMut(u32, Option<crate::coordinator::FinalMapping>) -> Result<()>,
{
    anyhow::ensure!(
        index.read_len() == READ_LEN || args.get("engine") != Some("xla"),
        "the AOT artifacts target {}bp reads; use --engine rust or bitpal for other lengths",
        READ_LEN
    );
    let paired = paired_mode(args)?;
    let mut cfg = shared_pipeline_config(args, crate::runtime::default_engine())?;
    // paired mapping needs both strands: R2 is sequenced from the
    // opposite strand of its fragment
    cfg.handle_revcomp = args.flag("revcomp") || paired;
    cfg.pairing = if paired { Some(pairing_from_args(args)?) } else { None };
    // Default engine: the PJRT path when it is compiled in, else the
    // DART_PIM_ENGINE host engine (identical numerics; see the
    // engine_parity and engine_parity_bitpal suites).
    let default_engine =
        if cfg!(feature = "pjrt") { "xla" } else { crate::runtime::default_engine().name() };
    match args.get("engine").unwrap_or(default_engine) {
        "rust" => {
            let cfg = PipelineConfig { worker_engine: EngineKind::Rust, ..cfg };
            Pipeline::new(index, cfg, RustEngine).map_stream(reads, sink)
        }
        "bitpal" => {
            // bit-parallel filter engine; Send, so worker shards run it
            // too and --threads N composes
            let cfg = PipelineConfig { worker_engine: EngineKind::Bitpal, ..cfg };
            let engine = BitpalEngine::with_mode(cfg.simd);
            Pipeline::new(index, cfg, engine).map_stream(reads, sink)
        }
        #[cfg(feature = "pjrt")]
        "xla" => {
            if cfg.threads > 1 {
                // worker shards own RustEngines (the PJRT client is not
                // Send); don't let the banner claim PJRT ran the work
                eprintln!(
                    "--engine xla is single-threaded (PJRT client); \
                     ignoring --threads {} and running on one thread",
                    cfg.threads
                );
            }
            let cfg = PipelineConfig { threads: 1, ..cfg };
            let engine = XlaEngine::load_default()?;
            eprintln!(
                "engine: xla (PJRT {}, {} artifacts)",
                engine.platform(),
                engine.manifest().artifacts.len()
            );
            Pipeline::new(index, cfg, engine).map_stream(reads, sink)
        }
        #[cfg(not(feature = "pjrt"))]
        "xla" => bail!(
            "this build has no XLA/PJRT support (rebuild with `--features pjrt`); \
             use --engine rust or --engine bitpal"
        ),
        other => bail!("unknown engine {other:?} (xla|rust|bitpal)"),
    }
}

/// Collect wrapper over [`run_pipeline_stream`] for subcommands that
/// post-process the whole mapping vector (`evaluate`).
fn run_pipeline(
    args: &Args,
    index: &MinimizerIndex,
    reads: &[ReadRecord],
) -> Result<(Vec<Option<crate::coordinator::FinalMapping>>, crate::coordinator::metrics::Metrics)> {
    let mut out = Vec::with_capacity(reads.len());
    let metrics = run_pipeline_stream(args, index.into(), reads.iter().map(Ok), |_, m| {
        out.push(m);
        Ok(())
    })?;
    Ok((out, metrics))
}

fn cmd_map(args: &Args) -> Result<()> {
    let (read_len, paired, reads) = stream_input(args)?;
    let backend = load_or_build_backend(args, read_len)?;
    let index = backend.view();
    let out_path = args.get("out");
    // write through a `.tmp` sibling so a mid-stream failure (malformed
    // FASTQ record, worker error) never leaves a truncated TSV at the
    // requested path — the rename happens only after a clean flush
    let tmp_path = out_path.map(|p| format!("{p}.tmp"));
    let mut out: Box<dyn Write> = match &tmp_path {
        Some(tmp) => {
            let f = std::fs::File::create(tmp).with_context(|| format!("creating {tmp}"))?;
            Box::new(io::BufWriter::new(f))
        }
        None => Box::new(io::BufWriter::new(io::stdout())),
    };
    // streaming TSV emitter: rows leave as epochs complete, so memory
    // stays O(epoch + threads x batch) no matter the FASTQ size (stdin
    // included); row order and bytes are identical for every --threads
    // and --engine setting
    let result = (|| -> Result<crate::coordinator::metrics::Metrics> {
        write_tsv_header(&mut out, paired)?;
        let metrics = run_pipeline_stream(args, index, reads, |_, m| {
            if let Some(m) = m {
                write_tsv_row(&mut out, paired, &m)?;
            }
            Ok(())
        })?;
        out.flush()?;
        Ok(metrics)
    })();
    drop(out);
    match result {
        Ok(metrics) => {
            if let (Some(path), Some(tmp)) = (out_path, &tmp_path) {
                std::fs::rename(tmp, path)
                    .with_context(|| format!("renaming {tmp} to {path}"))?;
            }
            eprintln!("{}", metrics.summary());
            if let Some(path) = out_path {
                eprintln!("wrote {path}");
            }
            Ok(())
        }
        Err(e) => {
            if let Some(tmp) = &tmp_path {
                let _ = std::fs::remove_file(tmp);
            }
            Err(e)
        }
    }
}

/// `serve`: bring up the long-lived mapping daemon (SERVING.md). The
/// index loads once; every connection becomes a session multiplexed
/// onto one shared worker pool.
#[cfg(unix)]
fn cmd_serve(args: &Args) -> Result<()> {
    let engine_name = args.get("engine").unwrap_or(crate::runtime::default_engine().name());
    let engine = EngineKind::from_name(engine_name).with_context(|| {
        format!(
            "serve shards sessions across thread-constructible engines \
             (rust|bitpal), not {engine_name:?}"
        )
    })?;
    let mut cfg = shared_pipeline_config(args, engine)?;
    cfg.threads = cfg.threads.max(1);
    // The daemon fixes the read length up front (it determines the index
    // geometry); sessions whose streams diverge are rejected at intake.
    let backend = if let Some(idx_path) = args.get("index") {
        let backend = load_backend(args, idx_path)?;
        if let Some(rl) = args.get("read-len") {
            let rl: usize = rl.parse().context("--read-len expects an integer")?;
            anyhow::ensure!(
                backend.view().read_len() == rl,
                "index {idx_path} was built for {} bp reads, --read-len says {rl}",
                backend.view().read_len()
            );
        }
        backend
    } else {
        anyhow::ensure!(
            index_format_from_args(args)? != Some(IndexFormat::V2),
            "--index-format v2 needs an on-disk index (--index FILE); build one with \
             `index --ref ... --index-format v2` first"
        );
        let ref_path = args.get("ref").context("--ref or --index required")?;
        let read_len = args.get_usize("read-len", READ_LEN)?;
        let reference = load_reference(ref_path)?;
        IndexBackend::Heap(MinimizerIndex::build(reference, K, W, read_len))
    };
    eprintln!("serve: index backend: {}", backend.kind());
    let template = crate::serve::SessionTemplate {
        cfg,
        pairing: pairing_from_args(args)?,
        revcomp: args.flag("revcomp"),
    };
    let bind = match (args.get("socket"), args.get("tcp")) {
        (Some(_), Some(_)) => bail!("--socket and --tcp are mutually exclusive"),
        (Some(path), None) => crate::serve::Bind::Unix(path.into()),
        (None, Some(addr)) => crate::serve::Bind::Tcp(addr.to_string()),
        (None, None) => bail!("serve requires --socket PATH or --tcp HOST:PORT"),
    };
    crate::serve::run_daemon(backend.view(), template, bind)
}

/// `serve` needs Unix-domain sockets and POSIX signal numbers.
#[cfg(not(unix))]
fn cmd_serve(_args: &Args) -> Result<()> {
    bail!("the serve daemon requires a Unix platform")
}

fn cmd_evaluate(args: &Args) -> Result<()> {
    let paired = paired_mode(args)?;
    let (index, mut reads) = load_inputs(args)?;
    let truth = load_truth(args.get("truth").context("--truth required")?, reads.len())?;
    for r in reads.iter_mut() {
        r.truth_pos = truth[r.id as usize];
    }
    let tol = args.get_usize("tolerance", 5)? as i64;
    let (mappings, metrics) = run_pipeline(args, &index, &reads)?;
    let rep = crate::eval::evaluate_accuracy(&index, &reads, &mappings, tol);
    println!("{}", metrics.summary());
    println!(
        "accuracy vs oracle (±{tol}): {:.4}  exact: {:.4}  \
         | vs truth (±{tol}): {:.4}  mapped: {}/{}",
        rep.accuracy_vs_oracle(),
        rep.oracle_exact as f64 / rep.oracle_mapped.max(1) as f64,
        rep.accuracy_vs_truth(),
        rep.mapped,
        rep.n_reads
    );
    if paired {
        let pr = crate::eval::evaluate_pair_accuracy(&reads, &mappings, tol);
        println!(
            "pair-aware (±{tol}): pair recall {:.4} ({}/{} pairs)  mate accuracy {:.4}  \
             precision {:.4}  proper mates {}  rescued {}",
            pr.pair_recall(),
            pr.pair_correct,
            pr.n_pairs,
            pr.mate_accuracy(),
            pr.mate_precision(),
            pr.proper_mates,
            pr.rescued_mates
        );
        // single-end baseline over the same records (pairing off,
        // revcomp kept on so both strands stay mappable): the pairing
        // gain the paper-adjacent literature leans on, measured here
        let mut se_args = Args {
            cmd: args.cmd.clone(),
            opts: args.opts.clone(),
            flags: args.flags.clone(),
        };
        se_args.opts.remove("reads2");
        se_args.flags.retain(|f| f != "interleaved");
        se_args.flags.push("revcomp".into());
        let (se_mappings, _) = run_pipeline(&se_args, &index, &reads)?;
        let se = crate::eval::evaluate_pair_accuracy(&reads, &se_mappings, tol);
        println!(
            "single-end baseline on the same reads: mate accuracy {:.4}  (pairing gain {:+.4})",
            se.mate_accuracy(),
            pr.mate_accuracy() - se.mate_accuracy()
        );
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let (read_len, paired, reads) = stream_input(args)?;
    let index = load_or_build_index(args, read_len)?;
    let cfg = dart_config(args)?;
    let threads = args.get_usize("threads", default_threads())?;
    let engine_name = args.get("engine").unwrap_or(crate::runtime::default_engine().name());
    let engine = EngineKind::from_name(engine_name).with_context(|| {
        format!(
            "simulate runs the host filter on a thread-constructible engine \
             (rust|bitpal), not {engine_name:?}"
        )
    })?;
    let simd = simd_from_args(args)?;
    let sim = FullSystemSim::new(&index, cfg.clone());
    // streams the FASTQ through the bounded sim shards (O(batch) in
    // flight), exactly like `map`; paired sources mirror the live
    // pipeline's mate orientation and report pair availability
    let counts = if paired {
        sim.simulate_stream_paired(reads, threads, engine, simd)?
    } else {
        sim.simulate_stream(reads, threads, engine, simd)?
    };
    if paired {
        println!(
            "paired workload: {} pairs, both-mates-alive {} ({:.1}%)",
            counts.n_pairs,
            counts.pairs_with_candidates,
            100.0 * counts.pairs_with_candidates as f64 / counts.n_pairs.max(1) as f64
        );
    }
    let cost = if args.flag("constructive") {
        CostSource::Constructive
    } else {
        CostSource::PaperTable4
    };
    let timing = if args.flag("batched-affine") {
        TimingMode::Batched8
    } else {
        TimingMode::PaperSerial
    };
    let report = build_report(&counts, &cfg, cost, timing);
    println!("measured workload: {} reads, PLs/read={:.1}, pass={:.2}%, riscv share={:.3}%",
        counts.n_reads, counts.pls_per_read(), 100.0 * counts.pass_rate(),
        100.0 * counts.riscv_affine_share());
    println!(
        "simulated: T={:.4}s (dpmem {:.4}, riscv {:.4}, readout {:.4})  E={:.3}J  {:.0} reads/s",
        report.exec_time_s, report.t_dpmem_s, report.t_riscv_s, report.t_readout_s,
        report.energy.total(), report.throughput()
    );
    let scale = args.get_usize("scale", 0)?;
    if scale > 0 {
        let scaled = scale_counts(&counts, scale as u64, &cfg);
        let r = build_report(&scaled, &cfg, cost, timing);
        println!(
            "projected to {scale} reads: T={:.1}s  E={:.1}kJ  {:.2} Mreads/s  {:.0}W avg",
            r.exec_time_s,
            r.energy.total() / 1e3,
            r.throughput() / 1e6,
            r.avg_power_w()
        );
    }
    let j = Json::obj(vec![
        ("exec_time_s", report.exec_time_s.into()),
        ("energy_j", report.energy.total().into()),
        ("throughput", report.throughput().into()),
        ("pls_per_read", counts.pls_per_read().into()),
        ("pass_rate", counts.pass_rate().into()),
    ]);
    if let Some(path) = args.get("json") {
        std::fs::write(path, j.pretty())?;
    }
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let which = args.get("fig").unwrap_or("all");
    let mut out = String::new();
    if matches!(which, "table4" | "all") {
        out.push_str(&figures::table4());
        out.push('\n');
    }
    if matches!(which, "8" | "all") {
        out.push_str(&figures::fig8());
        out.push('\n');
    }
    if matches!(which, "9" | "all") {
        out.push_str(&figures::fig9());
        out.push('\n');
    }
    if matches!(which, "10a" | "all") {
        out.push_str(&figures::fig10a());
        out.push('\n');
    }
    if matches!(which, "10b" | "all") {
        out.push_str(&figures::fig10b());
        out.push('\n');
    }
    if matches!(which, "10c" | "all") {
        out.push_str(&figures::fig10c());
        out.push('\n');
    }
    if matches!(which, "headline" | "all") {
        out.push_str(&figures::headline());
        out.push('\n');
    }
    if matches!(which, "motivation" | "all") {
        out.push_str(&crate::eval::datavolume::render(
            &crate::eval::datavolume::paper_volume(),
            "paper (§II)",
        ));
    }
    anyhow::ensure!(!out.is_empty(), "unknown figure {which:?}");
    print!("{out}");
    Ok(())
}

fn cmd_crossbar() -> Result<()> {
    print!("{}", figures::table4());
    let lin = xbar_sim::linear_row_allocation(READ_LEN, 1024);
    let aff = xbar_sim::affine_row_allocation(READ_LEN, 1024);
    println!("\nrow allocation (bits of 1024):");
    println!(
        "  linear: segment {} + read {} + band {} + temps {}",
        lin.segment_bits, lin.read_bits, lin.band_bits, lin.temp_bits
    );
    println!(
        "  affine: window {} + read {} + bands {} + temps {}; traceback {} bits / instance",
        aff.segment_bits,
        aff.read_bits,
        aff.band_bits,
        aff.temp_bits,
        xbar_sim::traceback_bits(READ_LEN)
    );
    Ok(())
}

fn cmd_config() -> Result<()> {
    let c = DartPimConfig::default();
    println!("{c:#?}");
    println!(
        "derived: {} crossbars, {} GB, {} RISC-V cores, {} reads/FIFO, \
         {} affine instances/crossbar",
        c.total_xbars(),
        c.total_capacity_bytes() >> 30,
        c.total_riscv(),
        c.fifo_capacity_reads(),
        c.affine_instances()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_opts_and_flags() {
        let a = Args::parse(&argv("map --ref r.fa --reads r.fq --min-only --batch 64")).unwrap();
        assert_eq!(a.cmd, "map");
        assert_eq!(a.get("ref"), Some("r.fa"));
        assert_eq!(a.get_usize("batch", 0).unwrap(), 64);
        assert!(a.flag("min-only"));
        assert!(!a.flag("nope"));
    }

    #[test]
    fn rejects_positionals_and_bad_ints() {
        assert!(Args::parse(&argv("map positional")).is_err());
        let a = Args::parse(&argv("map --batch abc")).unwrap();
        assert!(a.get_usize("batch", 0).is_err());
    }

    #[test]
    fn unknown_command_errors() {
        assert!(run(&argv("frobnicate")).is_err());
    }

    #[test]
    fn figures_command_runs() {
        run(&argv("figures --fig table4")).unwrap();
        run(&argv("crossbar")).unwrap();
        run(&argv("config")).unwrap();
    }

    #[test]
    fn empty_reference_fasta_errors_with_the_path() {
        let dir = std::env::temp_dir().join(format!("dartpim-efa-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let fa = dir.join("empty.fasta");
        std::fs::write(&fa, "").unwrap();
        let fq = dir.join("r.fastq");
        std::fs::write(&fq, "@r0\nACGTACGTACGTACGT\n+\nIIIIIIIIIIIIIIII\n").unwrap();
        let fa_s = fa.to_str().unwrap();
        let fq_s = fq.to_str().unwrap();

        let err = run(&argv(&format!("map --ref {fa_s} --reads {fq_s}"))).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("no sequences") && msg.contains("empty.fasta"),
            "map error must name the file: {msg}"
        );

        let out = dir.join("x.idx");
        let err = run(&argv(&format!("index --ref {fa_s} --out {}", out.display())))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("no sequences") && msg.contains("empty.fasta"),
            "index error must name the file: {msg}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_map_leaves_no_output_file() {
        let dir = std::env::temp_dir().join(format!("dartpim-atomic-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let fa = dir.join("ref.fasta");
        std::fs::write(&fa, format!(">r\n{}\n", "ACGTTGCAAGCT".repeat(500))).unwrap();
        // second record diverges in length -> the pipeline errors
        // mid-stream, after the TSV header has already been written
        let fq = dir.join("bad.fastq");
        std::fs::write(&fq, "@r0\nACGTACGTACGTACGT\n+\nIIIIIIIIIIIIIIII\n@r1\nACGT\n+\nIIII\n")
            .unwrap();
        let out = dir.join("map.tsv");
        let err = run(&argv(&format!(
            "map --ref {} --reads {} --out {}",
            fa.display(),
            fq.display(),
            out.display()
        )))
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("uniform read length"),
            "expected the length-divergence error, got: {err:#}"
        );
        assert!(!out.exists(), "failed map must not leave a partial {}", out.display());
        assert!(
            !dir.join("map.tsv.tmp").exists(),
            "failed map must remove its temporary output file"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bitpal_engine_tsv_is_byte_identical() {
        let dir = std::env::temp_dir().join(format!("dartpim-bitpal-{}", std::process::id()));
        let d = dir.to_str().unwrap();
        run(&argv(&format!("synth --out-dir {d} --len 60000 --reads 40"))).unwrap();
        run(&argv(&format!(
            "map --ref {d}/ref.fasta --reads {d}/reads.fastq --engine rust --low-th 0 \
             --out {d}/rust.tsv"
        )))
        .unwrap();
        run(&argv(&format!(
            "map --ref {d}/ref.fasta --reads {d}/reads.fastq --engine bitpal --low-th 0 \
             --out {d}/bitpal.tsv"
        )))
        .unwrap();
        run(&argv(&format!(
            "map --ref {d}/ref.fasta --reads {d}/reads.fastq --engine bitpal --low-th 0 \
             --threads 4 --out {d}/bitpal4.tsv"
        )))
        .unwrap();
        let rust = std::fs::read_to_string(dir.join("rust.tsv")).unwrap();
        let bitpal = std::fs::read_to_string(dir.join("bitpal.tsv")).unwrap();
        let bitpal4 = std::fs::read_to_string(dir.join("bitpal4.tsv")).unwrap();
        assert!(rust.lines().count() > 30, "workload must map reads:\n{rust}");
        assert_eq!(rust, bitpal, "bitpal must be byte-identical to rust");
        assert_eq!(rust, bitpal4, "sharded bitpal must be byte-identical too");
        // simulate accepts the engine as well and must not error
        run(&argv(&format!(
            "simulate --ref {d}/ref.fasta --reads {d}/reads.fastq --low-th 0 \
             --engine bitpal --threads 2"
        )))
        .unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn paired_synth_map_evaluate_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dartpim-pe-{}", std::process::id()));
        let d = dir.to_str().unwrap();
        run(&argv(&format!("synth --out-dir {d} --len 80000 --reads 30 --paired"))).unwrap();
        // two-file and interleaved sources must produce identical TSVs
        run(&argv(&format!(
            "map --ref {d}/ref.fasta --reads {d}/reads_1.fastq --reads2 {d}/reads_2.fastq \
             --low-th 0 --out {d}/two.tsv"
        )))
        .unwrap();
        run(&argv(&format!(
            "map --ref {d}/ref.fasta --reads {d}/reads_interleaved.fastq --interleaved \
             --low-th 0 --out {d}/il.tsv"
        )))
        .unwrap();
        let two = std::fs::read_to_string(dir.join("two.tsv")).unwrap();
        let il = std::fs::read_to_string(dir.join("il.tsv")).unwrap();
        assert_eq!(two, il, "two-file and interleaved sources must agree byte-for-byte");
        assert!(two.lines().count() > 50, "most mates should map:\n{two}");
        assert!(two.starts_with("pair_id\tmate\t"), "paired TSV schema:\n{two}");
        assert!(two.contains("proper"), "proper pairs expected:\n{two}");
        // sharded paired mapping stays byte-identical
        run(&argv(&format!(
            "map --ref {d}/ref.fasta --reads {d}/reads_1.fastq --reads2 {d}/reads_2.fastq \
             --low-th 0 --threads 3 --out {d}/two3.tsv"
        )))
        .unwrap();
        let two3 = std::fs::read_to_string(dir.join("two3.tsv")).unwrap();
        assert_eq!(two, two3, "sharded paired mapping must be byte-identical");
        run(&argv(&format!(
            "evaluate --ref {d}/ref.fasta --reads {d}/reads_1.fastq --reads2 {d}/reads_2.fastq \
             --truth {d}/truth.tsv --low-th 0"
        )))
        .unwrap();
        run(&argv(&format!(
            "simulate --ref {d}/ref.fasta --reads {d}/reads_interleaved.fastq --interleaved \
             --low-th 0"
        )))
        .unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v2_index_builds_converts_and_maps_byte_identically() {
        let dir = std::env::temp_dir().join(format!("dartpim-v2cli-{}", std::process::id()));
        let d = dir.to_str().unwrap();
        run(&argv(&format!("synth --out-dir {d} --len 60000 --reads 30"))).unwrap();
        run(&argv(&format!("index --ref {d}/ref.fasta --out {d}/v1.idx"))).unwrap();
        run(&argv(&format!(
            "index --ref {d}/ref.fasta --out {d}/v2.idx --index-format v2 --shards 4"
        )))
        .unwrap();
        // the streaming builder and the v1->v2 converter must emit the
        // same bytes (both order shards and keys identically)
        run(&argv(&format!(
            "index --from {d}/v1.idx --out {d}/v2c.idx --index-format v2 --shards 4"
        )))
        .unwrap();
        let built = std::fs::read(dir.join("v2.idx")).unwrap();
        let converted = std::fs::read(dir.join("v2c.idx")).unwrap();
        assert_eq!(built, converted, "streaming build and conversion must agree");
        // invariant 9: heap (v1), mapped (v2), and forced-heap-on-v2
        // backends produce byte-identical mappings
        for (idx, fmt, out) in [
            ("v1.idx", "", "heap.tsv"),
            ("v2.idx", "", "mapped.tsv"),
            ("v2.idx", "--index-format v1", "forced.tsv"),
        ] {
            run(&argv(&format!(
                "map --index {d}/{idx} {fmt} --reads {d}/reads.fastq --low-th 0 --out {d}/{out}"
            )))
            .unwrap();
        }
        let heap = std::fs::read_to_string(dir.join("heap.tsv")).unwrap();
        let mapped = std::fs::read_to_string(dir.join("mapped.tsv")).unwrap();
        let forced = std::fs::read_to_string(dir.join("forced.tsv")).unwrap();
        assert!(heap.lines().count() > 20, "workload must map reads:\n{heap}");
        assert_eq!(heap, mapped, "mapped backend must be byte-identical to heap");
        assert_eq!(heap, forced, "forced heap load of a v2 file must be byte-identical");
        // forcing the mapped backend onto a v1 file must refuse loudly
        let err = run(&argv(&format!(
            "map --index {d}/v1.idx --index-format v2 --reads {d}/reads.fastq --out {d}/x.tsv"
        )))
        .unwrap_err();
        assert!(
            format!("{err:#}").contains("convert"),
            "v2-on-v1 must point at the converter: {err:#}"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn synth_map_evaluate_roundtrip() {
        let dir = std::env::temp_dir().join(format!("dartpim-cli-{}", std::process::id()));
        let d = dir.to_str().unwrap();
        run(&argv(&format!("synth --out-dir {d} --len 60000 --reads 40"))).unwrap();
        run(&argv(&format!(
            "map --ref {d}/ref.fasta --reads {d}/reads.fastq --engine rust --low-th 0 \
             --out {d}/map.tsv"
        )))
        .unwrap();
        let tsv = std::fs::read_to_string(dir.join("map.tsv")).unwrap();
        assert!(tsv.lines().count() > 30, "most reads should map:\n{tsv}");
        run(&argv(&format!(
            "evaluate --ref {d}/ref.fasta --reads {d}/reads.fastq --truth {d}/truth.tsv \
             --engine rust --low-th 0"
        )))
        .unwrap();
        run(&argv(&format!(
            "simulate --ref {d}/ref.fasta --reads {d}/reads.fastq --low-th 0 --scale 389000000"
        )))
        .unwrap();
        // offline indexing: build once, map from the saved index
        run(&argv(&format!("index --ref {d}/ref.fasta --out {d}/ref.idx"))).unwrap();
        run(&argv(&format!(
            "map --index {d}/ref.idx --reads {d}/reads.fastq --engine rust --low-th 0 \
             --out {d}/map2.tsv"
        )))
        .unwrap();
        let a = std::fs::read_to_string(dir.join("map.tsv")).unwrap();
        let b = std::fs::read_to_string(dir.join("map2.tsv")).unwrap();
        assert_eq!(a, b, "mapping from a loaded index must be identical");
        // sharded mapping must produce byte-identical TSV output
        run(&argv(&format!(
            "map --ref {d}/ref.fasta --reads {d}/reads.fastq --engine rust --low-th 0 \
             --threads 3 --out {d}/map3.tsv"
        )))
        .unwrap();
        let c = std::fs::read_to_string(dir.join("map3.tsv")).unwrap();
        assert_eq!(a, c, "sharded mapping must be byte-identical");
        std::fs::remove_dir_all(&dir).ok();
    }
}
