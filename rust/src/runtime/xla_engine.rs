//! PJRT-backed WF engine: loads the AOT-lowered HLO text artifacts and
//! executes them on the XLA CPU client (adapting the pattern from
//! /opt/xla-example/load_hlo).
//!
//! One compiled executable per (kind, batch) variant; batches are padded
//! to the nearest variant with all-zero instances (their outputs are
//! discarded). Interchange is HLO *text* — see `python/compile/aot.py`
//! for why serialized protos are rejected by xla_extension 0.5.1.

use anyhow::{Context, Result};

use super::artifacts::ArtifactManifest;
use super::engine::{check_batch, AffineBatch, LinearBatch, WfEngine};
use crate::params::{window_len, BAND};

struct Variant {
    batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// The XLA/PJRT engine.
pub struct XlaEngine {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    linear: Vec<Variant>,
    affine: Vec<Variant>,
    /// PJRT executions performed (metrics).
    pub calls: u64,
}

impl XlaEngine {
    /// Load every artifact in `dir` and compile it on the CPU PJRT
    /// client. Fails fast on any geometry mismatch.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = ArtifactManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut linear = Vec::new();
        let mut affine = Vec::new();
        for entry in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                entry.path.to_str().context("artifact path not UTF-8")?,
            )
            .with_context(|| format!("parsing HLO text {}", entry.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                client.compile(&comp).with_context(|| format!("compiling {}", entry.name))?;
            let v = Variant { batch: entry.batch, exe };
            match entry.kind.as_str() {
                "linear_wf" => linear.push(v),
                "affine_wf" => affine.push(v),
                other => anyhow::bail!("unknown artifact kind {other}"),
            }
        }
        linear.sort_by_key(|v| v.batch);
        affine.sort_by_key(|v| v.batch);
        anyhow::ensure!(!linear.is_empty() && !affine.is_empty(), "missing artifact kinds");
        Ok(XlaEngine { client, manifest, linear, affine, calls: 0 })
    }

    /// Load from the default artifacts directory
    /// (`$DART_PIM_ARTIFACTS` or `./artifacts`).
    pub fn load_default() -> Result<Self> {
        Self::load(super::artifacts::default_dir())
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest variant batch >= n (or the largest available).
    fn pick(variants: &[Variant], n: usize) -> usize {
        variants
            .iter()
            .find(|v| v.batch >= n)
            .unwrap_or_else(|| variants.last().expect("non-empty"))
            .batch
    }

    /// Pack a batch (padded to `batch` instances) into two i32 literals.
    fn pack(
        reads: &[&[u8]],
        wins: &[&[u8]],
        n: usize,
        batch: usize,
    ) -> Result<(xla::Literal, xla::Literal)> {
        let m = window_len(n);
        let mut r = vec![0i32; batch * n];
        let mut w = vec![0i32; batch * m];
        for (i, (rd, wn)) in reads.iter().zip(wins).enumerate() {
            for (j, &b) in rd.iter().enumerate() {
                r[i * n + j] = b as i32;
            }
            for (j, &b) in wn.iter().enumerate() {
                w[i * m + j] = b as i32;
            }
        }
        // one-copy literal creation (no vec1 + reshape round trip) —
        // §Perf opt 3
        // SAFETY: reinterprets an initialized, live &[i32] as bytes —
        // same allocation, size_of_val-exact length, and u8 has no
        // alignment or validity requirements. The slice outlives both
        // uses below (r/w are borrowed for the whole call).
        let as_bytes = |v: &[i32]| unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8, std::mem::size_of_val(v))
        };
        let lr = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[batch, n],
            as_bytes(&r),
        )?;
        let lw = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S32,
            &[batch, m],
            as_bytes(&w),
        )?;
        Ok((lr, lw))
    }

    /// Execute one variant and decompose the output tuple.
    fn exec(
        &mut self,
        is_linear: bool,
        batch: usize,
        lr: xla::Literal,
        lw: xla::Literal,
    ) -> Result<Vec<xla::Literal>> {
        self.calls += 1;
        let variants = if is_linear { &self.linear } else { &self.affine };
        let exe = &variants
            .iter()
            .find(|v| v.batch == batch)
            .context("variant disappeared")?
            .exe;
        let result = exe.execute::<xla::Literal>(&[lr, lw])?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    fn check_read_len(&self, n: usize) -> Result<()> {
        anyhow::ensure!(
            n == self.manifest.read_len,
            "artifacts were lowered for read_len {}, got {n}",
            self.manifest.read_len
        );
        Ok(())
    }

    fn unpack_band(lit: &xla::Literal, b: usize) -> Result<Vec<[i32; BAND]>> {
        let flat = lit.to_vec::<i32>()?;
        anyhow::ensure!(flat.len() == b * BAND, "band shape mismatch");
        Ok((0..b)
            .map(|i| {
                let mut row = [0i32; BAND];
                row.copy_from_slice(&flat[i * BAND..(i + 1) * BAND]);
                row
            })
            .collect())
    }
}

impl WfEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn linear_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<LinearBatch> {
        let n = check_batch(reads, wins)?;
        self.check_read_len(n)?;
        let b = reads.len();
        let largest = self.linear.last().expect("non-empty").batch;
        if b > largest {
            // split oversized batches across the largest variant
            let mut out = LinearBatch { band: vec![], best: vec![], best_j: vec![] };
            for (cr, cw) in reads.chunks(largest).zip(wins.chunks(largest)) {
                let part = self.linear_batch(cr, cw)?;
                out.band.extend(part.band);
                out.best.extend(part.best);
                out.best_j.extend(part.best_j);
            }
            return Ok(out);
        }
        let batch = Self::pick(&self.linear, b);
        let (lr, lw) = Self::pack(reads, wins, n, batch)?;
        let outs = self.exec(true, batch, lr, lw)?;
        anyhow::ensure!(outs.len() == 3, "linear graph returns 3 outputs");
        let band = Self::unpack_band(&outs[0], batch)?;
        let best = outs[1].to_vec::<i32>()?;
        let best_j = outs[2].to_vec::<i32>()?;
        Ok(LinearBatch {
            band: band.into_iter().take(b).collect(),
            best: best.into_iter().take(b).collect(),
            best_j: best_j.into_iter().take(b).map(|j| j as u32).collect(),
        })
    }

    fn affine_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<AffineBatch> {
        let n = check_batch(reads, wins)?;
        self.check_read_len(n)?;
        let b = reads.len();
        let largest = self.affine.last().expect("non-empty").batch;
        if b > largest {
            let mut out =
                AffineBatch { band: vec![], best: vec![], best_j: vec![], dirs: vec![] };
            for (cr, cw) in reads.chunks(largest).zip(wins.chunks(largest)) {
                let part = self.affine_batch(cr, cw)?;
                out.band.extend(part.band);
                out.best.extend(part.best);
                out.best_j.extend(part.best_j);
                out.dirs.extend(part.dirs);
            }
            return Ok(out);
        }
        let batch = Self::pick(&self.affine, b);
        let (lr, lw) = Self::pack(reads, wins, n, batch)?;
        let outs = self.exec(false, batch, lr, lw)?;
        anyhow::ensure!(outs.len() == 4, "affine graph returns 4 outputs");
        let band = Self::unpack_band(&outs[0], batch)?;
        let best = outs[1].to_vec::<i32>()?;
        let best_j = outs[2].to_vec::<i32>()?;
        let dirs_flat = outs[3].to_vec::<i32>()?;
        anyhow::ensure!(dirs_flat.len() == batch * n * BAND, "dirs shape mismatch");
        let dirs: Vec<Vec<u8>> = (0..b)
            .map(|i| {
                dirs_flat[i * n * BAND..(i + 1) * n * BAND].iter().map(|&v| v as u8).collect()
            })
            .collect();
        Ok(AffineBatch {
            band: band.into_iter().take(b).collect(),
            best: best.into_iter().take(b).collect(),
            best_j: best_j.into_iter().take(b).map(|j| j as u32).collect(),
            dirs,
        })
    }
}
