//! Artifact manifest: shapes and file names of the AOT-lowered HLO
//! modules, written by `python/compile/aot.py`. Parsed with the in-crate
//! JSON parser and cross-checked against this crate's algorithm
//! parameters at startup (a mismatch means the Python and Rust layers
//! were built from different geometry and all numerics would be garbage).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json;

/// One lowered HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    /// Unique artifact name (e.g. "linear_wf_b256").
    pub name: String,
    /// "linear_wf" or "affine_wf".
    pub kind: String,
    /// Batch size the module was lowered for.
    pub batch: usize,
    /// Path of the HLO text file.
    pub path: PathBuf,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Read length the kernels were lowered for.
    pub read_len: usize,
    /// Window length (read_len + 2*eth).
    pub win_len: usize,
    /// Band width (2*eth + 1).
    pub band: usize,
    /// Error threshold eth.
    pub eth: usize,
    /// Linear WF saturation value.
    pub sat_linear: i32,
    /// Affine WF saturation value.
    pub sat_affine: i32,
    /// The lowered modules.
    pub artifacts: Vec<ArtifactEntry>,
}

impl ArtifactManifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let text = std::fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!("reading {}/manifest.json (run `make artifacts`)", dir.display())
        })?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let get = |k: &str| -> Result<usize> {
            v.get(k).and_then(|x| x.as_usize()).with_context(|| format!("manifest missing {k}"))
        };
        let mut artifacts = Vec::new();
        for a in v.get("artifacts").and_then(|x| x.as_arr()).context("manifest artifacts")? {
            let s = |k: &str| -> Result<String> {
                let v = a.get(k).and_then(|x| x.as_str());
                Ok(v.with_context(|| format!("artifact {k}"))?.to_string())
            };
            artifacts.push(ArtifactEntry {
                name: s("name")?,
                kind: s("kind")?,
                batch: a.get("batch").and_then(|x| x.as_usize()).context("artifact batch")?,
                path: dir.join(s("file")?),
            });
        }
        let m = ArtifactManifest {
            read_len: get("read_len")?,
            win_len: get("win_len")?,
            band: get("band")?,
            eth: get("eth")?,
            sat_linear: get("sat_linear")? as i32,
            sat_affine: get("sat_affine")? as i32,
            artifacts,
        };
        m.validate()?;
        Ok(m)
    }

    /// Cross-check against crate::params.
    pub fn validate(&self) -> Result<()> {
        use crate::params::*;
        if self.band != BAND || self.eth != ETH {
            bail!("manifest band/eth {}/{} != crate {}/{}", self.band, self.eth, BAND, ETH);
        }
        if self.win_len != window_len(self.read_len) {
            bail!("manifest win_len {} inconsistent with read_len {}", self.win_len, self.read_len);
        }
        if self.sat_linear != SAT_LINEAR || self.sat_affine != SAT_AFFINE {
            bail!("manifest saturation constants differ from crate params");
        }
        if self.artifacts.is_empty() {
            bail!("manifest lists no artifacts");
        }
        for a in &self.artifacts {
            if !a.path.exists() {
                bail!("artifact file missing: {}", a.path.display());
            }
        }
        Ok(())
    }

    /// Batch variants available for a kind, ascending.
    pub fn batches(&self, kind: &str) -> Vec<usize> {
        let mut v: Vec<usize> =
            self.artifacts.iter().filter(|a| a.kind == kind).map(|a| a.batch).collect();
        v.sort_unstable();
        v
    }

    /// The entry for (kind, batch).
    pub fn entry(&self, kind: &str, batch: usize) -> Option<&ArtifactEntry> {
        self.artifacts.iter().find(|a| a.kind == kind && a.batch == batch)
    }

    /// Smallest variant whose batch >= n, or the largest variant.
    pub fn variant_for(&self, kind: &str, n: usize) -> Option<&ArtifactEntry> {
        let mut candidates: Vec<&ArtifactEntry> =
            self.artifacts.iter().filter(|a| a.kind == kind).collect();
        candidates.sort_by_key(|a| a.batch);
        candidates.iter().find(|a| a.batch >= n).copied().or(candidates.last().copied())
    }
}

/// Default artifacts directory: `$DART_PIM_ARTIFACTS`, else `./artifacts`
/// when it holds a manifest, else the crate-local `rust/artifacts/` that
/// `make artifacts` populates (compile-time path — correct for binaries
/// run on the machine that built them, which is the dev/CI case).
pub fn default_dir() -> PathBuf {
    if let Some(dir) = std::env::var_os("DART_PIM_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let cwd_local = PathBuf::from("artifacts");
    if cwd_local.join("manifest.json").exists() {
        return cwd_local;
    }
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn real_manifest_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = real_manifest_dir() else { return };
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.read_len, crate::params::READ_LEN);
        assert_eq!(m.batches("linear_wf"), vec![32, 256]);
        assert_eq!(m.batches("affine_wf"), vec![8, 64]);
    }

    #[test]
    fn variant_selection() {
        let Some(dir) = real_manifest_dir() else { return };
        let m = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(m.variant_for("linear_wf", 1).unwrap().batch, 32);
        assert_eq!(m.variant_for("linear_wf", 32).unwrap().batch, 32);
        assert_eq!(m.variant_for("linear_wf", 33).unwrap().batch, 256);
        assert_eq!(m.variant_for("linear_wf", 9999).unwrap().batch, 256);
        assert!(m.variant_for("nonexistent", 1).is_none());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(ArtifactManifest::load("/nonexistent/dir").is_err());
    }

    #[test]
    fn rejects_inconsistent_manifest() {
        let tmp = std::env::temp_dir().join(format!("dartpim-test-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(
            tmp.join("manifest.json"),
            r#"{"read_len": 150, "win_len": 99, "band": 13, "eth": 6,
                "sat_linear": 7, "sat_affine": 31, "artifacts": []}"#,
        )
        .unwrap();
        assert!(ArtifactManifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
