//! Lane-width abstraction for the bit-parallel engines.
//!
//! The paper's crossbar advances *every row at once*; how many rows a
//! host word op advances is the machine's vector width. [`LaneWord`] is
//! the word the bit-parallel kernels are generic over: `u64` (the
//! classic 64-lane BitPal word) or `[u64; N]` for 128/256/512-bit lanes.
//! The array forms use only portable bitwise ops, so they compile on
//! every target; on x86_64 the engine wraps them in
//! `#[target_feature(enable = "avx2")]` functions (see
//! `bitpal_engine.rs`) so LLVM lowers each plane op to one (or two)
//! vector instructions.
//!
//! [`SimdMode`] is the user-facing knob (`--simd`, `DART_PIM_SIMD`):
//! `u64` pins the historical word, `wide` picks the widest lane the host
//! supports at runtime, `off` forces the scalar reference path. The mode
//! NEVER changes output bytes — only throughput (determinism invariant 8,
//! ARCHITECTURE.md).

/// A machine word holding one bit lane per WF instance.
///
/// Implementations must be pure value types: every op is lane-wise
/// bitwise, so per-lane results are independent regardless of width.
pub trait LaneWord: Copy + Send + 'static {
    /// Lane count (bits) of this word.
    const BITS: usize;
    /// The all-zeros word.
    const ZERO: Self;
    /// The all-ones word.
    const ONES: Self;
    /// Bitwise AND.
    fn and(self, o: Self) -> Self;
    /// Bitwise OR.
    fn or(self, o: Self) -> Self;
    /// Bitwise XOR.
    fn xor(self, o: Self) -> Self;
    /// Bitwise NOT.
    fn not(self) -> Self;
    /// `self & !o` (AND-NOT: one op on most vector ISAs).
    fn andnot(self, o: Self) -> Self;
    /// Set bit `lane` (lane < `BITS`).
    fn set_lane(&mut self, lane: usize);
    /// Read bit `lane` as a bool.
    fn lane(self, lane: usize) -> bool;
}

impl LaneWord for u64 {
    const BITS: usize = 64;
    const ZERO: Self = 0;
    const ONES: Self = !0;
    #[inline(always)]
    fn and(self, o: Self) -> Self {
        self & o
    }
    #[inline(always)]
    fn or(self, o: Self) -> Self {
        self | o
    }
    #[inline(always)]
    fn xor(self, o: Self) -> Self {
        self ^ o
    }
    #[inline(always)]
    fn not(self) -> Self {
        !self
    }
    #[inline(always)]
    fn andnot(self, o: Self) -> Self {
        self & !o
    }
    #[inline(always)]
    fn set_lane(&mut self, lane: usize) {
        *self |= 1u64 << lane;
    }
    #[inline(always)]
    fn lane(self, lane: usize) -> bool {
        (self >> lane) & 1 == 1
    }
}

impl<const N: usize> LaneWord for [u64; N] {
    const BITS: usize = 64 * N;
    const ZERO: Self = [0; N];
    const ONES: Self = [!0; N];
    #[inline(always)]
    fn and(self, o: Self) -> Self {
        std::array::from_fn(|i| self[i] & o[i])
    }
    #[inline(always)]
    fn or(self, o: Self) -> Self {
        std::array::from_fn(|i| self[i] | o[i])
    }
    #[inline(always)]
    fn xor(self, o: Self) -> Self {
        std::array::from_fn(|i| self[i] ^ o[i])
    }
    #[inline(always)]
    fn not(self) -> Self {
        std::array::from_fn(|i| !self[i])
    }
    #[inline(always)]
    fn andnot(self, o: Self) -> Self {
        std::array::from_fn(|i| self[i] & !o[i])
    }
    #[inline(always)]
    fn set_lane(&mut self, lane: usize) {
        self[lane >> 6] |= 1u64 << (lane & 63);
    }
    #[inline(always)]
    fn lane(self, lane: usize) -> bool {
        (self[lane >> 6] >> (lane & 63)) & 1 == 1
    }
}

/// User-facing SIMD dispatch mode (`--simd`, `DART_PIM_SIMD`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdMode {
    /// The classic single-`u64` 64-lane word.
    U64,
    /// The widest lane the host supports, detected at runtime.
    #[default]
    Wide,
    /// Scalar reference path: no bit-parallel kernels at all.
    Off,
}

impl SimdMode {
    /// Parse a mode name (`u64` / `wide` / `off`). `None` for unknown.
    pub fn from_name(name: &str) -> Option<SimdMode> {
        match name {
            "u64" => Some(SimdMode::U64),
            "wide" => Some(SimdMode::Wide),
            "off" => Some(SimdMode::Off),
            _ => None,
        }
    }

    /// The mode name (matches the CLI `--simd` spelling).
    pub fn name(self) -> &'static str {
        match self {
            SimdMode::U64 => "u64",
            SimdMode::Wide => "wide",
            SimdMode::Off => "off",
        }
    }

    /// The lane width this mode runs at on this host; `None` = scalar.
    pub fn resolve(self) -> Option<SimdWidth> {
        match self {
            SimdMode::U64 => Some(SimdWidth::W64),
            // dart-analyze: allow(determinism): host detection picks a
            // lane *width*, and output bytes are width-invariant by
            // construction (invariant 8) — the determinism suite compares
            // Wide vs U64 mappings byte-for-byte; only throughput and the
            // simd_width gauge vary with the host.
            SimdMode::Wide => Some(detect_wide()),
            SimdMode::Off => None,
        }
    }
}

/// A concrete lane width the bit-parallel kernels can run at.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdWidth {
    /// 64 lanes: one `u64`.
    W64,
    /// 128 lanes: `[u64; 2]` (SSE2 / NEON — baseline on x86_64/aarch64).
    W128,
    /// 256 lanes: `[u64; 4]` under the AVX2 target feature.
    W256,
    /// 512 lanes: `[u64; 8]`, selected when AVX-512F is detected.
    W512,
}

impl SimdWidth {
    /// Lane count (bits per plane word).
    pub fn bits(self) -> usize {
        match self {
            SimdWidth::W64 => 64,
            SimdWidth::W128 => 128,
            SimdWidth::W256 => 256,
            SimdWidth::W512 => 512,
        }
    }

    /// Every width the portable kernels can be forced to (for parity
    /// sweeps and benches; [`detect_wide`] picks what production uses).
    pub fn all() -> [SimdWidth; 4] {
        [SimdWidth::W64, SimdWidth::W128, SimdWidth::W256, SimdWidth::W512]
    }
}

/// The widest lane worth running on this host, by runtime detection.
///
/// x86_64: AVX-512F → 512, AVX2 → 256, else 128 (SSE2 is baseline).
/// aarch64: 128 (NEON is baseline). Other targets: 128 — the portable
/// `[u64; 2]` kernel is still correct and usually beats one `u64` by
/// amortizing the per-row scalar bookkeeping.
pub fn detect_wide() -> SimdWidth {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx512f") {
            SimdWidth::W512
        } else if std::arch::is_x86_feature_detected!("avx2") {
            SimdWidth::W256
        } else {
            SimdWidth::W128
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        SimdWidth::W128
    }
}

/// Default SIMD mode: the `DART_PIM_SIMD` environment variable when it
/// names a mode (CI re-runs the suite under `off` and `wide`), else
/// [`SimdMode::Wide`] — the contract that width never changes bytes
/// makes the fastest lane a safe default.
pub fn default_simd_mode() -> SimdMode {
    std::env::var("DART_PIM_SIMD")
        .ok()
        .and_then(|v| SimdMode::from_name(&v))
        .unwrap_or(SimdMode::Wide)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn word_ops_roundtrip<W: LaneWord>() {
        let mut a = W::ZERO;
        let mut b = W::ZERO;
        a.set_lane(0);
        a.set_lane(W::BITS - 1);
        b.set_lane(W::BITS - 1);
        assert!(a.lane(0) && a.lane(W::BITS - 1) && !a.lane(1));
        assert!(!a.and(b).lane(0) && a.and(b).lane(W::BITS - 1));
        assert!(a.or(b).lane(0));
        assert!(a.xor(b).lane(0) && !a.xor(b).lane(W::BITS - 1));
        assert!(a.not().lane(1) && !a.not().lane(0));
        assert!(a.andnot(b).lane(0) && !a.andnot(b).lane(W::BITS - 1));
        assert!(W::ONES.lane(0) && W::ONES.lane(W::BITS - 1));
    }

    #[test]
    fn lane_words_implement_the_same_algebra() {
        word_ops_roundtrip::<u64>();
        word_ops_roundtrip::<[u64; 2]>();
        word_ops_roundtrip::<[u64; 4]>();
        word_ops_roundtrip::<[u64; 8]>();
    }

    #[test]
    fn mode_names_round_trip() {
        for m in [SimdMode::U64, SimdMode::Wide, SimdMode::Off] {
            assert_eq!(SimdMode::from_name(m.name()), Some(m));
        }
        assert_eq!(SimdMode::from_name("avx2"), None);
    }

    #[test]
    fn resolution_is_sane() {
        assert_eq!(SimdMode::U64.resolve(), Some(SimdWidth::W64));
        assert_eq!(SimdMode::Off.resolve(), None);
        let wide = SimdMode::Wide.resolve().unwrap();
        assert!(wide.bits() >= 64, "wide must never be narrower than u64");
        for w in SimdWidth::all() {
            assert_eq!(w.bits() % 64, 0);
        }
    }
}
