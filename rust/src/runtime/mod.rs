//! Execution runtime: the AOT bridge between the Rust coordinator and
//! the JAX/Pallas-authored WF compute graphs.
//!
//! `make artifacts` (python -m compile.aot) lowers the L2 graphs once to HLO text
//! (`artifacts/*.hlo.txt` + `manifest.json`); [`artifacts`] loads the
//! manifest, `xla_engine` (behind the off-by-default `pjrt` cargo
//! feature) compiles each variant on the PJRT CPU client and executes
//! batches from the hot path. Python never runs at request time. The
//! default build is hermetic: no XLA toolchain is required, and the
//! coordinator runs on [`engine::RustEngine`].
//!
//! [`engine::RustEngine`] is the bit-identical pure-Rust mirror (also the
//! RISC-V-offload compute path); `tests/engine_parity.rs` holds the two
//! engines to exact agreement.

pub mod artifacts;
pub mod engine;
#[cfg(feature = "pjrt")]
pub mod xla_engine;

pub use artifacts::ArtifactManifest;
pub use engine::{AffineBatch, LinearBatch, RustEngine, WfEngine};
#[cfg(feature = "pjrt")]
pub use xla_engine::XlaEngine;
