//! Execution runtime: the AOT bridge between the Rust coordinator and
//! the JAX/Pallas-authored WF compute graphs.
//!
//! `make artifacts` (python -m compile.aot) lowers the L2 graphs once to HLO text
//! (`artifacts/*.hlo.txt` + `manifest.json`); [`artifacts`] loads the
//! manifest, `xla_engine` (behind the off-by-default `pjrt` cargo
//! feature) compiles each variant on the PJRT CPU client and executes
//! batches from the hot path. Python never runs at request time. The
//! default build is hermetic: no XLA toolchain is required, and the
//! coordinator runs on [`engine::RustEngine`].
//!
//! [`engine::RustEngine`] is the bit-identical pure-Rust mirror (also the
//! RISC-V-offload compute path); `tests/engine_parity.rs` holds the two
//! engines to exact agreement. [`bitpal_engine::BitpalEngine`] is the
//! bit-parallel host analog of the crossbars' row-parallel compute
//! (§IV/Fig. 5): a delta-encoded linear filter plus a bit-sliced affine
//! stage ([`bitpal_affine`]) with one word lane per instance, generic
//! over the machine lane width ([`lanes`]: `u64` up to 512-bit words,
//! runtime-detected via `--simd` / `DART_PIM_SIMD`), same numerics
//! contract at every width (`tests/engine_parity_bitpal.rs`).
//! [`engine::EngineKind`] is the factory shard workers use to construct
//! their thread-local engine.

pub mod artifacts;
pub mod bitpal_affine;
pub mod bitpal_engine;
pub mod engine;
pub mod lanes;
#[cfg(feature = "pjrt")]
pub mod xla_engine;

pub use artifacts::ArtifactManifest;
pub use bitpal_engine::BitpalEngine;
pub use engine::{default_engine, AffineBatch, EngineKind, LinearBatch, RustEngine, WfEngine};
pub use lanes::{default_simd_mode, LaneWord, SimdMode, SimdWidth};
#[cfg(feature = "pjrt")]
pub use xla_engine::XlaEngine;
