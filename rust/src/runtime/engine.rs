//! The WF engine abstraction + the pure-Rust reference engine.
//!
//! Every engine implements identical numerics (band values, best-of-band
//! tie-breaks, packed traceback directions): the XLA engine runs the
//! AOT-compiled Pallas kernels, the Rust engine runs the in-crate
//! mirrors, and the bitpal engine runs the bit-parallel delta encoding
//! of the same recurrence. The coordinator is engine-agnostic.

use anyhow::{ensure, Result};

use crate::align::banded_affine::affine_wf_band;
use crate::align::banded_linear::{best_of_band, linear_wf_band};
use crate::params::BAND;

/// Results of one batched linear-filter call.
#[derive(Debug, Clone)]
pub struct LinearBatch {
    /// Final band row per instance.
    pub band: Vec<[i32; BAND]>,
    /// Best distance per instance (saturated => filtered out).
    pub best: Vec<i32>,
    /// Band coordinate of the best distance.
    pub best_j: Vec<u32>,
}

/// Results of one batched affine-alignment call.
#[derive(Debug, Clone)]
pub struct AffineBatch {
    /// Final band row per instance.
    pub band: Vec<[i32; BAND]>,
    /// Best distance per instance (saturated => unmappable here).
    pub best: Vec<i32>,
    /// Band coordinate of the best distance.
    pub best_j: Vec<u32>,
    /// Packed 4-bit traceback directions, row-major (read_len, BAND).
    pub dirs: Vec<Vec<u8>>,
}

/// A batched Wagner-Fischer compute engine.
///
/// The trait itself does not require `Send` (the PJRT client is
/// single-threaded by construction), but the pure-host engines
/// ([`RustEngine`], [`super::BitpalEngine`]) are `Send`; shard workers
/// construct one on their owning thread via [`EngineKind::build`].
pub trait WfEngine {
    /// Short engine name for logs and bench labels.
    fn name(&self) -> &'static str;

    /// Pre-alignment filter: banded linear WF over (read, window) pairs.
    /// All reads must share one length; windows must be read_len + 2*eth.
    fn linear_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<LinearBatch>;

    /// Read alignment: banded affine WF with traceback directions.
    fn affine_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<AffineBatch>;
}

// Boxed engines (the `EngineKind::build` product) are engines too, so a
// worker-built `Box<dyn WfEngine + Send>` can drive a `Pipeline` directly.
impl<E: WfEngine + ?Sized> WfEngine for Box<E> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn linear_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<LinearBatch> {
        (**self).linear_batch(reads, wins)
    }

    fn affine_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<AffineBatch> {
        (**self).affine_batch(reads, wins)
    }
}

pub(crate) fn check_batch(reads: &[&[u8]], wins: &[&[u8]]) -> Result<usize> {
    ensure!(!reads.is_empty(), "empty batch");
    ensure!(reads.len() == wins.len(), "reads/windows length mismatch");
    let n = reads[0].len();
    for (r, w) in reads.iter().zip(wins) {
        ensure!(r.len() == n, "mixed read lengths in batch");
        ensure!(w.len() == crate::params::window_len(n), "bad window length");
    }
    Ok(n)
}

/// Exact scalar linear filter over a batch — the reference filter path,
/// shared by [`RustEngine`] and the bit-parallel engine's `--simd off`
/// fallback.
pub(crate) fn scalar_linear_batch(reads: &[&[u8]], wins: &[&[u8]]) -> Result<LinearBatch> {
    check_batch(reads, wins)?;
    let mut out = LinearBatch {
        band: Vec::with_capacity(reads.len()),
        best: Vec::with_capacity(reads.len()),
        best_j: Vec::with_capacity(reads.len()),
    };
    for (r, w) in reads.iter().zip(wins) {
        let band = linear_wf_band(r, w);
        let (d, j) = best_of_band(&band);
        out.band.push(band);
        out.best.push(d);
        out.best_j.push(j as u32);
    }
    Ok(out)
}

/// Exact scalar affine WF + traceback directions over a batch — the
/// reference affine path, shared by [`RustEngine`] and the bit-parallel
/// engine's `--simd off` fallback.
pub(crate) fn scalar_affine_batch(reads: &[&[u8]], wins: &[&[u8]]) -> Result<AffineBatch> {
    check_batch(reads, wins)?;
    let mut out = AffineBatch {
        band: Vec::with_capacity(reads.len()),
        best: Vec::with_capacity(reads.len()),
        best_j: Vec::with_capacity(reads.len()),
        dirs: Vec::with_capacity(reads.len()),
    };
    for (r, w) in reads.iter().zip(wins) {
        let res = affine_wf_band(r, w);
        let (d, j) = best_of_band(&res.band);
        out.band.push(res.band);
        out.best.push(d);
        out.best_j.push(j as u32);
        out.dirs.push(res.dirs);
    }
    Ok(out)
}

/// Selector for engines that shard workers (and other threads) can
/// construct locally. The PJRT engine is deliberately absent: it is not
/// `Send`, so it only ever drives the single-threaded pipeline path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// The scalar pure-Rust reference engine.
    #[default]
    Rust,
    /// The bit-parallel delta-encoded filter engine.
    Bitpal,
}

impl EngineKind {
    /// Parse an engine name (`rust` / `bitpal`). `None` for engines that
    /// cannot be thread-constructed (e.g. `xla`) or unknown names.
    pub fn from_name(name: &str) -> Option<EngineKind> {
        match name {
            "rust" => Some(EngineKind::Rust),
            "bitpal" => Some(EngineKind::Bitpal),
            _ => None,
        }
    }

    /// The engine name (matches the CLI `--engine` spelling).
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Rust => "rust",
            EngineKind::Bitpal => "bitpal",
        }
    }

    /// Construct the engine at the default SIMD mode (`DART_PIM_SIMD`,
    /// else the widest host lane). Every variant is `Send`, so the
    /// result can be built and owned by a worker thread.
    pub fn build(self) -> Box<dyn WfEngine + Send> {
        self.build_simd(super::lanes::default_simd_mode())
    }

    /// Construct the engine at an explicit SIMD mode (the
    /// `PipelineConfig::simd` plumbing). The mode only affects the
    /// bit-parallel engine — [`EngineKind::Rust`] is always scalar —
    /// and never changes output bytes (determinism invariant 8).
    pub fn build_simd(self, simd: super::lanes::SimdMode) -> Box<dyn WfEngine + Send> {
        match self {
            EngineKind::Rust => Box::new(RustEngine),
            EngineKind::Bitpal => Box::new(super::BitpalEngine::with_mode(simd)),
        }
    }
}

/// Default worker-engine kind: the `DART_PIM_ENGINE` environment
/// variable when it names a thread-constructible engine (CI runs the
/// whole suite under `DART_PIM_ENGINE=bitpal`), else [`EngineKind::Rust`].
pub fn default_engine() -> EngineKind {
    std::env::var("DART_PIM_ENGINE")
        .ok()
        .and_then(|v| EngineKind::from_name(&v))
        .unwrap_or(EngineKind::Rust)
}

/// Pure-Rust engine (reference numerics; also models the DP-RISC-V
/// offload path, which runs the same WF in scalar code).
#[derive(Debug, Default, Clone)]
pub struct RustEngine;

impl WfEngine for RustEngine {
    fn name(&self) -> &'static str {
        "rust"
    }

    fn linear_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<LinearBatch> {
        scalar_linear_batch(reads, wins)
    }

    fn affine_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<AffineBatch> {
        scalar_affine_batch(reads, wins)
    }
}

#[cfg(test)]
mod kind_tests {
    use super::*;

    #[test]
    fn engine_kind_round_trips_names() {
        for kind in [EngineKind::Rust, EngineKind::Bitpal] {
            assert_eq!(EngineKind::from_name(kind.name()), Some(kind));
            assert_eq!(kind.build().name(), kind.name());
        }
        assert_eq!(EngineKind::from_name("xla"), None);
        assert_eq!(EngineKind::from_name("nope"), None);
    }

    #[test]
    fn built_engines_run_a_batch() {
        let read = vec![1u8; 20];
        let win = vec![1u8; crate::params::window_len(20)];
        for kind in [EngineKind::Rust, EngineKind::Bitpal] {
            let mut e = kind.build();
            let out = e.linear_batch(&[&read], &[&win]).unwrap();
            assert_eq!(out.best, vec![0], "{}", kind.name());
        }
    }

    #[test]
    fn build_simd_spans_every_mode() {
        use crate::runtime::lanes::SimdMode;
        let read = vec![1u8; 20];
        let win = vec![1u8; crate::params::window_len(20)];
        for kind in [EngineKind::Rust, EngineKind::Bitpal] {
            for mode in [SimdMode::U64, SimdMode::Wide, SimdMode::Off] {
                let mut e = kind.build_simd(mode);
                let out = e.linear_batch(&[&read], &[&win]).unwrap();
                assert_eq!(out.best, vec![0], "{} {}", kind.name(), mode.name());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::SmallRng;

    fn mk_batch(rng: &mut SmallRng, b: usize, n: usize) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
        let reads: Vec<Vec<u8>> =
            (0..b).map(|_| (0..n).map(|_| rng.gen_range(0..4)).collect()).collect();
        let wins: Vec<Vec<u8>> = reads
            .iter()
            .map(|r| {
                let mut w: Vec<u8> =
                    (0..crate::params::window_len(n)).map(|_| rng.gen_range(0..4)).collect();
                w[crate::params::ETH..crate::params::ETH + n].copy_from_slice(r);
                w
            })
            .collect();
        (reads, wins)
    }

    #[test]
    fn rust_engine_round_trip() {
        let mut rng = SmallRng::seed_from_u64(40);
        let (reads, wins) = mk_batch(&mut rng, 4, 30);
        let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
        let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
        let mut e = RustEngine;
        let lin = e.linear_batch(&rr, &ww).unwrap();
        assert_eq!(lin.best, vec![0, 0, 0, 0], "planted exact matches");
        let aff = e.affine_batch(&rr, &ww).unwrap();
        assert_eq!(aff.best, vec![0, 0, 0, 0]);
        assert!(aff.dirs.iter().all(|d| d.len() == 30 * BAND));
    }

    #[test]
    fn batch_validation() {
        let mut e = RustEngine;
        assert!(e.linear_batch(&[], &[]).is_err());
        let r = vec![0u8; 20];
        let w = vec![0u8; 20]; // wrong window length
        assert!(e.linear_batch(&[&r], &[&w]).is_err());
        let w2 = vec![0u8; 32];
        let r2 = vec![0u8; 10];
        assert!(e.linear_batch(&[&r, &r2], &[&w2, &w2]).is_err(), "mixed read lengths");
    }
}
