//! Bit-sliced (bit-plane) banded affine WF — the lane-parallel affine
//! stage of the bitpal engine.
//!
//! The linear filter has a pure boolean delta form (one plane per band
//! coordinate), but the affine recurrence carries three value layers
//! (D/M1/M2) plus 4-bit traceback directions, so deltas don't close over
//! it. Instead this module does what the paper's crossbars do for
//! arbitrary arithmetic (§IV: bit-serial MAGIC NOR over all rows at
//! once): it **bit-slices** the values. Every layer value at band
//! coordinate `j` is stored as [`B`] = 6 bit planes of a [`LaneWord`],
//! bit `k` of plane `p` holding bit `p` of instance `k`'s value. Adds
//! are ripple-carry over the planes, comparisons are borrow chains, and
//! selects are masks — each plane op advances *every lane at once*,
//! exactly the row-parallel compute the paper maps to memristive rows.
//!
//! # Exactness vs [`crate::align::banded_affine::affine_wf_band`]
//!
//! 6 planes represent `0..=63`. The scalar kernel's values are bounded:
//! layer inputs are clamped to `SAT_AFFINE = 31` every row, so within a
//! row `ext <= 32`, `opn <= 33`, `m1new <= 32`, `a <= 32`, `vsub <= 32`,
//! and `cbase <= 34` for `j >= 1`. The only unbounded scalar quantity is
//! the `BIG` pseudo-infinity seeding the M2 chain; substituting
//! [`INF`] = 62 preserves every comparison because 62 exceeds every real
//! operand above and `INF + W_EX = 63` still fits the planes. All
//! min/`<`/`<=` tie-breaks (prefer-open, sub < M1 < M2) are computed
//! with the same operand order as the scalar kernel, so values, bands,
//! and packed direction bytes are byte-identical — held by
//! `tests/engine_parity_bitpal.rs` and the unit tests below.

use crate::align::banded_linear::best_of_band;
use crate::params::{BAND, SAT_AFFINE, W_EX, W_OP, W_SUB};

use super::engine::AffineBatch;
use super::lanes::LaneWord;

/// Bit planes per value: enough for `0..=63`.
const B: usize = 6;

/// Pseudo-infinity seeding the M2 chain (replaces the scalar `BIG`;
/// see the module docs for why 62 is exact here).
const INF: i32 = 62;

// The plane count and clamp trick below hard-code the parameter values;
// fail the build, not the output, if they ever drift.
const _: () = assert!(SAT_AFFINE == 31 && W_SUB == 1 && W_OP == 1 && W_EX == 1);

/// One bit-sliced number: plane `p` holds bit `p` of every lane's value.
type Num<W> = [W; B];

/// Broadcast a constant into all lanes.
#[inline(always)]
fn splat<W: LaneWord>(v: i32) -> Num<W> {
    std::array::from_fn(|p| if (v >> p) & 1 == 1 { W::ONES } else { W::ZERO })
}

/// Lane-wise `x + c` by ripple carry (no overflow by the bounds above).
#[inline(always)]
fn add_const<W: LaneWord>(x: &Num<W>, c: i32) -> Num<W> {
    let mut out = [W::ZERO; B];
    let mut carry = W::ZERO;
    for p in 0..B {
        let cb = if (c >> p) & 1 == 1 { W::ONES } else { W::ZERO };
        let axc = x[p].xor(cb);
        out[p] = axc.xor(carry);
        carry = x[p].and(cb).or(carry.and(axc));
    }
    out
}

/// Lane mask of `a < b` (borrow-out of `a - b` over the planes).
#[inline(always)]
fn lt<W: LaneWord>(a: &Num<W>, b: &Num<W>) -> W {
    let mut borrow = W::ZERO;
    for p in 0..B {
        let na = a[p].not();
        borrow = na.and(b[p]).or(borrow.and(na.or(b[p])));
    }
    borrow
}

/// Lane mask of `a <= b`.
#[inline(always)]
fn le<W: LaneWord>(a: &Num<W>, b: &Num<W>) -> W {
    lt(b, a).not()
}

/// Lane-wise `mask ? a : b`.
#[inline(always)]
fn select<W: LaneWord>(mask: W, a: &Num<W>, b: &Num<W>) -> Num<W> {
    std::array::from_fn(|p| a[p].and(mask).or(b[p].andnot(mask)))
}

/// Lane-wise `min(a, b)` (ties keep `b`, matching `i32::min` values).
#[inline(always)]
fn min_n<W: LaneWord>(a: &Num<W>, b: &Num<W>) -> Num<W> {
    select(lt(a, b), a, b)
}

/// Lane-wise `min(x, SAT_AFFINE)` for `x in 0..=63`: bit 5 set means
/// `x >= 32 > 31`, so OR it into the low planes and clear it.
#[inline(always)]
fn clamp_sat<W: LaneWord>(x: &Num<W>) -> Num<W> {
    let m = x[B - 1];
    std::array::from_fn(|p| if p < B - 1 { x[p].or(m) } else { W::ZERO })
}

/// Read lane `k` of a bit-sliced number back as a scalar.
#[inline(always)]
fn decode<W: LaneWord>(x: &Num<W>, k: usize) -> i32 {
    let mut v = 0i32;
    for (p, plane) in x.iter().enumerate() {
        v |= i32::from(plane.lane(k)) << p;
    }
    v
}

/// Reusable scratch for [`affine_chunk`] (match planes + direction
/// planes), kept across batches to avoid per-call allocation.
#[derive(Debug)]
pub(crate) struct AffineScratch<W: LaneWord> {
    /// Match planes: `mt[i][j]` bit `k` = lane `k` matches at (row `i`,
    /// band `j`) — the *complement* polarity of the linear `mm` words.
    mt: Vec<[W; BAND]>,
    /// Direction planes per `(row, j)`: `[dd0, dd1, m1dir, m2dir]`.
    dirs: Vec<[W; 4]>,
}

// Manual impl: the derive would demand `W: Default`, which `LaneWord`
// deliberately does not imply.
impl<W: LaneWord> Default for AffineScratch<W> {
    fn default() -> Self {
        AffineScratch { mt: Vec::new(), dirs: Vec::new() }
    }
}

/// Run one `<= W::BITS`-instance chunk of the bit-sliced affine kernel
/// and append per-lane results (band, best, packed dirs) to `out`.
///
/// Inactive lanes compute on all-mismatch planes (every value stays in
/// bounds either way) and are never read back.
pub(crate) fn affine_chunk<W: LaneWord>(
    scratch: &mut AffineScratch<W>,
    reads: &[&[u8]],
    wins: &[&[u8]],
    out: &mut AffineBatch,
) {
    let lanes = reads.len();
    debug_assert!(lanes >= 1 && lanes <= W::BITS);
    let n = reads[0].len();

    // ---- match planes ----
    scratch.mt.clear();
    scratch.mt.resize(n, [W::ZERO; BAND]);
    for (k, (r, w)) in reads.iter().zip(wins).enumerate() {
        for (i, mrow) in scratch.mt.iter_mut().enumerate() {
            let rb = r[i];
            let g = &w[i..i + BAND];
            for j in 0..BAND {
                if rb == g[j] && rb < 4 {
                    mrow[j].set_lane(k);
                }
            }
        }
    }
    scratch.dirs.clear();
    scratch.dirs.resize(n * BAND, [W::ZERO; 4]);

    // ---- layer state: anchored init row |j - eth| for D, SAT for M1/M2 ----
    let init = crate::align::banded_linear::init_band();
    let mut d: [Num<W>; BAND] = std::array::from_fn(|j| splat(init[j]));
    let mut m1: [Num<W>; BAND] = std::array::from_fn(|_| splat(SAT_AFFINE));
    let mut m2: [Num<W>; BAND] = std::array::from_fn(|_| splat(SAT_AFFINE));
    let sat: Num<W> = splat(SAT_AFFINE);

    let mut m1new: [Num<W>; BAND] = std::array::from_fn(|_| splat(0));
    let mut m1dir = [W::ZERO; BAND];
    let mut m2raw: [Num<W>; BAND] = std::array::from_fn(|_| splat(0));
    let mut m2dir = [W::ZERO; BAND];
    let mut acc: [Num<W>; BAND] = std::array::from_fn(|_| splat(0));

    for (i, mrow) in scratch.mt.iter().enumerate() {
        // M1 (vertical: consume read base, gap in reference)
        for j in 0..BAND {
            let (up_m1, up_d) = if j < BAND - 1 { (&m1[j + 1], &d[j + 1]) } else { (&sat, &sat) };
            let ext = add_const(up_m1, W_EX);
            let opn = add_const(up_d, W_OP + W_EX);
            let open_loses = lt(&ext, &opn); // prefer open on ties
            m1new[j] = select(open_loses, &ext, &opn);
            m1dir[j] = open_loses;
            acc[j] = min_n(&m1new[j], &add_const(&d[j], W_SUB));
        }
        // M2 (horizontal) via the folded serial chain
        let mut prev: Num<W> = splat(INF);
        for j in 0..BAND {
            let cbase = if j == 0 {
                splat(INF)
            } else {
                add_const(&select(mrow[j - 1], &d[j - 1], &acc[j - 1]), W_OP + W_EX)
            };
            let pext = add_const(&prev, W_EX);
            let ext_wins = lt(&pext, &cbase); // prefer open on ties
            m2raw[j] = select(ext_wins, &pext, &cbase);
            m2dir[j] = ext_wins;
            prev = m2raw[j];
        }
        // D with deterministic origin priority: match, then sub<M1<M2.
        for j in 0..BAND {
            let vsub = add_const(&d[j], W_SUB);
            let sub_wins = le(&vsub, &m1new[j]).and(le(&vsub, &m2raw[j]));
            let m1_le_m2 = le(&m1new[j], &m2raw[j]);
            let dn_nm = min_n(&min_n(&vsub, &m1new[j]), &m2raw[j]);
            let mat = mrow[j];
            // dd encodes D_MATCH=0 / D_SUB=1 / D_M1=2 / D_M2=3 as two planes
            let dd0 = sub_wins.or(m1_le_m2.not()).andnot(mat);
            let dd1 = sub_wins.not().andnot(mat);
            let dn = select(mat, &d[j], &dn_nm);
            d[j] = clamp_sat(&dn);
            scratch.dirs[i * BAND + j] = [dd0, dd1, m1dir[j], m2dir[j]];
        }
        for j in 0..BAND {
            m1[j] = clamp_sat(&m1new[j]);
            m2[j] = clamp_sat(&m2raw[j]);
        }
    }

    // ---- per-lane readback: band + best + packed 4-bit dirs ----
    for k in 0..lanes {
        let mut band = [0i32; BAND];
        for (j, num) in d.iter().enumerate() {
            band[j] = decode(num, k);
        }
        let (best, best_j) = best_of_band(&band);
        let mut dirs = Vec::with_capacity(n * BAND);
        for planes in &scratch.dirs {
            let byte = u8::from(planes[0].lane(k))
                | u8::from(planes[1].lane(k)) << 1
                | u8::from(planes[2].lane(k)) << 2
                | u8::from(planes[3].lane(k)) << 3;
            dirs.push(byte);
        }
        out.band.push(band);
        out.best.push(best);
        out.best_j.push(best_j as u32);
        out.dirs.push(dirs);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::align::banded_affine::affine_wf_band;
    use crate::params::{window_len, ETH};
    use crate::util::SmallRng;

    #[test]
    fn sliced_arithmetic_matches_scalar() {
        for a in 0..=34i32 {
            for b in 0..=34i32 {
                let an = splat::<u64>(a);
                let bn = splat::<u64>(b);
                assert_eq!(decode(&add_const(&an, 2), 0), a + 2, "{a}+2");
                assert_eq!(lt(&an, &bn).lane(0), a < b, "{a}<{b}");
                assert_eq!(le(&an, &bn).lane(0), a <= b, "{a}<={b}");
                assert_eq!(decode(&min_n(&an, &bn), 0), a.min(b), "min({a},{b})");
            }
            assert_eq!(decode(&clamp_sat(&splat::<u64>(a)), 0), a.min(SAT_AFFINE));
        }
        assert_eq!(decode(&clamp_sat(&splat::<u64>(INF + 1)), 0), SAT_AFFINE);
    }

    fn rand_pair(rng: &mut SmallRng, n: usize, planted: bool) -> (Vec<u8>, Vec<u8>) {
        let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..5)).collect();
        let mut win: Vec<u8> = (0..window_len(n)).map(|_| rng.gen_range(0..4)).collect();
        if planted {
            win[ETH..ETH + n].copy_from_slice(&read);
            for _ in 0..rng.gen_range(0..4usize) {
                let p = rng.gen_range(ETH..ETH + n);
                win[p] = (win[p] + rng.gen_range(1..4u8)) % 4;
            }
        }
        (read, win)
    }

    fn chunk_parity<W: LaneWord>(seed: u64, b: usize, n: usize) {
        let mut rng = SmallRng::seed_from_u64(seed);
        let pairs: Vec<(Vec<u8>, Vec<u8>)> =
            (0..b).map(|i| rand_pair(&mut rng, n, i % 2 == 0)).collect();
        let rr: Vec<&[u8]> = pairs.iter().map(|(r, _)| r.as_slice()).collect();
        let ww: Vec<&[u8]> = pairs.iter().map(|(_, w)| w.as_slice()).collect();
        let mut out = AffineBatch {
            band: Vec::new(),
            best: Vec::new(),
            best_j: Vec::new(),
            dirs: Vec::new(),
        };
        affine_chunk::<W>(&mut AffineScratch::default(), &rr, &ww, &mut out);
        for (k, (r, w)) in pairs.iter().enumerate() {
            let res = affine_wf_band(r, w);
            assert_eq!(out.band[k], res.band, "seed={seed} lane={k} band");
            assert_eq!(out.dirs[k], res.dirs, "seed={seed} lane={k} dirs");
        }
    }

    #[test]
    fn chunk_matches_scalar_oracle_at_every_width() {
        chunk_parity::<u64>(0xAF01, 64, 30);
        chunk_parity::<u64>(0xAF02, 17, 64);
        chunk_parity::<[u64; 2]>(0xAF03, 128, 17);
        chunk_parity::<[u64; 4]>(0xAF04, 256, 30);
        chunk_parity::<[u64; 4]>(0xAF05, 70, 30);
        chunk_parity::<[u64; 8]>(0xAF06, 300, 17);
    }

    #[test]
    fn pseudo_infinity_clears_the_real_value_range() {
        // Every real operand of an M2 comparison is <= 34 (module docs);
        // INF and INF + W_EX must stay above that and inside the planes.
        assert!(INF > 2 + SAT_AFFINE + W_SUB + 1);
        assert!(INF + W_EX < (1 << B));
    }
}
