//! Bit-parallel batched WF engine (`--engine bitpal`), generic over the
//! machine lane width.
//!
//! The paper's speedup comes from executing the optimized Wagner-Fischer
//! recurrence bit-serially across *all crossbar rows at once* (§IV,
//! Fig. 5): every crossbar row holds one WF instance, and one broadcast
//! MAGIC op sequence advances every instance by one DP cell. The host
//! analog inverts the axes: a machine word holds **one bit lane per
//! instance slot**, and one word op advances every resident instance by
//! one DP cell — the Myers/BitPal family of bit-parallel alignment
//! encodings (Alser et al. 2020; Diab et al. 2022), re-derived for the
//! paper's *banded, anchored, saturating* recurrences. How many
//! instances one op advances is exactly the word width, so the kernels
//! here are generic over [`LaneWord`]: `u64` (64 lanes), or `[u64; N]`
//! for 128/256/512-bit lanes compiled to vector code on x86_64 (AVX2 /
//! AVX-512-selected) via `#[target_feature]` wrapper functions. The
//! [`SimdMode`] knob (`--simd`, `DART_PIM_SIMD`) picks the width at
//! runtime; `off` drops to the scalar reference kernels.
//!
//! # Delta encoding (linear filter)
//!
//! Band values are never materialized during the scan. Per band
//! coordinate `j` the engine tracks, as one lane word each:
//!
//! * `hp[j]` / `hm[j]` — the **horizontal delta** `V[j] - V[j-1]` of the
//!   current row, which is always in `{-1, 0, +1}` (`hp` = +1 lanes,
//!   `hm` = -1 lanes), and
//! * `d[j]` — the **diagonal delta** `V'[j] - V[j]` between consecutive
//!   rows, always in `{0, +1}`.
//!
//! For the banded recurrence
//! `raw[j] = min(V[j] + mm, V[j+1] + 1, raw[j-1] + 1)` the diagonal
//! delta has a pure boolean form (`d == 1` iff no min-term hits zero):
//!
//! ```text
//! d[j] = mm[j] & !hm[j+1] & !(hp[j] & !d[j-1])
//! ```
//!
//! and the new horizontal deltas follow from
//! `ΔH'[j] = ΔH[j] + d[j] - d[j-1]` (provably back in `{-1, 0, +1}`).
//! The absolute anchor value `V[row][0]` is carried as a bit-sliced
//! ripple counter (one increment-by-`d[0]` per row), so the scan does no
//! per-lane scalar work at all; lanes are only read back once at the
//! end.
//!
//! Two exactness arguments make the output identical to
//! [`super::RustEngine`]:
//!
//! * **Clamp commutation** — the scalar kernel saturates every row at
//!   `eth + 1`; saturating only the final row gives the same band
//!   because clamping is monotone and all recurrence increments are
//!   >= 0 (`min(min(u, S) + a, S) = min(u + a, S)` for `a >= 0`).
//! * **Early-exit equivalence** — the scalar kernel's all-saturated
//!   early exit returns exactly the all-`SAT` band the full recurrence
//!   would produce, so not early-exiting here changes nothing.
//!
//! The affine stage no longer serializes survivors through the scalar
//! kernel: it runs the bit-sliced plane arithmetic of
//! [`super::bitpal_affine`], byte-identical to `scalar_affine_batch`.
//! `tests/engine_parity_bitpal.rs` holds both stages to exact agreement
//! with [`super::RustEngine`] at every lane width.

use anyhow::Result;

use crate::align::banded_linear::best_of_band;
use crate::params::{BAND, ETH, SAT_LINEAR};

use super::bitpal_affine::{affine_chunk, AffineScratch};
use super::engine::{
    check_batch, scalar_affine_batch, scalar_linear_batch, AffineBatch, LinearBatch, WfEngine,
};
use super::lanes::{default_simd_mode, LaneWord, SimdMode, SimdWidth};

/// Widest supported lane (bits); bounds the v0-counter plane count so
/// `64 * 2^V0_PLANES` instance rows can never overflow it.
const MAX_LANES: usize = 512;

/// Bit planes of the v0 ripple counter (`v0 <= read_len`, so 16 planes
/// cover every read shorter than 64 kbp).
const V0_PLANES: usize = 16;

/// Run one `<= W::BITS`-instance chunk of the delta-encoded linear
/// filter and append per-lane results to `out`.
///
/// Inactive lanes (`reads.len() < W::BITS`) compute on all-zero
/// mismatch words; their results are simply never read back.
#[inline(always)]
fn linear_chunk<W: LaneWord>(
    mm: &mut Vec<[W; BAND]>,
    reads: &[&[u8]],
    wins: &[&[u8]],
    out: &mut LinearBatch,
) {
    let lanes = reads.len();
    debug_assert!(lanes >= 1 && lanes <= W::BITS && W::BITS <= MAX_LANES);
    let n = reads[0].len();
    debug_assert!(n < 1 << V0_PLANES, "read too long for the v0 counter");

    // ---- mismatch words: mm[i][j] bit k = lane k mismatches at
    // (row i, band j); the `r >= 4` term keeps N bases unmatchable,
    // exactly as in the scalar kernel ----
    mm.clear();
    mm.resize(n, [W::ZERO; BAND]);
    for (k, (r, w)) in reads.iter().zip(wins).enumerate() {
        for (i, mrow) in mm.iter_mut().enumerate() {
            let rb = r[i];
            let g = &w[i..i + BAND];
            for j in 0..BAND {
                if rb != g[j] || rb >= 4 {
                    mrow[j].set_lane(k);
                }
            }
        }
    }

    // ---- delta state of the anchored init row |j - eth|:
    // descending toward the anchor, ascending after it ----
    let mut hp = [W::ZERO; BAND];
    let mut hm = [W::ZERO; BAND];
    for j in 1..BAND {
        if j <= ETH {
            hm[j] = W::ONES;
        } else {
            hp[j] = W::ONES;
        }
    }
    // bit-sliced count of d[0] increments: V[row][0] = eth + decode(v0)
    let mut v0 = [W::ZERO; V0_PLANES];

    // ---- the scan: one anti-diagonal of all lanes per word op ----
    let mut d = [W::ZERO; BAND];
    for row in mm.iter() {
        d[0] = row[0].andnot(hm[1]);
        for j in 1..BAND {
            // j = BAND-1 has no top neighbour: its min-term can
            // never hit zero, so the mask is all-ones
            let t = row[j].andnot(hp[j].andnot(d[j - 1]));
            d[j] = if j < BAND - 1 { t.andnot(hm[j + 1]) } else { t };
        }
        for j in 1..BAND {
            let bp = d[j].andnot(d[j - 1]); // ΔH' contribution +1
            let bm = d[j - 1].andnot(d[j]); // ΔH' contribution -1
            let nhp = hp[j].andnot(bm).or(bp.andnot(hm[j]));
            let nhm = hm[j].andnot(bp).or(bm.andnot(hp[j]));
            hp[j] = nhp;
            hm[j] = nhm;
        }
        // v0 += d[0], lane-wise, by ripple carry over the planes
        let mut carry = d[0];
        for c in v0.iter_mut() {
            let nc = c.xor(carry);
            carry = c.and(carry);
            *c = nc;
        }
    }

    // ---- reconstruct per-lane bands (clamp once, at the end) ----
    for k in 0..lanes {
        let mut v = ETH as i32;
        for (p, c) in v0.iter().enumerate() {
            v += i32::from(c.lane(k)) << p;
        }
        let mut band = [0i32; BAND];
        band[0] = v.min(SAT_LINEAR);
        for j in 1..BAND {
            v += i32::from(hp[j].lane(k)) - i32::from(hm[j].lane(k));
            band[j] = v.min(SAT_LINEAR);
        }
        let (best, best_j) = best_of_band(&band);
        out.band.push(band);
        out.best.push(best);
        out.best_j.push(best_j as u32);
    }
}

/// One lane-width instantiation of both bit-parallel kernels, behind a
/// trait object so [`BitpalEngine`] can pick the width at runtime.
trait SimdKernel: Send {
    /// Lane count of this kernel.
    fn width_bits(&self) -> usize;
    /// Delta-encoded linear filter over a validated batch.
    fn linear(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut LinearBatch);
    /// Bit-sliced affine alignment over a validated batch.
    fn affine(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut AffineBatch);
}

/// The portable kernel pair at width `W`: pure bitwise Rust, correct on
/// every target. The x86_64 wrappers below recompile exactly this code
/// under wider target features.
struct PortableKernel<W: LaneWord> {
    /// Linear-filter mismatch words — scratch reused across batches.
    mm: Vec<[W; BAND]>,
    /// Affine match/direction planes — scratch reused across batches.
    affine: AffineScratch<W>,
}

impl<W: LaneWord> PortableKernel<W> {
    fn new() -> Self {
        PortableKernel { mm: Vec::new(), affine: AffineScratch::default() }
    }

    #[inline(always)]
    fn run_linear(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut LinearBatch) {
        for (rc, wc) in reads.chunks(W::BITS).zip(wins.chunks(W::BITS)) {
            linear_chunk(&mut self.mm, rc, wc, out);
        }
    }

    #[inline(always)]
    fn run_affine(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut AffineBatch) {
        for (rc, wc) in reads.chunks(W::BITS).zip(wins.chunks(W::BITS)) {
            affine_chunk(&mut self.affine, rc, wc, out);
        }
    }
}

impl<W: LaneWord> SimdKernel for PortableKernel<W> {
    fn width_bits(&self) -> usize {
        W::BITS
    }

    fn linear(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut LinearBatch) {
        self.run_linear(reads, wins, out);
    }

    fn affine(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut AffineBatch) {
        self.run_affine(reads, wins, out);
    }
}

/// x86_64 vector-compiled instantiations of the portable kernels.
///
/// No intrinsics: the `[u64; N]` plane ops are plain bitwise Rust, and
/// the `#[target_feature]` wrappers let LLVM lower each `[u64; 4]` op
/// to one 256-bit instruction (resp. two for `[u64; 8]`). The unsafe
/// surface is exactly the feature precondition, discharged by runtime
/// detection at construction time.
#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::*;

    /// 256-bit lanes under the AVX2 target feature.
    ///
    /// # Safety
    /// Construct only after `is_x86_feature_detected!("avx2")`.
    pub(super) struct Avx2Kernel(pub(super) PortableKernel<[u64; 4]>);

    /// # Safety
    /// Callable only when AVX2 is available; [`Avx2Kernel`] guarantees
    /// this by being constructed after runtime detection.
    #[target_feature(enable = "avx2")]
    unsafe fn linear_avx2(
        k: &mut PortableKernel<[u64; 4]>,
        reads: &[&[u8]],
        wins: &[&[u8]],
        out: &mut LinearBatch,
    ) {
        k.run_linear(reads, wins, out);
    }

    /// # Safety
    /// Callable only when AVX2 is available; [`Avx2Kernel`] guarantees
    /// this by being constructed after runtime detection.
    #[target_feature(enable = "avx2")]
    unsafe fn affine_avx2(
        k: &mut PortableKernel<[u64; 4]>,
        reads: &[&[u8]],
        wins: &[&[u8]],
        out: &mut AffineBatch,
    ) {
        k.run_affine(reads, wins, out);
    }

    impl SimdKernel for Avx2Kernel {
        fn width_bits(&self) -> usize {
            256
        }

        fn linear(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut LinearBatch) {
            // SAFETY: constructed only when AVX2 was detected at runtime.
            unsafe { linear_avx2(&mut self.0, reads, wins, out) }
        }

        fn affine(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut AffineBatch) {
            // SAFETY: constructed only when AVX2 was detected at runtime.
            unsafe { affine_avx2(&mut self.0, reads, wins, out) }
        }
    }

    /// 512-bit lanes, selected when AVX-512F is detected.
    ///
    /// Compiled under the `avx2` target feature (the `avx512f`
    /// target-feature attribute needs a newer rustc than our MSRV), so
    /// LLVM emits two 256-bit ops per plane op — wider lanes still
    /// halve the per-lane bookkeeping relative to 256-bit words.
    ///
    /// # Safety
    /// Construct only after `is_x86_feature_detected!("avx512f")`
    /// (which implies AVX2).
    pub(super) struct Avx512Kernel(pub(super) PortableKernel<[u64; 8]>);

    /// # Safety
    /// Callable only when AVX2 is available (AVX-512F detection implies
    /// it); [`Avx512Kernel`] guarantees this by being constructed after
    /// runtime detection.
    #[target_feature(enable = "avx2")]
    unsafe fn linear_avx512(
        k: &mut PortableKernel<[u64; 8]>,
        reads: &[&[u8]],
        wins: &[&[u8]],
        out: &mut LinearBatch,
    ) {
        k.run_linear(reads, wins, out);
    }

    /// # Safety
    /// Callable only when AVX2 is available (AVX-512F detection implies
    /// it); [`Avx512Kernel`] guarantees this by being constructed after
    /// runtime detection.
    #[target_feature(enable = "avx2")]
    unsafe fn affine_avx512(
        k: &mut PortableKernel<[u64; 8]>,
        reads: &[&[u8]],
        wins: &[&[u8]],
        out: &mut AffineBatch,
    ) {
        k.run_affine(reads, wins, out);
    }

    impl SimdKernel for Avx512Kernel {
        fn width_bits(&self) -> usize {
            512
        }

        fn linear(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut LinearBatch) {
            // SAFETY: constructed only when AVX-512F (=> AVX2) was detected.
            unsafe { linear_avx512(&mut self.0, reads, wins, out) }
        }

        fn affine(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut AffineBatch) {
            // SAFETY: constructed only when AVX-512F (=> AVX2) was detected.
            unsafe { affine_avx512(&mut self.0, reads, wins, out) }
        }
    }
}

/// The portable kernel at an explicitly forced width.
fn portable_kernel(width: SimdWidth) -> Box<dyn SimdKernel> {
    match width {
        SimdWidth::W64 => Box::new(PortableKernel::<u64>::new()),
        SimdWidth::W128 => Box::new(PortableKernel::<[u64; 2]>::new()),
        SimdWidth::W256 => Box::new(PortableKernel::<[u64; 4]>::new()),
        SimdWidth::W512 => Box::new(PortableKernel::<[u64; 8]>::new()),
    }
}

/// The best kernel for `width` on this host: the vector-compiled x86
/// wrappers when their features are present, else the portable code.
fn make_kernel(width: SimdWidth) -> Box<dyn SimdKernel> {
    #[cfg(target_arch = "x86_64")]
    {
        // dart-analyze: allow(determinism): feature detection selects
        // between kernels that are bit-identical by contract (the x86
        // wrappers wrap the portable kernel they must agree with, held
        // by the kernel-equivalence tests); detection changes speed,
        // never bytes (invariant 8).
        if width == SimdWidth::W512 && std::arch::is_x86_feature_detected!("avx512f") {
            return Box::new(x86::Avx512Kernel(PortableKernel::new()));
        }
        if width == SimdWidth::W256 && std::arch::is_x86_feature_detected!("avx2") {
            return Box::new(x86::Avx2Kernel(PortableKernel::new()));
        }
    }
    portable_kernel(width)
}

/// Bit-parallel linear filter + bit-sliced affine, lane-width selected
/// at construction.
///
/// `Send` (unlike the PJRT engine), so shard workers can own one and the
/// engine composes with `--threads N`. The width NEVER changes output
/// bytes (determinism invariant 8); `SimdMode::Off` swaps in the exact
/// scalar reference kernels.
pub struct BitpalEngine {
    /// The mode this engine was built with.
    mode: SimdMode,
    /// Resolved lane width in bits (0 = scalar fallback).
    width_bits: usize,
    /// The width-specialized kernel pair; `None` = scalar fallback.
    kern: Option<Box<dyn SimdKernel>>,
}

impl BitpalEngine {
    /// A fresh engine at the default SIMD mode (`DART_PIM_SIMD`, else
    /// the widest host lane).
    pub fn new() -> Self {
        BitpalEngine::with_mode(default_simd_mode())
    }

    /// An engine pinned to `mode` (the `--simd` flag's entry point).
    pub fn with_mode(mode: SimdMode) -> Self {
        match mode.resolve() {
            None => BitpalEngine { mode, width_bits: 0, kern: None },
            Some(w) => {
                BitpalEngine { mode, width_bits: w.bits(), kern: Some(make_kernel(w)) }
            }
        }
    }

    /// An engine forced onto the *portable* kernel at an explicit width,
    /// regardless of host features — every width is correct everywhere,
    /// so parity suites and benches can sweep all of [`SimdWidth::all`]
    /// on any machine.
    pub fn portable(width: SimdWidth) -> Self {
        BitpalEngine {
            mode: SimdMode::Wide,
            width_bits: width.bits(),
            kern: Some(portable_kernel(width)),
        }
    }

    /// The SIMD mode this engine was built with.
    pub fn mode(&self) -> SimdMode {
        self.mode
    }

    /// Resolved lane width in bits (0 when the scalar fallback is
    /// active) — what the `simd_width` metrics counter reports.
    pub fn width_bits(&self) -> usize {
        self.width_bits
    }
}

impl Default for BitpalEngine {
    fn default() -> Self {
        BitpalEngine::new()
    }
}

impl std::fmt::Debug for BitpalEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("BitpalEngine")
            .field("simd", &self.mode.name())
            .field("width_bits", &self.width_bits)
            .finish()
    }
}

impl WfEngine for BitpalEngine {
    fn name(&self) -> &'static str {
        "bitpal"
    }

    fn linear_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<LinearBatch> {
        match &mut self.kern {
            None => scalar_linear_batch(reads, wins),
            Some(k) => {
                check_batch(reads, wins)?;
                let mut out = LinearBatch {
                    band: Vec::with_capacity(reads.len()),
                    best: Vec::with_capacity(reads.len()),
                    best_j: Vec::with_capacity(reads.len()),
                };
                k.linear(reads, wins, &mut out);
                Ok(out)
            }
        }
    }

    fn affine_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<AffineBatch> {
        match &mut self.kern {
            None => scalar_affine_batch(reads, wins),
            Some(k) => {
                check_batch(reads, wins)?;
                let mut out = AffineBatch {
                    band: Vec::with_capacity(reads.len()),
                    best: Vec::with_capacity(reads.len()),
                    best_j: Vec::with_capacity(reads.len()),
                    dirs: Vec::with_capacity(reads.len()),
                };
                k.affine(reads, wins, &mut out);
                Ok(out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::window_len;
    use crate::runtime::RustEngine;
    use crate::util::SmallRng;

    fn planted_batch(
        rng: &mut SmallRng,
        b: usize,
        n: usize,
        subs: usize,
    ) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
        let reads: Vec<Vec<u8>> =
            (0..b).map(|_| (0..n).map(|_| rng.gen_range(0..4)).collect()).collect();
        let wins: Vec<Vec<u8>> = reads
            .iter()
            .map(|r| {
                let mut w: Vec<u8> =
                    (0..window_len(n)).map(|_| rng.gen_range(0..4)).collect();
                w[ETH..ETH + n].copy_from_slice(r);
                for _ in 0..subs {
                    let p = rng.gen_range(ETH..ETH + n);
                    w[p] = (w[p] + rng.gen_range(1..4u8)) % 4;
                }
                w
            })
            .collect();
        (reads, wins)
    }

    fn as_slices(v: &[Vec<u8>]) -> Vec<&[u8]> {
        v.iter().map(|x| x.as_slice()).collect()
    }

    /// Every engine variant the unit tests sweep: the three modes plus
    /// all four portable widths.
    fn variants() -> Vec<(String, BitpalEngine)> {
        let mut v: Vec<(String, BitpalEngine)> = [SimdMode::U64, SimdMode::Wide, SimdMode::Off]
            .into_iter()
            .map(|m| (format!("mode={}", m.name()), BitpalEngine::with_mode(m)))
            .collect();
        for w in SimdWidth::all() {
            v.push((format!("portable={}", w.bits()), BitpalEngine::portable(w)));
        }
        v
    }

    #[test]
    fn planted_matches_are_zero() {
        for (label, mut e) in variants() {
            let mut rng = SmallRng::seed_from_u64(70);
            let (reads, wins) = planted_batch(&mut rng, 5, 40, 0);
            let out = e.linear_batch(&as_slices(&reads), &as_slices(&wins)).unwrap();
            assert_eq!(out.best, vec![0; 5], "{label}");
            assert_eq!(out.best_j, vec![ETH as u32; 5], "{label}");
        }
    }

    #[test]
    fn chunking_covers_batches_off_the_lane_grid() {
        let mut rng = SmallRng::seed_from_u64(71);
        for b in [1usize, 63, 64, 65, 127, 128, 129, 130] {
            let (reads, wins) = planted_batch(&mut rng, b, 30, 2);
            let rr = as_slices(&reads);
            let ww = as_slices(&wins);
            let rust = RustEngine.linear_batch(&rr, &ww).unwrap();
            for (label, mut e) in variants() {
                let bit = e.linear_batch(&rr, &ww).unwrap();
                assert_eq!(bit.best, rust.best, "{label} b={b}");
                assert_eq!(bit.best_j, rust.best_j, "{label} b={b}");
                assert_eq!(bit.band, rust.band, "{label} b={b}");
            }
        }
    }

    #[test]
    fn all_mismatch_saturates_at_band_center() {
        let read = vec![0u8; 30];
        let win = vec![1u8; window_len(30)];
        for (label, mut e) in variants() {
            let out = e.linear_batch(&[&read], &[&win]).unwrap();
            assert_eq!(out.best, vec![SAT_LINEAR], "{label}");
            assert_eq!(out.best_j, vec![ETH as u32], "{label}");
        }
    }

    #[test]
    fn n_bases_never_match() {
        // base code 4 (N) mismatches even against itself
        let read = vec![4u8; 20];
        let win = vec![4u8; window_len(20)];
        let rust = RustEngine.linear_batch(&[&read], &[&win]).unwrap();
        assert!(rust.best[0] > 0);
        for (label, mut e) in variants() {
            let out = e.linear_batch(&[&read], &[&win]).unwrap();
            assert_eq!(out.best, rust.best, "{label}");
            assert_eq!(out.band, rust.band, "{label}");
        }
    }

    #[test]
    fn rejects_malformed_batches() {
        for (label, mut e) in variants() {
            assert!(e.linear_batch(&[], &[]).is_err(), "{label}");
            let r = vec![0u8; 20];
            let w = vec![0u8; 20]; // wrong window length
            assert!(e.linear_batch(&[&r], &[&w]).is_err(), "{label}");
            assert!(e.affine_batch(&[&r], &[&w]).is_err(), "{label}");
        }
    }

    #[test]
    fn affine_matches_the_scalar_path_everywhere() {
        let mut rng = SmallRng::seed_from_u64(72);
        let (reads, wins) = planted_batch(&mut rng, 70, 30, 1);
        let rr = as_slices(&reads);
        let ww = as_slices(&wins);
        let rust = RustEngine.affine_batch(&rr, &ww).unwrap();
        for (label, mut e) in variants() {
            let bit = e.affine_batch(&rr, &ww).unwrap();
            assert_eq!(bit.best, rust.best, "{label}");
            assert_eq!(bit.best_j, rust.best_j, "{label}");
            assert_eq!(bit.dirs, rust.dirs, "{label}");
        }
    }

    #[test]
    fn width_resolution_is_visible() {
        assert_eq!(BitpalEngine::with_mode(SimdMode::U64).width_bits(), 64);
        assert_eq!(BitpalEngine::with_mode(SimdMode::Off).width_bits(), 0);
        assert!(BitpalEngine::with_mode(SimdMode::Wide).width_bits() >= 64);
        for w in SimdWidth::all() {
            assert_eq!(BitpalEngine::portable(w).width_bits(), w.bits());
        }
    }
}
