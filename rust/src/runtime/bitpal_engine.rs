//! Bit-parallel batched linear-filter engine (`--engine bitpal`).
//!
//! The paper's speedup comes from executing the optimized Wagner-Fischer
//! recurrence bit-serially across *all crossbar rows at once* (§IV,
//! Fig. 5): every crossbar row holds one WF instance, and one broadcast
//! MAGIC op sequence advances every instance by one DP cell. The closest
//! host analog inverts the axes: a 64-bit machine word holds **one bit
//! lane per instance slot**, and one word op advances up to 64 instances
//! by one DP cell — the Myers/BitPal family of bit-parallel alignment
//! encodings (Alser et al. 2020; Diab et al. 2022), re-derived here for
//! the paper's *banded, anchored, saturating* linear recurrence.
//!
//! # Delta encoding
//!
//! Band values are never materialized during the scan. Per band
//! coordinate `j` the engine tracks, as one `u64` word each:
//!
//! * `hp[j]` / `hm[j]` — the **horizontal delta** `V[j] - V[j-1]` of the
//!   current row, which is always in `{-1, 0, +1}` (`hp` = +1 lanes,
//!   `hm` = -1 lanes), and
//! * `d[j]` — the **diagonal delta** `V'[j] - V[j]` between consecutive
//!   rows, always in `{0, +1}`.
//!
//! For the banded recurrence
//! `raw[j] = min(V[j] + mm, V[j+1] + 1, raw[j-1] + 1)` the diagonal
//! delta has a pure boolean form (`d == 1` iff no min-term hits zero):
//!
//! ```text
//! d[j] = mm[j] & !hm[j+1] & !(hp[j] & !d[j-1])
//! ```
//!
//! and the new horizontal deltas follow from
//! `ΔH'[j] = ΔH[j] + d[j] - d[j-1]` (provably back in `{-1, 0, +1}`).
//! One row of one 64-instance batch therefore costs ~13 word ops per
//! band coordinate instead of 64 scalar min-chains.
//!
//! Two exactness arguments make the output identical to
//! [`super::RustEngine`]:
//!
//! * **Clamp commutation** — the scalar kernel saturates every row at
//!   `eth + 1`; saturating only the final row gives the same band
//!   because clamping is monotone and all recurrence increments are
//!   >= 0 (`min(min(u, S) + a, S) = min(u + a, S)` for `a >= 0`).
//! * **Early-exit equivalence** — the scalar kernel's all-saturated
//!   early exit returns exactly the all-`SAT` band the full recurrence
//!   would produce, so not early-exiting here changes nothing.
//!
//! The affine stage keeps exact scalar WF + traceback: only filter
//! *survivors* reach it (a few percent of instances), and the packed
//! 4-bit direction planes it must emit have no bit-parallel encoding
//! with the same numerics contract. `tests/engine_parity_bitpal.rs`
//! holds both stages to exact agreement with [`super::RustEngine`].

use anyhow::Result;

use crate::align::banded_linear::best_of_band;
use crate::params::{BAND, ETH, SAT_LINEAR};

use super::engine::{check_batch, scalar_affine_batch, AffineBatch, LinearBatch, WfEngine};

/// Instance slots per machine word: one bit lane each.
pub const LANES: usize = 64;

/// Bit-parallel linear filter + exact scalar affine fallback.
///
/// `Send` (unlike the PJRT engine), so shard workers can own one and the
/// engine composes with `--threads N`.
#[derive(Debug, Default, Clone)]
pub struct BitpalEngine {
    /// Mismatch words, `mm[i][j]` = one bit per lane — scratch reused
    /// across batches to avoid per-call allocation.
    mm: Vec<[u64; BAND]>,
}

impl BitpalEngine {
    /// A fresh engine (no artifacts to load; state is scratch only).
    pub fn new() -> Self {
        BitpalEngine::default()
    }

    /// Run one <= 64-instance chunk and append its results to `out`.
    ///
    /// Inactive lanes (`reads.len() < 64`) compute on all-zero mismatch
    /// words; their results are simply never read back.
    fn linear_chunk(&mut self, reads: &[&[u8]], wins: &[&[u8]], out: &mut LinearBatch) {
        let lanes = reads.len();
        debug_assert!(lanes >= 1 && lanes <= LANES);
        let n = reads[0].len();

        // ---- mismatch words: mm[i][j] bit k = lane k mismatches at
        // (row i, band j); the `r >= 4` term keeps N bases unmatchable,
        // exactly as in the scalar kernel ----
        self.mm.clear();
        self.mm.resize(n, [0u64; BAND]);
        for (k, (r, w)) in reads.iter().zip(wins).enumerate() {
            for (i, mrow) in self.mm.iter_mut().enumerate() {
                let rb = r[i];
                let g = &w[i..i + BAND];
                for j in 0..BAND {
                    let mm = rb != g[j] || rb >= 4;
                    mrow[j] |= u64::from(mm) << k;
                }
            }
        }

        // ---- delta state of the anchored init row |j - eth|:
        // descending toward the anchor, ascending after it ----
        let mut hp = [0u64; BAND];
        let mut hm = [0u64; BAND];
        for j in 1..BAND {
            if j <= ETH {
                hm[j] = !0;
            } else {
                hp[j] = !0;
            }
        }
        // absolute value of V[row][0] per lane (init row: |0 - eth|)
        let mut v0 = [ETH as i32; LANES];

        // ---- the scan: one anti-diagonal of all lanes per word op ----
        let mut d = [0u64; BAND];
        for row in &self.mm {
            d[0] = row[0] & !hm[1];
            for j in 1..BAND {
                // j = BAND-1 has no top neighbour: its min-term can
                // never hit zero, so the mask is all-ones
                let top_nonzero = if j < BAND - 1 { !hm[j + 1] } else { !0 };
                d[j] = row[j] & top_nonzero & !(hp[j] & !d[j - 1]);
            }
            for j in 1..BAND {
                let bp = d[j] & !d[j - 1]; // ΔH' contribution +1
                let bm = !d[j] & d[j - 1]; // ΔH' contribution -1
                let nhp = (hp[j] & !bm) | (bp & !hm[j]);
                let nhm = (hm[j] & !bp) | (bm & !hp[j]);
                hp[j] = nhp;
                hm[j] = nhm;
            }
            let d0 = d[0];
            for (k, v) in v0.iter_mut().enumerate().take(lanes) {
                *v += ((d0 >> k) & 1) as i32;
            }
        }

        // ---- reconstruct per-lane bands (clamp once, at the end) ----
        for k in 0..lanes {
            let mut v = v0[k];
            let mut band = [0i32; BAND];
            band[0] = v.min(SAT_LINEAR);
            for j in 1..BAND {
                v += ((hp[j] >> k) & 1) as i32 - ((hm[j] >> k) & 1) as i32;
                band[j] = v.min(SAT_LINEAR);
            }
            let (best, best_j) = best_of_band(&band);
            out.band.push(band);
            out.best.push(best);
            out.best_j.push(best_j as u32);
        }
    }
}

impl WfEngine for BitpalEngine {
    fn name(&self) -> &'static str {
        "bitpal"
    }

    fn linear_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<LinearBatch> {
        check_batch(reads, wins)?;
        let mut out = LinearBatch {
            band: Vec::with_capacity(reads.len()),
            best: Vec::with_capacity(reads.len()),
            best_j: Vec::with_capacity(reads.len()),
        };
        for (rc, wc) in reads.chunks(LANES).zip(wins.chunks(LANES)) {
            self.linear_chunk(rc, wc, &mut out);
        }
        Ok(out)
    }

    fn affine_batch(&mut self, reads: &[&[u8]], wins: &[&[u8]]) -> Result<AffineBatch> {
        // Exact scalar affine + traceback: only filter survivors get here.
        scalar_affine_batch(reads, wins)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::window_len;
    use crate::runtime::RustEngine;
    use crate::util::SmallRng;

    fn planted_batch(
        rng: &mut SmallRng,
        b: usize,
        n: usize,
        subs: usize,
    ) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
        let reads: Vec<Vec<u8>> =
            (0..b).map(|_| (0..n).map(|_| rng.gen_range(0..4)).collect()).collect();
        let wins: Vec<Vec<u8>> = reads
            .iter()
            .map(|r| {
                let mut w: Vec<u8> =
                    (0..window_len(n)).map(|_| rng.gen_range(0..4)).collect();
                w[ETH..ETH + n].copy_from_slice(r);
                for _ in 0..subs {
                    let p = rng.gen_range(ETH..ETH + n);
                    w[p] = (w[p] + rng.gen_range(1..4u8)) % 4;
                }
                w
            })
            .collect();
        (reads, wins)
    }

    fn as_slices(v: &[Vec<u8>]) -> Vec<&[u8]> {
        v.iter().map(|x| x.as_slice()).collect()
    }

    #[test]
    fn planted_matches_are_zero() {
        let mut rng = SmallRng::seed_from_u64(70);
        let (reads, wins) = planted_batch(&mut rng, 5, 40, 0);
        let out =
            BitpalEngine::new().linear_batch(&as_slices(&reads), &as_slices(&wins)).unwrap();
        assert_eq!(out.best, vec![0; 5]);
        assert_eq!(out.best_j, vec![ETH as u32; 5]);
    }

    #[test]
    fn chunking_covers_batches_beyond_64_lanes() {
        let mut rng = SmallRng::seed_from_u64(71);
        for b in [1usize, 63, 64, 65, 130] {
            let (reads, wins) = planted_batch(&mut rng, b, 30, 2);
            let rr = as_slices(&reads);
            let ww = as_slices(&wins);
            let bit = BitpalEngine::new().linear_batch(&rr, &ww).unwrap();
            let rust = RustEngine.linear_batch(&rr, &ww).unwrap();
            assert_eq!(bit.best, rust.best, "b={b}");
            assert_eq!(bit.best_j, rust.best_j, "b={b}");
            assert_eq!(bit.band, rust.band, "b={b}");
        }
    }

    #[test]
    fn all_mismatch_saturates_at_band_center() {
        let read = vec![0u8; 30];
        let win = vec![1u8; window_len(30)];
        let out = BitpalEngine::new().linear_batch(&[&read], &[&win]).unwrap();
        assert_eq!(out.best, vec![SAT_LINEAR]);
        assert_eq!(out.best_j, vec![ETH as u32]);
    }

    #[test]
    fn n_bases_never_match() {
        // base code 4 (N) mismatches even against itself
        let read = vec![4u8; 20];
        let win = vec![4u8; window_len(20)];
        let out = BitpalEngine::new().linear_batch(&[&read], &[&win]).unwrap();
        assert!(out.best[0] > 0);
        let rust = RustEngine.linear_batch(&[&read], &[&win]).unwrap();
        assert_eq!(out.best, rust.best);
        assert_eq!(out.band, rust.band);
    }

    #[test]
    fn rejects_malformed_batches() {
        let mut e = BitpalEngine::new();
        assert!(e.linear_batch(&[], &[]).is_err());
        let r = vec![0u8; 20];
        let w = vec![0u8; 20]; // wrong window length
        assert!(e.linear_batch(&[&r], &[&w]).is_err());
    }

    #[test]
    fn affine_fallback_is_the_scalar_path() {
        let mut rng = SmallRng::seed_from_u64(72);
        let (reads, wins) = planted_batch(&mut rng, 6, 30, 1);
        let rr = as_slices(&reads);
        let ww = as_slices(&wins);
        let bit = BitpalEngine::new().affine_batch(&rr, &ww).unwrap();
        let rust = RustEngine.affine_batch(&rr, &ww).unwrap();
        assert_eq!(bit.best, rust.best);
        assert_eq!(bit.best_j, rust.best_j);
        assert_eq!(bit.dirs, rust.dirs);
    }
}
