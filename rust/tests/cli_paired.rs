//! CLI error paths for paired-end input: every structural failure —
//! mismatched R1/R2 record counts, mate-name mismatches, conflicting
//! paired flags, paired stdin misuse, length-divergent mates — must
//! abort with an error that locates the problem (1-based record/pair
//! ordinal and read name), and the interleaved-stdin happy path must be
//! byte-identical to a file-fed run in a real subprocess.

use std::io::Write as _;
use std::process::{Command, Stdio};

use dart_pim::cli;

fn run(cmd: &str) -> anyhow::Result<()> {
    let argv: Vec<String> = cmd.split_whitespace().map(|s| s.to_string()).collect();
    cli::run(&argv)
}

fn setup(tag: &str) -> (std::path::PathBuf, String) {
    let dir = std::env::temp_dir().join(format!("dartpim-clip-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let d = dir.to_str().unwrap().to_string();
    run(&format!("synth --out-dir {d} --len 60000 --reads 8 --paired")).unwrap();
    (dir, d)
}

#[test]
fn mismatched_mate_counts_error_names_pair_and_read() {
    let (dir, d) = setup("counts");
    // drop the last record (4 lines) of R2
    let r2 = std::fs::read_to_string(dir.join("reads_2.fastq")).unwrap();
    let lines: Vec<&str> = r2.lines().collect();
    let truncated: String =
        lines[..lines.len() - 4].iter().map(|l| format!("{l}\n")).collect();
    std::fs::write(dir.join("short_2.fastq"), truncated).unwrap();
    let err = run(&format!(
        "map --ref {d}/ref.fasta --reads {d}/reads_1.fastq --reads2 {d}/short_2.fastq \
         --low-th 0 --out {d}/x.tsv"
    ))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("#8") && msg.contains("pair7/1") && msg.contains("R2"),
        "error must locate the unmatched mate: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn mate_name_mismatch_error_names_both_reads() {
    let (dir, d) = setup("names");
    // rename the *second* R2 record so the failure is mid-stream
    let r2 = std::fs::read_to_string(dir.join("reads_2.fastq")).unwrap();
    let renamed = r2.replace("@pair1/2", "@intruder/2");
    std::fs::write(dir.join("renamed_2.fastq"), renamed).unwrap();
    let err = run(&format!(
        "map --ref {d}/ref.fasta --reads {d}/reads_1.fastq --reads2 {d}/renamed_2.fastq \
         --low-th 0 --out {d}/x.tsv"
    ))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("#2") && msg.contains("pair1/1") && msg.contains("intruder/2"),
        "error must name the pair ordinal and both reads: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn interleaved_conflicts_with_reads2() {
    let (dir, d) = setup("conflict");
    let err = run(&format!(
        "map --ref {d}/ref.fasta --reads {d}/reads_1.fastq --reads2 {d}/reads_2.fastq \
         --interleaved --out {d}/x.tsv"
    ))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("--reads2") && msg.contains("--interleaved"),
        "error must name the conflicting flags: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn double_stdin_paired_input_is_rejected_with_guidance() {
    let (dir, d) = setup("stdin2");
    let err = run(&format!("map --ref {d}/ref.fasta --reads - --reads2 - --out {d}/x.tsv"))
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("stdin") && msg.contains("--interleaved"),
        "error must point at the interleaved alternative: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn interleaved_stream_ending_mid_pair_errors_with_position() {
    let (dir, d) = setup("odd");
    // drop the final record so the interleaved stream holds 15 records
    let il = std::fs::read_to_string(dir.join("reads_interleaved.fastq")).unwrap();
    let lines: Vec<&str> = il.lines().collect();
    let odd: String = lines[..lines.len() - 4].iter().map(|l| format!("{l}\n")).collect();
    std::fs::write(dir.join("odd.fastq"), odd).unwrap();
    let err = run(&format!(
        "map --ref {d}/ref.fasta --reads {d}/odd.fastq --interleaved --low-th 0 --out {d}/x.tsv"
    ))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("#8") && msg.contains("pair7/1") && msg.contains("mid-pair"),
        "error must locate the unmatched interleaved record: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn length_divergent_mate_errors_with_ordinal_and_name() {
    let (dir, d) = setup("lens");
    // shrink the second R2 record's sequence+quality to 30 bp
    let r2 = std::fs::read_to_string(dir.join("reads_2.fastq")).unwrap();
    let mut lines: Vec<String> = r2.lines().map(|l| l.to_string()).collect();
    lines[5] = lines[5][..30].to_string(); // pair1/2 sequence
    lines[7] = lines[7][..30].to_string(); // pair1/2 quality
    let patched: String = lines.iter().map(|l| format!("{l}\n")).collect();
    std::fs::write(dir.join("short_read_2.fastq"), patched).unwrap();
    let err = run(&format!(
        "map --ref {d}/ref.fasta --reads {d}/reads_1.fastq --reads2 {d}/short_read_2.fastq \
         --low-th 0 --out {d}/x.tsv"
    ))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("#2") && msg.contains("pair1/2") && msg.contains("30"),
        "error must locate the divergent mate: {msg}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Paired stdin happy path, as a real subprocess: `--interleaved
/// --reads -` fed the interleaved FASTQ over stdin must emit exactly
/// the bytes of the file-fed two-file run.
#[test]
fn interleaved_stdin_matches_file_fed_paired_run() {
    let (dir, d) = setup("stdinok");
    run(&format!(
        "map --ref {d}/ref.fasta --reads {d}/reads_1.fastq --reads2 {d}/reads_2.fastq \
         --low-th 0 --threads 2 --out {d}/file.tsv"
    ))
    .unwrap();
    let expected = std::fs::read_to_string(dir.join("file.tsv")).unwrap();
    assert!(expected.lines().count() > 8, "most mates should map:\n{expected}");

    let fastq = std::fs::read(dir.join("reads_interleaved.fastq")).unwrap();
    let mut child = Command::new(env!("CARGO_BIN_EXE_dart-pim"))
        .args([
            "map",
            "--ref",
            &format!("{d}/ref.fasta"),
            "--reads",
            "-",
            "--interleaved",
            "--low-th",
            "0",
            "--threads",
            "2",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn dart-pim");
    child.stdin.as_mut().unwrap().write_all(&fastq).unwrap();
    let out = child.wait_with_output().unwrap();
    assert!(
        out.status.success(),
        "paired stdin map failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(
        expected,
        String::from_utf8_lossy(&out.stdout),
        "interleaved stdin must be byte-identical to the file-fed paired run"
    );
    std::fs::remove_dir_all(&dir).ok();
}
