//! Shared test-support: the randomized workload builders and TSV
//! renderers that the determinism/parity suites and the benches all
//! use. One definition, so "the same workload shape" means exactly
//! that across `engine_parity_bitpal`, `stream_parity`,
//! `shard_determinism`, `pair_parity`, and the engine benches (which
//! include this file via `#[path]`).
//!
//! Each integration-test binary compiles its own copy and typically
//! uses a subset, hence the module-wide dead_code allowance.
#![allow(dead_code)]

use dart_pim::coordinator::FinalMapping;
use dart_pim::genome::mutate::MutateConfig;
use dart_pim::genome::synth::{PairSimConfig, ReadSimConfig, SynthConfig};
use dart_pim::genome::ReadRecord;
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{window_len, ETH, K, READ_LEN, W};
use dart_pim::util::SmallRng;

/// Donor-derived randomized single-end workload (SNPs + indels between
/// donor and reference, sequencing errors on top) — the standard shape
/// of the determinism suites, chosen so ties and near-ties actually
/// occur.
pub fn workload_sized(genome_len: usize, n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
    let genome = SynthConfig { len: genome_len, ..Default::default() }.generate();
    let donor = MutateConfig::default().apply(&genome);
    let idx = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads =
        ReadSimConfig { n_reads, ..Default::default() }.simulate(&donor.seq, donor.mapper());
    (idx, reads)
}

/// [`workload_sized`] at the suites' historical default genome size.
pub fn workload(n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
    workload_sized(250_000, n_reads)
}

/// Donor-derived randomized *paired* workload: FR pairs with the
/// default insert model, in the paired id layout (R1 at `2i`, R2 at
/// `2i + 1`).
pub fn paired_workload(
    genome_len: usize,
    n_pairs: usize,
) -> (MinimizerIndex, Vec<ReadRecord>) {
    let genome = SynthConfig { len: genome_len, ..Default::default() }.generate();
    let donor = MutateConfig::default().apply(&genome);
    let idx = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads =
        PairSimConfig { n_pairs, ..Default::default() }.simulate(&donor.seq, donor.mapper());
    (idx, reads)
}

/// Render mappings exactly like `dart-pim map` writes its single-end
/// TSV rows, so "byte-identical" means what the CLI user sees.
pub fn render(mappings: &[Option<FinalMapping>]) -> String {
    let mut out = String::new();
    for m in mappings.iter().flatten() {
        out.push_str(&format!(
            "{}\t{}\t{}\t{}\t{}\t{}\n",
            m.read_id,
            m.pos,
            if m.reverse { '-' } else { '+' },
            m.dist,
            m.cigar,
            m.candidates
        ));
    }
    out
}

/// Render mappings exactly like `dart-pim map` writes its *paired* TSV
/// rows (pair_id, mate, …, pair status).
pub fn render_paired(mappings: &[Option<FinalMapping>]) -> String {
    let mut out = String::new();
    for m in mappings.iter().flatten() {
        out.push_str(&format!(
            "{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
            m.read_id / 2,
            m.read_id % 2 + 1,
            m.pos,
            if m.reverse { '-' } else { '+' },
            m.dist,
            m.cigar,
            m.candidates,
            m.pair.as_str()
        ));
    }
    out
}

/// Borrow a `Vec<Vec<u8>>` batch as the `&[&[u8]]` shape engines take.
pub fn as_slices(v: &[Vec<u8>]) -> Vec<&[u8]> {
    v.iter().map(|x| x.as_slice()).collect()
}

/// One random (read, window) pair in one of several adversarial shapes
/// (pure random / planted with edits straddling the eth boundary /
/// all-mismatch / N-alphabet) — the engine-parity fuzz unit.
pub fn rand_instance(rng: &mut SmallRng, n: usize) -> (Vec<u8>, Vec<u8>) {
    let wl = window_len(n);
    match rng.gen_range(0..5u32) {
        // pure random (usually saturates)
        0 => {
            let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..4)).collect();
            let win: Vec<u8> = (0..wl).map(|_| rng.gen_range(0..4)).collect();
            (read, win)
        }
        // planted at a random band shift with 0..=8 substitutions, so
        // distances land on both sides of the eth boundary
        1 | 2 => {
            let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..4)).collect();
            let mut win: Vec<u8> = (0..wl).map(|_| rng.gen_range(0..4)).collect();
            let shift = rng.gen_range(0..=2 * ETH);
            win[shift..shift + n].copy_from_slice(&read);
            for _ in 0..rng.gen_range(0..=8usize) {
                let p = rng.gen_range(shift..shift + n);
                win[p] = (win[p] + rng.gen_range(1..4u8)) % 4;
            }
            (read, win)
        }
        // all-mismatch (the saturation fixed point / early-exit path)
        3 => (vec![0u8; n], vec![1u8; wl]),
        // alphabet with N bases (code 4 never matches, even vs itself)
        _ => {
            let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..5)).collect();
            let mut win: Vec<u8> = (0..wl).map(|_| rng.gen_range(0..5)).collect();
            let shift = rng.gen_range(0..=2 * ETH);
            win[shift..shift + n].copy_from_slice(&read);
            (read, win)
        }
    }
}

/// A batch of [`rand_instance`]s.
pub fn rand_batch(rng: &mut SmallRng, b: usize, n: usize) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let mut reads = Vec::with_capacity(b);
    let mut wins = Vec::with_capacity(b);
    for _ in 0..b {
        let (r, w) = rand_instance(rng, n);
        reads.push(r);
        wins.push(w);
    }
    (reads, wins)
}

/// A corpus of [`rand_batch`]es holding at least `min_instances` WF
/// instances in total: batch sizes land off the lane grid on purpose
/// (1..=130 uniformly, so every 64/128/256/512-bit tail path is hit)
/// and read lengths cycle through the shapes the engines must chunk
/// correctly (tiny, sub-word, READ_LEN-scale, long). One definition so
/// the lane-width parity fortress and the SIMD determinism suite fuzz
/// the *same* distribution; the seed is the caller's, so a failure
/// message that prints it reproduces the corpus exactly.
pub fn rand_wf_corpus(seed: u64, min_instances: usize) -> Vec<(Vec<Vec<u8>>, Vec<Vec<u8>>)> {
    let mut rng = SmallRng::seed_from_u64(seed);
    let lens = [1usize, 3, 17, 30, 64, 150];
    let mut corpus = Vec::new();
    let mut total = 0usize;
    let mut li = 0usize;
    while total < min_instances {
        let b = rng.gen_range(1..=130usize);
        let n = lens[li % lens.len()];
        li += 1;
        corpus.push(rand_batch(&mut rng, b, n));
        total += b;
    }
    corpus
}

/// A batch of `b` random reads, each planted exactly (no errors) at the
/// band anchor of an otherwise-random window — the standard engine
/// micro-bench workload (shared with the benches so printed and
/// recorded comparisons measure exactly the same batch shape).
pub fn planted_wf_batch(rng: &mut SmallRng, b: usize) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
    let reads: Vec<Vec<u8>> =
        (0..b).map(|_| (0..READ_LEN).map(|_| rng.gen_range(0..4)).collect()).collect();
    let wins: Vec<Vec<u8>> = reads
        .iter()
        .map(|r| {
            let mut w: Vec<u8> =
                (0..window_len(READ_LEN)).map(|_| rng.gen_range(0..4)).collect();
            w[ETH..ETH + READ_LEN].copy_from_slice(r);
            w
        })
        .collect();
    (reads, wins)
}
