//! End-to-end tests for `dart-pim serve`: a real daemon subprocess on a
//! Unix socket, exercised by real clients.
//!
//! The core claim is determinism invariant 7 (ARCHITECTURE.md): for any
//! single client, the TSV bytes that come back over the socket are
//! identical to what `map` writes for the same input and flags — for
//! both framings, both modes, engines {rust, bitpal} × threads {1, 4}.
//! On top of parity: concurrent sessions don't corrupt each other, a
//! malformed stream fails only its own session, and SIGTERM drains
//! in-flight sessions to completion before the daemon exits 0.
#![cfg(unix)]

use std::io::{Read, Write};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU32, Ordering};
use std::time::{Duration, Instant};

use dart_pim::cli;
use dart_pim::serve::protocol::{encode_data_frame, finish_frame, read_framed_response};

static DAEMON_SEQ: AtomicU32 = AtomicU32::new(0);

fn fixtures() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden")
}

/// The golden fixtures use 100 bp reads; every daemon here must be told
/// so (`serve` fixes the index geometry at startup).
const FIXTURE_READ_LEN: &str = "100";

struct Daemon {
    child: Child,
    sock: PathBuf,
}

impl Daemon {
    /// Start a daemon on the golden reference with `--low-th 0` (the
    /// fixtures' setting) plus `extra` flags, and wait for its socket.
    fn start(extra: &[&str]) -> Daemon {
        let seq = DAEMON_SEQ.fetch_add(1, Ordering::Relaxed);
        let sock = std::env::temp_dir()
            .join(format!("dartpim-serve-{}-{seq}.sock", std::process::id()));
        let _ = std::fs::remove_file(&sock);
        let fx = fixtures();
        let mut child = Command::new(env!("CARGO_BIN_EXE_dart-pim"))
            .arg("serve")
            .arg("--ref")
            .arg(fx.join("ref.fasta"))
            .arg("--read-len")
            .arg(FIXTURE_READ_LEN)
            .arg("--low-th")
            .arg("0")
            .arg("--socket")
            .arg(&sock)
            .args(extra)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning the serve daemon");
        let t0 = Instant::now();
        while !sock.exists() {
            if let Some(status) = child.try_wait().expect("polling the daemon") {
                panic!("daemon exited during startup: {status}");
            }
            assert!(
                t0.elapsed() < Duration::from_secs(30),
                "daemon socket {} never appeared",
                sock.display()
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        Daemon { child, sock }
    }

    fn sigterm(&self) {
        let ok = Command::new("kill")
            .arg("-TERM")
            .arg(self.child.id().to_string())
            .status()
            .expect("running kill")
            .success();
        assert!(ok, "kill -TERM failed");
    }

    fn wait_exit(&mut self) -> std::process::ExitStatus {
        self.child.wait().expect("waiting for the daemon")
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
        let _ = std::fs::remove_file(&self.sock);
    }
}

/// Reference output: run `map` in-process with the given flags and
/// return the TSV bytes it writes.
fn map_tsv(input_flags: &str, engine_flags: &str) -> String {
    let fx = fixtures();
    let seq = DAEMON_SEQ.fetch_add(1, Ordering::Relaxed);
    let name = format!("dartpim-serve-map-{}-{seq}.tsv", std::process::id());
    let out = std::env::temp_dir().join(name);
    let cmd = format!(
        "map --ref {} {input_flags} --low-th 0 {engine_flags} --out {}",
        fx.join("ref.fasta").display(),
        out.display()
    );
    let argv: Vec<String> = cmd.split_whitespace().map(|s| s.to_string()).collect();
    cli::run(&argv).unwrap_or_else(|e| panic!("`{cmd}` failed: {e:#}"));
    let tsv = std::fs::read_to_string(&out).unwrap();
    let _ = std::fs::remove_file(&out);
    tsv
}

/// One framed session: handshake, FASTQ in `chunk`-byte data frames, a
/// finish frame, then the server's full response.
fn framed_session(
    sock: &Path,
    mode: &str,
    fastq: &[u8],
    chunk: usize,
) -> (Vec<u8>, Option<String>, Option<String>) {
    let mut s = UnixStream::connect(sock).expect("connecting to the daemon");
    writeln!(s, "DART/1 mode={mode}").unwrap();
    for c in fastq.chunks(chunk.max(1)) {
        s.write_all(&encode_data_frame(c)).unwrap();
    }
    s.write_all(&finish_frame()).unwrap();
    s.flush().unwrap();
    read_framed_response(&mut s).expect("reading the framed response")
}

/// One raw session: handshake, FASTQ bytes, half-close, then everything
/// the server sends back.
fn raw_session(sock: &Path, mode: &str, fastq: &[u8]) -> Vec<u8> {
    let mut s = UnixStream::connect(sock).expect("connecting to the daemon");
    writeln!(s, "DART/1 mode={mode} framing=raw").unwrap();
    s.write_all(fastq).unwrap();
    s.flush().unwrap();
    s.shutdown(std::net::Shutdown::Write).unwrap();
    let mut out = Vec::new();
    s.read_to_end(&mut out).unwrap();
    out
}

#[test]
fn serve_matches_map_byte_for_byte_across_engines_and_threads() {
    let fx = fixtures();
    let se = std::fs::read(fx.join("reads_se.fastq")).unwrap();
    let pe = std::fs::read(fx.join("reads_interleaved.fastq")).unwrap();
    let se_input = format!("--reads {}", fx.join("reads_se.fastq").display());
    let pe_input =
        format!("--reads {} --interleaved", fx.join("reads_interleaved.fastq").display());
    for engine in ["rust", "bitpal"] {
        for threads in ["1", "4"] {
            let flags = format!("--engine {engine} --threads {threads}");
            let want_se = map_tsv(&se_input, &flags);
            let want_pe = map_tsv(&pe_input, &flags);
            let daemon = Daemon::start(&["--engine", engine, "--threads", threads]);

            let (tsv, metrics, error) = framed_session(&daemon.sock, "se", &se, 4096);
            assert_eq!(error, None, "[{flags}] single-end session failed");
            assert_eq!(
                String::from_utf8(tsv).unwrap(),
                want_se,
                "[{flags}] framed single-end bytes must match `map`"
            );
            let metrics = metrics.expect("metrics frame");
            assert!(
                metrics.starts_with("reads=12 "),
                "[{flags}] 12 reads streamed, got: {metrics}"
            );

            let (tsv, metrics, error) = framed_session(&daemon.sock, "pe", &pe, 4096);
            assert_eq!(error, None, "[{flags}] paired session failed");
            assert_eq!(
                String::from_utf8(tsv).unwrap(),
                want_pe,
                "[{flags}] framed paired bytes must match `map --interleaved`"
            );
            assert!(
                metrics.expect("metrics frame").starts_with("reads=16 "),
                "[{flags}] 8 pairs = 16 reads"
            );

            // raw mode: the response is *exactly* the map TSV bytes
            let raw = raw_session(&daemon.sock, "se", &se);
            assert_eq!(
                String::from_utf8(raw).unwrap(),
                want_se,
                "[{flags}] raw single-end bytes must match `map`"
            );
            let raw = raw_session(&daemon.sock, "pe", &pe);
            assert_eq!(
                String::from_utf8(raw).unwrap(),
                want_pe,
                "[{flags}] raw paired bytes must match `map --interleaved`"
            );
        }
    }
}

#[test]
fn concurrent_sessions_are_isolated() {
    let fx = fixtures();
    let se = std::fs::read(fx.join("reads_se.fastq")).unwrap();
    let pe = std::fs::read(fx.join("reads_interleaved.fastq")).unwrap();
    let want_se = map_tsv(
        &format!("--reads {}", fx.join("reads_se.fastq").display()),
        "--threads 2 --stream-epoch 4",
    );
    let want_pe = map_tsv(
        &format!("--reads {} --interleaved", fx.join("reads_interleaved.fastq").display()),
        "--threads 2 --stream-epoch 4",
    );
    // small epochs + tiny frames force the two sessions' epochs to
    // interleave on the shared workers
    let daemon = Daemon::start(&["--threads", "2", "--stream-epoch", "4"]);
    let outputs = std::thread::scope(|s| {
        let h1 = s.spawn(|| slow_framed_session(&daemon.sock, "se", &se));
        let h2 = s.spawn(|| slow_framed_session(&daemon.sock, "pe", &pe));
        (h1.join().unwrap(), h2.join().unwrap())
    });
    let (tsv, metrics, error) = outputs.0;
    assert_eq!(error, None, "single-end session failed");
    assert!(metrics.is_some());
    assert_eq!(
        String::from_utf8(tsv).unwrap(),
        want_se,
        "concurrent single-end session must still match `map`"
    );
    let (tsv, metrics, error) = outputs.1;
    assert_eq!(error, None, "paired session failed");
    assert!(metrics.is_some());
    assert_eq!(
        String::from_utf8(tsv).unwrap(),
        want_pe,
        "concurrent paired session must still match `map --interleaved`"
    );
}

/// Like [`framed_session`] but dribbles 64-byte frames with pauses, so
/// two of these genuinely overlap on the daemon.
fn slow_framed_session(
    sock: &Path,
    mode: &str,
    fastq: &[u8],
) -> (Vec<u8>, Option<String>, Option<String>) {
    let mut s = UnixStream::connect(sock).expect("connecting to the daemon");
    writeln!(s, "DART/1 mode={mode}").unwrap();
    for c in fastq.chunks(64) {
        s.write_all(&encode_data_frame(c)).unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    s.write_all(&finish_frame()).unwrap();
    s.flush().unwrap();
    read_framed_response(&mut s).expect("reading the framed response")
}

#[test]
fn malformed_fastq_poisons_only_its_own_session() {
    let fx = fixtures();
    let se = std::fs::read(fx.join("reads_se.fastq")).unwrap();
    let want_se =
        map_tsv(&format!("--reads {}", fx.join("reads_se.fastq").display()), "--threads 2");
    let daemon = Daemon::start(&["--threads", "2"]);

    // mid-stream corruption: good records, then a length-divergent one
    let mut bad = se.clone();
    bad.extend_from_slice(b"@short\nACGT\n+\nIIII\n");
    let (_, metrics, error) = framed_session(&daemon.sock, "se", &bad, 4096);
    let error = error.expect("the corrupted session must fail");
    assert!(
        error.contains("uniform read length"),
        "error should name the malformed record: {error}"
    );
    assert_eq!(metrics, None, "a failed session reports no metrics frame");

    // outright garbage, raw framing: the error travels as a trailer line
    let raw = raw_session(&daemon.sock, "se", b"this is not fastq\n");
    let text = String::from_utf8_lossy(&raw);
    assert!(text.lines().any(|l| l.starts_with("#!error: ")), "raw error trailer: {text}");

    // the daemon and its workers survive: a clean session still matches
    let (tsv, _, error) = framed_session(&daemon.sock, "se", &se, 4096);
    assert_eq!(error, None, "session after a poisoned one must succeed");
    assert_eq!(
        String::from_utf8(tsv).unwrap(),
        want_se,
        "session after a poisoned one must still match `map`"
    );
}

#[test]
fn malicious_frame_headers_fail_loudly_without_exhausting_memory() {
    let fx = fixtures();
    let se = std::fs::read(fx.join("reads_se.fastq")).unwrap();
    let want_se =
        map_tsv(&format!("--reads {}", fx.join("reads_se.fastq").display()), "--threads 2");
    let daemon = Daemon::start(&["--threads", "2"]);

    // a data-frame header claiming u32::MAX payload bytes: the daemon
    // must reject it from the 5 header bytes alone (no allocation, no
    // payload read) and answer with an E frame naming the cap
    let mut s = UnixStream::connect(&daemon.sock).unwrap();
    writeln!(s, "DART/1 mode=se").unwrap();
    s.write_all(&[b'D', 0xFF, 0xFF, 0xFF, 0xFF]).unwrap();
    s.flush().unwrap();
    let (_, metrics, error) =
        read_framed_response(&mut s).expect("reading the error response");
    let error = error.expect("the oversized frame must fail the session");
    assert!(error.contains("cap"), "error must name the frame cap: {error}");
    assert_eq!(metrics, None, "a failed session reports no metrics frame");
    drop(s);

    // a finish frame smuggling a payload length is rejected too
    let mut s = UnixStream::connect(&daemon.sock).unwrap();
    writeln!(s, "DART/1 mode=se").unwrap();
    s.write_all(&encode_data_frame(&se)).unwrap();
    s.write_all(&[b'F', 0, 0, 0, 8]).unwrap();
    s.flush().unwrap();
    let (_, _, error) = read_framed_response(&mut s).expect("reading the error response");
    let error = error.expect("the nonzero-length finish frame must fail the session");
    assert!(error.contains("finish frame"), "{error}");
    drop(s);

    // the daemon and its workers survive both attacks: a clean session
    // on the same socket still matches `map`
    let (tsv, _, error) = framed_session(&daemon.sock, "se", &se, 4096);
    assert_eq!(error, None, "session after the malicious ones must succeed");
    assert_eq!(
        String::from_utf8(tsv).unwrap(),
        want_se,
        "session after the malicious ones must still match `map`"
    );
}

#[test]
fn sigterm_drains_in_flight_sessions_and_exits_zero() {
    let fx = fixtures();
    let se = std::fs::read(fx.join("reads_se.fastq")).unwrap();
    let want_se =
        map_tsv(&format!("--reads {}", fx.join("reads_se.fastq").display()), "--threads 1");
    let mut daemon = Daemon::start(&["--threads", "1"]);

    // open a session and stream only half of the FASTQ...
    let mut s = UnixStream::connect(&daemon.sock).unwrap();
    writeln!(s, "DART/1 mode=se").unwrap();
    let half = se.len() / 2;
    s.write_all(&encode_data_frame(&se[..half])).unwrap();
    s.flush().unwrap();

    // ...signal the drain while the session is in flight...
    daemon.sigterm();
    std::thread::sleep(Duration::from_millis(300));

    // ...then finish the stream: the draining daemon must still serve
    // the complete, byte-correct response
    s.write_all(&encode_data_frame(&se[half..])).unwrap();
    s.write_all(&finish_frame()).unwrap();
    s.flush().unwrap();
    let (tsv, metrics, error) = read_framed_response(&mut s).unwrap();
    assert_eq!(error, None, "drained session must complete cleanly");
    assert!(metrics.is_some(), "drained session still reports metrics");
    assert_eq!(
        String::from_utf8(tsv).unwrap(),
        want_se,
        "a session caught by SIGTERM must still produce the full `map` bytes"
    );

    let status = daemon.wait_exit();
    assert!(status.success(), "graceful drain must exit 0, got {status}");
    assert!(!daemon.sock.exists(), "the daemon must remove its socket file on exit");
}
