//! Integration: the DARTPIM2 mmap-able index must round-trip exactly,
//! reject truncated / misaligned / internally-inconsistent files with
//! descriptive errors (never misparse, never trust a declared length),
//! and — determinism invariant 9 — produce byte-identical `map` output
//! whichever backend serves it, across threads and engines.

use std::path::PathBuf;

use dart_pim::cli;
use dart_pim::genome::synth::SynthConfig;
use dart_pim::index::v2::{write_index_v2, V2Layout};
use dart_pim::index::{parse_v2, save_index_v2, MappedIndex, MinimizerIndex};
use dart_pim::params::{K, READ_LEN, W};

fn build_index() -> MinimizerIndex {
    let g = SynthConfig { len: 40_000, ..Default::default() }.generate();
    MinimizerIndex::build(g, K, W, READ_LEN)
}

fn serialized(idx: &MinimizerIndex, n_shards: usize) -> Vec<u8> {
    let mut buf = Vec::new();
    write_index_v2(&mut buf, idx, n_shards).unwrap();
    buf
}

fn parse(buf: &[u8]) -> std::io::Result<V2Layout> {
    parse_v2(buf)
}

/// Byte offset of shard `s`'s 32-byte directory record.
fn dir_record(buf: &[u8], s: usize) -> usize {
    let ref_len = u64::from_le_bytes(buf[32..40].try_into().unwrap()) as usize;
    ((72 + ref_len + 7) & !7) + 32 * s
}

#[test]
fn mapped_file_round_trip_preserves_everything() {
    let idx = build_index();
    let path = std::env::temp_dir().join(format!("dartpim-v2io-{}.bin", std::process::id()));
    save_index_v2(&path, &idx, 4).unwrap();
    let mapped = MappedIndex::open(&path).unwrap();
    assert_eq!((mapped.k(), mapped.w(), mapped.read_len()), (idx.k, idx.w, idx.read_len));
    assert_eq!(mapped.reference(), &idx.reference[..]);
    assert_eq!(mapped.n_minimizers(), idx.n_minimizers());
    for (m, occs) in idx.iter() {
        assert_eq!(mapped.occurrences(m), occs, "minimizer {m:#x}");
    }
    drop(mapped);
    std::fs::remove_file(&path).ok();
}

#[test]
fn every_truncation_point_is_rejected() {
    let idx = build_index();
    let buf = serialized(&idx, 4);
    // sweep the header + directory densely and the slabs sparsely;
    // every proper prefix must fail (the header pins the exact file
    // length, so the format has no optional tail)
    let mut cuts: Vec<usize> = (0..256.min(buf.len())).collect();
    cuts.extend((256..buf.len()).step_by(buf.len() / 31 + 1));
    cuts.push(buf.len() - 1);
    for cut in cuts {
        let err = parse(&buf[..cut]).expect_err(&format!("prefix of {cut} bytes must fail"));
        let msg = err.to_string();
        assert!(
            msg.contains("truncated") || msg.contains("magic"),
            "cut={cut}: unhelpful error {msg:?}"
        );
    }
    // one byte too many must fail just as loudly
    let mut long = buf.clone();
    long.push(0);
    let err = parse(&long).unwrap_err();
    assert!(err.to_string().contains("truncated or padded"), "{err}");
}

#[test]
fn bad_magic_and_version_skew_are_distinguished() {
    let idx = build_index();
    let mut buf = serialized(&idx, 2);
    // wholly different magic
    let err = parse(b"NOTANIDXatall").unwrap_err();
    assert!(err.to_string().contains("not a DART-PIM index"), "{err}");
    // same family, other version byte: the error must point at the
    // converter rather than claiming corruption
    buf[7] = b'1';
    let err = parse(&buf).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("version") && msg.contains("--from"), "{msg}");
}

#[test]
fn corrupt_header_fields_fail_without_huge_allocation() {
    let idx = build_index();
    let buf = serialized(&idx, 4);
    // ref_len -> absurd: must fail loudly, never pre-allocate
    let mut evil = buf.clone();
    evil[32..40].copy_from_slice(&u64::MAX.to_le_bytes());
    parse(&evil).unwrap_err();
    // geometry: k = 0 is implausible
    let mut evil = buf.clone();
    evil[8..16].copy_from_slice(&0u64.to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("geometry"), "{err}");
    // shard count 0 and beyond the format cap
    for bogus in [0u64, 1 << 21] {
        let mut evil = buf.clone();
        evil[40..48].copy_from_slice(&bogus.to_le_bytes());
        let err = parse(&evil).unwrap_err();
        assert!(err.to_string().contains("shard count"), "{err}");
    }
}

#[test]
fn misaligned_slab_is_rejected() {
    let idx = build_index();
    let buf = serialized(&idx, 4);
    let mut evil = buf.clone();
    let rec = dir_record(&buf, 0);
    let off = u64::from_le_bytes(buf[rec..rec + 8].try_into().unwrap());
    evil[rec..rec + 8].copy_from_slice(&(off + 4).to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("misaligned"), "{err}");
    // an aligned but displaced slab breaks contiguity instead
    let mut evil = buf.clone();
    evil[rec..rec + 8].copy_from_slice(&(off + 8).to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("contiguous"), "{err}");
}

#[test]
fn directory_and_slab_disagreements_are_rejected() {
    let idx = build_index();
    let buf = serialized(&idx, 4);
    // a directory record whose counts no longer match its slab length
    let mut evil = buf.clone();
    let rec = dir_record(&buf, 0);
    let n_e = u64::from_le_bytes(buf[rec + 8..rec + 16].try_into().unwrap());
    evil[rec + 8..rec + 16].copy_from_slice(&(n_e + 1).to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("disagrees"), "{err}");
    // directory totals that no longer match the header totals
    let mut evil = buf.clone();
    let total = u64::from_le_bytes(buf[48..56].try_into().unwrap());
    evil[48..56].copy_from_slice(&(total + 1).to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("disagree with the header"), "{err}");
}

#[test]
fn corrupt_slab_payload_is_rejected() {
    let idx = build_index();
    let buf = serialized(&idx, 4);
    let layout = parse(&buf).unwrap();
    let sh = layout
        .shards
        .iter()
        .find(|sh| sh.n_entries >= 2)
        .expect("a 40kb genome fills every shard");
    // keys must be strictly ascending
    let mut evil = buf.clone();
    let k0 = buf[sh.keys_off..sh.keys_off + 8].to_vec();
    evil[sh.keys_off + 8..sh.keys_off + 16].copy_from_slice(&k0);
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("keys are not sorted"), "{err}");
    // a key stored in a shard that does not own it
    let other = layout
        .shards
        .iter()
        .find(|o| o.n_entries >= 1 && o.keys_off != sh.keys_off)
        .expect("two populated shards");
    let mut evil = buf.clone();
    let foreign = buf[other.keys_off..other.keys_off + 8].to_vec();
    evil[sh.keys_off..sh.keys_off + 8].copy_from_slice(&foreign);
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("owned by"), "{err}");
    // an occurrence position beyond the reference
    let mut evil = buf.clone();
    let last = sh.pos_off + 4 * (sh.n_positions - 1);
    evil[last..last + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("out of reference bounds"), "{err}");
}

#[test]
fn mapped_open_validates_the_file_end_to_end() {
    let idx = build_index();
    let buf = serialized(&idx, 4);
    let dir = std::env::temp_dir().join(format!("dartpim-v2open-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    // the clean file opens
    let good = dir.join("good.idx");
    std::fs::write(&good, &buf).unwrap();
    let mapped = MappedIndex::open(&good).unwrap();
    assert_eq!(mapped.n_minimizers(), idx.n_minimizers());
    drop(mapped);
    // a truncated file is refused through the same validation
    let bad = dir.join("bad.idx");
    std::fs::write(&bad, &buf[..buf.len() - 1]).unwrap();
    let err = MappedIndex::open(&bad).unwrap_err();
    assert!(err.to_string().contains("truncated or padded"), "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

fn fixtures() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden")
}

fn run(cmd: &str) {
    let argv: Vec<String> = cmd.split_whitespace().map(|s| s.to_string()).collect();
    cli::run(&argv).unwrap_or_else(|e| panic!("`{cmd}` failed: {e:#}"));
}

/// Determinism invariant 9 on the golden fixtures: the index backend —
/// heap (v1) or mmap (v2) — never changes a single output byte, across
/// threads {1,4} x engines {rust,bitpal}.
#[test]
fn golden_mapping_is_byte_identical_across_backends_threads_and_engines() {
    let fx = fixtures();
    let dir = std::env::temp_dir().join(format!("dartpim-v2golden-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (rf, rd) = (fx.join("ref.fasta"), fx.join("reads_se.fastq"));
    run(&format!(
        "index --ref {} --read-len 100 --out {}",
        rf.display(),
        dir.join("golden-v1.idx").display()
    ));
    run(&format!(
        "index --ref {} --read-len 100 --index-format v2 --shards 4 --out {}",
        rf.display(),
        dir.join("golden-v2.idx").display()
    ));
    let mut outputs: Vec<(String, String)> = Vec::new();
    for backend in ["v1", "v2"] {
        for threads in [1usize, 4] {
            for engine in ["rust", "bitpal"] {
                let out = dir.join(format!("se-{backend}-{threads}-{engine}.tsv"));
                run(&format!(
                    "map --index {} --reads {} --low-th 0 --engine {engine} \
                     --threads {threads} --out {}",
                    dir.join(format!("golden-{backend}.idx")).display(),
                    rd.display(),
                    out.display()
                ));
                outputs.push((
                    format!("backend={backend} threads={threads} engine={engine}"),
                    std::fs::read_to_string(&out).unwrap(),
                ));
            }
        }
    }
    let (base_label, base) = &outputs[0];
    assert_eq!(base.lines().count(), 1 + 11, "one header + 11 mapped rows:\n{base}");
    for (label, tsv) in &outputs[1..] {
        assert_eq!(base, tsv, "{label} must equal {base_label} (invariant 9)");
    }
    std::fs::remove_dir_all(&dir).ok();
}
