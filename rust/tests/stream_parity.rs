//! Streaming-vs-in-memory parity: `map_stream` (tiny epochs, bounded
//! channels) must be byte-identical to the collect wrapper `map_reads`
//! for every threads × engine combination, and the CLI's streamed TSV —
//! including `--reads -` over stdin — must be byte-identical to a
//! file-fed run. This is the acceptance contract of the bounded-memory
//! ingestion path: streaming changes *when* work happens, never *what*
//! comes out.

mod common;

use std::io::Write as _;
use std::process::{Command, Stdio};

use common::{render, workload};
use dart_pim::cli;
use dart_pim::coordinator::{FinalMapping, Pipeline, PipelineConfig};
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::EngineKind;

fn cfg(threads: usize, engine: EngineKind, stream_epoch: usize) -> PipelineConfig {
    PipelineConfig {
        dart: DartPimConfig { low_th: 1, ..Default::default() },
        threads,
        worker_engine: engine,
        stream_epoch,
        ..Default::default()
    }
}

/// map_stream with a deliberately tiny epoch (forcing many flush
/// barriers and partial batches) must equal map_reads with the default
/// epoch, for threads {1,4} × engines {rust,bitpal} — and the sink must
/// see every read id exactly once, in order.
#[test]
fn stream_is_byte_identical_to_in_memory_for_threads_x_engines() {
    let (idx, reads) = workload(300);
    let baseline = {
        let mut p = Pipeline::new(&idx, cfg(1, EngineKind::Rust, 4096), EngineKind::Rust.build());
        render(&p.map_reads(&reads).unwrap().0)
    };
    assert!(!baseline.is_empty(), "workload must map reads");
    for threads in [1usize, 4] {
        for engine in [EngineKind::Rust, EngineKind::Bitpal] {
            let mut p = Pipeline::new(&idx, cfg(threads, engine, 17), engine.build());
            let mut got: Vec<Option<FinalMapping>> = Vec::new();
            let mut next_expected = 0u32;
            let metrics = p
                .map_stream(reads.iter().cloned().map(Ok), |id, m| {
                    assert_eq!(id, next_expected, "sink ids must be dense and ordered");
                    next_expected += 1;
                    got.push(m);
                    Ok(())
                })
                .unwrap();
            assert_eq!(metrics.n_reads, reads.len() as u64);
            assert_eq!(
                baseline,
                render(&got),
                "threads={threads} engine={} epoch=17 must be byte-identical",
                engine.name()
            );
        }
    }
}

/// The CLI TSV must be byte-identical across `--threads` × `--engine`
/// on a synthesized workload (the exact file a user diffs).
#[test]
fn cli_tsv_is_byte_identical_across_threads_and_engines() {
    let dir = std::env::temp_dir().join(format!("dartpim-sp-{}", std::process::id()));
    let d = dir.to_str().unwrap().to_string();
    let run = |s: &str| cli::run(&s.split_whitespace().map(|x| x.to_string()).collect::<Vec<_>>());
    run(&format!("synth --out-dir {d} --len 60000 --reads 60")).unwrap();
    let mut outputs: Vec<(String, String)> = Vec::new();
    for threads in [1usize, 4] {
        for engine in ["rust", "bitpal"] {
            let out = format!("{d}/map-{threads}-{engine}.tsv");
            run(&format!(
                "map --ref {d}/ref.fasta --reads {d}/reads.fastq --low-th 0 \
                 --engine {engine} --threads {threads} --out {out}"
            ))
            .unwrap();
            outputs.push((
                format!("threads={threads} engine={engine}"),
                std::fs::read_to_string(&out).unwrap(),
            ));
        }
    }
    let (base_label, base) = &outputs[0];
    assert!(base.lines().count() > 40, "most reads must map:\n{base}");
    for (label, tsv) in &outputs[1..] {
        assert_eq!(base, tsv, "{label} must equal {base_label}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// `map --reads -` (stdin) must produce the same bytes on stdout as a
/// file-fed `--out` run — the real process, not a harness shortcut.
#[test]
fn stdin_streaming_matches_file_input() {
    let dir = std::env::temp_dir().join(format!("dartpim-stdin-{}", std::process::id()));
    let d = dir.to_str().unwrap().to_string();
    let run = |s: &str| cli::run(&s.split_whitespace().map(|x| x.to_string()).collect::<Vec<_>>());
    run(&format!("synth --out-dir {d} --len 60000 --reads 50")).unwrap();
    run(&format!(
        "map --ref {d}/ref.fasta --reads {d}/reads.fastq --low-th 0 --threads 2 \
         --out {d}/file.tsv"
    ))
    .unwrap();
    let expected = std::fs::read_to_string(format!("{d}/file.tsv")).unwrap();

    let fastq = std::fs::read(format!("{d}/reads.fastq")).unwrap();
    let mut child = Command::new(env!("CARGO_BIN_EXE_dart-pim"))
        .args([
            "map",
            "--ref",
            &format!("{d}/ref.fasta"),
            "--reads",
            "-",
            "--low-th",
            "0",
            "--threads",
            "2",
        ])
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn dart-pim");
    child.stdin.as_mut().unwrap().write_all(&fastq).unwrap();
    let out = child.wait_with_output().unwrap();
    assert!(
        out.status.success(),
        "map --reads - failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert_eq!(
        expected,
        String::from_utf8_lossy(&out.stdout),
        "stdin-streamed TSV must be byte-identical to the file-fed run"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// A malformed record mid-stream aborts the run with the record's
/// ordinal and name in the error (no silent partial output).
#[test]
fn malformed_mid_stream_record_aborts_with_position() {
    let dir = std::env::temp_dir().join(format!("dartpim-badfq-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let d = dir.to_str().unwrap().to_string();
    let run = |s: &str| cli::run(&s.split_whitespace().map(|x| x.to_string()).collect::<Vec<_>>());
    run(&format!("synth --out-dir {d} --len 60000 --reads 5")).unwrap();
    // append a record whose quality is shorter than its sequence
    let mut fq = std::fs::read_to_string(format!("{d}/reads.fastq")).unwrap();
    fq.push_str("@broken\nACGTACGT\n+\nII\n");
    std::fs::write(format!("{d}/bad.fastq"), fq).unwrap();
    let err = run(&format!(
        "map --ref {d}/ref.fasta --reads {d}/bad.fastq --low-th 0 --out {d}/x.tsv"
    ))
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("#6") && msg.contains("broken"), "error must locate the record: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}
