//! Integration: the PJRT-executed Pallas kernels must agree with the
//! pure-Rust mirrors bit-for-bit — band values, best-of-band tie-breaks,
//! and packed traceback directions. This is the contract that lets the
//! coordinator treat the engines interchangeably (and the strongest
//! end-to-end check that the three layers compose).
//!
//! The suite needs two ingredients beyond the default build:
//! * the `pjrt` cargo feature (`cargo test --features pjrt`), and
//! * the AOT artifacts (`make artifacts`).
//!
//! Without either it skips gracefully (with a note) rather than failing:
//! the default CI build is hermetic and has neither.

#[cfg(not(feature = "pjrt"))]
#[test]
fn engine_parity_requires_pjrt_feature() {
    eprintln!(
        "SKIP: engine parity suite is inert without the `pjrt` feature; \
         run `cargo test --features pjrt` with artifacts built"
    );
}

#[cfg(feature = "pjrt")]
mod pjrt_parity {
    use dart_pim::coordinator::{Pipeline, PipelineConfig};
    use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
    use dart_pim::index::MinimizerIndex;
    use dart_pim::params::{window_len, ETH, K, READ_LEN, W};
    use dart_pim::pim::DartPimConfig;
    use dart_pim::runtime::{RustEngine, WfEngine, XlaEngine};
    use dart_pim::util::SmallRng;

    fn engine() -> Option<XlaEngine> {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        match XlaEngine::load(dir) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("SKIP: artifacts not available ({e:#}); run `make artifacts`");
                None
            }
        }
    }

    /// Random / planted (read, window) batches at the artifact read length.
    fn mk_batch(rng: &mut SmallRng, b: usize, planted: bool) -> (Vec<Vec<u8>>, Vec<Vec<u8>>) {
        let n = READ_LEN;
        let reads: Vec<Vec<u8>> =
            (0..b).map(|_| (0..n).map(|_| rng.gen_range(0..4)).collect()).collect();
        let wins: Vec<Vec<u8>> = reads
            .iter()
            .map(|r| {
                let mut w: Vec<u8> =
                    (0..window_len(n)).map(|_| rng.gen_range(0..4)).collect();
                if planted {
                    // read at a random in-band shift with a few edits
                    let shift = rng.gen_range(0..2 * ETH + 1);
                    let mut seq = r.clone();
                    for _ in 0..rng.gen_range(0..4usize) {
                        let p = rng.gen_range(0..seq.len());
                        seq[p] = (seq[p] + rng.gen_range(1..4u8)) % 4;
                    }
                    if rng.gen_bool(0.4) {
                        let p = rng.gen_range(1..seq.len());
                        seq.remove(p);
                    }
                    let take = seq.len().min(window_len(n) - shift);
                    w[shift..shift + take].copy_from_slice(&seq[..take]);
                }
                w
            })
            .collect();
        (reads, wins)
    }

    #[test]
    fn linear_bitwise_parity() {
        let Some(mut xla) = engine() else { return };
        let mut rust = RustEngine;
        let mut rng = SmallRng::seed_from_u64(0x11EA);
        for (b, planted) in [(1, true), (7, true), (32, true), (50, false), (64, true)] {
            let (reads, wins) = mk_batch(&mut rng, b, planted);
            let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
            let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
            let a = xla.linear_batch(&rr, &ww).unwrap();
            let e = rust.linear_batch(&rr, &ww).unwrap();
            assert_eq!(a.band, e.band, "band mismatch b={b}");
            assert_eq!(a.best, e.best, "best mismatch b={b}");
            assert_eq!(a.best_j, e.best_j, "best_j mismatch b={b}");
        }
    }

    #[test]
    fn affine_bitwise_parity_including_tracebacks() {
        let Some(mut xla) = engine() else { return };
        let mut rust = RustEngine;
        let mut rng = SmallRng::seed_from_u64(0xAFF1);
        for (b, planted) in [(1, true), (8, true), (13, true), (20, false)] {
            let (reads, wins) = mk_batch(&mut rng, b, planted);
            let rr: Vec<&[u8]> = reads.iter().map(|v| v.as_slice()).collect();
            let ww: Vec<&[u8]> = wins.iter().map(|v| v.as_slice()).collect();
            let a = xla.affine_batch(&rr, &ww).unwrap();
            let e = rust.affine_batch(&rr, &ww).unwrap();
            assert_eq!(a.band, e.band, "band mismatch b={b}");
            assert_eq!(a.best, e.best, "best mismatch b={b}");
            assert_eq!(a.best_j, e.best_j, "best_j mismatch b={b}");
            assert_eq!(a.dirs, e.dirs, "traceback directions mismatch b={b}");
        }
    }

    #[test]
    fn pipeline_end_to_end_parity() {
        let Some(xla) = engine() else { return };
        let g = SynthConfig { len: 60_000, ..Default::default() }.generate();
        let idx = MinimizerIndex::build(g, K, W, READ_LEN);
        let reads = ReadSimConfig { n_reads: 25, ..Default::default() }
            .simulate(&idx.reference, |p| p as u32);
        let cfg = PipelineConfig {
            dart: DartPimConfig { low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let (a, am) = Pipeline::new(&idx, cfg.clone(), xla).map_reads(&reads).unwrap();
        let (e, em) = Pipeline::new(&idx, cfg, RustEngine).map_reads(&reads).unwrap();
        assert_eq!(am.linear_instances, em.linear_instances);
        assert_eq!(am.affine_instances, em.affine_instances);
        for (x, y) in a.iter().zip(&e) {
            match (x, y) {
                (None, None) => {}
                (Some(x), Some(y)) => {
                    assert_eq!(
                        (x.pos, x.dist, x.cigar.to_string()),
                        (y.pos, y.dist, y.cigar.to_string())
                    );
                }
                _ => panic!("presence mismatch between engines"),
            }
        }
    }
}
