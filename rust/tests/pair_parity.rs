//! Paired-end parity and determinism: proper-pair arbitration must be a
//! pure function of the epoch's candidates — byte-identical across
//! threads × engines × epoch sizes — must degrade to the single-end
//! decision when a mate is unmappable, and must not lose accuracy
//! against a single-end run of the same records. Randomized donor
//! workload (SNPs + indels + sequencing errors + garbage mates), the
//! same shape as the other determinism suites.

mod common;

use common::{paired_workload, render_paired};
use dart_pim::coordinator::{PairStatus, PairingConfig, Pipeline, PipelineConfig};
use dart_pim::eval::evaluate_pair_accuracy;
use dart_pim::genome::ReadRecord;
use dart_pim::index::MinimizerIndex;
use dart_pim::params::READ_LEN;
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::EngineKind;
use dart_pim::util::SmallRng;

fn cfg(
    threads: usize,
    engine: EngineKind,
    stream_epoch: usize,
    pairing: Option<PairingConfig>,
) -> PipelineConfig {
    PipelineConfig {
        dart: DartPimConfig { low_th: 1, ..Default::default() },
        handle_revcomp: true,
        threads,
        worker_engine: engine,
        stream_epoch,
        pairing,
        ..Default::default()
    }
}

fn run_paired(
    idx: &MinimizerIndex,
    reads: &[ReadRecord],
    threads: usize,
    engine: EngineKind,
    epoch: usize,
) -> (String, std::collections::BTreeMap<String, u64>) {
    let pairing = Some(PairingConfig::default());
    let mut p = Pipeline::new(idx, cfg(threads, engine, epoch, pairing), engine.build());
    let (m, metrics) = p.map_reads(reads).unwrap();
    (render_paired(&m), metrics.invariant_counters())
}

/// The paired TSV must be byte-identical for every threads × engine ×
/// epoch combination — including odd epochs, which must defer to the
/// next pair boundary.
#[test]
fn paired_output_is_byte_identical_across_threads_engines_epochs() {
    let (idx, reads) = paired_workload(250_000, 150);
    let (base, base_counters) = run_paired(&idx, &reads, 1, EngineKind::Rust, 4096);
    assert!(!base.is_empty(), "workload must map mates");
    assert!(base.contains("proper"), "workload must resolve proper pairs");
    for threads in [1usize, 4] {
        for engine in [EngineKind::Rust, EngineKind::Bitpal] {
            for epoch in [17usize, 64, 4096] {
                let (tsv, counters) = run_paired(&idx, &reads, threads, engine, epoch);
                assert_eq!(
                    base,
                    tsv,
                    "threads={threads} engine={} epoch={epoch}",
                    engine.name()
                );
                assert_eq!(base_counters, counters);
            }
        }
    }
}

/// Randomized degradation sweep: scatter unmappable mates through the
/// pair set; every pair with a garbage mate must resolve its good mate
/// to exactly the single-end decision (same pos/dist/CIGAR/candidates),
/// and the garbage mate must stay unmapped.
#[test]
fn pairs_with_unmappable_mates_degrade_to_single_end_decisions() {
    let (idx, mut reads) = paired_workload(200_000, 120);
    let mut rng = SmallRng::seed_from_u64(0xDE6D);
    let mut garbage: Vec<u32> = Vec::new();
    for pair in 0..120u32 {
        if rng.gen_bool(0.25) {
            // kill one mate at random (either side)
            let victim = 2 * pair + rng.gen_range(0..2u32);
            reads[victim as usize].seq = (0..READ_LEN).map(|_| rng.gen_range(0..4u8)).collect();
            garbage.push(victim);
        }
    }
    assert!(garbage.len() > 15, "sweep needs a meaningful garbage fraction");

    let paired = {
        let pairing = Some(PairingConfig::default());
        let mut p =
            Pipeline::new(&idx, cfg(1, EngineKind::Rust, 4096, pairing), EngineKind::Rust.build());
        p.map_reads(&reads).unwrap().0
    };
    let single = {
        let c = cfg(1, EngineKind::Rust, 4096, None);
        let mut p = Pipeline::new(&idx, c, EngineKind::Rust.build());
        p.map_reads(&reads).unwrap().0
    };
    for &victim in &garbage {
        assert!(paired[victim as usize].is_none(), "garbage mate {victim} must stay unmapped");
        let partner = victim ^ 1;
        match (&paired[partner as usize], &single[partner as usize]) {
            (None, None) => {}
            (Some(a), Some(b)) => {
                assert_eq!(
                    (a.pos, a.dist, a.cigar.to_string(), a.candidates, a.reverse),
                    (b.pos, b.dist, b.cigar.to_string(), b.candidates, b.reverse),
                    "partner {partner} of garbage mate {victim} must keep its single-end decision"
                );
                assert_eq!(a.pair, PairStatus::Single);
            }
            _ => panic!("presence mismatch for partner {partner}"),
        }
    }
}

/// The acceptance bar: pair-aware accuracy on a mutated-donor workload
/// is at least the single-end accuracy over the same records, and
/// proper pairs carry the bulk of the decisions.
#[test]
fn pairing_does_not_lose_accuracy_and_mostly_resolves_proper() {
    let (idx, reads) = paired_workload(250_000, 150);
    let run = |pairing| {
        let mut p =
            Pipeline::new(&idx, cfg(1, EngineKind::Rust, 4096, pairing), EngineKind::Rust.build());
        p.map_reads(&reads).unwrap()
    };
    let (paired, metrics) = run(Some(PairingConfig::default()));
    let (single, _) = run(None);
    let pr = evaluate_pair_accuracy(&reads, &paired, 5);
    let sr = evaluate_pair_accuracy(&reads, &single, 5);
    assert!(
        pr.mate_accuracy() >= sr.mate_accuracy(),
        "pair-aware accuracy {} must be >= single-end {} on the same reads",
        pr.mate_accuracy(),
        sr.mate_accuracy()
    );
    assert!(pr.pair_recall() > 0.85, "pair recall {}", pr.pair_recall());
    assert!(
        metrics.proper_pairs as f64 >= 0.8 * pr.n_pairs as f64,
        "proper pairs {}/{}",
        metrics.proper_pairs,
        pr.n_pairs
    );
}
