//! Golden end-to-end fixtures: a tiny checked-in FASTA + FASTQ workload
//! whose `map` TSV output is asserted byte-identical across
//! threads {1,4} × engines {rust,bitpal} × stream epochs {7,default} —
//! and, once blessed, against checked-in expected bytes, so the
//! determinism contract lives in executable evidence rather than
//! review. Paired runs additionally assert the two paired sources
//! (two-file zip vs interleaved) agree byte-for-byte.
//!
//! The expected files carry an `# UNBLESSED` sentinel until they are
//! recorded on a host with a Rust toolchain (`GOLDEN_BLESS=1 cargo test
//! --test golden_e2e`); the cross-configuration parity sweep runs — and
//! gates — either way.

use std::path::PathBuf;

use dart_pim::cli;

const SENTINEL: &str = "# UNBLESSED";

fn fixtures() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden")
}

fn run(cmd: &str) {
    let argv: Vec<String> = cmd.split_whitespace().map(|s| s.to_string()).collect();
    cli::run(&argv).unwrap_or_else(|e| panic!("`{cmd}` failed: {e:#}"));
}

/// Compare against the checked-in golden bytes, or (while unblessed)
/// optionally record them.
fn check_golden(expected: &std::path::Path, actual: &str, label: &str) {
    let want = std::fs::read_to_string(expected)
        .unwrap_or_else(|e| panic!("missing golden {}: {e}", expected.display()));
    if want.starts_with(SENTINEL) {
        if std::env::var("GOLDEN_BLESS").as_deref() == Ok("1") {
            std::fs::write(expected, actual).unwrap();
            eprintln!("BLESSED {label}: wrote {}", expected.display());
        } else {
            eprintln!(
                "NOTE: {label} golden is unblessed; cross-config parity verified, bytes not \
                 yet pinned (record with GOLDEN_BLESS=1)"
            );
        }
    } else {
        assert_eq!(want, actual, "{label} diverged from the checked-in golden bytes");
    }
}

#[test]
fn single_end_golden_is_byte_identical_across_configs() {
    let fx = fixtures();
    let dir = std::env::temp_dir().join(format!("dartpim-golden-se-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let (rf, rd) = (fx.join("ref.fasta"), fx.join("reads_se.fastq"));
    let mut outputs: Vec<(String, String)> = Vec::new();
    for threads in [1usize, 4] {
        for engine in ["rust", "bitpal"] {
            for epoch in [7usize, 2048] {
                let out = dir.join(format!("se-{threads}-{engine}-{epoch}.tsv"));
                run(&format!(
                    "map --ref {} --reads {} --low-th 0 --engine {engine} --threads {threads} \
                     --stream-epoch {epoch} --out {}",
                    rf.display(),
                    rd.display(),
                    out.display()
                ));
                outputs.push((
                    format!("threads={threads} engine={engine} epoch={epoch}"),
                    std::fs::read_to_string(&out).unwrap(),
                ));
            }
        }
    }
    let (base_label, base) = &outputs[0];
    for (label, tsv) in &outputs[1..] {
        assert_eq!(base, tsv, "{label} must equal {base_label}");
    }
    // 11 mappable reads (10 exact + 1 with two substitutions); the
    // random read11 must not map
    assert_eq!(base.lines().count(), 1 + 11, "one header + 11 mapped rows:\n{base}");
    assert!(!base.lines().any(|l| l.starts_with("11\t")), "garbage read mapped:\n{base}");
    for id in 0..10 {
        let row = base
            .lines()
            .find(|l| l.starts_with(&format!("{id}\t")))
            .unwrap_or_else(|| panic!("exact read {id} unmapped:\n{base}"));
        assert!(row.contains("\t0\t"), "exact read {id} should map at distance 0: {row}");
    }
    check_golden(&fx.join("expected_se.tsv"), base, "single-end");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn paired_golden_is_byte_identical_across_configs_and_sources() {
    let fx = fixtures();
    let dir = std::env::temp_dir().join(format!("dartpim-golden-pe-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let rf = fx.join("ref.fasta");
    let two = format!(
        "--reads {} --reads2 {}",
        fx.join("reads_r1.fastq").display(),
        fx.join("reads_r2.fastq").display()
    );
    let il = format!("--reads {} --interleaved", fx.join("reads_interleaved.fastq").display());
    let mut outputs: Vec<(String, String)> = Vec::new();
    for threads in [1usize, 4] {
        for engine in ["rust", "bitpal"] {
            for epoch in [7usize, 2048] {
                for (src_name, src) in [("two-file", &two), ("interleaved", &il)] {
                    let out =
                        dir.join(format!("pe-{threads}-{engine}-{epoch}-{src_name}.tsv"));
                    run(&format!(
                        "map --ref {} {src} --low-th 0 --engine {engine} --threads {threads} \
                         --stream-epoch {epoch} --out {}",
                        rf.display(),
                        out.display()
                    ));
                    outputs.push((
                        format!("threads={threads} engine={engine} epoch={epoch} {src_name}"),
                        std::fs::read_to_string(&out).unwrap(),
                    ));
                }
            }
        }
    }
    let (base_label, base) = &outputs[0];
    for (label, tsv) in &outputs[1..] {
        assert_eq!(base, tsv, "{label} must equal {base_label}");
    }
    assert!(base.starts_with("pair_id\tmate\t"), "paired TSV schema:\n{base}");
    // pairs 0..=6 have both mates planted: 14 proper mates; pair7's R2
    // is random garbage — unmappable and unrescuable — so its R1 falls
    // back to the single-end decision and R2 emits no row
    assert_eq!(base.lines().count(), 1 + 15, "one header + 15 mapped mates:\n{base}");
    assert_eq!(
        base.matches("\tproper\n").count(),
        14,
        "pairs 0..=6 must resolve proper:\n{base}"
    );
    let pair7_r1 = base
        .lines()
        .find(|l| l.starts_with("7\t1\t"))
        .unwrap_or_else(|| panic!("pair7 R1 unmapped:\n{base}"));
    assert!(pair7_r1.ends_with("\tsingle"), "pair7 R1 degrades to single-end: {pair7_r1}");
    assert!(!base.lines().any(|l| l.starts_with("7\t2\t")), "garbage mate mapped:\n{base}");
    // R2 mates map on the reverse strand in FR pairs
    assert!(base.lines().any(|l| {
        let cols: Vec<&str> = l.split('\t').collect();
        cols.len() == 8 && cols[1] == "2" && cols[3] == "-" && cols[7] == "proper"
    }));
    check_golden(&fx.join("expected_pe.tsv"), base, "paired");
    std::fs::remove_dir_all(&dir).ok();
}
