//! Cross-module property tests: the algorithmic invariants the
//! reproduction rests on, exercised at full read length with seeded
//! random workloads (in-crate property harness; see util::proptest).

use dart_pim::align::banded_affine::affine_wf_band;
use dart_pim::align::banded_linear::{best_of_band, linear_wf_band};
use dart_pim::align::full_dp::{semi_global_affine, semi_global_linear};
use dart_pim::align::traceback::{script_consistent, script_cost, traceback};
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{window_len, BAND, ETH, K, READ_LEN, SAT_AFFINE, SAT_LINEAR, W};
use dart_pim::util::proptest::check;
use dart_pim::util::SmallRng;

/// Random (read, window) pair; optionally plant the read with edits.
fn pair(rng: &mut SmallRng, n: usize, plant: bool) -> (Vec<u8>, Vec<u8>, usize) {
    let read: Vec<u8> = (0..n).map(|_| rng.gen_range(0..4)).collect();
    let mut win: Vec<u8> = (0..window_len(n)).map(|_| rng.gen_range(0..4)).collect();
    let mut edits = 0;
    if plant {
        let shift = rng.gen_range(0..BAND);
        let mut seq = read.clone();
        edits = rng.gen_range(0..5usize);
        for _ in 0..edits {
            match rng.gen_range(0..3u8) {
                0 => {
                    let p = rng.gen_range(0..seq.len());
                    seq[p] = (seq[p] + rng.gen_range(1..4u8)) % 4;
                }
                1 => {
                    let p = rng.gen_range(0..seq.len());
                    seq.remove(p);
                }
                _ => {
                    let p = rng.gen_range(0..=seq.len());
                    seq.insert(p, rng.gen_range(0..4));
                }
            }
        }
        let take = seq.len().min(win.len() - shift);
        win[shift..shift + take].copy_from_slice(&seq[..take]);
    }
    (read, win, edits)
}

#[test]
fn linear_band_never_beats_unbanded_dp() {
    // The band can only restrict the alignment space: an unsaturated
    // banded result is lower-bounded by the unbanded semi-global
    // distance over the same window.
    check("band >= unbanded", 0x1001, 120, |rng| {
        let plant = rng.gen_bool(0.7);
        let (read, win, _) = pair(rng, READ_LEN, plant);
        let (band_best, _) = best_of_band(&linear_wf_band(&read, &win));
        let full = semi_global_linear(&read, &win).dist;
        if band_best < SAT_LINEAR {
            assert!(
                band_best >= full,
                "banded {band_best} < unbanded {full} — band cannot find cheaper alignments"
            );
        }
    });
}

#[test]
fn affine_dominates_linear() {
    // Affine gap costs >= linear gap costs (open adds w_op), so the
    // affine band distance is >= the linear band distance wherever both
    // are unsaturated.
    check("affine >= linear", 0x1002, 120, |rng| {
        let plant = rng.gen_bool(0.8);
        let (read, win, _) = pair(rng, READ_LEN, plant);
        let (lin, _) = best_of_band(&linear_wf_band(&read, &win));
        let (aff, _) = best_of_band(&affine_wf_band(&read, &win).band);
        if lin < SAT_LINEAR && aff < SAT_AFFINE {
            assert!(aff >= lin, "affine {aff} < linear {lin}");
        }
    });
}

#[test]
fn affine_band_brackets_unbanded_gotoh() {
    check("affine band brackets gotoh", 0x1003, 80, |rng| {
        let read: Vec<u8> = (0..READ_LEN).map(|_| rng.gen_range(0..4)).collect();
        let mut seq = read.clone();
        for _ in 0..rng.gen_range(0..3usize) {
            let p = rng.gen_range(0..seq.len());
            seq[p] = (seq[p] + rng.gen_range(1..4u8)) % 4;
        }
        let mut win: Vec<u8> =
            (0..window_len(READ_LEN)).map(|_| rng.gen_range(0..4)).collect();
        win[ETH..ETH + READ_LEN].copy_from_slice(&seq[..READ_LEN]);
        let (aff, _) = best_of_band(&affine_wf_band(&read, &win).band);
        let gotoh = semi_global_affine(&read, &win).dist;
        if aff < SAT_AFFINE && gotoh <= ETH as i32 {
            // the band restricts, the anchor charges at most |shift|<=eth
            assert!(aff >= gotoh && aff <= gotoh + ETH as i32, "aff {aff} vs gotoh {gotoh}");
        }
    });
}

#[test]
fn traceback_identities_at_full_read_length() {
    check("traceback cost+consistency @150bp", 0x1004, 100, |rng| {
        let (read, win, _) = pair(rng, READ_LEN, true);
        let res = affine_wf_band(&read, &win);
        let (dist, j) = best_of_band(&res.band);
        if dist >= SAT_AFFINE {
            return;
        }
        let aln = traceback(&res.dirs, read.len(), j).expect("unsaturated traceback");
        assert_eq!(script_cost(&aln.ops, aln.j_end), dist, "cost identity");
        assert!(script_consistent(&aln.ops, aln.j_end, &read, &win), "structural consistency");
    });
}

#[test]
fn window_extraction_paths_agree() {
    // index.window_for (host fast path) == window_of_segment(segment)
    // (the paper's crossbar data layout) for every occurrence.
    let g = SynthConfig { len: 50_000, ..Default::default() }.generate();
    let idx = MinimizerIndex::build(g, K, W, READ_LEN);
    let mut checked = 0;
    for (_, occs) in idx.iter().take(300) {
        for &pos in occs {
            let seg = idx.segment(pos);
            for q in [0usize, 17, 77, READ_LEN - K] {
                let a = idx.window_of_segment(&seg, q);
                let b = idx.window_for(pos, q);
                assert_eq!(a, &b[..], "window mismatch at pos={pos} q={q}");
                checked += 1;
            }
        }
    }
    assert!(checked > 100);
}

#[test]
fn unsaturated_filter_passes_are_genuine() {
    // Every banded pass (distance <= eth) is a true near-match: the
    // unbanded distance cannot exceed it — the property that justifies
    // the paper's 3-bit saturation.
    check("passes are genuine", 0x1005, 100, |rng| {
        let plant = rng.gen_bool(0.6);
        let (read, win, _) = pair(rng, 60, plant);
        let (best, _) = best_of_band(&linear_wf_band(&read, &win));
        if best <= ETH as i32 {
            let full = semi_global_linear(&read, &win).dist;
            assert!(full <= best, "full {full} > banded {best}");
        }
    });
}

#[test]
fn simulated_reads_always_have_inband_truth_windows() {
    // Read-simulator + indexing geometry: for an error-free read, the
    // window built from any of its minimizer occurrences at the truth
    // position has banded distance 0 on the anchor diagonal.
    let g = SynthConfig { len: 60_000, ..Default::default() }.generate();
    let idx = MinimizerIndex::build(g, K, W, READ_LEN);
    let reads = ReadSimConfig {
        n_reads: 30,
        sub_rate: 0.0,
        ins_rate: 0.0,
        del_rate: 0.0,
        ..Default::default()
    }
    .simulate(&idx.reference, |p| p as u32);
    for r in &reads {
        let mut found_zero = false;
        for seed in dart_pim::seeding::seed_read(&idx, &r.seq) {
            for &pos in idx.occurrences(seed.kmer) {
                if pos as i64 - seed.read_offset as i64 == r.truth_pos as i64 {
                    let win = idx.window_for(pos, seed.read_offset as usize);
                    let (d, j) = best_of_band(&linear_wf_band(&r.seq, &win));
                    assert_eq!(d, 0, "error-free read truth window must be exact");
                    assert_eq!(j, ETH, "exact match sits on the anchor diagonal");
                    found_zero = true;
                }
            }
        }
        assert!(found_zero, "read {} never saw its truth window", r.id);
    }
}
