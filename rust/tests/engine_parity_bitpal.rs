//! Bit-parallel engine parity: `BitpalEngine` must agree with
//! `RustEngine` *exactly* — same bands, same best distances, same
//! best-of-band tie-breaks, same affine direction planes — over
//! randomized batches, including the shapes that stress the word-lane
//! layout (batch sizes that don't divide 64), the recurrence's fixed
//! points (all-mismatch reads, N bases), and instances that straddle
//! the `dist == eth` filter boundary.

mod common;

use common::{as_slices, rand_batch};
use dart_pim::params::{window_len, ETH, SAT_LINEAR};
use dart_pim::runtime::{BitpalEngine, RustEngine, WfEngine};
use dart_pim::util::proptest::check;

#[test]
fn linear_batch_parity_randomized() {
    check("bitpal linear parity", 0xB17A, 40, |rng| {
        // batch sizes deliberately off the 64-lane grid
        let b = rng.gen_range(1..=130usize);
        let n = [1usize, 3, 17, 30, 64, 150][rng.gen_range(0..6usize)];
        let (reads, wins) = rand_batch(rng, b, n);
        let rr = as_slices(&reads);
        let ww = as_slices(&wins);
        let rust = RustEngine.linear_batch(&rr, &ww).unwrap();
        let bit = BitpalEngine::new().linear_batch(&rr, &ww).unwrap();
        assert_eq!(rust.best, bit.best, "b={b} n={n}");
        assert_eq!(rust.best_j, bit.best_j, "b={b} n={n}");
        assert_eq!(rust.band, bit.band, "b={b} n={n}");
    });
}

#[test]
fn affine_batch_parity_randomized() {
    check("bitpal affine parity", 0xAFF1, 25, |rng| {
        let b = rng.gen_range(1..=70usize);
        let n = [17usize, 30, 64, 150][rng.gen_range(0..4usize)];
        let (reads, wins) = rand_batch(rng, b, n);
        let rr = as_slices(&reads);
        let ww = as_slices(&wins);
        let rust = RustEngine.affine_batch(&rr, &ww).unwrap();
        let bit = BitpalEngine::new().affine_batch(&rr, &ww).unwrap();
        assert_eq!(rust.best, bit.best, "b={b} n={n}");
        assert_eq!(rust.best_j, bit.best_j, "b={b} n={n}");
        assert_eq!(rust.band, bit.band, "b={b} n={n}");
        assert_eq!(rust.dirs, bit.dirs, "b={b} n={n}");
    });
}

/// Deterministic boundary sweep: one instance per substitution count
/// s = 0..=12 (sub positions spaced so no cheaper gap path exists, the
/// filler base pattern shifted so off-diagonals mismatch). The batch of
/// 13 straddles the filter threshold instance by instance:
/// `best == min(s, eth + 1)` with the tie-break pinned at the anchor.
#[test]
fn boundary_instances_straddle_the_filter_threshold() {
    let n = 30;
    let read: Vec<u8> = (0..n).map(|i| (i % 4) as u8).collect();
    let mut reads = Vec::new();
    let mut wins = Vec::new();
    for s in 0..=12usize {
        let mut win: Vec<u8> = (0..window_len(n)).map(|c| ((c + 2) % 4) as u8).collect();
        win[ETH..ETH + n].copy_from_slice(&read);
        for t in 0..s {
            let p = 2 * t + 1;
            win[ETH + p] = (read[p] + 2) % 4;
        }
        reads.push(read.clone());
        wins.push(win);
    }
    let rr = as_slices(&reads);
    let ww = as_slices(&wins);
    let rust = RustEngine.linear_batch(&rr, &ww).unwrap();
    let bit = BitpalEngine::new().linear_batch(&rr, &ww).unwrap();
    assert_eq!(rust.best, bit.best);
    assert_eq!(rust.best_j, bit.best_j);
    assert_eq!(rust.band, bit.band);
    for (s, &best) in bit.best.iter().enumerate() {
        assert_eq!(best, (s as i32).min(SAT_LINEAR), "s={s}");
    }
    // the sweep really covers dist == eth and the first saturated value
    assert!(bit.best.contains(&(ETH as i32)));
    assert!(bit.best.contains(&SAT_LINEAR));
    assert_eq!(bit.best_j[ETH], ETH as u32, "anchor tie-break at the boundary");
}
