//! The lane-width parity fortress: every `BitpalEngine` variant —
//! `--simd u64`, `--simd wide` (whatever width this host resolves),
//! `--simd off` (scalar fallback), and the portable kernel forced to
//! each of the four lane widths (64/128/256/512 bits, runnable on any
//! host) — must agree with `RustEngine` *exactly*. Same bands, same
//! best distances, same best-of-band tie-breaks, same affine direction
//! planes, over ≥10k randomized linear instances and a dedicated
//! affine corpus per variant, including the shapes that stress the
//! word-lane layout (batch sizes off every lane grid), the
//! recurrence's fixed points (all-mismatch reads, N bases), and
//! instances that straddle the `dist == eth` filter boundary.
//!
//! Every randomized corpus is built from a named seed constant that
//! appears in the failure message, so a red run reproduces exactly.

mod common;

use common::{as_slices, rand_wf_corpus};
use dart_pim::params::{window_len, ETH, SAT_LINEAR};
use dart_pim::runtime::{BitpalEngine, RustEngine, SimdMode, SimdWidth, WfEngine};

/// Seed of the linear fortress corpus (≥10k instances per variant).
const LINEAR_SEED: u64 = 0xB17A_F0B7;
/// Seed of the affine fortress corpus (≥1.5k instances per variant).
const AFFINE_SEED: u64 = 0xAFF1_F0B7;

/// Every engine variant the fortress holds to the oracle: the three
/// `--simd` modes as the CLI builds them, plus the portable kernel
/// pinned to each lane width (so 256/512-bit chunking is exercised
/// even on hosts without AVX2/AVX-512).
fn variants() -> Vec<(String, BitpalEngine)> {
    let mut v: Vec<(String, BitpalEngine)> = [SimdMode::U64, SimdMode::Wide, SimdMode::Off]
        .into_iter()
        .map(|m| (format!("mode={}", m.name()), BitpalEngine::with_mode(m)))
        .collect();
    for w in SimdWidth::all() {
        v.push((format!("portable{}", w.bits()), BitpalEngine::portable(w)));
    }
    v
}

#[test]
fn linear_fortress_every_width_matches_the_oracle() {
    let corpus = rand_wf_corpus(LINEAR_SEED, 10_000);
    // oracle once per batch, reused across all variants
    let oracle: Vec<_> = corpus
        .iter()
        .map(|(reads, wins)| {
            RustEngine.linear_batch(&as_slices(reads), &as_slices(wins)).unwrap()
        })
        .collect();
    for (name, mut engine) in variants() {
        for (bi, ((reads, wins), rust)) in corpus.iter().zip(&oracle).enumerate() {
            let ctx = format!(
                "{name} batch {bi} (b={}, n={}, seed {LINEAR_SEED:#x})",
                reads.len(),
                reads[0].len()
            );
            let bit = engine.linear_batch(&as_slices(reads), &as_slices(wins)).unwrap();
            assert_eq!(rust.best, bit.best, "best diverged: {ctx}");
            assert_eq!(rust.best_j, bit.best_j, "best_j diverged: {ctx}");
            assert_eq!(rust.band, bit.band, "band diverged: {ctx}");
        }
    }
}

#[test]
fn affine_fortress_every_width_matches_the_oracle() {
    let corpus = rand_wf_corpus(AFFINE_SEED, 1_500);
    let oracle: Vec<_> = corpus
        .iter()
        .map(|(reads, wins)| {
            RustEngine.affine_batch(&as_slices(reads), &as_slices(wins)).unwrap()
        })
        .collect();
    for (name, mut engine) in variants() {
        for (bi, ((reads, wins), rust)) in corpus.iter().zip(&oracle).enumerate() {
            let ctx = format!(
                "{name} batch {bi} (b={}, n={}, seed {AFFINE_SEED:#x})",
                reads.len(),
                reads[0].len()
            );
            let bit = engine.affine_batch(&as_slices(reads), &as_slices(wins)).unwrap();
            assert_eq!(rust.best, bit.best, "best diverged: {ctx}");
            assert_eq!(rust.best_j, bit.best_j, "best_j diverged: {ctx}");
            assert_eq!(rust.band, bit.band, "band diverged: {ctx}");
            assert_eq!(rust.dirs, bit.dirs, "dirs diverged: {ctx}");
        }
    }
}

/// Batch sizes sitting exactly on and around every lane-grid edge
/// (64/128/256/512 all divide into these boundaries), fed to every
/// variant: the tail-lane masking paths are where width bugs live.
#[test]
fn lane_grid_edges_are_exact_at_every_width() {
    const EDGE_SEED: u64 = 0xED6E_5EED;
    let mut rng = dart_pim::util::SmallRng::seed_from_u64(EDGE_SEED);
    for b in [1usize, 63, 64, 65, 127, 128, 129, 130, 255, 256, 257, 511, 512, 513] {
        let (reads, wins) = common::rand_batch(&mut rng, b, 30);
        let rr = as_slices(&reads);
        let ww = as_slices(&wins);
        let lin = RustEngine.linear_batch(&rr, &ww).unwrap();
        let aff = RustEngine.affine_batch(&rr, &ww).unwrap();
        for (name, mut engine) in variants() {
            let ctx = format!("{name} b={b} (seed {EDGE_SEED:#x})");
            let bl = engine.linear_batch(&rr, &ww).unwrap();
            assert_eq!(lin.best, bl.best, "linear best: {ctx}");
            assert_eq!(lin.best_j, bl.best_j, "linear best_j: {ctx}");
            assert_eq!(lin.band, bl.band, "linear band: {ctx}");
            let ba = engine.affine_batch(&rr, &ww).unwrap();
            assert_eq!(aff.best, ba.best, "affine best: {ctx}");
            assert_eq!(aff.dirs, ba.dirs, "affine dirs: {ctx}");
        }
    }
}

/// Deterministic boundary sweep: one instance per substitution count
/// s = 0..=12 (sub positions spaced so no cheaper gap path exists, the
/// filler base pattern shifted so off-diagonals mismatch). The batch of
/// 13 straddles the filter threshold instance by instance:
/// `best == min(s, eth + 1)` with the tie-break pinned at the anchor —
/// checked at every lane width, since the filter boundary is where a
/// one-off in the clamp or the counter would change routing decisions.
#[test]
fn boundary_instances_straddle_the_filter_threshold() {
    let n = 30;
    let read: Vec<u8> = (0..n).map(|i| (i % 4) as u8).collect();
    let mut reads = Vec::new();
    let mut wins = Vec::new();
    for s in 0..=12usize {
        let mut win: Vec<u8> = (0..window_len(n)).map(|c| ((c + 2) % 4) as u8).collect();
        win[ETH..ETH + n].copy_from_slice(&read);
        for t in 0..s {
            let p = 2 * t + 1;
            win[ETH + p] = (read[p] + 2) % 4;
        }
        reads.push(read.clone());
        wins.push(win);
    }
    let rr = as_slices(&reads);
    let ww = as_slices(&wins);
    let rust = RustEngine.linear_batch(&rr, &ww).unwrap();
    for (name, mut engine) in variants() {
        let bit = engine.linear_batch(&rr, &ww).unwrap();
        assert_eq!(rust.best, bit.best, "{name}");
        assert_eq!(rust.best_j, bit.best_j, "{name}");
        assert_eq!(rust.band, bit.band, "{name}");
        for (s, &best) in bit.best.iter().enumerate() {
            assert_eq!(best, (s as i32).min(SAT_LINEAR), "{name} s={s}");
        }
        // the sweep really covers dist == eth and the first saturated value
        assert!(bit.best.contains(&(ETH as i32)), "{name}");
        assert!(bit.best.contains(&SAT_LINEAR), "{name}");
        assert_eq!(bit.best_j[ETH], ETH as u32, "{name}: anchor tie-break at the boundary");
    }
}

/// The wide mode resolves to a real lane width on this host and the
/// scalar fallback reports none — the dispatch surface the pipeline
/// metrics gauge reads.
#[test]
fn resolved_widths_are_reported() {
    assert_eq!(BitpalEngine::with_mode(SimdMode::U64).width_bits(), 64);
    assert_eq!(BitpalEngine::with_mode(SimdMode::Off).width_bits(), 0);
    let wide = BitpalEngine::with_mode(SimdMode::Wide).width_bits();
    assert!(
        [128, 256, 512].contains(&wide),
        "wide must resolve to a detected SIMD width, got {wide}"
    );
    for w in SimdWidth::all() {
        assert_eq!(BitpalEngine::portable(w).width_bits(), w.bits());
    }
}
