//! Sharding determinism: `map_reads` must produce byte-identical output
//! for every worker-thread count — same mappings, same CIGARs, same
//! workload counters. This is the contract that lets the sharded
//! pipeline (and every later scaling PR built on it) claim the paper's
//! parallelism without changing a single mapping decision.
//!
//! Workload: synthetic reference, donor-derived mutated reads (SNPs +
//! indels between donor and reference, sequencing errors on top) — the
//! same shape as the e2e suite, so ties and near-ties actually occur.

mod common;

use common::{render, workload_sized};
use dart_pim::coordinator::{FilterPolicy, Pipeline, PipelineConfig};
use dart_pim::genome::ReadRecord;
use dart_pim::index::MinimizerIndex;
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::{BitpalEngine, EngineKind, RustEngine};

fn workload(n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
    workload_sized(300_000, n_reads)
}

fn run(
    idx: &MinimizerIndex,
    reads: &[ReadRecord],
    threads: usize,
    policy: FilterPolicy,
    revcomp: bool,
) -> (String, std::collections::BTreeMap<String, u64>) {
    let cfg = PipelineConfig {
        dart: DartPimConfig { low_th: 1, ..Default::default() },
        filter_policy: policy,
        handle_revcomp: revcomp,
        threads,
        ..Default::default()
    };
    let mut p = Pipeline::new(idx, cfg, RustEngine);
    let (mappings, metrics) = p.map_reads(reads).unwrap();
    (render(&mappings), metrics.invariant_counters())
}

#[test]
fn threads_1_and_4_are_byte_identical() {
    let (idx, reads) = workload(400);
    let (tsv1, counters1) = run(&idx, &reads, 1, FilterPolicy::AllPassing, false);
    let (tsv4, counters4) = run(&idx, &reads, 4, FilterPolicy::AllPassing, false);
    assert!(!tsv1.is_empty(), "workload must actually map reads");
    assert_eq!(tsv1, tsv4, "mappings + CIGARs must be byte-identical");
    assert_eq!(counters1, counters4, "workload counters must be identical");
}

#[test]
fn every_thread_count_agrees() {
    let (idx, reads) = workload(200);
    let (base, counters) = run(&idx, &reads, 1, FilterPolicy::AllPassing, false);
    for threads in [2usize, 3, 4, 8] {
        let (tsv, c) = run(&idx, &reads, threads, FilterPolicy::AllPassing, false);
        assert_eq!(base, tsv, "threads={threads}");
        assert_eq!(counters, c, "threads={threads}");
    }
}

#[test]
fn min_only_policy_is_also_deterministic() {
    let (idx, reads) = workload(200);
    let (tsv1, c1) = run(&idx, &reads, 1, FilterPolicy::MinOnly, false);
    let (tsv4, c4) = run(&idx, &reads, 4, FilterPolicy::MinOnly, false);
    assert!(!tsv1.is_empty());
    assert_eq!(tsv1, tsv4);
    assert_eq!(c1, c4);
}

#[test]
fn revcomp_reads_are_also_deterministic() {
    let (idx, mut reads) = workload(150);
    for r in reads.iter_mut() {
        if r.id % 2 == 1 {
            r.seq = dart_pim::genome::revcomp(&r.seq);
        }
    }
    let (tsv1, c1) = run(&idx, &reads, 1, FilterPolicy::AllPassing, true);
    let (tsv4, c4) = run(&idx, &reads, 4, FilterPolicy::AllPassing, true);
    assert!(tsv1.contains('-'), "some reads must map on the reverse strand");
    assert_eq!(tsv1, tsv4);
    assert_eq!(c1, c4);
}

#[test]
fn bitpal_workers_are_byte_identical_to_rust() {
    // engine determinism composes with shard determinism: a 4-way
    // sharded run whose workers own bit-parallel engines must emit the
    // same bytes as the single-threaded scalar run
    let (idx, reads) = workload(200);
    let (base_tsv, base_counters) = run(&idx, &reads, 1, FilterPolicy::AllPassing, false);
    assert!(!base_tsv.is_empty());
    let cfg = PipelineConfig {
        dart: DartPimConfig { low_th: 1, ..Default::default() },
        threads: 4,
        worker_engine: EngineKind::Bitpal,
        ..Default::default()
    };
    let mut p = Pipeline::new(&idx, cfg, BitpalEngine::new());
    let (mappings, metrics) = p.map_reads(&reads).unwrap();
    assert_eq!(base_tsv, render(&mappings), "bitpal TSV must match rust byte-for-byte");
    assert_eq!(base_counters, metrics.invariant_counters());
}

#[test]
fn max_reads_cap_drops_identically() {
    // the FIFO lifetime cap is order-sensitive bookkeeping; the
    // minimizer-hash partition must preserve which pairs are dropped
    let (idx, reads) = workload(300);
    let run_capped = |threads: usize| {
        let cfg = PipelineConfig {
            dart: DartPimConfig { low_th: 0, max_reads: 3, ..Default::default() },
            threads,
            ..Default::default()
        };
        let mut p = Pipeline::new(&idx, cfg, RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        (render(&mappings), metrics.invariant_counters())
    };
    let (tsv1, c1) = run_capped(1);
    assert!(c1["dropped_pairs"] > 0, "cap of 3 must drop pairs");
    let (tsv4, c4) = run_capped(4);
    assert_eq!(tsv1, tsv4);
    assert_eq!(c1, c4);
}
