//! Integration: the on-disk index cache must round-trip exactly and
//! reject truncated / corrupted / version-skewed files with descriptive
//! errors — never misparse, never trust a declared length with a giant
//! allocation (a corrupt length field must fail as "truncated", not
//! abort the process).

use dart_pim::genome::synth::SynthConfig;
use dart_pim::index::{load_index, save_index, MinimizerIndex};
use dart_pim::params::{K, READ_LEN, W};

fn build_index() -> MinimizerIndex {
    let g = SynthConfig { len: 40_000, ..Default::default() }.generate();
    MinimizerIndex::build(g, K, W, READ_LEN)
}

fn serialized(idx: &MinimizerIndex) -> Vec<u8> {
    let mut buf = Vec::new();
    dart_pim::index::io::write_index(&mut buf, idx).unwrap();
    buf
}

fn parse(buf: &[u8]) -> std::io::Result<MinimizerIndex> {
    dart_pim::index::io::read_index(&mut &buf[..])
}

#[test]
fn file_round_trip_preserves_everything() {
    let idx = build_index();
    let path = std::env::temp_dir().join(format!("dartpim-iio-{}.bin", std::process::id()));
    save_index(&path, &idx).unwrap();
    let back = load_index(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!((back.k, back.w, back.read_len), (idx.k, idx.w, idx.read_len));
    assert_eq!(back.reference, idx.reference);
    assert_eq!(back.n_minimizers(), idx.n_minimizers());
    for (m, occs) in idx.iter() {
        assert_eq!(back.occurrences(m), occs, "minimizer {m:#x}");
    }
}

#[test]
fn every_truncation_point_is_rejected() {
    let idx = build_index();
    let buf = serialized(&idx);
    // sweep the header densely and the payload sparsely; every proper
    // prefix must fail (the format has no optional tail)
    let mut cuts: Vec<usize> = (0..64.min(buf.len())).collect();
    cuts.extend((64..buf.len()).step_by(buf.len() / 31 + 1));
    cuts.push(buf.len() - 1);
    for cut in cuts {
        let err = parse(&buf[..cut]).expect_err(&format!("prefix of {cut} bytes must fail"));
        let msg = err.to_string();
        assert!(
            msg.contains("truncated") || msg.contains("magic"),
            "cut={cut}: unhelpful error {msg:?}"
        );
    }
}

#[test]
fn bad_magic_and_version_skew_are_distinguished() {
    let idx = build_index();
    let mut buf = serialized(&idx);
    // wholly different magic
    let err = parse(b"NOTANIDXatall").unwrap_err();
    assert!(err.to_string().contains("not a DART-PIM index"), "{err}");
    // same family, future version byte: the error must say "version"
    buf[7] = b'9';
    let err = parse(&buf).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");
}

#[test]
fn corrupt_length_fields_fail_without_huge_allocation() {
    let idx = build_index();
    let buf = serialized(&idx);
    // ref_len (bytes 32..40) -> absurd: must report truncation, and must
    // not try to pre-allocate 2^64 bytes on the way there
    let mut evil = buf.clone();
    evil[32..40].copy_from_slice(&u64::MAX.to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("truncated"), "{err}");
    // geometry: k = 0 is implausible
    let mut evil = buf.clone();
    evil[8..16].copy_from_slice(&0u64.to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("geometry"), "{err}");
}

#[test]
fn corrupt_payload_is_rejected() {
    let idx = build_index();
    let buf = serialized(&idx);
    // first reference base -> invalid code
    let mut evil = buf.clone();
    evil[40] = 9;
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("base codes"), "{err}");
    // last occurrence position -> far out of the reference
    let mut evil = buf.clone();
    let n = evil.len();
    evil[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
    let err = parse(&evil).unwrap_err();
    assert!(err.to_string().contains("out of reference bounds"), "{err}");
}

#[test]
fn trailing_garbage_is_rejected() {
    let idx = build_index();
    let mut buf = serialized(&idx);
    buf.push(0x5a);
    let err = parse(&buf).unwrap_err();
    assert!(err.to_string().contains("trailing"), "{err}");
}
