//! Determinism invariant 8: the SIMD lane width is a dispatch detail.
//! `map` must emit byte-identical TSV for every `--simd` mode — across
//! thread counts, engines, and both input layouts (single-end and
//! interleaved paired) — and a `serve` daemon pinned to one mode must
//! answer with the same bytes as a `map` run in another. The golden
//! fixtures make the claim executable on the exact workload the other
//! e2e suites pin.

use std::path::PathBuf;

use dart_pim::cli;

fn fixtures() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden")
}

fn run(cmd: &str) {
    let argv: Vec<String> = cmd.split_whitespace().map(|s| s.to_string()).collect();
    cli::run(&argv).unwrap_or_else(|e| panic!("`{cmd}` failed: {e:#}"));
}

/// `map` over the golden fixtures with `flags`, returning the TSV bytes.
fn map_tsv(tag: &str, input_flags: &str, flags: &str) -> String {
    let fx = fixtures();
    let out = std::env::temp_dir().join(format!(
        "dartpim-simd-{}-{tag}.tsv",
        std::process::id()
    ));
    run(&format!(
        "map --ref {} {input_flags} --low-th 0 {flags} --out {}",
        fx.join("ref.fasta").display(),
        out.display()
    ));
    let tsv = std::fs::read_to_string(&out).unwrap();
    let _ = std::fs::remove_file(&out);
    tsv
}

/// The full `simd × engine × threads` sweep on both input layouts: one
/// baseline (scalar reference engine), every other cell byte-equal.
#[test]
fn map_bytes_are_identical_across_simd_modes_engines_and_threads() {
    let fx = fixtures();
    let se_input = format!("--reads {}", fx.join("reads_se.fastq").display());
    let pe_input =
        format!("--reads {} --interleaved", fx.join("reads_interleaved.fastq").display());
    for (layout, input) in [("se", &se_input), ("pe", &pe_input)] {
        let base = map_tsv(
            &format!("{layout}-base"),
            input,
            "--engine rust --threads 1 --simd off",
        );
        assert!(!base.is_empty(), "{layout}: baseline produced no bytes");
        let mut cells = 0usize;
        for engine in ["rust", "bitpal"] {
            for simd in ["u64", "wide", "off"] {
                for threads in [1usize, 4] {
                    let label = format!("{layout} engine={engine} simd={simd} t={threads}");
                    let tsv = map_tsv(
                        &format!("{layout}-{engine}-{simd}-{threads}"),
                        input,
                        &format!("--engine {engine} --simd {simd} --threads {threads}"),
                    );
                    assert_eq!(base, tsv, "{label} diverged from the scalar baseline");
                    cells += 1;
                }
            }
        }
        assert_eq!(cells, 12, "{layout}: the sweep must cover every combination");
    }
}

/// An unknown `--simd` value is a loud CLI error, not a silent default.
#[test]
fn unknown_simd_mode_is_rejected() {
    let fx = fixtures();
    let cmd = format!(
        "map --ref {} --reads {} --low-th 0 --simd avx9000 --out /dev/null",
        fx.join("ref.fasta").display(),
        fx.join("reads_se.fastq").display()
    );
    let argv: Vec<String> = cmd.split_whitespace().map(|s| s.to_string()).collect();
    let err = cli::run(&argv).expect_err("--simd avx9000 must fail");
    assert!(format!("{err:#}").contains("avx9000"), "error names the bad value: {err:#}");
}

/// Cross-mode serve parity: a daemon pinned to `--simd off` must answer
/// a raw-mode session with exactly the bytes `map --simd wide` writes —
/// the lane width cannot leak through the wire protocol either.
#[cfg(unix)]
#[test]
fn serve_daemon_simd_mode_cannot_change_response_bytes() {
    use std::io::{Read, Write};
    use std::os::unix::net::UnixStream;
    use std::process::{Command, Stdio};
    use std::time::{Duration, Instant};

    let fx = fixtures();
    let want = map_tsv(
        "serve-want",
        &format!("--reads {}", fx.join("reads_se.fastq").display()),
        "--engine bitpal --simd wide --threads 2",
    );
    let sock = std::env::temp_dir().join(format!("dartpim-simd-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&sock);
    // golden fixtures are 100 bp reads; the daemon fixes geometry at startup
    let mut child = Command::new(env!("CARGO_BIN_EXE_dart-pim"))
        .args(["serve", "--read-len", "100", "--low-th", "0"])
        .arg("--ref")
        .arg(fx.join("ref.fasta"))
        .args(["--engine", "bitpal", "--simd", "off", "--threads", "2"])
        .arg("--socket")
        .arg(&sock)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning the serve daemon");
    let t0 = Instant::now();
    while !sock.exists() {
        if let Some(status) = child.try_wait().expect("polling the daemon") {
            panic!("daemon exited during startup: {status}");
        }
        assert!(t0.elapsed() < Duration::from_secs(30), "daemon socket never appeared");
        std::thread::sleep(Duration::from_millis(10));
    }
    let result = std::panic::catch_unwind(|| {
        let fastq = std::fs::read(fx.join("reads_se.fastq")).unwrap();
        let mut s = UnixStream::connect(&sock).expect("connecting to the daemon");
        writeln!(s, "DART/1 mode=se framing=raw").unwrap();
        s.write_all(&fastq).unwrap();
        s.flush().unwrap();
        s.shutdown(std::net::Shutdown::Write).unwrap();
        let mut out = Vec::new();
        s.read_to_end(&mut out).unwrap();
        assert_eq!(
            String::from_utf8(out).unwrap(),
            want,
            "serve --simd off must answer with the `map --simd wide` bytes"
        );
    });
    let _ = child.kill();
    let _ = child.wait();
    let _ = std::fs::remove_file(&sock);
    if let Err(e) = result {
        std::panic::resume_unwind(e);
    }
}
