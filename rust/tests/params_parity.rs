//! Rust ↔ Python parameter parity.
//!
//! `python/compile/params.py` is the single source of truth for the
//! paper's Table III values on the Python/Pallas side; `dart_pim::params`
//! mirrors it on the Rust side. The AOT manifest cross-check
//! (`runtime::artifacts::ArtifactManifest::validate`) only runs under the
//! `pjrt` feature with artifacts built, so this test keeps the two layers
//! honest in the default hermetic build: it parses the Python module's
//! top-level integer assignments with a tiny expression evaluator (no
//! Python interpreter needed) and compares every shared constant.

use std::collections::HashMap;

/// Evaluate `+`, `-`, `*`, `<<`, parentheses, integer literals, and
/// previously bound names. Returns None for anything fancier (function
/// defs, calls, strings, tuples) — those lines are simply skipped.
fn eval_expr(src: &str, env: &HashMap<String, i64>) -> Option<i64> {
    let tokens = tokenize(src)?;
    let mut p = Parser { tokens, pos: 0 };
    let v = p.shift_expr(env)?;
    (p.pos == p.tokens.len()).then_some(v)
}

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Num(i64),
    Name(String),
    Plus,
    Minus,
    Star,
    Shl,
    LParen,
    RParen,
}

fn tokenize(src: &str) -> Option<Vec<Tok>> {
    let mut out = Vec::new();
    let b: Vec<char> = src.chars().collect();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        match c {
            ' ' | '\t' => i += 1,
            '0'..='9' => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                out.push(Tok::Num(b[start..i].iter().collect::<String>().parse().ok()?));
            }
            'A'..='Z' | 'a'..='z' | '_' => {
                let start = i;
                while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == '_') {
                    i += 1;
                }
                out.push(Tok::Name(b[start..i].iter().collect()));
            }
            '+' => {
                out.push(Tok::Plus);
                i += 1;
            }
            '-' => {
                out.push(Tok::Minus);
                i += 1;
            }
            '*' => {
                out.push(Tok::Star);
                i += 1;
            }
            '<' => {
                if b.get(i + 1) == Some(&'<') {
                    out.push(Tok::Shl);
                    i += 2;
                } else {
                    return None;
                }
            }
            '(' => {
                out.push(Tok::LParen);
                i += 1;
            }
            ')' => {
                out.push(Tok::RParen);
                i += 1;
            }
            _ => return None, // strings, calls with '.', etc. — skip line
        }
    }
    Some(out)
}

struct Parser {
    tokens: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Tok> {
        self.tokens.get(self.pos)
    }

    // shift := additive ('<<' additive)*
    fn shift_expr(&mut self, env: &HashMap<String, i64>) -> Option<i64> {
        let mut v = self.additive(env)?;
        while self.peek() == Some(&Tok::Shl) {
            self.pos += 1;
            let rhs = self.additive(env)?;
            v <<= rhs;
        }
        Some(v)
    }

    // additive := term (('+'|'-') term)*
    fn additive(&mut self, env: &HashMap<String, i64>) -> Option<i64> {
        let mut v = self.term(env)?;
        loop {
            match self.peek() {
                Some(Tok::Plus) => {
                    self.pos += 1;
                    v += self.term(env)?;
                }
                Some(Tok::Minus) => {
                    self.pos += 1;
                    v -= self.term(env)?;
                }
                _ => return Some(v),
            }
        }
    }

    // term := atom ('*' atom)*
    fn term(&mut self, env: &HashMap<String, i64>) -> Option<i64> {
        let mut v = self.atom(env)?;
        while self.peek() == Some(&Tok::Star) {
            self.pos += 1;
            v *= self.atom(env)?;
        }
        Some(v)
    }

    fn atom(&mut self, env: &HashMap<String, i64>) -> Option<i64> {
        match self.peek()?.clone() {
            Tok::Num(n) => {
                self.pos += 1;
                Some(n)
            }
            Tok::Name(name) => {
                self.pos += 1;
                env.get(&name).copied() // a call like f(x) fails at ')' parity
            }
            Tok::Minus => {
                self.pos += 1;
                Some(-self.atom(env)?)
            }
            Tok::LParen => {
                self.pos += 1;
                let v = self.shift_expr(env)?;
                if self.peek() == Some(&Tok::RParen) {
                    self.pos += 1;
                    Some(v)
                } else {
                    None
                }
            }
            _ => None,
        }
    }
}

/// Parse `NAME = <int expr>` top-level assignments from Python source.
fn parse_python_consts(src: &str) -> HashMap<String, i64> {
    let mut env = HashMap::new();
    for line in src.lines() {
        // top-level only: skip indented lines (function bodies)
        if line.starts_with(' ') || line.starts_with('\t') {
            continue;
        }
        let line = line.split('#').next().unwrap_or("");
        let Some((lhs, rhs)) = line.split_once('=') else { continue };
        let name = lhs.trim();
        if !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') || name.is_empty() {
            continue; // `==` comparisons, annotations, etc.
        }
        if let Some(v) = eval_expr(rhs.trim(), &env) {
            env.insert(name.to_string(), v);
        }
    }
    env
}

fn python_params() -> HashMap<String, i64> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../python/compile/params.py");
    let src = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("cannot read {path}: {e} — the Python layer moved?"));
    parse_python_consts(&src)
}

#[test]
fn python_layer_agrees_with_rust_params() {
    use dart_pim::params as p;
    let py = python_params();
    let get = |k: &str| -> i64 { *py.get(k).unwrap_or_else(|| panic!("params.py lost {k}")) };

    assert_eq!(get("READ_LEN"), p::READ_LEN as i64);
    assert_eq!(get("K"), p::K as i64);
    assert_eq!(get("W"), p::W as i64);
    assert_eq!(get("ETH"), p::ETH as i64);
    assert_eq!(get("BAND"), p::BAND as i64);
    assert_eq!(get("SAT_LINEAR"), p::SAT_LINEAR as i64);
    assert_eq!(get("SAT_AFFINE"), p::SAT_AFFINE as i64);
    assert_eq!(get("W_SUB"), p::W_SUB as i64);
    assert_eq!(get("W_INS"), p::W_INS as i64);
    assert_eq!(get("W_DEL"), p::W_DEL as i64);
    assert_eq!(get("W_OP"), p::W_OP as i64);
    assert_eq!(get("W_EX"), p::W_EX as i64);
    assert_eq!(get("BIG"), p::BIG as i64);
    assert_eq!(get("SEGMENT_LEN"), p::segment_len(p::READ_LEN) as i64);
}

#[test]
fn derived_geometry_matches() {
    use dart_pim::params as p;
    let py = python_params();
    // BAND must be derived identically: 2*eth + 1.
    assert_eq!(py["BAND"], 2 * py["ETH"] + 1);
    assert_eq!(p::BAND, 2 * p::ETH + 1);
    // Segment length: 2*(rl + eth) - k on both sides (300 for 150 bp).
    assert_eq!(py["SEGMENT_LEN"], 2 * (py["READ_LEN"] + py["ETH"]) - py["K"]);
    assert_eq!(p::segment_len(150), 300);
    // Linear saturation is eth + 1 on both sides.
    assert_eq!(py["SAT_LINEAR"], py["ETH"] + 1);
    assert_eq!(p::SAT_LINEAR, p::ETH as i32 + 1);
}

#[test]
fn traceback_direction_codes_match() {
    use dart_pim::align::banded_affine::{D_M1, D_M2, D_MATCH, D_SUB};
    let py = python_params();
    assert_eq!(py["D_MATCH"], D_MATCH as i64);
    assert_eq!(py["D_SUB"], D_SUB as i64);
    assert_eq!(py["D_M1"], D_M1 as i64);
    assert_eq!(py["D_M2"], D_M2 as i64);
}

#[test]
fn evaluator_handles_the_forms_params_py_uses() {
    let mut env = HashMap::new();
    env.insert("ETH".to_string(), 6);
    assert_eq!(eval_expr("2 * ETH + 1", &env), Some(13));
    assert_eq!(eval_expr("1 << 20", &env), Some(1 << 20));
    assert_eq!(eval_expr("ETH + 1", &env), Some(7));
    assert_eq!(eval_expr("2 * (150 + ETH) - 12", &env), Some(300));
    assert_eq!(eval_expr("-5 + 2", &env), Some(-3));
    // non-integer constructs are rejected, not mis-evaluated
    assert_eq!(eval_expr("window_len(READ_LEN)", &env), None);
    assert_eq!(eval_expr("(32, 256)", &env), None);
    assert_eq!(eval_expr("\"text\"", &env), None);
}
