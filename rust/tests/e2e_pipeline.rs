//! Integration: the full pipeline on donor-derived reads (SNPs + indels
//! between donor and reference, sequencing errors on top), checking
//! accuracy, metrics coherence, maxReads accuracy degradation, and the
//! simulator bridge. Uses the Rust engine for speed; engine equivalence
//! is covered by engine_parity.rs.

use dart_pim::coordinator::scheduler::run_streaming;
use dart_pim::coordinator::{FilterPolicy, Pipeline, PipelineConfig};
use dart_pim::eval::accuracy::evaluate_accuracy;
use dart_pim::genome::mutate::MutateConfig;
use dart_pim::genome::synth::{ReadSimConfig, SynthConfig};
use dart_pim::genome::ReadRecord;
use dart_pim::index::MinimizerIndex;
use dart_pim::params::{K, READ_LEN, W};
use dart_pim::pim::xbar_sim::CostSource;
use dart_pim::pim::DartPimConfig;
use dart_pim::runtime::RustEngine;
use dart_pim::simulator::report::build_report;
use dart_pim::simulator::TimingMode;

fn workload(n_reads: usize) -> (MinimizerIndex, Vec<ReadRecord>) {
    let genome = SynthConfig { len: 400_000, ..Default::default() }.generate();
    let donor = MutateConfig::default().apply(&genome);
    let idx = MinimizerIndex::build(genome, K, W, READ_LEN);
    let reads =
        ReadSimConfig { n_reads, ..Default::default() }.simulate(&donor.seq, |p| donor.to_ref(p));
    (idx, reads)
}

fn cfg() -> PipelineConfig {
    PipelineConfig {
        dart: DartPimConfig { low_th: 1, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn donor_reads_map_accurately() {
    let (idx, reads) = workload(800);
    let mut p = Pipeline::new(&idx, cfg(), RustEngine);
    let (mappings, metrics) = p.map_reads(&reads).unwrap();
    let rep = evaluate_accuracy(&idx, &reads[..300], &mappings[..300], 5);
    assert!(rep.accuracy_vs_truth() > 0.95, "truth accuracy {}", rep.accuracy_vs_truth());
    assert!(rep.accuracy_vs_oracle() > 0.97, "oracle accuracy {}", rep.accuracy_vs_oracle());
    assert_eq!(metrics.traceback_failures, 0);
    // metrics coherence
    assert_eq!(metrics.n_reads, 800);
    assert!(metrics.filter_passed >= metrics.affine_instances);
    assert!(metrics.affine_instances > 0 || metrics.riscv_affine_instances > 0);
    let candidates: u64 =
        mappings.iter().flatten().map(|m| m.candidates as u64).sum();
    assert!(
        candidates <= metrics.affine_instances + metrics.riscv_affine_instances,
        "candidate outcomes cannot exceed affine instances"
    );
}

#[test]
fn tighter_max_reads_only_loses_accuracy() {
    let (idx, reads) = workload(600);
    let accuracy = |max_reads: usize| {
        let c = PipelineConfig {
            dart: DartPimConfig { max_reads, low_th: 0, ..Default::default() },
            ..Default::default()
        };
        let mut p = Pipeline::new(&idx, c, RustEngine);
        let (mappings, metrics) = p.map_reads(&reads).unwrap();
        let mut near = 0usize;
        for r in &reads {
            if let Some(m) = &mappings[r.id as usize] {
                if (m.pos - r.truth_pos as i64).abs() <= 5 {
                    near += 1;
                }
            }
        }
        (near as f64 / reads.len() as f64, metrics.dropped_pairs)
    };
    let (acc_tight, dropped_tight) = accuracy(2);
    let (acc_loose, dropped_loose) = accuracy(25_000);
    assert_eq!(dropped_loose, 0);
    assert!(dropped_tight > 0, "cap of 2 must drop pairs");
    assert!(acc_tight <= acc_loose + 1e-9, "tight {acc_tight} loose {acc_loose}");
    assert!(acc_loose > 0.95);
}

#[test]
fn filter_policies_agree_on_best_distance() {
    // MinOnly evaluates fewer candidates but the winning distance can
    // never improve; mapped positions of unambiguous reads agree.
    let (idx, reads) = workload(300);
    let run = |policy| {
        let c = PipelineConfig { filter_policy: policy, ..cfg() };
        Pipeline::new(&idx, c, RustEngine).map_reads(&reads).unwrap().0
    };
    let all = run(FilterPolicy::AllPassing);
    let min_only = run(FilterPolicy::MinOnly);
    let mut agree = 0;
    let mut total = 0;
    for (a, m) in all.iter().zip(&min_only) {
        if let (Some(a), Some(m)) = (a, m) {
            total += 1;
            assert!(m.dist >= a.dist, "MinOnly cannot find better alignments");
            if a.pos == m.pos {
                agree += 1;
            }
        }
    }
    assert!(total > 250);
    assert!(agree as f64 / total as f64 > 0.95, "agree {agree}/{total}");
}

#[test]
fn streaming_matches_batch_on_donor_workload() {
    let (idx, reads) = workload(300);
    let (batch, _) = Pipeline::new(&idx, cfg(), RustEngine).map_reads(&reads).unwrap();
    let (streamed, _) =
        run_streaming(&idx, cfg(), || Ok(RustEngine), reads.clone(), 64).unwrap();
    for (a, b) in batch.iter().zip(&streamed) {
        match (a, b) {
            (None, None) => {}
            (Some(a), Some(b)) => assert_eq!((a.pos, a.dist), (b.pos, b.dist)),
            _ => panic!("presence mismatch"),
        }
    }
}

#[test]
fn measured_workload_produces_sane_hardware_report() {
    let (idx, reads) = workload(400);
    let mut p = Pipeline::new(&idx, cfg(), RustEngine);
    let (_, metrics) = p.map_reads(&reads).unwrap();
    let counts = metrics.to_sim_counts();
    for timing in [TimingMode::PaperSerial, TimingMode::Batched8] {
        for cost in [CostSource::PaperTable4, CostSource::Constructive] {
            let r = build_report(&counts, &p.cfg.dart, cost, timing);
            assert!(r.exec_time_s > 0.0 && r.exec_time_s.is_finite());
            assert!(r.energy.total() > 0.0);
            assert!(r.area.total() > 8000.0 && r.area.total() < 8500.0);
            assert!(r.throughput() > 0.0);
        }
    }
}

#[test]
fn reverse_complement_reads_map_when_enabled() {
    let (idx, mut reads) = workload(200);
    // flip half the reads to the reverse strand (their origin stays put)
    for r in reads.iter_mut() {
        if r.id % 2 == 1 {
            r.seq = dart_pim::genome::revcomp(&r.seq);
        }
    }
    // without revcomp handling, flipped reads are effectively unmappable
    let (plain, _) = Pipeline::new(&idx, cfg(), RustEngine).map_reads(&reads).unwrap();
    let flipped_mapped_plain = reads
        .iter()
        .filter(|r| r.id % 2 == 1)
        .filter(|r| {
            plain[r.id as usize]
                .as_ref()
                .is_some_and(|m| (m.pos - r.truth_pos as i64).abs() <= 5)
        })
        .count();

    let rc_cfg = PipelineConfig { handle_revcomp: true, ..cfg() };
    let (mapped, metrics) = Pipeline::new(&idx, rc_cfg, RustEngine).map_reads(&reads).unwrap();
    assert_eq!(metrics.traceback_failures, 0);
    let mut fwd_ok = 0;
    let mut rev_ok = 0;
    for r in &reads {
        if let Some(m) = &mapped[r.id as usize] {
            if (m.pos - r.truth_pos as i64).abs() <= 5 {
                if r.id % 2 == 1 {
                    assert!(m.reverse, "flipped read must report reverse strand");
                    rev_ok += 1;
                } else {
                    assert!(!m.reverse, "forward read must report forward strand");
                    fwd_ok += 1;
                }
            }
        }
    }
    assert!(fwd_ok >= 90, "forward reads: {fwd_ok}/100");
    assert!(rev_ok >= 90, "reverse reads: {rev_ok}/100");
    assert!(
        rev_ok > flipped_mapped_plain,
        "revcomp handling must recover strand-flipped reads ({rev_ok} vs {flipped_mapped_plain})"
    );
}

#[test]
fn revcomp_does_not_change_forward_results() {
    let (idx, reads) = workload(150);
    let (plain, _) = Pipeline::new(&idx, cfg(), RustEngine).map_reads(&reads).unwrap();
    let rc_cfg = PipelineConfig { handle_revcomp: true, ..cfg() };
    let (both, _) = Pipeline::new(&idx, rc_cfg, RustEngine).map_reads(&reads).unwrap();
    let mut same = 0;
    let mut total = 0;
    for (a, b) in plain.iter().zip(&both) {
        if let (Some(a), Some(b)) = (a, b) {
            total += 1;
            if a.pos == b.pos && a.dist == b.dist && !b.reverse {
                same += 1;
            }
        }
    }
    // forward reads keep their forward mappings (a rare palindromic
    // repeat may legitimately tie; allow a sliver)
    assert!(total > 140);
    assert!(same as f64 / total as f64 > 0.98, "{same}/{total}");
}
