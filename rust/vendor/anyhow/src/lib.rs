//! Hermetic stand-in for the [`anyhow`](https://docs.rs/anyhow) crate.
//!
//! The build environments this repository targets have no network access
//! to crates.io (see the Cargo.toml note at the workspace root), so the
//! API subset DART-PIM uses is vendored here as a path dependency:
//!
//! * [`Error`] — an error with a context chain (no backtraces),
//! * [`Result`] — `Result<T, Error>` alias,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//!
//! Semantics intentionally mirror anyhow 1.x: `{:#}` formatting prints
//! the full cause chain separated by `": "`, `Debug` prints the message
//! plus a `Caused by:` list, and any `std::error::Error + Send + Sync`
//! converts into [`Error`] keeping its source chain. Swapping back to
//! the real crate is a one-line Cargo.toml change; no call sites would
//! need to move.

use std::error::Error as StdError;
use std::fmt;

/// An error with a chain of context messages (most recent first).
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)) }
    }

    /// Iterate over the message chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The outermost message (the deepest cause is at the chain's end).
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(s) = &cur.source {
            cur = s;
        }
        cur
    }
}

/// Iterator over an error's context chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Flatten the std source chain into nested context frames so the
        // alternate / Debug renderings show the full story.
        let mut messages = Vec::new();
        let mut src: Option<&(dyn StdError + 'static)> = e.source();
        while let Some(s) = src {
            messages.push(s.to_string());
            src = s.source();
        }
        let mut inner = None;
        for msg in messages.into_iter().rev() {
            inner = Some(Box::new(Error { msg, source: inner }));
        }
        Error { msg: e.to_string(), source: inner }
    }
}

/// `Result<T, anyhow::Error>` (second parameter overridable, as in anyhow).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (inline captures work).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)+));
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                concat!("Condition failed: `", stringify!($cond), "`")
            ));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_render_like_anyhow() {
        let e: Error = Err::<(), _>(io_err()).context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: file missing");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:") && dbg.contains("file missing"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let e = None::<u32>.context("missing key").unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        let e = None::<u32>.with_context(|| format!("k={}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "k=7");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
        let e = anyhow!("plain {}", "args");
        assert_eq!(e.to_string(), "plain args");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(f().unwrap(), 12);
        fn g() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(g().is_err());
    }

    #[test]
    fn chain_and_root_cause() {
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let msgs: Vec<String> = e.chain().map(|x| x.to_string()).collect();
        assert_eq!(msgs, vec!["outer".to_string(), "file missing".to_string()]);
        assert_eq!(e.root_cause().to_string(), "file missing");
    }
}
