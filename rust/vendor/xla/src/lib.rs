//! Type-checking stub of the `xla` crate (the PJRT bindings the real
//! deployment uses, cf. LaurentMazare's `xla-rs`).
//!
//! The real crate links a C++ XLA distribution through a build script,
//! which no hermetic build environment here provides. This stub exposes
//! the exact API surface `dart_pim::runtime::xla_engine` consumes so that
//! `cargo check --features pjrt` type-checks the engine end to end, while
//! every runtime entry point returns [`Error`] — `XlaEngine::load` then
//! fails cleanly and callers fall back to the pure-Rust `WfEngine`
//! (which is held to bit-identical numerics by `tests/engine_parity.rs`
//! when real artifacts and a real PJRT build are present).
//!
//! Swapping in the real bindings is a Cargo.toml change only: replace the
//! `xla` path dependency at the workspace root with the registry/git
//! crate; no engine code changes.

use std::fmt;

/// Error type mirroring `xla::Error` (a message-carrying enum upstream).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` alias mirroring the real crate.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} is unavailable — this build vendors a PJRT stub (no XLA \
         distribution in the build environment); use the pure-Rust engine"
    )))
}

/// Element types of XLA literals (subset).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    S32,
    F32,
    U8,
}

/// An XLA literal (host tensor). Stub: never instantiable with data.
#[derive(Debug)]
pub struct Literal {}

impl Literal {
    /// Mirrors `Literal::create_from_shape_and_untyped_data`.
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Self> {
        unavailable("Literal creation")
    }

    /// Mirrors `Literal::to_vec`.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal readback")
    }

    /// Mirrors `Literal::to_tuple` (decompose a tuple literal).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal tuple decomposition")
    }
}

/// A parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto {}

impl HloModuleProto {
    /// Mirrors `HloModuleProto::from_text_file` (HLO text parsing).
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HLO text parsing")
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug)]
pub struct XlaComputation {}

impl XlaComputation {
    /// Mirrors `XlaComputation::from_proto` (infallible upstream).
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation {}
    }
}

/// A device buffer holding one execution output.
#[derive(Debug)]
pub struct PjRtBuffer {}

impl PjRtBuffer {
    /// Mirrors `PjRtBuffer::to_literal_sync`.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("buffer readback")
    }
}

/// A compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Mirrors `PjRtLoadedExecutable::execute`.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execution")
    }
}

/// The PJRT client.
#[derive(Debug)]
pub struct PjRtClient {}

impl PjRtClient {
    /// Mirrors `PjRtClient::cpu`. Always fails in the stub, so engine
    /// construction errors out before any compute is attempted.
    pub fn cpu() -> Result<Self> {
        unavailable("PJRT CPU client")
    }

    /// Mirrors `PjRtClient::platform_name`.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Mirrors `PjRtClient::compile`.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compilation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_fails_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[0; 4])
            .is_err());
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("stub"), "{err}");
    }
}
