# Convenience targets. The Rust workspace itself needs only cargo (no
# network, no XLA) — see README.md. `make analyze` needs only Python.

PYTHON ?= python3

.PHONY: analyze analyze-fast analyze-bench build test fmt clippy artifacts python-test

# Toolchain-free static analysis (call-graph determinism taint,
# protocol lints, unsafe audit, MSRV, docs parity) — see tools/analyze/
# and ARCHITECTURE.md.
analyze:
	$(PYTHON) -m tools.analyze

# Pre-commit loop: whole-tree analysis, findings reported only for
# git-changed files (call resolution stays global, so a hazard you just
# made reachable is still caught in the file you touched).
analyze-fast:
	$(PYTHON) -m tools.analyze --changed

# Full run + wall-time budget, recorded into BENCH_analyze.json (CI
# fails the analyze job if the pass ever crosses 10 s on the real tree).
analyze-bench:
	$(PYTHON) -m tools.analyze --bench BENCH_analyze.json

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --all --check

# Lint levels come from [workspace.lints] in Cargo.toml.
clippy:
	cargo clippy --workspace --all-targets

# Lower the L2 JAX graphs to HLO text artifacts for the `pjrt` engine
# (requires jax; consumed from rust/artifacts by runtime::artifacts).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../rust/artifacts

python-test:
	$(PYTHON) -m pytest python/tests -q
