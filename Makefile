# Convenience targets. The Rust workspace itself needs only cargo (no
# network, no XLA) — see README.md.

PYTHON ?= python3

.PHONY: build test fmt clippy artifacts python-test

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --all --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

# Lower the L2 JAX graphs to HLO text artifacts for the `pjrt` engine
# (requires jax; consumed from rust/artifacts by runtime::artifacts).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../rust/artifacts

python-test:
	$(PYTHON) -m pytest python/tests -q
