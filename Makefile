# Convenience targets. The Rust workspace itself needs only cargo (no
# network, no XLA) — see README.md. `make analyze` needs only Python.

PYTHON ?= python3

.PHONY: analyze build test fmt clippy artifacts python-test

# Toolchain-free static analysis (determinism invariants, unsafe audit,
# MSRV, docs parity) — see tools/analyze/ and ARCHITECTURE.md.
analyze:
	$(PYTHON) -m tools.analyze

build:
	cargo build --release

test:
	cargo test -q

fmt:
	cargo fmt --all --check

# Lint levels come from [workspace.lints] in Cargo.toml.
clippy:
	cargo clippy --workspace --all-targets

# Lower the L2 JAX graphs to HLO text artifacts for the `pjrt` engine
# (requires jax; consumed from rust/artifacts by runtime::artifacts).
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../rust/artifacts

python-test:
	$(PYTHON) -m pytest python/tests -q
