import os
import sys

# Make the `compile` package importable regardless of pytest rootdir.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
