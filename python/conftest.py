"""Pytest configuration for the Python (L1/L2) layer.

Two jobs:

1. Make the ``compile`` package importable regardless of pytest rootdir.
2. Keep CI hermetic: the kernel tests need ``jax`` (Pallas) and the
   property suites need ``hypothesis``. Runners without those must SKIP
   the affected modules cleanly rather than die at collection time
   (see .github/workflows/ci.yml, job ``python``).
"""

import importlib.util
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def _missing(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is None
    except (ImportError, ValueError):
        return True


HAVE_JAX = not _missing("jax")
HAVE_HYPOTHESIS = not _missing("hypothesis")

collect_ignore = []

if not HAVE_HYPOTHESIS:
    # Property suites are hypothesis-driven; test_model_aot imports
    # helpers from test_linear_kernel, which imports hypothesis too.
    collect_ignore += [
        "tests/test_linear_kernel.py",
        "tests/test_affine_kernel.py",
        "tests/test_ref_properties.py",
        "tests/test_model_aot.py",
    ]

if not HAVE_JAX:
    # Kernel/graph/AOT tests execute Pallas; the pure-numpy oracle
    # properties (test_ref_properties) still run when hypothesis exists.
    for mod in (
        "tests/test_linear_kernel.py",
        "tests/test_affine_kernel.py",
        "tests/test_model_aot.py",
        "tests/test_kernels_smoke.py",
    ):
        if mod not in collect_ignore:
            collect_ignore.append(mod)

if collect_ignore:
    sys.stderr.write(
        "conftest: skipping {} module(s) (jax available: {}, hypothesis "
        "available: {})\n".format(len(collect_ignore), HAVE_JAX, HAVE_HYPOTHESIS)
    )
