"""Pure-numpy serial oracles for the banded Wagner-Fischer kernels.

These implement the EXACT cell-by-cell semantics the Pallas kernels must
match (same band anchoring, same pad values, same end-of-row saturation,
same direction tie-breaking). They are deliberately written as naive
serial loops so that any vectorization bug in the kernels shows up as a
mismatch rather than being replicated.

Conventions (see params.py and DESIGN.md §3):
  * read  R[0..n)      — the query string, 2-bit base codes (0..3).
  * win   G[0..n+2eth) — the reference window; the read is expected to
    start near window offset eth (the minimizer-anchored diagonal).
  * band coordinate j in [0, 2*eth]: DP cell (i, c) with c = i + j.
  * buffer value wfd[j] after row i equals D[i][i+j].
  * init D[0][j] = |j - eth| (anchored start), M1 = M2 = saturated.
  * values are saturated at end-of-row (linear: eth+1, affine: 31).
"""

from __future__ import annotations

import numpy as np

from ..params import (
    BAND,
    BIG,
    D_M1,
    D_M2,
    D_MATCH,
    D_SUB,
    ETH,
    SAT_AFFINE,
    SAT_LINEAR,
    W_EX,
    W_OP,
    W_SUB,
    window_len,
)


def _check_shapes(read: np.ndarray, win: np.ndarray) -> int:
    assert read.ndim == 1 and win.ndim == 1, "oracles are single-instance"
    n = read.shape[0]
    assert win.shape[0] == window_len(n), (
        f"window must be read_len + 2*eth = {window_len(n)}, got {win.shape[0]}"
    )
    return n


# ---------------------------------------------------------------------------
# Linear WF (pre-alignment filter)
# ---------------------------------------------------------------------------


def linear_wf_band(read: np.ndarray, win: np.ndarray, clamp: bool = True) -> np.ndarray:
    """Banded linear WF; returns the final band row (13 int values).

    ``clamp=False`` disables the 3-bit saturation (used by the property
    test that saturation never changes results that stay <= eth).
    """
    n = _check_shapes(read, win)
    sat = SAT_LINEAR if clamp else BIG
    wfd = np.array([abs(j - ETH) for j in range(BAND)], dtype=np.int64)
    for i in range(n):
        mm = np.array([1 if read[i] != win[i + j] else 0 for j in range(BAND)])
        raw = np.empty(BAND, dtype=np.int64)
        left = BIG
        for j in range(BAND):
            top = (wfd[j + 1] if j < BAND - 1 else sat) + 1
            diag = wfd[j] + mm[j] * W_SUB
            raw[j] = min(diag, top, left + 1)
            left = raw[j]
        wfd = np.minimum(raw, sat)
    return wfd


def linear_wf_full(read: np.ndarray, win: np.ndarray) -> np.ndarray:
    """Structurally independent validator: full (n+1)x(m+1) DP matrix with
    explicit band masking and identical pad/saturation semantics. Returns
    the final band row D[n][n..n+2eth] so it is directly comparable with
    :func:`linear_wf_band`.
    """
    n = _check_shapes(read, win)
    m = win.shape[0]
    D = np.full((n + 1, m + 1), BIG, dtype=np.int64)

    def in_band(i: int, c: int) -> bool:
        return i <= c <= i + 2 * ETH

    for c in range(0, 2 * ETH + 1):
        D[0][c] = abs(c - ETH)
    for i in range(1, n + 1):
        for c in range(i, i + 2 * ETH + 1):
            # Out-of-band neighbours read as the saturation value (the
            # paper's cells physically hold eth+1 there).
            diag = D[i - 1][c - 1] if in_band(i - 1, c - 1) else SAT_LINEAR
            top = D[i - 1][c] if in_band(i - 1, c) else SAT_LINEAR
            left = D[i][c - 1] if in_band(i, c - 1) else SAT_LINEAR
            mm = 0 if read[i - 1] == win[c - 1] else W_SUB
            D[i][c] = min(diag + mm, top + 1, left + 1)
        # end-of-row saturation, as in the rolling-buffer version
        for c in range(i, i + 2 * ETH + 1):
            D[i][c] = min(D[i][c], SAT_LINEAR)
    return D[n][n : n + BAND].copy()


# ---------------------------------------------------------------------------
# Affine WF (read alignment) with traceback directions
# ---------------------------------------------------------------------------


def affine_wf_band(read: np.ndarray, win: np.ndarray):
    """Banded affine-gap WF (Eqs. 3-5 of the paper, all costs 1).

    Returns ``(band, dirs)`` where ``band`` is the final D row (13 values,
    saturated at 31) and ``dirs`` is an (n, 13) int array of packed 4-bit
    direction codes (see params.py) for traceback.
    """
    n = _check_shapes(read, win)
    sat = SAT_AFFINE
    d = np.array([abs(j - ETH) for j in range(BAND)], dtype=np.int64)
    m1 = np.full(BAND, sat, dtype=np.int64)
    m2 = np.full(BAND, sat, dtype=np.int64)
    dirs = np.zeros((n, BAND), dtype=np.int64)
    for i in range(n):
        match = np.array([read[i] == win[i + j] for j in range(BAND)])
        m1new = np.empty(BAND, dtype=np.int64)
        m1dir = np.empty(BAND, dtype=np.int64)
        for j in range(BAND):
            ext = (m1[j + 1] if j < BAND - 1 else sat) + W_EX
            opn = (d[j + 1] if j < BAND - 1 else sat) + W_OP + W_EX
            m1new[j] = min(ext, opn)
            m1dir[j] = 1 if ext < opn else 0  # prefer "open" on ties
        a = np.minimum(m1new, d + W_SUB)
        m2raw = np.empty(BAND, dtype=np.int64)
        m2dir = np.empty(BAND, dtype=np.int64)
        prev = BIG
        for j in range(BAND):
            if j == 0:
                cbase = BIG
            else:
                cbase = W_OP + W_EX + (d[j - 1] if match[j - 1] else a[j - 1])
            m2raw[j] = min(cbase, prev + W_EX)
            m2dir[j] = 1 if m2raw[j] < cbase else 0  # prefer "open" on ties
            prev = m2raw[j]
        dnew = np.empty(BAND, dtype=np.int64)
        ddir = np.empty(BAND, dtype=np.int64)
        for j in range(BAND):
            if match[j]:
                dnew[j] = d[j]
                ddir[j] = D_MATCH
            else:
                vsub = d[j] + W_SUB
                dnew[j] = min(vsub, m1new[j], m2raw[j])
                if vsub <= m1new[j] and vsub <= m2raw[j]:
                    ddir[j] = D_SUB
                elif m1new[j] <= m2raw[j]:
                    ddir[j] = D_M1
                else:
                    ddir[j] = D_M2
        dirs[i] = ddir | (m1dir << 2) | (m2dir << 3)
        d = np.minimum(dnew, sat)
        m1 = np.minimum(m1new, sat)
        m2 = np.minimum(m2raw, sat)
    return d, dirs


def traceback(dirs: np.ndarray, j_start: int):
    """Reconstruct the edit script from packed directions.

    Starts at DP cell (n, n + j_start) in matrix D and walks back to row 0.
    Returns ``(ops, j_end)`` where ``ops`` is the edit string from the
    START of the alignment (characters '=', 'X', 'I', 'D'; 'I' consumes a
    read base with a gap in the reference, 'D' the converse) and ``j_end``
    is the band coordinate at row 0 (window start offset = j_end, with a
    leading anchoring cost of |j_end - eth|).

    Only meaningful for unsaturated results (distance < 31); raises
    ``ValueError`` if the recorded path escapes the band, which cannot
    happen for a valid unsaturated path.
    """
    n = dirs.shape[0]
    i, j = n, int(j_start)
    mat = "D"
    ops: list[str] = []
    steps = 0
    limit = 4 * (n + BAND) + 16
    while i > 0:
        steps += 1
        if steps > limit:
            raise ValueError("traceback did not terminate (corrupt directions)")
        if not (0 <= j < BAND):
            raise ValueError(f"traceback escaped the band at i={i}, j={j}")
        bits = int(dirs[i - 1][j])
        if mat == "D":
            dd = bits & 3
            if dd == D_MATCH:
                ops.append("=")
                i -= 1
            elif dd == D_SUB:
                ops.append("X")
                i -= 1
            elif dd == D_M1:
                mat = "M1"
            else:
                mat = "M2"
        elif mat == "M1":
            ops.append("I")
            ext = (bits >> 2) & 1
            i -= 1
            j += 1
            if not ext:
                mat = "D"
        else:  # M2
            ops.append("D")
            ext = (bits >> 3) & 1
            j -= 1
            if not ext:
                mat = "D"
    if mat != "D":
        raise ValueError("traceback ended inside a gap matrix (saturated path?)")
    ops.reverse()
    return "".join(ops), j


def script_cost(ops: str, j_end: int) -> int:
    """Affine cost of an edit script + the |j_end - eth| anchoring charge.

    Must equal the reported band distance for unsaturated alignments —
    this is the core traceback-correctness invariant.
    """
    cost = abs(j_end - ETH)
    i = 0
    while i < len(ops):
        c = ops[i]
        if c == "=":
            i += 1
        elif c == "X":
            cost += W_SUB
            i += 1
        elif c in ("I", "D"):
            run = 0
            while i < len(ops) and ops[i] == c:
                run += 1
                i += 1
            cost += W_OP + run * W_EX
        else:
            raise ValueError(f"bad op {c!r}")
    return cost


def apply_script(ops: str, j_end: int, win: np.ndarray, read_len: int) -> np.ndarray:
    """Apply the edit script to the window to re-derive the read.

    '=' copies a window base, 'X' consumes a window base but emits an
    (unknown) substituted base, 'I' emits a read base not present in the
    window, 'D' skips a window base. Returns an int array of length
    ``read_len`` where substituted/inserted positions are -1. Used by
    tests to check structural consistency of the alignment.
    """
    out: list[int] = []
    c = int(j_end)  # window cursor at alignment start
    for op in ops:
        if op == "=":
            out.append(int(win[c]))
            c += 1
        elif op == "X":
            out.append(-1)
            c += 1
        elif op == "I":
            out.append(-1)
        elif op == "D":
            c += 1
    assert len(out) == read_len, f"script consumes {len(out)} read bases, want {read_len}"
    return np.array(out, dtype=np.int64)
