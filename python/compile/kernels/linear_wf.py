"""L1 Pallas kernel: banded linear Wagner-Fischer (pre-alignment filter).

TPU mapping of the paper's in-crossbar-row computation (DESIGN.md
§Hardware-Adaptation):

  * one crossbar row per WF instance      ->  batch dim in sublanes
  * 13-cell WF distance buffer in the row ->  band dim in lanes
  * bit-serial MAGIC NOR op chains        ->  int32 VPU min/add/select
  * the serial left-neighbour chain
    ``new[j] = min(tmp[j], new[j-1] + 1)``->  prefix-min-with-ramp scan,
    computed with log2-doubling shifts (4 steps for a 13-lane band)

The scan identity: ``new[j] = min_{k<=j}(tmp[k] + (j-k))`` — exact because
the ramp is linear in the shift distance (requires W_EX-style unit step;
asserted below).

Kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode lowers to plain HLO that the Rust
runtime compiles natively.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import BAND, BIG, SAT_LINEAR, W_SUB, window_len

assert W_SUB == 1, "scan ramp assumes unit edit costs (paper Table III)"

# Log2-doubling shift schedule covering offsets 0..12 (band width 13).
_SCAN_SHIFTS = (1, 2, 4, 8)


def _shift_left(x: jnp.ndarray, fill: int) -> jnp.ndarray:
    """x[:, j] -> x[:, j+1], padding the last lane with ``fill``."""
    pad = jnp.full((x.shape[0], 1), fill, dtype=x.dtype)
    return jnp.concatenate([x[:, 1:], pad], axis=1)


def _shift_right(x: jnp.ndarray, s: int, fill: int) -> jnp.ndarray:
    """x[:, j] -> x[:, j-s], padding the first ``s`` lanes with ``fill``."""
    pad = jnp.full((x.shape[0], s), fill, dtype=x.dtype)
    return jnp.concatenate([pad, x[:, : x.shape[1] - s]], axis=1)


def prefix_min_ramp(tmp: jnp.ndarray) -> jnp.ndarray:
    """new[j] = min_{k<=j}(tmp[k] + (j-k)), vectorized over lanes."""
    out = tmp
    for s in _SCAN_SHIFTS:
        out = jnp.minimum(out, _shift_right(out, s, BIG) + s)
    return out


def linear_row_update(read_i: jnp.ndarray, g: jnp.ndarray, wfd: jnp.ndarray) -> jnp.ndarray:
    """One WF matrix row: (B,1) read chars x (B,BAND) window slice.

    Exactly mirrors ref.linear_wf_band's inner loop (pad = saturation
    value, end-of-row clamp).
    """
    mm = (g != read_i).astype(jnp.int32)
    diag = wfd + mm
    top = _shift_left(wfd, SAT_LINEAR) + 1
    tmp = jnp.minimum(diag, top)
    new = prefix_min_ramp(tmp)
    return jnp.minimum(new, SAT_LINEAR)


def _linear_wf_kernel(read_ref, win_ref, out_ref):
    """Pallas kernel body: a block of (Bt) WF instances.

    read_ref: (Bt, n) int32, win_ref: (Bt, n + 2*eth) int32,
    out_ref: (Bt, BAND) int32 — the final band row.
    """
    read = read_ref[...]
    win = win_ref[...]
    bt, n = read.shape

    init = jnp.broadcast_to(
        jnp.abs(jnp.arange(BAND, dtype=jnp.int32) - (BAND // 2)), (bt, BAND)
    )

    def row(i, wfd):
        g = jax.lax.dynamic_slice(win, (0, i), (bt, BAND))
        r = jax.lax.dynamic_slice(read, (0, i), (bt, 1))
        return linear_row_update(r, g, wfd)

    out_ref[...] = jax.lax.fori_loop(0, n, row, init)


@functools.partial(jax.jit, static_argnames=("block",))
def linear_wf(read: jnp.ndarray, win: jnp.ndarray, block: int | None = None) -> jnp.ndarray:
    """Banded linear WF distance band for a batch of (read, window) pairs.

    Args:
      read: (B, n) int32 base codes.
      win:  (B, n + 2*eth) int32 base codes.
      block: batch block size for the Pallas grid (defaults to min(B, 32),
        mirroring the 32-row linear WF buffer of one crossbar).

    Returns:
      (B, BAND) int32 — final band row, saturated at eth+1.
    """
    b, n = read.shape
    assert win.shape == (b, window_len(n)), (read.shape, win.shape)
    bt = block or min(b, 32)
    assert b % bt == 0, f"batch {b} not divisible by block {bt}"
    return pl.pallas_call(
        _linear_wf_kernel,
        grid=(b // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((bt, window_len(n)), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, BAND), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, BAND), jnp.int32),
        interpret=True,  # CPU path; real-TPU lowering emits Mosaic custom-calls
    )(read.astype(jnp.int32), win.astype(jnp.int32))
