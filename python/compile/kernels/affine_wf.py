"""L1 Pallas kernel: banded affine-gap Wagner-Fischer with traceback.

Implements the paper's Eqs. (3)-(5) inside the same band as the linear
filter (half-width eth = 6), with 5-bit value saturation at 31 and packed
4-bit traceback directions per cell (paper §IV-B / Fig. 6 affine buffer).

The in-row D <-> M2 mutual dependency (M2 opens from the *current* row's
D, D takes the minimum over the current row's M2) folds into a single
prefix-min-with-ramp scan:

    newM2[j] = min(cbase[j], newM2[j-1] + w_ex)
    cbase[j] = w_op + w_ex + (match[j-1] ? oldD[j-1] : A[j-1])
    A[j]     = min(newM1[j], oldD[j] + w_sub)

because at a mismatch cell ``newD[j-1] = min(A[j-1], newM2[j-1])`` and the
``newM2[j-1] + w_op + w_ex`` branch is dominated by the chain term
``newM2[j-1] + w_ex``. Exactness vs the serial recurrence is property-
tested against ref.affine_wf_band.

Direction encoding (params.py): bits[1:0] = D origin (match/sub/M1/M2,
tie-break sub < M1 < M2), bit[2] = M1 extend, bit[3] = M2 extend (opens
preferred on ties).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..params import (
    BAND,
    BIG,
    D_M1,
    D_M2,
    D_SUB,
    SAT_AFFINE,
    W_EX,
    W_OP,
    W_SUB,
    window_len,
)
from .linear_wf import _shift_left, _shift_right, prefix_min_ramp

assert W_EX == 1 and W_OP == 1 and W_SUB == 1, "scan ramp assumes unit costs"


def affine_row_update(read_i, g, d, m1, m2):
    """One affine WF row. Returns (d', m1', m2', dirs_row), all (B, BAND)."""
    match = g == read_i

    # M1 (vertical: consume a read base, gap in the reference).
    m1ext = _shift_left(m1, SAT_AFFINE) + W_EX
    m1opn = _shift_left(d, SAT_AFFINE) + W_OP + W_EX
    m1new = jnp.minimum(m1ext, m1opn)
    m1dir = (m1ext < m1opn).astype(jnp.int32)

    # Candidate D value ignoring the current row's M2.
    a = jnp.minimum(m1new, d + W_SUB)

    # M2 (horizontal) via the folded prefix scan.
    base = jnp.where(match, d, a) + (W_OP + W_EX)
    cbase = _shift_right(base, 1, BIG)
    m2new = prefix_min_ramp(cbase)
    m2dir = (m2new < cbase).astype(jnp.int32)

    # D with deterministic origin priority: match, then sub < M1 < M2.
    vsub = d + W_SUB
    dnew = jnp.where(match, d, jnp.minimum(vsub, jnp.minimum(m1new, m2new)))
    ddir = jnp.where(
        match,
        0,
        jnp.where(
            (vsub <= m1new) & (vsub <= m2new),
            D_SUB,
            jnp.where(m1new <= m2new, D_M1, D_M2),
        ),
    ).astype(jnp.int32)

    dirs_row = ddir | (m1dir << 2) | (m2dir << 3)
    return (
        jnp.minimum(dnew, SAT_AFFINE),
        jnp.minimum(m1new, SAT_AFFINE),
        jnp.minimum(m2new, SAT_AFFINE),
        dirs_row,
    )


def _affine_wf_kernel(read_ref, win_ref, band_ref, dirs_ref):
    """Pallas kernel body: (Bt) affine WF instances with traceback.

    band_ref: (Bt, BAND) final D row; dirs_ref: (Bt, n, BAND) packed dirs.
    """
    read = read_ref[...]
    win = win_ref[...]
    bt, n = read.shape

    d0 = jnp.broadcast_to(
        jnp.abs(jnp.arange(BAND, dtype=jnp.int32) - (BAND // 2)), (bt, BAND)
    )
    m0 = jnp.full((bt, BAND), SAT_AFFINE, dtype=jnp.int32)
    dirs0 = jnp.zeros((bt, n, BAND), dtype=jnp.int32)

    def row(i, carry):
        d, m1, m2, dirs = carry
        g = jax.lax.dynamic_slice(win, (0, i), (bt, BAND))
        r = jax.lax.dynamic_slice(read, (0, i), (bt, 1))
        d, m1, m2, dr = affine_row_update(r, g, d, m1, m2)
        dirs = jax.lax.dynamic_update_slice(dirs, dr[:, None, :], (0, i, 0))
        return d, m1, m2, dirs

    d, _, _, dirs = jax.lax.fori_loop(0, n, row, (d0, m0, m0, dirs0))
    band_ref[...] = d
    dirs_ref[...] = dirs


@functools.partial(jax.jit, static_argnames=("block",))
def affine_wf(read: jnp.ndarray, win: jnp.ndarray, block: int | None = None):
    """Banded affine WF for a batch of (read, window) pairs.

    Args:
      read: (B, n) int32 base codes.
      win:  (B, n + 2*eth) int32 base codes.
      block: batch block size (defaults to min(B, 8), mirroring the 8
        concurrent affine instances per crossbar).

    Returns:
      (band, dirs): (B, BAND) int32 final D row saturated at 31, and
      (B, n, BAND) int32 packed 4-bit traceback directions.
    """
    b, n = read.shape
    assert win.shape == (b, window_len(n)), (read.shape, win.shape)
    bt = block or min(b, 8)
    assert b % bt == 0, f"batch {b} not divisible by block {bt}"
    return pl.pallas_call(
        _affine_wf_kernel,
        grid=(b // bt,),
        in_specs=[
            pl.BlockSpec((bt, n), lambda i: (i, 0)),
            pl.BlockSpec((bt, window_len(n)), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bt, BAND), lambda i: (i, 0)),
            pl.BlockSpec((bt, n, BAND), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, BAND), jnp.int32),
            jax.ShapeDtypeStruct((b, n, BAND), jnp.int32),
        ],
        interpret=True,  # CPU path; real-TPU lowering emits Mosaic custom-calls
    )(read.astype(jnp.int32), win.astype(jnp.int32))
