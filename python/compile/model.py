"""L2: JAX compute graphs composed from the L1 Pallas kernels.

Two graphs, mirroring the two in-crossbar stages of DART-PIM:

  * ``linear_filter``  — pre-alignment filtering. Kernel band + a fused
    best-of-band epilogue (min distance + its band coordinate), i.e. the
    paper's step (4) "extract the minimal value from the linear WF buffer
    rows" runs inside the same lowered module.
  * ``affine_align``   — read alignment. Kernel band + traceback
    directions + the same best-of-band epilogue.

Tie-breaking for the argmin is (distance, |j - eth|, j) — encoded into a
single integer key so the whole selection is one vectorized argmin. This
matches the Rust-side reference engine bit-for-bit.

Both graphs are pure functions of int32 tensors and are AOT-lowered once
by aot.py; Python never runs on the request path.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels.affine_wf import affine_wf
from .kernels.linear_wf import linear_wf
from .params import BAND, ETH


def best_of_band(band: jnp.ndarray):
    """Fused epilogue: (B, BAND) -> (best_dist (B,), best_j (B,)).

    Deterministic tie-break (dist, |j-eth|, j), encoded as
    key = dist*1024 + |j-eth|*16 + j (dist <= 31, so no field overlap).
    """
    j = jnp.arange(BAND, dtype=jnp.int32)
    key = band * 1024 + jnp.abs(j - ETH) * 16 + j
    bj = jnp.argmin(key, axis=1).astype(jnp.int32)
    best = jnp.take_along_axis(band, bj[:, None], axis=1)[:, 0]
    return best, bj


def linear_filter(read: jnp.ndarray, win: jnp.ndarray):
    """Pre-alignment filter graph.

    Returns (band (B,13), best_dist (B,), best_j (B,)) — all int32.

    Lowered with the full batch as one Pallas block: on the CPU PJRT
    backend a single wide block beats 32-row grid steps by ~6 %
    (EXPERIMENTS.md §Perf); the 32-row crossbar geometry lives in the
    cost model, not the kernel schedule. The affine graph keeps 8-row
    blocks (wider blocks regressed due to the (B, n, 13) traceback
    carry).
    """
    band = linear_wf(read, win, block=read.shape[0])
    best, bj = best_of_band(band)
    return band, best, bj


def affine_align(read: jnp.ndarray, win: jnp.ndarray):
    """Read-alignment graph.

    Returns (band (B,13), best_dist (B,), best_j (B,), dirs (B,n,13)).
    """
    band, dirs = affine_wf(read, win)
    best, bj = best_of_band(band)
    return band, best, bj, dirs
