"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()`` / serialized protos) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version behind the Rust
``xla`` crate) rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts (one compiled executable per model variant):
  linear_wf_b32.hlo.txt   — one crossbar's 32-row linear WF buffer
  linear_wf_b256.hlo.txt  — bulk batch for the coordinator's batcher
  affine_wf_b8.hlo.txt    — one crossbar's 8 concurrent affine instances
  affine_wf_b64.hlo.txt   — bulk batch
  manifest.json           — shapes/dtypes/params consumed by the Rust
                            runtime at startup (runtime::artifacts)

Usage: cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import params
from .model import affine_align, linear_filter


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side always unwraps a tuple, regardless of arity)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(batch: int, length: int):
    return jax.ShapeDtypeStruct((batch, length), "int32")


def lower_variant(fn, batch: int, read_len: int):
    read = _spec(batch, read_len)
    win = _spec(batch, params.window_len(read_len))
    return jax.jit(fn).lower(read, win)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--read-len", type=int, default=params.READ_LEN)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    n = args.read_len
    manifest = {
        "read_len": n,
        "win_len": params.window_len(n),
        "band": params.BAND,
        "eth": params.ETH,
        "sat_linear": params.SAT_LINEAR,
        "sat_affine": params.SAT_AFFINE,
        "artifacts": [],
    }

    variants = [
        ("linear_wf", linear_filter, b, ["band", "best", "best_j"])
        for b in params.LINEAR_BATCHES
    ] + [
        ("affine_wf", affine_align, b, ["band", "best", "best_j", "dirs"])
        for b in params.AFFINE_BATCHES
    ]

    for kind, fn, batch, outputs in variants:
        name = f"{kind}_b{batch}"
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        text = to_hlo_text(lower_variant(fn, batch, n))
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "kind": kind,
                "batch": batch,
                "file": f"{name}.hlo.txt",
                "outputs": outputs,
            }
        )
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
