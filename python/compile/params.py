"""Shared DART-PIM algorithm parameters (paper Table III).

These constants define the *numeric semantics* of the banded Wagner-Fischer
kernels. The PIM bit-width story (3-bit linear cells, 5-bit affine cells)
lives in the Rust cost model; here the values only matter through the
saturation thresholds.
"""

# Read length (bases). Kernels are length-generic; this is the default used
# for the AOT artifacts (paper: Illumina 150 bp short reads).
READ_LEN = 150

# Minimizer geometry (paper Table III): k-mer length and window size (in
# k-mers). Only the Rust indexing layer consumes these, but they are
# declared here so this file is the single source of truth for every
# Table III value; rust/tests/params_parity.rs cross-checks them against
# dart_pim::params.
K = 12
W = 30

# Band half-width. The paper computes 2*eth+1 = 13 unsaturated cells around
# the minimizer-anchored diagonal for BOTH the linear filter and the affine
# aligner (the affine "eth = 31" is the 5-bit value-saturation threshold,
# not the band width: 8 crossbar rows of traceback only fit 4b x 13 x 150).
ETH = 6
BAND = 2 * ETH + 1  # 13

# Reference window length fed to a banded WF instance: the read may align
# starting anywhere in the first BAND positions of the window.
def window_len(read_len: int) -> int:
    return read_len + 2 * ETH


WIN_LEN = window_len(READ_LEN)  # 162

# Indexed reference segment length per minimizer occurrence (paper §V-B):
# the union of banded WF windows over all in-read minimizer offsets.
# Kept as plain arithmetic so the Rust parity test can evaluate it.
SEGMENT_LEN = 2 * (READ_LEN + ETH) - K  # 300 for 150 bp reads

# Saturation values. Linear WF cells are 3-bit (saturate at eth+1 = 7);
# affine WF cells are 5-bit (saturate at 31). Any saturated value means
# "too different" and is never a valid mapping distance.
SAT_LINEAR = ETH + 1  # 7
SAT_AFFINE = 31

# Edit costs (paper Table III: w_sub = w_ins = w_del = w_op = w_ex = 1).
W_SUB = 1
W_INS = 1
W_DEL = 1
W_OP = 1
W_EX = 1

# "Infinity" for the in-row prefix-min scans; large enough to never win a
# min against any reachable value, small enough to never overflow int32
# after the +ramp additions.
BIG = 1 << 20

# Direction encoding for the affine traceback (4 bits per banded cell):
#   bits [1:0] D-origin:  0 = diagonal match, 1 = substitution,
#                         2 = came from M1 (gap in reference / insertion),
#                         3 = came from M2 (gap in read / deletion)
#   bit  [2]   M1-origin: 1 = extend, 0 = open
#   bit  [3]   M2-origin: 1 = extend, 0 = open
D_MATCH = 0
D_SUB = 1
D_M1 = 2
D_M2 = 3

# AOT artifact batch sizes. b32 mirrors one crossbar's 32-row linear WF
# buffer; b8 mirrors the 8 concurrent affine instances per crossbar. The
# larger variants are bulk-mode batches for the coordinator's batcher.
LINEAR_BATCHES = (32, 256)
AFFINE_BATCHES = (8, 64)
